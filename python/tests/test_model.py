"""L2 correctness: the model graphs and their AOT lowering."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from compile.aot import lower_codeword, lower_encode
from compile.kernels.gf_matmul import DEFAULT_P
from compile.kernels.ref import gf_matmul_ref
from compile.model import codeword, encode


def test_encode_shape_and_value():
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.integers(0, DEFAULT_P, (16, 4)), jnp.int32)
    x = jnp.asarray(rng.integers(0, DEFAULT_P, (16, 8)), jnp.int32)
    (y,) = encode(a, x)
    assert y.shape == (4, 8)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(gf_matmul_ref(a, x)))


def test_codeword_is_systematic():
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.integers(0, DEFAULT_P, (8, 4)), jnp.int32)
    x = jnp.asarray(rng.integers(0, DEFAULT_P, (8, 8)), jnp.int32)
    (cw,) = codeword(a, x)
    assert cw.shape == (12, 8)
    np.testing.assert_array_equal(np.asarray(cw[:8]), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(cw[8:]), np.asarray(gf_matmul_ref(a, x)))


def test_lowering_produces_hlo_text():
    text = lower_encode(8, 2, 4)
    assert "HloModule" in text
    assert "s32" in text  # int32 interface
    text = lower_codeword(8, 2, 4)
    assert "HloModule" in text


def test_lowered_hlo_has_no_custom_calls():
    # interpret=True must lower to plain HLO the CPU PJRT client can run —
    # a Mosaic custom-call here would break the rust side.
    text = lower_encode(16, 4, 8)
    assert "custom-call" not in text.lower()

"""L1 correctness: the Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes and values; exactness is required (integer
arithmetic — no tolerance).
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.gf_matmul import (
    DEFAULT_P,
    gf_matmul,
    mxu_utilization_estimate,
    vmem_bytes,
)
from compile.kernels.ref import gf_matmul_ref


def rand(rng, shape, p=DEFAULT_P):
    return jnp.asarray(rng.integers(0, p, size=shape, dtype=np.int64), jnp.int32)


@pytest.mark.parametrize(
    "k,r,w",
    [
        (1, 1, 1),
        (4, 4, 4),
        (16, 4, 64),
        (64, 16, 256),
        (48, 16, 256),
        (33, 7, 129),  # deliberately non-tile-aligned
        (128, 130, 5),
        (256, 1, 300),
    ],
)
def test_kernel_matches_ref_fixed_shapes(k, r, w):
    rng = np.random.default_rng(k * 1000 + r * 10 + w)
    a, x = rand(rng, (k, r)), rand(rng, (k, w))
    got = gf_matmul(a, x)
    want = gf_matmul_ref(a, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=40, deadline=None)
@given(
    k=st.integers(1, 96),
    r=st.integers(1, 40),
    w=st.integers(1, 160),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(k, r, w, seed):
    rng = np.random.default_rng(seed)
    a, x = rand(rng, (k, r)), rand(rng, (k, w))
    got = gf_matmul(a, x)
    want = gf_matmul_ref(a, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(
    p=st.sampled_from([786433, 65537, 12289, 257, 7]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_other_primes(p, seed):
    rng = np.random.default_rng(seed)
    a = rand(rng, (24, 8), p)
    x = rand(rng, (24, 16), p)
    got = gf_matmul(a, x, p=p)
    want = gf_matmul_ref(a, x, p=p)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_extreme_values_no_overflow():
    # All entries at p−1, K large enough to stress the accumulator.
    k, r, w = 512, 8, 8
    a = jnp.full((k, r), DEFAULT_P - 1, jnp.int32)
    x = jnp.full((k, w), DEFAULT_P - 1, jnp.int32)
    got = gf_matmul(a, x)
    want = gf_matmul_ref(a, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # Analytic check: K·(p−1)² mod p = K mod p.
    assert int(got[0, 0]) == (k * (DEFAULT_P - 1) ** 2) % DEFAULT_P


def test_output_range():
    rng = np.random.default_rng(0)
    a, x = rand(rng, (50, 20)), rand(rng, (50, 30))
    y = np.asarray(gf_matmul(a, x))
    assert y.min() >= 0 and y.max() < DEFAULT_P


def test_vmem_estimate_within_budget():
    # The DESIGN.md claim: K = 4096 with 128×128 tiles fits VMEM.
    assert vmem_bytes(4096) < 16 * 2**20


def test_mxu_estimate_bounds():
    u = mxu_utilization_estimate(64, 16, 256)
    assert 0.0 < u <= 1.0
    assert mxu_utilization_estimate(64, 128, 128) == 1.0

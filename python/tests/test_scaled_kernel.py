"""L1 correctness: the fused scaled-matmul kernel (§VI pattern)."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.gf_matmul import DEFAULT_P
from compile.kernels.gf_scaled_matmul import gf_scaled_matmul, gf_scaled_matmul_ref
from compile.kernels.ref import gf_matmul_ref


def rand(rng, shape, p=DEFAULT_P):
    return jnp.asarray(rng.integers(0, p, size=shape, dtype=np.int64), jnp.int32)


@pytest.mark.parametrize(
    "k,r,w",
    [(1, 1, 1), (8, 8, 8), (24, 10, 33), (64, 16, 256), (33, 130, 7)],
)
def test_scaled_matches_ref(k, r, w):
    rng = np.random.default_rng(k * 7 + r + w)
    pre, post = rand(rng, (k,)), rand(rng, (r,))
    a, x = rand(rng, (k, r)), rand(rng, (k, w))
    got = gf_scaled_matmul(pre, post, a, x)
    want = gf_scaled_matmul_ref(pre, post, a, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(1, 64),
    r=st.integers(1, 32),
    w=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_scaled_hypothesis(k, r, w, seed):
    rng = np.random.default_rng(seed)
    pre, post = rand(rng, (k,)), rand(rng, (r,))
    a, x = rand(rng, (k, r)), rand(rng, (k, w))
    got = gf_scaled_matmul(pre, post, a, x)
    want = gf_scaled_matmul_ref(pre, post, a, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_unit_scales_reduce_to_plain_matmul():
    rng = np.random.default_rng(1)
    k, r, w = 16, 8, 8
    ones_k = jnp.ones((k,), jnp.int32)
    ones_r = jnp.ones((r,), jnp.int32)
    a, x = rand(rng, (k, r)), rand(rng, (k, w))
    got = gf_scaled_matmul(ones_k, ones_r, a, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(gf_matmul_ref(a, x)))


def test_aot_lowering():
    from compile.aot import lower_scaled_encode

    text = lower_scaled_encode(16, 4, 8)
    assert "HloModule" in text
    assert "custom-call" not in text.lower()

"""Layer 2 — the JAX compute graphs lowered to PJRT artifacts.

Python runs only at build time (``make artifacts``); the rust coordinator
loads the resulting HLO text and executes it on the request path.

Graphs:
  * ``encode(a, x)``      — bulk parity computation ``(Aᵀ·X) mod p``,
                            the payload hot path (calls the Pallas kernel).
  * ``codeword(a, x)``    — systematic codeword ``[X; (Aᵀ·X) mod p]``,
                            used by the coordinator's verifier.
"""

import jax
import jax.numpy as jnp

from .kernels.gf_matmul import DEFAULT_P, gf_matmul
from .kernels.gf_scaled_matmul import gf_scaled_matmul

jax.config.update("jax_enable_x64", True)


def encode(a, x, *, p=DEFAULT_P):
    """Parity packets: int32[R, W] from A: int32[K, R], X: int32[K, W]."""
    return (gf_matmul(a, x, p=p),)


def codeword(a, x, *, p=DEFAULT_P):
    """Full systematic codeword int32[K+R, W] = [X; parity]."""
    parity = gf_matmul(a, x, p=p)
    return (jnp.concatenate([x, parity], axis=0),)


def scaled_encode(pre, post, a, x, *, p=DEFAULT_P):
    """The fused §VI block product ``diag(post)·Aᵀ·diag(pre)·X mod p``."""
    return (gf_scaled_matmul(pre, post, a, x, p=p),)

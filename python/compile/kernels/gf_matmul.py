"""Layer 1 — the Pallas GF(p) matmul kernel.

The compute hot-spot of decentralized encoding is bulk finite-field
encoding: ``Y = (Aᵀ · X) mod p`` for data ``X ∈ F_p^{K×W}`` and a coding
matrix ``A ∈ F_p^{K×R}`` (each column of ``A`` is one sink's linear
combination; each row of ``X`` is one source's W-symbol payload).

TPU mapping (DESIGN.md §2 Hardware-Adaptation): the kernel tiles the
*output* (R × W) across the grid, streams full-K panels of ``A`` and ``X``
HBM→VMEM per tile, accumulates on the MXU in one ``jnp.dot`` (exact in
int64: q < 2^20 ⇒ K·q² < 2^63 for K ≤ 2^22), and applies a single modulo
per output tile. ``interpret=True`` everywhere: the CPU PJRT plugin cannot
run Mosaic custom-calls, so interpret mode is the correctness path and the
TPU analysis is static (see EXPERIMENTS.md §Perf).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The repository's default NTT-friendly prime: 3·2^18 + 1 (see
# rust/src/gf/prime.rs — the two sides must agree).
DEFAULT_P = 786433

# Output tile sizes. 128 matches the MXU systolic array edge; the VMEM
# footprint per grid step is K·(TR + TW)·4 bytes for the operand panels
# plus TR·TW·8 for the accumulator — for K = 4096, TR = TW = 128 that is
# ~4.2 MiB, comfortably inside the ~16 MiB VMEM budget of a TPU core.
TILE_R = 128
TILE_W = 128


def _gf_matmul_kernel(a_ref, x_ref, o_ref, *, p):
    """One (TILE_R × TILE_W) output tile: o = (a_panelᵀ @ x_panel) mod p."""
    a = a_ref[...].astype(jnp.int64)  # (K, TR) panel
    x = x_ref[...].astype(jnp.int64)  # (K, TW) panel
    acc = jnp.dot(a.T, x)  # exact: K·p² < 2^63
    o_ref[...] = (acc % p).astype(jnp.int32)


def gf_matmul(a, x, *, p=DEFAULT_P, tile_r=TILE_R, tile_w=TILE_W):
    """``(Aᵀ·X) mod p`` via a tiled Pallas kernel.

    Args:
      a: int32[K, R] coding matrix, entries in [0, p).
      x: int32[K, W] payload matrix, entries in [0, p).
      p: field modulus (prime < 2^20 for exact int64 accumulation
         at any K ≤ 2^22).

    Returns:
      int32[R, W] coded payloads.
    """
    k, r = a.shape
    k2, w = x.shape
    assert k == k2, f"K mismatch: {k} vs {k2}"
    tr = min(tile_r, r)
    tw = min(tile_w, w)
    # Pallas requires the grid to cover the outputs exactly; pad to tiles.
    rp = -(-r // tr) * tr
    wp = -(-w // tw) * tw
    a_p = jnp.pad(a, ((0, 0), (0, rp - r)))
    x_p = jnp.pad(x, ((0, 0), (0, wp - w)))
    out = pl.pallas_call(
        partial(_gf_matmul_kernel, p=p),
        grid=(rp // tr, wp // tw),
        in_specs=[
            pl.BlockSpec((k, tr), lambda i, j: (0, i)),  # A panel: all K rows
            pl.BlockSpec((k, tw), lambda i, j: (0, j)),  # X panel: all K rows
        ],
        out_specs=pl.BlockSpec((tr, tw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp, wp), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(a_p, x_p)
    return out[:r, :w]


def vmem_bytes(k, tile_r=TILE_R, tile_w=TILE_W):
    """Static VMEM footprint estimate per grid step (bytes)."""
    panels = k * (tile_r + tile_w) * 4  # int32 operand panels
    acc = tile_r * tile_w * 8  # int64 accumulator
    out = tile_r * tile_w * 4
    return panels + acc + out


def mxu_utilization_estimate(k, r, w, tile_r=TILE_R, tile_w=TILE_W):
    """Fraction of MXU-issue slots doing useful work (static estimate):
    the int64 dot dominates; padding waste is the only inefficiency."""
    useful = r * w * k
    padded = (-(-r // tile_r) * tile_r) * (-(-w // tile_w) * tile_w) * k
    return useful / padded

"""Layer 1 — fused scaled GF(p) matmul kernel (the §VI block pattern).

The Cauchy-like A2A of §VI computes ``diag(pre)·A·diag(post)`` products:
every systematic-RS parity block is ``Φ^{-1}·V_α^{-1}·V_β·Ψ`` (Theorem 6).
On the bulk-payload path this fuses into one kernel:

    Y = (diag(post) · Aᵀ · (pre ⊙ X)) mod p
      i.e.  Y[j, c] = post[j] · Σ_k A[k, j]·pre[k]·X[k, c]   (mod p)

Fusing the diagonals avoids two extra HBM round-trips over X and Y —
the scales ride along in VMEM (K + T_R extra words per tile, noise next
to the K·(T_R+T_W) operand panels).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .gf_matmul import DEFAULT_P, TILE_R, TILE_W


def _scaled_kernel(pre_ref, post_ref, a_ref, x_ref, o_ref, *, p):
    pre = pre_ref[...].astype(jnp.int64)  # (K,)
    post = post_ref[...].astype(jnp.int64)  # (TR,)
    a = a_ref[...].astype(jnp.int64)  # (K, TR)
    x = x_ref[...].astype(jnp.int64)  # (K, TW)
    # Scale X rows by pre, reduce mod p to keep products in-range, then
    # one exact int64 dot and the post scale (one more mod each).
    xs = (x * pre[:, None]) % p
    acc = jnp.dot(a.T, xs) % p
    o_ref[...] = ((acc * post[:, None]) % p).astype(jnp.int32)


def gf_scaled_matmul(pre, post, a, x, *, p=DEFAULT_P, tile_r=TILE_R, tile_w=TILE_W):
    """``(diag(post)·Aᵀ·diag(pre)·X) mod p``.

    Args:
      pre:  int32[K] row scales (applied to X).
      post: int32[R] output scales.
      a:    int32[K, R] coding matrix.
      x:    int32[K, W] payloads.

    Returns:
      int32[R, W].
    """
    k, r = a.shape
    _, w = x.shape
    assert pre.shape == (k,) and post.shape == (r,)
    tr = min(tile_r, r)
    tw = min(tile_w, w)
    rp = -(-r // tr) * tr
    wp = -(-w // tw) * tw
    a_p = jnp.pad(a, ((0, 0), (0, rp - r)))
    x_p = jnp.pad(x, ((0, 0), (0, wp - w)))
    post_p = jnp.pad(post, (0, rp - r))
    out = pl.pallas_call(
        partial(_scaled_kernel, p=p),
        grid=(rp // tr, wp // tw),
        in_specs=[
            pl.BlockSpec((k,), lambda i, j: (0,)),
            pl.BlockSpec((tr,), lambda i, j: (i,)),
            pl.BlockSpec((k, tr), lambda i, j: (0, i)),
            pl.BlockSpec((k, tw), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tr, tw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp, wp), jnp.int32),
        interpret=True,
    )(pre, post_p, a_p, x_p)
    return out[:r, :w]


def gf_scaled_matmul_ref(pre, post, a, x, *, p=DEFAULT_P):
    """Pure-jnp oracle."""
    pre = pre.astype(jnp.int64)
    post = post.astype(jnp.int64)
    xs = (x.astype(jnp.int64) * pre[:, None]) % p
    acc = jnp.dot(a.astype(jnp.int64).T, xs) % p
    return ((acc * post[:, None]) % p).astype(jnp.int32)

"""Pure-jnp oracle for the Pallas kernel — the correctness ground truth.

No Pallas, no tiling: one exact int64 matmul followed by the modulo.
"""

import jax.numpy as jnp

from .gf_matmul import DEFAULT_P


def gf_matmul_ref(a, x, *, p=DEFAULT_P):
    """``(Aᵀ·X) mod p`` — reference implementation."""
    acc = jnp.dot(a.astype(jnp.int64).T, x.astype(jnp.int64))
    return (acc % p).astype(jnp.int32)

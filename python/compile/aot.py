"""AOT lowering: JAX graphs → HLO *text* → ``artifacts/*.hlo.txt``.

HLO text (NOT ``HloModuleProto.serialize()``) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:
    python -m compile.aot --out-dir ../artifacts            # default shapes
    python -m compile.aot --shapes 64x16x256,32x8x64 ...

Artifact naming: ``encode_K{K}_R{R}_W{W}_p{P}.hlo.txt`` plus a
``manifest.txt`` of one ``name k r w p`` line per artifact — consumed by
``rust/src/runtime/artifacts.rs``.
"""

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from .kernels.gf_matmul import DEFAULT_P  # noqa: E402
from .model import codeword, encode, scaled_encode  # noqa: E402

# The default artifact set: quickstart/bench shapes (K, R, W).
DEFAULT_SHAPES = [
    (16, 4, 64),
    (64, 16, 256),
    (48, 16, 256),
    (256, 64, 256),
]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_encode(k, r, w, p=DEFAULT_P) -> str:
    import jax.numpy as jnp

    a_spec = jax.ShapeDtypeStruct((k, r), jnp.int32)
    x_spec = jax.ShapeDtypeStruct((k, w), jnp.int32)
    return to_hlo_text(jax.jit(lambda a, x: encode(a, x, p=p)).lower(a_spec, x_spec))


def lower_codeword(k, r, w, p=DEFAULT_P) -> str:
    import jax.numpy as jnp

    a_spec = jax.ShapeDtypeStruct((k, r), jnp.int32)
    x_spec = jax.ShapeDtypeStruct((k, w), jnp.int32)
    return to_hlo_text(jax.jit(lambda a, x: codeword(a, x, p=p)).lower(a_spec, x_spec))


def lower_scaled_encode(k, r, w, p=DEFAULT_P) -> str:
    import jax.numpy as jnp

    pre_spec = jax.ShapeDtypeStruct((k,), jnp.int32)
    post_spec = jax.ShapeDtypeStruct((r,), jnp.int32)
    a_spec = jax.ShapeDtypeStruct((k, r), jnp.int32)
    x_spec = jax.ShapeDtypeStruct((k, w), jnp.int32)
    return to_hlo_text(
        jax.jit(lambda pre, post, a, x: scaled_encode(pre, post, a, x, p=p)).lower(
            pre_spec, post_spec, a_spec, x_spec
        )
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--shapes",
        default=",".join(f"{k}x{r}x{w}" for k, r, w in DEFAULT_SHAPES),
        help="comma-separated KxRxW triples",
    )
    ap.add_argument("--prime", type=int, default=DEFAULT_P)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = []
    for spec in args.shapes.split(","):
        k, r, w = (int(t) for t in spec.split("x"))
        for kind, lower in (
            ("encode", lower_encode),
            ("codeword", lower_codeword),
            ("scaled_encode", lower_scaled_encode),
        ):
            name = f"{kind}_K{k}_R{r}_W{w}_p{args.prime}"
            path = os.path.join(args.out_dir, f"{name}.hlo.txt")
            text = lower(k, r, w, args.prime)
            with open(path, "w") as fh:
                fh.write(text)
            manifest.append(f"{kind} {k} {r} {w} {args.prime} {name}.hlo.txt")
            print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as fh:
        fh.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()

#!/usr/bin/env python3
"""Gate bench results against committed baselines.

Usage:
    bench_trend.py --baseline-dir DIR --current-dir DIR FILE [FILE...]

Each FILE is a ``BENCH_*.json`` emitted by one of the ``harness = false``
bench binaries (they write to the repo root). The committed copy at the
repo root is the baseline; a CI run stashes it aside, re-runs the bench,
and compares.

Gating rules
------------
* The current file must exist, parse, and carry the same ``"bench"``
  name as the baseline.
* A baseline marked ``"seed_baseline": true`` has never been measured:
  only structure is checked, and a refresh notice is printed. Committing
  the artifact of a real (non-smoke) bench run replaces it.
* **Deterministic** fields gate unconditionally:
  - ``slots_after`` must not increase (optimizer regressions),
  - ``recovery_exact``, ``packed_equals_scalar``,
    ``simd_equals_scalar``, ``backend_equals_dense``,
    ``responses_match_direct``, ``shutdown_drained``,
    ``peer_equals_replay``, ``peer_matches_statics``,
    ``transient_bit_identical`` and ``peer_degraded_equals_analysis``
    must not flip away from ``true``.
* **Timing** fields gate only when *both* files were produced with
  ``smoke == false`` (a real multi-iteration run on comparable
  hardware). Smoke runs execute one iteration on shared runners — their
  timings are reported as advisory deltas, never failed on:
  - lower-is-better (fail when current > 1.30 x baseline):
    ``singles_us_per_job``, ``batch_us_per_job``, ``us_per_job``,
    ``packed_us_per_job``, ``dense_us_per_job``, ``ntt_us_per_job``,
    ``gemm_us``, ``p50_us``, ``p99_us``, ``p999_us``;
  - higher-is-better (fail when current < baseline / 1.30):
    ``speedup``, ``recovered_per_s``, ``axpy_speedup``,
    ``lincomb_speedup``, ``gemm_speedup``,
    ``gemm_speedup_vs_scalar_tier``, ``speedup_vs_single_queue``,
    ``sharded_throughput_req_per_s``.
* Seed and smoke baselines are **loudly flagged**: a ``WARN`` line (and
  a GitHub ``::warning::`` annotation when running under Actions) makes
  an ungated comparison impossible to mistake for a passing gate.
* ``crossover_k`` (the measured dense→NTT crossover of the K-sweep in
  ``BENCH_ntt.json``) is **advisory**: a shift is printed as a notice,
  never failed on — it moves with the hardware, not with regressions.

Exit status: 0 when every gate passes, 1 otherwise.
"""

import argparse
import json
import os
import sys

TOLERANCE = 1.30
TIMING_LOWER_BETTER = {
    "singles_us_per_job",
    "batch_us_per_job",
    "us_per_job",
    "packed_us_per_job",
    "dense_us_per_job",
    "ntt_us_per_job",
    "gemm_us",
    "p50_us",
    "p99_us",
    "p999_us",
}
TIMING_HIGHER_BETTER = {
    "speedup",
    "recovered_per_s",
    "axpy_speedup",
    "lincomb_speedup",
    "gemm_speedup",
    "gemm_speedup_vs_scalar_tier",
    "speedup_vs_single_queue",
    "sharded_throughput_req_per_s",
}
EXACT_LOWER_OR_EQUAL = {"slots_after"}
# Booleans that may never flip away from true: exact erasure recovery,
# packed-kernel/scalar bit-identity, SIMD-tier/scalar-tier bit-identity,
# NTT-backend/dense bit-identity, serving-tier/direct-path bit-identity,
# the zero-drop graceful-shutdown guarantee, peer-execution
# bit-identity / measured-traffic == plan-statics conformance, and the
# chaos invariants (transient faults absorbed bit-identically; the
# peer-side degraded report equal to the replay engine's analysis).
EXACT_MUST_HOLD = {
    "recovery_exact",
    "packed_equals_scalar",
    "simd_equals_scalar",
    "backend_equals_dense",
    "responses_match_direct",
    "shutdown_drained",
    "peer_equals_replay",
    "peer_matches_statics",
    "transient_bit_identical",
    "peer_degraded_equals_analysis",
}
# Numbers that move with the hardware, not with regressions: report
# shifts as notices, never failures.
ADVISORY_SHIFT = {"crossover_k"}
# Keys that identify entries when aligning lists of objects.
ALIGN_KEYS = ("name", "failed")

failures = []
notices = []
warnings = []


def warn(name, title, detail):
    """A loud, ungated-run warning: WARN line + GitHub annotation."""
    warnings.append(f"{name}: {detail}")
    if os.environ.get("GITHUB_ACTIONS") == "true":
        # Surfaces in the Actions run summary and on the PR checks tab,
        # so an ungated comparison is visible without opening the log.
        print(f"::warning title={title}::{name}: {detail}")


def align(base_list, cur_list):
    """Pair up list entries by an identifying key, else by index."""
    if base_list and isinstance(base_list[0], dict):
        for key in ALIGN_KEYS:
            if all(isinstance(e, dict) and key in e for e in base_list + cur_list):
                cur_by = {e[key]: e for e in cur_list}
                return [
                    (f"[{key}={b[key]}]", b, cur_by.get(b[key]))
                    for b in base_list
                ]
    pairs = []
    for i, b in enumerate(base_list):
        pairs.append((f"[{i}]", b, cur_list[i] if i < len(cur_list) else None))
    return pairs


def compare(path, base, cur, timing_gated):
    if isinstance(base, dict):
        if not isinstance(cur, dict):
            failures.append(f"{path}: object became {type(cur).__name__}")
            return
        for k, bv in base.items():
            if k not in cur:
                failures.append(f"{path}.{k}: missing from current result")
                continue
            compare_field(f"{path}.{k}", k, bv, cur[k], timing_gated)
    elif isinstance(base, list):
        if not isinstance(cur, list):
            failures.append(f"{path}: list became {type(cur).__name__}")
            return
        for tag, b, c in align(base, cur):
            if c is None:
                failures.append(f"{path}{tag}: entry missing from current result")
            else:
                compare(f"{path}{tag}", b, c, timing_gated)


def compare_field(path, key, bv, cv, timing_gated):
    if isinstance(bv, (dict, list)):
        compare(path, bv, cv, timing_gated)
        return
    if key in EXACT_MUST_HOLD:
        if bv is True and cv is not True:
            failures.append(f"{path}: was {bv!r}, now {cv!r}")
        return
    if key in ADVISORY_SHIFT:
        if bv != cv:
            notices.append(f"advisory {path}: shifted {bv!r} -> {cv!r}")
        return
    if key in EXACT_LOWER_OR_EQUAL:
        if isinstance(bv, (int, float)) and isinstance(cv, (int, float)) and cv > bv:
            failures.append(f"{path}: regressed {bv} -> {cv} (must not increase)")
        return
    if key in TIMING_LOWER_BETTER or key in TIMING_HIGHER_BETTER:
        if not isinstance(bv, (int, float)) or not isinstance(cv, (int, float)):
            return
        if bv <= 0:
            return
        ratio = cv / bv
        worse = ratio > TOLERANCE if key in TIMING_LOWER_BETTER else ratio < 1 / TOLERANCE
        line = f"{path}: {bv:.3f} -> {cv:.3f} ({ratio:.2f}x)"
        if not timing_gated:
            notices.append(f"advisory (smoke timings not gated) {line}")
        elif worse:
            failures.append(f"{line} exceeds the {TOLERANCE - 1:.0%} regression tolerance")


def check_file(name, baseline_dir, current_dir):
    base_path = os.path.join(baseline_dir, name)
    cur_path = os.path.join(current_dir, name)
    if not os.path.exists(base_path):
        failures.append(f"{name}: no committed baseline at {base_path}")
        return
    if not os.path.exists(cur_path):
        failures.append(f"{name}: bench did not produce {cur_path}")
        return
    try:
        base = json.load(open(base_path))
    except json.JSONDecodeError as e:
        failures.append(f"{name}: baseline is not valid JSON: {e}")
        return
    try:
        cur = json.load(open(cur_path))
    except json.JSONDecodeError as e:
        failures.append(f"{name}: current result is not valid JSON: {e}")
        return
    if base.get("bench") != cur.get("bench"):
        failures.append(
            f"{name}: bench name changed: {base.get('bench')!r} -> {cur.get('bench')!r}"
        )
        return
    if base.get("seed_baseline"):
        warn(
            name,
            "seed bench baseline — numbers NOT gated",
            "seed baseline (never measured): structure checked only, every "
            "number is ungated; commit a fresh non-smoke run of this bench "
            "to start gating (CI's bench-refresh job does this on main)",
        )
        return
    timing_gated = base.get("smoke") is False and cur.get("smoke") is False
    if not timing_gated:
        warn(
            name,
            "smoke bench baseline — timings NOT gated",
            f"smoke-mode timings (base smoke={base.get('smoke')}, "
            f"current smoke={cur.get('smoke')}): timing deltas advisory "
            f"only, deterministic fields still gated",
        )
    compare(name, base, cur, timing_gated)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline-dir", required=True)
    ap.add_argument("--current-dir", required=True)
    ap.add_argument("files", nargs="+")
    args = ap.parse_args()
    for name in args.files:
        check_file(name, args.baseline_dir, args.current_dir)
    for w in warnings:
        print(f"WARN  {w}")
    for n in notices:
        print(f"NOTE  {n}")
    for f in failures:
        print(f"FAIL  {f}")
    if failures:
        print(f"\nbench-trend: {len(failures)} regression(s) against committed baselines")
        return 1
    if warnings:
        print(
            f"\nbench-trend: OK with {len(warnings)} WARNING(s) — some numbers "
            f"were NOT gated ({len(args.files)} result file(s) checked)"
        )
        return 0
    print(f"\nbench-trend: OK ({len(args.files)} result file(s) checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

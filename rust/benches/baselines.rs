//! Bench: **§II baselines** — prepare-and-shoot versus (a) the Jeong et
//! al. [21] multi-reduce (all-gather + combine) and (b) the naive direct
//! transfer ([22]-style). Reproduces the paper's stated gap
//! `(K − 2√K − 1)·β⌈log2 q⌉·W` against multi-reduce, and the Θ(K) vs
//! Θ(√K) separation against direct transfer.

use dce::collectives::{DirectEncode, MultiReduce, PrepareShoot};
use dce::framework::costs;
use dce::gf::{Field, GfPrime, Mat};
use dce::net::{run, Packet, Sim, SimReport};
use dce::util::bench;
use std::sync::Arc;

fn inputs(f: &GfPrime, k: usize, w: usize) -> Vec<Packet> {
    (0..k)
        .map(|i| (0..w).map(|j| f.elem((i + j) as u64 + 1)).collect())
        .collect()
}

fn run_ps(f: &GfPrime, k: usize, w: usize, p: usize) -> SimReport {
    let c = Arc::new(Mat::random(f, k, k, 1));
    let mut ps = PrepareShoot::new(*f, (0..k).collect(), p, c, inputs(f, k, w));
    run(&mut Sim::new(p), &mut ps).unwrap()
}

fn run_mr(f: &GfPrime, k: usize, w: usize, p: usize) -> SimReport {
    let c = Arc::new(Mat::random(f, k, k, 1));
    let mut mr = MultiReduce::new(*f, (0..k).collect(), p, c, inputs(f, k, w));
    run(&mut Sim::new(p), &mut mr).unwrap()
}

fn main() {
    let f = GfPrime::default_field();

    println!("## multi-reduce gap (one port): C2(mr) − C2(ps) vs (K − 2√K − 1)·W");
    println!(
        "{:>5} {:>3} | {:>8} {:>8} | {:>9} {:>12}",
        "K", "W", "C2 ps", "C2 mr", "gap meas", "gap formula"
    );
    for &(k, w) in &[
        (16usize, 1usize),
        (64, 1),
        (256, 1),
        (1024, 1),
        (64, 8),
        (256, 8),
    ] {
        let ps = run_ps(&f, k, w, 1);
        let mr = run_mr(&f, k, w, 1);
        let gap = mr.c2 as i64 - ps.c2 as i64;
        let formula = costs::multireduce_gap(k as u64, w as u64);
        println!(
            "{k:>5} {w:>3} | {:>8} {:>8} | {gap:>9} {formula:>12.1}",
            ps.c2, mr.c2
        );
        // The measured gap matches the paper's expression up to the O(1)
        // slack in "2√K" for non-square K.
        assert!(mr.c2 >= ps.c2);
        assert_eq!(mr.c2, costs::multireduce_c2(k as u64, w as u64, 1));
    }

    println!("\n## multi-port multi-reduce (the [21] restriction lifted)");
    println!("{:>5} {:>2} | {:>8} {:>8}", "K", "p", "C2 ps", "C2 mr");
    for &(k, p) in &[(81usize, 2usize), (256, 3), (625, 4)] {
        let ps = run_ps(&f, k, 1, p);
        let mr = run_mr(&f, k, 1, p);
        println!("{k:>5} {p:>2} | {:>8} {:>8}", ps.c2, mr.c2);
        assert!(mr.c2 >= ps.c2);
    }

    println!("\n## direct transfer ([22]-style strawman): Θ(K) rounds");
    println!(
        "{:>5} {:>4} {:>2} | {:>8} {:>8} | {:>10}",
        "K", "R", "p", "C1", "C2", "bandwidth"
    );
    for &(k, r, p) in &[
        (32usize, 4usize, 1usize),
        (64, 8, 1),
        (128, 8, 2),
        (256, 16, 4),
    ] {
        let a = Arc::new(Mat::random(&f, k, r, 2));
        let mut d = DirectEncode::new(
            f,
            (0..k).collect(),
            (k..k + r).collect(),
            p,
            a,
            inputs(&f, k, 1),
        );
        let rep = run(&mut Sim::new(p), &mut d).unwrap();
        println!(
            "{k:>5} {r:>4} {p:>2} | {:>8} {:>8} | {:>10}",
            rep.c1, rep.c2, rep.bandwidth
        );
        assert!(rep.c1 as usize >= k.min(r * k / (p * (k + r))));
    }

    println!("\n## wall-clock");
    for &k in &[256usize, 1024] {
        println!("{}", bench(&format!("prepare-shoot K={k}"), 5, |_| run_ps(&f, k, 1, 1)));
        println!("{}", bench(&format!("multi-reduce  K={k}"), 5, |_| run_mr(&f, k, 1, 1)));
    }
    println!("\nbaselines bench complete");
}

//! Bench: **plan-cache replay vs per-request live stepping** — the
//! amortized serving-path win of the compile/execute split.
//!
//! Scenario: a service receives `N` same-shape encode requests. The
//! pre-Plan-IR path re-plans and re-steps the collective per request;
//! the cached path compiles the schedule once (the first request's
//! cache miss, included in the timed region) and replays it for every
//! request. Acceptance target: ≥ 2× amortized speedup, asserted below
//! (skipped under `DCE_BENCH_SMOKE=1`, where everything runs once so CI
//! can't let this target rot).

use dce::coordinator::config::VerifyMode;
use dce::coordinator::{EncodeJob, ExecOptions, JobConfig, PlanCache};
use dce::framework::AlgoRequest;
use dce::gf::Field;
use dce::net::{run, Packet, Sim};
use dce::util::{bench_iters, bench_smoke, Rng};
use std::time::Instant;

fn main() {
    let requests = bench_iters(32);
    let cfg = JobConfig {
        k: 64,
        r: 16,
        w: 64,
        ports: 2,
        algorithm: AlgoRequest::Universal,
        verify: VerifyMode::Off,
        ..JobConfig::default()
    };
    let job = EncodeJob::synthetic(cfg.clone()).unwrap();
    let f = job.field.clone();
    let mut rng = Rng::new(7);
    let payloads: Vec<Vec<Packet>> = (0..requests)
        .map(|_| {
            (0..cfg.k)
                .map(|_| (0..cfg.w).map(|_| rng.below(f.order())).collect())
                .collect()
        })
        .collect();

    println!("## plan replay vs live stepping (K=64 R=16 W=64 p=2, {requests} requests)");

    // Live path: plan + step the collective per request.
    let t0 = Instant::now();
    let mut live_out: Vec<Vec<Packet>> = Vec::with_capacity(requests);
    for x in &payloads {
        let mut pl = dce::framework::plan_with_model(
            &f,
            job.code.as_ref(),
            Some(job.parity.clone()),
            x.clone(),
            cfg.ports,
            cfg.algorithm,
            Some(cfg.cost_model().unwrap()),
        )
        .unwrap();
        run(&mut Sim::new(cfg.ports), pl.job.as_mut()).unwrap();
        let outs = pl.job.outputs();
        live_out.push(
            (0..pl.layout.r)
                .map(|r| outs[&pl.layout.sink(r)].clone())
                .collect(),
        );
    }
    let live_total = t0.elapsed();

    // Cached path: compile once, replay per request (compile included).
    let cache = PlanCache::new();
    let t0 = Instant::now();
    let mut cached_out: Vec<Vec<Packet>> = Vec::with_capacity(requests);
    for x in &payloads {
        cached_out.push(job.encode(&cache, &[x], &ExecOptions::cached(&cache)).unwrap().coded.remove(0));
    }
    let cached_total = t0.elapsed();

    assert_eq!(live_out, cached_out, "replay must be bit-identical to live stepping");
    assert_eq!(cache.stats(), (requests as u64 - 1, 1), "one miss, rest hits");

    let speedup = live_total.as_secs_f64() / cached_total.as_secs_f64();
    println!(
        "live stepping : {live_total:>12?} total  ({:>10?}/req)",
        live_total / requests as u32
    );
    println!(
        "plan replay   : {cached_total:>12?} total  ({:>10?}/req, compile amortized)",
        cached_total / requests as u32
    );
    println!("amortized speedup: {speedup:.2}x (acceptance target >= 2x)");
    if bench_smoke() {
        println!("(smoke mode: timing assertion skipped)");
    } else {
        assert!(
            speedup >= 2.0,
            "plan-cache replay must be >= 2x live stepping, got {speedup:.2}x"
        );
    }

    // Width-independence: the same cached plan serves other widths.
    for w in [16usize, 256] {
        let x: Vec<Packet> = (0..cfg.k)
            .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
            .collect();
        let t0 = Instant::now();
        let y = job.encode(&cache, &[&x], &ExecOptions::cached(&cache)).unwrap().coded.remove(0);
        let dt = t0.elapsed();
        assert_eq!(y.len(), cfg.r);
        println!("replay W={w:<4} (same plan, no recompile): {dt:?}");
    }
    assert_eq!(cache.len(), 1, "one shape, one compiled plan across widths");

    println!("\nplan_replay bench complete");
}

//! Bench: **Table I** — costs of the all-to-all encode schemes, measured
//! on the round engine against the paper's closed forms, plus the
//! Lemma 1/2 lower bounds and wall-clock timings.
//!
//! Regenerates:
//!   * row 1 (universal / Theorem 3) over K ∈ {16..4096}, p ∈ {1,2,3,4},
//!   * row 2 (DFT / Theorem 4 + Corollary 1) for K = P^H,
//!   * row 3 (Vandermonde / Theorem 5) for K = M·P^H.

use dce::codes::StructuredPoints;
use dce::collectives::{DftA2A, DrawLoose, PrepareShoot};
use dce::framework::costs;
use dce::gf::{Field, GfPrime, Mat};
use dce::net::{run, Packet, Sim, SimReport};
use dce::util::{bench, ipow};
use std::sync::Arc;

fn inputs(f: &GfPrime, k: usize) -> Vec<Packet> {
    (0..k as u64).map(|i| vec![f.elem(i * 7 + 1)]).collect()
}

fn run_universal(f: &GfPrime, k: usize, p: usize) -> SimReport {
    let c = Arc::new(Mat::random(f, k, k, k as u64));
    let mut ps = PrepareShoot::new(*f, (0..k).collect(), p, c, inputs(f, k));
    run(&mut Sim::new(p), &mut ps).expect("universal run")
}

fn main() {
    let f = GfPrime::default_field();

    println!("## Table I row 1 — universal (prepare-and-shoot, Theorem 3)");
    println!(
        "{:>5} {:>2} | {:>8} {:>8} | {:>8} {:>8} {:>9} | {:>12}",
        "K", "p", "C1 meas", "C1 thm", "C2 meas", "C2 thm", "C2 lower", "wall(med)"
    );
    for &p in &[1usize, 2, 3, 4] {
        for &k in &[16usize, 64, 256, 1024, 4096] {
            let rep = run_universal(&f, k, p);
            let (c1t, c2t) = costs::theorem3_universal(k as u64, p as u64);
            let lb = costs::lemma2_c2_lower_bound(k as u64, p as u64);
            let iters = if k >= 1024 { 3 } else { 10 };
            let stats = bench("univ", iters, |_| run_universal(&f, k, p));
            println!(
                "{k:>5} {p:>2} | {:>8} {:>8} | {:>8} {:>8} {:>9.1} | {:>12?}",
                rep.c1, c1t, rep.c2, c2t, lb, stats.median
            );
            assert_eq!(rep.c1, c1t, "C1 must equal Lemma-1 optimum");
            assert!(rep.c2 <= c2t, "C2 must not exceed Theorem 3");
        }
    }

    println!("\n## Table I row 2 — DFT (Theorem 4; Corollary 1 when P = p+1)");
    println!(
        "{:>5} {:>2} {:>3} {:>2} | {:>8} {:>8} | {:>8} {:>8} | {:>12}",
        "K", "P", "H", "p", "C1 meas", "C1 thm", "C2 meas", "C2 thm", "wall(med)"
    );
    for &(p_base, h, p) in &[
        (2u64, 4u32, 1usize),
        (2, 8, 1),
        (2, 10, 1),
        (4, 4, 3),
        (4, 6, 3),
        (8, 3, 7),
        (2, 8, 3),
    ] {
        let k = ipow(p_base, h) as usize;
        let runner = || {
            let mut d = DftA2A::new(
                f,
                (0..k).collect(),
                p,
                p_base,
                h,
                inputs(&f, k),
                false,
            )
            .expect("dft");
            run(&mut Sim::new(p), &mut d).expect("dft run")
        };
        let rep = runner();
        let (c1t, c2t) = costs::theorem4_dft(p_base, h, p as u64);
        let stats = bench("dft", if k >= 1024 { 3 } else { 10 }, |_| runner());
        println!(
            "{k:>5} {p_base:>2} {h:>3} {p:>2} | {:>8} {:>8} | {:>8} {:>8} | {:>12?}",
            rep.c1, c1t, rep.c2, c2t, stats.median
        );
        assert_eq!(rep.c1, c1t);
        assert!(rep.c2 <= c2t);
    }

    println!("\n## Table I row 3 — Vandermonde (draw-and-loose, Theorem 5)");
    println!(
        "{:>5} {:>3} {:>4} {:>2} | {:>8} {:>8} | {:>8} {:>8} | {:>12}",
        "K", "M", "Z", "p", "C1 meas", "C1 thm", "C2 meas", "C2 thm", "wall(med)"
    );
    for &(n, p_base, p) in &[
        (24usize, 2u64, 1usize),
        (48, 2, 1),
        (96, 2, 1),
        (192, 2, 1),
        (768, 2, 1),
        (48, 4, 3),
        (192, 4, 3),
    ] {
        let h = StructuredPoints::max_h(&f, n as u64, p_base);
        let z = ipow(p_base, h);
        let m = n / z as usize;
        let sp = StructuredPoints::new(&f, n, p_base, (0..m as u64).collect()).expect("design");
        let runner = || {
            let mut dl =
                DrawLoose::new(f, (0..n).collect(), p, &sp, inputs(&f, n), false).expect("dl");
            run(&mut Sim::new(p), &mut dl).expect("dl run")
        };
        let rep = runner();
        let (c1t, c2t) = costs::theorem5_vandermonde(m as u64, p_base, h, p as u64);
        let stats = bench("vand", if n >= 256 { 3 } else { 10 }, |_| runner());
        println!(
            "{n:>5} {m:>3} {z:>4} {p:>2} | {:>8} {:>8} | {:>8} {:>8} | {:>12?}",
            rep.c1, c1t, rep.c2, c2t, stats.median
        );
        assert_eq!(rep.c1, c1t);
        assert!(rep.c2 <= c2t);
    }

    println!("\n## Remark 7 — universal C2 within √2 of the Lemma 2 bound");
    println!("{:>6} | {:>8} {:>9} {:>6}", "K", "C2 univ", "C2 lower", "ratio");
    for &k in &[256u64, 1024, 4096, 16384, 65536] {
        let (_, c2) = costs::theorem3_universal(k, 1);
        let lb = costs::lemma2_c2_lower_bound(k, 1);
        println!("{k:>6} | {c2:>8} {lb:>9.1} {:>6.3}", c2 as f64 / lb);
    }
    println!("\ntable1 bench complete");
}

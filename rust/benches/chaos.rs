//! Bench: **chaos-hardened peer execution** — what fault tolerance
//! costs on the wire and through the coordinator.
//!
//! Two sweeps, correctness asserted inline every iteration:
//!
//! * **Transient absorption** — the same plan runs over every transport
//!   through a clean mesh and through a mesh with full-rate injected
//!   delay + duplication + reorder; outputs must stay bit-identical and
//!   nothing may be reported dropped (`transient_bit_identical` in the
//!   JSON is a hard trend gate). Retry and delayed-round counts land in
//!   the rows.
//! * **Degraded sweep** — the coordinator's peer engine runs with `F`
//!   post-run sink crashes for `F` in {0, 1, 2, 4}; lost rows must be
//!   healed bit-identically to the healthy oracle and the peer-side
//!   degraded telemetry must agree with the replay engine's analysis
//!   (`peer_degraded_equals_analysis` is a hard trend gate). Recovery
//!   wall time and recovered-row counts land in the rows.
//!
//! Results land in `BENCH_chaos.json` at the repo root.

use dce::coordinator::config::VerifyMode;
use dce::coordinator::{EncodeJob, Engine, ExecOptions, JobConfig, PlanCache};
use dce::framework::{A2aAlgo, AlgoRequest, SystematicEncode};
use dce::gf::{Field, GfPrime, Mat};
use dce::net::peer::{spawn_local_chaos, DegradedPeerRun, RetryPolicy, ShardedPlan};
use dce::net::transport::{ChaosSpec, TransportKind};
use dce::net::{exec, plan, Collective, FaultSpec, Packet, ProcId};
use dce::util::{bench_iters, bench_smoke, Rng};
use std::sync::Arc;
use std::time::{Duration, Instant};

const TIMEOUT: Duration = Duration::from_secs(20);

struct TransientRow {
    kind: String,
    clean_us: u64,
    chaos_us: u64,
    retries: u64,
    rounds_delayed: u64,
}

struct SweepRow {
    lost: usize,
    run_us: u64,
    recovery_us: u64,
    recovered: u64,
}

fn median_us(samples: &mut Vec<u64>) -> u64 {
    samples.sort_unstable();
    samples[samples.len() / 2]
}

/// Every transient knob at full rate: each receive sees a stale
/// duplicate, a delayed attempt, and a reordered attempt before the
/// real frame lands — the worst stacking the retry budget must absorb.
fn full_transients(seed: u64) -> ChaosSpec {
    ChaosSpec::new()
        .with_seed(seed)
        .delay(1000, 1)
        .dup(1000)
        .reorder(1000)
}

fn peer_channel() -> Engine {
    Engine::Peer(TransportKind::Channel)
}

/// Median wall time of `iters` chaos-mesh runs, plus the last run.
fn timed_mesh(
    sharded: &ShardedPlan,
    f: &GfPrime,
    inputs: &[Packet],
    kind: TransportKind,
    spec: &ChaosSpec,
    iters: usize,
) -> (u64, DegradedPeerRun) {
    let policy = RetryPolicy::default();
    let mut samples = Vec::with_capacity(iters);
    let mut last = None;
    for _ in 0..iters {
        let t0 = Instant::now();
        let run = spawn_local_chaos(sharded, f, inputs, kind, TIMEOUT, spec, &policy)
            .unwrap_or_else(|e| panic!("mesh over {kind}: {e:#}"));
        samples.push(t0.elapsed().as_micros() as u64);
        last = Some(run);
    }
    (median_us(&mut samples), last.expect("at least one iteration"))
}

fn main() {
    let iters = bench_iters(12);
    let smoke = bench_smoke();
    let mut bit_identical = true;
    let mut equals_analysis = true;

    // Part 1: transient absorption at the mesh layer, per transport.
    let f = GfPrime::default_field();
    let (k, r, p, w) = (12usize, 4usize, 2usize, 16usize);
    let a = Arc::new(Mat::random(&f, k, r, 0xC4A0_5EED));
    let build = move |ins: Vec<Packet>| -> Box<dyn Collective> {
        Box::new(SystematicEncode::new(f, a, ins, p, A2aAlgo::Universal).unwrap())
    };
    let compiled = plan::compile(p, k, |basis| Ok(build(basis))).unwrap();
    let mut rng = Rng::new(0xC4A0);
    let inputs: Vec<Packet> = (0..k)
        .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
        .collect();
    let rep = exec::replay(&compiled, &f, &inputs).unwrap();
    let owners: Vec<ProcId> = (0..compiled.n_inputs).collect();
    let sharded = ShardedPlan::new(&compiled, &f, &owners).unwrap();
    println!("## transient absorption: K={k} R={r} p={p} W={w}, full-rate delay+dup+reorder");

    let clean = ChaosSpec::new();
    let chaos = full_transients(0xBE2C);
    let mut transients = Vec::new();
    for kind in TransportKind::ALL {
        let (clean_us, base) = timed_mesh(&sharded, &f, &inputs, kind, &clean, iters);
        if base.outputs != rep.outputs {
            bit_identical = false;
        }
        let (chaos_us, run) = timed_mesh(&sharded, &f, &inputs, kind, &chaos, iters);
        if run.outputs != rep.outputs || run.report.dropped_messages != 0 {
            bit_identical = false;
        }
        let retries = run.retries;
        let delayed = run.rounds_delayed;
        println!(
            "  {kind:<7}: clean {clean_us:>7} us, chaos {chaos_us:>7} us, \
             retries {retries}, rounds delayed {delayed}"
        );
        transients.push(TransientRow {
            kind: kind.to_string(),
            clean_us,
            chaos_us,
            retries,
            rounds_delayed: delayed,
        });
    }

    // Part 2: degraded healing through the coordinator, channel mesh.
    let cfg = JobConfig {
        k: 16,
        r: 8,
        w: 32,
        ports: 2,
        algorithm: AlgoRequest::Universal,
        verify: VerifyMode::Off,
        ..JobConfig::default()
    };
    let job = EncodeJob::synthetic(cfg).unwrap();
    let cache = PlanCache::new();
    let opts = ExecOptions::cached(&cache);
    let healthy = job.encode(&cache, &[&job.inputs], &opts).unwrap();
    println!("## degraded sweep: K=16 R=8 W=32, crash_after on F sinks, channel mesh");

    let mut sweep = Vec::new();
    for lost in [0usize, 1, 2, 4] {
        let mut spec = FaultSpec::new();
        for pid in 16..16 + lost {
            spec = spec.crash_after(pid);
        }
        let opts_f = if lost == 0 {
            opts.engine(peer_channel())
        } else {
            opts.faults(&spec).engine(peer_channel())
        };
        let mut samples = Vec::with_capacity(iters);
        let mut last = None;
        for _ in 0..iters {
            let t0 = Instant::now();
            let rep = job.run(&opts_f).expect("peer run");
            samples.push(t0.elapsed().as_micros() as u64);
            last = Some(rep);
        }
        let us = median_us(&mut samples);
        let rep = last.expect("at least one iteration");

        let out = job.encode(&cache, &[&job.inputs], &opts_f).unwrap();
        if out.coded != healthy.coded {
            equals_analysis = false;
        }
        let (rec_us, recovered) = match &out.recovery {
            Some(s) => (s.recovery_wall.as_micros() as u64, s.outputs_recovered),
            None => (0, 0),
        };
        if lost > 0 {
            let replayed = job.run(&opts.faults(&spec)).unwrap();
            let da = replayed.degraded.as_ref().expect("replay degraded");
            let db = rep.degraded.as_ref().expect("peer degraded");
            if db.coded != da.coded || db.crashed != da.crashed {
                equals_analysis = false;
            }
            if db.lost_sinks != da.lost_sinks || rep.sim != replayed.sim {
                equals_analysis = false;
            }
            if recovered != lost as u64 {
                equals_analysis = false;
            }
        }
        println!("  lost={lost}: {us:>8} us/run (recovery {rec_us} us, {recovered} rows)");
        sweep.push(SweepRow {
            lost,
            run_us: us,
            recovery_us: rec_us,
            recovered,
        });
    }

    assert!(bit_identical, "transient chaos must leave outputs bit-identical");
    assert!(equals_analysis, "peer degraded path must match replay analysis");

    let transient_json: Vec<String> = transients
        .iter()
        .map(|t| {
            format!(
                concat!(
                    "{{\"kind\":\"{}\",\"clean_us\":{},\"chaos_us\":{},",
                    "\"retries\":{},\"rounds_delayed\":{}}}"
                ),
                t.kind,
                t.clean_us,
                t.chaos_us,
                t.retries,
                t.rounds_delayed
            )
        })
        .collect();
    let sweep_json: Vec<String> = sweep
        .iter()
        .map(|s| {
            format!(
                concat!(
                    "{{\"lost_sinks\":{},\"run_us\":{},",
                    "\"recovery_us\":{},\"outputs_recovered\":{}}}"
                ),
                s.lost,
                s.run_us,
                s.recovery_us,
                s.recovered
            )
        })
        .collect();
    let json = format!(
        concat!(
            "{{\"bench\":\"chaos\",\"smoke\":{},\"iters\":{},",
            "\"transient_bit_identical\":{},",
            "\"peer_degraded_equals_analysis\":{},",
            "\"transients\":[{}],\"sweep\":[{}]}}"
        ),
        smoke,
        iters,
        bit_identical,
        equals_analysis,
        transient_json.join(","),
        sweep_json.join(",")
    );
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("manifest dir has a parent")
        .join("BENCH_chaos.json");
    std::fs::write(&path, format!("{json}\n"))
        .unwrap_or_else(|e| panic!("could not write {}: {e}", path.display()));
    println!("wrote {}", path.display());
    println!("\nchaos bench complete");
}

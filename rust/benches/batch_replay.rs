//! Bench: **batched columnar replay vs looped single-job replay** — the
//! throughput win of serving micro-batches through one
//! `OutputMatrix · arena` gemm pass.
//!
//! Scenario: a service holds `B` same-shape, same-width encode jobs
//! (the micro-batching queue of `EncodeService::start_replay`). The
//! baseline replays the *same optimized plan* one job at a time
//! (`replay_opt`); the batched path packs the jobs into one `K × (W·B)`
//! columnar arena and evaluates every output row once across all of
//! them (`replay_batch`). Small per-job widths are exactly the
//! micro-batch regime — tiny payloads at high request rates, where
//! per-coefficient fixed costs (term setup, reduction bookkeeping,
//! per-job allocation) rival the element work itself and amortize over
//! `W·B` columns instead of `W`.
//!
//! Acceptance targets, asserted below:
//! * `replay_batch` at `B ≥ 16` reaches ≥ 2× per-job throughput over
//!   the looped single-job baseline (timing assertion skipped under
//!   `DCE_BENCH_SMOKE=1`, where everything runs once);
//! * optimized plans report **strictly fewer live slots** than raw
//!   plans for every A2A variant at `N ≥ 64` (always asserted).
//!
//! Machine-readable results land in `BENCH_batch.json` at the repo
//! root, so the perf trajectory is recorded run over run.

use dce::codes::{structured::disjoint_family, StructuredPoints};
use dce::collectives::{CauchyA2A, DftA2A, DrawLoose, PrepareShoot};
use dce::framework::{compile_plan, AlgoRequest};
use dce::gf::{Field, GfPrime, Mat};
use dce::net::{exec, opt, plan, Collective, Packet};
use dce::util::{bench, bench_iters, bench_smoke, ipow, Rng};
use std::sync::Arc;

fn main() {
    let f = GfPrime::default_field();
    let (k, r, w, ports) = (64usize, 16usize, 2usize, 2usize);
    let b = 32usize; // acceptance target is stated at B >= 16
    let iters = bench_iters(30);

    let a = Arc::new(Mat::random(&f, k, r, 7));
    let compiled = compile_plan(&f, None, Some(a), ports, w, AlgoRequest::Universal, None)
        .expect("compile universal plan");
    let optimized = &compiled.opt;
    println!(
        "## batched columnar replay (K={k} R={r} W={w} p={ports}, B={b}, {iters} rounds)"
    );
    println!(
        "optimizer: {} -> {} live slots ({} lincombs eliminated)",
        optimized.stats.slots_before,
        optimized.stats.slots_after,
        optimized.stats.lincombs_eliminated()
    );

    let mut rng = Rng::new(41);
    let jobs: Vec<Vec<Packet>> = (0..b)
        .map(|_| {
            (0..k)
                .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
                .collect()
        })
        .collect();
    let refs: Vec<&[Packet]> = jobs.iter().map(|x| x.as_slice()).collect();

    // Correctness first: batch ≡ per-job singles, bit for bit.
    let batched = exec::replay_batch(optimized, &f, &refs).unwrap();
    for (j, x) in jobs.iter().enumerate() {
        let single = exec::replay_opt(optimized, &f, x).unwrap();
        assert_eq!(batched[j].outputs, single.outputs, "job {j}: outputs");
        assert_eq!(batched[j].report, single.report, "job {j}: report");
    }

    let singles = bench("looped replay_opt (B jobs, one at a time)", iters, |_| {
        let mut served = 0usize;
        for x in &jobs {
            served += exec::replay_opt(optimized, &f, x).unwrap().outputs.len();
        }
        served
    });
    let batch = bench("replay_batch (one columnar pass)", iters, |_| {
        exec::replay_batch(optimized, &f, &refs).unwrap().len()
    });
    println!("{singles}");
    println!("{batch}");

    let singles_per_job_us = singles.median.as_secs_f64() * 1e6 / b as f64;
    let batch_per_job_us = batch.median.as_secs_f64() * 1e6 / b as f64;
    let speedup = singles.median.as_secs_f64() / batch.median.as_secs_f64();
    println!(
        "per-job: singles {singles_per_job_us:.2}us  batch {batch_per_job_us:.2}us  \
         speedup {speedup:.2}x (acceptance target >= 2x at B >= 16)"
    );

    // Live-slot reduction across every A2A variant at N = 64.
    let variant_stats = a2a_variant_stats(&f, 64);
    for (name, stats) in &variant_stats {
        println!(
            "{name:<12} N=64: {} -> {} live slots ({} dead, {} CSE)",
            stats.slots_before, stats.slots_after, stats.dead_lincombs, stats.cse_merged
        );
        assert!(
            stats.slots_after < stats.slots_before,
            "{name}: optimized plan must have strictly fewer live slots, got {stats:?}"
        );
    }

    write_json(k, r, w, ports, b, singles_per_job_us, batch_per_job_us, speedup, &variant_stats);

    if bench_smoke() {
        println!("(smoke mode: timing assertion skipped)");
    } else {
        assert!(
            speedup >= 2.0,
            "replay_batch must reach >= 2x per-job throughput over looped \
             single-job replay, got {speedup:.2}x"
        );
    }
    println!("\nbatch_replay bench complete");
}

/// Compile each A2A variant at `N = n` and report its optimizer stats.
fn a2a_variant_stats(f: &GfPrime, n: usize) -> Vec<(&'static str, opt::OptStats)> {
    let f = *f;
    let mut rng = Rng::new(0xBE);
    let mut out = Vec::new();

    let c = Arc::new(Mat::random(&f, n, n, rng.next_u64()));
    out.push((
        "universal",
        stats_of(n, |basis| {
            Box::new(PrepareShoot::new(f, (0..n).collect(), 1, c.clone(), basis))
        }),
    ));
    out.push((
        "dft",
        stats_of(n, |basis| {
            Box::new(DftA2A::new(f, (0..n).collect(), 1, 2, 6, basis, false).unwrap())
        }),
    ));
    let hmax = StructuredPoints::max_h(&f, n as u64, 2);
    let m = n / ipow(2, hmax) as usize;
    let sp = StructuredPoints::new(&f, n, 2, (0..m as u64).collect()).unwrap();
    out.push((
        "vandermonde",
        stats_of(n, |basis| {
            Box::new(DrawLoose::new(f, (0..n).collect(), 1, &sp, basis, false).unwrap())
        }),
    ));
    let fam = disjoint_family(&f, n, 2, 2).unwrap();
    let pre: Vec<u64> = (0..n).map(|_| rng.range(1, f.order())).collect();
    let post: Vec<u64> = (0..n).map(|_| rng.range(1, f.order())).collect();
    out.push((
        "cauchy",
        stats_of(n, |basis| {
            Box::new(
                CauchyA2A::new(
                    f,
                    (0..n).collect(),
                    1,
                    &fam[0],
                    &fam[1],
                    pre.clone(),
                    post.clone(),
                    basis,
                )
                .unwrap(),
            )
        }),
    ));
    out
}

fn stats_of(n: usize, build: impl Fn(Vec<Packet>) -> Box<dyn Collective>) -> opt::OptStats {
    let compiled = plan::compile(1, n, |basis| Ok(build(basis))).unwrap();
    opt::optimize(&compiled).stats
}

/// Emit `BENCH_batch.json` at the repo root (manifest dir's parent).
#[allow(clippy::too_many_arguments)]
fn write_json(
    k: usize,
    r: usize,
    w: usize,
    ports: usize,
    b: usize,
    singles_per_job_us: f64,
    batch_per_job_us: f64,
    speedup: f64,
    variants: &[(&'static str, opt::OptStats)],
) {
    let variant_json: Vec<String> = variants
        .iter()
        .map(|(name, s)| {
            format!(
                concat!(
                    "{{\"name\":\"{}\",\"slots_before\":{},\"slots_after\":{},",
                    "\"dead_lincombs\":{},\"cse_merged\":{}}}"
                ),
                name, s.slots_before, s.slots_after, s.dead_lincombs, s.cse_merged
            )
        })
        .collect();
    let json = format!(
        concat!(
            "{{\"bench\":\"batch_replay\",\"smoke\":{},",
            "\"shape\":{{\"k\":{},\"r\":{},\"w\":{},\"ports\":{}}},\"batch\":{},",
            "\"singles_us_per_job\":{:.3},\"batch_us_per_job\":{:.3},",
            "\"speedup\":{:.3},\"a2a_variants_n64\":[{}]}}"
        ),
        bench_smoke(),
        k,
        r,
        w,
        ports,
        b,
        singles_per_job_us,
        batch_per_job_us,
        speedup,
        variant_json.join(",")
    );
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("manifest dir has a parent")
        .join("BENCH_batch.json");
    // Fail loudly: a missing BENCH_batch.json silently breaks the
    // "perf trajectory is recorded" contract this bench exists for.
    std::fs::write(&path, format!("{json}\n"))
        .unwrap_or_else(|e| panic!("could not write {}: {e}", path.display()));
    println!("wrote {}", path.display());
}

//! Bench: **hot paths** — the §Perf harness. Micro-benchmarks for every
//! layer the profile identified:
//!
//! * L3 field inner loops: mul / mul_add / packet axpy (Barrett vs naive),
//! * L3 engine: prepare-and-shoot wall-clock scaling, allocation pressure,
//! * structured-matrix construction (Vandermonde inverse, Cauchy blocks),
//! * PJRT bulk encode throughput (the L1/L2 artifact) vs a native rust
//!   GF matmul, when artifacts are present.
//!
//! Before/after numbers from this harness are recorded in DESIGN.md
//! §Perf; the flat-buffer section asserts the ≥ 2× acceptance target
//! against the seed (vec-of-vecs) representation.

use dce::collectives::PrepareShoot;
use dce::gf::{vandermonde, Field, GfPrime, Mat};
use dce::net::{pkt_add_scaled, run, Packet, PacketBuf, Sim};
use dce::util::{bench, bench_iters, bench_smoke, Rng};
use std::hint::black_box;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let f = GfPrime::default_field();
    let mut rng = Rng::new(1);

    println!("## L3 — field inner loops (1M ops per iteration)");
    let xs: Vec<u64> = (0..1024).map(|_| rng.below(f.order())).collect();
    let stats = bench("gf_mul 1M", bench_iters(20), |_| {
        let mut acc = 1u64;
        for _ in 0..1024 {
            for &x in &xs {
                acc = f.mul(acc, x | 1);
            }
        }
        acc
    });
    println!(
        "{stats}   ({:.2} ns/mul)",
        stats.per_iter_ns() / (1024.0 * 1024.0)
    );
    let stats = bench("gf_mul_add 1M", bench_iters(20), |_| {
        let mut acc = 0u64;
        for _ in 0..1024 {
            for &x in &xs {
                acc = f.mul_add(acc, x, 12345);
            }
        }
        acc
    });
    println!(
        "{stats}   ({:.2} ns/op)",
        stats.per_iter_ns() / (1024.0 * 1024.0)
    );

    println!("\n## L3 — packet axpy (W = 4096, 256 terms)");
    let w = 4096usize;
    let packets: Vec<Packet> = (0..256)
        .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
        .collect();
    let coeffs: Vec<u64> = (0..256).map(|_| rng.below(f.order())).collect();
    let stats = bench("axpy 256x4096 (per-term reduce)", bench_iters(20), |_| {
        let mut acc = vec![0u64; w];
        for (c, p) in coeffs.iter().zip(&packets) {
            pkt_add_scaled(&f, &mut acc, *c, p);
        }
        acc
    });
    println!(
        "{stats}   ({:.3} Gop/s)",
        (256.0 * w as f64) / stats.per_iter_ns()
    );
    let stats = bench("lincomb 256x4096 (delayed reduce)", bench_iters(20), |_| {
        let mut acc = vec![0u64; w];
        let terms: Vec<(u64, &[u64])> = coeffs
            .iter()
            .zip(&packets)
            .map(|(&c, p)| (c, p.as_slice()))
            .collect();
        f.lincomb_into(&mut acc, &terms);
        acc
    });
    println!(
        "{stats}   ({:.3} Gop/s)",
        (256.0 * w as f64) / stats.per_iter_ns()
    );

    println!("\n## L3 — flat buffer vs seed representation (256×4096 lincomb)");
    // Seed representation: one heap allocation per packet, one Barrett
    // reduction per element-multiply (the `Vec<Packet>` + `mul_add` hot
    // path this engine replaced).
    let seed_stats = bench("seed rep: vec-of-vecs, reduce per multiply", bench_iters(20), |_| {
        let mut acc = vec![0u64; w];
        for (c, p) in coeffs.iter().zip(&packets) {
            if *c == 0 {
                continue;
            }
            for (a, &s) in acc.iter_mut().zip(p) {
                *a = f.mul_add(*a, *c, s);
            }
        }
        acc
    });
    println!("{seed_stats}");
    // Flat representation: one contiguous PacketBuf, delayed-reduction
    // lincomb over slice views.
    let mut flat = PacketBuf::with_capacity(w, packets.len());
    for p in &packets {
        flat.push(p);
    }
    let flat_stats = bench("flat rep: PacketBuf lincomb, delayed reduce", bench_iters(20), |_| {
        let mut acc = vec![0u64; w];
        let terms: Vec<(u64, &[u64])> = coeffs
            .iter()
            .enumerate()
            .map(|(i, &c)| (c, flat.pkt(i)))
            .collect();
        f.lincomb_into(&mut acc, &terms);
        acc
    });
    println!("{flat_stats}");
    let speedup = seed_stats.per_iter_ns() / flat_stats.per_iter_ns();
    println!("flat-buffer speedup: {speedup:.2}x (acceptance target ≥ 2x)");
    if bench_smoke() {
        println!("(smoke mode: timing assertion skipped)");
    } else {
        assert!(
            speedup >= 2.0,
            "flat-buffer lincomb must be ≥ 2x the seed representation, got {speedup:.2}x"
        );
    }

    println!("\n## L3 — structured matrices");
    let points: Vec<u64> = (1..=256u64).collect();
    println!("{}", bench("vandermonde::inverse n=256", bench_iters(10), |_| {
        vandermonde::inverse(&f, &points)
    }));
    println!("{}", bench("Mat::inverse (GJ) n=256", bench_iters(5), |_| {
        let v = vandermonde::square(&f, &points);
        v.inverse(&f).unwrap()
    }));

    println!("\n## L3 — prepare-and-shoot engine scaling (W = 1)");
    let scaling_ks: &[usize] = if bench_smoke() {
        &[256]
    } else {
        &[256, 1024, 4096]
    };
    for &k in scaling_ks {
        let c = Arc::new(Mat::random(&f, k, k, 3));
        let inputs: Vec<Packet> = (0..k as u64).map(|i| vec![f.elem(i + 1)]).collect();
        let stats = bench(&format!("prepare-shoot K={k}"), bench_iters(5), |_| {
            let mut ps = PrepareShoot::new(f, (0..k).collect(), 1, c.clone(), inputs.clone());
            run(&mut Sim::new(1), &mut ps).unwrap()
        });
        println!("{stats}");
    }

    println!("\n## L1/L2 via PJRT vs native rust GF matmul (K=256, R=64, W=256)");
    let artifacts = Path::new("artifacts");
    let (k, r, w) = (256usize, 64usize, 256usize);
    let a = Mat::random(&f, k, r, 5);
    let x = Mat::random(&f, k, w, 6);
    let a_flat: Vec<u64> = (0..k).flat_map(|i| a.row(i).to_vec()).collect();
    let x_flat: Vec<u64> = (0..k).flat_map(|i| x.row(i).to_vec()).collect();
    let stats = bench("native matmul (per-term reduce)", bench_iters(10), |_| {
        // y[j][c] = Σ_i a[i][j]·x[i][c]
        let mut y = vec![0u64; r * w];
        for i in 0..k {
            let xi = x.row(i);
            for j in 0..r {
                let aij = a[(i, j)];
                if aij == 0 {
                    continue;
                }
                let row = &mut y[j * w..(j + 1) * w];
                for (yy, &xv) in row.iter_mut().zip(xi) {
                    *yy = f.mul_add(*yy, aij, xv);
                }
            }
        }
        black_box(y)
    });
    let flops = (k * r * w) as f64;
    println!("{stats}   ({:.3} Gmul/s)", flops / stats.per_iter_ns());
    let stats = bench("native matmul (lazy reduce)", bench_iters(10), |_| {
        let mut y = vec![0u64; r * w];
        let chunk = f.lazy_chunk();
        for (i0, rows) in (0..k).collect::<Vec<_>>().chunks(chunk).enumerate() {
            for &i in rows {
                let xi = x.row(i);
                for j in 0..r {
                    let aij = a[(i, j)];
                    if aij == 0 {
                        continue;
                    }
                    let row = &mut y[j * w..(j + 1) * w];
                    for (yy, &xv) in row.iter_mut().zip(xi) {
                        *yy = f.lazy_mul_acc(*yy, aij, xv);
                    }
                }
            }
            let _ = i0;
            for yy in y.iter_mut() {
                *yy = f.lazy_reduce(*yy);
            }
        }
        black_box(y)
    });
    println!("{stats}   ({:.3} Gmul/s)", flops / stats.per_iter_ns());
    if artifacts.join("manifest.txt").exists() {
        let rt = dce::runtime::Runtime::cpu().unwrap();
        let enc = rt.load_encoder(artifacts, k, r, w, f.order()).unwrap();
        // Warm + measure.
        let t0 = Instant::now();
        let iters = bench_iters(10) as u32;
        for _ in 0..iters {
            black_box(enc.encode_u64(&a_flat, &x_flat).unwrap());
        }
        let per = t0.elapsed() / iters;
        println!(
            "pjrt encode 256x64x256                       median {per:?}   ({:.3} Gmul/s)",
            flops / per.as_nanos() as f64
        );
    } else {
        println!("(skipping PJRT: run `make artifacts`)");
    }
    println!("\nhotpath bench complete");
}

//! Bench: **Theorems 1–2** — the systematic framework's end-to-end costs
//! (`max_m C_A2A(A_m) + C_BR`) measured against the component formulas,
//! for both aspect-ratio regimes and several payload widths; plus the
//! Appendix-A broadcast/reduce variants.

use dce::collectives::{PipelinedBroadcast, TreeBroadcast};
use dce::framework::{costs, A2aAlgo, SystematicEncode};
use dce::gf::{Field, GfPrime, Mat};
use dce::net::{run, CostModel, Packet, ProcId, Sim};
use dce::util::bench;
use std::sync::Arc;

fn payloads(f: &GfPrime, k: usize, w: usize) -> Vec<Packet> {
    (0..k)
        .map(|i| (0..w).map(|j| f.elem((i * w + j) as u64 + 1)).collect())
        .collect()
}

fn main() {
    let f = GfPrime::default_field();

    println!("## Theorem 1 (K ≥ R) and Theorem 2 (K < R) — universal framework");
    println!(
        "{:>5} {:>5} {:>3} {:>3} | {:>8} {:>8} | {:>8} {:>8} | {:>12}",
        "K", "R", "W", "p", "C1 meas", "C1 thm", "C2 meas", "C2 thm", "wall(med)"
    );
    for &(k, r, w, p) in &[
        (16usize, 4usize, 1usize, 1usize),
        (64, 16, 1, 1),
        (64, 16, 8, 1),
        (256, 16, 1, 2),
        (25, 4, 1, 1),
        (4, 16, 1, 1),
        (16, 64, 1, 1),
        (16, 64, 8, 2),
        (4, 25, 1, 1),
    ] {
        let a = Arc::new(Mat::random(&f, k, r, (k * r) as u64));
        let runner = || {
            let mut job = SystematicEncode::new(
                f,
                a.clone(),
                payloads(&f, k, w),
                p,
                A2aAlgo::Universal,
            )
            .expect("job");
            run(&mut Sim::new(p), &mut job).expect("run")
        };
        let rep = runner();
        // Component formula: block A2A + broadcast/reduce over the grid.
        let block = k.max(r).div_ceil(k.min(r)).max(1);
        let a2a = costs::theorem3_universal(k.min(r) as u64, p as u64);
        let a2a = (a2a.0, a2a.1 * w as u64);
        let (c1t, c2t) = if k >= r {
            costs::theorem1_framework(a2a, k as u64, r as u64, w as u64, p as u64)
        } else {
            costs::theorem2_framework(a2a, k as u64, r as u64, w as u64, p as u64)
        };
        let _ = block;
        let stats = bench("fw", 8, |_| runner());
        println!(
            "{k:>5} {r:>5} {w:>3} {p:>3} | {:>8} {:>8} | {:>8} {:>8} | {:>12?}",
            rep.c1, c1t, rep.c2, c2t, stats.median
        );
        assert!(rep.c1 <= c1t, "C1 {} must be ≤ formula {}", rep.c1, c1t);
        assert!(rep.c2 <= c2t, "C2 {} must be ≤ formula {}", rep.c2, c2t);
    }

    println!("\n## Appendix A — broadcast implementations vs W (N = 8, p = 1)");
    println!(
        "{:>6} | {:>14} {:>14} | {:>10}",
        "W", "tree C (model)", "chain C (model)", "winner"
    );
    let model = CostModel::new(10.0, 0.1, 20);
    let procs: Vec<ProcId> = (0..8).collect();
    for &w in &[1usize, 16, 64, 256, 1024, 4096] {
        let data: Packet = (0..w as u64).collect();
        let mut tree = TreeBroadcast::new(procs.clone(), 1, data.clone());
        let rt = run(&mut Sim::new(1), &mut tree).unwrap();
        let segments = (w / 8).max(1);
        let mut chain = PipelinedBroadcast::new(procs.clone(), data, segments);
        let rc = run(&mut Sim::new(1), &mut chain).unwrap();
        let (ct, cc) = (rt.cost(&model), rc.cost(&model));
        println!(
            "{w:>6} | {ct:>14.1} {cc:>14.1} | {:>10}",
            if ct <= cc { "tree" } else { "pipelined" }
        );
    }

    println!("\n## wall-clock scaling of the full framework (universal, W = 4)");
    for &(k, r) in &[(64usize, 16usize), (256, 64), (1024, 256)] {
        let a = Arc::new(Mat::random(&f, k, r, 77));
        let stats = bench(&format!("framework K={k} R={r}"), 5, |_| {
            let mut job = SystematicEncode::new(
                f,
                a.clone(),
                payloads(&f, k, 4),
                2,
                A2aAlgo::Universal,
            )
            .unwrap();
            run(&mut Sim::new(2), &mut job).unwrap()
        });
        println!("{stats}");
    }
    println!("\nframework bench complete");
}

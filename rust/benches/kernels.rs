//! Bench: **packed narrow-lane kernels vs the scalar `u64` field path**
//! — the memory-bandwidth win of storing each wire symbol in the
//! `⌈log2 q⌉`-sized lane the cost model already charges for — and, per
//! executable **ISA tier** ([`IsaTier::available`]), the explicit-SIMD
//! backends vs the scalar packed engine.
//!
//! Three sections:
//!
//! * **micro** — axpy / lincomb / gemm per field × tier, packed
//!   (`gf::kernels`) vs scalar (`Field` trait over `u64`), equal inputs,
//!   correctness asserted before any timing;
//! * **tier gain** — packed gemm at the widest tier vs the *scalar
//!   packed* tier (SIMD win on top of the narrow-lane win);
//! * **batched replay** — the serving path end to end:
//!   `replay_batch_kernels` per tier vs `replay_batch_scalar`
//!   (the pre-packing `u64` engine) on a compiled universal plan at
//!   `B = 32`, bit-identity asserted per tier before timing.
//!
//! Acceptance targets, asserted below (skipped under
//! `DCE_BENCH_SMOKE=1`): **≥ 3×** per-job batched-replay throughput on
//! `gf2e:8` and **≥ 1.5×** on the default prime 786433, at the widest
//! tier; and on AVX2 hosts **≥ 2×** (`gf2e:8`) / **≥ 1.5×**
//! (`prime:786433`) gemm over the scalar packed tier. Machine-readable
//! results land in `BENCH_kernels.json` at the repo root for the CI
//! bench-trend gate; entry names are `field@tier` so the trend script
//! aligns runs per tier.

use dce::framework::{compile_plan, AlgoRequest};
use dce::gf::matrix::gemm_into;
use dce::gf::{AnyField, Field, IsaTier, Kernels, Mat};
use dce::net::{exec, Packet};
use dce::util::{bench, bench_iters, bench_smoke, Rng};
use std::sync::Arc;

struct MicroResult {
    /// `field@tier` — unique per tier so bench-trend aligns by name.
    name: String,
    field: &'static str,
    isa: &'static str,
    layout: &'static str,
    axpy_speedup: f64,
    lincomb_speedup: f64,
    gemm_speedup: f64,
    /// Packed gemm median, µs — the cross-tier comparable number.
    gemm_us: f64,
}

/// SIMD-tier gemm gain over the scalar *packed* tier (not the u64 path).
struct TierGain {
    field: &'static str,
    isa: &'static str,
    gemm_speedup_vs_scalar_tier: f64,
    target: f64,
}

struct ReplayResult {
    name: String,
    field: &'static str,
    isa: &'static str,
    layout: &'static str,
    b: usize,
    w: usize,
    scalar_us_per_job: f64,
    packed_us_per_job: f64,
    speedup: f64,
    target: f64,
}

fn rand_vec(f: &AnyField, n: usize, rng: &mut Rng) -> Vec<u64> {
    (0..n).map(|_| rng.below(f.order())).collect()
}

fn micro(field: &'static str, isa: IsaTier, iters: usize, rng: &mut Rng) -> MicroResult {
    let f = AnyField::parse(field).unwrap();
    let kern = Kernels::for_field_with_isa(&f, isa);
    let tier = kern.isa().name();
    let layout = kern.layout().name();
    let tag = format!("{field}@{tier}");
    let n = 1 << 16;
    let (m, k) = (80usize, 64usize);

    // --- axpy ---
    let src = rand_vec(&f, n, rng);
    let acc0 = rand_vec(&f, n, rng);
    let c = rng.range(1, f.order());
    {
        let mut s = acc0.clone();
        f.axpy_into(&mut s, c, &src);
        let mut p = kern.pack(&acc0);
        kern.axpy(&mut p, c, &kern.pack(&src)).unwrap();
        assert_eq!(p.to_u64(), s, "{tag}: packed axpy != scalar axpy");
    }
    let mut acc_s = acc0.clone();
    let axpy_scalar = bench(&format!("{tag:<22} axpy scalar/u64"), iters, |_| {
        f.axpy_into(&mut acc_s, c, &src);
        acc_s[0]
    });
    let mut acc_p = kern.pack(&acc0);
    let src_p = kern.pack(&src);
    let axpy_packed = bench(&format!("{tag:<22} axpy packed/{layout}"), iters, |_| {
        kern.axpy(&mut acc_p, c, &src_p).unwrap();
        acc_p.get(0)
    });

    // --- lincomb (k terms over n-lane rows) ---
    let arena = rand_vec(&f, k * n, rng);
    let coeffs = rand_vec(&f, k, rng);
    let terms: Vec<(u64, &[u64])> = coeffs
        .iter()
        .enumerate()
        .map(|(i, &cc)| (cc, &arena[i * n..(i + 1) * n]))
        .collect();
    let arena_p = kern.pack(&arena);
    {
        let mut s = vec![0u64; n];
        f.lincomb_into(&mut s, &terms);
        let mut p = kern.zeros(n);
        kern.lincomb(&mut p, &coeffs, &arena_p).unwrap();
        assert_eq!(p.to_u64(), s, "{tag}: packed lincomb != scalar lincomb");
    }
    let mut lin_s = vec![0u64; n];
    let lincomb_scalar = bench(&format!("{tag:<22} lincomb scalar/u64"), iters, |_| {
        lin_s.fill(0);
        f.lincomb_into(&mut lin_s, &terms);
        lin_s[0]
    });
    let mut lin_p = kern.zeros(n);
    let lincomb_packed = bench(&format!("{tag:<22} lincomb packed/{layout}"), iters, |_| {
        lin_p.fill_zero();
        kern.lincomb(&mut lin_p, &coeffs, &arena_p).unwrap();
        lin_p.get(0)
    });

    // --- gemm (m output rows over the same arena) ---
    let a = rand_vec(&f, m * k, rng);
    let rows: Vec<&[u64]> = (0..m).map(|i| &a[i * k..(i + 1) * k]).collect();
    {
        let mut s = vec![0u64; m * n];
        gemm_into(&f, m, k, &a, &arena, n, &mut s);
        let mut p = kern.zeros(m * n);
        kern.gemm_rows(&rows, &arena_p, n, &mut p, false).unwrap();
        assert_eq!(p.to_u64(), s, "{tag}: packed gemm != scalar gemm");
    }
    let mut gemm_s = vec![0u64; m * n];
    let gemm_scalar = bench(&format!("{tag:<22} gemm scalar/u64"), iters, |_| {
        gemm_s.fill(0);
        gemm_into(&f, m, k, &a, &arena, n, &mut gemm_s);
        gemm_s[0]
    });
    let mut gemm_p = kern.zeros(m * n);
    let gemm_packed = bench(&format!("{tag:<22} gemm packed/{layout}"), iters, |_| {
        gemm_p.fill_zero();
        kern.gemm_rows(&rows, &arena_p, n, &mut gemm_p, false).unwrap();
        gemm_p.get(0)
    });

    for st in [
        &axpy_scalar,
        &axpy_packed,
        &lincomb_scalar,
        &lincomb_packed,
        &gemm_scalar,
        &gemm_packed,
    ] {
        println!("{st}");
    }
    MicroResult {
        name: tag,
        field,
        isa: tier,
        layout,
        axpy_speedup: axpy_scalar.median.as_secs_f64() / axpy_packed.median.as_secs_f64().max(1e-12),
        lincomb_speedup: lincomb_scalar.median.as_secs_f64()
            / lincomb_packed.median.as_secs_f64().max(1e-12),
        gemm_speedup: gemm_scalar.median.as_secs_f64() / gemm_packed.median.as_secs_f64().max(1e-12),
        gemm_us: gemm_packed.median.as_secs_f64() * 1e6,
    }
}

fn batched_replay(
    field: &'static str,
    isa: IsaTier,
    target: f64,
    iters: usize,
    rng: &mut Rng,
) -> ReplayResult {
    let f = AnyField::parse(field).unwrap();
    let (k, r, w, ports, b) = (64usize, 16usize, 256usize, 2usize, 32usize);
    let parity = Arc::new(Mat::random(&f, k, r, 0xC0DE));
    let compiled = compile_plan(&f, None, Some(parity), ports, w, AlgoRequest::Universal, None)
        .expect("compile universal plan");
    let opt = &compiled.opt;
    let kern = compiled.kernels.with_isa(isa);
    let tier = kern.isa().name();
    let layout = kern.layout().name();
    let tag = format!("{field}@{tier}");

    let jobs: Vec<Vec<Packet>> = (0..b)
        .map(|_| (0..k).map(|_| rand_vec(&f, w, rng)).collect())
        .collect();
    let refs: Vec<&[Packet]> = jobs.iter().map(|x| x.as_slice()).collect();

    // Correctness gate: this tier ≡ the u64 scalar engine, bit for bit
    // (outputs and report), before any timing — unconditionally, smoke
    // mode included.
    let packed = exec::replay_batch_kernels(opt, &kern, &refs).unwrap();
    let scalar = exec::replay_batch_scalar(opt, &f, &refs).unwrap();
    for (j, (pj, sj)) in packed.iter().zip(&scalar).enumerate() {
        assert_eq!(pj.outputs, sj.outputs, "{tag} job {j}: packed != scalar");
        assert_eq!(pj.report, sj.report, "{tag} job {j}: packed report != scalar");
    }

    let scalar_st = bench(&format!("{tag:<22} replay_batch scalar/u64"), iters, |_| {
        exec::replay_batch_scalar(opt, &f, &refs).unwrap().len()
    });
    let packed_st = bench(
        &format!("{tag:<22} replay_batch packed/{layout}"),
        iters,
        |_| exec::replay_batch_kernels(opt, &kern, &refs).unwrap().len(),
    );
    println!("{scalar_st}");
    println!("{packed_st}");
    let scalar_us = scalar_st.median.as_secs_f64() * 1e6 / b as f64;
    let packed_us = packed_st.median.as_secs_f64() * 1e6 / b as f64;
    let speedup = scalar_st.median.as_secs_f64() / packed_st.median.as_secs_f64().max(1e-12);
    println!(
        "{tag}: per-job scalar {scalar_us:.2}us  packed {packed_us:.2}us  \
         speedup {speedup:.2}x (target >= {target}x at widest tier)"
    );
    ReplayResult {
        name: tag,
        field,
        isa: tier,
        layout,
        b,
        w,
        scalar_us_per_job: scalar_us,
        packed_us_per_job: packed_us,
        speedup,
        target,
    }
}

fn main() {
    let iters = bench_iters(20);
    let mut rng = Rng::new(0x5EED);
    let tiers = IsaTier::available();
    let widest = IsaTier::widest();
    let tier_names: Vec<&str> = tiers.iter().map(|t| t.name()).collect();
    println!("## packed-symbol kernels vs scalar u64 ({iters} rounds; tiers {tier_names:?})");

    let fields = ["gf2e:8", "gf2e:12", "prime:786433", "prime:2147483647"];
    let mut micro_results: Vec<MicroResult> = Vec::new();
    for &field in &fields {
        for &tier in &tiers {
            micro_results.push(micro(field, tier, iters, &mut rng));
        }
    }
    for m in &micro_results {
        println!(
            "{:<24} [{:>3}] axpy {:>5.2}x  lincomb {:>5.2}x  gemm {:>5.2}x  ({:>8.1}us gemm)",
            m.name, m.layout, m.axpy_speedup, m.lincomb_speedup, m.gemm_speedup, m.gemm_us
        );
    }

    // SIMD gain over the scalar packed tier, per hot field.
    println!("\n## widest tier ({}) vs scalar packed tier, gemm", widest.name());
    let gains: Vec<TierGain> = [("gf2e:8", 2.0f64), ("prime:786433", 1.5)]
        .into_iter()
        .map(|(field, target)| {
            let gemm_us = |isa: &str| {
                micro_results
                    .iter()
                    .find(|m| m.field == field && m.isa == isa)
                    .map(|m| m.gemm_us)
                    .expect("micro result for every field × tier")
            };
            let gain = gemm_us("scalar") / gemm_us(widest.name()).max(1e-9);
            println!("{field:<18} {gain:>5.2}x (target >= {target}x on avx2 hosts)");
            TierGain {
                field,
                isa: widest.name(),
                gemm_speedup_vs_scalar_tier: gain,
                target,
            }
        })
        .collect();

    println!("\n## batched replay, packed vs scalar (B=32)");
    let mut replay_results: Vec<ReplayResult> = Vec::new();
    for (field, target) in [("gf2e:8", 3.0), ("prime:786433", 1.5)] {
        for &tier in &tiers {
            replay_results.push(batched_replay(field, tier, target, iters, &mut rng));
        }
    }

    write_json(&tier_names, &micro_results, &gains, &replay_results);

    if bench_smoke() {
        println!("(smoke mode: timing assertions skipped)");
    } else {
        for r in replay_results.iter().filter(|r| r.isa == widest.name()) {
            assert!(
                r.speedup >= r.target,
                "{}: packed batched replay must reach >= {}x over the scalar u64 \
                 path at B={}, got {:.2}x",
                r.name,
                r.target,
                r.b,
                r.speedup
            );
        }
        if widest == IsaTier::Avx2 {
            for g in &gains {
                assert!(
                    g.gemm_speedup_vs_scalar_tier >= g.target,
                    "{}: avx2 gemm must reach >= {}x over the scalar packed tier, got {:.2}x",
                    g.field,
                    g.target,
                    g.gemm_speedup_vs_scalar_tier
                );
            }
        } else {
            println!(
                "(widest tier is {}, not avx2: tier-gain targets not asserted)",
                widest.name()
            );
        }
    }
    println!("\nkernels bench complete");
}

/// Emit `BENCH_kernels.json` at the repo root (manifest dir's parent).
fn write_json(tiers: &[&str], micro: &[MicroResult], gains: &[TierGain], replay: &[ReplayResult]) {
    let micro_json: Vec<String> = micro
        .iter()
        .map(|m| {
            format!(
                concat!(
                    "{{\"name\":\"{}\",\"field\":\"{}\",\"isa\":\"{}\",\"layout\":\"{}\",",
                    "\"axpy_speedup\":{:.3},\"lincomb_speedup\":{:.3},\"gemm_speedup\":{:.3},",
                    "\"gemm_us\":{:.3}}}"
                ),
                m.name,
                m.field,
                m.isa,
                m.layout,
                m.axpy_speedup,
                m.lincomb_speedup,
                m.gemm_speedup,
                m.gemm_us
            )
        })
        .collect();
    let gain_json: Vec<String> = gains
        .iter()
        .map(|g| {
            format!(
                concat!(
                    "{{\"name\":\"{}@simd-gain\",\"field\":\"{}\",\"isa\":\"{}\",",
                    "\"gemm_speedup_vs_scalar_tier\":{:.3},\"target\":{}}}"
                ),
                g.field, g.field, g.isa, g.gemm_speedup_vs_scalar_tier, g.target
            )
        })
        .collect();
    let replay_json: Vec<String> = replay
        .iter()
        .map(|r| {
            format!(
                concat!(
                    "{{\"name\":\"{}\",\"field\":\"{}\",\"isa\":\"{}\",\"layout\":\"{}\",",
                    "\"batch\":{},\"w\":{},",
                    "\"scalar_us_per_job\":{:.3},\"packed_us_per_job\":{:.3},",
                    "\"speedup\":{:.3},\"target\":{}}}"
                ),
                r.name,
                r.field,
                r.isa,
                r.layout,
                r.b,
                r.w,
                r.scalar_us_per_job,
                r.packed_us_per_job,
                r.speedup,
                r.target
            )
        })
        .collect();
    let tiers_json: Vec<String> = tiers.iter().map(|t| format!("\"{t}\"")).collect();
    let json = format!(
        concat!(
            "{{\"bench\":\"kernels\",\"smoke\":{},\"packed_equals_scalar\":true,",
            "\"simd_equals_scalar\":true,\"isa_tier\":\"{}\",\"tiers\":[{}],",
            "\"micro\":[{}],\"simd\":[{}],\"replay\":[{}]}}"
        ),
        bench_smoke(),
        IsaTier::detect().name(),
        tiers_json.join(","),
        micro_json.join(","),
        gain_json.join(","),
        replay_json.join(",")
    );
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("manifest dir has a parent")
        .join("BENCH_kernels.json");
    std::fs::write(&path, format!("{json}\n"))
        .unwrap_or_else(|e| panic!("could not write {}: {e}", path.display()));
    println!("wrote {}", path.display());
}

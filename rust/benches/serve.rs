//! Bench: **high-concurrency serving tier** — the sharded-cache
//! event-driven dispatcher ([`EncodeService`]) vs a faithful
//! reimplementation of the pre-sharding service (one shared
//! `sync_channel` behind a mutex, 50 ms poll loops, the queue lock held
//! across the batch-collect window, mixed-width batches split into one
//! columnar pass per width at serve time).
//!
//! Scenario: 64 closed-loop clients per shape, 4 shapes (two fields,
//! three code families), every client cycling small mixed-width
//! payloads — the regime where per-pass fixed costs dominate and the
//! dispatcher's per-width queues turn each batch into a single
//! columnar pass while the legacy engine splits every batch four ways.
//!
//! Asserted unconditionally (smoke included): every response is
//! **bit-identical** to the direct `encode` oracle, and a
//! graceful shutdown answers all queued requests (zero drops).
//! Asserted non-smoke: ≥ 2× aggregate throughput over the legacy
//! engine. Results land in `BENCH_serve.json` at the repo root for the
//! CI `bench-trend` job.

use dce::coordinator::config::CodeKind;
use dce::coordinator::{BatchPolicy, EncodeJob, EncodeService, ExecOptions, JobConfig, PlanCache};
use dce::gf::Field;
use dce::util::{bench_smoke, Rng};
use std::collections::BTreeMap;
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Request payload widths every client cycles through — small on
/// purpose: the many-small-requests regime the serving tier targets.
const WIDTHS: [usize; 4] = [2, 3, 4, 5];
const N_WORKERS: usize = 4;
const QUEUE_DEPTH: usize = 256;

fn policy() -> BatchPolicy {
    BatchPolicy {
        max_batch: 16,
        max_delay: Duration::from_micros(200),
    }
}

fn shapes() -> Vec<(String, JobConfig)> {
    let base = JobConfig::default();
    vec![
        (
            "prime:786433 k64 r16 rs-structured".into(),
            JobConfig {
                k: 64,
                r: 16,
                code: CodeKind::RsStructured,
                ..base.clone()
            },
        ),
        (
            "prime:786433 k32 r8 lagrange".into(),
            JobConfig {
                k: 32,
                r: 8,
                code: CodeKind::Lagrange,
                ..base.clone()
            },
        ),
        (
            "gf2e:8 k24 r8 rs-structured".into(),
            JobConfig {
                field: "gf2e:8".into(),
                k: 24,
                r: 8,
                code: CodeKind::RsStructured,
                ..base.clone()
            },
        ),
        (
            "prime:65537 k16 r4 rs-plain".into(),
            JobConfig {
                field: "prime:65537".into(),
                k: 16,
                r: 4,
                code: CodeKind::RsPlain,
                ..base
            },
        ),
    ]
}

/// One client's request pool: `(payload, oracle parity)` pairs,
/// precomputed outside every timed region.
type Pool = Vec<(Vec<Vec<u64>>, Vec<Vec<u64>>)>;

fn build_pools(cfg: &JobConfig, job: &EncodeJob, clients: usize, seed: u64) -> Vec<Pool> {
    let f = cfg.any_field().unwrap();
    let oracle_cache = PlanCache::new();
    (0..clients)
        .map(|c| {
            let mut rng = Rng::new(seed ^ (c as u64).wrapping_mul(0x9E37_79B9));
            WIDTHS
                .iter()
                .map(|&w| {
                    let x: Vec<Vec<u64>> = (0..cfg.k)
                        .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
                        .collect();
                    let y = job.encode(&oracle_cache, &[&x], &ExecOptions::cached(&oracle_cache)).unwrap().coded.remove(0);
                    (x, y)
                })
                .collect()
        })
        .collect()
}

/// Run `clients` closed-loop clients, each issuing `reqs` requests via
/// `roundtrip` (submit + await). Returns (wall seconds, all request
/// latencies in µs, every response matched its oracle).
fn run_clients<F>(clients: usize, reqs: usize, pools: &[Pool], roundtrip: F) -> (f64, Vec<u64>, bool)
where
    F: Fn(u64, &[Vec<u64>]) -> Vec<Vec<u64>> + Sync,
{
    let t0 = Instant::now();
    let per_client: Vec<(Vec<u64>, bool)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let pool = &pools[c];
                let rt = &roundtrip;
                s.spawn(move || {
                    let mut lat = Vec::with_capacity(reqs);
                    let mut ok = true;
                    for r in 0..reqs {
                        let (x, want) = &pool[r % pool.len()];
                        let q0 = Instant::now();
                        let y = rt(c as u64, x);
                        lat.push(q0.elapsed().as_micros() as u64);
                        ok &= &y == want;
                    }
                    (lat, ok)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let secs = t0.elapsed().as_secs_f64();
    let mut lat = Vec::with_capacity(clients * reqs);
    let mut ok = true;
    for (l, o) in per_client {
        lat.extend(l);
        ok &= o;
    }
    (secs, lat, ok)
}

// ---------------------------------------------------------------------------
// The legacy engine: a faithful compact reimplementation of the
// pre-sharding service, kept as the bench baseline. One bounded
// channel; every worker locks the receiver, polls with a 50 ms
// timeout, holds the lock for the whole batch-collect window, then
// serves the (possibly mixed-width) batch as one columnar pass per
// width group.
// ---------------------------------------------------------------------------

struct LegacyRequest {
    x: Vec<Vec<u64>>,
    reply: mpsc::Sender<Vec<Vec<u64>>>,
}

struct LegacyService {
    tx: Option<mpsc::SyncSender<LegacyRequest>>,
    workers: Vec<JoinHandle<()>>,
}

impl LegacyService {
    fn start(cfg: &JobConfig, n_workers: usize, queue_depth: usize, pol: BatchPolicy) -> Self {
        let job = Arc::new(EncodeJob::synthetic(cfg.clone()).unwrap());
        let cache = Arc::new(PlanCache::new());
        let (tx, rx) = mpsc::sync_channel::<LegacyRequest>(queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n_workers)
            .map(|_| {
                let rx = rx.clone();
                let job = job.clone();
                let cache = cache.clone();
                std::thread::spawn(move || loop {
                    let guard = rx.lock().unwrap();
                    let first = match guard.recv_timeout(Duration::from_millis(50)) {
                        Ok(r) => r,
                        Err(mpsc::RecvTimeoutError::Timeout) => continue,
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    };
                    let mut batch = vec![first];
                    let t0 = Instant::now();
                    while batch.len() < pol.max_batch {
                        let left = pol.max_delay.saturating_sub(t0.elapsed());
                        if left.is_zero() {
                            break;
                        }
                        match guard.recv_timeout(left) {
                            Ok(r) => batch.push(r),
                            Err(_) => break,
                        }
                    }
                    drop(guard);
                    // Mixed widths split into one pass per group here —
                    // the structural cost the dispatcher removed.
                    let mut by_width: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
                    for (i, r) in batch.iter().enumerate() {
                        by_width.entry(r.x[0].len()).or_default().push(i);
                    }
                    for idxs in by_width.values() {
                        let jobs: Vec<&[Vec<u64>]> =
                            idxs.iter().map(|&i| batch[i].x.as_slice()).collect();
                        let ys = job.encode(&cache, &jobs, &ExecOptions::cached(&cache)).unwrap().coded;
                        for (&i, y) in idxs.iter().zip(ys) {
                            let _ = batch[i].reply.send(y);
                        }
                    }
                })
            })
            .collect();
        LegacyService {
            tx: Some(tx),
            workers,
        }
    }

    fn submit(&self, x: Vec<Vec<u64>>) -> mpsc::Receiver<Vec<Vec<u64>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .as_ref()
            .expect("legacy service running")
            .send(LegacyRequest { x, reply })
            .expect("legacy queue alive");
        rx
    }

    fn shutdown(mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Graceful-shutdown drain check on the sharded engine: queue `n`
/// requests into a wide-open batch window, shut down, count replies.
fn shutdown_drain_check(cfg: &JobConfig, n: usize) -> bool {
    let svc = EncodeService::start_replay_with(
        cfg,
        N_WORKERS,
        n,
        BatchPolicy {
            max_batch: 16,
            max_delay: Duration::from_secs(10),
        },
    )
    .unwrap();
    let f = cfg.any_field().unwrap();
    let mut rng = Rng::new(0xD1A1);
    let pending: Vec<_> = (0..n)
        .map(|_| {
            let x: Vec<Vec<u64>> = (0..cfg.k)
                .map(|_| (0..3).map(|_| rng.below(f.order())).collect())
                .collect();
            svc.submit(x).unwrap()
        })
        .collect();
    svc.shutdown();
    pending
        .into_iter()
        .all(|rx| matches!(rx.recv(), Ok(resp) if resp.y.is_ok()))
}

fn pct(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

fn main() {
    let smoke = bench_smoke();
    let clients = if smoke { 8 } else { 64 };
    let reqs = if smoke { 4 } else { 100 };
    println!(
        "## serving tier: sharded dispatcher vs legacy single-queue \
         ({clients} clients × {reqs} reqs × {} shapes{})",
        shapes().len(),
        if smoke { ", SMOKE" } else { "" }
    );

    let mut new_secs = 0.0f64;
    let mut legacy_secs = 0.0f64;
    let mut total_reqs = 0u64;
    let mut all_lat: Vec<u64> = Vec::new();
    let mut all_match = true;
    let mut shape_names = Vec::new();
    for (si, (name, cfg)) in shapes().into_iter().enumerate() {
        let job = EncodeJob::synthetic(cfg.clone()).unwrap();
        let pools = build_pools(&cfg, &job, clients, 0x5EED + si as u64);

        let legacy = LegacyService::start(&cfg, N_WORKERS, QUEUE_DEPTH, policy());
        let (lsecs, _llat, lok) = run_clients(clients, reqs, &pools, |_tenant, x| {
            legacy.submit(x.to_vec()).recv().expect("legacy reply")
        });
        legacy.shutdown();

        let mut cfg_srv = cfg.clone();
        cfg_srv.serve.max_batch = policy().max_batch;
        cfg_srv.serve.max_delay_us = policy().max_delay.as_micros() as u64;
        cfg_srv.serve.queue_depth = QUEUE_DEPTH;
        let svc = EncodeService::start_replay(&cfg_srv, N_WORKERS, QUEUE_DEPTH).unwrap();
        let (nsecs, nlat, nok) = run_clients(clients, reqs, &pools, |tenant, x| {
            svc.submit_tenant(tenant, x.to_vec())
                .expect("admitted")
                .recv()
                .expect("served")
                .y
                .expect("encoded")
        });
        svc.shutdown();

        assert!(lok, "{name}: legacy responses must match the oracle");
        assert!(nok, "{name}: sharded responses must match the oracle");
        all_match &= lok & nok;
        let n = (clients * reqs) as f64;
        println!(
            "{name}: legacy {:>9.0} req/s | sharded {:>9.0} req/s | {:.2}x",
            n / lsecs,
            n / nsecs,
            lsecs / nsecs
        );
        new_secs += nsecs;
        legacy_secs += lsecs;
        total_reqs += clients as u64 * reqs as u64;
        all_lat.extend(nlat);
        shape_names.push(name);
    }

    let drained = shutdown_drain_check(&shapes()[0].1, if smoke { 16 } else { 64 });
    assert!(drained, "graceful shutdown must answer every queued request");

    all_lat.sort_unstable();
    let (p50, p99, p999) = (pct(&all_lat, 0.50), pct(&all_lat, 0.99), pct(&all_lat, 0.999));
    let max_us = all_lat.last().copied().unwrap_or(0);
    let sharded_tput = total_reqs as f64 / new_secs;
    let legacy_tput = total_reqs as f64 / legacy_secs;
    let speedup = legacy_secs / new_secs;
    println!(
        "aggregate: legacy {legacy_tput:>9.0} req/s | sharded {sharded_tput:>9.0} req/s | \
         {speedup:.2}x | p50 {p50}us p99 {p99}us p999 {p999}us"
    );
    if !smoke {
        assert!(
            speedup >= 2.0,
            "sharded serving tier must be >=2x the single-queue engine, got {speedup:.2}x"
        );
    } else {
        println!("smoke run: timing assertions skipped");
    }

    let shape_json: Vec<String> = shape_names.iter().map(|s| format!("{s:?}")).collect();
    let json = format!(
        concat!(
            "{{\"bench\":\"serve\",\"smoke\":{},\"clients\":{},\"requests\":{},",
            "\"shapes\":[{}],\"responses_match_direct\":{},\"shutdown_drained\":{},",
            "\"sharded_throughput_req_per_s\":{:.1},\"single_queue_throughput_req_per_s\":{:.1},",
            "\"speedup_vs_single_queue\":{:.3},",
            "\"p50_us\":{},\"p99_us\":{},\"p999_us\":{},\"max_us\":{}}}"
        ),
        smoke,
        clients,
        total_reqs,
        shape_json.join(","),
        all_match,
        drained,
        sharded_tput,
        legacy_tput,
        speedup,
        p50,
        p99,
        p999,
        max_us
    );
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("manifest dir has a parent")
        .join("BENCH_serve.json");
    std::fs::write(&path, format!("{json}\n"))
        .unwrap_or_else(|e| panic!("could not write {}: {e}", path.display()));
    println!("wrote {}", path.display());
    println!("\nserve bench complete");
}

//! Bench: **NTT encode backend vs the dense gemm engine** — the
//! `O(K log K)` transform pipeline against the packed `OutputMatrix`
//! replay it replaces past the op-count crossover.
//!
//! Scenario: batched serving (`B = 32` jobs, `W = 2` payload symbols)
//! of NTT-friendly GRS codes over a K-sweep with `R = K/4`. At each K
//! both engines replay the identical columnar arena; the sweep records
//! per-job latency for each, the measured speedup, and which backend
//! the selection pass would actually serve
//! ([`select_backend`](dce::net::select_backend) with the
//! `NTT_DENSE_OP_RATIO` gate).
//!
//! Acceptance targets, asserted below:
//! * both engines are **bit-identical** on every job at every K
//!   (always asserted — `backend_equals_dense` in the JSON);
//! * the transform reaches ≥ 2× per-job throughput over the dense
//!   engine at `K = 1024` (timing assertion skipped under
//!   `DCE_BENCH_SMOKE=1`);
//! * the compile-time selection matches the op-count gate at every
//!   swept K (always asserted).
//!
//! Machine-readable results land in `BENCH_ntt.json` at the repo root
//! with the K-sweep crossover curve, so the perf trajectory is recorded
//! run over run (CI bench-trend gates on it; see
//! `scripts/bench_trend.py`).

use dce::codes::GrsCode;
use dce::framework::{compile_plan, AlgoRequest};
use dce::gf::{Field, GfPrime};
use dce::net::{
    replay_batch_kernels, replay_batch_ntt, BackendKind, CodeShape, NttBackend, Packet,
};
use dce::util::{bench, bench_iters, bench_smoke, Rng};

struct SweepPoint {
    k: usize,
    r: usize,
    selected: BackendKind,
    dense_ops: usize,
    ntt_ops: usize,
    dense_us_per_job: f64,
    ntt_us_per_job: f64,
    speedup: f64,
}

fn main() {
    let f = GfPrime::default_field();
    let (w, b) = (2usize, 32usize);
    let iters = bench_iters(20);
    println!("## NTT encode backend vs dense gemm (R=K/4, W={w}, B={b}, {iters} rounds)");

    let mut equals_dense = true;
    let mut sweep = Vec::new();
    for k in [64usize, 256, 1024] {
        let r = k / 4;
        let mut mrng = Rng::new(0x17A7 ^ k as u64);
        let u: Vec<u64> = (0..k).map(|_| mrng.below(f.order() - 1) + 1).collect();
        let v: Vec<u64> = (0..r).map(|_| mrng.below(f.order() - 1) + 1).collect();
        let code = GrsCode::ntt_friendly(&f, k, r, u, v).expect("ntt-friendly code");
        let compiled = compile_plan(&f, Some(&code), None, 1, w, AlgoRequest::Direct, None)
            .expect("compile direct plan");
        let shape = CodeShape {
            alphas: &code.alphas,
            betas: &code.betas,
            u: &code.u,
            v: &code.v,
        };
        let sink_rows: Vec<usize> = (0..r)
            .map(|ri| compiled.opt.matrix.assignment()[&compiled.layout.sink(ri)])
            .collect();
        let backend = NttBackend::detect(&f, &compiled.opt.matrix, &shape, &sink_rows)
            .expect("cross-check")
            .expect("sweep shapes are NTT-friendly by construction");
        // The selection pass must agree with the op-count gate.
        let want = if backend.ntt_wins() {
            BackendKind::Ntt
        } else {
            BackendKind::Dense
        };
        assert_eq!(
            compiled.backend.kind(),
            want,
            "K={k}: selected backend disagrees with the op-count gate"
        );

        let mut rng = Rng::new(43 + k as u64);
        let jobs: Vec<Vec<Packet>> = (0..b)
            .map(|_| {
                (0..k)
                    .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
                    .collect()
            })
            .collect();
        let refs: Vec<&[Packet]> = jobs.iter().map(|x| x.as_slice()).collect();

        // Correctness first: transform ≡ dense, bit for bit, every job.
        let dense = replay_batch_kernels(&compiled.opt, &compiled.kernels, &refs).unwrap();
        let ntt = replay_batch_ntt(&compiled.opt, &backend, &refs).unwrap();
        for j in 0..b {
            if ntt[j].outputs != dense[j].outputs || ntt[j].report != dense[j].report {
                equals_dense = false;
                println!("K={k} job {j}: NTT output DIVERGES from dense");
            }
        }

        let dense_stats = bench(&format!("dense gemm      K={k:<5}"), iters, |_| {
            replay_batch_kernels(&compiled.opt, &compiled.kernels, &refs)
                .unwrap()
                .len()
        });
        let ntt_stats = bench(&format!("ntt pipeline    K={k:<5}"), iters, |_| {
            replay_batch_ntt(&compiled.opt, &backend, &refs).unwrap().len()
        });
        println!("{dense_stats}");
        println!("{ntt_stats}");
        let dense_us = dense_stats.median.as_secs_f64() * 1e6 / b as f64;
        let ntt_us = ntt_stats.median.as_secs_f64() * 1e6 / b as f64;
        let speedup = dense_stats.median.as_secs_f64() / ntt_stats.median.as_secs_f64();
        println!(
            "K={k:<5} R={r:<4} ops {}:{} selected={} per-job: dense {dense_us:.2}us  \
             ntt {ntt_us:.2}us  speedup {speedup:.2}x",
            backend.dense_ops(),
            backend.ntt_ops(),
            want.name(),
        );
        sweep.push(SweepPoint {
            k,
            r,
            selected: want,
            dense_ops: backend.dense_ops(),
            ntt_ops: backend.ntt_ops(),
            dense_us_per_job: dense_us,
            ntt_us_per_job: ntt_us,
            speedup,
        });
    }

    // Measured crossover: the smallest swept K where the transform wins
    // wall time (0 = never did, in this run).
    let crossover_k = sweep.iter().find(|p| p.speedup >= 1.0).map_or(0, |p| p.k);
    println!("measured crossover K: {crossover_k} (0 = dense won everywhere)");
    assert!(equals_dense, "NTT backend must be bit-identical to the dense engine");

    write_json(w, b, equals_dense, crossover_k, &sweep);

    if bench_smoke() {
        println!("(smoke mode: timing assertion skipped)");
    } else {
        let big = sweep.last().expect("non-empty sweep");
        assert!(
            big.speedup >= 2.0,
            "NTT backend must reach >= 2x per-job throughput over the dense \
             engine at K={}, got {:.2}x",
            big.k,
            big.speedup
        );
    }
    println!("\nntt_backend bench complete");
}

/// Emit `BENCH_ntt.json` at the repo root (manifest dir's parent).
fn write_json(w: usize, b: usize, equals_dense: bool, crossover_k: usize, sweep: &[SweepPoint]) {
    let sweep_json: Vec<String> = sweep
        .iter()
        .map(|p| {
            format!(
                concat!(
                    "{{\"name\":\"k{}\",\"k\":{},\"r\":{},\"selected\":\"{}\",",
                    "\"dense_ops\":{},\"ntt_ops\":{},",
                    "\"dense_us_per_job\":{:.3},\"ntt_us_per_job\":{:.3},",
                    "\"speedup\":{:.3}}}"
                ),
                p.k,
                p.k,
                p.r,
                p.selected.name(),
                p.dense_ops,
                p.ntt_ops,
                p.dense_us_per_job,
                p.ntt_us_per_job,
                p.speedup
            )
        })
        .collect();
    let json = format!(
        concat!(
            "{{\"bench\":\"ntt_backend\",\"smoke\":{},",
            "\"shape\":{{\"w\":{},\"batch\":{},\"ratio_gate\":{}}},",
            "\"backend_equals_dense\":{},\"crossover_k\":{},\"sweep\":[{}]}}"
        ),
        bench_smoke(),
        w,
        b,
        dce::net::NTT_DENSE_OP_RATIO,
        equals_dense,
        crossover_k,
        sweep_json.join(",")
    );
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("manifest dir has a parent")
        .join("BENCH_ntt.json");
    // Fail loudly: a missing BENCH_ntt.json silently breaks the
    // "perf trajectory is recorded" contract this bench exists for.
    std::fs::write(&path, format!("{json}\n"))
        .unwrap_or_else(|e| panic!("could not write {}: {e}", path.display()));
    println!("wrote {}", path.display());
}

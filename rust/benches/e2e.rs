//! Bench: **end-to-end system** — full coordinator jobs (plan → simulate
//! → verify) across algorithms, and the PJRT bulk-encode serving path
//! (throughput / latency), mirroring the paper's deployment story.
//!
//! The PJRT sections need `make artifacts`; they are skipped otherwise.

use dce::coordinator::config::CodeKind;
use dce::coordinator::{EncodeJob, EncodeService, ExecOptions, JobConfig};
use dce::framework::AlgoRequest;
use dce::gf::{Field, GfPrime};
use dce::util::{bench, Rng};
use std::path::Path;

fn main() {
    let f = GfPrime::default_field();

    println!("## coordinator jobs: plan → simulate → verify (W = 64)");
    println!(
        "{:<12} {:>4} {:>4} | {:>5} {:>8} | {:>12}",
        "algorithm", "K", "R", "C1", "C2", "wall(med)"
    );
    for algo in [
        AlgoRequest::RsSpecific,
        AlgoRequest::Universal,
        AlgoRequest::MultiReduce,
        AlgoRequest::Direct,
    ] {
        let cfg = JobConfig {
            k: 64,
            r: 16,
            w: 64,
            ports: 2,
            code: CodeKind::RsStructured,
            algorithm: algo,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg).unwrap();
        let rep = job.run(&ExecOptions::new()).unwrap();
        assert_eq!(rep.verified, Some(true));
        let stats = bench(&format!("{algo:?}"), 5, |_| job.run(&ExecOptions::new()).unwrap());
        println!(
            "{:<12} {:>4} {:>4} | {:>5} {:>8} | {:>12?}",
            format!("{}", rep.choice),
            64,
            16,
            rep.sim.c1,
            rep.sim.c2,
            stats.median
        );
    }

    let artifacts = Path::new("artifacts");
    if !artifacts.join("manifest.txt").exists() {
        println!("\n(skipping PJRT serving bench: run `make artifacts`)");
        return;
    }

    println!("\n## PJRT serving path: batched GF(786433) encode (K=64, R=16)");
    let code = dce::codes::GrsCode::structured(&f, 64, 16, 2).unwrap();
    let parity = code.parity_matrix(&f);
    for &(workers, requests, w) in &[(1usize, 32usize, 256usize), (2, 64, 256), (4, 64, 512)] {
        let svc = EncodeService::start(&f, &parity, artifacts, 256, workers, 32).unwrap();
        let mut rng = Rng::new(9);
        let batches: Vec<Vec<Vec<u64>>> = (0..requests)
            .map(|_| {
                (0..64)
                    .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
                    .collect()
            })
            .collect();
        let t0 = std::time::Instant::now();
        let pending: Vec<_> = batches
            .iter()
            .map(|x| svc.submit(x.clone()).unwrap())
            .collect();
        for rx in pending {
            rx.recv().unwrap().y.unwrap();
        }
        let wall = t0.elapsed();
        let elems = requests * 64 * w;
        println!(
            "workers={workers} requests={requests} W={w}: {wall:?} — {:>7.1} req/s, {:>7.2} Melem/s",
            requests as f64 / wall.as_secs_f64(),
            elems as f64 / wall.as_secs_f64() / 1e6
        );
        if let Some((n, p50, p99, max)) = svc.metrics.latency_summary("encode_latency") {
            println!("  latency µs: n={n} p50={p50} p99={p99} max={max}");
        }
        svc.shutdown();
    }
    println!("\ne2e bench complete");
}

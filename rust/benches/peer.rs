//! Bench: **peer-to-peer execution vs centralized replay** — what the
//! "no central processor" model costs (or saves) on real substrates.
//!
//! For each shape, the same cached Plan runs through the replay engine
//! (one thread walks the schedule) and through the peer engine over all
//! three transports (N threads, each holding only its own shard,
//! exchanging packets through channels / shared-memory rings / framed
//! TCP sockets). Correctness is asserted inline, every iteration:
//!
//! * peer coded outputs are **bit-identical** to replay, and
//! * the **measured** traffic — barriers crossed, messages, bandwidth —
//!   equals `costs::plan_statics` exactly (`peer_equals_replay` /
//!   `peer_matches_statics` in the JSON are hard trend gates).
//!
//! Results land in `BENCH_peer.json` at the repo root.

use dce::coordinator::config::VerifyMode;
use dce::coordinator::{EncodeJob, Engine, ExecOptions, JobConfig, PlanCache};
use dce::framework::{costs, AlgoRequest};
use dce::net::transport::TransportKind;
use dce::util::{bench_iters, bench_smoke};
use std::time::Instant;

struct EngineRow {
    label: String,
    median_us: u64,
}

fn median_us(samples: &mut Vec<u64>) -> u64 {
    samples.sort_unstable();
    samples[samples.len() / 2]
}

fn main() {
    let iters = bench_iters(12);
    let smoke = bench_smoke();
    let shapes = [
        ("K16_R4_W64", 16usize, 4usize, 64usize),
        ("K32_R8_W32", 32, 8, 32),
        ("K64_R16_W16", 64, 16, 16),
    ];

    let mut rows: Vec<(String, Vec<EngineRow>)> = Vec::new();
    let mut equals_replay = true;
    let mut matches_statics = true;

    for (name, k, r, w) in shapes {
        let cfg = JobConfig {
            k,
            r,
            w,
            ports: 2,
            algorithm: AlgoRequest::Universal,
            verify: VerifyMode::Off,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg).unwrap();
        let cache = PlanCache::new();
        let compiled = job.compiled(&cache).unwrap();
        let statics = costs::plan_statics(&compiled.plan, w as u64);
        println!("## {name}: statics C1={} C2={}", statics.0, statics.1);

        let replay_opts = ExecOptions::cached(&cache);
        let baseline = job.run(&replay_opts).unwrap();
        assert_eq!((baseline.sim.c1, baseline.sim.c2), statics, "{name}: replay vs statics");

        let mut engine_rows = Vec::new();
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            let rep = job.run(&replay_opts).unwrap();
            samples.push(t0.elapsed().as_micros() as u64);
            assert_eq!(rep.sim, baseline.sim);
        }
        let replay_us = median_us(&mut samples);
        println!("  replay           : {replay_us:>8} us/run (median of {iters})");
        engine_rows.push(EngineRow {
            label: "replay".into(),
            median_us: replay_us,
        });

        // Replay's coded bits are the oracle the peer engines must hit.
        let oracle = job.encode(&cache, &[&job.inputs], &replay_opts).unwrap();

        for kind in TransportKind::ALL {
            let opts = ExecOptions::cached(&cache).engine(Engine::Peer(kind));
            let mut samples = Vec::with_capacity(iters);
            let mut last_sim = None;
            for _ in 0..iters {
                let t0 = Instant::now();
                let rep = job.run(&opts).unwrap();
                samples.push(t0.elapsed().as_micros() as u64);
                last_sim = Some(rep.sim);
            }
            let us = median_us(&mut samples);
            let sim = last_sim.expect("at least one iteration");
            if (sim.c1, sim.c2) != statics || sim != baseline.sim {
                matches_statics = false;
            }
            let peer_coded = job.encode(&cache, &[&job.inputs], &opts).unwrap();
            if peer_coded.coded != oracle.coded {
                equals_replay = false;
            }
            println!(
                "  peer over {kind:<7}: {us:>8} us/run ({:.2}x replay, measured C1={} C2={})",
                us as f64 / replay_us.max(1) as f64,
                sim.c1,
                sim.c2
            );
            engine_rows.push(EngineRow {
                label: format!("peer-{kind}"),
                median_us: us,
            });
        }
        rows.push((name.to_string(), engine_rows));
    }

    assert!(equals_replay, "peer coded outputs must be bit-identical to replay");
    assert!(matches_statics, "peer measured traffic must equal plan statics");

    let shape_json: Vec<String> = rows
        .iter()
        .map(|(name, engines)| {
            let engine_json: Vec<String> = engines
                .iter()
                .map(|e| format!("{{\"engine\":\"{}\",\"median_us\":{}}}", e.label, e.median_us))
                .collect();
            format!("{{\"shape\":\"{name}\",\"engines\":[{}]}}", engine_json.join(","))
        })
        .collect();
    let json = format!(
        concat!(
            "{{\"bench\":\"peer\",\"smoke\":{},\"iters\":{},",
            "\"peer_equals_replay\":{},\"peer_matches_statics\":{},",
            "\"shapes\":[{}]}}"
        ),
        smoke,
        iters,
        equals_replay,
        matches_statics,
        shape_json.join(",")
    );
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("manifest dir has a parent")
        .join("BENCH_peer.json");
    std::fs::write(&path, format!("{json}\n"))
        .unwrap_or_else(|e| panic!("could not write {}: {e}", path.display()));
    println!("wrote {}", path.display());
    println!("\npeer bench complete");
}

//! Bench: **§VI headline + Theorems 7/9** — decentralized encoding of
//! systematic Reed–Solomon codes: the specific (Cauchy / two
//! draw-and-loose) path versus the universal path, sweeping R, aspect
//! ratio and the α/β cost regime. Reproduces the crossover structure the
//! paper predicts: the specific algorithm doubles C1 but shrinks C2 from
//! `Θ(√R)` to `Θ(log R)`, so it wins whenever bandwidth dominates and `H`
//! is large (Remark 8), and loses for small codes or latency-dominated
//! regimes.

use dce::codes::GrsCode;
use dce::framework::{A2aAlgo, SystematicEncode};
use dce::gf::{Field, GfPrime};
use dce::net::{run, CostModel, Packet, Sim, SimReport};
use dce::util::bench;
use std::sync::Arc;

fn payloads(f: &GfPrime, k: usize, w: usize) -> Vec<Packet> {
    (0..k)
        .map(|i| (0..w).map(|j| f.elem((i * w + j) as u64 * 31 + 1)).collect())
        .collect()
}

fn both(f: &GfPrime, k: usize, r: usize, w: usize, p: usize) -> (SimReport, SimReport) {
    let code = GrsCode::structured(f, k, r, 2).expect("structured code");
    let mut spec = SystematicEncode::new_rs(*f, &code, payloads(f, k, w), p).expect("spec");
    let rep_s = run(&mut Sim::new(p), &mut spec).expect("spec run");
    let a = Arc::new(code.parity_matrix(f));
    let mut univ =
        SystematicEncode::new(*f, a, payloads(f, k, w), p, A2aAlgo::Universal).expect("univ");
    let rep_u = run(&mut Sim::new(p), &mut univ).expect("univ run");
    assert_eq!(spec.coded(), univ.coded(), "K={k} R={r}: outputs must agree");
    (rep_s, rep_u)
}

fn main() {
    let f = GfPrime::default_field();

    println!("## specific vs universal — C1/C2 sweep (W = 1, p = 1)");
    println!(
        "{:>5} {:>5} | {:>7} {:>7} | {:>7} {:>7} | {:>9}",
        "K", "R", "C1 spec", "C1 univ", "C2 spec", "C2 univ", "C2 gain"
    );
    for &(k, r) in &[
        (16usize, 16usize),
        (64, 16),
        (64, 64),
        (256, 64),
        (256, 256),
        (1024, 256),
        (1024, 1024),
        (16, 64),
        (64, 256),
    ] {
        let (s, u) = both(&f, k, r, 1, 1);
        println!(
            "{k:>5} {r:>5} | {:>7} {:>7} | {:>7} {:>7} | {:>8.2}x",
            s.c1,
            u.c1,
            s.c2,
            u.c2,
            u.c2 as f64 / s.c2 as f64
        );
    }

    println!("\n## cost-model crossover (K = R = 256, W = 64): C = αC1 + β·20·C2");
    println!(
        "{:>9} {:>9} | {:>12} {:>12} | {:>8}",
        "alpha", "beta", "C specific", "C universal", "winner"
    );
    let (s, u) = both(&f, 256, 256, 64, 1);
    for &(alpha, beta) in &[
        (1.0f64, 1.0f64),
        (10.0, 1.0),
        (100.0, 1.0),
        (1000.0, 1.0),
        (10000.0, 1.0),
        (1.0, 10.0),
    ] {
        let model = CostModel::new(alpha, beta, 20);
        let (cs, cu) = (s.cost(&model), u.cost(&model));
        println!(
            "{alpha:>9.0} {beta:>9.0} | {cs:>12.0} {cu:>12.0} | {:>8}",
            if cs <= cu { "specific" } else { "universal" }
        );
    }

    println!("\n## Theorem 7/9 round structure: C1(spec) = 2·C1(draw-and-loose) + reduce");
    for &(k, r) in &[(64usize, 64usize), (256, 256)] {
        let (s, _) = both(&f, k, r, 1, 1);
        // Single block (K = R): C1 = 2·log2(R) + 0-round scales + 1-col
        // framework (no reduce needed when M = 1... the row reduce over
        // M+1 = 2 nodes adds 1 round).
        let h = (r as f64).log2() as u64;
        println!("K=R={r}: C1 = {} (2H = {}, +reduce)", s.c1, 2 * h);
    }

    println!("\n## ablation — Remark 8: draw-and-loose C2 vs DFT depth H (K = 256, p = 1)");
    println!("(H = 0 degenerates to prepare-and-shoot; gains require large H)");
    println!("{:>3} {:>5} {:>5} | {:>6} {:>6}", "H", "Z", "M", "C1", "C2");
    {
        use dce::codes::StructuredPoints;
        use dce::collectives::DrawLoose;
        let n = 256usize;
        for h in [0u32, 2, 4, 6, 8] {
            let z = dce::util::ipow(2, h);
            let m = n / z as usize;
            let sp = StructuredPoints::with_h(&f, n, 2, h, (0..m as u64).collect()).unwrap();
            let inputs: Vec<Packet> = (0..n as u64).map(|i| vec![f.elem(i + 1)]).collect();
            let mut dl = DrawLoose::new(f, (0..n).collect(), 1, &sp, inputs, false).unwrap();
            let rep = run(&mut Sim::new(1), &mut dl).unwrap();
            println!("{h:>3} {z:>5} {m:>5} | {:>6} {:>6}", rep.c1, rep.c2);
        }
    }

    println!("\n## ablation — structured-point radix P (K = 256, p = 1)");
    println!("{:>3} {:>3} | {:>6} {:>6}", "P", "H", "C1", "C2");
    {
        use dce::codes::StructuredPoints;
        use dce::collectives::DrawLoose;
        let n = 256usize;
        for p_base in [2u64, 4, 16] {
            let h = StructuredPoints::max_h(&f, n as u64, p_base);
            let m = n / dce::util::ipow(p_base, h) as usize;
            let sp = StructuredPoints::with_h(&f, n, p_base, h, (0..m as u64).collect()).unwrap();
            let inputs: Vec<Packet> = (0..n as u64).map(|i| vec![f.elem(i + 1)]).collect();
            let mut dl = DrawLoose::new(f, (0..n).collect(), 1, &sp, inputs, false).unwrap();
            let rep = run(&mut Sim::new(1), &mut dl).unwrap();
            println!("{p_base:>3} {h:>3} | {:>6} {:>6}", rep.c1, rep.c2);
        }
    }

    println!("\n## wall-clock (specific path, W = 16)");
    for &(k, r) in &[(64usize, 16usize), (256, 64)] {
        let stats = bench(&format!("rs-specific K={k} R={r} W=16"), 5, |_| {
            both(&f, k, r, 16, 1)
        });
        println!("{stats}");
    }
    println!("\nrs_specific bench complete");
}

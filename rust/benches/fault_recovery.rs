//! Bench: **erasure-recovery throughput vs fraction of failed
//! processors** — the cost of serving a batch through the degraded
//! path (taint analysis + surviving-rows columnar pass + survivor →
//! lost-sink repair) as the failure count sweeps 0 → R.
//!
//! Scenario: a `[N = 80, K = 64]` structured-RS shape loses `F`
//! processors (drawn over sources *and* sinks, storage-loss style) and
//! the service keeps answering every request with all `R` parity rows —
//! lost sinks reconstructed from any `K` survivors instead of
//! re-encoded. Correctness is asserted unconditionally: every degraded
//! batch must be **bit-identical** to the healthy batch at every
//! failure count up to `R`. Timings land in `BENCH_fault.json` at the
//! repo root for the CI `bench-trend` job (smoke runs gate structure
//! only; commit a non-smoke run to track the perf trajectory).

use dce::coordinator::{EncodeJob, ExecOptions, JobConfig, PlanCache};
use dce::gf::Field;
use dce::net::{FaultSpec, POST_RUN};
use dce::util::{bench, bench_iters, bench_smoke, Rng};

struct Point {
    failed: usize,
    frac: f64,
    us_per_job: f64,
    recovered_per_job: usize,
    recovered_per_s: f64,
}

fn main() {
    let cfg = JobConfig {
        k: 64,
        r: 16,
        w: 4,
        ports: 2,
        ..JobConfig::default()
    };
    let (k, r, w, ports) = (cfg.k, cfg.r, cfg.w, cfg.ports);
    let n = k + r;
    let b = 16usize;
    let iters = bench_iters(20);
    let job = EncodeJob::synthetic(cfg).unwrap();
    let cache = PlanCache::new();
    let f = job.field.clone();

    let mut rng = Rng::new(0xFA);
    let jobs: Vec<Vec<Vec<u64>>> = (0..b)
        .map(|_| {
            (0..k)
                .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
                .collect()
        })
        .collect();
    let refs: Vec<&[Vec<u64>]> = jobs.iter().map(|x| x.as_slice()).collect();
    let healthy = job.encode(&cache, &refs, &ExecOptions::cached(&cache)).unwrap().coded;

    println!("## erasure recovery (K={k} R={r} W={w} p={ports}, B={b}, {iters} rounds)");
    let procs: Vec<usize> = (0..n).collect();
    let mut points = Vec::new();
    for failed in [0usize, 4, 8, 12, 16] {
        let faults = FaultSpec::random_crashes(0xFA + failed as u64, &procs, failed, POST_RUN);
        // Correctness gate first — at every failure count up to R, the
        // repaired batch is bit-identical to the healthy one.
        let out = job
            .encode(&cache, &refs, &ExecOptions::cached(&cache).faults(&faults))
            .expect("≤ R crashes are always recoverable");
        let (coded, stats) = (out.coded, out.recovery.expect("degraded batch reports stats"));
        assert_eq!(coded, healthy, "failed={failed}: repaired ≡ healthy");
        assert_eq!(
            stats.outputs_recovered,
            (stats.outputs_lost * b) as u64,
            "failed={failed}"
        );

        let st = bench(&format!("degraded batch serve, {failed:>2} failed"), iters, |_| {
            job.encode(&cache, &refs, &ExecOptions::cached(&cache).faults(&faults))
                .unwrap()
                .coded
                .len()
        });
        println!("{st}");
        let secs = st.median.as_secs_f64();
        let recovered = stats.outputs_recovered as f64;
        points.push(Point {
            failed,
            frac: failed as f64 / n as f64,
            us_per_job: secs * 1e6 / b as f64,
            recovered_per_job: stats.outputs_lost,
            recovered_per_s: if secs > 0.0 { recovered / secs } else { 0.0 },
        });
    }
    for p in &points {
        println!(
            "failed {:>2} ({:>5.1}%): {:>8.2} us/job, {} sinks repaired/job, {:>10.0} repairs/s",
            p.failed,
            p.frac * 100.0,
            p.us_per_job,
            p.recovered_per_job,
            p.recovered_per_s
        );
    }
    write_json(k, r, w, ports, b, &points);
    println!("\nfault_recovery bench complete");
}

/// Emit `BENCH_fault.json` at the repo root (manifest dir's parent).
fn write_json(k: usize, r: usize, w: usize, ports: usize, b: usize, points: &[Point]) {
    let point_json: Vec<String> = points
        .iter()
        .map(|p| {
            format!(
                concat!(
                    "{{\"failed\":{},\"frac\":{:.4},\"us_per_job\":{:.3},",
                    "\"recovered_per_job\":{},\"recovered_per_s\":{:.1}}}"
                ),
                p.failed, p.frac, p.us_per_job, p.recovered_per_job, p.recovered_per_s
            )
        })
        .collect();
    let json = format!(
        concat!(
            "{{\"bench\":\"fault_recovery\",\"smoke\":{},",
            "\"shape\":{{\"k\":{},\"r\":{},\"w\":{},\"ports\":{}}},\"batch\":{},",
            "\"recovery_exact\":true,\"points\":[{}]}}"
        ),
        bench_smoke(),
        k,
        r,
        w,
        ports,
        b,
        point_json.join(",")
    );
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("manifest dir has a parent")
        .join("BENCH_fault.json");
    std::fs::write(&path, format!("{json}\n"))
        .unwrap_or_else(|e| panic!("could not write {}: {e}", path.display()));
    println!("wrote {}", path.display());
}

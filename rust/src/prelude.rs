//! The supported public surface, in one import.
//!
//! ```no_run
//! use dce::prelude::*;
//!
//! let job = EncodeJob::synthetic(JobConfig::default())?;
//! let report = job.run(&ExecOptions::new())?;
//! # Ok::<(), Box<dyn std::error::Error>>(())
//! ```
//!
//! Everything here follows the crate's deprecation policy (see the
//! crate docs' *Stable vs internal surface*); examples import only
//! from this module. Internal layers (plan IR, collectives, kernels,
//! transports) stay behind their full paths on purpose — reaching for
//! them is the signal you've left the supported surface.

pub use crate::coordinator::{
    BatchPolicy, DegradedInfo, EncodeJob, EncodeOutcome, EncodeRequest, EncodeResponse,
    EncodeService, Engine, ExecOptions, JobConfig, JobReport, Metrics, PlanCache, RecoveryStats,
    ServeOptions, ServeRejection, WireClient, WireServer,
};
pub use crate::error::{Error, RecoveryShortfall};
pub use crate::gf::{AnyField, Field, Gf2e, GfPrime, IsaRequest, Mat};
pub use crate::net::transport::TransportKind;
pub use crate::net::{CostModel, FaultSpec, Packet, SimReport, POST_RUN};

// Teaching surface: the building blocks the `examples/` walk through
// (codes, frameworks, the round simulator, peer execution). Stable in
// spirit — they mirror the paper — but their signatures track the
// engine more closely than the job/service API above.
pub use crate::codes::{GrsCode, LagrangeCode};
pub use crate::collectives::TreeReduce;
pub use crate::coordinator::wire_layout;
pub use crate::framework::{A2aAlgo, NonSystematicEncode, SystematicEncode};
pub use crate::gf::SymbolLayout;
pub use crate::net::peer::{
    execute_shard, merge_stats, run_peer, spawn_local, spawn_local_chaos, DegradedPeerRun,
    PeerRun, PeerStats, RetryPolicy, ShardedPlan,
};
pub use crate::net::transport::{ChaosSpec, TcpTransport};
pub use crate::net::{pkt_scale, run, Collective, ProcId, Sim};
pub use crate::util::Rng;

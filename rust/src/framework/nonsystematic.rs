//! Appendix B: decentralized encoding for **non-systematic** codes
//! `(x̃_0..x̃_{N−1}) = (x_0..x_{K−1})·G`, `G ∈ F^{K×N}`.
//!
//! * **K > R** (App. B-A): pad `G' = [G; B] ∈ F^{N×N}` (sinks hold zero
//!   packets, `B` arbitrary) and run ONE all-to-all encode over all `N`
//!   processors; processor `j` ends with codeword coordinate `j`.
//! * **K ≤ R** (App. B-B, Fig. 9): sinks form a `K×⌊R/K⌋` grid with the
//!   `L = R mod K` leftover sinks stacked one-per-column at the bottom;
//!   sources are a prepended column. Phase 1: `K` row broadcasts of
//!   `x_k`. Phase 2: column `m` (height `K + e_m`) runs an A2A on
//!   `G'_m = [[G_m | G_{M,m}]; [B]]` — stacked sinks hold zeros and
//!   receive the leftover coordinates; simultaneously the *sources* run
//!   one A2A among themselves for coordinates `0..K` (the paper's grid
//!   only covers the sink coordinates; the source column is
//!   processor-disjoint from the sink columns, so this shares rounds).
//!
//! Coordinate ownership: coordinate `j` ends at processor `j` in both
//! cases (sources `0..K`, sinks `K..N`).

use super::systematic::Layout;
use crate::collectives::{Par, Pipeline, PrepareShoot, StageBuilder, TreeBroadcast};
use crate::gf::{Field, Mat};
use crate::net::{pkt_zero, Collective, Msg, Outputs, Packet, ProcId};
use std::sync::Arc;

/// A non-systematic encoding job. Processor ids: sources `0..K`, sinks
/// `K..K+R` (`N = K + R` codeword coordinates).
pub struct NonSystematicEncode {
    pipe: Pipeline,
    layout: Layout,
}

impl NonSystematicEncode {
    /// `g`: the `K×N` generator; `inputs`: the `K` source packets.
    pub fn new<F: Field>(
        f: F,
        g: Arc<Mat>,
        inputs: Vec<Packet>,
        p: usize,
    ) -> anyhow::Result<Self> {
        let k = g.rows;
        let n = g.cols;
        anyhow::ensure!(n >= k, "generator must have N ≥ K");
        let r = n - k;
        anyhow::ensure!(inputs.len() == k);
        let layout = Layout { k, r };
        let w = inputs.first().map_or(0, |x| x.len());
        let pipe = if k > r {
            Self::build_k_gt_r(f, g, inputs, p, w, layout)
        } else {
            Self::build_k_le_r(f, g, inputs, p, w, layout)?
        };
        Ok(NonSystematicEncode { pipe, layout })
    }

    /// K > R: one N×N all-to-all encode on `G' = [G; 0]`.
    fn build_k_gt_r<F: Field>(
        f: F,
        g: Arc<Mat>,
        inputs: Vec<Packet>,
        p: usize,
        w: usize,
        layout: Layout,
    ) -> Pipeline {
        let (k, n) = (layout.k, layout.n());
        let stage: StageBuilder = Box::new(move |prev: &Outputs| {
            let gp = Mat::from_fn(n, n, |row, col| if row < k { g[(row, col)] } else { 0 });
            let procs: Vec<ProcId> = (0..n).collect();
            let ins: Vec<Packet> = (0..n)
                .map(|i| prev.get(&i).cloned().unwrap_or_else(|| pkt_zero(w)))
                .collect();
            Box::new(PrepareShoot::new(f.clone(), procs, p, Arc::new(gp), ins))
                as Box<dyn Collective>
        });
        let init: Outputs = inputs.into_iter().enumerate().collect();
        Pipeline::from_inputs(init, vec![stage])
    }

    /// K ≤ R: the Fig. 9 grid.
    fn build_k_le_r<F: Field>(
        f: F,
        g: Arc<Mat>,
        inputs: Vec<Packet>,
        p: usize,
        w: usize,
        layout: Layout,
    ) -> anyhow::Result<Pipeline> {
        let (k, r) = (layout.k, layout.r);
        let full_cols = r / k; // grid columns of height K
        let l = r % k; // leftover sinks, stacked one per column
        anyhow::ensure!(
            l == 0 || l <= full_cols,
            "cannot distribute {l} leftover sinks into {full_cols} columns"
        );

        // Phase 1: K row broadcasts (source kk → its row's grid sinks).
        let phase1: StageBuilder = Box::new(move |prev: &Outputs| {
            let rows: Vec<Box<dyn Collective>> = (0..k)
                .map(|kk| {
                    let mut procs: Vec<ProcId> = vec![kk];
                    for m in 0..full_cols {
                        procs.push(k + m * k + kk);
                    }
                    Box::new(TreeBroadcast::new(procs, p, prev[&kk].clone()))
                        as Box<dyn Collective>
                })
                .collect();
            Box::new(Par::new(rows).expect("disjoint by construction")) as Box<dyn Collective>
        });

        // Phase 2 (one Par): per-column A2As over the sinks, plus the
        // source-column A2A for coordinates 0..K — all disjoint.
        let phase2: StageBuilder = Box::new(move |prev: &Outputs| {
            let mut groups: Vec<Box<dyn Collective>> = Vec::with_capacity(full_cols + 1);
            // Sources compute coordinates 0..K among themselves.
            {
                let procs: Vec<ProcId> = (0..k).collect();
                let block = Mat::from_fn(k, k, |row, col| g[(row, col)]);
                let ins: Vec<Packet> = procs.iter().map(|pid| prev[pid].clone()).collect();
                groups.push(Box::new(PrepareShoot::new(
                    f.clone(),
                    procs,
                    p,
                    Arc::new(block),
                    ins,
                )));
            }
            // Sink column m computes coordinates [K+mK, K+(m+1)K) plus,
            // if it hosts a stacked sink, coordinate K + full_cols·K + m.
            for m in 0..full_cols {
                let extra = usize::from(m < l);
                let size = k + extra;
                let mut procs: Vec<ProcId> = (0..k).map(|kk| k + m * k + kk).collect();
                if extra == 1 {
                    procs.push(k + full_cols * k + m);
                }
                let block = Mat::from_fn(size, size, |row, col| {
                    if row >= k {
                        return 0; // B rows — stacked sink holds zero
                    }
                    let coord = if col < k {
                        k + m * k + col
                    } else {
                        k + full_cols * k + m
                    };
                    g[(row, coord)]
                });
                let ins: Vec<Packet> = procs
                    .iter()
                    .enumerate()
                    .map(|(i, pid)| {
                        if i < k {
                            prev[pid].clone()
                        } else {
                            pkt_zero(w)
                        }
                    })
                    .collect();
                groups.push(Box::new(PrepareShoot::new(
                    f.clone(),
                    procs,
                    p,
                    Arc::new(block),
                    ins,
                )));
            }
            Box::new(Par::new(groups).expect("disjoint by construction")) as Box<dyn Collective>
        });

        let init: Outputs = inputs.into_iter().enumerate().collect();
        Ok(Pipeline::from_inputs(init, vec![phase1, phase2]))
    }

    /// Remark 9 + Appendix B: non-systematic **Lagrange** encoding on
    /// structured points — every `K×K` block `L_m = V_α^{-1}·V_{β,m}` of
    /// the Lagrange matrix is Cauchy-like with `u = v = 1`, so each grid
    /// column (and the source column, for coordinates `0..K`) runs the
    /// §VI two-pass draw-and-loose instead of the universal A2A.
    /// Requires `K | N` (the code builder guarantees it).
    pub fn new_lagrange<F: Field>(
        f: F,
        code: &crate::codes::LagrangeCode,
        inputs: Vec<Packet>,
        p: usize,
    ) -> anyhow::Result<Self> {
        let k = code.k();
        let n = code.n();
        anyhow::ensure!(n % k == 0 && n >= 2 * k, "need K | N with at least one worker block");
        let alpha_design = code
            .alpha_design
            .clone()
            .ok_or_else(|| anyhow::anyhow!("code must be built with LagrangeCode::structured"))?;
        let beta_designs = code.beta_designs.clone();
        anyhow::ensure!(beta_designs.len() == n / k);
        anyhow::ensure!(inputs.len() == k);
        let r = n - k;
        let layout = Layout { k, r };
        let full_cols = r / k;
        let ones = vec![1u64; k];

        // Phase 1: K row broadcasts (as in the universal K ≤ R path).
        let phase1: StageBuilder = Box::new(move |prev: &Outputs| {
            let rows: Vec<Box<dyn Collective>> = (0..k)
                .map(|kk| {
                    let mut procs: Vec<ProcId> = vec![kk];
                    for m in 0..full_cols {
                        procs.push(k + m * k + kk);
                    }
                    Box::new(TreeBroadcast::new(procs, p, prev[&kk].clone()))
                        as Box<dyn Collective>
                })
                .collect();
            Box::new(Par::new(rows).expect("disjoint by construction")) as Box<dyn Collective>
        });

        // Phase 2: sources run the block-0 Cauchy A2A (coordinates 0..K);
        // sink column m runs block m+1 — all disjoint, shared rounds.
        let phase2: StageBuilder = {
            let f = f.clone();
            Box::new(move |prev: &Outputs| {
                let mut groups: Vec<Box<dyn Collective>> = Vec::with_capacity(full_cols + 1);
                for block in 0..=full_cols {
                    let procs: Vec<ProcId> = if block == 0 {
                        (0..k).collect()
                    } else {
                        (0..k).map(|kk| k + (block - 1) * k + kk).collect()
                    };
                    let ins: Vec<Packet> = procs.iter().map(|pid| prev[pid].clone()).collect();
                    groups.push(Box::new(
                        crate::collectives::CauchyA2A::new(
                            f.clone(),
                            procs,
                            p,
                            &alpha_design,
                            &beta_designs[block],
                            ones.clone(),
                            ones.clone(),
                            ins,
                        )
                        .expect("structured Lagrange designs validated"),
                    ));
                }
                Box::new(Par::new(groups).expect("disjoint by construction")) as Box<dyn Collective>
            })
        };

        let init: Outputs = inputs.into_iter().enumerate().collect();
        Ok(NonSystematicEncode {
            pipe: Pipeline::from_inputs(init, vec![phase1, phase2]),
            layout,
        })
    }

    pub fn layout(&self) -> Layout {
        self.layout
    }

    /// The full codeword in coordinate order (coordinate `j` lives at
    /// processor `j`).
    pub fn codeword(&self) -> Vec<Packet> {
        let outs = self.pipe.outputs();
        (0..self.layout.n()).map(|pid| outs[&pid].clone()).collect()
    }
}

impl Collective for NonSystematicEncode {
    fn participants(&self) -> Vec<ProcId> {
        self.pipe.participants()
    }
    fn is_done(&self) -> bool {
        self.pipe.is_done()
    }
    fn step(&mut self, inbox: Vec<Msg>) -> Vec<Msg> {
        self.pipe.step(inbox)
    }
    fn outputs(&self) -> Outputs {
        self.pipe.outputs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{pkt_add_scaled, run, Sim};

    fn oracle<F: Field>(f: &F, g: &Mat, inputs: &[Packet]) -> Vec<Packet> {
        let w = inputs[0].len();
        (0..g.cols)
            .map(|j| {
                let mut acc = pkt_zero(w);
                for i in 0..g.rows {
                    pkt_add_scaled(f, &mut acc, g[(i, j)], &inputs[i]);
                }
                acc
            })
            .collect()
    }

    fn check(k: usize, r: usize, p: usize) {
        let f = crate::gf::GfPrime::default_field();
        let g = Arc::new(Mat::random(&f, k, k + r, (k * 100 + r) as u64));
        let inputs: Vec<Packet> = (0..k as u64).map(|i| vec![f.elem(i * 11 + 1)]).collect();
        let mut job = NonSystematicEncode::new(f, g.clone(), inputs.clone(), p).unwrap();
        run(&mut Sim::new(p), &mut job).unwrap();
        assert_eq!(job.codeword(), oracle(&f, &g, &inputs), "K={k} R={r} p={p}");
    }

    #[test]
    fn k_gt_r_single_a2a() {
        check(12, 4, 1);
        check(9, 2, 2);
    }

    #[test]
    fn fig9_k4_r27() {
        // Fig. 9: K = 4, R = 27 — 6 full columns + 3 stacked sinks.
        check(4, 27, 1);
    }

    #[test]
    fn k_le_r_exact_and_ragged_grids() {
        check(4, 12, 1);
        check(5, 5, 1);
        check(3, 10, 2);
        check(4, 9, 1);
    }

    #[test]
    fn lagrange_specific_path_matches_universal() {
        // Remark 9: the structured non-systematic Lagrange encode via
        // Cauchy A2As equals the universal App-B encode of L_{α,β}.
        let f = crate::gf::GfPrime::default_field();
        for (k, n, ports) in [(8usize, 24usize, 1usize), (8, 32, 2), (16, 32, 1)] {
            let code = crate::codes::LagrangeCode::structured(&f, k, n, 2).unwrap();
            let g = Arc::new(code.matrix(&f));
            let inputs: Vec<Packet> =
                (0..k as u64).map(|i| vec![f.elem(i * 5 + 1), f.elem(i)]).collect();
            let mut spec =
                NonSystematicEncode::new_lagrange(f, &code, inputs.clone(), ports).unwrap();
            let rep_s = run(&mut Sim::new(ports), &mut spec).unwrap();
            let mut univ = NonSystematicEncode::new(f, g.clone(), inputs.clone(), ports).unwrap();
            let rep_u = run(&mut Sim::new(ports), &mut univ).unwrap();
            assert_eq!(spec.codeword(), univ.codeword(), "K={k} N={n}");
            assert_eq!(spec.codeword(), oracle(&f, &g, &inputs), "K={k} N={n}");
            // Both paths move data; costs differ per the §VI trade-off.
            assert!(rep_s.c1 > 0 && rep_u.c1 > 0);
        }
    }

    #[test]
    fn lagrange_nonsystematic_generator() {
        // LCC's non-systematic use case (Appendix B motivation).
        let f = crate::gf::GfPrime::default_field();
        let code = crate::codes::LagrangeCode::new(
            (1..=4).collect(),
            (100..112).collect(),
        )
        .unwrap();
        let g = Arc::new(code.matrix(&f));
        let inputs: Vec<Packet> = (0..4u64).map(|i| vec![i * 9 + 2]).collect();
        let mut job = NonSystematicEncode::new(f, g.clone(), inputs.clone(), 1).unwrap();
        run(&mut Sim::new(1), &mut job).unwrap();
        assert_eq!(job.codeword(), oracle(&f, &g, &inputs));
    }
}

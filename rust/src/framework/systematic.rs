//! The §III decentralized-encoding framework for systematic codes
//! `G = [I | A]`: sources `0..K` hold data, sinks `K..K+R` require
//! `x̃_r = Σ_k A[k][r]·x_k`.
//!
//! * **K ≥ R** (§III-A, Fig. 3): sources form an `R×M` grid
//!   (`M = ⌈K/R⌉`); missing cells of the last column are filled by
//!   *borrowing* sinks `T_r` (holding zero packets). Phase 1 runs `M`
//!   parallel column all-to-all encodes on the stacked blocks
//!   `A_m` (eq. (1)); phase 2 runs `R` parallel row reduces accumulating
//!   the partials at each sink.
//! * **K < R** (§III-B, Fig. 4): sinks form a `K×M` grid
//!   (`M = ⌈R/K⌉`) with the sources as an extra column. Phase 1 runs `K`
//!   parallel row broadcasts of `x_k`; phase 2 runs `M` parallel column
//!   A2As on the concatenated blocks `A_m` (eq. (2)), borrowing `S_k` for
//!   missing cells.
//!
//! Each column A2A is either universal ([`PrepareShoot`]), the
//! [`MultiReduce`] baseline, or — for structured GRS codes — the §VI
//! [`CauchyA2A`] (Theorems 6–9).

use crate::codes::GrsCode;
use crate::collectives::{
    CauchyA2A, LocalOp, MultiReduce, Par, Pipeline, PrepareShoot, StageBuilder, TreeBroadcast,
    TreeReduce,
};
use crate::gf::{Field, Mat};
use crate::net::{pkt_zero, Collective, Msg, Outputs, Packet, ProcId};
use std::sync::Arc;

/// Which all-to-all encode implementation drives the column phases.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum A2aAlgo {
    /// Prepare-and-shoot (§IV) — works for any matrix.
    Universal,
    /// All-gather + local combine (Jeong et al. \[21\] baseline).
    MultiReduce,
}

/// Processor-id layout shared by all frameworks: sources then sinks.
#[derive(Clone, Copy, Debug)]
pub struct Layout {
    pub k: usize,
    pub r: usize,
}

impl Layout {
    pub fn source(&self, k: usize) -> ProcId {
        debug_assert!(k < self.k);
        k
    }
    pub fn sink(&self, r: usize) -> ProcId {
        debug_assert!(r < self.r);
        self.k + r
    }
    pub fn n(&self) -> usize {
        self.k + self.r
    }
}

/// A fully-composed systematic encoding job (a [`Collective`]); outputs
/// are the coded packets at the sink processors.
pub struct SystematicEncode {
    pipe: Pipeline,
    layout: Layout,
}

impl SystematicEncode {
    /// Universal/baseline path: encode arbitrary `A ∈ F^{K×R}`.
    pub fn new<F: Field>(
        f: F,
        a: Arc<Mat>,
        inputs: Vec<Packet>,
        p: usize,
        algo: A2aAlgo,
    ) -> anyhow::Result<Self> {
        let (k, r) = (a.rows, a.cols);
        anyhow::ensure!(inputs.len() == k, "need K = {k} inputs");
        let layout = Layout { k, r };
        let w = inputs.first().map_or(0, |x| x.len());
        let make_a2a = move |f: &F,
                             procs: Vec<ProcId>,
                             p: usize,
                             c: Arc<Mat>,
                             ins: Vec<Packet>|
              -> Box<dyn Collective> {
            match algo {
                A2aAlgo::Universal => Box::new(PrepareShoot::new(f.clone(), procs, p, c, ins)),
                A2aAlgo::MultiReduce => Box::new(MultiReduce::new(f.clone(), procs, p, c, ins)),
            }
        };
        let pipe = if k >= r {
            build_k_ge_r(f, a, inputs, p, w, layout, make_a2a)
        } else {
            build_k_lt_r(f, a, inputs, p, w, layout, make_a2a)
        };
        Ok(SystematicEncode { pipe, layout })
    }

    /// Specific path (§VI): systematic GRS on structured points; the
    /// parity matrix is derived from the code. Requires `R | K` or `K | R`
    /// (Remark 4), which [`GrsCode::structured`] guarantees.
    pub fn new_rs<F: Field>(
        f: F,
        code: &GrsCode,
        inputs: Vec<Packet>,
        p: usize,
    ) -> anyhow::Result<Self> {
        let (k, r) = (code.k(), code.r());
        anyhow::ensure!(inputs.len() == k);
        let layout = Layout { k, r };
        let w = inputs.first().map_or(0, |x| x.len());
        let cauchy = code.cauchy();
        if k >= r {
            anyhow::ensure!(k % r == 0, "specific path needs R | K");
            anyhow::ensure!(
                code.alpha_designs.len() == k / r && code.beta_design.is_some(),
                "code must be built with GrsCode::structured"
            );
            let beta_design = code.beta_design.clone().unwrap();
            let designs = code.alpha_designs.clone();
            let pipe = build_k_ge_r_with(
                f.clone(),
                inputs,
                p,
                w,
                layout,
                move |ff: &F, procs, pp, m, ins| -> Box<dyn Collective> {
                    let pre: Vec<u64> =
                        (0..r).map(|s| ff.inv(cauchy.phi(ff, m, s, r))).collect();
                    let post: Vec<u64> = (0..r).map(|rr| cauchy.psi(ff, m, rr, r)).collect();
                    Box::new(
                        CauchyA2A::new(
                            ff.clone(),
                            procs,
                            pp,
                            &designs[m],
                            &beta_design,
                            pre,
                            post,
                            ins,
                        )
                        .expect("structured design validated"),
                    )
                },
            );
            Ok(SystematicEncode { pipe, layout })
        } else {
            anyhow::ensure!(r % k == 0, "specific path needs K | R");
            let (_, beta_designs) =
                GrsCode::structured_beta_designs(&f, k, r, code.alpha_designs[0].p_base)?;
            let alpha_design = code.alpha_designs[0].clone();
            let uinv: Vec<u64> = code.u.iter().map(|&x| f.inv(x)).collect();
            let v = code.v.clone();
            let pipe = build_k_lt_r_with(
                f.clone(),
                inputs,
                p,
                w,
                layout,
                move |ff, procs, pp, m, ins| {
                    let post: Vec<u64> = v[m * k..(m + 1) * k].to_vec();
                    Box::new(
                        CauchyA2A::new(
                            ff.clone(),
                            procs,
                            pp,
                            &alpha_design,
                            &beta_designs[m],
                            uinv.clone(),
                            post,
                            ins,
                        )
                        .expect("structured design validated"),
                    )
                },
            );
            Ok(SystematicEncode { pipe, layout })
        }
    }

    pub fn layout(&self) -> Layout {
        self.layout
    }

    /// Coded packets in sink order `T_0..T_{R−1}`.
    pub fn coded(&self) -> Vec<Packet> {
        let outs = self.pipe.outputs();
        (0..self.layout.r)
            .map(|r| outs[&self.layout.sink(r)].clone())
            .collect()
    }
}

/// K ≥ R, universal/baseline: generic over the block-A2A factory
/// (signature: field, procs, ports, block matrix, inputs).
fn build_k_ge_r<F: Field>(
    f: F,
    a: Arc<Mat>,
    inputs: Vec<Packet>,
    p: usize,
    w: usize,
    layout: Layout,
    make_a2a: impl Fn(&F, Vec<ProcId>, usize, Arc<Mat>, Vec<Packet>) -> Box<dyn Collective>
        + Send
        + 'static,
) -> Pipeline {
    let (k, r) = (layout.k, layout.r);
    let m_cols = k.div_ceil(r);
    let f2 = f.clone();
    build_k_ge_r_with(f2, inputs, p, w, layout, move |ff, procs, pp, m, ins| {
        // Block A_m = rows [mR, (m+1)R) of A, zero-padded past row K
        // (borrowed processors hold zero data; B is arbitrary).
        let block = Mat::from_fn(r, r, |s, c| {
            let row = m * r + s;
            if row < k {
                a[(row, c)]
            } else {
                0
            }
        });
        let _ = m_cols;
        make_a2a(ff, procs, pp, Arc::new(block), ins)
    })
}

/// K ≥ R grid scaffolding, generic over a per-column A2A factory
/// (receives the *block index m*).
fn build_k_ge_r_with<F: Field>(
    f: F,
    inputs: Vec<Packet>,
    p: usize,
    w: usize,
    layout: Layout,
    make_block: impl Fn(&F, Vec<ProcId>, usize, usize, Vec<Packet>) -> Box<dyn Collective>
        + Send
        + 'static,
) -> Pipeline {
    let (k, r) = (layout.k, layout.r);
    let m_cols = k.div_ceil(r);
    // Grid cell (row s, col m) → processor: source s + mR, or the
    // borrowed sink T_s when s + mR ≥ K (Fig. 3).
    let cell = move |s: usize, m: usize| -> ProcId {
        let idx = s + m * r;
        if idx < k {
            layout.source(idx)
        } else {
            layout.sink(s)
        }
    };

    // Phase 1: M parallel column A2As.
    let phase1: StageBuilder = {
        let f = f.clone();
        Box::new(move |prev: &Outputs| {
            let cols: Vec<Box<dyn Collective>> = (0..m_cols)
                .map(|m| {
                    let procs: Vec<ProcId> = (0..r).map(|s| cell(s, m)).collect();
                    let ins: Vec<Packet> = (0..r)
                        .map(|s| {
                            if s + m * r < k {
                                prev[&cell(s, m)].clone()
                            } else {
                                pkt_zero(w) // borrowed sink: zero data
                            }
                        })
                        .collect();
                    make_block(&f, procs, p, m, ins)
                })
                .collect();
            Box::new(Par::new(cols).expect("disjoint by construction")) as Box<dyn Collective>
        })
    };

    // Phase 2: R parallel row reduces rooted at the sinks.
    let phase2: StageBuilder = {
        let f = f.clone();
        Box::new(move |prev: &Outputs| {
            let rows: Vec<Box<dyn Collective>> = (0..r)
                .map(|s| {
                    let mut procs: Vec<ProcId> = vec![layout.sink(s)];
                    for m in 0..m_cols {
                        let pid = cell(s, m);
                        if pid != layout.sink(s) {
                            procs.push(pid);
                        }
                    }
                    Box::new(TreeReduce::from_outputs(f.clone(), procs, p, prev, w))
                        as Box<dyn Collective>
                })
                .collect();
            Box::new(Par::new(rows).expect("disjoint by construction")) as Box<dyn Collective>
        })
    };

    let init: Outputs = inputs
        .into_iter()
        .enumerate()
        .map(|(i, pkt)| (layout.source(i), pkt))
        .collect();
    Pipeline::from_inputs(init, vec![phase1, phase2])
}

/// K < R, universal/baseline.
fn build_k_lt_r<F: Field>(
    f: F,
    a: Arc<Mat>,
    inputs: Vec<Packet>,
    p: usize,
    w: usize,
    layout: Layout,
    make_a2a: impl Fn(&F, Vec<ProcId>, usize, Arc<Mat>, Vec<Packet>) -> Box<dyn Collective>
        + Send
        + 'static,
) -> Pipeline {
    let (k, r) = (layout.k, layout.r);
    build_k_lt_r_with(f, inputs, p, w, layout, move |ff, procs, pp, m, ins| {
        // Block A_m = columns [mK, (m+1)K) of A, zero-padded past col R
        // (borrowed sources require no packet; B is arbitrary).
        let block = Mat::from_fn(k, k, |row, c| {
            let col = m * k + c;
            if col < r {
                a[(row, col)]
            } else {
                0
            }
        });
        make_a2a(ff, procs, pp, Arc::new(block), ins)
    })
}

/// K < R grid scaffolding, generic over a per-column A2A factory.
fn build_k_lt_r_with<F: Field>(
    f: F,
    inputs: Vec<Packet>,
    p: usize,
    w: usize,
    layout: Layout,
    make_block: impl Fn(&F, Vec<ProcId>, usize, usize, Vec<Packet>) -> Box<dyn Collective>
        + Send
        + 'static,
) -> Pipeline {
    let (k, r) = (layout.k, layout.r);
    let m_cols = r.div_ceil(k);
    // Grid cell (row kk, col m) → sink T_{kk + mK}, or borrowed source
    // S_kk when the sink does not exist (Fig. 4).
    let cell = move |kk: usize, m: usize| -> ProcId {
        let idx = kk + m * k;
        if idx < r {
            layout.sink(idx)
        } else {
            layout.source(kk)
        }
    };

    // Phase 1: K parallel row broadcasts (source → its row's sinks).
    let phase1: StageBuilder = {
        let f = f.clone();
        let _ = &f;
        Box::new(move |prev: &Outputs| {
            let rows: Vec<Box<dyn Collective>> = (0..k)
                .map(|kk| {
                    let mut procs: Vec<ProcId> = vec![layout.source(kk)];
                    for m in 0..m_cols {
                        let pid = cell(kk, m);
                        if pid != layout.source(kk) {
                            procs.push(pid);
                        }
                    }
                    Box::new(TreeBroadcast::new(procs, p, prev[&layout.source(kk)].clone()))
                        as Box<dyn Collective>
                })
                .collect();
            Box::new(Par::new(rows).expect("disjoint by construction")) as Box<dyn Collective>
        })
    };

    // Phase 2: M parallel column A2As on A_m (K×K).
    let phase2: StageBuilder = {
        let f = f.clone();
        Box::new(move |prev: &Outputs| {
            let cols: Vec<Box<dyn Collective>> = (0..m_cols)
                .map(|m| {
                    let procs: Vec<ProcId> = (0..k).map(|kk| cell(kk, m)).collect();
                    // Every participant of column m holds x_kk after the
                    // broadcast (the borrowed source natively).
                    let ins: Vec<Packet> = procs.iter().map(|pid| prev[pid].clone()).collect();
                    make_block(&f, procs, p, m, ins)
                })
                .collect();
            Box::new(Par::new(cols).expect("disjoint by construction")) as Box<dyn Collective>
        })
    };

    // Keep only sink outputs (drop the borrowed sources' garbage columns).
    let cleanup: StageBuilder = Box::new(move |prev: &Outputs| {
        let outs: Outputs = prev
            .iter()
            .filter(|(&pid, _)| pid >= k && pid < k + r)
            .map(|(&pid, pkt)| (pid, pkt.clone()))
            .collect();
        Box::new(LocalOp::new(outs)) as Box<dyn Collective>
    });

    let init: Outputs = inputs
        .into_iter()
        .enumerate()
        .map(|(i, pkt)| (layout.source(i), pkt))
        .collect();
    let _ = w;
    Pipeline::from_inputs(init, vec![phase1, phase2, cleanup])
}

impl Collective for SystematicEncode {
    fn participants(&self) -> Vec<ProcId> {
        self.pipe.participants()
    }
    fn is_done(&self) -> bool {
        self.pipe.is_done()
    }
    fn step(&mut self, inbox: Vec<Msg>) -> Vec<Msg> {
        self.pipe.step(inbox)
    }
    fn outputs(&self) -> Outputs {
        self.pipe.outputs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{run, Sim};

    fn oracle<F: Field>(f: &F, a: &Mat, inputs: &[Packet]) -> Vec<Packet> {
        let w = inputs[0].len();
        (0..a.cols)
            .map(|j| {
                let mut acc = pkt_zero(w);
                for i in 0..a.rows {
                    crate::net::pkt_add_scaled(f, &mut acc, a[(i, j)], &inputs[i]);
                }
                acc
            })
            .collect()
    }

    fn check_universal(k: usize, r: usize, p: usize, w: usize, algo: A2aAlgo) {
        let f = crate::gf::GfPrime::default_field();
        let a = Arc::new(Mat::random(&f, k, r, (k * 1000 + r) as u64));
        let inputs: Vec<Packet> = (0..k)
            .map(|i| (0..w).map(|j| f.elem((i * w + j + 1) as u64 * 37)).collect())
            .collect();
        let mut job = SystematicEncode::new(f, a.clone(), inputs.clone(), p, algo).unwrap();
        run(&mut Sim::new(p), &mut job).unwrap();
        assert_eq!(job.coded(), oracle(&f, &a, &inputs), "K={k} R={r} p={p}");
    }

    #[test]
    fn k_ge_r_divisible() {
        check_universal(12, 4, 1, 1, A2aAlgo::Universal);
        check_universal(16, 4, 2, 2, A2aAlgo::Universal);
    }

    #[test]
    fn fig3_k25_r4() {
        // Fig. 3: K = 25, R = 4, p = 1 — borrow T_1..T_3.
        check_universal(25, 4, 1, 1, A2aAlgo::Universal);
    }

    #[test]
    fn fig4_k4_r25() {
        // Fig. 4: K = 4, R = 25, p = 1 — borrow S_1..S_3.
        check_universal(4, 25, 1, 1, A2aAlgo::Universal);
    }

    #[test]
    fn k_lt_r_divisible() {
        check_universal(4, 12, 1, 1, A2aAlgo::Universal);
        check_universal(8, 24, 2, 3, A2aAlgo::Universal);
    }

    #[test]
    fn equal_k_r() {
        check_universal(8, 8, 1, 1, A2aAlgo::Universal);
        check_universal(7, 7, 2, 1, A2aAlgo::Universal);
    }

    #[test]
    fn multireduce_baseline_agrees() {
        check_universal(12, 4, 1, 1, A2aAlgo::MultiReduce);
        check_universal(4, 12, 1, 2, A2aAlgo::MultiReduce);
    }

    #[test]
    fn rs_specific_k_ge_r() {
        let f = crate::gf::GfPrime::default_field();
        let code = GrsCode::structured(&f, 24, 8, 2).unwrap();
        let a = code.parity_matrix(&f);
        let inputs: Vec<Packet> = (0..24u64).map(|i| vec![f.elem(i * 71 + 5)]).collect();
        let mut job = SystematicEncode::new_rs(f, &code, inputs.clone(), 1).unwrap();
        run(&mut Sim::new(1), &mut job).unwrap();
        assert_eq!(job.coded(), oracle(&f, &a, &inputs));
    }

    #[test]
    fn rs_specific_k_lt_r() {
        let f = crate::gf::GfPrime::default_field();
        let code = GrsCode::structured(&f, 8, 24, 2).unwrap();
        let a = code.parity_matrix(&f);
        let inputs: Vec<Packet> = (0..8u64).map(|i| vec![f.elem(i * 13 + 3)]).collect();
        let mut job = SystematicEncode::new_rs(f, &code, inputs.clone(), 1).unwrap();
        run(&mut Sim::new(1), &mut job).unwrap();
        assert_eq!(job.coded(), oracle(&f, &a, &inputs));
    }

    #[test]
    fn rs_specific_beats_universal_in_c2() {
        // The §VI headline: specific ≪ universal in C2 for structured RS.
        let f = crate::gf::GfPrime::default_field();
        let code = GrsCode::structured(&f, 64, 64, 2).unwrap();
        let a = Arc::new(code.parity_matrix(&f));
        let inputs: Vec<Packet> = (0..64u64).map(|i| vec![f.elem(i + 1)]).collect();

        let mut spec = SystematicEncode::new_rs(f, &code, inputs.clone(), 1).unwrap();
        let rep_s = run(&mut Sim::new(1), &mut spec).unwrap();
        let mut univ =
            SystematicEncode::new(f, a, inputs, 1, A2aAlgo::Universal).unwrap();
        let rep_u = run(&mut Sim::new(1), &mut univ).unwrap();
        assert_eq!(spec.coded(), univ.coded());
        assert!(
            rep_s.c2 < rep_u.c2,
            "specific C2 {} should beat universal C2 {}",
            rep_s.c2,
            rep_u.c2
        );
    }
}

//! The paper's decentralized-encoding frameworks (§III, Appendix B) and
//! every closed-form cost expression (Table I, Lemmas 1–4, Theorems 1–9,
//! Corollary 1, Appendix A).
//!
//! [`plan`] is the entry point used by the coordinator: given a code /
//! matrix and the network parameters, it picks the cheapest applicable
//! algorithm (specific when the structure admits it, universal otherwise)
//! and returns a ready-to-run [`Collective`](crate::net::Collective).

pub mod costs;
pub mod nonsystematic;
pub mod systematic;

pub use nonsystematic::NonSystematicEncode;
pub use systematic::{A2aAlgo, Layout, SystematicEncode};

use crate::codes::GrsCode;
use crate::gf::{Field, Mat};
use crate::net::Packet;
use std::sync::Arc;

/// What the planner decided to run (reported in job metrics).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PlanChoice {
    /// §VI specific path: Cauchy blocks via two draw-and-looses.
    RsSpecific,
    /// §IV universal path: prepare-and-shoot per block.
    Universal,
    /// Jeong et al. \[21\] baseline.
    MultiReduce,
    /// Naive dense transfers.
    Direct,
}

impl std::fmt::Display for PlanChoice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            PlanChoice::RsSpecific => "rs-specific",
            PlanChoice::Universal => "universal",
            PlanChoice::MultiReduce => "multi-reduce",
            PlanChoice::Direct => "direct",
        };
        write!(f, "{s}")
    }
}

/// Requested algorithm (config); `Auto` lets the planner decide.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum AlgoRequest {
    #[default]
    Auto,
    RsSpecific,
    Universal,
    MultiReduce,
    Direct,
}

impl std::str::FromStr for AlgoRequest {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "auto" => AlgoRequest::Auto,
            "rs-specific" | "specific" => AlgoRequest::RsSpecific,
            "universal" => AlgoRequest::Universal,
            "multi-reduce" | "multireduce" => AlgoRequest::MultiReduce,
            "direct" => AlgoRequest::Direct,
            other => anyhow::bail!("unknown algorithm {other:?}"),
        })
    }
}

/// A planned systematic encoding job, ready to step live on the engine.
///
/// (Distinct from the compiled, replayable [`crate::net::plan::Plan`] IR —
/// see [`compile_plan`] for the bridge between the two.)
pub struct PlannedJob {
    pub choice: PlanChoice,
    pub job: Box<dyn crate::net::Collective>,
    pub layout: Layout,
}

/// A shape's encoding schedule compiled to the replayable Plan IR: the
/// planner's `choice`, the processor `layout`, the raw [`Plan`], its
/// pass-pipeline lowering (the flattened
/// [`OptimizedPlan`](crate::net::opt::OptimizedPlan) the serving path
/// executes — the raw plan stays alongside for wire-level replay,
/// tracing and inspection), and the field's packed-symbol
/// [`Kernels`](crate::gf::kernels::Kernels) vtable resolved **once
/// here** so no per-request (let alone per-element) field dispatch
/// survives on the batched serving path. Cache-friendly
/// (width-independent, `Send + Sync`); the coordinator's `PlanCache`
/// stores these behind `Arc`s.
#[derive(Clone, Debug)]
pub struct CompiledPlan {
    pub choice: PlanChoice,
    pub layout: Layout,
    pub plan: crate::net::plan::Plan,
    pub opt: crate::net::opt::OptimizedPlan,
    pub kernels: crate::gf::kernels::Kernels,
    /// The encode engine the backend-selection pass picked for batched
    /// replays ([`select_backend`](crate::net::opt::select_backend)):
    /// the packed dense gemm, or — for GRS/Lagrange codes on NTT-friendly
    /// geometry past the op-count crossover — the `O(K log K)` transform
    /// pipeline. Cross-checked against the generator algebra at compile
    /// time, exactly like the [`OutputMatrix`](crate::net::OutputMatrix).
    pub backend: crate::net::opt::EncodeBackend,
}

impl CompiledPlan {
    /// Batched columnar replay through this compiled schedule — the
    /// coordinator's batch-serving hot loop. Dispatches to whichever
    /// engine the backend-selection pass picked: the plan's pre-resolved
    /// packed kernels
    /// ([`replay_batch_kernels`](crate::net::exec::replay_batch_kernels))
    /// or the NTT pipeline
    /// ([`replay_batch_ntt`](crate::net::exec::replay_batch_ntt)) — both
    /// bit-identical per job.
    pub fn replay_batch(
        &self,
        jobs: &[&[Packet]],
    ) -> anyhow::Result<Vec<crate::net::Replay>> {
        match &self.backend {
            crate::net::opt::EncodeBackend::Ntt(b) => {
                crate::net::exec::replay_batch_ntt(&self.opt, b, jobs)
            }
            crate::net::opt::EncodeBackend::Dense => {
                crate::net::exec::replay_batch_kernels(&self.opt, &self.kernels, jobs)
            }
        }
    }

    /// The plan's [`PlanProfile`](costs::PlanProfile) at payload width
    /// `w`: communication statics, optimizer statics, the chosen encode
    /// backend with the op counts behind the crossover decision, and
    /// the ISA tier the plan's kernels dispatch to.
    pub fn profile(&self, w: u64) -> costs::PlanProfile {
        let mut prof = costs::plan_profile(&self.plan, w);
        prof.backend = self.backend.kind();
        if let crate::net::opt::EncodeBackend::Ntt(b) = &self.backend {
            prof.backend_dense_ops = b.dense_ops();
            prof.backend_ntt_ops = b.ntt_ops();
        }
        prof.isa = self.kernels.isa().name();
        prof
    }

    /// This plan re-pinned to an explicit kernel ISA tier — the
    /// coordinator applies a job's `isa = "…"` config override here,
    /// right after compile. The tier is clamped to host support
    /// ([`IsaTier::clamp_supported`](crate::gf::simd::IsaTier)), so a
    /// forced `avx2` on a non-AVX2 host degrades to scalar, never to an
    /// illegal instruction.
    pub fn with_isa(mut self, isa: crate::gf::simd::IsaTier) -> Self {
        self.kernels = self.kernels.with_isa(isa);
        self
    }

    /// Degraded batched replay through this compiled schedule: the
    /// failure pattern is analyzed once on the raw plan's round/SendOp
    /// schedule (which is the live emission stream verbatim), then one
    /// strided columnar pass evaluates only the surviving rows of the
    /// optimized plan — through the plan's packed kernels. The pairing
    /// of raw + optimized forms is exactly why this struct keeps both —
    /// see
    /// [`replay_degraded_batch`](crate::net::exec::replay_degraded_batch).
    pub fn replay_degraded_batch(
        &self,
        jobs: &[&[Packet]],
        faults: &crate::net::FaultSpec,
    ) -> anyhow::Result<(crate::net::DegradedReport, Vec<crate::net::Outputs>)> {
        crate::net::exec::replay_degraded_batch_kernels(
            &self.plan,
            &self.opt,
            &self.kernels,
            jobs,
            faults,
        )
    }
}

/// Predicted `(C1, C2)` of the specific (§VI) and universal (§IV) paths
/// for a structured code, from the paper's formulas — used by the
/// cost-aware `Auto` planner. Returns `(specific, universal)`.
pub fn predict_costs(code: &GrsCode, w: u64, p: u64) -> ((u64, u64), (u64, u64)) {
    let (k, r) = (code.k() as u64, code.r() as u64);
    let block = k.min(r);
    // Specific: two draw-and-loose passes per Theorem 7/9.
    let design = code
        .alpha_designs
        .first()
        .expect("structured code has designs");
    let z = design.z;
    let m = (block / z).max(1);
    let spec_a2a = costs::theorem7_cauchy(m, design.p_base, design.h, p);
    let univ_a2a = costs::theorem3_universal(block, p);
    let scale = |a2a: (u64, u64)| (a2a.0, a2a.1 * w);
    let (spec, univ) = if k >= r {
        (
            costs::theorem1_framework(scale(spec_a2a), k, r, w, p),
            costs::theorem1_framework(scale(univ_a2a), k, r, w, p),
        )
    } else {
        (
            costs::theorem2_framework(scale(spec_a2a), k, r, w, p),
            costs::theorem2_framework(scale(univ_a2a), k, r, w, p),
        )
    };
    (spec, univ)
}

/// Plan a systematic encode of `code` (or of an explicit parity matrix
/// when `code` is `None`) under the given request. `Auto` compares the
/// paper's cost formulas under `model` (falling back to a
/// bandwidth-dominated default) and picks the cheaper of specific /
/// universal — reproducing Remark 8's guidance that the specific path
/// only pays off when `H` is large relative to the doubled round count.
pub fn plan<F: Field>(
    f: &F,
    code: Option<&GrsCode>,
    parity: Option<Arc<Mat>>,
    inputs: Vec<Packet>,
    p: usize,
    request: AlgoRequest,
) -> anyhow::Result<PlannedJob> {
    plan_with_model(f, code, parity, inputs, p, request, None)
}

/// Resolve the parity matrix a request encodes against.
fn resolve_matrix<F: Field>(
    f: &F,
    code: Option<&GrsCode>,
    parity: Option<Arc<Mat>>,
) -> anyhow::Result<Arc<Mat>> {
    match (parity, code) {
        (Some(m), _) => Ok(m),
        (None, Some(c)) => Ok(Arc::new(c.parity_matrix(f))),
        (None, None) => anyhow::bail!("plan needs a code or a parity matrix"),
    }
}

/// Resolve an [`AlgoRequest`] into a concrete [`PlanChoice`] for payload
/// width `w` — the cost-aware `Auto` decision of Remark 8, shared by the
/// live planner and the plan compiler (and by cache-key derivation, which
/// must know the resolved algorithm without building anything).
pub fn resolve_choice<F: Field>(
    f: &F,
    code: Option<&GrsCode>,
    w: usize,
    p: usize,
    request: AlgoRequest,
    model: Option<crate::net::CostModel>,
) -> anyhow::Result<PlanChoice> {
    // The specific path applies when the code carries structured designs
    // and the aspect ratio is divisible (Remark 4).
    let specific_ok = code.is_some_and(|c| {
        let (k, r) = (c.k(), c.r());
        let div_ok = (k >= r && k % r == 0) || (k < r && r % k == 0);
        let designs_ok = if k >= r {
            c.alpha_designs.len() == k.div_ceil(r.max(1)) && c.beta_design.is_some()
        } else {
            !c.alpha_designs.is_empty()
        };
        div_ok && designs_ok
    });
    Ok(match request {
        AlgoRequest::Auto => {
            if specific_ok {
                // Cost-aware: compare the formula-predicted costs.
                let (spec, univ) =
                    predict_costs(code.expect("specific_ok"), w.max(1) as u64, p as u64);
                let model = model
                    .unwrap_or_else(|| crate::net::CostModel::bandwidth_bound(f.bits()));
                if model.cost(spec.0, spec.1) <= model.cost(univ.0, univ.1) {
                    PlanChoice::RsSpecific
                } else {
                    PlanChoice::Universal
                }
            } else {
                PlanChoice::Universal
            }
        }
        AlgoRequest::RsSpecific => {
            anyhow::ensure!(specific_ok, "specific algorithm requires a structured GRS code");
            PlanChoice::RsSpecific
        }
        AlgoRequest::Universal => PlanChoice::Universal,
        AlgoRequest::MultiReduce => PlanChoice::MultiReduce,
        AlgoRequest::Direct => PlanChoice::Direct,
    })
}

/// Build the collective executing `choice` over `inputs`.
fn build_job<F: Field>(
    f: &F,
    code: Option<&GrsCode>,
    a: Arc<Mat>,
    inputs: Vec<Packet>,
    p: usize,
    choice: PlanChoice,
) -> anyhow::Result<Box<dyn crate::net::Collective>> {
    let layout = Layout {
        k: a.rows,
        r: a.cols,
    };
    Ok(match choice {
        PlanChoice::RsSpecific => Box::new(SystematicEncode::new_rs(
            f.clone(),
            code.ok_or_else(|| anyhow::anyhow!("specific path requires a code"))?,
            inputs,
            p,
        )?),
        PlanChoice::Universal => Box::new(SystematicEncode::new(
            f.clone(),
            a,
            inputs,
            p,
            A2aAlgo::Universal,
        )?),
        PlanChoice::MultiReduce => Box::new(SystematicEncode::new(
            f.clone(),
            a,
            inputs,
            p,
            A2aAlgo::MultiReduce,
        )?),
        PlanChoice::Direct => {
            let sources: Vec<usize> = (0..layout.k).collect();
            let sinks: Vec<usize> = (layout.k..layout.n()).collect();
            Box::new(crate::collectives::DirectEncode::new(
                f.clone(),
                sources,
                sinks,
                p,
                a,
                inputs,
            ))
        }
    })
}

/// [`plan`] with an explicit cost model for the `Auto` decision.
pub fn plan_with_model<F: Field>(
    f: &F,
    code: Option<&GrsCode>,
    parity: Option<Arc<Mat>>,
    inputs: Vec<Packet>,
    p: usize,
    request: AlgoRequest,
    model: Option<crate::net::CostModel>,
) -> anyhow::Result<PlannedJob> {
    let a = resolve_matrix(f, code, parity)?;
    let layout = Layout {
        k: a.rows,
        r: a.cols,
    };
    let w = inputs.first().map_or(1, |x| x.len());
    let choice = resolve_choice(f, code, w, p, request, model)?;
    let job = build_job(f, code, a, inputs, p, choice)?;
    Ok(PlannedJob {
        choice,
        job,
        layout,
    })
}

/// Compile the encoding schedule for a shape into the replayable
/// [`Plan`](crate::net::plan::Plan) IR: resolve the `Auto` choice for the
/// *intended* payload width `w` (the schedule itself is width-independent,
/// but the cost-aware decision is not), build the chosen collective over
/// the `K` basis payloads, and record one run through the instrumenting
/// recorder (`net::plan::compile`). The returned [`CompiledPlan`] replays
/// any same-shape request via [`crate::net::exec::replay`] with no
/// control-flow rederivation.
pub fn compile_plan<F: Field>(
    f: &F,
    code: Option<&GrsCode>,
    parity: Option<Arc<Mat>>,
    p: usize,
    w: usize,
    request: AlgoRequest,
    model: Option<crate::net::CostModel>,
) -> anyhow::Result<CompiledPlan> {
    let a = resolve_matrix(f, code, parity)?;
    let layout = Layout {
        k: a.rows,
        r: a.cols,
    };
    let choice = resolve_choice(f, code, w, p, request, model)?;
    let plan = crate::net::plan::compile(p, layout.k, |basis| {
        build_job(f, code, a.clone(), basis, p, choice)
    })?;
    let opt = crate::net::opt::optimize(&plan);
    // Cross-check the flattening against the code's algebra: sink `r`
    // must end up with `Σ_k A[k][r]·x_k`, so its dense row over the
    // inputs is exactly column `r` of the parity matrix (column `K + r`
    // of the systematic generator `G = [I | A]`). Any divergence means a
    // miscompiled schedule or a broken optimizer pass — fail before the
    // plan can be cached.
    let mut sink_rows = Vec::with_capacity(layout.r);
    for r in 0..layout.r {
        let pid = layout.sink(r);
        let row = opt
            .matrix
            .row_for(pid)
            .ok_or_else(|| anyhow::anyhow!("compiled plan has no output for sink {pid}"))?;
        for k in 0..layout.k {
            anyhow::ensure!(
                row[k] == a[(k, r)],
                "flattened row of sink {r} diverges from the generator matrix at \
                 input {k}: plan has {}, code has {}",
                row[k],
                a[(k, r)]
            );
        }
        sink_rows.push(opt.matrix.assignment()[&pid]);
    }
    // Backend selection (the second compile-time cross-check): when the
    // code's evaluation geometry admits the NTT pipeline *and* the
    // op-count crossover favors it, the serving path gets the transform;
    // a detected-but-divergent shape is a hard compile error.
    let shape = code.map(|c| crate::net::opt::CodeShape {
        alphas: &c.alphas,
        betas: &c.betas,
        u: &c.u,
        v: &c.v,
    });
    let backend = crate::net::opt::select_backend(f, &opt, shape, &sink_rows)?;
    Ok(CompiledPlan {
        choice,
        layout,
        plan,
        opt,
        backend,
        // Resolved once per compile: every cached replay (batched,
        // degraded, service path) reuses this vtable instead of
        // re-deriving layout/tables — and instead of per-element
        // `AnyField` dispatch.
        kernels: crate::gf::kernels::Kernels::for_field(f),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{run, Sim};

    #[test]
    fn auto_is_cost_aware() {
        let f = crate::gf::GfPrime::default_field();
        // Large structured code: specific's Θ(log R) C2 wins under the
        // bandwidth-dominated default model.
        let code = GrsCode::structured(&f, 256, 256, 2).unwrap();
        let inputs: Vec<Packet> = (0..256u64).map(|i| vec![i + 1]).collect();
        let plan_big = plan(&f, Some(&code), None, inputs, 1, AlgoRequest::Auto).unwrap();
        assert_eq!(plan_big.choice, PlanChoice::RsSpecific);
        // Small code: the doubled rounds are not worth it (Remark 8).
        let code = GrsCode::structured(&f, 16, 4, 2).unwrap();
        let inputs: Vec<Packet> = (0..16u64).map(|i| vec![i + 1]).collect();
        let plan_small = plan(&f, Some(&code), None, inputs, 1, AlgoRequest::Auto).unwrap();
        assert_eq!(plan_small.choice, PlanChoice::Universal);
        // Latency-dominated model: universal even at scale (half the rounds).
        let code = GrsCode::structured(&f, 256, 256, 2).unwrap();
        let inputs: Vec<Packet> = (0..256u64).map(|i| vec![i + 1]).collect();
        let plan_lat = plan_with_model(
            &f,
            Some(&code),
            None,
            inputs,
            1,
            AlgoRequest::Auto,
            Some(crate::net::CostModel::latency_bound(20)),
        )
        .unwrap();
        assert_eq!(plan_lat.choice, PlanChoice::Universal);
    }

    #[test]
    fn auto_falls_back_to_universal() {
        let f = crate::gf::GfPrime::default_field();
        let code = GrsCode::plain(&f, (1..=10).collect(), (100..104).collect()).unwrap();
        let inputs: Vec<Packet> = (0..10u64).map(|i| vec![i + 1]).collect();
        let plan = plan(&f, Some(&code), None, inputs, 1, AlgoRequest::Auto).unwrap();
        assert_eq!(plan.choice, PlanChoice::Universal);
    }

    #[test]
    fn all_choices_produce_identical_codewords() {
        let f = crate::gf::GfPrime::default_field();
        let code = GrsCode::structured(&f, 16, 8, 2).unwrap();
        let inputs: Vec<Packet> = (0..16u64).map(|i| vec![f.elem(i * 3 + 2)]).collect();
        let mut outs = Vec::new();
        for req in [
            AlgoRequest::RsSpecific,
            AlgoRequest::Universal,
            AlgoRequest::MultiReduce,
            AlgoRequest::Direct,
        ] {
            let mut pl = plan(&f, Some(&code), None, inputs.clone(), 1, req).unwrap();
            run(&mut Sim::new(1), pl.job.as_mut()).unwrap();
            let o = pl.job.outputs();
            let coded: Vec<Packet> = (16..24).map(|pid| o[&pid].clone()).collect();
            outs.push(coded);
        }
        assert!(outs.windows(2).all(|w| w[0] == w[1]));
    }
}

//! Every closed-form communication cost in the paper, as executable
//! functions. The benches print these next to measured values; the tests
//! assert they match exactly where the paper's preconditions hold.
//!
//! All functions return `(C1, C2)` pairs in rounds / field elements;
//! evaluate against a [`CostModel`](crate::net::CostModel) for the scalar
//! cost `C = α·C1 + β⌈log2 q⌉·C2`.

use crate::util::{ceil_log, ipow};

/// Lemma 1: any universal A2A needs `C1 ≥ ⌈log_{p+1} K⌉`.
pub fn lemma1_c1_lower_bound(k: u64, p: u64) -> u64 {
    ceil_log(p + 1, k) as u64
}

/// Lemma 2: any universal A2A needs
/// `C2 ≥ 1/2 − 1/p + √(1/4 − 1/p − 1/p² + 2K/p²) = √(2K)/p − O(1)`.
pub fn lemma2_c2_lower_bound(k: u64, p: u64) -> f64 {
    let (k, p) = (k as f64, p as f64);
    0.5 - 1.0 / p + (0.25 - 1.0 / p - 1.0 / (p * p) + 2.0 * k / (p * p)).sqrt()
}

/// Lemma 3: prepare phase — `C1 = T_p`, `C2 = ((p+1)^{T_p} − 1)/p`.
pub fn lemma3_prepare(k: u64, p: u64) -> (u64, u64) {
    let l = ceil_log(p + 1, k);
    let tp = l.div_ceil(2);
    (tp as u64, (ipow(p + 1, tp) - 1) / p)
}

/// Lemma 4: shoot phase — `C1 = T_s`, `C2 = ((p+1)^{T_s} − 1)/p`.
pub fn lemma4_shoot(k: u64, p: u64) -> (u64, u64) {
    let l = ceil_log(p + 1, k);
    let ts = l - l.div_ceil(2);
    (ts as u64, (ipow(p + 1, ts) - 1) / p)
}

/// Theorem 3: prepare-and-shoot —
/// `C1 = ⌈log_{p+1} K⌉` and
/// `C2 = ((p+1)^{(L−1)/2}(p+2) − 2)/p` (L odd) or `(2(p+1)^{L/2} − 2)/p`
/// (L even). Exact when `K = (p+1)^L`; an upper bound otherwise (the
/// engine measures saturated message sizes, never larger).
pub fn theorem3_universal(k: u64, p: u64) -> (u64, u64) {
    let l = ceil_log(p + 1, k);
    let c2 = if l % 2 == 1 {
        (ipow(p + 1, (l - 1) / 2) * (p + 2) - 2) / p
    } else {
        (2 * ipow(p + 1, l / 2) - 2) / p
    };
    (l as u64, c2)
}

/// Appendix A: `(p+1)`-nomial tree broadcast/reduce of a `W`-vector over
/// `N` processors — `C1 = ⌈log_{p+1} N⌉`, `C2 = W·⌈log_{p+1} N⌉`.
pub fn broadcast_tree(n: u64, w: u64, p: u64) -> (u64, u64) {
    let l = ceil_log(p + 1, n) as u64;
    (l, w * l)
}

/// Theorem 4: DFT A2A for `K = P^H` — `H · C_univ(P)` component-wise.
pub fn theorem4_dft(p_base: u64, h: u32, p: u64) -> (u64, u64) {
    let (c1, c2) = theorem3_universal(p_base, p);
    (h as u64 * c1, h as u64 * c2)
}

/// Corollary 1: `K = (p+1)^H` — `C1 = C2 = H`.
pub fn corollary1_dft(h: u32) -> (u64, u64) {
    (h as u64, h as u64)
}

/// Theorem 5: draw-and-loose for `K = M·Z`, `Z = P^H` —
/// `C_vand = C_dft(P, H) + C_univ(M)` component-wise.
pub fn theorem5_vandermonde(m: u64, p_base: u64, h: u32, p: u64) -> (u64, u64) {
    let (dc1, dc2) = theorem4_dft(p_base, h, p);
    let (uc1, uc2) = if m > 1 {
        theorem3_universal(m, p)
    } else {
        (0, 0)
    };
    (dc1 + uc1, dc2 + uc2)
}

/// Theorems 7/9: Cauchy-like A2A — two draw-and-loose passes (the scale
/// steps are free local computation).
pub fn theorem7_cauchy(m: u64, p_base: u64, h: u32, p: u64) -> (u64, u64) {
    let (c1, c2) = theorem5_vandermonde(m, p_base, h, p);
    (2 * c1, 2 * c2)
}

/// Theorem 1 (K ≥ R framework): `C = max_m C_A2A(A_m) + C_BR(⌈K/R⌉, W)`,
/// with the A2A cost supplied by the caller. `C_BR` here covers the
/// row-wise reduce over `M` grid cells plus the external sink (see
/// DESIGN.md §1 on the `M+1` deviation).
pub fn theorem1_framework(a2a: (u64, u64), k: u64, r: u64, w: u64, p: u64) -> (u64, u64) {
    let m = k.div_ceil(r);
    let br = broadcast_tree(m + 1, w, p);
    (a2a.0 + br.0, a2a.1 + br.1)
}

/// Theorem 2 (K < R framework): `C = C_BR(⌈R/K⌉, W) + max_m C_A2A(A_m)`;
/// the broadcast spans the `M` row sinks plus the source.
pub fn theorem2_framework(a2a: (u64, u64), k: u64, r: u64, w: u64, p: u64) -> (u64, u64) {
    let m = r.div_ceil(k);
    let br = broadcast_tree(m + 1, w, p);
    (a2a.0 + br.0, a2a.1 + br.1)
}

/// Measured `(C1, C2)` of a compiled [`Plan`](crate::net::plan::Plan) at
/// payload width `w` — read off the plan's statics, nothing is executed.
/// This is the zero-cost replacement for the "run the collective and read
/// the [`SimReport`](crate::net::SimReport)" pattern: where the paper's
/// preconditions hold, these statics equal the closed forms above exactly
/// (asserted in the tests below), and elsewhere they are the ground truth
/// the formulas upper-bound.
pub fn plan_statics(plan: &crate::net::plan::Plan, w: u64) -> (u64, u64) {
    (plan.c1(), plan.c2(w))
}

/// A compiled plan's communication statics side by side with what the
/// optimizer pass pipeline (`net::opt`) bought for the shape: arena
/// slots before/after and the interned lincombs eliminated (dead
/// wire-only intermediates + CSE merges). `C1`/`C2` are untouched by
/// optimization — the passes change what replay *computes*, never what
/// the schedule *costs*.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlanProfile {
    pub c1: u64,
    pub c2: u64,
    pub slots_before: usize,
    pub slots_after: usize,
    pub lincombs_eliminated: usize,
    /// The encode engine serving this plan's batched replays
    /// ([`select_backend`](crate::net::opt::select_backend)); a bare
    /// [`plan_profile`] (no compiled backend in hand) reports the dense
    /// default — [`CompiledPlan::profile`](super::CompiledPlan::profile)
    /// reports the selected one.
    pub backend: crate::net::opt::BackendKind,
    /// Per-column op counts behind the crossover decision (dense
    /// `R·K` vs transform `K log K + …`); zero when no NTT shape was
    /// detected, so the gate never ran.
    pub backend_dense_ops: usize,
    pub backend_ntt_ops: usize,
    /// The ISA tier the plan's packed kernels dispatch to (`"scalar"`,
    /// `"avx2"`, `"neon"`). A bare [`plan_profile`] reports scalar;
    /// [`CompiledPlan::profile`](super::CompiledPlan::profile) reports
    /// the tier the compiled vtable actually resolved
    /// ([`Kernels::isa`](crate::gf::kernels::Kernels::isa)).
    pub isa: &'static str,
}

/// Profile a plan at payload width `w`: its `(C1, C2)` statics plus the
/// optimizer statics of running the pass pipeline over it.
pub fn plan_profile(plan: &crate::net::plan::Plan, w: u64) -> PlanProfile {
    let stats = crate::net::opt::optimize(plan).stats;
    PlanProfile {
        c1: plan.c1(),
        c2: plan.c2(w),
        slots_before: stats.slots_before,
        slots_after: stats.slots_after,
        lincombs_eliminated: stats.lincombs_eliminated(),
        backend: crate::net::opt::BackendKind::Dense,
        backend_dense_ops: 0,
        backend_ntt_ops: 0,
        isa: "scalar",
    }
}

/// §II: the multi-reduce baseline's `C2` — all-gather then combine:
/// `(K−1)·W` for one port (p-port: `≈ (K−1)·W/p`).
pub fn multireduce_c2(k: u64, w: u64, p: u64) -> u64 {
    // The (p+1)-ary Bruck gather telescopes to exactly (K−1)·W for p = 1
    // (any K); for p ports the sequential volume divides by ~p.
    (k - 1) * w / p
}

/// The §II claimed gap: multi-reduce minus prepare-and-shoot `C2` is
/// `(K − 2√K − 1)·W` for one port.
pub fn multireduce_gap(k: u64, w: u64) -> f64 {
    (k as f64 - 2.0 * (k as f64).sqrt() - 1.0) * w as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn theorem3_split_matches_lemmas() {
        for (k, p) in [(16u64, 1u64), (64, 1), (81, 2), (65, 2), (256, 3), (4096, 1)] {
            let (c1p, c2p) = lemma3_prepare(k, p);
            let (c1s, c2s) = lemma4_shoot(k, p);
            let (c1, c2) = theorem3_universal(k, p);
            assert_eq!(c1, c1p + c1s, "K={k} p={p}");
            assert_eq!(c2, c2p + c2s, "K={k} p={p}");
        }
    }

    #[test]
    fn theorem3_is_within_sqrt2_of_lemma2() {
        // Remark 7: C2 ≈ 2√K/p, suboptimal within √2.
        for k in [64u64, 256, 1024, 4096, 16384] {
            let (_, c2) = theorem3_universal(k, 1);
            let lb = lemma2_c2_lower_bound(k, 1);
            assert!(c2 as f64 >= lb, "K={k}: {c2} < {lb}");
            assert!(
                (c2 as f64) < lb * 1.5 + 4.0,
                "K={k}: {c2} should be within ~√2 of {lb}"
            );
        }
    }

    #[test]
    fn lemma1_matches_universal_c1() {
        for (k, p) in [(5u64, 1u64), (1024, 2), (17, 4)] {
            assert_eq!(theorem3_universal(k, p).0, lemma1_c1_lower_bound(k, p));
        }
    }

    #[test]
    fn corollary1_is_theorem4_special_case() {
        for (p, h) in [(1u64, 5u32), (2, 3), (3, 4)] {
            assert_eq!(theorem4_dft(p + 1, h, p), corollary1_dft(h));
        }
    }

    #[test]
    fn plan_statics_match_theorem3_without_execution() {
        // Compile prepare-and-shoot once per shape; the plan's statics
        // must equal Theorem 3 exactly at exact powers, for every width.
        let f = crate::gf::GfPrime::default_field();
        for (k, p) in [(16usize, 1usize), (81, 2), (64, 1)] {
            let c = std::sync::Arc::new(crate::gf::Mat::random(&f, k, k, 3));
            let plan = crate::net::plan::compile(p, k, |basis| {
                Ok(Box::new(crate::collectives::PrepareShoot::new(
                    f,
                    (0..k).collect(),
                    p,
                    c.clone(),
                    basis,
                )))
            })
            .unwrap();
            let (c1f, c2f) = theorem3_universal(k as u64, p as u64);
            assert_eq!(plan_statics(&plan, 1), (c1f, c2f), "K={k} p={p}");
            assert_eq!(plan_statics(&plan, 7), (c1f, 7 * c2f), "K={k} p={p} W=7");
        }
    }

    #[test]
    fn plan_profile_reports_optimizer_statics_next_to_costs() {
        let f = crate::gf::GfPrime::default_field();
        let (k, p) = (64usize, 1usize);
        let c = std::sync::Arc::new(crate::gf::Mat::random(&f, k, k, 9));
        let plan = crate::net::plan::compile(p, k, |basis| {
            Ok(Box::new(crate::collectives::PrepareShoot::new(
                f,
                (0..k).collect(),
                p,
                c.clone(),
                basis,
            )))
        })
        .unwrap();
        let prof = plan_profile(&plan, 3);
        // Costs agree with the raw statics (optimization never changes them).
        assert_eq!((prof.c1, prof.c2), plan_statics(&plan, 3));
        // The pass pipeline dropped the wire-only prepare intermediates.
        assert!(prof.slots_after < prof.slots_before, "{prof:?}");
        assert_eq!(
            prof.lincombs_eliminated,
            prof.slots_before - prof.slots_after,
            "{prof:?}"
        );
    }

    #[test]
    fn specific_beats_universal_asymptotically() {
        // K = 2^16, p = 1: universal C2 ≈ 2·2^8; DFT C2 = 16.
        let k = 1u64 << 16;
        let (_, univ) = theorem3_universal(k, 1);
        let (_, dft) = theorem4_dft(2, 16, 1);
        assert!(dft * 10 < univ, "dft={dft} univ={univ}");
    }
}

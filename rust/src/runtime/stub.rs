//! Always-available stand-in for the PJRT bridge (no `pjrt` feature).
//!
//! Signature-identical to `runtime::pjrt`; every entry point fails with a
//! descriptive error, so code paths that *optionally* use the compiled
//! kernel (service workers, `verify = "pjrt"`, `dce info`) degrade
//! gracefully instead of failing to link.

use anyhow::{bail, Result};
use std::path::Path;

const UNAVAILABLE: &str =
    "PJRT runtime not compiled in (build with `--features pjrt` and the `xla` bindings)";

/// A PJRT CPU session (one per process) — stub.
pub struct Runtime {
    _private: (),
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        bail!(UNAVAILABLE)
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Load and compile an HLO-text artifact.
    pub fn load(&self, _path: &Path) -> Result<Executable> {
        bail!(UNAVAILABLE)
    }

    /// Load the `encode` artifact for the given shape from a manifest.
    pub fn load_encoder(
        &self,
        _dir: &Path,
        _k: usize,
        _r: usize,
        _w: usize,
        _p: u64,
    ) -> Result<GfEncoder> {
        bail!(UNAVAILABLE)
    }

    /// Load the fused §VI scaled encoder for the given shape.
    pub fn load_scaled_encoder(
        &self,
        _dir: &Path,
        _k: usize,
        _r: usize,
        _w: usize,
        _p: u64,
    ) -> Result<ScaledGfEncoder> {
        bail!(UNAVAILABLE)
    }
}

/// A compiled PJRT executable — stub.
pub struct Executable {
    _private: (),
}

impl Executable {
    /// Execute on i32 tensors; returns the flattened first tuple element.
    pub fn run_i32(&self, _args: &[(&[i32], &[i64])]) -> Result<Vec<i32>> {
        bail!(UNAVAILABLE)
    }
}

/// Typed wrapper for the bulk GF(p) encoder `Y[R,W] = (Aᵀ·X) mod p` — stub.
pub struct GfEncoder {
    pub k: usize,
    pub r: usize,
    pub w: usize,
}

impl GfEncoder {
    /// `a`: row-major `K×R`; `x`: row-major `K×W` → row-major `R×W`.
    pub fn encode(&self, _a: &[i32], _x: &[i32]) -> Result<Vec<i32>> {
        bail!(UNAVAILABLE)
    }

    /// Convenience over u64 field elements (must be < 2^31).
    pub fn encode_u64(&self, _a: &[u64], _x: &[u64]) -> Result<Vec<u64>> {
        bail!(UNAVAILABLE)
    }
}

/// Typed wrapper for the fused §VI scaled encoder — stub.
pub struct ScaledGfEncoder {
    pub k: usize,
    pub r: usize,
    pub w: usize,
}

impl ScaledGfEncoder {
    pub fn encode_u64(
        &self,
        _pre: &[u64],
        _post: &[u64],
        _a: &[u64],
        _x: &[u64],
    ) -> Result<Vec<u64>> {
        bail!(UNAVAILABLE)
    }
}

//! The real PJRT bridge (the `pjrt` cargo feature): load AOT-compiled
//! HLO artifacts and execute them via the `xla` bindings crate. See
//! `runtime::stub` for the always-available fallback.

// The `xla` bindings crate is not published/offline-available, so the
// feature cannot build until it is wired in manually. Fail with an
// actionable message instead of a wall of unresolved imports. To
// enable: add `xla = { ... }` to [dependencies] in rust/Cargo.toml,
// list it under the `pjrt` feature, and delete this guard.
compile_error!(
    "the `pjrt` feature requires the `xla` bindings crate: add it to \
     rust/Cargo.toml and remove this guard (see rust/DESIGN.md §4)"
);

use super::{ArtifactKind, Manifest};
use anyhow::{Context, Result};
use std::path::Path;

/// A PJRT CPU session (one per process).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile an HLO-text artifact.
    pub fn load(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe })
    }

    /// Load the `encode` artifact for the given shape from a manifest.
    pub fn load_encoder(
        &self,
        dir: &Path,
        k: usize,
        r: usize,
        w: usize,
        p: u64,
    ) -> Result<GfEncoder> {
        let manifest = Manifest::load(dir)?;
        let entry = manifest
            .find(ArtifactKind::Encode, k, r, w, p)
            .with_context(|| {
                format!("no encode artifact for K={k} R={r} W={w} p={p}; run `make artifacts`")
            })?;
        let exe = self.load(&dir.join(&entry.file))?;
        Ok(GfEncoder { exe, k, r, w })
    }
}

/// A compiled PJRT executable (tuple-returning, per aot.py's lowering).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute on i32 tensors; returns the flattened first tuple element.
    pub fn run_i32(&self, args: &[(&[i32], &[i64])]) -> Result<Vec<i32>> {
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|(data, dims)| xla::Literal::vec1(data).reshape(dims))
            .collect::<std::result::Result<_, _>>()
            .context("building input literals")?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        let out = result.to_tuple1().context("unwrapping result tuple")?;
        Ok(out.to_vec::<i32>()?)
    }
}

impl Runtime {
    /// Load the fused §VI scaled encoder for the given shape.
    pub fn load_scaled_encoder(
        &self,
        dir: &Path,
        k: usize,
        r: usize,
        w: usize,
        p: u64,
    ) -> Result<ScaledGfEncoder> {
        let manifest = Manifest::load(dir)?;
        let entry = manifest
            .find(ArtifactKind::ScaledEncode, k, r, w, p)
            .with_context(|| {
                format!("no scaled_encode artifact for K={k} R={r} W={w} p={p}")
            })?;
        let exe = self.load(&dir.join(&entry.file))?;
        Ok(ScaledGfEncoder { exe, k, r, w })
    }
}

/// Typed wrapper for the fused scaled encoder
/// `Y[R,W] = (diag(post)·Aᵀ·diag(pre)·X) mod p` (the §VI block product).
pub struct ScaledGfEncoder {
    exe: Executable,
    pub k: usize,
    pub r: usize,
    pub w: usize,
}

impl ScaledGfEncoder {
    pub fn encode_u64(
        &self,
        pre: &[u64],
        post: &[u64],
        a: &[u64],
        x: &[u64],
    ) -> Result<Vec<u64>> {
        anyhow::ensure!(pre.len() == self.k && post.len() == self.r);
        anyhow::ensure!(a.len() == self.k * self.r && x.len() == self.k * self.w);
        let to_i32 = |v: &[u64]| v.iter().map(|&x| x as i32).collect::<Vec<i32>>();
        let (pi, qi, ai, xi) = (to_i32(pre), to_i32(post), to_i32(a), to_i32(x));
        let y = self.exe.run_i32(&[
            (&pi, &[self.k as i64]),
            (&qi, &[self.r as i64]),
            (&ai, &[self.k as i64, self.r as i64]),
            (&xi, &[self.k as i64, self.w as i64]),
        ])?;
        Ok(y.into_iter().map(|v| v as u64).collect())
    }
}

/// Typed wrapper for the bulk GF(p) encoder `Y[R,W] = (Aᵀ·X) mod p`.
pub struct GfEncoder {
    exe: Executable,
    pub k: usize,
    pub r: usize,
    pub w: usize,
}

impl GfEncoder {
    /// `a`: row-major `K×R`; `x`: row-major `K×W` → row-major `R×W`.
    pub fn encode(&self, a: &[i32], x: &[i32]) -> Result<Vec<i32>> {
        anyhow::ensure!(a.len() == self.k * self.r, "A must be K×R");
        anyhow::ensure!(x.len() == self.k * self.w, "X must be K×W");
        let y = self.exe.run_i32(&[
            (a, &[self.k as i64, self.r as i64]),
            (x, &[self.k as i64, self.w as i64]),
        ])?;
        anyhow::ensure!(y.len() == self.r * self.w, "bad output size");
        Ok(y)
    }

    /// Convenience over u64 field elements (must be < 2^31).
    pub fn encode_u64(&self, a: &[u64], x: &[u64]) -> Result<Vec<u64>> {
        let ai: Vec<i32> = a.iter().map(|&v| v as i32).collect();
        let xi: Vec<i32> = x.iter().map(|&v| v as i32).collect();
        Ok(self.encode(&ai, &xi)?.into_iter().map(|v| v as u64).collect())
    }
}

//! Artifact manifest — the contract between `python/compile/aot.py` and
//! the rust runtime. One line per artifact:
//!
//! ```text
//! encode 64 16 256 786433 encode_K64_R16_W256_p786433.hlo.txt
//! ```

use anyhow::{Context, Result};
use std::path::Path;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// `(A, X) → parity` — the payload hot path.
    Encode,
    /// `(A, X) → [X; parity]` — the verifier graph.
    Codeword,
    /// `(pre, post, A, X) → parity` — the fused §VI block product
    /// `diag(post)·Aᵀ·diag(pre)·X`.
    ScaledEncode,
}

impl ArtifactKind {
    fn parse(s: &str) -> Option<Self> {
        match s {
            "encode" => Some(ArtifactKind::Encode),
            "codeword" => Some(ArtifactKind::Codeword),
            "scaled_encode" => Some(ArtifactKind::ScaledEncode),
            _ => None,
        }
    }
}

/// One manifest row.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub kind: ArtifactKind,
    pub k: usize,
    pub r: usize,
    pub w: usize,
    pub p: u64,
    pub file: String,
}

/// The parsed `artifacts/manifest.txt`.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            anyhow::ensure!(parts.len() == 6, "manifest line {} malformed: {line}", ln + 1);
            let kind = ArtifactKind::parse(parts[0])
                .with_context(|| format!("unknown artifact kind {}", parts[0]))?;
            entries.push(ArtifactEntry {
                kind,
                k: parts[1].parse()?,
                r: parts[2].parse()?,
                w: parts[3].parse()?,
                p: parts[4].parse()?,
                file: parts[5].to_string(),
            });
        }
        Ok(Manifest { entries })
    }

    pub fn find(
        &self,
        kind: ArtifactKind,
        k: usize,
        r: usize,
        w: usize,
        p: u64,
    ) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.kind == kind && e.k == k && e.r == r && e.w == w && e.p == p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let m = Manifest::parse(
            "# comment\n\
             encode 64 16 256 786433 encode_K64_R16_W256_p786433.hlo.txt\n\
             codeword 64 16 256 786433 codeword_K64_R16_W256_p786433.hlo.txt\n",
        )
        .unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = m.find(ArtifactKind::Encode, 64, 16, 256, 786433).unwrap();
        assert!(e.file.starts_with("encode_K64"));
        assert!(m.find(ArtifactKind::Encode, 1, 2, 3, 5).is_none());
    }

    #[test]
    fn malformed_rejected() {
        assert!(Manifest::parse("encode 64 16").is_err());
        assert!(Manifest::parse("mystery 1 2 3 4 f.hlo.txt").is_err());
    }
}

//! The PJRT bridge: load AOT-compiled HLO artifacts and execute them from
//! the rust hot path. Python never runs at request time.
//!
//! `python/compile/aot.py` lowers the Layer-2 JAX graphs (which call the
//! Layer-1 Pallas GF(p) kernel with `interpret=True`) to **HLO text**;
//! the `pjrt` cargo feature parses that text (`HloModuleProto::
//! from_text_file` — the text parser reassigns instruction ids,
//! sidestepping the 64-bit-id protos that xla_extension 0.5.1 rejects),
//! compiles it on the PJRT CPU client and exposes typed `execute`
//! wrappers.
//!
//! The feature requires the `xla` bindings crate plus the `xla_extension`
//! native library, neither of which exists in offline builds — so the
//! default build ships a **stub** with identical signatures whose
//! constructors return errors. Every caller (the encode service, the
//! `pjrt` verify mode, the CLI `info` command, the integration tests)
//! already treats PJRT as optional and degrades gracefully.

pub mod artifacts;

pub use artifacts::{ArtifactKind, Manifest};

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{Executable, GfEncoder, Runtime, ScaledGfEncoder};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{Executable, GfEncoder, Runtime, ScaledGfEncoder};

//! Code substrate: generalized Reed–Solomon codes, Lagrange codes, and the
//! structured evaluation-point designs that make the paper's specific
//! (draw-and-loose–based) algorithms applicable.
//!
//! * [`structured`] — `ω_{i,j} = g^{φ(i)}·g^{j′(q−1)/Z}` point grids
//!   (eq. (15)); Theorem 5's `((q−1)/Z choose M)` Vandermonde family.
//! * [`rs`] — GRS generator (eq. (22)), systematic form (eqs. (23)–(24)),
//!   erasure decoding, MDS checks.
//! * [`lagrange`] — Lagrange matrices & Lagrange coded computing
//!   (Remark 9).
//! * [`recovery`] — the erasure-recovery operator the coordinator's
//!   repair path executes: survivors → data / lost sink outputs, as one
//!   dense matrix per failure pattern (GRS interpolation algebra, with a
//!   Gaussian-elimination fallback for arbitrary linear codes).

pub mod lagrange;
pub mod recovery;
pub mod rm;
pub mod rs;
pub mod structured;

pub use lagrange::LagrangeCode;
pub use recovery::Recovery;
pub use rm::RmCode;
pub use rs::GrsCode;
pub use structured::StructuredPoints;

//! Reed–Muller codes — the paper's first "future work" target (§VII:
//! *"extending our results to Reed-Muller codes"*).
//!
//! `RM(r, m)` over `GF(2)`: codewords are evaluations of degree-≤ r
//! multilinear polynomials on `{0,1}^m` — `K = Σ_{i≤r} C(m,i)` data bits,
//! `N = 2^m` coded bits, minimum distance `2^{m−r}`.
//!
//! Decentralized encoding needs nothing new: `G` is a binary generator
//! matrix, so the Appendix-B non-systematic framework (or the §III
//! systematic framework after Gaussian systematisation) encodes it with
//! the universal A2A over `GF(2^w)`-packed symbols — demonstrated in the
//! tests below. The *specific*-algorithm question (is there a
//! draw-and-loose analogue exploiting the Plotkin/evaluation structure?)
//! is exactly what the paper leaves open; we provide the substrate.

use crate::gf::{Field, Mat};

/// The binary Reed–Muller code `RM(r, m)`.
#[derive(Clone, Debug)]
pub struct RmCode {
    pub r: u32,
    pub m: u32,
    /// Monomial exponent masks, one per data position (sorted by degree
    /// then value): data bit `k` multiplies `∏_{i ∈ masks[k]} x_i`.
    masks: Vec<u32>,
}

impl RmCode {
    pub fn new(r: u32, m: u32) -> anyhow::Result<Self> {
        anyhow::ensure!(m >= 1 && m <= 20, "m in 1..=20");
        anyhow::ensure!(r <= m, "need r ≤ m");
        let mut masks: Vec<u32> = (0u32..1 << m)
            .filter(|s| s.count_ones() <= r)
            .collect();
        masks.sort_by_key(|s| (s.count_ones(), *s));
        Ok(RmCode { r, m, masks })
    }

    /// Data length `K = Σ_{i≤r} C(m,i)`.
    pub fn k(&self) -> usize {
        self.masks.len()
    }

    /// Block length `N = 2^m`.
    pub fn n(&self) -> usize {
        1 << self.m
    }

    /// Minimum distance `2^{m−r}`.
    pub fn min_distance(&self) -> usize {
        1 << (self.m - self.r)
    }

    /// Generator matrix over GF(2) (entries 0/1 as `u64`): row `k`,
    /// column `point` = monomial `masks[k]` evaluated at `point`.
    pub fn generator(&self) -> Mat {
        Mat::from_fn(self.k(), self.n(), |k, point| {
            // x_S(point) = 1 iff every variable in S is 1 at `point`.
            u64::from((point as u32) & self.masks[k] == self.masks[k])
        })
    }

    /// Encode over any field of characteristic 2 (the generator is 0/1).
    pub fn encode<F: Field>(&self, f: &F, data: &[u64]) -> Vec<u64> {
        assert_eq!(data.len(), self.k());
        assert_eq!(f.order() & 1, 0, "RM needs characteristic 2");
        self.generator().vec_mul(f, data)
    }

    /// Erasure decoding by linear solve: recover the data from any set of
    /// unerased coordinates whose generator columns have full rank
    /// (guaranteed when ≥ N − d_min + 1 coordinates survive).
    pub fn decode_erasures<F: Field>(
        &self,
        f: &F,
        coords: &[(usize, u64)],
    ) -> anyhow::Result<Vec<u64>> {
        let k = self.k();
        anyhow::ensure!(coords.len() >= k, "need at least K coordinates");
        let g = self.generator();
        // Solve y = x·G_sub for x: square subsystem from the first K
        // independent columns.
        let mut cols = Vec::with_capacity(k);
        let mut vals = Vec::with_capacity(k);
        let mut basis = Mat::zero(k, 0);
        for &(pos, v) in coords {
            let cand = basis.hstack(&Mat::from_fn(k, 1, |row, _| g[(row, pos)]));
            if cand.rank(f) > cols.len() {
                basis = cand;
                cols.push(pos);
                vals.push(v);
                if cols.len() == k {
                    break;
                }
            }
        }
        anyhow::ensure!(cols.len() == k, "surviving columns do not span");
        let sub = Mat::from_fn(k, k, |row, c| g[(row, cols[c])]);
        let inv = sub
            .inverse(f)
            .ok_or_else(|| anyhow::anyhow!("singular subsystem"))?;
        // x = y · sub^{-1} (row-vector convention: y = x·sub).
        Ok(inv.vec_mul(f, &vals))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::framework::NonSystematicEncode;
    use crate::gf::Gf2e;
    use crate::net::{run, Packet, Sim};
    use crate::util::Rng;
    use std::sync::Arc;

    #[test]
    fn dimensions_and_known_codes() {
        // RM(1, 3) = [8,4,4] (extended Hamming); RM(1,5) = [32,6,16]
        // (the Mariner 9 code); RM(2,4) = [16,11,4].
        let c = RmCode::new(1, 3).unwrap();
        assert_eq!((c.n(), c.k(), c.min_distance()), (8, 4, 4));
        let c = RmCode::new(1, 5).unwrap();
        assert_eq!((c.n(), c.k(), c.min_distance()), (32, 6, 16));
        let c = RmCode::new(2, 4).unwrap();
        assert_eq!((c.n(), c.k(), c.min_distance()), (16, 11, 4));
    }

    #[test]
    fn min_distance_exhaustive_small() {
        // Check d_min = 2^{m−r} by enumerating all nonzero codewords.
        let f = Gf2e::new(1).unwrap();
        for (r, m) in [(1u32, 3u32), (2, 3), (1, 4)] {
            let c = RmCode::new(r, m).unwrap();
            let mut dmin = usize::MAX;
            for x in 1u64..(1 << c.k()) {
                let data: Vec<u64> = (0..c.k()).map(|i| (x >> i) & 1).collect();
                let cw = c.encode(&f, &data);
                let wt = cw.iter().filter(|&&b| b == 1).count();
                dmin = dmin.min(wt);
            }
            assert_eq!(dmin, c.min_distance(), "RM({r},{m})");
        }
    }

    #[test]
    fn erasure_decode_up_to_dmin_minus_1() {
        let f = Gf2e::new(1).unwrap();
        let c = RmCode::new(1, 4).unwrap(); // [16, 5, 8]
        let data = vec![1u64, 0, 1, 1, 0];
        let cw = c.encode(&f, &data);
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            // Erase d_min − 1 = 7 random coordinates.
            let erased = rng.choose(c.n(), c.min_distance() - 1);
            let coords: Vec<(usize, u64)> = (0..c.n())
                .filter(|i| !erased.contains(i))
                .map(|i| (i, cw[i]))
                .collect();
            assert_eq!(c.decode_erasures(&f, &coords).unwrap(), data);
        }
    }

    #[test]
    fn decentralized_rm_encoding_via_appendix_b() {
        // §VII future work, realised: RM(1,4) encoded decentrally over
        // GF(2^8)-packed symbols (8 codeword bits per wire symbol lane,
        // here W = 4 lanes of independent data).
        let f = Gf2e::new(8).unwrap();
        let c = RmCode::new(1, 4).unwrap(); // K = 5, N = 16
        let g = Arc::new(c.generator());
        let w = 4usize;
        let mut rng = Rng::new(11);
        let inputs: Vec<Packet> = (0..c.k())
            .map(|_| (0..w).map(|_| rng.below(256)).collect())
            .collect();
        let mut job = NonSystematicEncode::new(f.clone(), g.clone(), inputs.clone(), 1).unwrap();
        run(&mut Sim::new(1), &mut job).unwrap();
        let cw = job.codeword();
        // Lane-wise oracle.
        for lane in 0..w {
            let data: Vec<u64> = inputs.iter().map(|p| p[lane]).collect();
            let want = c.encode(&f, &data);
            let got: Vec<u64> = cw.iter().map(|p| p[lane]).collect();
            assert_eq!(got, want, "lane {lane}");
        }
    }
}

//! Lagrange codes and Lagrange coded computing (Remark 9).
//!
//! LCC interpolates `g` with `g(α_k) = x_k`, hands worker `n` the coded
//! value `x̃_n = g(β_n)`, evaluates a polynomial `h` on the coded data, and
//! decodes `h(x_k)` from any `deg(h)(K−1)+1` worker results — because
//! `h∘g` is itself a polynomial of that degree. The coding matrix
//! `L_{α,β} = V_α^{-1}·V_β` is Cauchy-like with `u = v = 1`, so all of
//! §VI applies verbatim; if `β_k = α_k` for `k < K` the code is
//! systematic.

use crate::gf::{cauchy::CauchyLike, poly, vandermonde, Field, Mat};

/// A Lagrange code: data at `alphas`, coded evaluations at `betas`.
#[derive(Clone, Debug)]
pub struct LagrangeCode {
    pub alphas: Vec<u64>,
    pub betas: Vec<u64>,
    /// Structured designs when built via [`structured`](Self::structured):
    /// the α family and one β family per K-sized block of workers — what
    /// makes every block of `L_{α,β}` computable with the §VI algorithm
    /// (Remark 9 + Appendix B).
    pub alpha_design: Option<crate::codes::StructuredPoints>,
    pub beta_designs: Vec<crate::codes::StructuredPoints>,
}

impl LagrangeCode {
    /// `betas.len() = N` may exceed or overlap `alphas` (overlapping
    /// prefixes give a systematic code).
    pub fn new(alphas: Vec<u64>, betas: Vec<u64>) -> anyhow::Result<Self> {
        anyhow::ensure!(
            vandermonde::points_distinct(&alphas),
            "alpha points must be distinct"
        );
        anyhow::ensure!(
            vandermonde::points_distinct(&betas),
            "beta points must be distinct"
        );
        Ok(LagrangeCode {
            alphas,
            betas,
            alpha_design: None,
            beta_designs: Vec::new(),
        })
    }

    /// Non-systematic Lagrange code on structured points: `K` data owners,
    /// `n_total` workers (`K | n_total`), with disjoint draw-and-loose
    /// designs for the α family and each worker block's β family — every
    /// `K×K` block of `L_{α,β}` is then a §VI Cauchy-like A2A away
    /// (Remark 9; used by the Appendix-B framework).
    pub fn structured<F: Field>(
        f: &F,
        k: usize,
        n_total: usize,
        p_base: u64,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(k >= 1 && n_total >= k, "need N ≥ K ≥ 1");
        anyhow::ensure!(n_total % k == 0, "structured Lagrange needs K | N");
        let blocks = n_total / k;
        let fam = crate::codes::structured::disjoint_family(f, k, p_base, blocks + 1)?;
        let alpha_design = fam[blocks].clone();
        let beta_designs = fam[..blocks].to_vec();
        let betas: Vec<u64> = beta_designs.iter().flat_map(|d| d.points.clone()).collect();
        Ok(LagrangeCode {
            alphas: alpha_design.points.clone(),
            betas,
            alpha_design: Some(alpha_design),
            beta_designs,
        })
    }

    pub fn k(&self) -> usize {
        self.alphas.len()
    }

    pub fn n(&self) -> usize {
        self.betas.len()
    }

    /// True iff `β_k = α_k` for all `k < K` (systematic Lagrange code).
    pub fn is_systematic(&self) -> bool {
        self.n() >= self.k() && self.betas[..self.k()] == self.alphas[..]
    }

    /// The Lagrange matrix `L_{α,β} = V_α^{-1}·V_β ∈ F^{K×N}`.
    pub fn matrix<F: Field>(&self, f: &F) -> Mat {
        let va_inv = vandermonde::inverse(f, &self.alphas);
        let vb = vandermonde::vandermonde(f, self.k(), &self.betas);
        va_inv.mul(f, &vb)
    }

    /// The Cauchy-like view (Remark 9) of the non-overlapping columns.
    pub fn cauchy_part<F: Field>(&self, f: &F) -> CauchyLike {
        let skip = if self.is_systematic() { self.k() } else { 0 };
        CauchyLike::lagrange(f, self.alphas.clone(), self.betas[skip..].to_vec())
    }

    /// Encode: `x̃_n = g(β_n)` for the interpolant `g(α_k) = x_k`.
    pub fn encode<F: Field>(&self, f: &F, x: &[u64]) -> Vec<u64> {
        assert_eq!(x.len(), self.k());
        let g = poly::interpolate(f, &self.alphas, x);
        poly::eval_many(f, &g, &self.betas)
    }

    /// Decode the *results of a degree-`d` computation* `h` applied to the
    /// coded data: given ≥ `d(K−1)+1` pairs `(worker index n, h(x̃_n))`,
    /// recover `h(x_k)` for all `k` by interpolating `h∘g`.
    pub fn decode_computation<F: Field>(
        &self,
        f: &F,
        degree: usize,
        results: &[(usize, u64)],
    ) -> anyhow::Result<Vec<u64>> {
        let need = degree * (self.k() - 1) + 1;
        anyhow::ensure!(
            results.len() >= need,
            "need {need} results for degree {degree}, got {}",
            results.len()
        );
        let pts: Vec<u64> = results.iter().take(need).map(|&(n, _)| self.betas[n]).collect();
        let vals: Vec<u64> = results.iter().take(need).map(|&(_, v)| v).collect();
        anyhow::ensure!(vandermonde::points_distinct(&pts), "repeated workers");
        let hg = poly::interpolate(f, &pts, &vals);
        Ok(self
            .alphas
            .iter()
            .map(|&a| poly::eval(f, &hg, a))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::{Field, GfPrime};

    fn f() -> GfPrime {
        GfPrime::default_field()
    }

    #[test]
    fn systematic_when_points_overlap() {
        let c = LagrangeCode::new(vec![1, 2, 3], vec![1, 2, 3, 10, 11]).unwrap();
        assert!(c.is_systematic());
        let f = f();
        let x = vec![5u64, 7, 9];
        let cw = c.encode(&f, &x);
        assert_eq!(&cw[..3], &x[..]);
    }

    #[test]
    fn matrix_encode_agrees_with_polynomial_encode() {
        let f = f();
        let c = LagrangeCode::new(vec![1, 2, 3, 4], vec![10, 11, 12, 13, 14, 15]).unwrap();
        let x = vec![3u64, 1, 4, 1];
        let via_matrix = c.matrix(&f).vec_mul(&f, &x);
        assert_eq!(via_matrix, c.encode(&f, &x));
    }

    #[test]
    fn lcc_quadratic_computation_roundtrip() {
        // Workers compute h(z) = z² + 5z + 1 on coded data; decode h(x_k)
        // from 2(K−1)+1 of N worker results.
        let f = f();
        let k = 4usize;
        let n = 9usize; // ≥ 2(K−1)+1 = 7
        let c = LagrangeCode::new(
            (1..=k as u64).collect(),
            (100..100 + n as u64).collect(),
        )
        .unwrap();
        let x: Vec<u64> = vec![12, 99, 786001, 5];
        let coded = c.encode(&f, &x);
        let h = |z: u64| f.add(f.add(f.mul(z, z), f.mul(5, z)), 1);
        let results: Vec<(usize, u64)> = coded.iter().enumerate().map(|(i, &z)| (i, h(z))).collect();
        // Straggler-resilient: drop two workers.
        let got = c.decode_computation(&f, 2, &results[2..]).unwrap();
        let want: Vec<u64> = x.iter().map(|&z| h(z)).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn too_few_results_rejected() {
        let f = f();
        let c = LagrangeCode::new(vec![1, 2, 3], vec![10, 11, 12, 13]).unwrap();
        let res = vec![(0usize, 1u64), (1, 2), (2, 3), (3, 4)];
        assert!(c.decode_computation(&f, 2, &res[..4]).is_err()); // need 5
    }
}

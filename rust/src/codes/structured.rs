//! Structured evaluation points — eq. (15) of §V-B.
//!
//! Draw-and-loose computes Vandermonde matrices whose points form a
//! multiplicative grid: with `Z = P^H` dividing `q − 1`, `K = M·Z`, and an
//! injective `φ : [0, M) → [0, (q−1)/Z)`,
//!
//! ```text
//! ω_{i,j} = α_i · β_{j'},   α_i = g^{φ(i)},   β_{j'} = g^{j'·(q−1)/Z},
//! ```
//!
//! where `j'` is the base-`P` digit reversal of `j`. Processor `i·Z + j`
//! evaluates the data polynomial at `ω_{i,j}`. Exponent uniqueness
//! (`φ(i) < (q−1)/Z`) makes all `K` points distinct, so the matrix is an
//! invertible Vandermonde; Theorem 5 counts `((q−1)/Z choose M)` distinct
//! such matrices. RS/Lagrange code builders pick their `α`/`β` families
//! from *disjoint* `φ` ranges so every Theorem-6 factor is draw-and-loose
//! computable.

use crate::gf::{dft, Field, Mat};
use crate::util::ipow;

/// A draw-and-loose–compatible evaluation point design for `n` processors.
#[derive(Clone, Debug)]
pub struct StructuredPoints {
    /// The radix `P` of the DFT part.
    pub p_base: u64,
    /// `H` — the DFT depth; `Z = P^H`.
    pub h: u32,
    /// `Z = P^H` (divides both `n` and `q − 1`).
    pub z: u64,
    /// `M = n / Z` — the universal (draw-phase) dimension.
    pub m: usize,
    /// The injective row map `φ : [0, M) → [0, (q−1)/Z)`.
    pub phi: Vec<u64>,
    /// `points[i·Z + j] = ω_{i,j}` in processor-rank order.
    pub points: Vec<u64>,
}

impl StructuredPoints {
    /// Largest `h` with `P^h | n` and `P^h | q−1`.
    pub fn max_h<F: Field>(f: &F, n: u64, p_base: u64) -> u32 {
        assert!(p_base >= 2);
        let q1 = f.order() - 1;
        let mut h = 0;
        let mut z = 1u64;
        while n % (z * p_base) == 0 && q1 % (z * p_base) == 0 {
            z *= p_base;
            h += 1;
        }
        h
    }

    /// Design points for `n` processors with radix `P` and row map `φ`
    /// (`φ.len()` must be `n / P^H`). Pass `phi_offset`-shifted ranges to
    /// keep several families disjoint (see [`disjoint_family`]).
    pub fn new<F: Field>(f: &F, n: usize, p_base: u64, phi: Vec<u64>) -> anyhow::Result<Self> {
        let h = Self::max_h(f, n as u64, p_base);
        Self::with_h(f, n, p_base, h, phi)
    }

    /// As [`new`](Self::new) but with an explicit (possibly smaller) `H`.
    pub fn with_h<F: Field>(
        f: &F,
        n: usize,
        p_base: u64,
        h: u32,
        phi: Vec<u64>,
    ) -> anyhow::Result<Self> {
        let z = ipow(p_base, h);
        anyhow::ensure!(n as u64 % z == 0, "Z = {z} must divide n = {n}");
        anyhow::ensure!((f.order() - 1) % z == 0, "Z = {z} must divide q−1");
        let m = n / z as usize;
        anyhow::ensure!(phi.len() == m, "phi must have M = {m} entries");
        let cap = (f.order() - 1) / z;
        anyhow::ensure!(
            phi.iter().all(|&x| x < cap),
            "phi values must lie below (q−1)/Z = {cap}"
        );
        let mut sorted = phi.clone();
        sorted.sort_unstable();
        sorted.dedup();
        anyhow::ensure!(sorted.len() == m, "phi must be injective");
        let g = f.generator();
        let step = (f.order() - 1) / z; // (q−1)/Z
        let mut points = Vec::with_capacity(n);
        for i in 0..m {
            let alpha = f.pow(g, phi[i]);
            for j in 0..z {
                let jrev = dft::digit_reverse(j, p_base, h);
                let beta = f.pow(g, jrev * step);
                points.push(f.mul(alpha, beta));
            }
        }
        Ok(StructuredPoints {
            p_base,
            h,
            z,
            m,
            phi,
            points,
        })
    }

    /// `α_i = g^{φ(i)}` for grid row `i`.
    pub fn alpha<F: Field>(&self, f: &F, i: usize) -> u64 {
        f.pow(f.generator(), self.phi[i])
    }

    /// Number of processors covered.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }
}

/// Build `count` point families of `n` points each, all mutually disjoint
/// (family `t` uses `φ(i) = t·M + i`). Used by the systematic-RS encoder:
/// one family per α-block plus one for the β (parity) points.
pub fn disjoint_family<F: Field>(
    f: &F,
    n: usize,
    p_base: u64,
    count: usize,
) -> anyhow::Result<Vec<StructuredPoints>> {
    let h = StructuredPoints::max_h(f, n as u64, p_base);
    let z = ipow(p_base, h);
    let m = n / z as usize;
    anyhow::ensure!(
        (count * m) as u64 <= (f.order() - 1) / z,
        "field too small for {count} disjoint families of {n} points"
    );
    (0..count)
        .map(|t| {
            let phi: Vec<u64> = (0..m as u64).map(|i| t as u64 * m as u64 + i).collect();
            StructuredPoints::with_h(f, n, p_base, h, phi)
        })
        .collect()
}

/// Generic erasure recovery for an *arbitrary* systematic linear code
/// `G = [I | A]` — the Gaussian-elimination fallback behind
/// [`codes::recovery`](crate::codes::recovery) when no GRS structure is
/// available (e.g. a random parity matrix): with `c` the row vector of
/// codeword values at `positions` (`K` distinct coordinates in
/// `[0, N)`), solve `c = x · G_S` for the data `x` by inverting the
/// `K×K` survivor submatrix `G_S`. Returns the `K×K` matrix `D` with
/// `x = c · D`, or an error when the surviving columns are dependent
/// (impossible for an MDS code, possible for arbitrary `A`).
pub fn solve_data_matrix<F: Field>(f: &F, a: &Mat, positions: &[usize]) -> anyhow::Result<Mat> {
    let (k, r) = (a.rows, a.cols);
    anyhow::ensure!(
        positions.len() == k,
        "need exactly K = {k} positions, got {}",
        positions.len()
    );
    anyhow::ensure!(
        positions.iter().all(|&p| p < k + r),
        "position out of range (N = {})",
        k + r
    );
    let mut sorted = positions.to_vec();
    sorted.sort_unstable();
    sorted.dedup();
    anyhow::ensure!(sorted.len() == k, "repeated positions");
    // G_S in column order of `positions`: column i is generator column
    // `positions[i]`.
    let mut gs = Mat::zero(k, k);
    for (i, &pos) in positions.iter().enumerate() {
        for (kk, v) in generator_column(a, pos).into_iter().enumerate() {
            gs[(kk, i)] = v;
        }
    }
    // c = x·G_S  ⇔  x = c·G_S^{-1}: one Gauss–Jordan inversion per
    // failure pattern, then packet recovery is K lincombs.
    gs.inverse(f).ok_or_else(|| {
        anyhow::anyhow!("surviving coordinates do not determine the data (dependent columns)")
    })
}

/// Column `pos` of the systematic generator `G = [I | A]`: a unit
/// vector for systematic coordinates (`pos < K`), a parity column of
/// `A` otherwise. `pos < K + R` is release-checked — the shared guard
/// of both the Gaussian solver and the rank-revealing selector.
fn generator_column(a: &Mat, pos: usize) -> Vec<u64> {
    let k = a.rows;
    assert!(pos < k + a.cols, "coordinate {pos} out of range (N = {})", k + a.cols);
    (0..k)
        .map(|kk| {
            if pos < k {
                u64::from(pos == kk)
            } else {
                a[(kk, pos - k)]
            }
        })
        .collect()
}

/// Choose up to `K` positions whose generator columns (`G = [I | A]`)
/// are linearly independent, scanning `candidates` in order (first-fit
/// Gaussian elimination, `O(K²·|candidates|)`). For an MDS code this is
/// simply the first `K` candidates; for arbitrary `A` it *skips*
/// dependent coordinates, so a survivor set of full rank is never
/// spuriously rejected just because its first `K` entries happen to be
/// dependent. Returns fewer than `K` positions exactly when the
/// candidate columns do not span — i.e. the data is genuinely
/// unrecoverable.
pub fn independent_positions<F: Field>(f: &F, a: &Mat, candidates: &[usize]) -> Vec<usize> {
    let k = a.rows;
    // Incremental elimination: each kept column is normalized on its
    // pivot row; a fresh column is reduced against all kept ones and
    // admitted iff a nonzero residue remains.
    let mut basis: Vec<(usize, Vec<u64>)> = Vec::new();
    let mut chosen = Vec::with_capacity(k);
    for &pos in candidates {
        if chosen.len() == k {
            break;
        }
        let mut v = generator_column(a, pos);
        for (piv, b) in &basis {
            let c = v[*piv];
            if c != 0 {
                for (vi, &bi) in v.iter_mut().zip(b) {
                    *vi = f.sub(*vi, f.mul(c, bi));
                }
            }
        }
        if let Some(piv) = v.iter().position(|&x| x != 0) {
            let inv = f.inv(v[piv]);
            let b: Vec<u64> = v.iter().map(|&x| f.mul(x, inv)).collect();
            basis.push((piv, b));
            chosen.push(pos);
        }
    }
    chosen
}

/// Packet-wise form of [`solve_data_matrix`]: reconstruct the `K` data
/// packets from any `K` independent surviving coordinates
/// (`(position, packet)` pairs; extras ignored). Returns one flat
/// width-aware [`PacketBuf`](crate::net::PacketBuf).
pub fn recover_data<F: Field>(
    f: &F,
    a: &Mat,
    coords: &[(usize, &[u64])],
) -> anyhow::Result<crate::net::PacketBuf> {
    let k = a.rows;
    anyhow::ensure!(coords.len() >= k, "need at least K = {k} coordinates");
    let coords = &coords[..k];
    let w = coords.first().map_or(0, |(_, p)| p.len());
    anyhow::ensure!(coords.iter().all(|(_, p)| p.len() == w), "ragged packets");
    let positions: Vec<usize> = coords.iter().map(|&(pos, _)| pos).collect();
    let d = solve_data_matrix(f, a, &positions)?;
    let pkts: Vec<&[u64]> = coords.iter().map(|&(_, p)| p).collect();
    Ok(d.packet_vec_mul(f, &pkts))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::{vandermonde, GfPrime};

    fn f() -> GfPrime {
        GfPrime::default_field() // q − 1 = 2^18 · 3
    }

    #[test]
    fn max_h_matches_structure() {
        let f = f();
        assert_eq!(StructuredPoints::max_h(&f, 16, 2), 4);
        assert_eq!(StructuredPoints::max_h(&f, 24, 2), 3);
        assert_eq!(StructuredPoints::max_h(&f, 9, 3), 1); // 3^2 ∤ q−1 (q−1 = 2^18·3)
        assert_eq!(StructuredPoints::max_h(&f, 5, 2), 0);
    }

    #[test]
    fn points_are_distinct_and_invertible() {
        let f = f();
        for (n, p) in [(16usize, 2u64), (24, 2), (12, 2), (9, 3)] {
            let m = n / ipow(p, StructuredPoints::max_h(&f, n as u64, p)) as usize;
            let phi: Vec<u64> = (0..m as u64).collect();
            let sp = StructuredPoints::new(&f, n, p, phi).unwrap();
            assert_eq!(sp.len(), n);
            assert!(vandermonde::points_distinct(&sp.points), "n={n} P={p}");
        }
    }

    #[test]
    fn families_are_disjoint() {
        let f = f();
        let fam = disjoint_family(&f, 8, 2, 4).unwrap();
        let mut all: Vec<u64> = fam.iter().flat_map(|s| s.points.clone()).collect();
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n);
    }

    #[test]
    fn rejects_non_injective_phi() {
        let f = f();
        assert!(StructuredPoints::with_h(&f, 8, 2, 2, vec![1, 1]).is_err());
    }

    #[test]
    fn independent_positions_skips_dependent_columns() {
        let f = f();
        let k = 4usize;
        // Parity with a duplicated column: coordinate K+1 is dependent
        // on K+0 and must be skipped in favor of a systematic column.
        let col: Vec<u64> = vec![1, 2, 3, 4];
        let a = Mat::from_fn(k, 2, |kk, _| col[kk]);
        let candidates = [4usize, 5, 0, 1, 2, 3];
        let chosen = independent_positions(&f, &a, &candidates);
        assert_eq!(chosen.len(), k);
        assert_eq!(chosen, vec![4, 0, 1, 2]);
        assert!(solve_data_matrix(&f, &a, &chosen).is_ok());
        // MDS-like case: first K candidates independent → first-fit
        // keeps exactly the old truncate order.
        let b = Mat::random(&f, 3, 3, 9);
        let all = [0usize, 1, 2, 3, 4, 5];
        assert_eq!(independent_positions(&f, &b, &all)[..], all[..3]);
        // Not enough rank: fewer than K come back.
        let short = independent_positions(&f, &a, &[4, 5]);
        assert_eq!(short.len(), 1);
    }

    #[test]
    fn gaussian_fallback_recovers_data_from_any_full_rank_subset() {
        let f = f();
        let (k, r, w) = (6usize, 4usize, 3usize);
        let a = Mat::random(&f, k, r, 77);
        let mut rng = crate::util::Rng::new(5);
        let xs: Vec<Vec<u64>> = (0..k)
            .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
            .collect();
        let mut coords_all = xs.clone();
        for rr in 0..r {
            let mut acc = vec![0u64; w];
            for kk in 0..k {
                crate::net::pkt_add_scaled(&f, &mut acc, a[(kk, rr)], &xs[kk]);
            }
            coords_all.push(acc);
        }
        for trial in 0..20 {
            let subset = rng.choose(k + r, k);
            let coords: Vec<(usize, &[u64])> =
                subset.iter().map(|&i| (i, coords_all[i].as_slice())).collect();
            match recover_data(&f, &a, &coords) {
                Ok(got) => assert_eq!(got.into_packets(), xs, "trial {trial}"),
                // A random (non-MDS) matrix may have dependent subsets;
                // the fallback must report, not panic.
                Err(e) => assert!(e.to_string().contains("determine"), "trial {trial}: {e}"),
            }
        }
        // The all-systematic subset is the identity solve.
        let coords: Vec<(usize, &[u64])> =
            (0..k).map(|i| (i, coords_all[i].as_slice())).collect();
        assert_eq!(recover_data(&f, &a, &coords).unwrap().into_packets(), xs);
        assert!(recover_data(&f, &a, &coords[..k - 1]).is_err(), "too few");
        // An out-of-range coordinate is a proper error, never a silent
        // read of the wrong parity element.
        let bad: Vec<(usize, &[u64])> = (0..k)
            .map(|i| (if i == 0 { k + r } else { i }, coords_all[i].as_slice()))
            .collect();
        assert!(recover_data(&f, &a, &bad).is_err(), "position N rejected");
    }

    #[test]
    fn pure_dft_when_m_is_1() {
        // n = Z: the design degenerates to the permuted DFT points
        // scaled by g^{φ(0)}.
        let f = f();
        let sp = StructuredPoints::new(&f, 8, 2, vec![0]).unwrap();
        let d = crate::gf::dft::permuted_dft_matrix(&f, 2, 3).unwrap();
        let v = vandermonde::square(&f, &sp.points);
        assert_eq!(v, d); // φ(0) = 0 ⇒ α_0 = 1
    }
}

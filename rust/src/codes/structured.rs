//! Structured evaluation points — eq. (15) of §V-B.
//!
//! Draw-and-loose computes Vandermonde matrices whose points form a
//! multiplicative grid: with `Z = P^H` dividing `q − 1`, `K = M·Z`, and an
//! injective `φ : [0, M) → [0, (q−1)/Z)`,
//!
//! ```text
//! ω_{i,j} = α_i · β_{j'},   α_i = g^{φ(i)},   β_{j'} = g^{j'·(q−1)/Z},
//! ```
//!
//! where `j'` is the base-`P` digit reversal of `j`. Processor `i·Z + j`
//! evaluates the data polynomial at `ω_{i,j}`. Exponent uniqueness
//! (`φ(i) < (q−1)/Z`) makes all `K` points distinct, so the matrix is an
//! invertible Vandermonde; Theorem 5 counts `((q−1)/Z choose M)` distinct
//! such matrices. RS/Lagrange code builders pick their `α`/`β` families
//! from *disjoint* `φ` ranges so every Theorem-6 factor is draw-and-loose
//! computable.

use crate::gf::{dft, Field};
use crate::util::ipow;

/// A draw-and-loose–compatible evaluation point design for `n` processors.
#[derive(Clone, Debug)]
pub struct StructuredPoints {
    /// The radix `P` of the DFT part.
    pub p_base: u64,
    /// `H` — the DFT depth; `Z = P^H`.
    pub h: u32,
    /// `Z = P^H` (divides both `n` and `q − 1`).
    pub z: u64,
    /// `M = n / Z` — the universal (draw-phase) dimension.
    pub m: usize,
    /// The injective row map `φ : [0, M) → [0, (q−1)/Z)`.
    pub phi: Vec<u64>,
    /// `points[i·Z + j] = ω_{i,j}` in processor-rank order.
    pub points: Vec<u64>,
}

impl StructuredPoints {
    /// Largest `h` with `P^h | n` and `P^h | q−1`.
    pub fn max_h<F: Field>(f: &F, n: u64, p_base: u64) -> u32 {
        assert!(p_base >= 2);
        let q1 = f.order() - 1;
        let mut h = 0;
        let mut z = 1u64;
        while n % (z * p_base) == 0 && q1 % (z * p_base) == 0 {
            z *= p_base;
            h += 1;
        }
        h
    }

    /// Design points for `n` processors with radix `P` and row map `φ`
    /// (`φ.len()` must be `n / P^H`). Pass `phi_offset`-shifted ranges to
    /// keep several families disjoint (see [`disjoint_family`]).
    pub fn new<F: Field>(f: &F, n: usize, p_base: u64, phi: Vec<u64>) -> anyhow::Result<Self> {
        let h = Self::max_h(f, n as u64, p_base);
        Self::with_h(f, n, p_base, h, phi)
    }

    /// As [`new`](Self::new) but with an explicit (possibly smaller) `H`.
    pub fn with_h<F: Field>(
        f: &F,
        n: usize,
        p_base: u64,
        h: u32,
        phi: Vec<u64>,
    ) -> anyhow::Result<Self> {
        let z = ipow(p_base, h);
        anyhow::ensure!(n as u64 % z == 0, "Z = {z} must divide n = {n}");
        anyhow::ensure!((f.order() - 1) % z == 0, "Z = {z} must divide q−1");
        let m = n / z as usize;
        anyhow::ensure!(phi.len() == m, "phi must have M = {m} entries");
        let cap = (f.order() - 1) / z;
        anyhow::ensure!(
            phi.iter().all(|&x| x < cap),
            "phi values must lie below (q−1)/Z = {cap}"
        );
        let mut sorted = phi.clone();
        sorted.sort_unstable();
        sorted.dedup();
        anyhow::ensure!(sorted.len() == m, "phi must be injective");
        let g = f.generator();
        let step = (f.order() - 1) / z; // (q−1)/Z
        let mut points = Vec::with_capacity(n);
        for i in 0..m {
            let alpha = f.pow(g, phi[i]);
            for j in 0..z {
                let jrev = dft::digit_reverse(j, p_base, h);
                let beta = f.pow(g, jrev * step);
                points.push(f.mul(alpha, beta));
            }
        }
        Ok(StructuredPoints {
            p_base,
            h,
            z,
            m,
            phi,
            points,
        })
    }

    /// `α_i = g^{φ(i)}` for grid row `i`.
    pub fn alpha<F: Field>(&self, f: &F, i: usize) -> u64 {
        f.pow(f.generator(), self.phi[i])
    }

    /// Number of processors covered.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }
}

/// Build `count` point families of `n` points each, all mutually disjoint
/// (family `t` uses `φ(i) = t·M + i`). Used by the systematic-RS encoder:
/// one family per α-block plus one for the β (parity) points.
pub fn disjoint_family<F: Field>(
    f: &F,
    n: usize,
    p_base: u64,
    count: usize,
) -> anyhow::Result<Vec<StructuredPoints>> {
    let h = StructuredPoints::max_h(f, n as u64, p_base);
    let z = ipow(p_base, h);
    let m = n / z as usize;
    anyhow::ensure!(
        (count * m) as u64 <= (f.order() - 1) / z,
        "field too small for {count} disjoint families of {n} points"
    );
    (0..count)
        .map(|t| {
            let phi: Vec<u64> = (0..m as u64).map(|i| t as u64 * m as u64 + i).collect();
            StructuredPoints::with_h(f, n, p_base, h, phi)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::{vandermonde, GfPrime};

    fn f() -> GfPrime {
        GfPrime::default_field() // q − 1 = 2^18 · 3
    }

    #[test]
    fn max_h_matches_structure() {
        let f = f();
        assert_eq!(StructuredPoints::max_h(&f, 16, 2), 4);
        assert_eq!(StructuredPoints::max_h(&f, 24, 2), 3);
        assert_eq!(StructuredPoints::max_h(&f, 9, 3), 1); // 3^2 ∤ q−1 (q−1 = 2^18·3)
        assert_eq!(StructuredPoints::max_h(&f, 5, 2), 0);
    }

    #[test]
    fn points_are_distinct_and_invertible() {
        let f = f();
        for (n, p) in [(16usize, 2u64), (24, 2), (12, 2), (9, 3)] {
            let m = n / ipow(p, StructuredPoints::max_h(&f, n as u64, p)) as usize;
            let phi: Vec<u64> = (0..m as u64).collect();
            let sp = StructuredPoints::new(&f, n, p, phi).unwrap();
            assert_eq!(sp.len(), n);
            assert!(vandermonde::points_distinct(&sp.points), "n={n} P={p}");
        }
    }

    #[test]
    fn families_are_disjoint() {
        let f = f();
        let fam = disjoint_family(&f, 8, 2, 4).unwrap();
        let mut all: Vec<u64> = fam.iter().flat_map(|s| s.points.clone()).collect();
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n);
    }

    #[test]
    fn rejects_non_injective_phi() {
        let f = f();
        assert!(StructuredPoints::with_h(&f, 8, 2, 2, vec![1, 1]).is_err());
    }

    #[test]
    fn pure_dft_when_m_is_1() {
        // n = Z: the design degenerates to the permuted DFT points
        // scaled by g^{φ(0)}.
        let f = f();
        let sp = StructuredPoints::new(&f, 8, 2, vec![0]).unwrap();
        let d = crate::gf::dft::permuted_dft_matrix(&f, 2, 3).unwrap();
        let v = vandermonde::square(&f, &sp.points);
        assert_eq!(v, d); // φ(0) = 0 ⇒ α_0 = 1
    }
}

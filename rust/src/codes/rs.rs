//! Generalized Reed–Solomon codes (§VI).
//!
//! `G_GRS = [V_α | V_β]·diag(u, v)` (eq. (22)); the systematic form is
//! `G_SGRS = [I | A]` with `A = (V_α·P)^{-1}·V_β·Q` (eq. (23)), which by
//! Roth–Seroussi is the Cauchy-like matrix of eq. (24). Decoding from any
//! `K` of the `N` coordinates is Lagrange interpolation of the degree-<K
//! polynomial `g` with `c_i = u_i·g(α_i)` / `c_{K+r} = v_r·g(β_r)`.
//!
//! [`GrsCode::structured`] builds the code on disjoint
//! [`StructuredPoints`] families so that every Theorem-6/8 block of `A` is
//! computable with the specific (draw-and-loose) algorithms.

use super::structured::{disjoint_family, StructuredPoints};
use crate::gf::{cauchy::CauchyLike, poly, vandermonde, Field, Mat};

/// An `[N = K + R, K]` generalized Reed–Solomon code over `F_q`.
#[derive(Clone, Debug)]
pub struct GrsCode {
    /// Systematic evaluation points `α_0..α_{K−1}`.
    pub alphas: Vec<u64>,
    /// Parity evaluation points `β_0..β_{R−1}`.
    pub betas: Vec<u64>,
    /// Column multipliers `u` (systematic) and `v` (parity).
    pub u: Vec<u64>,
    pub v: Vec<u64>,
    /// Structured designs behind `alphas` (one per Theorem-6 block) and
    /// `betas`, when built via [`structured`](Self::structured).
    pub alpha_designs: Vec<StructuredPoints>,
    pub beta_design: Option<StructuredPoints>,
}

impl GrsCode {
    pub fn k(&self) -> usize {
        self.alphas.len()
    }

    pub fn r(&self) -> usize {
        self.betas.len()
    }

    pub fn n(&self) -> usize {
        self.k() + self.r()
    }

    /// Plain GRS on arbitrary distinct points with unit multipliers.
    pub fn plain<F: Field>(f: &F, alphas: Vec<u64>, betas: Vec<u64>) -> anyhow::Result<Self> {
        let all: Vec<u64> = alphas.iter().chain(&betas).copied().collect();
        anyhow::ensure!(vandermonde::points_distinct(&all), "points must be distinct");
        anyhow::ensure!(all.len() as u64 <= f.order(), "N must be at most q");
        Ok(GrsCode {
            u: vec![f.one(); alphas.len()],
            v: vec![f.one(); betas.len()],
            alphas,
            betas,
            alpha_designs: Vec::new(),
            beta_design: None,
        })
    }

    /// Structured GRS: the `α` points form `⌈K/B⌉` disjoint structured
    /// families of block size `B` and the `β` points one more, where `B`
    /// is `R` when `K ≥ R` (Theorem 6 blocks) and `K` otherwise
    /// (Theorem 8 blocks). All blocks are then draw-and-loose computable.
    pub fn structured<F: Field>(f: &F, k: usize, r: usize, p_base: u64) -> anyhow::Result<Self> {
        anyhow::ensure!(k >= 1 && r >= 1);
        if k >= r {
            anyhow::ensure!(k % r == 0, "structured codes need R | K (Remark 4)");
            let blocks = k / r;
            let fam = disjoint_family(f, r, p_base, blocks + 1)?;
            let beta_design = fam[blocks].clone();
            let alpha_designs = fam[..blocks].to_vec();
            let alphas: Vec<u64> = alpha_designs.iter().flat_map(|d| d.points.clone()).collect();
            Ok(GrsCode {
                u: vec![f.one(); k],
                v: vec![f.one(); r],
                alphas,
                betas: beta_design.points.clone(),
                alpha_designs,
                beta_design: Some(beta_design),
            })
        } else {
            anyhow::ensure!(r % k == 0, "structured codes need K | R (Remark 4)");
            let blocks = r / k;
            let fam = disjoint_family(f, k, p_base, blocks + 1)?;
            let alpha_design = fam[blocks].clone();
            let betas: Vec<u64> = fam[..blocks].iter().flat_map(|d| d.points.clone()).collect();
            Ok(GrsCode {
                u: vec![f.one(); k],
                v: vec![f.one(); r],
                alphas: alpha_design.points.clone(),
                betas,
                alpha_designs: vec![alpha_design],
                beta_design: None, // β designs live block-wise in fam[..blocks]
            })
        }
    }

    /// NTT-friendly GRS: `α_i = ω₁^i` sweeps *all* `K`-th roots of unity
    /// and `β_r = c·ω₂^r` lives on the coset `c·⟨ω₂⟩` of the `n2`-th
    /// roots (`n2 = max(1, R.next_power_of_two())`, `c = f.generator()`),
    /// so systematic encode is one size-`K` inverse NTT followed by one
    /// twisted size-`n2` forward NTT — the shape
    /// [`net::opt::NttBackend`](crate::net) detects. Multipliers `u`/`v`
    /// are arbitrary nonzero (pass all-ones for a plain Lagrange code).
    ///
    /// `K` and `n2` must be powers of two dividing the field's two-adic
    /// torsion, and the coset must miss the α set — guaranteed when
    /// `ord(c) = q−1` has an odd factor (true for `q = 3·2^18 + 1`), but
    /// checked explicitly so Fermat-prime-like fields fail loudly.
    pub fn ntt_friendly<F: Field>(
        f: &F,
        k: usize,
        r: usize,
        u: Vec<u64>,
        v: Vec<u64>,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(k >= 1 && r >= 1, "need K ≥ 1 and R ≥ 1");
        anyhow::ensure!(k.is_power_of_two(), "NTT-friendly codes need K a power of two");
        anyhow::ensure!(u.len() == k && v.len() == r, "multiplier lengths must be K and R");
        anyhow::ensure!(u.iter().chain(&v).all(|&m| m != 0), "multipliers must be nonzero");
        let n2 = r.next_power_of_two();
        let w1 = f
            .root_of_unity(k as u64)
            .ok_or_else(|| anyhow::anyhow!("no {k}-th root of unity: K must divide q−1"))?;
        let w2 = f
            .root_of_unity(n2 as u64)
            .ok_or_else(|| anyhow::anyhow!("no {n2}-th root of unity: R̂ must divide q−1"))?;
        let c = f.generator();
        let alphas: Vec<u64> = (0..k as u64).map(|i| f.pow(w1, i)).collect();
        let betas: Vec<u64> = (0..r as u64).map(|j| f.mul(c, f.pow(w2, j))).collect();
        let all: Vec<u64> = alphas.iter().chain(&betas).copied().collect();
        anyhow::ensure!(
            vandermonde::points_distinct(&all),
            "coset β points collide with the α roots over this field"
        );
        Ok(GrsCode {
            alphas,
            betas,
            u,
            v,
            alpha_designs: Vec::new(),
            beta_design: None,
        })
    }

    /// Structured GRS keeping the per-block β designs (K < R case).
    pub fn structured_beta_designs<F: Field>(
        f: &F,
        k: usize,
        r: usize,
        p_base: u64,
    ) -> anyhow::Result<(Self, Vec<StructuredPoints>)> {
        anyhow::ensure!(k < r && r % k == 0);
        let blocks = r / k;
        let fam = disjoint_family(f, k, p_base, blocks + 1)?;
        let code = Self::structured(f, k, r, p_base)?;
        Ok((code, fam[..blocks].to_vec()))
    }

    /// The Cauchy-like description of `A` (eq. (24)).
    pub fn cauchy(&self) -> CauchyLike {
        CauchyLike {
            alphas: self.alphas.clone(),
            betas: self.betas.clone(),
            u: self.u.clone(),
            v: self.v.clone(),
        }
    }

    /// The non-systematic generator `G_GRS = [V_α | V_β]·diag(u,v)`.
    pub fn generator<F: Field>(&self, f: &F) -> Mat {
        let va = vandermonde::vandermonde(f, self.k(), &self.alphas);
        let vb = vandermonde::vandermonde(f, self.k(), &self.betas);
        let uv: Vec<u64> = self.u.iter().chain(&self.v).copied().collect();
        va.hstack(&vb).mul_diag(f, &uv)
    }

    /// The systematic parity matrix `A = (V_α P)^{-1} V_β Q` (eq. (23)),
    /// materialised via the eq. (24) closed form.
    pub fn parity_matrix<F: Field>(&self, f: &F) -> Mat {
        self.cauchy().to_mat(f)
    }

    /// Systematic encode: `x ↦ (x | x·A)`.
    pub fn encode<F: Field>(&self, f: &F, x: &[u64]) -> Vec<u64> {
        assert_eq!(x.len(), self.k());
        let parity = self.parity_matrix(f).vec_mul(f, x);
        x.iter().copied().chain(parity).collect()
    }

    /// The `K×K` erasure-decoding matrix for the codeword `positions`
    /// (distinct, in `[0, N)`): with `c` the row vector of the codeword
    /// values at those positions, the data is `x = c · D`.
    ///
    /// Derivation (all via `gf/poly` + `gf/vandermonde`): the codeword is
    /// `c_i = m_i·g(z_i)` for the degree-`<K` polynomial `g`, the
    /// evaluation point `z_i` (`α` or `β`) and multiplier `m_i` (`u` or
    /// `v`) of position `i`. Row `j` of the structured Vandermonde
    /// inverse on the survivor points is the coefficient vector of the
    /// Lagrange basis `ℓ_j` (eq. (28)), so `g = (c ⊙ m^{-1}) · V^{-1}`
    /// and `x_k = u_k·g(α_k)` gives
    ///
    /// ```text
    /// D = diag(m^{-1}) · V_pts^{-1} · V_α · diag(u).
    /// ```
    ///
    /// Computing `D` once per failure pattern turns packet-wise decoding
    /// into `K` lincombs per packet column — the same dense-row
    /// evaluation discipline as the serving path's `OutputMatrix`.
    pub fn decode_matrix<F: Field>(&self, f: &F, positions: &[usize]) -> anyhow::Result<Mat> {
        let k = self.k();
        anyhow::ensure!(
            positions.len() == k,
            "need exactly K = {k} positions, got {}",
            positions.len()
        );
        let mut pts = Vec::with_capacity(k);
        let mut minv = Vec::with_capacity(k);
        for &pos in positions {
            anyhow::ensure!(pos < self.n(), "position {pos} out of range");
            if pos < k {
                pts.push(self.alphas[pos]);
                minv.push(f.inv(self.u[pos]));
            } else {
                pts.push(self.betas[pos - k]);
                minv.push(f.inv(self.v[pos - k]));
            }
        }
        anyhow::ensure!(vandermonde::points_distinct(&pts), "repeated positions");
        let vinv = vandermonde::inverse(f, &pts);
        let va = vandermonde::vandermonde(f, k, &self.alphas);
        Ok(vinv.diag_mul(f, &minv).mul(f, &va).mul_diag(f, &self.u))
    }

    /// Packet-wise erasure decode: reconstruct the `K` data packets from
    /// any `K` surviving codeword coordinates (`(position, packet)`
    /// pairs; extra coordinates beyond `K` are ignored). Element-wise
    /// over the packet width — Remark 2's `F_q^W` view applies to
    /// decoding exactly as it does to encoding. Returns one flat
    /// width-aware [`PacketBuf`](crate::net::PacketBuf), not a heap
    /// vector per recovered packet.
    pub fn decode_packets<F: Field>(
        &self,
        f: &F,
        coords: &[(usize, &[u64])],
    ) -> anyhow::Result<crate::net::PacketBuf> {
        let k = self.k();
        anyhow::ensure!(coords.len() >= k, "need at least K = {k} coordinates");
        let coords = &coords[..k];
        let w = coords.first().map_or(0, |(_, p)| p.len());
        anyhow::ensure!(coords.iter().all(|(_, p)| p.len() == w), "ragged packets");
        let positions: Vec<usize> = coords.iter().map(|&(pos, _)| pos).collect();
        let d = self.decode_matrix(f, &positions)?;
        let pkts: Vec<&[u64]> = coords.iter().map(|&(_, p)| p).collect();
        Ok(d.packet_vec_mul(f, &pkts))
    }

    /// Erasure-decode the data `x` from any `K` codeword coordinates
    /// (`(position, value)` pairs, positions in `[0, N)`).
    pub fn decode<F: Field>(&self, f: &F, coords: &[(usize, u64)]) -> anyhow::Result<Vec<u64>> {
        let k = self.k();
        anyhow::ensure!(coords.len() >= k, "need at least K = {k} coordinates");
        // Interpolate g of degree < K with c_i = u_i·g(α_i) (systematic)
        // and c_{K+r} = v_r·g(β_r) (parity); here x = y·V_α·diag(u) with
        // g's coefficients y, hence x_k = u_k·g(α_k) = c_k — consistent.
        let mut pts = Vec::with_capacity(k);
        let mut vals = Vec::with_capacity(k);
        for &(pos, val) in coords.iter().take(k) {
            if pos < k {
                pts.push(self.alphas[pos]);
                vals.push(f.div(val, self.u[pos]));
            } else {
                pts.push(self.betas[pos - k]);
                vals.push(f.div(val, self.v[pos - k]));
            }
        }
        anyhow::ensure!(vandermonde::points_distinct(&pts), "repeated coordinates");
        let g = poly::interpolate(f, &pts, &vals);
        Ok((0..k)
            .map(|i| f.mul(self.u[i], poly::eval(f, &g, self.alphas[i])))
            .collect())
    }

    /// MDS sanity check: every `K`-subset of generator columns has full
    /// rank (exhaustive for small `N`, sampled otherwise).
    pub fn is_mds<F: Field>(&self, f: &F, samples: usize, seed: u64) -> bool {
        let gsys = Mat::identity(f, self.k()).hstack(&self.parity_matrix(f));
        let mut rng = crate::util::Rng::new(seed);
        for _ in 0..samples {
            let cols = rng.choose(self.n(), self.k());
            let sub = Mat::from_fn(self.k(), self.k(), |r, c| gsys[(r, cols[c])]);
            if sub.rank(f) != self.k() {
                return false;
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::GfPrime;

    fn f() -> GfPrime {
        GfPrime::default_field()
    }

    #[test]
    fn systematic_matches_definition() {
        let f = f();
        let code = GrsCode::plain(&f, (1..=6).collect(), (100..104).collect()).unwrap();
        let a = code.parity_matrix(&f);
        let by_def = code.cauchy().to_mat_by_definition(&f);
        assert_eq!(a, by_def);
    }

    #[test]
    fn encode_decode_roundtrip_all_positions() {
        let f = f();
        let code = GrsCode::plain(&f, (1..=5).collect(), (50..55).collect()).unwrap();
        let x: Vec<u64> = vec![7, 0, 123456, 3, 786432];
        let cw = code.encode(&f, &x);
        assert_eq!(&cw[..5], &x[..]); // systematic prefix
        // Decode from every contiguous window and from scattered subsets.
        let mut rng = crate::util::Rng::new(4);
        for trial in 0..50 {
            let subset = rng.choose(code.n(), code.k());
            let coords: Vec<(usize, u64)> = subset.iter().map(|&i| (i, cw[i])).collect();
            assert_eq!(code.decode(&f, &coords).unwrap(), x, "trial {trial}");
        }
    }

    #[test]
    fn structured_code_blocks_are_designs() {
        let f = f();
        // K = 24, R = 8: 3 α-blocks + 1 β family, all of size 8.
        let code = GrsCode::structured(&f, 24, 8, 2).unwrap();
        assert_eq!(code.alpha_designs.len(), 3);
        assert_eq!(code.k(), 24);
        assert_eq!(code.r(), 8);
        // All 32 points distinct.
        let all: Vec<u64> = code.alphas.iter().chain(&code.betas).copied().collect();
        assert!(vandermonde::points_distinct(&all));
        // And it is MDS (GRS always is; sanity-check the construction).
        assert!(code.is_mds(&f, 40, 11));
    }

    #[test]
    fn structured_code_k_lt_r() {
        let f = f();
        let (code, beta_designs) = GrsCode::structured_beta_designs(&f, 8, 24, 2).unwrap();
        assert_eq!(code.k(), 8);
        assert_eq!(code.r(), 24);
        assert_eq!(beta_designs.len(), 3);
        assert!(code.is_mds(&f, 40, 13));
        // Block m's betas are exactly design m's points.
        for (m, d) in beta_designs.iter().enumerate() {
            assert_eq!(&code.betas[m * 8..(m + 1) * 8], &d.points[..]);
        }
    }

    #[test]
    fn generator_contains_systematic_form() {
        // G_GRS · (V_α P)^{-1} has the form [I | A] up to the diag: check
        // encode consistency instead: x·G_SGRS parity == (x·(V_αP)^{-1})·V_βQ.
        let f = f();
        let code = GrsCode::plain(&f, vec![2, 4, 6], vec![10, 20, 30, 40]).unwrap();
        let x = vec![5u64, 9, 786000];
        let cw = code.encode(&f, &x);
        // Independent check through polynomial evaluation.
        let va_inv = vandermonde::inverse(&f, &code.alphas);
        let y = va_inv.vec_mul(&f, &x); // g's coefficients (u = 1)
        for (r, &b) in code.betas.iter().enumerate() {
            assert_eq!(cw[3 + r], poly::eval(&f, &y, b));
        }
    }

    #[test]
    fn decode_matrix_agrees_with_interpolation_decode() {
        let f = f();
        let code = GrsCode::plain(&f, (1..=6).collect(), (60..64).collect()).unwrap();
        let x: Vec<u64> = vec![5, 786000, 0, 17, 99, 3];
        let cw = code.encode(&f, &x);
        let mut rng = crate::util::Rng::new(21);
        for trial in 0..30 {
            let subset = rng.choose(code.n(), code.k());
            // Scalar path (poly interpolation per call).
            let coords: Vec<(usize, u64)> = subset.iter().map(|&i| (i, cw[i])).collect();
            assert_eq!(code.decode(&f, &coords).unwrap(), x, "trial {trial}");
            // Matrix path: x = c · D.
            let d = code.decode_matrix(&f, &subset).unwrap();
            let got: Vec<u64> = (0..code.k())
                .map(|kk| {
                    let mut acc = 0u64;
                    for (i, &pos) in subset.iter().enumerate() {
                        acc = f.add(acc, f.mul(cw[pos], d[(i, kk)]));
                    }
                    acc
                })
                .collect();
            assert_eq!(got, x, "trial {trial}: decode matrix");
        }
    }

    #[test]
    fn decode_packets_roundtrips_wide_payloads_both_fields() {
        let f = f();
        let code = GrsCode::structured(&f, 8, 4, 2).unwrap();
        let w = 5usize;
        let mut rng = crate::util::Rng::new(8);
        let xs: Vec<Vec<u64>> = (0..8)
            .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
            .collect();
        // Column-wise encode: coordinate j's packet.
        let a = code.parity_matrix(&f);
        let mut coords_all: Vec<Vec<u64>> = xs.clone();
        for r in 0..4 {
            let mut acc = vec![0u64; w];
            for k in 0..8 {
                crate::net::pkt_add_scaled(&f, &mut acc, a[(k, r)], &xs[k]);
            }
            coords_all.push(acc);
        }
        for trial in 0..20 {
            let subset = rng.choose(12, 8);
            let coords: Vec<(usize, &[u64])> =
                subset.iter().map(|&i| (i, coords_all[i].as_slice())).collect();
            assert_eq!(
                code.decode_packets(&f, &coords).unwrap().into_packets(),
                xs,
                "trial {trial}"
            );
        }
        // GF(2^8): same story on a plain code.
        let f = crate::gf::Gf2e::new(8).unwrap();
        let code = GrsCode::plain(&f, (1..=5).collect(), (10..13).collect()).unwrap();
        let xs: Vec<Vec<u64>> = (0..5u64)
            .map(|i| vec![(i * 31) % 256, (i * 7 + 2) % 256])
            .collect();
        let a = code.parity_matrix(&f);
        let mut coords_all = xs.clone();
        for r in 0..3 {
            let mut acc = vec![0u64; 2];
            for k in 0..5 {
                crate::net::pkt_add_scaled(&f, &mut acc, a[(k, r)], &xs[k]);
            }
            coords_all.push(acc);
        }
        let coords: Vec<(usize, &[u64])> =
            (3..8).map(|i| (i, coords_all[i].as_slice())).collect();
        assert_eq!(code.decode_packets(&f, &coords).unwrap().into_packets(), xs);
        // Too few coordinates is a proper error, not a panic.
        assert!(code.decode_packets(&f, &coords[..4]).is_err());
    }

    #[test]
    fn ntt_friendly_code_is_a_real_grs_code() {
        let f = f();
        let mut rng = crate::util::Rng::new(9);
        for (k, r) in [(1usize, 1usize), (2, 3), (8, 4), (16, 5)] {
            let u: Vec<u64> = (0..k).map(|_| rng.below(f.order() - 1) + 1).collect();
            let v: Vec<u64> = (0..r).map(|_| rng.below(f.order() - 1) + 1).collect();
            let code = GrsCode::ntt_friendly(&f, k, r, u, v).unwrap();
            assert_eq!((code.k(), code.r()), (k, r));
            assert!(code.is_mds(&f, 20, 3), "K={k} R={r}");
            // α's really are the K-th roots, β's really are on the coset.
            let w1 = f.root_of_unity(k as u64).unwrap();
            for (i, &a) in code.alphas.iter().enumerate() {
                assert_eq!(a, f.pow(w1, i as u64));
            }
            let x: Vec<u64> = (0..k as u64).map(|i| f.elem(i * 13 + 1)).collect();
            let cw = code.encode(&f, &x);
            assert_eq!(&cw[..k], &x[..]); // systematic prefix survives
        }
        // Non-power-of-two K and zero multipliers are rejected loudly.
        assert!(GrsCode::ntt_friendly(&f, 3, 2, vec![1; 3], vec![1; 2]).is_err());
        assert!(GrsCode::ntt_friendly(&f, 2, 2, vec![1, 0], vec![1, 1]).is_err());
    }

    #[test]
    fn gf256_storage_code() {
        let f = crate::gf::Gf2e::new(8).unwrap();
        let code = GrsCode::plain(&f, (1..=10).collect(), (20..24).collect()).unwrap();
        let x: Vec<u64> = (0..10).map(|i| (i * 31) % 256).collect();
        let cw = code.encode(&f, &x);
        let coords: Vec<(usize, u64)> = (4..14).map(|i| (i, cw[i])).collect();
        assert_eq!(code.decode(&f, &coords).unwrap(), x);
    }
}

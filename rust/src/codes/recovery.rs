//! The erasure-recovery operator: survivors → lost outputs, as one
//! dense matrix per failure pattern.
//!
//! This is the layer the coordinator's repair path executes. Given a
//! systematic code `G = [I | A]` and the `K` survivor coordinate
//! positions a [`DegradedReport`](crate::net::DegradedReport) certifies,
//! it precomputes
//!
//! * the **data matrix** `D` (`K×K`, `x = c·D`) — by structured
//!   Lagrange-interpolation algebra for GRS/Lagrange codes
//!   ([`GrsCode::decode_matrix`], `O(K²)` construction via
//!   `gf/vandermonde` + `gf/poly`) or by Gaussian elimination for
//!   arbitrary parity matrices
//!   ([`structured::solve_data_matrix`](super::structured::solve_data_matrix));
//! * the **repair matrix** `R = D·A_lost` (`K×L`) mapping survivor
//!   packets straight to the `L` lost sink outputs.
//!
//! Applying the operator is then `L` dense lincombs over the survivor
//! packets per job — exactly the `OutputMatrix · x` evaluation
//! discipline of the serving path, so recovered packets are
//! **bit-identical** to the healthy run's (canonical field elements are
//! unique, and every evaluation path reduces to the same exact sum).

use super::rs::GrsCode;
use super::structured::solve_data_matrix;
use crate::gf::{Field, Mat};
use crate::net::PacketBuf;

/// A reusable recovery operator for one `(code, failure-pattern)` pair.
#[derive(Clone, Debug)]
pub struct Recovery {
    /// Survivor coordinate positions, in the order packets must be fed.
    positions: Vec<usize>,
    /// `K×K` data matrix: `x = c · D`.
    data: Mat,
    /// Lost sink indices (`r` in `[0, R)`) this operator reconstructs.
    lost_sinks: Vec<usize>,
    /// `K×L` repair matrix: `y_lost = c · (D·A_lost)`.
    repair: Mat,
}

impl Recovery {
    /// Build the operator from any `K` survivor `positions` (codeword
    /// coordinates in `[0, N)`) for the `lost_sinks` to reconstruct.
    /// Uses the GRS interpolation algebra when `code` is given, the
    /// Gaussian fallback on the raw parity matrix otherwise.
    pub fn plan<F: Field>(
        f: &F,
        code: Option<&GrsCode>,
        a: &Mat,
        positions: &[usize],
        lost_sinks: &[usize],
    ) -> anyhow::Result<Self> {
        let (k, r) = (a.rows, a.cols);
        anyhow::ensure!(
            lost_sinks.iter().all(|&s| s < r),
            "lost sink index out of range"
        );
        let data = match code {
            Some(c) => {
                anyhow::ensure!(
                    c.k() == k && c.r() == r,
                    "code shape ({}, {}) != parity shape ({k}, {r})",
                    c.k(),
                    c.r()
                );
                c.decode_matrix(f, positions)?
            }
            None => solve_data_matrix(f, a, positions)?,
        };
        // A_lost: the parity columns of the lost sinks, K×L.
        let a_lost = a.select_cols(lost_sinks);
        let repair = data.mul(f, &a_lost);
        Ok(Recovery {
            positions: positions.to_vec(),
            data,
            lost_sinks: lost_sinks.to_vec(),
            repair,
        })
    }

    /// The survivor positions this operator consumes, in feed order.
    pub fn positions(&self) -> &[usize] {
        &self.positions
    }

    /// The sink indices this operator reconstructs.
    pub fn lost_sinks(&self) -> &[usize] {
        &self.lost_sinks
    }

    /// Reconstruct the data packets from the survivor packets
    /// (`coords[i]` = the packet at `positions[i]`), as one flat
    /// width-aware [`PacketBuf`] — a single allocation per repair pass.
    pub fn data_packets<F: Field>(&self, f: &F, coords: &[&[u64]]) -> PacketBuf {
        assert_eq!(coords.len(), self.positions.len(), "survivor count");
        self.data.packet_vec_mul(f, coords)
    }

    /// Reconstruct the lost sinks' outputs (in `lost_sinks` order) from
    /// the survivor packets — bit-identical to the healthy run's
    /// packets at those sinks. Flat [`PacketBuf`], one allocation.
    pub fn lost_outputs<F: Field>(&self, f: &F, coords: &[&[u64]]) -> PacketBuf {
        assert_eq!(coords.len(), self.positions.len(), "survivor count");
        self.repair.packet_vec_mul(f, coords)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::GfPrime;
    use crate::net::pkt_add_scaled;
    use crate::util::Rng;

    fn encode_all<F: Field>(f: &F, a: &Mat, xs: &[Vec<u64>]) -> Vec<Vec<u64>> {
        let w = xs[0].len();
        let mut all = xs.to_vec();
        for r in 0..a.cols {
            let mut acc = vec![0u64; w];
            for k in 0..a.rows {
                pkt_add_scaled(f, &mut acc, a[(k, r)], &xs[k]);
            }
            all.push(acc);
        }
        all
    }

    #[test]
    fn grs_and_gaussian_paths_reconstruct_identically() {
        let f = GfPrime::default_field();
        let code = GrsCode::structured(&f, 8, 4, 2).unwrap();
        let a = code.parity_matrix(&f);
        let mut rng = Rng::new(3);
        let xs: Vec<Vec<u64>> = (0..8)
            .map(|_| (0..3).map(|_| rng.below(f.order())).collect())
            .collect();
        let all = encode_all(&f, &a, &xs);
        for trial in 0..15 {
            let survivors = rng.choose(12, 8);
            let lost_sinks: Vec<usize> = (0..4)
                .filter(|&r| !survivors.contains(&(8 + r)))
                .collect();
            let coords: Vec<&[u64]> = survivors.iter().map(|&i| all[i].as_slice()).collect();
            let grs = Recovery::plan(&f, Some(&code), &a, &survivors, &lost_sinks).unwrap();
            let gauss = Recovery::plan(&f, None, &a, &survivors, &lost_sinks).unwrap();
            assert_eq!(
                grs.data_packets(&f, &coords).into_packets(),
                xs,
                "trial {trial}: grs data"
            );
            assert_eq!(
                gauss.data_packets(&f, &coords).into_packets(),
                xs,
                "trial {trial}: gauss data"
            );
            let want: Vec<Vec<u64>> =
                lost_sinks.iter().map(|&r| all[8 + r].clone()).collect();
            assert_eq!(
                grs.lost_outputs(&f, &coords).into_packets(),
                want,
                "trial {trial}: grs sinks"
            );
            assert_eq!(
                gauss.lost_outputs(&f, &coords).into_packets(),
                want,
                "trial {trial}: gauss sinks"
            );
        }
    }

    #[test]
    fn recovery_works_over_gf2e() {
        let f = crate::gf::Gf2e::new(8).unwrap();
        let code = GrsCode::plain(&f, (1..=4).collect(), (9..12).collect()).unwrap();
        let a = code.parity_matrix(&f);
        let xs: Vec<Vec<u64>> = (0..4u64).map(|i| vec![(i * 53 + 1) % 256]).collect();
        let all = encode_all(&f, &a, &xs);
        // Lose sink 0 and source 2; recover from {0, 1, 3, K+1}.
        let survivors = vec![0usize, 1, 3, 5];
        let rec = Recovery::plan(&f, Some(&code), &a, &survivors, &[0]).unwrap();
        let coords: Vec<&[u64]> = survivors.iter().map(|&i| all[i].as_slice()).collect();
        assert_eq!(rec.data_packets(&f, &coords).into_packets(), xs);
        assert_eq!(
            rec.lost_outputs(&f, &coords).into_packets(),
            vec![all[4].clone()]
        );
    }

    #[test]
    fn rejects_bad_shapes() {
        let f = GfPrime::default_field();
        let a = Mat::random(&f, 4, 2, 1);
        assert!(Recovery::plan(&f, None, &a, &[0, 1, 2], &[0]).is_err(), "too few");
        assert!(Recovery::plan(&f, None, &a, &[0, 1, 2, 3], &[7]).is_err(), "bad sink");
    }
}

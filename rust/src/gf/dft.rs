//! DFT matrices, digit reversal and the two trees of §V-A.
//!
//! For `K | q − 1` and `K = P^H`, the paper's specific algorithm computes
//! the *permuted* DFT matrix `D_K · Π`, where `Π` is the digit-reversal
//! permutation (`Π_{k,k'} = 1`, `k'` = base-`P` digit reversal of `k`,
//! eqs. (6)–(7)). Column `j` of `D_K · Π` holds the powers of `β^{rev(j)}`,
//! so processor `j` ends up with the evaluation `f(β^{rev(j)})`.

use super::{vandermonde, Field, Mat};
use crate::util::ipow;

/// Base-`P` digit reversal of `k` with `H` digits (eq. (7)).
pub fn digit_reverse(k: u64, p: u64, h: u32) -> u64 {
    let mut k = k;
    let mut out = 0;
    for _ in 0..h {
        out = out * p + k % p;
        k /= p;
    }
    out
}

/// The base-`P` digits of `k`, least significant first (`k_1, …, k_H` in
/// the paper's notation of eq. (6) — note the paper indexes from 1).
pub fn digits(k: u64, p: u64, h: u32) -> Vec<u64> {
    let mut k = k;
    (0..h)
        .map(|_| {
            let d = k % p;
            k /= p;
            d
        })
        .collect()
}

/// A primitive `K`-th root of unity `β = g^{(q−1)/K}`; `None` if `K ∤ q−1`.
pub fn primitive_root<F: Field>(f: &F, k: u64) -> Option<u64> {
    f.root_of_unity(k)
}

/// The `K × K` DFT matrix `D_K[i][j] = β^{ij}` (eq. (8)).
pub fn dft_matrix<F: Field>(f: &F, k: usize) -> Option<Mat> {
    let beta = primitive_root(f, k as u64)?;
    let points: Vec<u64> = (0..k as u64).map(|j| f.pow(beta, j)).collect();
    Some(vandermonde::square(f, &points))
}

/// The permuted DFT matrix `D_K · Π` computed by the §V-A algorithm:
/// `(D_K Π)[i][j] = β^{i · rev(j)}`.
pub fn permuted_dft_matrix<F: Field>(f: &F, p: u64, h: u32) -> Option<Mat> {
    let k = ipow(p, h);
    let beta = primitive_root(f, k)?;
    let points: Vec<u64> = (0..k)
        .map(|j| f.pow(beta, digit_reverse(j, p, h)))
        .collect();
    Some(vandermonde::square(f, &points))
}

/// The element-tree entry `γ_{k_h…k_1}` of eq. (9): the vertex at level `h`
/// whose digit index (low `h` digits) is `low` hosts
/// `γ = (β^{low})^{K/P^h}` — each child a distinct `P`-th root of its
/// parent (eq. (10)).
pub fn gamma<F: Field>(f: &F, beta: u64, k: u64, p: u64, h: u32, low: u64) -> u64 {
    let ph = ipow(p, h);
    debug_assert!(k % ph == 0 && low < ph);
    f.pow(beta, low * (k / ph) % (f.order() - 1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::GfPrime;

    fn f() -> GfPrime {
        GfPrime::new(786433).unwrap()
    }

    #[test]
    fn digit_reverse_involution() {
        for k in 0..81 {
            assert_eq!(digit_reverse(digit_reverse(k, 3, 4), 3, 4), k);
        }
        assert_eq!(digit_reverse(1, 2, 3), 4); // 001 -> 100
        assert_eq!(digit_reverse(6, 2, 3), 3); // 110 -> 011
    }

    #[test]
    fn digits_reconstruct() {
        let ds = digits(57, 3, 4); // 57 = 0+3*(1+3*(0+3*2)) -> [0,1,0,2]? 57=2*27+3
        let mut back = 0;
        for (i, &d) in ds.iter().enumerate() {
            back += d * ipow(3, i as u32);
        }
        assert_eq!(back, 57);
    }

    #[test]
    fn dft_matrix_is_invertible_vandermonde() {
        let f = f();
        let d = dft_matrix(&f, 8).unwrap();
        assert_eq!(d.rank(&f), 8);
        assert_eq!(d[(0, 5)], 1); // first row all ones
        assert_eq!(d[(1, 0)], 1); // column 0 is all ones (β^0)
    }

    #[test]
    fn permuted_dft_is_column_permutation_of_dft() {
        let f = f();
        let (p, h) = (2u64, 3u32);
        let k = 8usize;
        let d = dft_matrix(&f, k).unwrap();
        let perm: Vec<usize> = (0..k).map(|j| digit_reverse(j as u64, p, h) as usize).collect();
        let dp = d.permute_cols(&perm);
        assert_eq!(dp, permuted_dft_matrix(&f, p, h).unwrap());
    }

    #[test]
    fn gamma_children_are_pth_roots_of_parent() {
        // Fig. 8 setting: K = 9, P = 3 — every child is a distinct cube
        // root of its parent; the root (level 0) hosts γ = 1.
        // (Needs 9 | q−1; the default prime has only one factor of 3, so
        // use q = 37.)
        let f = GfPrime::new(37).unwrap();
        let k = 9u64;
        let beta = primitive_root(&f, k).unwrap();
        assert_eq!(gamma(&f, beta, k, 3, 0, 0), 1);
        for h in 1..=2u32 {
            for low in 0..ipow(3, h) {
                let child = gamma(&f, beta, k, 3, h, low);
                let parent = gamma(&f, beta, k, 3, h - 1, low % ipow(3, h - 1));
                assert_eq!(f.pow(child, 3), parent, "h={h} low={low}");
            }
        }
        // Leaves host β^k.
        for kk in 0..k {
            assert_eq!(gamma(&f, beta, k, 3, 2, kk), f.pow(beta, kk));
        }
    }

    #[test]
    fn no_root_when_k_does_not_divide() {
        let f = f();
        assert!(dft_matrix(&f, 5).is_none()); // 5 ∤ 786432
        assert!(dft_matrix(&f, 512).is_some());
    }
}

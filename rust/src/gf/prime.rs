//! Prime fields `F_p` for odd primes `p < 2^31`.
//!
//! The default field of the repository is `p = 786433 = 3·2^18 + 1`, an
//! NTT-friendly prime whose multiplicative group contains `2^18`-th roots
//! of unity — exactly the structure §V-A of the paper needs (`K | q − 1`,
//! `K = P^H`).
//!
//! Multiplication uses Barrett reduction (a single `u128` multiply and a
//! correction step) rather than `%`, which matters in the payload hot loop
//! — see DESIGN.md §Perf and `benches/hotpath.rs`.

use super::Field;

/// A prime field `F_p`, `3 ≤ p < 2^31`.
#[derive(Clone, Copy)]
pub struct GfPrime {
    p: u64,
    /// Barrett constant `⌊2^64 / p⌋`.
    barrett: u64,
    generator: u64,
}

impl std::fmt::Debug for GfPrime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "GF({})", self.p)
    }
}

/// The repository's default prime: `786433 = 3·2^18 + 1`.
pub const DEFAULT_PRIME: u64 = 786433;

impl GfPrime {
    /// Construct `F_p`. Fails if `p` is not an odd prime below `2^31`.
    pub fn new(p: u64) -> anyhow::Result<Self> {
        anyhow::ensure!(p >= 3 && p < (1 << 31), "prime must be in [3, 2^31)");
        anyhow::ensure!(is_prime(p), "{p} is not prime");
        let generator = find_generator(p);
        // μ = ⌊2^64 / p⌋; since p is odd it never divides 2^64, so
        // ⌊(2^64 − 1)/p⌋ == ⌊2^64/p⌋. With x < p² < 2^62 the estimate
        // q = ⌊x·μ / 2^64⌋ satisfies ⌊x/p⌋ − 1 ≤ q ≤ ⌊x/p⌋, so a single
        // conditional subtraction completes the reduction.
        Ok(GfPrime {
            p,
            barrett: u64::MAX / p,
            generator,
        })
    }

    /// The default NTT-friendly field `F_786433`.
    pub fn default_field() -> Self {
        Self::new(DEFAULT_PRIME).expect("default prime is prime")
    }

    /// The modulus `p`.
    #[inline]
    pub fn modulus(&self) -> u64 {
        self.p
    }

    /// Reduce `x < p^2 < 2^62` modulo `p` via Barrett reduction.
    /// (`pub(crate)`: the packed kernels in `gf/kernels.rs` fuse it into
    /// their narrow-lane loops.)
    #[inline(always)]
    pub(crate) fn reduce(&self, x: u64) -> u64 {
        // q = ⌊x·μ / 2^64⌋ ≈ ⌊x/p⌋ (may be off by one, never over).
        let q = ((x as u128 * self.barrett as u128) >> 64) as u64;
        let r = x - q * self.p;
        if r >= self.p {
            r - self.p
        } else {
            r
        }
    }

    /// Reduce any `x < 2^64` modulo `p` (the Barrett estimate can be off
    /// by up to 2 for x near 2^64, hence the loop — at most two
    /// subtractions).
    #[inline(always)]
    pub(crate) fn reduce_wide(&self, x: u64) -> u64 {
        let q = ((x as u128 * self.barrett as u128) >> 64) as u64;
        let mut r = x - q.wrapping_mul(self.p);
        while r >= self.p {
            r -= self.p;
        }
        r
    }
}

impl Field for GfPrime {
    #[inline]
    fn order(&self) -> u64 {
        self.p
    }

    #[inline(always)]
    fn add(&self, a: u64, b: u64) -> u64 {
        let s = a + b;
        if s >= self.p {
            s - self.p
        } else {
            s
        }
    }

    #[inline(always)]
    fn sub(&self, a: u64, b: u64) -> u64 {
        if a >= b {
            a - b
        } else {
            a + self.p - b
        }
    }

    #[inline(always)]
    fn mul(&self, a: u64, b: u64) -> u64 {
        debug_assert!(a < self.p && b < self.p);
        self.reduce(a * b)
    }

    fn inv(&self, a: u64) -> u64 {
        assert!(a != 0, "division by zero in GF({})", self.p);
        // Extended Euclid on (a, p); p prime so gcd == 1.
        let (mut t, mut new_t): (i64, i64) = (0, 1);
        let (mut r, mut new_r): (i64, i64) = (self.p as i64, a as i64);
        while new_r != 0 {
            let q = r / new_r;
            (t, new_t) = (new_t, t - q * new_t);
            (r, new_r) = (new_r, r - q * new_r);
        }
        debug_assert_eq!(r, 1);
        t.rem_euclid(self.p as i64) as u64
    }

    #[inline]
    fn generator(&self) -> u64 {
        self.generator
    }

    /// Delayed reduction: raw products `c·s < (p−1)²` are accumulated
    /// unreduced; with `T = ⌊(2^64 − p)/(p−1)²⌋` terms per chunk the
    /// running sum never overflows, and one Barrett reduction per chunk
    /// (instead of per term) closes it. For the default `p ≈ 2^20` this
    /// is ~16700 terms per reduction — effectively one per combine.
    fn lazy_chunk(&self) -> usize {
        let p1 = self.p - 1;
        (((u64::MAX - self.p) / (p1 * p1)) as usize).max(1)
    }

    #[inline(always)]
    fn lazy_mul_acc(&self, acc: u64, c: u64, s: u64) -> u64 {
        acc + c * s // raw product ≤ (p−1)²; sum bounded by lazy_chunk
    }

    #[inline(always)]
    fn lazy_reduce(&self, x: u64) -> u64 {
        self.reduce_wide(x)
    }

    /// Fused axpy: `a + c·s ≤ (p−1) + (p−1)² < p²`, so a single Barrett
    /// reduction replaces the reduce-then-add-correct pair of `mul_add`.
    fn axpy_into(&self, acc: &mut [u64], c: u64, src: &[u64]) {
        if c == 0 {
            return;
        }
        debug_assert!(c < self.p);
        debug_assert_eq!(acc.len(), src.len());
        for (a, &s) in acc.iter_mut().zip(src) {
            *a = self.reduce(*a + c * s);
        }
    }
}

/// Deterministic Miller–Rabin, exact for all `u64` with these witnesses.
pub fn is_prime(n: u64) -> bool {
    if n < 2 {
        return false;
    }
    for p in [2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        if n % p == 0 {
            return n == p;
        }
    }
    let mut d = n - 1;
    let mut s = 0;
    while d % 2 == 0 {
        d /= 2;
        s += 1;
    }
    'witness: for a in [2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        let mut x = pow_mod(a % n, d, n);
        if x == 1 || x == n - 1 {
            continue;
        }
        for _ in 0..s - 1 {
            x = mul_mod(x, x, n);
            if x == n - 1 {
                continue 'witness;
            }
        }
        return false;
    }
    true
}

fn mul_mod(a: u64, b: u64, m: u64) -> u64 {
    ((a as u128 * b as u128) % m as u128) as u64
}

fn pow_mod(mut a: u64, mut e: u64, m: u64) -> u64 {
    let mut acc = 1u64;
    a %= m;
    while e > 0 {
        if e & 1 == 1 {
            acc = mul_mod(acc, a, m);
        }
        a = mul_mod(a, a, m);
        e >>= 1;
    }
    acc
}

/// Find the smallest generator of `F_p^*` by factoring `p − 1`.
fn find_generator(p: u64) -> u64 {
    let factors = prime_factors(p - 1);
    'cand: for g in 2..p {
        for &f in &factors {
            if pow_mod(g, (p - 1) / f, p) == 1 {
                continue 'cand;
            }
        }
        return g;
    }
    unreachable!("F_p^* is cyclic, a generator exists")
}

/// Distinct prime factors by trial division (fine for p − 1 < 2^31).
pub fn prime_factors(mut n: u64) -> Vec<u64> {
    let mut out = Vec::new();
    let mut d = 2;
    while d * d <= n {
        if n % d == 0 {
            out.push(d);
            while n % d == 0 {
                n /= d;
            }
        }
        d += 1;
    }
    if n > 1 {
        out.push(n);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_prime_structure() {
        // 786432 = 2^18 · 3, so K = 2^H roots of unity exist up to H = 18.
        assert!(is_prime(DEFAULT_PRIME));
        assert_eq!(DEFAULT_PRIME - 1, (1 << 18) * 3);
    }

    #[test]
    fn arithmetic_identities() {
        let f = GfPrime::default_field();
        let p = f.modulus();
        for a in [0u64, 1, 2, 17, p - 2, p - 1, 12345, 700001] {
            assert_eq!(f.add(a, f.neg(a)), 0);
            assert_eq!(f.sub(a, a), 0);
            if a != 0 {
                assert_eq!(f.mul(a, f.inv(a)), 1, "a={a}");
            }
            assert_eq!(f.mul(a, 1), a);
            assert_eq!(f.mul(a, 0), 0);
        }
    }

    #[test]
    fn barrett_matches_naive() {
        let f = GfPrime::default_field();
        let p = f.modulus();
        let mut x = 0x9E3779B97F4A7C15u64;
        for _ in 0..10_000 {
            x = x.wrapping_mul(0xBF58476D1CE4E5B9).wrapping_add(1);
            let a = x % p;
            let b = (x >> 32) % p;
            assert_eq!(f.mul(a, b), (a as u128 * b as u128 % p as u128) as u64);
        }
    }

    #[test]
    fn generator_has_full_order() {
        for p in [786433u64, 65537, 257, 13] {
            let f = GfPrime::new(p).unwrap();
            let g = f.generator();
            assert_eq!(f.pow(g, p - 1), 1);
            for &q in &prime_factors(p - 1) {
                assert_ne!(f.pow(g, (p - 1) / q), 1, "g not primitive mod {p}");
            }
        }
    }

    #[test]
    fn roots_of_unity() {
        let f = GfPrime::default_field();
        let w = f.root_of_unity(512).unwrap();
        assert_eq!(f.pow(w, 512), 1);
        assert_ne!(f.pow(w, 256), 1);
        assert!(f.root_of_unity(5).is_none()); // 5 ∤ 786432
    }

    #[test]
    fn pow_edge_cases() {
        let f = GfPrime::new(13).unwrap();
        assert_eq!(f.pow(0, 0), 1);
        assert_eq!(f.pow(0, 5), 0);
        assert_eq!(f.pow(5, 0), 1);
        assert_eq!(f.pow(2, 12), 1); // Fermat
    }

    #[test]
    fn lincomb_matches_naive_all_field_sizes() {
        // Exercise both the "one reduction per call" regime (small p) and
        // the chunked regime (p near 2^31 ⇒ ~4 terms per chunk).
        for p in [786433u64, 65537, 2147483647] {
            let f = GfPrime::new(p).unwrap();
            let mut rng = crate::util::Rng::new(p);
            let w = 37;
            let n_terms = 100;
            let srcs: Vec<Vec<u64>> = (0..n_terms)
                .map(|_| (0..w).map(|_| rng.below(p)).collect())
                .collect();
            let coeffs: Vec<u64> = (0..n_terms).map(|_| rng.below(p)).collect();
            let init: Vec<u64> = (0..w).map(|_| rng.below(p)).collect();

            let mut fast = init.clone();
            let terms: Vec<(u64, &[u64])> = coeffs
                .iter()
                .zip(&srcs)
                .map(|(&c, s)| (c, s.as_slice()))
                .collect();
            f.lincomb_into(&mut fast, &terms);

            let mut naive = init;
            for (&c, s) in coeffs.iter().zip(&srcs) {
                for (a, &x) in naive.iter_mut().zip(s) {
                    *a = f.mul_add(*a, c, x);
                }
            }
            assert_eq!(fast, naive, "p={p}");
        }
    }

    #[test]
    fn reduce_wide_full_range() {
        let f = GfPrime::default_field();
        let p = f.modulus();
        for x in [0u64, 1, p - 1, p, p + 1, u64::MAX, u64::MAX - 1, 1 << 63] {
            assert_eq!(f.reduce_wide(x), x % p, "x={x}");
        }
        let f = GfPrime::new(2147483647).unwrap();
        for x in [u64::MAX, (1 << 62) + 12345, 4611686018427387904] {
            assert_eq!(f.reduce_wide(x), x % 2147483647, "x={x}");
        }
    }

    #[test]
    fn rejects_non_primes() {
        assert!(GfPrime::new(1).is_err());
        assert!(GfPrime::new(4).is_err());
        assert!(GfPrime::new(1048575).is_err());
        assert!(GfPrime::new(1 << 32).is_err());
    }
}

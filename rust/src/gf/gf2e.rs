//! Binary extension fields `GF(2^w)`, `1 ≤ w ≤ 16`, via log/antilog tables.
//!
//! Used by the storage-flavoured examples and tests (`GF(256)` is the
//! lingua franca of erasure-coded storage). Addition is XOR;
//! multiplication is `exp[(log a + log b) mod (2^w − 1)]`.

use super::Field;
use std::sync::Arc;

/// Standard primitive polynomials (without the leading `x^w` term), indexed
/// by `w`. E.g. `w = 8` → `x^8 + x^4 + x^3 + x^2 + 1` (0x1D), the AES-adjacent
/// polynomial used by most storage systems.
const PRIMITIVE_POLY: [u32; 17] = [
    0, 0x1, 0x3, 0x3, 0x3, 0x5, 0x3, 0x3, 0x1D, 0x11, 0x9, 0x5, 0x53, 0x1B, 0x2B, 0x3, 0x2D,
];

#[derive(Debug)]
struct Tables {
    w: u32,
    /// `exp[i] = α^i` for `i ∈ [0, 2(2^w − 1))` (doubled to skip a mod).
    exp: Vec<u16>,
    /// `log[a]` for `a ∈ [1, 2^w)`; `log[0]` unused.
    log: Vec<u32>,
}

/// `GF(2^w)` with `α` = root of the primitive polynomial (element `2`).
#[derive(Clone)]
pub struct Gf2e {
    t: Arc<Tables>,
}

impl std::fmt::Debug for Gf2e {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "GF(2^{})", self.t.w)
    }
}

impl Gf2e {
    /// Construct `GF(2^w)` for `1 ≤ w ≤ 16`.
    pub fn new(w: u32) -> anyhow::Result<Self> {
        anyhow::ensure!((1..=16).contains(&w), "gf2e width must be in 1..=16");
        let order = 1u32 << w;
        let mask = order - 1; // 2^w − 1, the multiplicative group order
        let poly = PRIMITIVE_POLY[w as usize];
        let mut exp = vec![0u16; 2 * mask as usize + 2];
        let mut log = vec![0u32; order as usize];
        let mut x = 1u32;
        let mut seen = vec![false; order as usize];
        for i in 0..mask {
            anyhow::ensure!(!seen[x as usize], "polynomial for w={w} is not primitive");
            seen[x as usize] = true;
            exp[i as usize] = x as u16;
            log[x as usize] = i;
            x <<= 1;
            if x & order != 0 {
                x = (x ^ order) ^ poly;
            }
        }
        anyhow::ensure!(x == 1, "polynomial for w={w} is not primitive");
        for i in 0..=mask {
            exp[(mask + i) as usize] = exp[i as usize];
        }
        Ok(Gf2e {
            t: Arc::new(Tables { w, exp, log }),
        })
    }

    /// Field width `w`.
    pub fn width(&self) -> u32 {
        self.t.w
    }

    /// Packed-kernel hook: `log a` for a **nonzero** element (`log[0]`
    /// is an unused table slot — callers guard zero themselves). Lets
    /// `gf/kernels.rs` hoist `log c` out of its narrow-lane loops.
    #[inline(always)]
    pub(crate) fn log_of(&self, a: u64) -> u32 {
        self.t.log[a as usize]
    }

    /// Packed-kernel hook: raw exp-table read, valid for any index below
    /// `2(2^w − 1)` — i.e. for any sum of two logs.
    #[inline(always)]
    pub(crate) fn exp_at(&self, i: u32) -> u16 {
        self.t.exp[i as usize]
    }

    /// SIMD-gather hook: the whole log table, one `u32` entry per field
    /// element. `log[0]` is an unused-but-zero slot, so a vector gather
    /// over a block of symbols that happens to contain zeros stays in
    /// bounds (`0 + log c ≤ 2^w − 2`); the gathered garbage product is
    /// masked off by the caller.
    #[inline(always)]
    pub(crate) fn log_table(&self) -> &[u32] {
        &self.t.log
    }

    /// SIMD-gather hook: the doubled exp table (`len = 2(2^w − 1) + 2`).
    /// The largest index any log-sum gather can form is `2(2^w − 2)`,
    /// so even a 32-bit gather of this `u16` table's last reachable
    /// entry reads inside the allocation — no padding lane needed.
    #[inline(always)]
    pub(crate) fn exp_table(&self) -> &[u16] {
        &self.t.exp
    }
}

impl Field for Gf2e {
    #[inline]
    fn order(&self) -> u64 {
        1u64 << self.t.w
    }

    #[inline(always)]
    fn add(&self, a: u64, b: u64) -> u64 {
        a ^ b
    }

    #[inline(always)]
    fn sub(&self, a: u64, b: u64) -> u64 {
        a ^ b // characteristic 2
    }

    #[inline(always)]
    fn neg(&self, a: u64) -> u64 {
        a
    }

    #[inline(always)]
    fn mul(&self, a: u64, b: u64) -> u64 {
        if a == 0 || b == 0 {
            return 0;
        }
        let t = &*self.t;
        t.exp[(t.log[a as usize] + t.log[b as usize]) as usize] as u64
    }

    fn inv(&self, a: u64) -> u64 {
        assert!(a != 0, "division by zero in GF(2^{})", self.t.w);
        let t = &*self.t;
        let mask = (1u32 << t.w) - 1;
        t.exp[((mask - t.log[a as usize]) % mask) as usize] as u64
    }

    fn generator(&self) -> u64 {
        // α itself is primitive by construction (exp table covers F*).
        2
    }

    fn elem(&self, x: u64) -> u64 {
        x & (self.order() - 1)
    }

    /// XOR accumulation never overflows — no reduction passes needed.
    fn lazy_chunk(&self) -> usize {
        usize::MAX
    }

    #[inline(always)]
    fn lazy_mul_acc(&self, acc: u64, c: u64, s: u64) -> u64 {
        acc ^ self.mul(c, s)
    }

    /// Hoisted-log axpy: `log c` is looked up once per call instead of
    /// once per element, leaving one table read + XOR per element.
    fn axpy_into(&self, acc: &mut [u64], c: u64, src: &[u64]) {
        if c == 0 {
            return;
        }
        debug_assert_eq!(acc.len(), src.len());
        let t = &*self.t;
        let log_c = t.log[c as usize];
        for (a, &s) in acc.iter_mut().zip(src) {
            if s != 0 {
                *a ^= t.exp[(log_c + t.log[s as usize]) as usize] as u64;
            }
        }
    }

    fn scale_slice(&self, dst: &mut [u64], c: u64, src: &[u64]) {
        debug_assert_eq!(dst.len(), src.len());
        if c == 0 {
            dst.fill(0);
            return;
        }
        let t = &*self.t;
        let log_c = t.log[c as usize];
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = if s == 0 {
                0
            } else {
                t.exp[(log_c + t.log[s as usize]) as usize] as u64
            };
        }
    }

    /// A linear combination over `GF(2^w)` is a sequence of hoisted-log
    /// axpys — XOR accumulation needs no reduction passes at all.
    fn lincomb_into(&self, acc: &mut [u64], terms: &[(u64, &[u64])]) {
        for &(c, src) in terms {
            self.axpy_into(acc, c, src);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_widths_build() {
        for w in 1..=16 {
            let f = Gf2e::new(w).unwrap();
            assert_eq!(f.order(), 1 << w);
            assert_eq!(f.bits(), w);
        }
    }

    #[test]
    fn gf256_known_products() {
        // Classic GF(256)/0x11D values.
        let f = Gf2e::new(8).unwrap();
        assert_eq!(f.mul(2, 128), 29); // α^8 = poly bits 0x1D
        for a in 1..256u64 {
            assert_eq!(f.mul(a, f.inv(a)), 1);
            assert_eq!(f.div(f.mul(a, 77), a), 77);
        }
    }

    #[test]
    fn field_axioms_exhaustive_gf16() {
        let f = Gf2e::new(4).unwrap();
        let n = f.order();
        for a in 0..n {
            for b in 0..n {
                assert_eq!(f.mul(a, b), f.mul(b, a));
                assert_eq!(f.add(a, b), f.add(b, a));
                for c in 0..n {
                    assert_eq!(f.mul(a, f.add(b, c)), f.add(f.mul(a, b), f.mul(a, c)));
                }
            }
            if a != 0 {
                assert_eq!(f.mul(a, f.inv(a)), 1);
            }
        }
    }

    #[test]
    fn generator_order() {
        for w in [4u32, 8, 12] {
            let f = Gf2e::new(w).unwrap();
            let g = f.generator();
            let group = f.order() - 1;
            assert_eq!(f.pow(g, group), 1);
            // α is primitive: no smaller order among proper divisors.
            for d in crate::gf::prime::prime_factors(group) {
                assert_ne!(f.pow(g, group / d), 1);
            }
        }
    }
}

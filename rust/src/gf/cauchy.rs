//! Cauchy-like matrices — eq. (24) and the Theorem 6/8 factorizations.
//!
//! The non-systematic part of a systematic GRS generator matrix is
//! *Cauchy-like*: `A_{k,r} = c_k d_r / (β_r − α_k)` (eq. (24), via
//! Roth–Seroussi), with `A = (V_α P)^{-1} V_β Q` (eq. (23)). Theorem 6
//! further factors each square block `A_m` of `A` as
//! `A_m = (V_{α,m} Φ_m)^{-1} V_β Ψ`, which is what lets §VI compute it
//! with two consecutive draw-and-loose operations.

use super::{vandermonde, Field, Mat};

/// A Cauchy-like matrix specification: `A_{k,r} = c_k d_r / (β_r − α_k)`.
#[derive(Clone, Debug)]
pub struct CauchyLike {
    /// Row points `α_0, …, α_{K−1}` (systematic evaluation points).
    pub alphas: Vec<u64>,
    /// Column points `β_0, …, β_{R−1}` (parity evaluation points).
    pub betas: Vec<u64>,
    /// Row multipliers `u_0, …, u_{K−1}` (all 1 for Lagrange matrices).
    pub u: Vec<u64>,
    /// Column multipliers `v_0, …, v_{R−1}`.
    pub v: Vec<u64>,
}

impl CauchyLike {
    /// A Lagrange matrix `L_{α,β} = V_α^{-1} V_β` (Remark 9): `u = v = 1`.
    pub fn lagrange<F: Field>(f: &F, alphas: Vec<u64>, betas: Vec<u64>) -> Self {
        let (k, r) = (alphas.len(), betas.len());
        CauchyLike {
            alphas,
            betas,
            u: vec![f.one(); k],
            v: vec![f.one(); r],
        }
    }

    pub fn k(&self) -> usize {
        self.alphas.len()
    }

    pub fn r(&self) -> usize {
        self.betas.len()
    }

    /// All points distinct (required: `β_r ≠ α_k` keeps entries finite and
    /// distinctness within each family keeps the Vandermondes invertible).
    pub fn points_valid(&self) -> bool {
        let all: Vec<u64> = self.alphas.iter().chain(&self.betas).copied().collect();
        vandermonde::points_distinct(&all)
    }

    /// The row factor `c_k = u_k^{-1} / ∏_{t≠k}(α_k − α_t)` of eq. (24).
    pub fn c<F: Field>(&self, f: &F, k: usize) -> u64 {
        let mut prod = f.one();
        for (t, &at) in self.alphas.iter().enumerate() {
            if t != k {
                prod = f.mul(prod, f.sub(self.alphas[k], at));
            }
        }
        f.div(f.inv(self.u[k]), prod)
    }

    /// The column factor `d_r = v_r ∏_k (β_r − α_k)` of eq. (24).
    pub fn d<F: Field>(&self, f: &F, r: usize) -> u64 {
        let mut prod = self.v[r];
        for &ak in &self.alphas {
            prod = f.mul(prod, f.sub(self.betas[r], ak));
        }
        prod
    }

    /// Materialise `A` entry-wise from eq. (24).
    pub fn to_mat<F: Field>(&self, f: &F) -> Mat {
        let cs: Vec<u64> = (0..self.k()).map(|k| self.c(f, k)).collect();
        let ds: Vec<u64> = (0..self.r()).map(|r| self.d(f, r)).collect();
        Mat::from_fn(self.k(), self.r(), |k, r| {
            let denom = f.sub(self.betas[r], self.alphas[k]);
            f.div(f.mul(cs[k], ds[r]), denom)
        })
    }

    /// Materialise `A = (V_α · diag(u))^{-1} · V_β · diag(v)` from eq. (23)
    /// — the definition the eq. (24) closed form is checked against.
    pub fn to_mat_by_definition<F: Field>(&self, f: &F) -> Mat {
        let k = self.k();
        let va_inv = vandermonde::inverse(f, &self.alphas);
        // (V_α · diag(u))^{-1} = diag(u)^{-1} · V_α^{-1}
        let uinv: Vec<u64> = self.u.iter().map(|&x| f.inv(x)).collect();
        let vb = vandermonde::vandermonde(f, k, &self.betas);
        va_inv
            .diag_mul(f, &uinv)
            .mul(f, &vb)
            .mul_diag(f, &self.v)
    }

    /// Theorem 6 row factor `φ_{m,s}` (eq. (26)) for block `m` of size `R`:
    /// `φ_{m,s} = u_{mR+s} ∏_{j ∉ S_m} (α_{mR+s} − α_j)`.
    pub fn phi<F: Field>(&self, f: &F, m: usize, s: usize, r_block: usize) -> u64 {
        let i = m * r_block + s;
        let block = m * r_block..(m + 1) * r_block;
        let mut prod = self.u[i];
        for (j, &aj) in self.alphas.iter().enumerate() {
            if !block.contains(&j) {
                prod = f.mul(prod, f.sub(self.alphas[i], aj));
            }
        }
        prod
    }

    /// Theorem 6 column factor `ψ_r` (eq. (27)) for block `m`:
    /// `ψ_r = v_r ∏_{j ∉ S_m} (β_r − α_j)`.
    pub fn psi<F: Field>(&self, f: &F, m: usize, r: usize, r_block: usize) -> u64 {
        let block = m * r_block..(m + 1) * r_block;
        let mut prod = self.v[r];
        for (j, &aj) in self.alphas.iter().enumerate() {
            if !block.contains(&j) {
                prod = f.mul(prod, f.sub(self.betas[r], aj));
            }
        }
        prod
    }

    /// Theorem 8 (K < R case): `A_m = (diag(u)·V_α)^{-1} V_{β,m} diag(v_m)`
    /// where block `m` takes parity points `T_m = [mK, (m+1)K)`. Returns
    /// the `K × K` block directly.
    pub fn block_k_lt_r(&self, m: usize) -> CauchyLike {
        let k = self.k();
        CauchyLike {
            alphas: self.alphas.clone(),
            betas: self.betas[m * k..(m + 1) * k].to_vec(),
            u: self.u.clone(),
            v: self.v[m * k..(m + 1) * k].to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::{Field, GfPrime};

    fn f() -> GfPrime {
        GfPrime::new(786433).unwrap()
    }

    fn sample(k: usize, r: usize) -> CauchyLike {
        let f = f();
        CauchyLike {
            alphas: (1..=k as u64).collect(),
            betas: (1000..1000 + r as u64).collect(),
            u: (1..=k as u64).map(|i| f.elem(i * 7 + 1)).collect(),
            v: (1..=r as u64).map(|i| f.elem(i * 13 + 2)).collect(),
        }
    }

    #[test]
    fn closed_form_matches_definition() {
        // eq. (24) (Roth–Seroussi) vs eq. (23) (definition).
        let f = f();
        for (k, r) in [(4, 4), (6, 3), (3, 6), (8, 8)] {
            let c = sample(k, r);
            assert!(c.points_valid());
            assert_eq!(c.to_mat(&f), c.to_mat_by_definition(&f), "k={k} r={r}");
        }
    }

    #[test]
    fn theorem6_factorization() {
        // A_m == (V_{α,m} Φ_m)^{-1} V_β Ψ_m for every block m (K = M·R).
        let f = f();
        let (k, r) = (12, 4);
        let c = sample(k, r);
        let a = c.to_mat(&f);
        for m in 0..k / r {
            let block = a.block(m * r, 0, r, r);
            let alpha_m = &c.alphas[m * r..(m + 1) * r];
            let phi: Vec<u64> = (0..r).map(|s| c.phi(&f, m, s, r)).collect();
            let psi: Vec<u64> = (0..r).map(|rr| c.psi(&f, m, rr, r)).collect();
            let va_inv = vandermonde::inverse(&f, alpha_m);
            let phinv: Vec<u64> = phi.iter().map(|&x| f.inv(x)).collect();
            let vb = vandermonde::square(&f, &c.betas);
            let reconstructed = va_inv.diag_mul(&f, &phinv).mul(&f, &vb).mul_diag(&f, &psi);
            assert_eq!(block, reconstructed, "block {m}");
        }
    }

    #[test]
    fn theorem8_blocks() {
        // K < R: concatenated blocks are Cauchy-like on parity sub-ranges.
        let f = f();
        let (k, r) = (4, 12);
        let c = sample(k, r);
        let a = c.to_mat(&f);
        for m in 0..r / k {
            let block = a.block(0, m * k, k, k);
            assert_eq!(block, c.block_k_lt_r(m).to_mat(&f), "block {m}");
        }
    }

    #[test]
    fn lagrange_matrix_is_interpolation_then_evaluation() {
        let f = f();
        let alphas: Vec<u64> = (1..=5).collect();
        let betas: Vec<u64> = (100..105).collect();
        let l = CauchyLike::lagrange(&f, alphas.clone(), betas.clone()).to_mat(&f);
        // x·L should equal evaluating at β the degree-<5 interpolant of
        // (α_k, x_k).
        let x = [3u64, 1, 4, 1, 5];
        let y = l.vec_mul(&f, &x);
        let g = crate::gf::poly::interpolate(&f, &alphas, &x);
        for (j, &b) in betas.iter().enumerate() {
            assert_eq!(y[j], crate::gf::poly::eval(&f, &g, b));
        }
    }
}

//! Explicit-SIMD ISA tiers for the packed field kernels.
//!
//! [`Kernels`](crate::gf::kernels::Kernels) resolves one [`IsaTier`] per
//! compiled plan and threads it into every packed inner loop; the tier
//! decides whether a loop runs the portable scalar code (the PR 5
//! autovectorized kernels — retained as the bit-identity oracle and the
//! fallback on every host) or an explicit vector path from one of the
//! per-arch submodules:
//!
//! * [`x86`] (x86_64, AVX2 at runtime): `GF(2^8)` products as two
//!   `_mm256_shuffle_epi8` nibble-table lookups, `GF(2^w ≤ 16)` as
//!   gathered hoisted-log lanes, prime-field delayed reduction as
//!   `u64x4` fma tiles;
//! * [`neon`] (aarch64, baseline): `GF(2^8)` via `vqtbl1q_u8`.
//!
//! **Dispatch hierarchy.** `scalar` runs everywhere. The widest vector
//! tier is detected once per process ([`IsaTier::widest`]); any other
//! vector tier *requested* (config `isa = "…"`, `DCE_FORCE_ISA`)
//! degrades to scalar via [`IsaTier::clamp_supported`] — a tier value
//! can therefore never name instructions the host cannot execute, which
//! is the safety argument for every `unsafe` call into the submodules.
//!
//! GFNI is deliberately **not** a tier: the `_mm*_gf2p8*` intrinsics
//! both post-date this crate's MSRV and hard-wire the AES polynomial
//! `0x11B`, while this crate's `GF(2^8)` is built on `0x11D` — the
//! nibble-shuffle path is the portable-polynomial AVX2 optimum.
//! See `DESIGN.md §9`.

use std::sync::OnceLock;

#[cfg(target_arch = "aarch64")]
pub(crate) mod neon;
#[cfg(target_arch = "x86_64")]
pub(crate) mod x86;

/// An instruction-set tier the packed kernels can dispatch to. Ordered
/// by width: `Scalar` is the portable fallback, the vector tiers are
/// only ever constructed on hosts that can execute them (see
/// [`clamp_supported`](IsaTier::clamp_supported)).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum IsaTier {
    /// Portable scalar/autovectorized loops — every host, and the
    /// bit-identity oracle for the vector tiers.
    Scalar,
    /// 256-bit AVX2 paths (x86_64, runtime-detected).
    Avx2,
    /// 128-bit NEON paths (aarch64 baseline).
    Neon,
}

impl IsaTier {
    /// Lowercase tier name (metrics labels, `PlanProfile`, bench JSON).
    pub fn name(self) -> &'static str {
        match self {
            IsaTier::Scalar => "scalar",
            IsaTier::Avx2 => "avx2",
            IsaTier::Neon => "neon",
        }
    }

    /// The widest tier this host can execute: runtime feature detection
    /// on x86_64, the baseline guarantee on aarch64, scalar elsewhere.
    pub fn widest() -> IsaTier {
        widest_arch()
    }

    /// The process-wide default tier, cached after first use:
    /// `DCE_FORCE_ISA` when set and non-empty (an unrecognized value
    /// falls back to scalar with a warning — safe, never UB), otherwise
    /// [`widest`](IsaTier::widest).
    pub fn detect() -> IsaTier {
        static DETECTED: OnceLock<IsaTier> = OnceLock::new();
        *DETECTED.get_or_init(|| match std::env::var("DCE_FORCE_ISA") {
            Ok(v) if !v.is_empty() => match v.parse::<IsaRequest>() {
                Ok(req) => IsaTier::resolve(req),
                Err(_) => {
                    eprintln!(
                        "DCE_FORCE_ISA={v:?} is not a recognized tier \
                         (scalar|avx2|neon|native); using scalar kernels"
                    );
                    IsaTier::Scalar
                }
            },
            _ => IsaTier::widest(),
        })
    }

    /// Clamp to a tier whose instructions this host can execute: scalar
    /// and the detected widest tier pass through, anything else
    /// degrades to scalar. Every constructor of a [`Kernels`] tier runs
    /// through this, so a hand-built `Avx2` on a non-AVX2 host serves
    /// scalar kernels instead of reaching an illegal instruction.
    pub fn clamp_supported(self) -> IsaTier {
        if self == IsaTier::Scalar || self == Self::widest() {
            self
        } else {
            IsaTier::Scalar
        }
    }

    /// Resolve a requested tier against this host: `native` means the
    /// widest supported tier; explicit tiers are honored when supported
    /// and degrade to scalar otherwise.
    pub fn resolve(req: IsaRequest) -> IsaTier {
        match req {
            IsaRequest::Scalar => IsaTier::Scalar,
            IsaRequest::Native => IsaTier::widest(),
            IsaRequest::Avx2 => IsaTier::Avx2.clamp_supported(),
            IsaRequest::Neon => IsaTier::Neon.clamp_supported(),
        }
    }

    /// Every tier executable on this host: scalar, plus the widest
    /// vector tier when there is one. Test suites and benches sweep
    /// this to pin vector ≡ scalar bit-identity per tier.
    pub fn available() -> Vec<IsaTier> {
        let mut tiers = vec![IsaTier::Scalar];
        if Self::widest() != IsaTier::Scalar {
            tiers.push(Self::widest());
        }
        tiers
    }
}

#[cfg(target_arch = "x86_64")]
fn widest_arch() -> IsaTier {
    if is_x86_feature_detected!("avx2") {
        IsaTier::Avx2
    } else {
        IsaTier::Scalar
    }
}

#[cfg(target_arch = "aarch64")]
fn widest_arch() -> IsaTier {
    IsaTier::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn widest_arch() -> IsaTier {
    IsaTier::Scalar
}

/// A *requested* tier, as written in a job config (`isa = "…"`) or
/// `DCE_FORCE_ISA` — kept distinct from [`IsaTier`] because `native`
/// names a policy ("widest this host has"), not an instruction set, and
/// because requests are resolved per host via [`IsaTier::resolve`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum IsaRequest {
    Scalar,
    Avx2,
    Neon,
    /// The widest tier the serving host supports.
    Native,
}

impl std::str::FromStr for IsaRequest {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "scalar" => IsaRequest::Scalar,
            "avx2" => IsaRequest::Avx2,
            "neon" => IsaRequest::Neon,
            "native" => IsaRequest::Native,
            other => anyhow::bail!("unknown ISA tier {other:?} (expected scalar|avx2|neon|native)"),
        })
    }
}

impl std::fmt::Display for IsaRequest {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            IsaRequest::Scalar => "scalar",
            IsaRequest::Avx2 => "avx2",
            IsaRequest::Neon => "neon",
            IsaRequest::Native => "native",
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_parse_roundtrip_and_rejects_junk() {
        for (s, want) in [
            ("scalar", IsaRequest::Scalar),
            ("avx2", IsaRequest::Avx2),
            ("neon", IsaRequest::Neon),
            ("native", IsaRequest::Native),
        ] {
            let req: IsaRequest = s.parse().unwrap();
            assert_eq!(req, want);
            assert_eq!(req.to_string(), s);
        }
        assert!("sse9".parse::<IsaRequest>().is_err());
        assert!("".parse::<IsaRequest>().is_err());
    }

    #[test]
    fn clamp_only_ever_downgrades_to_executable_tiers() {
        let widest = IsaTier::widest();
        assert_eq!(IsaTier::Scalar.clamp_supported(), IsaTier::Scalar);
        assert_eq!(widest.clamp_supported(), widest);
        for tier in [IsaTier::Avx2, IsaTier::Neon] {
            let clamped = tier.clamp_supported();
            assert!(
                clamped == tier && tier == widest || clamped == IsaTier::Scalar,
                "{tier:?} clamped to {clamped:?} with widest {widest:?}"
            );
        }
    }

    #[test]
    fn resolve_maps_native_to_widest_and_respects_support() {
        assert_eq!(IsaTier::resolve(IsaRequest::Scalar), IsaTier::Scalar);
        assert_eq!(IsaTier::resolve(IsaRequest::Native), IsaTier::widest());
        for req in [IsaRequest::Avx2, IsaRequest::Neon] {
            let tier = IsaTier::resolve(req);
            assert!(IsaTier::available().contains(&tier), "{req:?} -> {tier:?}");
        }
    }

    #[test]
    fn available_lists_scalar_first_and_detect_stays_inside_it() {
        let tiers = IsaTier::available();
        assert_eq!(tiers[0], IsaTier::Scalar);
        assert!(tiers.contains(&IsaTier::widest()));
        assert!(tiers.len() <= 2);
        // Whatever DCE_FORCE_ISA says (CI's forced-tier matrix sets it
        // for whole test runs), the cached default is executable here.
        assert!(tiers.contains(&IsaTier::detect()));
    }

    #[test]
    fn tier_names_are_stable_labels() {
        assert_eq!(IsaTier::Scalar.name(), "scalar");
        assert_eq!(IsaTier::Avx2.name(), "avx2");
        assert_eq!(IsaTier::Neon.name(), "neon");
    }
}

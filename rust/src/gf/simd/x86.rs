//! AVX2 kernels for the packed field inner loops (x86_64).
//!
//! Every function here is `#[target_feature(enable = "avx2")]` and
//! therefore `unsafe` to call: the caller must have proved AVX2 is
//! available, which in this crate always means the call is guarded by
//! an [`IsaTier::Avx2`](super::IsaTier) value — constructible only
//! after runtime detection (`IsaTier::clamp_supported`). All loads and
//! stores are unaligned (`loadu`/`storeu`); the columnar arena's
//! tile-padded stride (`PackedPacketBuf::pack_columnar`) merely
//! guarantees whole rows are a multiple of one 32-byte tile so the
//! vector loop covers full rows, with the in-function scalar tails
//! handling ragged lengths from other call sites.
//!
//! Bit-identity with the scalar kernels is by construction, not by
//! rounding luck: GF(2) tiers XOR exact table products, and the prime
//! fma tiles do the same exact `u64` adds in the same per-lane order as
//! the scalar delayed-reduction loop.

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

/// `acc[i] ^= c·src[i]` over GF(2^w ≤ 8), 32 lanes per step, with `c`
/// pre-expanded into its two operand-nibble shuffle tables
/// (`tlo[j] = c·j`, `thi[j] = c·(j≪4)`, see
/// `Gf2eNibble::operand_tables`): the product of a symbol `s` is
/// `tlo[s & 15] ⊕ thi[s ≫ 4]`, two `vpshufb`s and one XOR.
///
/// # Safety
/// Caller must guarantee the CPU supports AVX2. `acc` and `src` must
/// have equal lengths (debug-asserted).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn gf256_axpy_avx2(
    acc: &mut [u8],
    src: &[u8],
    tlo: &[u8; 16],
    thi: &[u8; 16],
) {
    debug_assert_eq!(acc.len(), src.len());
    let n = acc.len();
    let vlo = _mm256_broadcastsi128_si256(_mm_loadu_si128(tlo.as_ptr() as *const __m128i));
    let vhi = _mm256_broadcastsi128_si256(_mm_loadu_si128(thi.as_ptr() as *const __m128i));
    let nib = _mm256_set1_epi8(0x0f);
    let mut i = 0;
    while i + 32 <= n {
        let s = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
        let lo_idx = _mm256_and_si256(s, nib);
        let hi_idx = _mm256_and_si256(_mm256_srli_epi16::<4>(s), nib);
        let prod = _mm256_xor_si256(
            _mm256_shuffle_epi8(vlo, lo_idx),
            _mm256_shuffle_epi8(vhi, hi_idx),
        );
        let a = _mm256_loadu_si256(acc.as_ptr().add(i) as *const __m256i);
        _mm256_storeu_si256(
            acc.as_mut_ptr().add(i) as *mut __m256i,
            _mm256_xor_si256(a, prod),
        );
        i += 32;
    }
    while i < n {
        let s = src[i];
        acc[i] ^= tlo[(s & 0x0f) as usize] ^ thi[(s >> 4) as usize];
        i += 1;
    }
}

/// `acc[i] ^= c·src[i]` over GF(2^w ≤ 16) via gathered hoisted-log
/// lanes: 16 symbols per step are widened to two 8×u32 halves, their
/// logs gathered from `log`, biased by `log_c`, the products gathered
/// back from the doubled `exp` table, re-narrowed and XORed in. Zero
/// lanes are masked out after the gathers (`log[0] = 0` keeps their
/// gather indices in bounds; the mask discards the bogus product).
///
/// # Safety
/// Caller must guarantee the CPU supports AVX2. `acc`/`src` must have
/// equal lengths; `log` must have one entry per field element, `exp`
/// must be the doubled table (length ≥ 2·(order−1)), and `log_c` must
/// be the log of a non-zero coefficient — exactly the `Gf2e` table
/// layout (`log_table`/`exp_table`), whose bounds proof lives with the
/// tables.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn gf2e_wide_axpy_avx2(
    acc: &mut [u16],
    src: &[u16],
    log: &[u32],
    exp: &[u16],
    log_c: u32,
) {
    debug_assert_eq!(acc.len(), src.len());
    let n = acc.len();
    let zero = _mm256_setzero_si256();
    let vlogc = _mm256_set1_epi32(log_c as i32);
    let mask16 = _mm256_set1_epi32(0xffff);
    let log_ptr = log.as_ptr() as *const i32;
    let exp_ptr = exp.as_ptr() as *const i32;
    let mut i = 0;
    while i + 16 <= n {
        let s = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
        let zmask = _mm256_cmpeq_epi16(s, zero);
        let s_lo = _mm256_cvtepu16_epi32(_mm256_castsi256_si128(s));
        let s_hi = _mm256_cvtepu16_epi32(_mm256_extracti128_si256::<1>(s));
        let i_lo = _mm256_add_epi32(_mm256_i32gather_epi32::<4>(log_ptr, s_lo), vlogc);
        let i_hi = _mm256_add_epi32(_mm256_i32gather_epi32::<4>(log_ptr, s_hi), vlogc);
        // The exp table is u16; gather 32-bit and mask the upper half.
        let e_lo = _mm256_and_si256(_mm256_i32gather_epi32::<2>(exp_ptr, i_lo), mask16);
        let e_hi = _mm256_and_si256(_mm256_i32gather_epi32::<2>(exp_ptr, i_hi), mask16);
        // packus interleaves the two 128-bit lanes; permute restores
        // element order. Saturation never triggers (values ≤ 0xffff).
        let packed = _mm256_permute4x64_epi64::<0b1101_1000>(_mm256_packus_epi32(e_lo, e_hi));
        let prod = _mm256_andnot_si256(zmask, packed);
        let a = _mm256_loadu_si256(acc.as_ptr().add(i) as *const __m256i);
        _mm256_storeu_si256(
            acc.as_mut_ptr().add(i) as *mut __m256i,
            _mm256_xor_si256(a, prod),
        );
        i += 16;
    }
    while i < n {
        let s = src[i];
        if s != 0 {
            acc[i] ^= exp[(log_c + log[s as usize]) as usize];
        }
        i += 1;
    }
}

/// `scratch[j] += c·src[j]` with u32 lanes widened into the u64
/// delayed-reduction scratch, 4 lanes per step. `_mm256_mul_epu32`
/// multiplies the low 32 bits of each 64-bit lane — exact here because
/// `c < 2^31` (prime moduli fit i32) and `src` lanes are ≤ 32 bits, so
/// products stay below 2^63 and the adds below the scalar loop's own
/// overflow headroom (`Field::lazy_chunk` bounds the run length).
///
/// # Safety
/// Caller must guarantee the CPU supports AVX2; `scratch`/`src` must
/// have equal lengths and `c < 2^32`.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn prime_fma_u32_avx2(scratch: &mut [u64], c: u64, src: &[u32]) {
    debug_assert_eq!(scratch.len(), src.len());
    let n = scratch.len();
    let vc = _mm256_set1_epi64x(c as i64);
    let mut i = 0;
    while i + 4 <= n {
        let x = _mm256_cvtepu32_epi64(_mm_loadu_si128(src.as_ptr().add(i) as *const __m128i));
        let a = _mm256_loadu_si256(scratch.as_ptr().add(i) as *const __m256i);
        let prod = _mm256_mul_epu32(vc, x);
        _mm256_storeu_si256(
            scratch.as_mut_ptr().add(i) as *mut __m256i,
            _mm256_add_epi64(a, prod),
        );
        i += 4;
    }
    while i < n {
        scratch[i] += c * src[i] as u64;
        i += 1;
    }
}

/// `scratch[j] += c·src[j]` for u16 lanes — see [`prime_fma_u32_avx2`].
///
/// # Safety
/// Caller must guarantee the CPU supports AVX2; `scratch`/`src` must
/// have equal lengths and `c < 2^32`.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn prime_fma_u16_avx2(scratch: &mut [u64], c: u64, src: &[u16]) {
    debug_assert_eq!(scratch.len(), src.len());
    let n = scratch.len();
    let vc = _mm256_set1_epi64x(c as i64);
    let mut i = 0;
    while i + 4 <= n {
        let x = _mm256_cvtepu16_epi64(_mm_loadl_epi64(src.as_ptr().add(i) as *const __m128i));
        let a = _mm256_loadu_si256(scratch.as_ptr().add(i) as *const __m256i);
        let prod = _mm256_mul_epu32(vc, x);
        _mm256_storeu_si256(
            scratch.as_mut_ptr().add(i) as *mut __m256i,
            _mm256_add_epi64(a, prod),
        );
        i += 4;
    }
    while i < n {
        scratch[i] += c * src[i] as u64;
        i += 1;
    }
}

/// `scratch[j] += c·src[j]` for u8 lanes — see [`prime_fma_u32_avx2`].
///
/// # Safety
/// Caller must guarantee the CPU supports AVX2; `scratch`/`src` must
/// have equal lengths and `c < 2^32`.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn prime_fma_u8_avx2(scratch: &mut [u64], c: u64, src: &[u8]) {
    debug_assert_eq!(scratch.len(), src.len());
    let n = scratch.len();
    let vc = _mm256_set1_epi64x(c as i64);
    let mut i = 0;
    while i + 4 <= n {
        let quad = (src.as_ptr().add(i) as *const u32).read_unaligned();
        let x = _mm256_cvtepu8_epi64(_mm_cvtsi32_si128(quad as i32));
        let a = _mm256_loadu_si256(scratch.as_ptr().add(i) as *const __m256i);
        let prod = _mm256_mul_epu32(vc, x);
        _mm256_storeu_si256(
            scratch.as_mut_ptr().add(i) as *mut __m256i,
            _mm256_add_epi64(a, prod),
        );
        i += 4;
    }
    while i < n {
        scratch[i] += c * src[i] as u64;
        i += 1;
    }
}

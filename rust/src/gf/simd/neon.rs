//! NEON kernels for the packed field inner loops (aarch64).
//!
//! NEON is baseline on aarch64, so no runtime detection is needed; the
//! functions are still `unsafe` + `#[target_feature]` for symmetry with
//! the AVX2 module and to keep the call-site contract identical. Only
//! the GF(2^8) nibble-shuffle path is accelerated here — `vqtbl1q_u8`
//! is the exact NEON analogue of `vpshufb`. The wide-gf2e gather and
//! prime fma loops stay on the portable scalar code on this arch (NEON
//! has no gather, and LLVM already autovectorizes the u64 fma scratch
//! loop well on aarch64); see `DESIGN.md §9`.

#[cfg(target_arch = "aarch64")]
use std::arch::aarch64::*;

/// `acc[i] ^= c·src[i]` over GF(2^w ≤ 8), 16 lanes per step, with `c`
/// pre-expanded into its operand-nibble tables (`tlo[j] = c·j`,
/// `thi[j] = c·(j≪4)`): the product of a symbol `s` is
/// `tlo[s & 15] ⊕ thi[s ≫ 4]`, two `vqtbl1q_u8` lookups and one XOR.
///
/// # Safety
/// NEON must be available (baseline on aarch64). `acc` and `src` must
/// have equal lengths (debug-asserted).
#[target_feature(enable = "neon")]
pub(crate) unsafe fn gf256_axpy_neon(
    acc: &mut [u8],
    src: &[u8],
    tlo: &[u8; 16],
    thi: &[u8; 16],
) {
    debug_assert_eq!(acc.len(), src.len());
    let n = acc.len();
    let vlo = vld1q_u8(tlo.as_ptr());
    let vhi = vld1q_u8(thi.as_ptr());
    let nib = vdupq_n_u8(0x0f);
    let mut i = 0;
    while i + 16 <= n {
        let s = vld1q_u8(src.as_ptr().add(i));
        let lo_idx = vandq_u8(s, nib);
        let hi_idx = vshrq_n_u8::<4>(s);
        let prod = veorq_u8(vqtbl1q_u8(vlo, lo_idx), vqtbl1q_u8(vhi, hi_idx));
        let a = vld1q_u8(acc.as_ptr().add(i));
        vst1q_u8(acc.as_mut_ptr().add(i), veorq_u8(a, prod));
        i += 16;
    }
    while i < n {
        let s = src[i];
        acc[i] ^= tlo[(s & 0x0f) as usize] ^ thi[(s >> 4) as usize];
        i += 1;
    }
}

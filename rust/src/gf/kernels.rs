//! Packed-symbol storage and per-field vectorized kernels — the serving
//! hot path's answer to the paper's `⌈log2 q⌉` accounting.
//!
//! The cost model charges every wire symbol `⌈log2 q⌉` bits
//! (`C = α·C1 + β⌈log2 q⌉·C2`), yet canonical storage spends a full
//! `u64` per element: 8× over-provisioned for `GF(2^8)`, ~3× for the
//! default 20-bit prime. On the batched replay path — a pure
//! `OutputMatrix · arena` streaming workload — memory bandwidth is the
//! binding resource, so this module provides
//!
//! * [`SymbolLayout`] — the narrow lane type (`u8`/`u16`/`u32`/`u64`)
//!   chosen from [`Field::bits`],
//! * [`PackedBuf`] — canonical `u64` symbols packed into one narrow-lane
//!   allocation (pack/unpack are pure width casts: canonical elements
//!   always fit their layout's lane),
//! * [`Kernels`] — a per-field kernel vtable resolved **once per plan**
//!   ([`Kernels::for_field`]), providing fused `axpy` / `lincomb` /
//!   `gemm_rows` over packed slices with *monomorphic* inner loops — no
//!   per-element [`AnyField`] dispatch anywhere on the hot path.
//!
//! Kernel selection (scalar column = the portable loops, always
//! compiled; the [`IsaTier`](crate::gf::simd::IsaTier) resolved with the
//! vtable upgrades the inner step to an explicit vector path from
//! [`crate::gf::simd`] where one exists — same exact field values, see
//! the bit-identity note below):
//!
//! | field | layout | scalar inner loop | vector inner loop |
//! |---|---|---|---|
//! | `GF(2^w)`, `w ≤ 8` | `u8` | two 16×256 nibble-split product tables (8 KB, L1-resident): `c·x = lo[c&15][x] ⊕ hi[c≫4][x]` — one XOR of two byte loads per element | AVX2 `vpshufb` / NEON `vqtbl1q_u8` over 16-entry operand-nibble tables: 32 (16) products per step |
//! | `GF(2^w)`, `8 < w ≤ 16` | `u16` | hoisted-log axpy (`log c` read once per row) over `u16` lanes | AVX2 gathered log/exp lanes, 16 symbols per step |
//! | `F_p` (`p < 2^31`) | from `bits()` | delayed reduction: raw `c·s` products accumulate in a `u64` scratch tile, one Barrett pass per [`Field::lazy_chunk`] terms, lanes only loaded/stored narrow | AVX2 `u64x4` fma tiles for the scratch accumulation ([`Lane::fma_wide`]); reductions stay scalar |
//! | anything else | `u64` | the [`Field`] trait's own fused kernels, behind one virtual call per row | — (tier pinned to scalar) |
//!
//! **Bit-identity.** Every kernel computes the exact field value of the
//! same linear combination, and canonical representatives are unique —
//! so unpacking a packed result yields the same `u64`s as the scalar
//! path, bit for bit, regardless of lane width or reduction schedule.
//! `tests/kernels.rs` asserts this exhaustively for `GF(2^8)` and with
//! seeded sweeps elsewhere; `tests/plan_opt.rs` asserts it end-to-end
//! through `replay_batch` for every A2A variant.

use super::matrix::GEMM_TILE;
use super::simd::IsaTier;
use super::{AnyField, Field, Gf2e, GfPrime};
use std::sync::Arc;

/// The wire-faithful storage width of one field symbol.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SymbolLayout {
    U8,
    U16,
    U32,
    U64,
}

impl SymbolLayout {
    /// The narrowest lane holding every canonical element of a field
    /// with `⌈log2 q⌉ = bits` — the layout-selection rule.
    pub fn for_bits(bits: u32) -> Self {
        match bits {
            0..=8 => SymbolLayout::U8,
            9..=16 => SymbolLayout::U16,
            17..=32 => SymbolLayout::U32,
            _ => SymbolLayout::U64,
        }
    }

    /// Bytes per stored symbol.
    pub fn bytes(self) -> usize {
        match self {
            SymbolLayout::U8 => 1,
            SymbolLayout::U16 => 2,
            SymbolLayout::U32 => 4,
            SymbolLayout::U64 => 8,
        }
    }

    /// Lowercase lane name (bench/report labels).
    pub fn name(self) -> &'static str {
        match self {
            SymbolLayout::U8 => "u8",
            SymbolLayout::U16 => "u16",
            SymbolLayout::U32 => "u32",
            SymbolLayout::U64 => "u64",
        }
    }
}

/// A lane type symbols are stored in. `from_u64` is a plain truncation
/// — callers pack canonical elements only, which always fit.
trait Lane: Copy + Send + Sync + 'static {
    fn to_u64(self) -> u64;
    fn from_u64(x: u64) -> Self;

    /// `scratch[j] += c·src[j]` with lanes widened into the `u64`
    /// delayed-reduction scratch — the inner step of [`prime_gemm_row`].
    /// The default is the portable loop; the narrow lanes override it
    /// with an explicit AVX2 tile behind the given ISA tier. Either way
    /// the per-lane adds are the same exact integers in the same order,
    /// so delayed-reduction results stay bit-identical across tiers.
    #[inline(always)]
    fn fma_wide(isa: IsaTier, scratch: &mut [u64], c: u64, src: &[Self]) {
        let _ = isa;
        for (s, &x) in scratch.iter_mut().zip(src) {
            *s += c * x.to_u64();
        }
    }
}

macro_rules! impl_lane_narrow {
    ($($t:ty => $fma:ident),*) => {$(
        impl Lane for $t {
            #[inline(always)]
            fn to_u64(self) -> u64 {
                self as u64
            }
            #[inline(always)]
            fn from_u64(x: u64) -> Self {
                debug_assert!(x <= <$t>::MAX as u64, "non-canonical symbol {x}");
                x as $t
            }
            #[cfg(target_arch = "x86_64")]
            fn fma_wide(isa: IsaTier, scratch: &mut [u64], c: u64, src: &[Self]) {
                if isa == IsaTier::Avx2 && src.len() >= 4 {
                    // SAFETY: the Avx2 tier is only constructed after
                    // runtime detection (`IsaTier::clamp_supported`).
                    unsafe { crate::gf::simd::x86::$fma(scratch, c, src) };
                    return;
                }
                for (s, &x) in scratch.iter_mut().zip(src) {
                    *s += c * x as u64;
                }
            }
        }
    )*};
}
impl_lane_narrow!(u8 => prime_fma_u8_avx2, u16 => prime_fma_u16_avx2, u32 => prime_fma_u32_avx2);

impl Lane for u64 {
    #[inline(always)]
    fn to_u64(self) -> u64 {
        self
    }
    #[inline(always)]
    fn from_u64(x: u64) -> Self {
        x
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
enum PackedData {
    U8(Vec<u8>),
    U16(Vec<u16>),
    U32(Vec<u32>),
    U64(Vec<u64>),
}

/// A flat buffer of field symbols in narrow-lane storage. Pack/unpack
/// are pure lane-width casts (no field arithmetic): canonical elements
/// (`< q ≤ 2^bits`) round-trip exactly.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedBuf {
    data: PackedData,
}

fn copy_lanes_in<L: Lane>(dst: &mut [L], src: &[u64]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = L::from_u64(s);
    }
}

fn copy_lanes_out<L: Lane>(src: &[L], dst: &mut [u64]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = s.to_u64();
    }
}

impl PackedBuf {
    /// `len` zero symbols in the given layout.
    pub fn zeros(layout: SymbolLayout, len: usize) -> Self {
        let data = match layout {
            SymbolLayout::U8 => PackedData::U8(vec![0; len]),
            SymbolLayout::U16 => PackedData::U16(vec![0; len]),
            SymbolLayout::U32 => PackedData::U32(vec![0; len]),
            SymbolLayout::U64 => PackedData::U64(vec![0; len]),
        };
        PackedBuf { data }
    }

    /// Pack canonical `u64` symbols into narrow storage.
    pub fn pack(layout: SymbolLayout, src: &[u64]) -> Self {
        let mut buf = Self::zeros(layout, src.len());
        buf.copy_from_u64(0, src);
        buf
    }

    pub fn layout(&self) -> SymbolLayout {
        match &self.data {
            PackedData::U8(_) => SymbolLayout::U8,
            PackedData::U16(_) => SymbolLayout::U16,
            PackedData::U32(_) => SymbolLayout::U32,
            PackedData::U64(_) => SymbolLayout::U64,
        }
    }

    /// Number of symbols stored.
    pub fn len(&self) -> usize {
        match &self.data {
            PackedData::U8(v) => v.len(),
            PackedData::U16(v) => v.len(),
            PackedData::U32(v) => v.len(),
            PackedData::U64(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total storage footprint in bytes — the packing win made visible.
    pub fn bytes(&self) -> usize {
        self.len() * self.layout().bytes()
    }

    /// Write `src` (canonical `u64`s) at symbol offset `at`.
    pub fn copy_from_u64(&mut self, at: usize, src: &[u64]) {
        match &mut self.data {
            PackedData::U8(v) => copy_lanes_in(&mut v[at..at + src.len()], src),
            PackedData::U16(v) => copy_lanes_in(&mut v[at..at + src.len()], src),
            PackedData::U32(v) => copy_lanes_in(&mut v[at..at + src.len()], src),
            PackedData::U64(v) => v[at..at + src.len()].copy_from_slice(src),
        }
    }

    /// Symbol `i`, unpacked.
    pub fn get(&self, i: usize) -> u64 {
        match &self.data {
            PackedData::U8(v) => v[i] as u64,
            PackedData::U16(v) => v[i] as u64,
            PackedData::U32(v) => v[i] as u64,
            PackedData::U64(v) => v[i],
        }
    }

    /// Read `dst.len()` symbols starting at `at` back out as `u64`s.
    pub fn unpack_into(&self, at: usize, dst: &mut [u64]) {
        match &self.data {
            PackedData::U8(v) => copy_lanes_out(&v[at..at + dst.len()], dst),
            PackedData::U16(v) => copy_lanes_out(&v[at..at + dst.len()], dst),
            PackedData::U32(v) => copy_lanes_out(&v[at..at + dst.len()], dst),
            PackedData::U64(v) => dst.copy_from_slice(&v[at..at + dst.len()]),
        }
    }

    /// `len` symbols starting at `at`, unpacked to a fresh `u64` vector.
    pub fn unpack_range(&self, at: usize, len: usize) -> Vec<u64> {
        let mut out = vec![0u64; len];
        self.unpack_into(at, &mut out);
        out
    }

    /// The whole buffer unpacked.
    pub fn to_u64(&self) -> Vec<u64> {
        self.unpack_range(0, self.len())
    }

    /// Reset every symbol to zero (accumulator reuse without realloc).
    pub fn fill_zero(&mut self) {
        match &mut self.data {
            PackedData::U8(v) => v.fill(0),
            PackedData::U16(v) => v.fill(0),
            PackedData::U32(v) => v.fill(0),
            PackedData::U64(v) => v.fill(0),
        }
    }

    /// An empty buffer with room for `cap` symbols — append-only
    /// construction via [`extend_from_u64`](Self::extend_from_u64),
    /// with no zero-fill pass over storage that is about to be
    /// overwritten anyway.
    pub fn with_capacity(layout: SymbolLayout, cap: usize) -> Self {
        let data = match layout {
            SymbolLayout::U8 => PackedData::U8(Vec::with_capacity(cap)),
            SymbolLayout::U16 => PackedData::U16(Vec::with_capacity(cap)),
            SymbolLayout::U32 => PackedData::U32(Vec::with_capacity(cap)),
            SymbolLayout::U64 => PackedData::U64(Vec::with_capacity(cap)),
        };
        PackedBuf { data }
    }

    /// Append canonical `u64` symbols, packing as they land.
    pub fn extend_from_u64(&mut self, src: &[u64]) {
        match &mut self.data {
            PackedData::U8(v) => v.extend(src.iter().map(|&s| u8::from_u64(s))),
            PackedData::U16(v) => v.extend(src.iter().map(|&s| u16::from_u64(s))),
            PackedData::U32(v) => v.extend(src.iter().map(|&s| u32::from_u64(s))),
            PackedData::U64(v) => v.extend_from_slice(src),
        }
    }

    /// Append `n` zero symbols — stride padding for tile-aligned rows.
    pub fn extend_zeros(&mut self, n: usize) {
        match &mut self.data {
            PackedData::U8(v) => v.resize(v.len() + n, 0),
            PackedData::U16(v) => v.resize(v.len() + n, 0),
            PackedData::U32(v) => v.resize(v.len() + n, 0),
            PackedData::U64(v) => v.resize(v.len() + n, 0),
        }
    }
}

/// Object-safe escape hatch for fields without a specialized kernel:
/// the `Field` trait's own fused loops behind one virtual call per row.
/// The gemm row is [`gemm_row_into`](crate::gf::matrix::gemm_row_into)
/// itself — same tiling, same zero-skip-before-chunking discipline the
/// bit-identity guarantee rests on — not a reimplementation.
trait DynField: Send + Sync {
    fn dyn_order(&self) -> u64;
    fn dyn_axpy_into(&self, acc: &mut [u64], c: u64, src: &[u64]);
    fn dyn_gemm_row(&self, coeffs: &[u64], b: &[u64], n: usize, out: &mut [u64]);
}

impl<F: Field> DynField for F {
    fn dyn_order(&self) -> u64 {
        self.order()
    }
    fn dyn_axpy_into(&self, acc: &mut [u64], c: u64, src: &[u64]) {
        self.axpy_into(acc, c, src);
    }
    fn dyn_gemm_row(&self, coeffs: &[u64], b: &[u64], n: usize, out: &mut [u64]) {
        super::matrix::gemm_row_into(self, coeffs, b, n, out);
    }
}

/// `GF(2^w ≤ 8)` product kernel: two 16×256 nibble-split tables.
/// `c = (c_hi ≪ 4) ⊕ c_lo` and multiplication distributes over XOR, so
/// `c·x = hi[c_hi][x] ⊕ lo[c_lo][x]` — per element, two byte loads from
/// 256-byte L1-resident rows and one XOR.
#[derive(Clone)]
struct Gf2eNibble {
    width: u32,
    /// `lo[n·256 + x] = n · x` for every field element `x`.
    lo: Arc<[u8]>,
    /// `hi[n·256 + x] = (n ≪ 4) · x` for every field element `x`.
    hi: Arc<[u8]>,
}

impl Gf2eNibble {
    fn new(g: &Gf2e) -> Self {
        let order = g.order();
        let mut lo = vec![0u8; 16 * 256];
        let mut hi = vec![0u8; 16 * 256];
        for nib in 0..16u64 {
            for x in 0..order {
                if nib < order {
                    lo[nib as usize * 256 + x as usize] = g.mul(nib, x) as u8;
                }
                if nib << 4 < order {
                    hi[nib as usize * 256 + x as usize] = g.mul(nib << 4, x) as u8;
                }
            }
        }
        Gf2eNibble {
            width: g.width(),
            lo: lo.into(),
            hi: hi.into(),
        }
    }

    #[inline]
    fn tables(&self, c: usize) -> (&[u8], &[u8]) {
        (
            &self.lo[(c & 0xF) * 256..(c & 0xF) * 256 + 256],
            &self.hi[(c >> 4) * 256..(c >> 4) * 256 + 256],
        )
    }

    /// The 16-entry **operand-nibble** tables of one coefficient `c`:
    /// `tlo[j] = c·j` and `thi[j] = c·(j≪4)`, folded out of the two
    /// coefficient-nibble table rows (`c·x = lo[x] ⊕ hi[x]`, evaluated
    /// at `x = j` and `x = j≪4`). These are the byte-shuffle operands of
    /// the SIMD axpy: `c·s = tlo[s & 15] ⊕ thi[s ≫ 4]`. For `w < 8` the
    /// out-of-field entries are zero and never indexed by valid lanes.
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    fn operand_tables(lo: &[u8], hi: &[u8]) -> ([u8; 16], [u8; 16]) {
        let mut tlo = [0u8; 16];
        let mut thi = [0u8; 16];
        for j in 0..16 {
            tlo[j] = lo[j] ^ hi[j];
            thi[j] = lo[j << 4] ^ hi[j << 4];
        }
        (tlo, thi)
    }

    fn axpy(&self, isa: IsaTier, acc: &mut [u8], c: u64, src: &[u8]) {
        debug_assert_eq!(acc.len(), src.len());
        if c == 0 {
            return;
        }
        if c == 1 {
            for (a, &s) in acc.iter_mut().zip(src) {
                *a ^= s;
            }
            return;
        }
        let (lo, hi) = self.tables(c as usize);
        #[cfg(target_arch = "x86_64")]
        if isa == IsaTier::Avx2 && acc.len() >= 32 {
            let (tlo, thi) = Self::operand_tables(lo, hi);
            // SAFETY: the Avx2 tier is only constructed after runtime
            // detection (`IsaTier::clamp_supported`).
            unsafe { crate::gf::simd::x86::gf256_axpy_avx2(acc, src, &tlo, &thi) };
            return;
        }
        #[cfg(target_arch = "aarch64")]
        if isa == IsaTier::Neon && acc.len() >= 16 {
            let (tlo, thi) = Self::operand_tables(lo, hi);
            // SAFETY: NEON is baseline on aarch64.
            unsafe { crate::gf::simd::neon::gf256_axpy_neon(acc, src, &tlo, &thi) };
            return;
        }
        let _ = isa;
        for (a, &s) in acc.iter_mut().zip(src) {
            *a ^= lo[s as usize] ^ hi[s as usize];
        }
    }

    fn gemm_row(&self, isa: IsaTier, coeffs: &[u64], b: &[u8], n: usize, out: &mut [u8]) {
        gemm_row_tiled(coeffs, b, n, out, |o, c, s| self.axpy(isa, o, c, s));
    }
}

/// The one column-tile walk every XOR-accumulating packed gemm row
/// shares — the same `GEMM_TILE` + zero-coefficient-skip discipline as
/// [`crate::gf::matrix::gemm_row_into`], parameterized by the per-tile
/// axpy so the discipline cannot drift between lane types.
fn gemm_row_tiled<L>(
    coeffs: &[u64],
    b: &[L],
    n: usize,
    out: &mut [L],
    mut axpy: impl FnMut(&mut [L], u64, &[L]),
) {
    debug_assert_eq!(out.len(), n);
    debug_assert_eq!(b.len(), coeffs.len() * n);
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + GEMM_TILE).min(n);
        for (k, &c) in coeffs.iter().enumerate() {
            if c != 0 {
                axpy(&mut out[j0..j1], c, &b[k * n + j0..k * n + j1]);
            }
        }
        j0 = j1;
    }
}

/// `GF(2^w)`, `8 < w ≤ 16`: hoisted-log axpy over `u16` lanes. The AVX2
/// tier gathers the log/exp lookups 16 symbols at a time; products are
/// the same exact table entries either way.
fn gf2e_wide_axpy(g: &Gf2e, isa: IsaTier, acc: &mut [u16], c: u64, src: &[u16]) {
    debug_assert_eq!(acc.len(), src.len());
    if c == 0 {
        return;
    }
    let log_c = g.log_of(c);
    #[cfg(target_arch = "x86_64")]
    if isa == IsaTier::Avx2 && acc.len() >= 16 {
        // SAFETY: the Avx2 tier is only constructed after runtime
        // detection; the table layout contract is the Gf2e one.
        unsafe {
            crate::gf::simd::x86::gf2e_wide_axpy_avx2(acc, src, g.log_table(), g.exp_table(), log_c)
        };
        return;
    }
    let _ = isa;
    for (a, &s) in acc.iter_mut().zip(src) {
        if s != 0 {
            *a ^= g.exp_at(log_c + g.log_of(s as u64));
        }
    }
}

fn gf2e_wide_gemm_row(
    g: &Gf2e,
    isa: IsaTier,
    coeffs: &[u64],
    b: &[u16],
    n: usize,
    out: &mut [u16],
) {
    gemm_row_tiled(coeffs, b, n, out, |o, c, s| gf2e_wide_axpy(g, isa, o, c, s));
}

/// Prime-field fused axpy over narrow lanes: `a + c·s < p²`, one Barrett
/// reduction per element, loads/stores in lane width only.
fn prime_axpy<L: Lane>(p: &GfPrime, acc: &mut [L], c: u64, src: &[L]) {
    debug_assert_eq!(acc.len(), src.len());
    if c == 0 {
        return;
    }
    for (a, &s) in acc.iter_mut().zip(src) {
        *a = L::from_u64(p.reduce(a.to_u64() + c * s.to_u64()));
    }
}

/// Prime-field packed gemm row with delayed reduction: raw `c·s`
/// products accumulate in a `u64` scratch tile, one `reduce_wide` pass
/// per [`Field::lazy_chunk`] terms (the same overflow discipline as
/// [`Field::lincomb_into`]: `acc < p` plus `lazy_chunk·(p−1)²` never
/// wraps), lanes only touched narrow on load and final store. The ISA
/// tier upgrades only the fma accumulation ([`Lane::fma_wide`]); the
/// reduction schedule is tier-independent, so results are bit-identical.
fn prime_gemm_row<L: Lane>(
    p: &GfPrime,
    isa: IsaTier,
    coeffs: &[u64],
    b: &[L],
    n: usize,
    out: &mut [L],
) {
    debug_assert_eq!(out.len(), n);
    debug_assert_eq!(b.len(), coeffs.len() * n);
    let nz: Vec<(u64, usize)> = coeffs
        .iter()
        .enumerate()
        .filter(|(_, &c)| c != 0)
        .map(|(k, &c)| (c, k))
        .collect();
    if nz.is_empty() || n == 0 {
        return;
    }
    let chunk = p.lazy_chunk();
    let mut scratch = vec![0u64; GEMM_TILE.min(n)];
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + GEMM_TILE).min(n);
        let sc = &mut scratch[..j1 - j0];
        for (s, o) in sc.iter_mut().zip(out[j0..j1].iter()) {
            *s = o.to_u64();
        }
        for group in nz.chunks(chunk) {
            for &(c, k) in group {
                L::fma_wide(isa, sc, c, &b[k * n + j0..k * n + j1]);
            }
            for s in sc.iter_mut() {
                *s = p.reduce_wide(*s);
            }
        }
        for (o, &s) in out[j0..j1].iter_mut().zip(sc.iter()) {
            *o = L::from_u64(s);
        }
        j0 = j1;
    }
}

#[derive(Clone)]
enum Impl {
    Gf2eNibble(Gf2eNibble),
    Gf2eWide(Gf2e),
    Prime(GfPrime, SymbolLayout),
    Scalar(Arc<dyn DynField>),
}

impl std::fmt::Debug for Impl {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Impl::Gf2eNibble(k) => write!(f, "gf2e-nibble(w={})", k.width),
            Impl::Gf2eWide(g) => write!(f, "gf2e-wide({g:?})"),
            Impl::Prime(p, l) => write!(f, "prime-packed({p:?}, {l:?})"),
            Impl::Scalar(_) => write!(f, "scalar-u64"),
        }
    }
}

/// The per-field kernel vtable (see module docs). Resolve once per plan
/// with [`Kernels::for_field`] (or a pinned tier with
/// [`Kernels::for_field_with_isa`]); every method then runs monomorphic
/// narrow-lane loops with no per-element field dispatch, vectorized at
/// the resolved [`IsaTier`].
#[derive(Clone, Debug)]
pub struct Kernels {
    imp: Impl,
    isa: IsaTier,
}

/// A packed buffer whose lane layout does not match the field the
/// kernels were resolved for. This is the *typed* form of what used to
/// be a worker-killing `panic!` in the kernel dispatch arms: a caller
/// pairing a plan's kernels with a buffer packed for a different field
/// now gets a recoverable error that propagates through
/// [`replay_batch`](crate::net::exec::replay_batch) and surfaces in the
/// coordinator as a rejected job (`coordinator::metrics::KERNEL_LAYOUT_REJECTS`)
/// instead of poisoning the batcher thread.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayoutMismatch {
    /// The layout this field's kernels compute in.
    pub expected: SymbolLayout,
    /// The offending buffer's layout.
    pub got: SymbolLayout,
}

impl std::fmt::Display for LayoutMismatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "packed buffer layout ({} lanes) does not match the field's kernels ({} lanes)",
            self.got.name(),
            self.expected.name()
        )
    }
}

impl std::error::Error for LayoutMismatch {}

/// A packed operand whose lane *count* does not match what the call
/// shape requires — the typed form of the arena-shape `assert_eq!`s
/// that used to abort the batch worker. `what` names the violated
/// contract in the kernel's own vocabulary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShapeMismatch {
    /// Which shape contract was violated (e.g. `"axpy operand lanes"`).
    pub what: &'static str,
    /// The lane count the call shape requires.
    pub expected: usize,
    /// The lane count actually supplied.
    pub got: usize,
}

impl std::fmt::Display for ShapeMismatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "packed {}: expected {} lanes, got {}",
            self.what, self.expected, self.got
        )
    }
}

impl std::error::Error for ShapeMismatch {}

/// Everything a packed kernel call can reject at its boundary — wrong
/// lane layout or wrong lane count — as a recoverable error. The
/// serving path (`replay_batch`, the coordinator's batch worker) counts
/// these as rejected jobs instead of panicking; `source()` exposes the
/// inner struct so existing `anyhow` chain downcasts to
/// [`LayoutMismatch`] keep working.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelError {
    Layout(LayoutMismatch),
    Shape(ShapeMismatch),
}

impl std::fmt::Display for KernelError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KernelError::Layout(e) => e.fmt(f),
            KernelError::Shape(e) => e.fmt(f),
        }
    }
}

impl std::error::Error for KernelError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            KernelError::Layout(e) => Some(e),
            KernelError::Shape(e) => Some(e),
        }
    }
}

impl From<LayoutMismatch> for KernelError {
    fn from(e: LayoutMismatch) -> Self {
        KernelError::Layout(e)
    }
}

impl From<ShapeMismatch> for KernelError {
    fn from(e: ShapeMismatch) -> Self {
        KernelError::Shape(e)
    }
}

/// `Ok(())` when a call-shape contract holds, the typed error otherwise.
fn check_shape(what: &'static str, expected: usize, got: usize) -> Result<(), KernelError> {
    if expected == got {
        Ok(())
    } else {
        Err(ShapeMismatch {
            what,
            expected,
            got,
        }
        .into())
    }
}

/// Run `body(i, row_i)` over the `n`-lane rows of `out`, rayon-parallel
/// when `par` (and the `parallel` feature) is on.
fn row_loop<T: Send>(out: &mut [T], n: usize, par: bool, body: impl Fn(usize, &mut [T]) + Sync + Send) {
    #[cfg(feature = "parallel")]
    if par {
        use rayon::prelude::*;
        out.par_chunks_mut(n).enumerate().for_each(|(i, row)| body(i, row));
        return;
    }
    let _ = par;
    for (i, row) in out.chunks_mut(n).enumerate() {
        body(i, row);
    }
}

impl Kernels {
    /// Resolve the kernel set for a field — once per plan, not per
    /// element — at the process default ISA tier ([`IsaTier::detect`]:
    /// the widest the host supports, or the `DCE_FORCE_ISA` override).
    /// Recognizes the crate's concrete fields (including through
    /// [`AnyField`], which is what kills the per-element enum dispatch
    /// on the coordinator's serving path); anything else gets the `u64`
    /// scalar fallback driven through the `Field` trait.
    pub fn for_field<F: Field>(f: &F) -> Kernels {
        Self::for_field_with_isa(f, IsaTier::detect())
    }

    /// [`for_field`](Self::for_field) with an explicit ISA tier. The
    /// tier is clamped to what this host can execute
    /// ([`IsaTier::clamp_supported`]) and pinned to scalar for the `u64`
    /// fallback (which has no vector path) — so the recorded
    /// [`isa`](Self::isa) is always the tier actually dispatched to.
    pub fn for_field_with_isa<F: Field>(f: &F, isa: IsaTier) -> Kernels {
        let any: &dyn std::any::Any = f;
        let imp = if let Some(af) = any.downcast_ref::<AnyField>() {
            match af {
                AnyField::Prime(p) => Self::prime_impl(*p),
                AnyField::Ext(g) => Self::gf2e_impl(g.clone()),
            }
        } else if let Some(p) = any.downcast_ref::<GfPrime>() {
            Self::prime_impl(*p)
        } else if let Some(g) = any.downcast_ref::<Gf2e>() {
            Self::gf2e_impl(g.clone())
        } else {
            Impl::Scalar(Arc::new(f.clone()))
        };
        Self::with_impl(imp, isa)
    }

    /// The same field's kernels re-pinned to `isa` (clamped the same
    /// way as [`for_field_with_isa`](Self::for_field_with_isa)). Cheap:
    /// the product tables live behind `Arc`s.
    pub fn with_isa(&self, isa: IsaTier) -> Kernels {
        Self::with_impl(self.imp.clone(), isa)
    }

    /// The ISA tier these kernels dispatch to.
    pub fn isa(&self) -> IsaTier {
        self.isa
    }

    fn with_impl(imp: Impl, isa: IsaTier) -> Kernels {
        let isa = if matches!(imp, Impl::Scalar(_)) {
            IsaTier::Scalar
        } else {
            isa.clamp_supported()
        };
        Kernels { imp, isa }
    }

    fn prime_impl(p: GfPrime) -> Impl {
        let layout = SymbolLayout::for_bits(p.bits());
        Impl::Prime(p, layout)
    }

    fn gf2e_impl(g: Gf2e) -> Impl {
        if g.width() <= 8 {
            Impl::Gf2eNibble(Gf2eNibble::new(&g))
        } else {
            Impl::Gf2eWide(g)
        }
    }

    /// The field order `q` these kernels compute in — the canonical
    /// range packing callers must validate against (a width cast is
    /// only lossless for elements `< q`; see `exec::check_canonical`).
    pub fn order(&self) -> u64 {
        match &self.imp {
            Impl::Gf2eNibble(k) => 1u64 << k.width,
            Impl::Gf2eWide(g) => g.order(),
            Impl::Prime(p, _) => p.order(),
            Impl::Scalar(ops) => ops.dyn_order(),
        }
    }

    /// The storage layout this field's symbols pack into.
    pub fn layout(&self) -> SymbolLayout {
        match &self.imp {
            Impl::Gf2eNibble(_) => SymbolLayout::U8,
            Impl::Gf2eWide(_) => SymbolLayout::U16,
            Impl::Prime(_, l) => *l,
            Impl::Scalar(_) => SymbolLayout::U64,
        }
    }

    /// Pack canonical symbols into this field's layout.
    pub fn pack(&self, src: &[u64]) -> PackedBuf {
        PackedBuf::pack(self.layout(), src)
    }

    /// `len` packed zeros in this field's layout.
    pub fn zeros(&self, len: usize) -> PackedBuf {
        PackedBuf::zeros(self.layout(), len)
    }

    /// The [`LayoutMismatch`] for a dispatch miss against `bufs`.
    fn mismatch(&self, bufs: &[SymbolLayout]) -> LayoutMismatch {
        let expected = self.layout();
        let got = bufs
            .iter()
            .copied()
            .find(|&l| l != expected)
            .unwrap_or(expected);
        LayoutMismatch { expected, got }
    }

    /// `acc[i] += c·src[i]` over packed storage.
    pub fn axpy(&self, acc: &mut PackedBuf, c: u64, src: &PackedBuf) -> Result<(), KernelError> {
        check_shape("axpy operand lanes", acc.len(), src.len())?;
        let isa = self.isa;
        let bufs = [acc.layout(), src.layout()];
        match (&self.imp, &mut acc.data, &src.data) {
            (Impl::Gf2eNibble(k), PackedData::U8(a), PackedData::U8(s)) => k.axpy(isa, a, c, s),
            (Impl::Gf2eWide(g), PackedData::U16(a), PackedData::U16(s)) => {
                gf2e_wide_axpy(g, isa, a, c, s)
            }
            (Impl::Prime(p, _), PackedData::U8(a), PackedData::U8(s)) => prime_axpy(p, a, c, s),
            (Impl::Prime(p, _), PackedData::U16(a), PackedData::U16(s)) => prime_axpy(p, a, c, s),
            (Impl::Prime(p, _), PackedData::U32(a), PackedData::U32(s)) => prime_axpy(p, a, c, s),
            (Impl::Scalar(ops), PackedData::U64(a), PackedData::U64(s)) => {
                ops.dyn_axpy_into(a, c, s)
            }
            _ => return Err(self.mismatch(&bufs).into()),
        }
        Ok(())
    }

    /// `acc[j] += Σ_k coeffs[k]·srcs[k·n + j]` — one dense lincomb over
    /// a row-major packed arena of `coeffs.len()` rows × `acc.len()`
    /// lanes.
    pub fn lincomb(
        &self,
        acc: &mut PackedBuf,
        coeffs: &[u64],
        srcs: &PackedBuf,
    ) -> Result<(), KernelError> {
        let n = acc.len();
        check_shape("lincomb arena lanes", coeffs.len() * n, srcs.len())?;
        let isa = self.isa;
        let bufs = [acc.layout(), srcs.layout()];
        match (&self.imp, &mut acc.data, &srcs.data) {
            (Impl::Gf2eNibble(k), PackedData::U8(a), PackedData::U8(s)) => {
                k.gemm_row(isa, coeffs, s, n, a)
            }
            (Impl::Gf2eWide(g), PackedData::U16(a), PackedData::U16(s)) => {
                gf2e_wide_gemm_row(g, isa, coeffs, s, n, a)
            }
            (Impl::Prime(p, _), PackedData::U8(a), PackedData::U8(s)) => {
                prime_gemm_row(p, isa, coeffs, s, n, a)
            }
            (Impl::Prime(p, _), PackedData::U16(a), PackedData::U16(s)) => {
                prime_gemm_row(p, isa, coeffs, s, n, a)
            }
            (Impl::Prime(p, _), PackedData::U32(a), PackedData::U32(s)) => {
                prime_gemm_row(p, isa, coeffs, s, n, a)
            }
            (Impl::Scalar(ops), PackedData::U64(a), PackedData::U64(s)) => {
                ops.dyn_gemm_row(coeffs, s, n, a)
            }
            _ => return Err(self.mismatch(&bufs).into()),
        }
        Ok(())
    }

    /// The batched serving kernel: `out[i·n + j] += Σ_k rows[i][k]·b[k·n + j]`
    /// — every coefficient row evaluated over the packed arena `b`
    /// (`rows[i].len()` rows × `n` lanes), rayon-parallel over the
    /// independent output rows when `par` is set (and the `parallel`
    /// feature is compiled in). `out` must hold `rows.len()·n` lanes
    /// (zeroed by the caller; the kernels accumulate).
    pub fn gemm_rows(
        &self,
        rows: &[&[u64]],
        b: &PackedBuf,
        n: usize,
        out: &mut PackedBuf,
        par: bool,
    ) -> Result<(), KernelError> {
        check_shape("gemm output lanes", rows.len() * n, out.len())?;
        if n == 0 || rows.is_empty() {
            return Ok(());
        }
        let isa = self.isa;
        let bufs = [out.layout(), b.layout()];
        match (&self.imp, &mut out.data, &b.data) {
            (Impl::Gf2eNibble(k), PackedData::U8(o), PackedData::U8(bs)) => {
                row_loop(o, n, par, |i, row| k.gemm_row(isa, rows[i], bs, n, row))
            }
            (Impl::Gf2eWide(g), PackedData::U16(o), PackedData::U16(bs)) => {
                row_loop(o, n, par, |i, row| gf2e_wide_gemm_row(g, isa, rows[i], bs, n, row))
            }
            (Impl::Prime(p, _), PackedData::U8(o), PackedData::U8(bs)) => {
                row_loop(o, n, par, |i, row| prime_gemm_row(p, isa, rows[i], bs, n, row))
            }
            (Impl::Prime(p, _), PackedData::U16(o), PackedData::U16(bs)) => {
                row_loop(o, n, par, |i, row| prime_gemm_row(p, isa, rows[i], bs, n, row))
            }
            (Impl::Prime(p, _), PackedData::U32(o), PackedData::U32(bs)) => {
                row_loop(o, n, par, |i, row| prime_gemm_row(p, isa, rows[i], bs, n, row))
            }
            (Impl::Scalar(ops), PackedData::U64(o), PackedData::U64(bs)) => {
                row_loop(o, n, par, |i, row| ops.dyn_gemm_row(rows[i], bs, n, row))
            }
            _ => return Err(self.mismatch(&bufs).into()),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn layout_selection_rule() {
        assert_eq!(SymbolLayout::for_bits(1), SymbolLayout::U8);
        assert_eq!(SymbolLayout::for_bits(8), SymbolLayout::U8);
        assert_eq!(SymbolLayout::for_bits(9), SymbolLayout::U16);
        assert_eq!(SymbolLayout::for_bits(16), SymbolLayout::U16);
        assert_eq!(SymbolLayout::for_bits(17), SymbolLayout::U32);
        assert_eq!(SymbolLayout::for_bits(32), SymbolLayout::U32);
        assert_eq!(SymbolLayout::for_bits(33), SymbolLayout::U64);
        // Concrete fields, direct and through AnyField.
        assert_eq!(Kernels::for_field(&Gf2e::new(8).unwrap()).layout(), SymbolLayout::U8);
        assert_eq!(Kernels::for_field(&Gf2e::new(12).unwrap()).layout(), SymbolLayout::U16);
        assert_eq!(
            Kernels::for_field(&GfPrime::default_field()).layout(),
            SymbolLayout::U32 // 20-bit prime
        );
        assert_eq!(
            Kernels::for_field(&GfPrime::new(251).unwrap()).layout(),
            SymbolLayout::U8
        );
        assert_eq!(
            Kernels::for_field(&GfPrime::new(257).unwrap()).layout(),
            SymbolLayout::U16
        );
        for (spec, want) in [
            ("gf2e:8", SymbolLayout::U8),
            ("gf2e:16", SymbolLayout::U16),
            ("786433", SymbolLayout::U32),
            ("2147483647", SymbolLayout::U32),
        ] {
            let f = AnyField::parse(spec).unwrap();
            assert_eq!(Kernels::for_field(&f).layout(), want, "{spec}");
        }
    }

    #[test]
    fn pack_roundtrip_every_layout() {
        for layout in [
            SymbolLayout::U8,
            SymbolLayout::U16,
            SymbolLayout::U32,
            SymbolLayout::U64,
        ] {
            let max = match layout {
                SymbolLayout::U8 => u8::MAX as u64,
                SymbolLayout::U16 => u16::MAX as u64,
                SymbolLayout::U32 => u32::MAX as u64,
                SymbolLayout::U64 => u64::MAX,
            };
            let vals = vec![0u64, 1, 2, max / 2, max];
            let buf = PackedBuf::pack(layout, &vals);
            assert_eq!(buf.layout(), layout);
            assert_eq!(buf.len(), vals.len());
            assert_eq!(buf.bytes(), vals.len() * layout.bytes());
            assert_eq!(buf.to_u64(), vals);
            assert_eq!(buf.unpack_range(1, 2), vec![1, 2]);
        }
    }

    #[test]
    fn packed_axpy_matches_scalar_per_field() {
        let mut rng = Rng::new(0xACC);
        let fields = [
            AnyField::parse("gf2e:8").unwrap(),
            AnyField::parse("gf2e:12").unwrap(),
            AnyField::parse("786433").unwrap(),
            AnyField::parse("2147483647").unwrap(),
        ];
        for f in &fields {
            let kern = Kernels::for_field(f);
            for n in [1usize, 7, 64, 100] {
                let acc0: Vec<u64> = (0..n).map(|_| rng.below(f.order())).collect();
                let src: Vec<u64> = (0..n).map(|_| rng.below(f.order())).collect();
                let c = rng.below(f.order());
                let mut scalar = acc0.clone();
                f.axpy_into(&mut scalar, c, &src);
                let mut packed = kern.pack(&acc0);
                kern.axpy(&mut packed, c, &kern.pack(&src)).unwrap();
                assert_eq!(packed.to_u64(), scalar, "{f:?} n={n} c={c}");
            }
        }
    }

    #[test]
    fn scalar_fallback_serves_unknown_field_shapes() {
        // A custom Field impl that none of the specialized kernels
        // recognize must fall back to u64 lanes and stay correct.
        #[derive(Clone, Debug)]
        struct Mod7;
        impl Field for Mod7 {
            fn order(&self) -> u64 {
                7
            }
            fn add(&self, a: u64, b: u64) -> u64 {
                (a + b) % 7
            }
            fn sub(&self, a: u64, b: u64) -> u64 {
                (a + 7 - b) % 7
            }
            fn mul(&self, a: u64, b: u64) -> u64 {
                a * b % 7
            }
            fn inv(&self, a: u64) -> u64 {
                self.pow(a, 5)
            }
            fn generator(&self) -> u64 {
                3
            }
        }
        let f = Mod7;
        let kern = Kernels::for_field(&f);
        assert_eq!(kern.layout(), SymbolLayout::U64);
        let mut acc = kern.pack(&[1, 2, 3, 4]);
        kern.axpy(&mut acc, 3, &kern.pack(&[5, 6, 0, 1])).unwrap();
        assert_eq!(acc.to_u64(), vec![(1 + 15) % 7, (2 + 18) % 7, 3, (4 + 3) % 7]);

        // The fallback's lincomb and gemm_rows arms, against a naive
        // mod-7 oracle (coeffs include a zero — the skip must hold).
        let n = 5usize;
        let coeffs = [3u64, 0, 6];
        let arena_u64: Vec<u64> = (0..coeffs.len() * n).map(|i| (i as u64 * 3 + 1) % 7).collect();
        let arena = kern.pack(&arena_u64);
        let oracle_row = |cs: &[u64], init: &[u64]| -> Vec<u64> {
            (0..n)
                .map(|j| {
                    cs.iter().enumerate().fold(init[j], |acc, (t, &c)| {
                        (acc + c * arena_u64[t * n + j]) % 7
                    })
                })
                .collect()
        };
        let init = [4u64, 5, 6, 0, 1];
        let mut acc = kern.pack(&init);
        kern.lincomb(&mut acc, &coeffs, &arena).unwrap();
        assert_eq!(acc.to_u64(), oracle_row(&coeffs, &init), "fallback lincomb");
        let row2 = [1u64, 2, 4];
        let rows: Vec<&[u64]> = vec![&coeffs, &row2];
        let mut out = kern.zeros(2 * n);
        kern.gemm_rows(&rows, &arena, n, &mut out, false).unwrap();
        assert_eq!(out.unpack_range(0, n), oracle_row(&coeffs, &[0; 5]), "fallback gemm row 0");
        assert_eq!(out.unpack_range(n, n), oracle_row(&row2, &[0; 5]), "fallback gemm row 1");
    }

    #[test]
    fn layout_mismatch_is_a_typed_error_not_a_panic() {
        // Kernels resolved for one field, buffers packed for another:
        // every vtable entry must return the typed error (the serving
        // path turns it into a rejected job), never panic.
        let prime = Kernels::for_field(&GfPrime::default_field()); // u32 lanes
        let bytes = Kernels::for_field(&Gf2e::new(8).unwrap()); // u8 lanes
        let mut acc = prime.zeros(4);
        let err = bytes.axpy(&mut acc, 3, &prime.zeros(4)).unwrap_err();
        let KernelError::Layout(lm) = err else {
            panic!("expected a layout error, got {err:?}")
        };
        assert_eq!(lm.expected, SymbolLayout::U8);
        assert_eq!(lm.got, SymbolLayout::U32);
        assert!(err.to_string().contains("does not match"), "{err}");
        let mut acc = prime.zeros(4);
        assert!(bytes.lincomb(&mut acc, &[1, 2], &prime.zeros(8)).is_err());
        let mut out = prime.zeros(4);
        let row: &[u64] = &[1, 2];
        assert!(bytes.gemm_rows(&[row], &prime.zeros(8), 4, &mut out, false).is_err());
        // And through anyhow chains the concrete type stays reachable
        // (the coordinator's reject counter downcasts exactly this way).
        let any: anyhow::Error = err.into();
        assert!(any
            .chain()
            .any(|c| c.downcast_ref::<LayoutMismatch>().is_some()));
    }

    #[test]
    fn shape_mismatch_is_a_typed_error_not_a_panic() {
        // Wrong lane counts used to be assert_eq! panics in the
        // dispatch; every entry must now reject with the typed error.
        let kern = Kernels::for_field(&GfPrime::default_field());
        let mut acc = kern.zeros(4);
        let err = kern.axpy(&mut acc, 3, &kern.zeros(5)).unwrap_err();
        let KernelError::Shape(sm) = err else {
            panic!("expected a shape error, got {err:?}")
        };
        assert_eq!(sm.expected, 4);
        assert_eq!(sm.got, 5);
        assert!(err.to_string().contains("lanes"), "{err}");
        let mut acc = kern.zeros(4);
        assert!(kern.lincomb(&mut acc, &[1, 2], &kern.zeros(7)).is_err());
        let row: &[u64] = &[1, 2];
        let mut out = kern.zeros(5);
        assert!(kern.gemm_rows(&[row], &kern.zeros(8), 4, &mut out, false).is_err());
        let any: anyhow::Error = err.into();
        assert!(any
            .chain()
            .any(|c| c.downcast_ref::<ShapeMismatch>().is_some()));
    }

    #[test]
    fn isa_tier_is_clamped_and_reported_per_kernels() {
        use crate::gf::simd::IsaTier;
        let f = AnyField::parse("gf2e:8").unwrap();
        // Whatever is requested, the resolved tier is executable here,
        // and the kernels stay correct after clamping.
        for req in [IsaTier::Scalar, IsaTier::Avx2, IsaTier::Neon] {
            let kern = Kernels::for_field_with_isa(&f, req);
            assert!(IsaTier::available().contains(&kern.isa()), "{req:?}");
            let mut acc = kern.pack(&[1, 2, 3]);
            kern.axpy(&mut acc, 7, &kern.pack(&[9, 8, 250])).unwrap();
            let scalar = Kernels::for_field_with_isa(&f, IsaTier::Scalar);
            let mut want = scalar.pack(&[1, 2, 3]);
            scalar.axpy(&mut want, 7, &scalar.pack(&[9, 8, 250])).unwrap();
            assert_eq!(acc.to_u64(), want.to_u64(), "{req:?}");
        }
        // with_isa re-pins an existing vtable the same way.
        let kern = Kernels::for_field(&f).with_isa(IsaTier::Scalar);
        assert_eq!(kern.isa(), IsaTier::Scalar);
        // The u64 fallback has no vector path and says so.
        #[derive(Clone, Debug)]
        struct Mod5;
        impl Field for Mod5 {
            fn order(&self) -> u64 {
                5
            }
            fn add(&self, a: u64, b: u64) -> u64 {
                (a + b) % 5
            }
            fn sub(&self, a: u64, b: u64) -> u64 {
                (a + 5 - b) % 5
            }
            fn mul(&self, a: u64, b: u64) -> u64 {
                a * b % 5
            }
            fn inv(&self, a: u64) -> u64 {
                self.pow(a, 3)
            }
            fn generator(&self) -> u64 {
                2
            }
        }
        let fallback = Kernels::for_field_with_isa(&Mod5, IsaTier::widest());
        assert_eq!(fallback.isa(), IsaTier::Scalar);
    }

    #[test]
    fn packed_gemm_rows_matches_lincomb() {
        let mut rng = Rng::new(0x6E);
        for spec in ["gf2e:8", "786433"] {
            let f = AnyField::parse(spec).unwrap();
            let kern = Kernels::for_field(&f);
            let (m, k, n) = (5usize, 9usize, 33usize);
            let rows: Vec<Vec<u64>> = (0..m)
                .map(|_| (0..k).map(|_| rng.below(f.order())).collect())
                .collect();
            let arena_u64: Vec<u64> = (0..k * n).map(|_| rng.below(f.order())).collect();
            let arena = kern.pack(&arena_u64);
            let mut out = kern.zeros(m * n);
            let row_refs: Vec<&[u64]> = rows.iter().map(|r| r.as_slice()).collect();
            kern.gemm_rows(&row_refs, &arena, n, &mut out, false).unwrap();
            for (i, row) in rows.iter().enumerate() {
                let mut want = kern.zeros(n);
                kern.lincomb(&mut want, row, &arena).unwrap();
                assert_eq!(out.unpack_range(i * n, n), want.to_u64(), "{spec} row {i}");
            }
        }
    }
}

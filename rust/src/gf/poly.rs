//! Polynomials over `F_q`: evaluation, interpolation, products.
//!
//! The paper's specific algorithms are polynomial-evaluation algorithms in
//! disguise — every processor `P_k` of §V requires `f(α_k)` for the data
//! polynomial `f(z) = Σ x_k z^k` (eq. (5)) — and the systematic-RS
//! decomposition (Theorem 6) is a statement about Lagrange basis
//! polynomials. This module is the local-computation substrate for both,
//! and the decoder of `codes::rs`.

use super::Field;

/// Evaluate `Σ coeffs[i]·z^i` at `z` (Horner).
pub fn eval<F: Field>(f: &F, coeffs: &[u64], z: u64) -> u64 {
    let mut acc = 0u64;
    for &c in coeffs.iter().rev() {
        acc = f.mul_add(c, acc, z);
    }
    acc
}

/// Evaluate at many points.
pub fn eval_many<F: Field>(f: &F, coeffs: &[u64], zs: &[u64]) -> Vec<u64> {
    zs.iter().map(|&z| eval(f, coeffs, z)).collect()
}

/// Multiply two polynomials (coefficient vectors).
pub fn mul<F: Field>(f: &F, a: &[u64], b: &[u64]) -> Vec<u64> {
    if a.is_empty() || b.is_empty() {
        return vec![];
    }
    let mut out = vec![0u64; a.len() + b.len() - 1];
    for (i, &ai) in a.iter().enumerate() {
        if ai == 0 {
            continue;
        }
        for (j, &bj) in b.iter().enumerate() {
            out[i + j] = f.mul_add(out[i + j], ai, bj);
        }
    }
    out
}

/// `∏ (z − roots[i])` as a coefficient vector (monic, degree = #roots).
pub fn from_roots<F: Field>(f: &F, roots: &[u64]) -> Vec<u64> {
    let mut out = vec![f.one()];
    for &r in roots {
        out = mul(f, &out, &[f.neg(r), f.one()]);
    }
    out
}

/// Synthetic division of `poly` by the monic linear factor `(z − root)`.
/// Returns the quotient; panics if `root` is not actually a root... it is
/// the caller's job to only divide by true roots (remainder is discarded,
/// asserted in debug builds).
pub fn div_linear<F: Field>(f: &F, poly: &[u64], root: u64) -> Vec<u64> {
    let n = poly.len();
    assert!(n >= 1);
    let mut q = vec![0u64; n - 1];
    let mut carry = 0u64;
    for i in (0..n).rev() {
        let v = f.mul_add(poly[i], carry, root);
        if i == 0 {
            debug_assert_eq!(v, 0, "div_linear: not a root");
        } else {
            q[i - 1] = v;
            carry = v;
        }
    }
    q
}

/// Lagrange interpolation: the unique polynomial of degree `< n` through
/// `(points[i], values[i])` for `n` distinct points. `O(n²)`.
pub fn interpolate<F: Field>(f: &F, points: &[u64], values: &[u64]) -> Vec<u64> {
    assert_eq!(points.len(), values.len());
    let n = points.len();
    if n == 0 {
        return vec![];
    }
    // master(z) = ∏ (z − x_i)
    let master = from_roots(f, points);
    let mut out = vec![0u64; n];
    for i in 0..n {
        // ℓ_i(z) = master / (z − x_i) / ∏_{j≠i}(x_i − x_j)
        let num = div_linear(f, &master, points[i]);
        let mut denom = f.one();
        for j in 0..n {
            if j != i {
                denom = f.mul(denom, f.sub(points[i], points[j]));
            }
        }
        let scale = f.mul(values[i], f.inv(denom));
        for (o, &c) in out.iter_mut().zip(&num) {
            *o = f.mul_add(*o, scale, c);
        }
    }
    out
}

/// Coefficients of the `i`-th Lagrange basis polynomial
/// `ℓ_i(z) = ∏_{j≠i} (z − x_j)/(x_i − x_j)` — eq. (28) of the paper.
pub fn lagrange_basis<F: Field>(f: &F, points: &[u64], i: usize) -> Vec<u64> {
    let master = from_roots(f, points);
    let num = div_linear(f, &master, points[i]);
    let mut denom = f.one();
    for (j, &xj) in points.iter().enumerate() {
        if j != i {
            denom = f.mul(denom, f.sub(points[i], xj));
        }
    }
    let dinv = f.inv(denom);
    num.iter().map(|&c| f.mul(c, dinv)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::{Gf2e, GfPrime};

    fn f() -> GfPrime {
        GfPrime::new(786433).unwrap()
    }

    #[test]
    fn horner_matches_naive() {
        let f = f();
        let coeffs = [3u64, 0, 7, 123456, 1];
        for z in [0u64, 1, 2, 786432, 55555] {
            let mut naive = 0;
            for (i, &c) in coeffs.iter().enumerate() {
                naive = f.add(naive, f.mul(c, f.pow(z, i as u64)));
            }
            assert_eq!(eval(&f, &coeffs, z), naive);
        }
    }

    #[test]
    fn interpolation_roundtrip() {
        let f = f();
        let coeffs: Vec<u64> = (0..12).map(|i| f.elem(i * i * 37 + 11)).collect();
        let points: Vec<u64> = (1..=12).collect();
        let values = eval_many(&f, &coeffs, &points);
        let back = interpolate(&f, &points, &values);
        assert_eq!(back, coeffs);
    }

    #[test]
    fn interpolation_roundtrip_gf256() {
        let f = Gf2e::new(8).unwrap();
        let coeffs: Vec<u64> = (0..10).map(|i| (i * 29 + 3) % 256).collect();
        let points: Vec<u64> = (1..=10).collect();
        let values = eval_many(&f, &coeffs, &points);
        assert_eq!(interpolate(&f, &points, &values), coeffs);
    }

    #[test]
    fn from_roots_vanishes_on_roots() {
        let f = f();
        let roots = [5u64, 99, 1234, 786000];
        let poly = from_roots(&f, &roots);
        assert_eq!(poly.len(), 5);
        assert_eq!(*poly.last().unwrap(), 1); // monic
        for &r in &roots {
            assert_eq!(eval(&f, &poly, r), 0);
        }
        assert_ne!(eval(&f, &poly, 6), 0);
    }

    #[test]
    fn div_linear_inverts_mul() {
        let f = f();
        let q = [7u64, 3, 0, 9];
        let root = 42u64;
        let prod = mul(&f, &q, &[f.neg(root), 1]);
        assert_eq!(div_linear(&f, &prod, root), q);
    }

    #[test]
    fn lagrange_basis_is_indicator() {
        let f = f();
        let points = [2u64, 7, 100, 2024, 99999];
        for i in 0..points.len() {
            let li = lagrange_basis(&f, &points, i);
            for (j, &xj) in points.iter().enumerate() {
                let expect = if i == j { 1 } else { 0 };
                assert_eq!(eval(&f, &li, xj), expect);
            }
        }
    }
}

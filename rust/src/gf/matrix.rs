//! Dense matrices over a finite field.
//!
//! Row-major `Vec<u64>` storage; all operations take the field as an
//! explicit context argument. This is the *oracle* side of the repository:
//! collectives are verified against direct `x · C` products computed here.

use super::Field;

/// A dense `rows × cols` matrix over some `F_q` (elements in canonical form).
#[derive(Clone, PartialEq, Eq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    data: Vec<u64>,
}

impl std::fmt::Debug for Mat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for r in 0..self.rows.min(8) {
            writeln!(f, "  {:?}", &self.data[r * self.cols..(r + 1) * self.cols])?;
        }
        if self.rows > 8 {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

impl Mat {
    /// All-zero matrix.
    pub fn zero(rows: usize, cols: usize) -> Self {
        Mat {
            rows,
            cols,
            data: vec![0; rows * cols],
        }
    }

    /// Identity matrix.
    pub fn identity<F: Field>(f: &F, n: usize) -> Self {
        let mut m = Mat::zero(n, n);
        for i in 0..n {
            m[(i, i)] = f.one();
        }
        m
    }

    /// Build from a row-major closure.
    pub fn from_fn(rows: usize, cols: usize, mut gen: impl FnMut(usize, usize) -> u64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(gen(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    /// Build from nested slices (tests / examples).
    pub fn from_rows(rows: &[&[u64]]) -> Self {
        let cols = rows.first().map_or(0, |r| r.len());
        assert!(rows.iter().all(|r| r.len() == cols));
        Mat {
            rows: rows.len(),
            cols,
            data: rows.concat(),
        }
    }

    /// Uniformly random matrix (deterministic from `seed`).
    pub fn random<F: Field>(f: &F, rows: usize, cols: usize, seed: u64) -> Self {
        let mut rng = crate::util::Rng::new(seed);
        Mat::from_fn(rows, cols, |_, _| rng.below(f.order()))
    }

    /// Row `r` as a slice.
    pub fn row(&self, r: usize) -> &[u64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Column `c` as a fresh vector.
    pub fn col(&self, c: usize) -> Vec<u64> {
        (0..self.rows).map(|r| self[(r, c)]).collect()
    }

    /// Matrix product `self · rhs`.
    pub fn mul<F: Field>(&self, f: &F, rhs: &Mat) -> Mat {
        assert_eq!(self.cols, rhs.rows, "dimension mismatch");
        let mut out = Mat::zero(self.rows, rhs.cols);
        for r in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(r, k)];
                if a == 0 {
                    continue;
                }
                for c in 0..rhs.cols {
                    out[(r, c)] = f.mul_add(out[(r, c)], a, rhs[(k, c)]);
                }
            }
        }
        out
    }

    /// Row-vector product `x · self` (the encoding operation of Def. 1/4).
    pub fn vec_mul<F: Field>(&self, f: &F, x: &[u64]) -> Vec<u64> {
        assert_eq!(x.len(), self.rows);
        let mut out = vec![0u64; self.cols];
        let terms: Vec<(u64, &[u64])> = x
            .iter()
            .enumerate()
            .map(|(k, &xv)| (xv, self.row(k)))
            .collect();
        f.lincomb_into(&mut out, &terms);
        out
    }

    /// Packet-valued row-vector product `y = c · self`: coordinate `i`
    /// carries the packet `coords[i]` and packet `j` of the result is
    /// `Σ_i self[(i,j)]·c_i` element-wise over the packet width
    /// (Remark 2's `F_q^W` view) — the shared kernel of the erasure
    /// decoders
    /// ([`GrsCode::decode_packets`](crate::codes::GrsCode::decode_packets),
    /// `codes::recovery`). Returns one flat width-aware
    /// [`PacketBuf`](crate::net::PacketBuf) — a single allocation, not
    /// one heap vector per output packet.
    pub fn packet_vec_mul<F: Field>(&self, f: &F, coords: &[&[u64]]) -> crate::net::PacketBuf {
        assert_eq!(coords.len(), self.rows, "coordinate count");
        let w = coords.first().map_or(0, |p| p.len());
        let mut out = crate::net::PacketBuf::zeros(w, self.cols);
        for j in 0..self.cols {
            let terms: Vec<(u64, &[u64])> = coords
                .iter()
                .enumerate()
                .map(|(i, &pkt)| (self[(i, j)], pkt))
                .collect();
            f.lincomb_into(out.pkt_mut(j), &terms);
        }
        out
    }

    /// Transpose.
    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |r, c| self[(c, r)])
    }

    /// Horizontal concatenation `[self | rhs]`.
    pub fn hstack(&self, rhs: &Mat) -> Mat {
        assert_eq!(self.rows, rhs.rows);
        Mat::from_fn(self.rows, self.cols + rhs.cols, |r, c| {
            if c < self.cols {
                self[(r, c)]
            } else {
                rhs[(r, c - self.cols)]
            }
        })
    }

    /// Vertical concatenation `[self; below]`.
    pub fn vstack(&self, below: &Mat) -> Mat {
        assert_eq!(self.cols, below.cols);
        Mat {
            rows: self.rows + below.rows,
            cols: self.cols,
            data: [self.data.clone(), below.data.clone()].concat(),
        }
    }

    /// Sub-block `[r0, r0+rows) × [c0, c0+cols)`.
    pub fn block(&self, r0: usize, c0: usize, rows: usize, cols: usize) -> Mat {
        assert!(r0 + rows <= self.rows && c0 + cols <= self.cols);
        Mat::from_fn(rows, cols, |r, c| self[(r0 + r, c0 + c)])
    }

    /// Scale every entry.
    pub fn scale<F: Field>(&self, f: &F, s: u64) -> Mat {
        Mat::from_fn(self.rows, self.cols, |r, c| f.mul(self[(r, c)], s))
    }

    /// `self · diag(d)` — scale column `c` by `d[c]`.
    pub fn mul_diag<F: Field>(&self, f: &F, d: &[u64]) -> Mat {
        assert_eq!(d.len(), self.cols);
        Mat::from_fn(self.rows, self.cols, |r, c| f.mul(self[(r, c)], d[c]))
    }

    /// `diag(d) · self` — scale row `r` by `d[r]`.
    pub fn diag_mul<F: Field>(&self, f: &F, d: &[u64]) -> Mat {
        assert_eq!(d.len(), self.rows);
        Mat::from_fn(self.rows, self.cols, |r, c| f.mul(d[r], self[(r, c)]))
    }

    /// Gauss–Jordan inverse. Returns `None` for singular matrices.
    pub fn inverse<F: Field>(&self, f: &F) -> Option<Mat> {
        assert_eq!(self.rows, self.cols, "inverse of non-square matrix");
        let n = self.rows;
        let mut a = self.clone();
        let mut inv = Mat::identity(f, n);
        for col in 0..n {
            // Find pivot.
            let pivot = (col..n).find(|&r| a[(r, col)] != 0)?;
            if pivot != col {
                a.swap_rows(pivot, col);
                inv.swap_rows(pivot, col);
            }
            let pinv = f.inv(a[(col, col)]);
            for c in 0..n {
                a[(col, c)] = f.mul(a[(col, c)], pinv);
                inv[(col, c)] = f.mul(inv[(col, c)], pinv);
            }
            for r in 0..n {
                if r == col || a[(r, col)] == 0 {
                    continue;
                }
                let factor = a[(r, col)];
                for c in 0..n {
                    let t = f.mul(factor, a[(col, c)]);
                    a[(r, c)] = f.sub(a[(r, c)], t);
                    let t = f.mul(factor, inv[(col, c)]);
                    inv[(r, c)] = f.sub(inv[(r, c)], t);
                }
            }
        }
        Some(inv)
    }

    /// Rank via Gaussian elimination.
    pub fn rank<F: Field>(&self, f: &F) -> usize {
        let mut a = self.clone();
        let mut rank = 0;
        for col in 0..a.cols {
            let Some(pivot) = (rank..a.rows).find(|&r| a[(r, col)] != 0) else {
                continue;
            };
            a.swap_rows(pivot, rank);
            let pinv = f.inv(a[(rank, col)]);
            for r in rank + 1..a.rows {
                if a[(r, col)] == 0 {
                    continue;
                }
                let factor = f.mul(a[(r, col)], pinv);
                for c in col..a.cols {
                    let t = f.mul(factor, a[(rank, c)]);
                    a[(r, c)] = f.sub(a[(r, c)], t);
                }
            }
            rank += 1;
            if rank == a.rows {
                break;
            }
        }
        rank
    }

    /// Permute columns: `out[:, j] = self[:, perm[j]]`.
    pub fn permute_cols(&self, perm: &[usize]) -> Mat {
        assert_eq!(perm.len(), self.cols);
        Mat::from_fn(self.rows, self.cols, |r, c| self[(r, perm[c])])
    }

    /// Column gather: `out[:, j] = self[:, cols[j]]` for any index list
    /// (repeats allowed, any length) — e.g. the lost-sink parity columns
    /// an erasure-recovery operator reconstructs (`codes::recovery`).
    pub fn select_cols(&self, cols: &[usize]) -> Mat {
        assert!(cols.iter().all(|&c| c < self.cols), "column out of range");
        Mat::from_fn(self.rows, cols.len(), |r, j| self[(r, cols[j])])
    }

    fn swap_rows(&mut self, a: usize, b: usize) {
        if a == b {
            return;
        }
        for c in 0..self.cols {
            self.data.swap(a * self.cols + c, b * self.cols + c);
        }
    }
}

/// Column tile width (in elements) for the blocked gemm kernels: the
/// working set of one tile — an accumulator stripe plus the matching
/// stripes of the source rows — stays L1/L2-resident while every
/// coefficient row streams over it exactly once.
pub const GEMM_TILE: usize = 4096;

/// `out += coeffs · b` for one output row: `out[j] = Σ_k coeffs[k]·b[k][j]`
/// over the row-major `b` (`coeffs.len() × n`). `out` must be
/// zero-initialised (the kernel accumulates).
///
/// Column-tiled so long rows stay cache-resident, with the field's fused
/// reduction discipline per tile: delayed reduction for prime fields
/// (raw `c·s` products accumulate unreduced up to `lazy_chunk` terms,
/// one Barrett pass per chunk) and hoisted-log axpys for `GF(2^w)` —
/// both inherited from [`Field::lincomb_into`]. Zero coefficients are
/// skipped *before* chunking, so the per-element operation sequence is
/// identical to a sparse lincomb over the same nonzero terms — callers
/// relying on bit-identity with term-list evaluation (the plan replay
/// path) get it by construction.
pub fn gemm_row_into<F: Field>(f: &F, coeffs: &[u64], b: &[u64], n: usize, out: &mut [u64]) {
    assert_eq!(out.len(), n, "output row width mismatch");
    assert_eq!(b.len(), coeffs.len() * n, "source arena shape mismatch");
    let nz: Vec<(u64, usize)> = coeffs
        .iter()
        .enumerate()
        .filter(|(_, &c)| c != 0)
        .map(|(k, &c)| (c, k))
        .collect();
    // One term buffer reused across tiles — no per-tile allocation in
    // the hot loop, only the slice bounds are rewritten.
    let mut terms: Vec<(u64, &[u64])> = Vec::with_capacity(nz.len());
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + GEMM_TILE).min(n);
        terms.clear();
        terms.extend(nz.iter().map(|&(c, k)| (c, &b[k * n + j0..k * n + j1])));
        f.lincomb_into(&mut out[j0..j1], &terms);
        j0 = j1;
    }
}

/// Dense `out = a · b` over flat row-major buffers: `a` is `m × k`,
/// `b` is `k × n`, `out` is `m × n` and must be zero-initialised.
/// Row-by-row over [`gemm_row_into`] — callers wanting parallelism over
/// output rows split `out` into row chunks themselves (see
/// `net::exec::replay_batch`).
pub fn gemm_into<F: Field>(
    f: &F,
    m: usize,
    k: usize,
    a: &[u64],
    b: &[u64],
    n: usize,
    out: &mut [u64],
) {
    assert_eq!(a.len(), m * k, "lhs shape mismatch");
    assert_eq!(out.len(), m * n, "output shape mismatch");
    if n == 0 {
        return;
    }
    for (i, out_row) in out.chunks_mut(n).enumerate() {
        gemm_row_into(f, &a[i * k..(i + 1) * k], b, n, out_row);
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = u64;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &u64 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut u64 {
        &mut self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::GfPrime;

    fn f() -> GfPrime {
        GfPrime::new(786433).unwrap()
    }

    #[test]
    fn identity_is_neutral() {
        let f = f();
        let a = Mat::random(&f, 7, 7, 1);
        let i = Mat::identity(&f, 7);
        assert_eq!(a.mul(&f, &i), a);
        assert_eq!(i.mul(&f, &a), a);
    }

    #[test]
    fn inverse_roundtrip() {
        let f = f();
        for seed in 0..20u64 {
            let a = Mat::random(&f, 6, 6, seed);
            if let Some(ainv) = a.inverse(&f) {
                assert_eq!(a.mul(&f, &ainv), Mat::identity(&f, 6), "seed {seed}");
                assert_eq!(ainv.mul(&f, &a), Mat::identity(&f, 6), "seed {seed}");
            }
        }
    }

    #[test]
    fn singular_has_no_inverse() {
        let f = f();
        let mut a = Mat::random(&f, 5, 5, 3);
        let dup: Vec<u64> = a.row(0).to_vec();
        for c in 0..5 {
            a[(4, c)] = dup[c];
        }
        assert!(a.inverse(&f).is_none());
        assert!(a.rank(&f) < 5);
    }

    #[test]
    fn vec_mul_matches_mat_mul() {
        let f = f();
        let a = Mat::random(&f, 9, 5, 7);
        let x: Vec<u64> = (0..9).map(|i| f.elem(i * 31 + 5)).collect();
        let xm = Mat {
            rows: 1,
            cols: 9,
            data: x.clone(),
        };
        assert_eq!(a.vec_mul(&f, &x), xm.mul(&f, &a).data);
    }

    #[test]
    fn rank_of_random_square_is_full_whp() {
        let f = f();
        let a = Mat::random(&f, 8, 8, 11);
        assert_eq!(a.rank(&f), 8);
    }

    #[test]
    fn block_stack_roundtrip() {
        let f = f();
        let a = Mat::random(&f, 4, 6, 2);
        let top = a.block(0, 0, 2, 6);
        let bot = a.block(2, 0, 2, 6);
        assert_eq!(top.vstack(&bot), a);
        let l = a.block(0, 0, 4, 3);
        let r = a.block(0, 3, 4, 3);
        assert_eq!(l.hstack(&r), a);
    }

    #[test]
    fn transpose_involution() {
        let f = f();
        let a = Mat::random(&f, 3, 8, 5);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn permute_cols_by_identity() {
        let f = f();
        let a = Mat::random(&f, 4, 4, 9);
        let perm: Vec<usize> = (0..4).collect();
        assert_eq!(a.permute_cols(&perm), a);
    }

    #[test]
    fn select_cols_gathers_any_subset() {
        let f = f();
        let a = Mat::random(&f, 3, 5, 2);
        let s = a.select_cols(&[4, 1, 1]);
        assert_eq!((s.rows, s.cols), (3, 3));
        for r in 0..3 {
            assert_eq!(s[(r, 0)], a[(r, 4)]);
            assert_eq!(s[(r, 1)], a[(r, 1)]);
            assert_eq!(s[(r, 2)], a[(r, 1)]);
        }
    }

    #[test]
    fn gemm_matches_mat_mul_prime() {
        let f = f();
        // n spans below/at/above one tile so the tiling seam is exercised.
        for (m, k, n) in [(3usize, 5usize, 7usize), (4, 8, GEMM_TILE), (2, 6, GEMM_TILE + 37)] {
            let a = Mat::random(&f, m, k, (m * k) as u64);
            let b = Mat::random(&f, k, n, (k * n) as u64);
            let oracle = a.mul(&f, &b);
            let a_flat: Vec<u64> = (0..m).flat_map(|i| a.row(i).to_vec()).collect();
            let b_flat: Vec<u64> = (0..k).flat_map(|i| b.row(i).to_vec()).collect();
            let mut out = vec![0u64; m * n];
            gemm_into(&f, m, k, &a_flat, &b_flat, n, &mut out);
            for i in 0..m {
                assert_eq!(&out[i * n..(i + 1) * n], oracle.row(i), "row {i} (m={m} k={k} n={n})");
            }
        }
    }

    #[test]
    fn gemm_matches_mat_mul_gf2e() {
        let f = crate::gf::Gf2e::new(8).unwrap();
        let (m, k, n) = (5usize, 9usize, 100usize);
        let a = Mat::random(&f, m, k, 21);
        let b = Mat::random(&f, k, n, 22);
        let oracle = a.mul(&f, &b);
        let a_flat: Vec<u64> = (0..m).flat_map(|i| a.row(i).to_vec()).collect();
        let b_flat: Vec<u64> = (0..k).flat_map(|i| b.row(i).to_vec()).collect();
        let mut out = vec![0u64; m * n];
        gemm_into(&f, m, k, &a_flat, &b_flat, n, &mut out);
        for i in 0..m {
            assert_eq!(&out[i * n..(i + 1) * n], oracle.row(i), "row {i}");
        }
    }

    #[test]
    fn gemm_row_bit_identical_to_sparse_lincomb() {
        // The replay path's contract: dense-row evaluation with zeros
        // skipped must equal the sparse term-list evaluation bit for bit
        // (same term order, same chunk boundaries) — including across a
        // GEMM_TILE seam, where the tiled kernel splits one logical
        // lincomb into several `lincomb_into` calls.
        let f = f();
        let k = 40usize;
        for n in [130usize, GEMM_TILE + 37] {
            let mut rng = crate::util::Rng::new(77);
            let mut coeffs: Vec<u64> = (0..k).map(|_| rng.below(f.order())).collect();
            for z in [0usize, 3, 7, 11, 39] {
                coeffs[z] = 0; // interleave zeros
            }
            let b: Vec<u64> = (0..k * n).map(|_| rng.below(f.order())).collect();
            let mut dense = vec![0u64; n];
            gemm_row_into(&f, &coeffs, &b, n, &mut dense);
            let terms: Vec<(u64, &[u64])> = coeffs
                .iter()
                .enumerate()
                .filter(|(_, &c)| c != 0)
                .map(|(i, &c)| (c, &b[i * n..(i + 1) * n]))
                .collect();
            let mut sparse = vec![0u64; n];
            f.lincomb_into(&mut sparse, &terms);
            assert_eq!(dense, sparse, "n={n}");
        }
    }
}

//! Number-theoretic transform — the `O(n log n)` *local computation*
//! counterpart of §V-A's *in-network* FFT.
//!
//! The paper distributes the Cooley–Tukey recursion across processors
//! (each §V-A step is one butterfly level, executed as grouped A2As).
//! Locally, the same recursion gives each processor a fast way to
//! evaluate/interpolate on structured point sets — used by the codes
//! layer for `O(n log n)` RS encode/decode over the default NTT-friendly
//! prime (`q = 786433 = 3·2^18 + 1` supports power-of-two sizes up to
//! `2^18`).

use super::Field;

/// In-place radix-2 decimation-in-time NTT (size `n = 2^s | q−1`),
/// bit-reversed input order handled internally: `data[j] ← f(β^j)` for
/// the polynomial with coefficients `data` and `β` the primitive `n`-th
/// root.
pub fn ntt<F: Field>(f: &F, data: &mut [u64]) -> anyhow::Result<()> {
    transform(f, data, false)
}

/// Inverse NTT: evaluations at all `n`-th roots → coefficients.
pub fn intt<F: Field>(f: &F, data: &mut [u64]) -> anyhow::Result<()> {
    transform(f, data, true)?;
    let n_inv = f.inv(f.elem(data.len() as u64));
    for x in data.iter_mut() {
        *x = f.mul(*x, n_inv);
    }
    Ok(())
}

fn transform<F: Field>(f: &F, data: &mut [u64], invert: bool) -> anyhow::Result<()> {
    let n = data.len();
    anyhow::ensure!(n.is_power_of_two(), "NTT size must be a power of two");
    // n ≤ 1 is the identity transform: a degree-0 polynomial already *is*
    // its evaluation at the sole 1st root of unity. (Also keeps the
    // bit-reversal below well-defined — `bits = 0` would shift by 64.)
    if n <= 1 {
        return Ok(());
    }
    let mut root = f
        .root_of_unity(n as u64)
        .ok_or_else(|| anyhow::anyhow!("{n} must divide q−1"))?;
    if invert {
        root = f.inv(root);
    }
    // Bit-reversal permutation.
    let bits = n.trailing_zeros();
    for i in 0..n {
        let j = (i as u64).reverse_bits() >> (64 - bits) as u64;
        let j = j as usize;
        if i < j {
            data.swap(i, j);
        }
    }
    // Butterfly levels.
    let mut len = 2;
    while len <= n {
        let wlen = f.pow(root, (n / len) as u64);
        for start in (0..n).step_by(len) {
            let mut w = f.one();
            for i in 0..len / 2 {
                let u = data[start + i];
                let v = f.mul(data[start + i + len / 2], w);
                data[start + i] = f.add(u, v);
                data[start + i + len / 2] = f.sub(u, v);
                w = f.mul(w, wlen);
            }
        }
        len <<= 1;
    }
    Ok(())
}

/// Row-batched forward NTT: `data` is a row-major `n × width` arena and
/// every *column* is transformed independently — the butterflies run on
/// whole rows, so one twiddle fetch serves `width` lanes. This is the
/// columnar-serving counterpart of [`evaluate_at_roots`]: with the rows
/// holding a polynomial's coefficients per column, row `j` ends up with
/// the evaluations at `β^j` (`β` the primitive `n`-th root), for all
/// `width` columns at once. Used by the optimizer's NTT encode backend
/// over the `W·B` batch arena (`net::opt::NttBackend`).
pub fn ntt_rows<F: Field>(f: &F, data: &mut [u64], n: usize, width: usize) -> anyhow::Result<()> {
    transform_rows(f, data, n, width, false)
}

/// Row-batched inverse NTT — see [`ntt_rows`]; scales by `n^{-1}`.
pub fn intt_rows<F: Field>(f: &F, data: &mut [u64], n: usize, width: usize) -> anyhow::Result<()> {
    transform_rows(f, data, n, width, true)?;
    let n_inv = f.inv(f.elem(n as u64));
    for x in data.iter_mut() {
        *x = f.mul(*x, n_inv);
    }
    Ok(())
}

fn transform_rows<F: Field>(
    f: &F,
    data: &mut [u64],
    n: usize,
    width: usize,
    invert: bool,
) -> anyhow::Result<()> {
    anyhow::ensure!(n.is_power_of_two(), "NTT size must be a power of two");
    anyhow::ensure!(data.len() == n * width, "arena must be n × width");
    if n <= 1 || width == 0 {
        return Ok(());
    }
    let mut root = f
        .root_of_unity(n as u64)
        .ok_or_else(|| anyhow::anyhow!("{n} must divide q−1"))?;
    if invert {
        root = f.inv(root);
    }
    // Bit-reversal permutation of whole rows.
    let bits = n.trailing_zeros();
    for i in 0..n {
        let j = ((i as u64).reverse_bits() >> (64 - bits) as u64) as usize;
        if i < j {
            for x in 0..width {
                data.swap(i * width + x, j * width + x);
            }
        }
    }
    // Butterfly levels, each pairing operating element-wise on two rows.
    let mut len = 2;
    while len <= n {
        let wlen = f.pow(root, (n / len) as u64);
        for start in (0..n).step_by(len) {
            let mut w = f.one();
            for i in 0..len / 2 {
                let ui = (start + i) * width;
                let vi = (start + i + len / 2) * width;
                for x in 0..width {
                    let u = data[ui + x];
                    let v = f.mul(data[vi + x], w);
                    data[ui + x] = f.add(u, v);
                    data[vi + x] = f.sub(u, v);
                }
                w = f.mul(w, wlen);
            }
        }
        len <<= 1;
    }
    Ok(())
}

/// Multiply two polynomials in `O(n log n)` via NTT (prime fields with
/// enough 2-adicity; falls back to the caller's schoolbook for others).
pub fn poly_mul_fast<F: Field>(f: &F, a: &[u64], b: &[u64]) -> anyhow::Result<Vec<u64>> {
    if a.is_empty() || b.is_empty() {
        return Ok(vec![]);
    }
    let out_len = a.len() + b.len() - 1;
    let n = out_len.next_power_of_two();
    let mut fa = a.to_vec();
    fa.resize(n, 0);
    let mut fb = b.to_vec();
    fb.resize(n, 0);
    ntt(f, &mut fa)?;
    ntt(f, &mut fb)?;
    for (x, y) in fa.iter_mut().zip(&fb) {
        *x = f.mul(*x, *y);
    }
    intt(f, &mut fa)?;
    fa.truncate(out_len);
    Ok(fa)
}

/// Evaluate a polynomial at *all* `n`-th roots of unity in `O(n log n)`
/// (the bulk-evaluation primitive behind fast RS encoding).
pub fn evaluate_at_roots<F: Field>(f: &F, coeffs: &[u64], n: usize) -> anyhow::Result<Vec<u64>> {
    anyhow::ensure!(coeffs.len() <= n, "degree must be < n");
    let mut data = coeffs.to_vec();
    data.resize(n, 0);
    ntt(f, &mut data)?;
    Ok(data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::{poly, GfPrime};

    fn f() -> GfPrime {
        GfPrime::default_field()
    }

    #[test]
    fn ntt_matches_naive_evaluation() {
        let f = f();
        for n in [2usize, 8, 64, 256] {
            let coeffs: Vec<u64> = (0..n as u64).map(|i| f.elem(i * 37 + 5)).collect();
            let beta = f.root_of_unity(n as u64).unwrap();
            let fast = evaluate_at_roots(&f, &coeffs, n).unwrap();
            for j in 0..n {
                let pt = f.pow(beta, j as u64);
                assert_eq!(fast[j], poly::eval(&f, &coeffs, pt), "n={n} j={j}");
            }
        }
    }

    #[test]
    fn intt_inverts_ntt() {
        let f = f();
        let orig: Vec<u64> = (0..128u64).map(|i| f.elem(i * i + 3)).collect();
        let mut data = orig.clone();
        ntt(&f, &mut data).unwrap();
        intt(&f, &mut data).unwrap();
        assert_eq!(data, orig);
    }

    #[test]
    fn fast_poly_mul_matches_schoolbook() {
        let f = f();
        let a: Vec<u64> = (1..=33u64).collect();
        let b: Vec<u64> = (5..=24u64).map(|i| f.elem(i * 11)).collect();
        assert_eq!(poly_mul_fast(&f, &a, &b).unwrap(), poly::mul(&f, &a, &b));
    }

    #[test]
    fn size_one_transform_is_identity() {
        // Regression: n = 1 used to shift the bit-reversal index by 64
        // (a debug-build panic). A constant polynomial is its own
        // evaluation/interpolation at the sole 1st root of unity.
        let f = f();
        let mut d = vec![42u64];
        ntt(&f, &mut d).unwrap();
        assert_eq!(d, vec![42]);
        intt(&f, &mut d).unwrap();
        assert_eq!(d, vec![42]);
        assert_eq!(evaluate_at_roots(&f, &[7], 1).unwrap(), vec![7]);
        // Reachable from poly_mul_fast on two constants: out_len = 1.
        assert_eq!(poly_mul_fast(&f, &[3], &[5]).unwrap(), vec![15]);
        assert_eq!(
            poly_mul_fast(&f, &[786432], &[2]).unwrap(),
            vec![f.mul(786432, 2)]
        );
        // And the row-batched variants degrade the same way.
        let mut rows = vec![9u64, 8, 7];
        ntt_rows(&f, &mut rows, 1, 3).unwrap();
        assert_eq!(rows, vec![9, 8, 7]);
        intt_rows(&f, &mut rows, 1, 3).unwrap();
        assert_eq!(rows, vec![9, 8, 7]);
    }

    #[test]
    fn row_transforms_match_per_column_transforms() {
        let f = f();
        for (n, width) in [(2usize, 1usize), (8, 3), (64, 5), (256, 2)] {
            let mut rng = crate::util::Rng::new((n * width) as u64);
            let arena: Vec<u64> = (0..n * width).map(|_| rng.below(f.order())).collect();
            for invert in [false, true] {
                let mut rows = arena.clone();
                if invert {
                    intt_rows(&f, &mut rows, n, width).unwrap();
                } else {
                    ntt_rows(&f, &mut rows, n, width).unwrap();
                }
                for col in 0..width {
                    let mut column: Vec<u64> =
                        (0..n).map(|i| arena[i * width + col]).collect();
                    if invert {
                        intt(&f, &mut column).unwrap();
                    } else {
                        ntt(&f, &mut column).unwrap();
                    }
                    for i in 0..n {
                        assert_eq!(
                            rows[i * width + col],
                            column[i],
                            "n={n} width={width} invert={invert} row {i} col {col}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn rejects_unsupported_sizes() {
        let f = f();
        let mut d = vec![1u64; 3];
        assert!(ntt(&f, &mut d).is_err()); // not a power of two
        let mut d = vec![1u64; 1 << 19];
        assert!(ntt(&f, &mut d).is_err()); // 2^19 ∤ q−1
    }

    #[test]
    fn matches_dft_matrix_product() {
        // The NTT is exactly multiplication by D_n (eq. (8)).
        let f = f();
        let n = 16usize;
        let coeffs: Vec<u64> = (0..n as u64).map(|i| f.elem(i + 2)).collect();
        let d = crate::gf::dft::dft_matrix(&f, n).unwrap();
        let slow = d.vec_mul(&f, &coeffs);
        assert_eq!(evaluate_at_roots(&f, &coeffs, n).unwrap(), slow);
    }
}

//! Finite-field substrate.
//!
//! Everything in the paper happens over a finite field `F_q`: the data
//! symbols, the coding matrices, and the coefficients processors apply to
//! previously received packets. This module provides
//!
//! * [`Field`] — the arithmetic interface all collectives are generic over,
//! * [`GfPrime`] — prime fields `F_p`, `p < 2^31` (Barrett reduction),
//! * [`Gf2e`] — binary extension fields `GF(2^w)`, `w ≤ 16` (log tables),
//! * [`kernels`] — packed-symbol storage ([`SymbolLayout`]/[`PackedBuf`])
//!   and the per-field vectorized kernel vtable ([`Kernels`]) behind the
//!   batched serving hot path,
//! * [`simd`] — explicit AVX2/NEON backends for those kernels, selected
//!   once per plan by runtime detection ([`IsaTier`]) with the scalar
//!   loops as the portable fallback and bit-identity oracle,
//! * dense [`matrix`] algebra, [`poly`]nomials and Lagrange interpolation,
//! * structured matrices: [`vandermonde`], [`cauchy`] (eq. (24) of the
//!   paper) and [`dft`] (§V-A).
//!
//! Field elements are represented uniformly as `u64` values in canonical
//! form (`< q`); the field object carries the modulus/tables so collectives
//! can be monomorphised per field kind.

pub mod cauchy;
pub mod dft;
pub mod gf2e;
pub mod kernels;
pub mod matrix;
pub mod ntt;
pub mod poly;
pub mod prime;
pub mod simd;
pub mod vandermonde;

pub use cauchy::CauchyLike;
pub use gf2e::Gf2e;
pub use kernels::{Kernels, PackedBuf, SymbolLayout};
pub use matrix::Mat;
pub use prime::GfPrime;
pub use simd::{IsaRequest, IsaTier};

/// A finite field `F_q` with elements canonically represented as `u64 < q`.
///
/// Implementations must be cheap to clone (collectives clone them freely);
/// table-based fields should wrap their tables in `Arc`.
pub trait Field: Clone + Send + Sync + std::fmt::Debug + 'static {
    /// The field order `q`.
    fn order(&self) -> u64;

    /// The multiplicative identity.
    fn one(&self) -> u64 {
        1
    }

    /// The additive identity.
    fn zero(&self) -> u64 {
        0
    }

    /// `⌈log2 q⌉` — the number of bits a symbol occupies on the wire
    /// (the `⌈log2 q⌉` factor of the paper's cost `C = αC1 + β⌈log2 q⌉C2`).
    fn bits(&self) -> u32 {
        64 - (self.order() - 1).leading_zeros()
    }

    /// Addition in `F_q`.
    fn add(&self, a: u64, b: u64) -> u64;

    /// Subtraction in `F_q`.
    fn sub(&self, a: u64, b: u64) -> u64;

    /// Additive inverse.
    fn neg(&self, a: u64) -> u64 {
        self.sub(0, a)
    }

    /// Multiplication in `F_q`.
    fn mul(&self, a: u64, b: u64) -> u64;

    /// Multiplicative inverse. Panics on zero.
    fn inv(&self, a: u64) -> u64;

    /// Division `a / b`. Panics on `b == 0`.
    fn div(&self, a: u64, b: u64) -> u64 {
        self.mul(a, self.inv(b))
    }

    /// Exponentiation by squaring; `pow(0, 0) == 1` by convention.
    fn pow(&self, a: u64, mut e: u64) -> u64 {
        let mut base = a;
        let mut acc = self.one();
        while e > 0 {
            if e & 1 == 1 {
                acc = self.mul(acc, base);
            }
            base = self.mul(base, base);
            e >>= 1;
        }
        acc
    }

    /// A generator of the multiplicative group `F_q^*`.
    fn generator(&self) -> u64;

    /// Canonicalise an arbitrary `u64` into the field (`x mod q`).
    fn elem(&self, x: u64) -> u64 {
        x % self.order()
    }

    /// `a + b*c` — the fused op of every coding-scheme inner loop.
    fn mul_add(&self, a: u64, b: u64, c: u64) -> u64 {
        self.add(a, self.mul(b, c))
    }

    /// A primitive `n`-th root of unity, if `n | q - 1`.
    fn root_of_unity(&self, n: u64) -> Option<u64> {
        let q1 = self.order() - 1;
        if n == 0 || q1 % n != 0 {
            return None;
        }
        Some(self.pow(self.generator(), q1 / n))
    }

    /// Lazy-reduction primitives — the hot-loop interface.
    ///
    /// `lazy_chunk()` terms may be accumulated with `lazy_mul_acc` before
    /// a `lazy_reduce` pass is required. Prime fields accumulate raw
    /// `c·s` products (thousands fit in a `u64` for `p < 2^20`); `GF(2^w)`
    /// accumulates with XOR, which never overflows. The defaults reduce
    /// every term. See DESIGN.md §Perf.
    fn lazy_chunk(&self) -> usize {
        1
    }

    /// One (possibly unreduced) accumulation step `acc ⊞ c·s`.
    #[inline(always)]
    fn lazy_mul_acc(&self, acc: u64, c: u64, s: u64) -> u64 {
        self.mul_add(acc, c, s)
    }

    /// Canonicalise a lazily-accumulated value.
    #[inline(always)]
    fn lazy_reduce(&self, x: u64) -> u64 {
        x
    }

    /// `acc[i] += Σ_t coeffs[t]·srcs[t][i]` — the hot loop of every coding
    /// scheme (shoot-phase initialisation, local combines, oracles),
    /// implemented over the lazy primitives.
    fn lincomb_into(&self, acc: &mut [u64], terms: &[(u64, &[u64])]) {
        for group in terms.chunks(self.lazy_chunk()) {
            for &(c, src) in group {
                if c == 0 {
                    continue;
                }
                debug_assert_eq!(acc.len(), src.len());
                for (a, &s) in acc.iter_mut().zip(src) {
                    *a = self.lazy_mul_acc(*a, c, s);
                }
            }
            for a in acc.iter_mut() {
                *a = self.lazy_reduce(*a);
            }
        }
    }

    /// `acc[i] += c·src[i]` — the single-term axpy over contiguous slices.
    ///
    /// The default applies `mul_add` per element; field implementations
    /// override with fused kernels (one Barrett reduction per element for
    /// prime fields, a hoisted `log c` for `GF(2^w)`).
    fn axpy_into(&self, acc: &mut [u64], c: u64, src: &[u64]) {
        if c == 0 {
            return;
        }
        debug_assert_eq!(acc.len(), src.len());
        for (a, &s) in acc.iter_mut().zip(src) {
            *a = self.mul_add(*a, c, s);
        }
    }

    /// `dst[i] = c·src[i]` over contiguous slices.
    fn scale_slice(&self, dst: &mut [u64], c: u64, src: &[u64]) {
        debug_assert_eq!(dst.len(), src.len());
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = self.mul(c, s);
        }
    }
}

/// Runtime-selected field (CLI / config layer).
#[derive(Clone, Debug)]
pub enum AnyField {
    Prime(GfPrime),
    Ext(Gf2e),
}

impl AnyField {
    /// Parse a field spec: `"prime:786433"` / `"786433"` / `"gf2e:8"`.
    pub fn parse(spec: &str) -> anyhow::Result<Self> {
        if let Some(rest) = spec.strip_prefix("gf2e:") {
            let w: u32 = rest.parse()?;
            Ok(AnyField::Ext(Gf2e::new(w)?))
        } else {
            let p: u64 = spec.strip_prefix("prime:").unwrap_or(spec).parse()?;
            Ok(AnyField::Prime(GfPrime::new(p)?))
        }
    }
}

macro_rules! dispatch {
    ($self:ident, $f:ident ( $($arg:expr),* )) => {
        match $self {
            AnyField::Prime(g) => g.$f($($arg),*),
            AnyField::Ext(g) => g.$f($($arg),*),
        }
    };
}

impl Field for AnyField {
    fn order(&self) -> u64 {
        dispatch!(self, order())
    }
    fn add(&self, a: u64, b: u64) -> u64 {
        dispatch!(self, add(a, b))
    }
    fn sub(&self, a: u64, b: u64) -> u64 {
        dispatch!(self, sub(a, b))
    }
    fn mul(&self, a: u64, b: u64) -> u64 {
        dispatch!(self, mul(a, b))
    }
    fn inv(&self, a: u64) -> u64 {
        dispatch!(self, inv(a))
    }
    fn generator(&self) -> u64 {
        dispatch!(self, generator())
    }
    fn elem(&self, x: u64) -> u64 {
        dispatch!(self, elem(x))
    }
    fn lincomb_into(&self, acc: &mut [u64], terms: &[(u64, &[u64])]) {
        dispatch!(self, lincomb_into(acc, terms))
    }
    fn axpy_into(&self, acc: &mut [u64], c: u64, src: &[u64]) {
        dispatch!(self, axpy_into(acc, c, src))
    }
    fn scale_slice(&self, dst: &mut [u64], c: u64, src: &[u64]) {
        dispatch!(self, scale_slice(dst, c, src))
    }
    fn lazy_chunk(&self) -> usize {
        dispatch!(self, lazy_chunk())
    }
    fn lazy_mul_acc(&self, acc: u64, c: u64, s: u64) -> u64 {
        dispatch!(self, lazy_mul_acc(acc, c, s))
    }
    fn lazy_reduce(&self, x: u64) -> u64 {
        dispatch!(self, lazy_reduce(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn any_field_parse() {
        let f = AnyField::parse("786433").unwrap();
        assert_eq!(f.order(), 786433);
        let f = AnyField::parse("prime:65537").unwrap();
        assert_eq!(f.order(), 65537);
        let f = AnyField::parse("gf2e:8").unwrap();
        assert_eq!(f.order(), 256);
        assert_eq!(f.bits(), 8);
    }

    #[test]
    fn bits_is_ceil_log2_q() {
        assert_eq!(AnyField::parse("786433").unwrap().bits(), 20);
        assert_eq!(AnyField::parse("65537").unwrap().bits(), 17);
        assert_eq!(AnyField::parse("gf2e:4").unwrap().bits(), 4);
    }
}

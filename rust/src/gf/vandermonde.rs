//! Vandermonde matrices and their structured inverses.
//!
//! Convention (matching the paper, §V): the matrix is indexed
//! `V[i][j] = x_j^i` — *column* `j` holds the powers of evaluation point
//! `x_j`, so the all-to-all encode `x · V` hands processor `j` the
//! evaluation `f(x_j)` of the data polynomial.

use super::{poly, Field, Mat};

/// `rows × points.len()` Vandermonde: `V[i][j] = points[j]^i`.
pub fn vandermonde<F: Field>(f: &F, rows: usize, points: &[u64]) -> Mat {
    let mut m = Mat::zero(rows, points.len());
    for (j, &x) in points.iter().enumerate() {
        let mut p = f.one();
        for i in 0..rows {
            m[(i, j)] = p;
            p = f.mul(p, x);
        }
    }
    m
}

/// Square Vandermonde on `points`.
pub fn square<F: Field>(f: &F, points: &[u64]) -> Mat {
    vandermonde(f, points.len(), points)
}

/// Inverse of the square Vandermonde on distinct `points`, via Lagrange
/// basis coefficients (eq. (28)): row `j` of `V^{-1}` is the coefficient
/// vector of `ℓ_j(z)`, since `(V^{-1}·V)[j][j'] = ℓ_j(x_{j'}) = δ_{jj'}`.
/// `O(n²)` instead of Gauss–Jordan's `O(n³)`.
pub fn inverse<F: Field>(f: &F, points: &[u64]) -> Mat {
    let n = points.len();
    let master = poly::from_roots(f, points);
    let mut m = Mat::zero(n, n);
    for j in 0..n {
        let num = poly::div_linear(f, &master, points[j]);
        let mut denom = f.one();
        for (t, &xt) in points.iter().enumerate() {
            if t != j {
                denom = f.mul(denom, f.sub(points[j], xt));
            }
        }
        let dinv = f.inv(denom);
        for (i, &c) in num.iter().enumerate() {
            m[(j, i)] = f.mul(c, dinv);
        }
    }
    m
}

/// Check that all points are distinct (a Vandermonde is invertible iff so).
pub fn points_distinct(points: &[u64]) -> bool {
    let mut sorted = points.to_vec();
    sorted.sort_unstable();
    sorted.windows(2).all(|w| w[0] != w[1])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::{Field, Gf2e, GfPrime};

    #[test]
    fn structured_inverse_matches_gauss_jordan() {
        let f = GfPrime::new(786433).unwrap();
        let points = [3u64, 17, 86, 1000, 786432, 12];
        let v = square(&f, &points);
        let fast = inverse(&f, &points);
        let slow = v.inverse(&f).unwrap();
        assert_eq!(fast, slow);
        assert_eq!(v.mul(&f, &fast), Mat::identity(&f, 6));
    }

    #[test]
    fn inverse_in_gf256() {
        let f = Gf2e::new(8).unwrap();
        let points: Vec<u64> = (1..=9).collect();
        let v = square(&f, &points);
        let vinv = inverse(&f, &points);
        assert_eq!(v.mul(&f, &vinv), Mat::identity(&f, 9));
    }

    #[test]
    fn encode_is_polynomial_evaluation() {
        let f = GfPrime::new(786433).unwrap();
        let points = [9u64, 81, 7, 55];
        let v = square(&f, &points);
        let x = [5u64, 0, 3, 786001];
        let y = v.vec_mul(&f, &x);
        for (j, &pt) in points.iter().enumerate() {
            assert_eq!(y[j], poly::eval(&f, &x, pt));
        }
    }

    #[test]
    fn rectangular_vandermonde_shape() {
        let f = GfPrime::new(65537).unwrap();
        let v = vandermonde(&f, 3, &[1, 2, 3, 4, 5]);
        assert_eq!((v.rows, v.cols), (3, 5));
        assert_eq!(v[(2, 3)], f.pow(4, 2));
    }

    #[test]
    fn distinctness_guard() {
        assert!(points_distinct(&[1, 2, 3]));
        assert!(!points_distinct(&[1, 2, 1]));
    }
}

//! `dce` — the launcher.
//!
//! ```text
//! dce run [--config FILE] [--k N --r N --w N --ports N --algorithm A ...]
//! dce table1 [--ports-max P]          # regenerate Table I rows
//! dce sweep --what rs|baselines       # cost-comparison sweeps
//! dce service [--workers N --requests N --w N]
//! dce info
//! ```
//!
//! (Hand-rolled argument parsing: the offline environment has no clap.)

use anyhow::{Context, Result};
use dce::coordinator::{EncodeJob, ExecOptions, JobConfig};
use dce::framework::costs;
use dce::gf::{Field, GfPrime};
use std::collections::HashMap;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match args.split_first() {
        Some((c, rest)) => (c.as_str(), rest),
        None => {
            print_usage();
            return Ok(());
        }
    };
    let flags = parse_flags(rest)?;
    match cmd {
        "run" => cmd_run(&flags),
        "table1" => cmd_table1(&flags),
        "sweep" => cmd_sweep(&flags),
        "service" => cmd_service(&flags),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => anyhow::bail!("unknown command {other:?} (try `dce help`)"),
    }
}

fn print_usage() {
    println!(
        "dce — Decentralized Coding Engine\n\
         \n\
         USAGE:\n\
         \x20 dce run      [--config FILE] [--k N] [--r N] [--w N] [--ports N]\n\
         \x20              [--algorithm auto|rs-specific|universal|multi-reduce|direct]\n\
         \x20              [--code rs-structured|rs-plain|lagrange|random]\n\
         \x20              [--verify native|freivalds|pjrt|off] [--alpha F] [--beta F] [--json]\n\
         \x20              [--engine live|replay|peer-channel|peer-shmem|peer-tcp]\n\
         \x20              (DCE_TRANSPORT=channel|shmem|tcp selects the peer engine by env)\n\
         \x20 dce table1   [--ports-max P]      regenerate Table I (measured vs formula)\n\
         \x20 dce sweep    --what rs|baselines  cost-comparison sweeps\n\
         \x20 dce service  [--workers N] [--requests N] [--w N]\n\
         \x20 dce info                          environment / artifact status"
    );
}

fn parse_flags(rest: &[String]) -> Result<HashMap<String, String>> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < rest.len() {
        let k = rest[i]
            .strip_prefix("--")
            .with_context(|| format!("expected --flag, got {:?}", rest[i]))?;
        if k == "json" {
            out.insert("json".into(), "true".into());
            i += 1;
            continue;
        }
        let v = rest
            .get(i + 1)
            .with_context(|| format!("--{k} needs a value"))?;
        out.insert(k.to_string(), v.clone());
        i += 2;
    }
    Ok(out)
}

fn config_from_flags(flags: &HashMap<String, String>) -> Result<JobConfig> {
    let mut cfg = match flags.get("config") {
        Some(path) => JobConfig::load(std::path::Path::new(path))?,
        None => JobConfig::default(),
    };
    if let Some(v) = flags.get("k") {
        cfg.k = v.parse()?;
    }
    if let Some(v) = flags.get("r") {
        cfg.r = v.parse()?;
    }
    if let Some(v) = flags.get("w") {
        cfg.w = v.parse()?;
    }
    if let Some(v) = flags.get("ports") {
        cfg.ports = v.parse()?;
    }
    if let Some(v) = flags.get("alpha") {
        cfg.alpha = v.parse()?;
    }
    if let Some(v) = flags.get("beta") {
        cfg.beta = v.parse()?;
    }
    if let Some(v) = flags.get("field") {
        cfg.field = v.clone();
    }
    if let Some(v) = flags.get("code") {
        cfg.code = v.parse()?;
    }
    if let Some(v) = flags.get("algorithm") {
        cfg.algorithm = v.parse()?;
    }
    if let Some(v) = flags.get("verify") {
        cfg.verify = v.parse()?;
    }
    if let Some(v) = flags.get("seed") {
        cfg.seed = v.parse()?;
    }
    if let Some(v) = flags.get("engine") {
        cfg.engine = v.parse()?;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_run(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = config_from_flags(flags)?;
    // `DCE_TRANSPORT=channel|shmem|tcp` selects the peer engine when no
    // explicit engine was configured (the CI transport matrix uses it).
    let engine = match cfg.engine {
        dce::coordinator::Engine::Live => dce::net::transport::TransportKind::from_env()
            .map(dce::coordinator::Engine::Peer)
            .unwrap_or(cfg.engine),
        e => e,
    };
    let job = EncodeJob::synthetic(cfg)?;
    let report = job.run(&ExecOptions::new().engine(engine))?;
    if flags.contains_key("json") {
        println!("{}", report.to_json());
    } else {
        println!("{report}");
    }
    if report.verified == Some(false) {
        anyhow::bail!("verification failed");
    }
    Ok(())
}

fn cmd_table1(flags: &HashMap<String, String>) -> Result<()> {
    let pmax: usize = flags.get("ports-max").map_or(Ok(2), |v| v.parse())?;
    println!("Table I — all-to-all encode costs (measured vs formula)");
    println!(
        "{:<10} {:>3} {:>4}  {:>8} {:>8}  {:>8} {:>8}  {:>10}",
        "algorithm", "p", "K", "C1 meas", "C1 form", "C2 meas", "C2 form", "C2 lower"
    );
    let f = GfPrime::default_field();
    for p in 1..=pmax {
        for k in [16usize, 64, 256, 1024] {
            let (rep, _) = support::run_universal(&f, k, p, k as u64)?;
            let (c1f, c2f) = costs::theorem3_universal(k as u64, p as u64);
            let lb = costs::lemma2_c2_lower_bound(k as u64, p as u64);
            println!(
                "{:<10} {:>3} {:>4}  {:>8} {:>8}  {:>8} {:>8}  {:>10.1}",
                "universal", p, k, rep.c1, c1f, rep.c2, c2f, lb
            );
        }
    }
    for (p_base, h) in [(2u64, 4u32), (2, 8), (4, 4)] {
        let k = dce::util::ipow(p_base, h) as usize;
        let (rep, _) = support::run_dft(&f, p_base, h, 1)?;
        let (c1f, c2f) = costs::theorem4_dft(p_base, h, 1);
        println!(
            "{:<10} {:>3} {:>4}  {:>8} {:>8}  {:>8} {:>8}  {:>10}",
            "dft", 1, k, rep.c1, c1f, rep.c2, c2f, "-"
        );
    }
    Ok(())
}

fn cmd_sweep(flags: &HashMap<String, String>) -> Result<()> {
    let what = flags.get("what").map(|s| s.as_str()).unwrap_or("rs");
    match what {
        "rs" => {
            println!("systematic RS: specific vs universal (C2, one port, W=1)");
            println!(
                "{:>5} {:>5}  {:>10} {:>10} {:>8}",
                "K", "R", "specific", "universal", "gain"
            );
            let f = GfPrime::default_field();
            for (k, r) in [(16usize, 16usize), (64, 16), (64, 64), (256, 64)] {
                let (spec, univ) = support::rs_spec_vs_univ(&f, k, r)?;
                println!(
                    "{k:>5} {r:>5}  {:>10} {:>10} {:>7.2}x",
                    spec.c2,
                    univ.c2,
                    univ.c2 as f64 / spec.c2 as f64
                );
            }
        }
        "baselines" => {
            println!("A2A baselines (one port, W=1): C2 and the §II gap");
            println!(
                "{:>5}  {:>10} {:>12} {:>10} {:>12}",
                "K", "universal", "multireduce", "gap meas", "gap formula"
            );
            let f = GfPrime::default_field();
            for k in [16usize, 64, 256] {
                let (ps, mr) = support::univ_vs_multireduce(&f, k)?;
                let gap = mr.c2 as i64 - ps.c2 as i64;
                let formula = costs::multireduce_gap(k as u64, 1);
                println!(
                    "{k:>5}  {:>10} {:>12} {:>10} {:>12.1}",
                    ps.c2, mr.c2, gap, formula
                );
            }
        }
        other => anyhow::bail!("unknown sweep {other:?}"),
    }
    Ok(())
}

fn cmd_service(flags: &HashMap<String, String>) -> Result<()> {
    let workers: usize = flags.get("workers").map_or(Ok(2), |v| v.parse())?;
    let requests: usize = flags.get("requests").map_or(Ok(32), |v| v.parse())?;
    let w: usize = flags.get("w").map_or(Ok(256), |v| v.parse())?;
    let f = GfPrime::default_field();
    let code = dce::codes::GrsCode::structured(&f, 64, 16, 2)?;
    let parity = code.parity_matrix(&f);
    let svc = dce::coordinator::EncodeService::start(
        &f,
        &parity,
        std::path::Path::new("artifacts"),
        256,
        workers,
        16,
    )?;
    let t0 = std::time::Instant::now();
    let mut rng = dce::util::Rng::new(1);
    let mut pending = Vec::new();
    for _ in 0..requests {
        let x: Vec<Vec<u64>> = (0..64)
            .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
            .collect();
        pending.push(svc.submit(x)?);
    }
    let mut ok = 0;
    for rx in pending {
        let resp = rx.recv()?;
        if resp.y.is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed();
    println!(
        "service: {ok}/{requests} requests ok in {wall:?} ({:.1} req/s, {:.2} Melem/s)",
        requests as f64 / wall.as_secs_f64(),
        (requests * 64 * w) as f64 / wall.as_secs_f64() / 1e6,
    );
    println!("metrics: {}", svc.metrics.to_json());
    svc.shutdown();
    Ok(())
}

fn cmd_info() -> Result<()> {
    let f = GfPrime::default_field();
    println!(
        "default field: GF({}) (q−1 = 2^18·3), {} wire bits",
        f.order(),
        f.bits()
    );
    match dce::runtime::Runtime::cpu() {
        Ok(rt) => println!("PJRT platform: {}", rt.platform()),
        Err(e) => println!("PJRT unavailable: {e:#}"),
    }
    match dce::runtime::Manifest::load(std::path::Path::new("artifacts")) {
        Ok(m) => println!("artifacts: {} entries", m.entries.len()),
        Err(_) => println!("artifacts: none (run `make artifacts`)"),
    }
    Ok(())
}

/// Small shared helpers for the CLI sweeps (mirrored by the benches).
mod support {
    use super::*;
    use dce::collectives::{MultiReduce, PrepareShoot};
    use dce::framework::{A2aAlgo, SystematicEncode};
    use dce::gf::Mat;
    use dce::net::{run, Collective, Packet, Sim, SimReport};
    use std::sync::Arc;

    pub fn run_universal(
        f: &GfPrime,
        k: usize,
        p: usize,
        seed: u64,
    ) -> Result<(SimReport, Vec<Packet>)> {
        let c = Arc::new(Mat::random(f, k, k, seed));
        let inputs: Vec<Packet> = (0..k as u64).map(|i| vec![f.elem(i + 1)]).collect();
        let mut ps = PrepareShoot::new(*f, (0..k).collect(), p, c, inputs);
        let rep = run(&mut Sim::new(p), &mut ps)?;
        let outs = ps.outputs();
        Ok((rep, (0..k).map(|i| outs[&i].clone()).collect()))
    }

    pub fn run_dft(
        f: &GfPrime,
        p_base: u64,
        h: u32,
        p: usize,
    ) -> Result<(SimReport, Vec<Packet>)> {
        let k = dce::util::ipow(p_base, h) as usize;
        let inputs: Vec<Packet> = (0..k as u64).map(|i| vec![f.elem(i + 1)]).collect();
        let mut d =
            dce::collectives::DftA2A::new(*f, (0..k).collect(), p, p_base, h, inputs, false)?;
        let rep = run(&mut Sim::new(p), &mut d)?;
        let outs = d.outputs();
        Ok((rep, (0..k).map(|i| outs[&i].clone()).collect()))
    }

    pub fn rs_spec_vs_univ(f: &GfPrime, k: usize, r: usize) -> Result<(SimReport, SimReport)> {
        let code = dce::codes::GrsCode::structured(f, k, r, 2)?;
        let inputs: Vec<Packet> = (0..k as u64).map(|i| vec![f.elem(i + 1)]).collect();
        let mut spec = SystematicEncode::new_rs(*f, &code, inputs.clone(), 1)?;
        let rep_s = run(&mut Sim::new(1), &mut spec)?;
        let a = Arc::new(code.parity_matrix(f));
        let mut univ = SystematicEncode::new(*f, a, inputs, 1, A2aAlgo::Universal)?;
        let rep_u = run(&mut Sim::new(1), &mut univ)?;
        Ok((rep_s, rep_u))
    }

    pub fn univ_vs_multireduce(f: &GfPrime, k: usize) -> Result<(SimReport, SimReport)> {
        let c = Arc::new(Mat::random(f, k, k, 5));
        let inputs: Vec<Packet> = (0..k as u64).map(|i| vec![f.elem(i + 1)]).collect();
        let mut ps = PrepareShoot::new(*f, (0..k).collect(), 1, c.clone(), inputs.clone());
        let rep_ps = run(&mut Sim::new(1), &mut ps)?;
        let mut mr = MultiReduce::new(*f, (0..k).collect(), 1, c, inputs);
        let rep_mr = run(&mut Sim::new(1), &mut mr)?;
        Ok((rep_ps, rep_mr))
    }
}

//! Small self-contained utilities: a deterministic PRNG (the offline build
//! has no `rand` crate) and a micro-benchmark harness (no `criterion`).

use std::time::{Duration, Instant};

/// SplitMix64 — tiny, fast, deterministic; plenty for tests/benches.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng {
            state: seed.wrapping_add(0x9E3779B97F4A7C15),
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)` (n > 0) via rejection-free multiply-shift.
    pub fn below(&mut self, n: u64) -> u64 {
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Choose `k` distinct indices from `[0, n)`.
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx.sort_unstable();
        idx
    }
}

/// Result of one micro-benchmark: wall-clock stats over `iters` runs.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchStats {
    pub fn per_iter_ns(&self) -> f64 {
        self.median.as_nanos() as f64
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} median {:>12?}  mean {:>12?}  min {:>12?}  (n={})",
            self.name, self.median, self.mean, self.min, self.iters
        )
    }
}

/// True when `DCE_BENCH_SMOKE` is set (and not `"0"`): bench binaries run
/// in *smoke mode* — one iteration per benchmark, timing assertions
/// skipped. CI uses this so bench targets are executed (and can't
/// silently rot) without flaking on shared-runner timing noise.
pub fn bench_smoke() -> bool {
    std::env::var_os("DCE_BENCH_SMOKE").is_some_and(|v| v != "0")
}

/// `iters`, or 1 in smoke mode (see [`bench_smoke`]).
pub fn bench_iters(iters: usize) -> usize {
    if bench_smoke() {
        1
    } else {
        iters
    }
}

/// Minimal criterion replacement: warm up, then time `iters` executions of
/// `body`, reporting median/mean/min/max. `body` receives the iteration
/// index and should return something opaque to keep the optimiser honest.
pub fn bench<T>(name: &str, iters: usize, mut body: impl FnMut(usize) -> T) -> BenchStats {
    // Warm-up: a few runs, or until ~50ms spent.
    let warm_start = Instant::now();
    for i in 0..3 {
        std::hint::black_box(body(i));
        if warm_start.elapsed() > Duration::from_millis(50) {
            break;
        }
    }
    let mut times = Vec::with_capacity(iters);
    for i in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(body(i));
        times.push(t0.elapsed());
    }
    times.sort_unstable();
    let mean = times.iter().sum::<Duration>() / times.len() as u32;
    BenchStats {
        name: name.to_string(),
        iters,
        median: times[times.len() / 2],
        mean,
        min: times[0],
        max: times[times.len() - 1],
    }
}

/// `⌈log_b n⌉` for integers (`b ≥ 2`, `n ≥ 1`) — the `⌈log_{p+1} K⌉` of the
/// paper, computed exactly (no floating point).
pub fn ceil_log(b: u64, n: u64) -> u32 {
    assert!(b >= 2 && n >= 1);
    let mut pow = 1u64;
    let mut l = 0u32;
    while pow < n {
        pow = pow.saturating_mul(b);
        l += 1;
    }
    l
}

/// `b^e` with overflow panic (fine for the sizes in this repo).
pub fn ipow(b: u64, e: u32) -> u64 {
    b.checked_pow(e).expect("integer overflow in ipow")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_log_exact() {
        assert_eq!(ceil_log(2, 1), 0);
        assert_eq!(ceil_log(2, 2), 1);
        assert_eq!(ceil_log(2, 3), 2);
        assert_eq!(ceil_log(2, 65), 7);
        assert_eq!(ceil_log(3, 9), 2);
        assert_eq!(ceil_log(3, 10), 3);
        assert_eq!(ceil_log(3, 65), 4); // the K=65, p=2 example of Fig. 5
        assert_eq!(ceil_log(4, 64), 3);
    }

    #[test]
    fn rng_below_in_range() {
        let mut rng = Rng::new(42);
        for _ in 0..1000 {
            assert!(rng.below(7) < 7);
        }
    }

    #[test]
    fn choose_is_sorted_distinct() {
        let mut rng = Rng::new(7);
        let picked = rng.choose(50, 20);
        assert_eq!(picked.len(), 20);
        for w in picked.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn rng_deterministic() {
        let a: Vec<u64> = {
            let mut r = Rng::new(5);
            (0..10).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Rng::new(5);
            (0..10).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
    }
}

//! # dce — Decentralized Coding Engine
//!
//! A production-oriented reproduction of *"On the Encoding Process in
//! Decentralized Systems"* (Wang & Raviv, 2024): decentralized encoding of
//! systematic (and non-systematic) linear codes in a fully-connected,
//! multi-port, round-based network, built around the paper's **all-to-all
//! encode** collective.
//!
//! The crate is layered bottom-up:
//!
//! * [`gf`] — finite fields, matrices, polynomials, structured matrices;
//! * [`net`] — the paper's communication model as an executable,
//!   port-enforcing round simulator with exact `C1`/`C2` accounting,
//!   plus the compile/execute split: [`net::plan`] compiles any
//!   collective into a reusable, width-independent Plan IR and
//!   [`net::exec`] replays it with zero control-flow rederivation;
//! * [`collectives`] — broadcast/reduce/all-gather, the universal
//!   **prepare-and-shoot** A2A (§IV), the specific **DFT** (§V-A),
//!   **draw-and-loose** (§V-B) and **Cauchy-like** (§VI) A2As, plus the
//!   multi-reduce and direct-transfer baselines;
//! * [`framework`] — the §III / Appendix B decentralized-encoding
//!   frameworks and every closed-form cost expression in the paper;
//! * [`codes`] — GRS / systematic RS / Lagrange codes and the structured
//!   evaluation-point designs that make the specific algorithms apply;
//! * [`coordinator`] — the deployable layer: config, planner, jobs,
//!   verification, metrics, and a threaded batch-encode service;
//! * [`runtime`] — the PJRT bridge that loads the AOT-compiled Pallas
//!   GF(p) kernel (`artifacts/*.hlo.txt`) for the bulk-encode hot path
//!   (a graceful stub unless built with the `pjrt` feature).
//!
//! See `DESIGN.md` (next to this crate's `Cargo.toml`) for the
//! paper-to-module map; `benches/` regenerates the measured-vs-theory
//! tables.
//!
//! Cargo features: `parallel` steps processor-disjoint collectives and
//! the prepare-and-shoot per-rank loops on rayon workers —
//! bit-identically to the sequential engine; `pjrt` enables the XLA
//! runtime bridge (needs the `xla` bindings crate).
//!
//! ## Stable vs internal surface
//!
//! The **supported public surface** is what [`prelude`] re-exports:
//! job configuration and execution ([`coordinator::EncodeJob`],
//! [`coordinator::JobConfig`], [`coordinator::ExecOptions`],
//! [`coordinator::PlanCache`]), the serving tier
//! ([`coordinator::EncodeService`]), fault injection
//! ([`net::FaultSpec`]), the field abstraction ([`gf::Field`] and its
//! concrete fields), and the unified [`Error`]. Those types follow the
//! crate's deprecation policy — entry points removed only after one
//! release behind a `#[deprecated]` shim.
//!
//! Everything else — the plan IR ([`net::plan`]), the collectives, the
//! kernel/backend internals, the transport substrate
//! ([`net::transport`]) — is **internal**: exported `pub` for tests,
//! benches and curious integrators, but free to change shape between
//! minor versions without notice.

pub mod codes;
pub mod collectives;
pub mod coordinator;
pub mod error;
pub mod framework;
pub mod gf;
pub mod net;
pub mod prelude;
pub mod runtime;
pub mod util;

pub use error::Error;
pub use gf::{Field, GfPrime, Mat};
pub use net::{CostModel, Packet, PacketBuf, SimReport};

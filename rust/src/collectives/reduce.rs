//! All-to-one reduce (Definition 3) — the dual of the `(p+1)`-nomial
//! broadcast: same tree, communication order reversed, packets summed on
//! the way down. `C1 = ⌈log_{p+1} N⌉`, `C2 = W·⌈log_{p+1} N⌉`.
//!
//! Phase two of the K ≥ R framework (§III-A) runs one instance per grid
//! row to accumulate the partially-coded packets at the sink.

use crate::gf::Field;
use crate::net::{pkt_add, Collective, Msg, Outputs, Packet, ProcId};
use crate::util::ipow;
use std::collections::HashMap;

/// `(p+1)`-nomial tree reduce of field-vector packets to `procs[0]`.
///
/// Every participant contributes one packet (callers pre-scale if the
/// reduction is a weighted sum); the root ends with `Σ_i inputs[i]`.
pub struct TreeReduce<F: Field> {
    f: F,
    procs: Vec<ProcId>,
    p: usize,
    rounds: u32,
    t: u32,
    acc: Vec<Option<Packet>>,
    done: bool,
}

impl<F: Field> TreeReduce<F> {
    /// `inputs[i]` is the packet initially held by `procs[i]`; the result
    /// accumulates at `procs[0]`.
    pub fn new(f: F, procs: Vec<ProcId>, p: usize, inputs: Vec<Packet>) -> Self {
        assert_eq!(procs.len(), inputs.len());
        assert!(!procs.is_empty());
        let n = procs.len();
        let rounds = crate::util::ceil_log(p as u64 + 1, n as u64);
        TreeReduce {
            f,
            procs,
            p,
            rounds,
            t: 0,
            acc: inputs.into_iter().map(Some).collect(),
            done: n <= 1,
        }
    }

    /// Build from an output map of a previous stage (pipeline glue);
    /// processors missing from `inputs` contribute zero packets.
    pub fn from_outputs(
        f: F,
        procs: Vec<ProcId>,
        p: usize,
        inputs: &Outputs,
        w: usize,
    ) -> Self {
        let packets = procs
            .iter()
            .map(|pid| inputs.get(pid).cloned().unwrap_or_else(|| vec![0; w]))
            .collect();
        TreeReduce::new(f, procs, p, packets)
    }
}

impl<F: Field> Collective for TreeReduce<F> {
    fn participants(&self) -> Vec<ProcId> {
        self.procs.clone()
    }

    fn is_done(&self) -> bool {
        self.done
    }

    fn step(&mut self, inbox: Vec<Msg>) -> Vec<Msg> {
        let rank_of: HashMap<ProcId, usize> =
            self.procs.iter().enumerate().map(|(i, &p)| (p, i)).collect();
        for m in inbox {
            let r = rank_of[&m.dst];
            let acc = self.acc[r].as_mut().expect("receiver lost its packet");
            for pkt in m.payload.iter() {
                pkt_add(&self.f, acc, pkt);
            }
        }
        if self.t == self.rounds {
            self.done = true;
            return Vec::new();
        }
        self.t += 1;
        // Reverse of broadcast round t' = rounds + 1 − t: every rank in
        // [(p+1)^{t'−1}, min(n, (p+1)^{t'})) sends its accumulator to its
        // tree parent rank = x mod (p+1)^{t'−1}.
        let tp = self.rounds + 1 - self.t;
        let lo = ipow(self.p as u64 + 1, tp - 1) as usize;
        let hi = (lo * (self.p + 1)).min(self.procs.len());
        let mut out = Vec::new();
        for x in lo..hi {
            let parent = x % lo;
            let pkt = self.acc[x].take().expect("sender lost its packet");
            out.push(Msg::single(self.procs[x], self.procs[parent], pkt));
        }
        out
    }

    fn outputs(&self) -> Outputs {
        let root = self.acc[0].clone().expect("reduce incomplete");
        Outputs::from([(self.procs[0], root)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::GfPrime;
    use crate::net::{run, Sim};

    #[test]
    fn reduce_sums_everything() {
        let f = GfPrime::default_field();
        for (n, p) in [(9usize, 1usize), (10, 2), (27, 2), (4, 3), (1, 1), (2, 1)] {
            let procs: Vec<ProcId> = (0..n).collect();
            let inputs: Vec<Packet> = (0..n as u64).map(|i| vec![i + 1, 2 * i]).collect();
            let mut red = TreeReduce::new(f, procs, p, inputs);
            let rep = run(&mut Sim::new(p), &mut red).unwrap();
            let l = crate::util::ceil_log(p as u64 + 1, n as u64) as u64;
            assert_eq!(rep.c1, l, "n={n} p={p}");
            assert_eq!(rep.c2, 2 * l, "n={n} p={p}");
            let out = &red.outputs()[&0];
            let s: u64 = (1..=n as u64).sum();
            let s2: u64 = (0..n as u64).map(|i| 2 * i).sum();
            assert_eq!(out, &vec![f.elem(s), f.elem(s2)]);
        }
    }

    #[test]
    fn reduce_is_broadcast_dual_in_cost() {
        // Same tree ⇒ same C1/C2 as broadcast for equal (n, p, W).
        let f = GfPrime::default_field();
        let (n, p, w) = (13usize, 2usize, 5usize);
        let procs: Vec<ProcId> = (0..n).collect();
        let inputs: Vec<Packet> = (0..n).map(|_| vec![1; w]).collect();
        let mut red = TreeReduce::new(f, procs.clone(), p, inputs);
        let rr = run(&mut Sim::new(p), &mut red).unwrap();
        let mut b = super::super::TreeBroadcast::new(procs, p, vec![1; w]);
        let rb = run(&mut Sim::new(p), &mut b).unwrap();
        assert_eq!(rr.c1, rb.c1);
        assert_eq!(rr.c2, rb.c2);
    }
}

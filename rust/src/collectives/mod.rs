//! Collective operations — the paper's building blocks, plus combinators.
//!
//! Every algorithm in the paper is a composition of a few collectives:
//!
//! * [`broadcast`] / [`reduce`] — the classic duals (Defs. 2–3, App. A),
//! * [`a2a_universal`] — **prepare-and-shoot**, the optimal universal
//!   all-to-all encode (§IV),
//! * [`a2a_dft`] — the permuted-DFT specific algorithm (§V-A),
//! * [`a2a_vand`] — **draw-and-loose** for general Vandermonde matrices
//!   (§V-B),
//! * [`a2a_cauchy`] — Cauchy-like matrices via two draw-and-loose passes
//!   (§VI, Theorems 6–9),
//! * [`allgather`] / [`multireduce`] — the Jeong et al. \[21\] baseline,
//! * [`direct`] — the naive direct-transfer baseline (\[22\]-style).
//!
//! Composition uses two combinators mirroring the paper's framework
//! figures: [`Par`] runs processor-disjoint collectives in the same rounds
//! (the "M instances in parallel" of §III) and [`Pipeline`] sequences
//! phases, handing each phase the previous phase's outputs.

pub mod a2a_cauchy;
pub mod a2a_dft;
pub mod a2a_universal;
pub mod a2a_vand;
pub mod allgather;
pub mod broadcast;
pub mod direct;
pub mod multireduce;
pub mod reduce;

pub use a2a_cauchy::CauchyA2A;
pub use a2a_dft::DftA2A;
pub use a2a_universal::PrepareShoot;
pub use a2a_vand::DrawLoose;
pub use allgather::AllGather;
pub use broadcast::{PipelinedBroadcast, TreeBroadcast};
pub use direct::DirectEncode;
pub use multireduce::MultiReduce;
pub use reduce::TreeReduce;

use crate::net::{Collective, Msg, Outputs, Packet, ProcId};
use std::collections::{HashMap, VecDeque};

/// `f(0..n) → Vec<Msg>` flattened in index order — rayon-parallel when the
/// `parallel` feature is on and enabled. Per-index outputs are independent
/// and merged in index order, so both paths are bit-identical.
pub(crate) fn par_flat_map_msgs<F>(n: usize, f: F) -> Vec<Msg>
where
    F: Fn(usize) -> Vec<Msg> + Sync + Send,
{
    #[cfg(feature = "parallel")]
    if crate::net::parallel_enabled() {
        use rayon::prelude::*;
        let per: Vec<Vec<Msg>> = (0..n).into_par_iter().map(&f).collect();
        return per.into_iter().flatten().collect();
    }
    (0..n).flat_map(f).collect()
}

/// Apply `f` to every item (disjoint mutable borrows) — rayon-parallel
/// when the `parallel` feature is on and enabled.
pub(crate) fn par_for_each_mut<A, F>(items: &mut [A], f: F)
where
    A: Send,
    F: Fn(usize, &mut A) + Sync + Send,
{
    #[cfg(feature = "parallel")]
    if crate::net::parallel_enabled() {
        use rayon::prelude::*;
        items.par_iter_mut().enumerate().for_each(|(i, a)| f(i, a));
        return;
    }
    for (i, a) in items.iter_mut().enumerate() {
        f(i, a);
    }
}

/// Map `f` over items (disjoint mutable borrows) collecting per-item
/// message batches, flattened in item order — rayon-parallel when enabled.
pub(crate) fn par_map_msgs_mut<A, F>(items: &mut [A], f: F) -> Vec<Msg>
where
    A: Send,
    F: Fn(usize, &mut A) -> Vec<Msg> + Sync + Send,
{
    #[cfg(feature = "parallel")]
    if crate::net::parallel_enabled() {
        use rayon::prelude::*;
        let per: Vec<Vec<Msg>> = items
            .par_iter_mut()
            .enumerate()
            .map(|(i, a)| f(i, a))
            .collect();
        return per.into_iter().flatten().collect();
    }
    let mut out = Vec::new();
    for (i, a) in items.iter_mut().enumerate() {
        out.extend(f(i, a));
    }
    out
}

/// A zero-round collective holding fixed outputs. Used as a pipeline
/// source ("these processors hold these packets") and for free local
/// computation steps (the model charges only for communication).
pub struct LocalOp {
    outs: Outputs,
}

impl LocalOp {
    pub fn new(outs: Outputs) -> Self {
        LocalOp { outs }
    }

    /// Map each processor's packet through `op`.
    pub fn map(
        inputs: &Outputs,
        mut op: impl FnMut(ProcId, &Packet) -> Packet,
    ) -> Self {
        LocalOp {
            outs: inputs.iter().map(|(&k, v)| (k, op(k, v))).collect(),
        }
    }
}

impl Collective for LocalOp {
    fn participants(&self) -> Vec<ProcId> {
        self.outs.keys().copied().collect()
    }
    fn is_done(&self) -> bool {
        true
    }
    fn step(&mut self, inbox: Vec<Msg>) -> Vec<Msg> {
        debug_assert!(inbox.is_empty(), "LocalOp received messages");
        Vec::new()
    }
    fn outputs(&self) -> Outputs {
        self.outs.clone()
    }
}

/// Run processor-disjoint collectives in the same round space.
///
/// This is the paper's "M instances of … operating in parallel": the
/// engine sees the union of the children's messages each round, so `C1` is
/// the max of the children's round counts and `m_t` is the max over all
/// children — exactly the `max[C_A2A(A_0), …]` of Theorems 1–2.
///
/// With the `parallel` feature the children — being processor-disjoint —
/// are stepped on rayon workers; their message batches are concatenated
/// in child order, so the round content is identical to sequential
/// stepping.
pub struct Par {
    children: Vec<Box<dyn Collective>>,
    /// Accumulated `processor → child` routing map: seeded with the
    /// construction-time participant sets and extended every round as
    /// children (pipelines) evolve. Sticky entries keep late in-flight
    /// deliveries routable after a child's stage has moved on.
    route: HashMap<ProcId, usize>,
}

impl Par {
    /// Compose processor-disjoint children. Overlapping participant sets
    /// are a construction-time `Err` naming the offending pair — a
    /// malformed composition can never crash mid-round. (Round-sharing
    /// over shared processors is not meaningful, and port violations
    /// would be unattributable.)
    pub fn new(children: Vec<Box<dyn Collective>>) -> anyhow::Result<Self> {
        let mut route: HashMap<ProcId, usize> = HashMap::new();
        for (i, c) in children.iter().enumerate() {
            for p in c.participants() {
                if let Some(j) = route.insert(p, i) {
                    anyhow::bail!(
                        "Par children {j} and {i} share processor {p}: \
                         parallel collectives must be processor-disjoint"
                    );
                }
            }
        }
        Ok(Par { children, route })
    }
}

impl Collective for Par {
    fn participants(&self) -> Vec<ProcId> {
        self.children.iter().flat_map(|c| c.participants()).collect()
    }

    fn is_done(&self) -> bool {
        self.children.iter().all(|c| c.is_done())
    }

    fn step(&mut self, inbox: Vec<Msg>) -> Vec<Msg> {
        // Route by destination. Participant sets may evolve (pipelines),
        // so fold the current sets into the sticky map each round;
        // construction seeded it, so a destination with no *current*
        // claimant still routes to its last one (in-flight deliveries
        // landing as a child finishes a stage). A destination no child
        // ever claimed cannot arise from a disjointness-validated
        // composition; tolerate it as a dropped message rather than a
        // mid-round crash.
        for (i, c) in self.children.iter().enumerate() {
            for p in c.participants() {
                self.route.insert(p, i);
            }
        }
        let mut boxes: Vec<Vec<Msg>> = (0..self.children.len()).map(|_| Vec::new()).collect();
        for m in inbox {
            match self.route.get(&m.dst) {
                Some(&i) => boxes[i].push(m),
                None => debug_assert!(false, "message to {} matches no child", m.dst),
            }
        }
        step_children(&mut self.children, boxes)
    }

    fn outputs(&self) -> Outputs {
        let mut out = Outputs::new();
        for c in &self.children {
            out.extend(c.outputs());
        }
        out
    }
}

/// Step processor-disjoint children against their routed inboxes, merging
/// the emitted messages in child order.
fn step_children(children: &mut [Box<dyn Collective>], boxes: Vec<Vec<Msg>>) -> Vec<Msg> {
    #[cfg(feature = "parallel")]
    if crate::net::parallel_enabled() {
        use rayon::prelude::*;
        let per: Vec<Vec<Msg>> = children
            .par_iter_mut()
            .zip(boxes)
            .map(|(c, b)| {
                if !c.is_done() || !b.is_empty() {
                    c.step(b)
                } else {
                    Vec::new()
                }
            })
            .collect();
        return per.into_iter().flatten().collect();
    }
    let mut out = Vec::new();
    for (c, b) in children.iter_mut().zip(boxes) {
        if !c.is_done() || !b.is_empty() {
            out.extend(c.step(b));
        }
    }
    out
}

/// Builder invoked with the previous stage's outputs.
pub type StageBuilder = Box<dyn FnOnce(&Outputs) -> Box<dyn Collective> + Send>;

/// Sequence collective phases; each stage starts from the previous stage's
/// outputs. Stage boundaries cost no extra rounds: a stage's first sends
/// share the round in which the previous stage's last deliveries land.
pub struct Pipeline {
    current: Option<Box<dyn Collective>>,
    builders: VecDeque<Option<StageBuilder>>,
    last_outputs: Outputs,
}

impl Pipeline {
    /// Start from an explicit first stage.
    pub fn new(first: Box<dyn Collective>, builders: Vec<StageBuilder>) -> Self {
        let mut p = Pipeline {
            current: Some(first),
            builders: builders.into_iter().map(Some).collect(),
            last_outputs: Outputs::new(),
        };
        p.advance();
        p
    }

    /// Start from fixed inputs (a [`LocalOp`] source stage).
    pub fn from_inputs(inputs: Outputs, builders: Vec<StageBuilder>) -> Self {
        Pipeline::new(Box::new(LocalOp::new(inputs)), builders)
    }

    /// Move past finished stages, building successors as needed.
    fn advance(&mut self) {
        loop {
            match &self.current {
                Some(c) if c.is_done() => {
                    self.last_outputs = c.outputs();
                    match self.builders.pop_front() {
                        Some(b) => {
                            let builder = b.expect("builder taken twice");
                            self.current = Some(builder(&self.last_outputs));
                        }
                        None => {
                            self.current = None;
                            return;
                        }
                    }
                }
                Some(_) => return,
                None => return,
            }
        }
    }
}

impl Collective for Pipeline {
    fn participants(&self) -> Vec<ProcId> {
        match &self.current {
            Some(c) => c.participants(),
            None => self.last_outputs.keys().copied().collect(),
        }
    }

    fn is_done(&self) -> bool {
        self.current.is_none()
    }

    fn step(&mut self, inbox: Vec<Msg>) -> Vec<Msg> {
        let mut inbox = Some(inbox);
        loop {
            let Some(cur) = self.current.as_mut() else {
                return Vec::new();
            };
            let out = cur.step(inbox.take().unwrap_or_default());
            if !out.is_empty() {
                return out;
            }
            if cur.is_done() {
                // Stage finished this round; its successor's first sends
                // may share the same round.
                self.advance();
                continue;
            }
            return out;
        }
    }

    fn outputs(&self) -> Outputs {
        match &self.current {
            Some(c) => c.outputs(),
            None => self.last_outputs.clone(),
        }
    }
}

/// Convenience: collect `(proc, packet)` pairs into the map all collective
/// constructors take.
pub fn inputs_of(pairs: impl IntoIterator<Item = (ProcId, Packet)>) -> Outputs {
    pairs.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_rejects_overlapping_children_at_construction() {
        let a = Box::new(LocalOp::new(inputs_of([(0, vec![1u64]), (1, vec![2])])))
            as Box<dyn Collective>;
        let b = Box::new(LocalOp::new(inputs_of([(1, vec![3u64])]))) as Box<dyn Collective>;
        let err = Par::new(vec![a, b]).unwrap_err();
        assert!(err.to_string().contains("share processor 1"), "{err}");
    }

    #[test]
    fn par_accepts_disjoint_children() {
        let a = Box::new(LocalOp::new(inputs_of([(0, vec![1u64])]))) as Box<dyn Collective>;
        let b = Box::new(LocalOp::new(inputs_of([(1, vec![2u64])]))) as Box<dyn Collective>;
        let par = Par::new(vec![a, b]).unwrap();
        assert_eq!(par.participants().len(), 2);
        assert!(par.is_done());
        assert_eq!(par.outputs().len(), 2);
    }
}

//! **Prepare-and-shoot** — the optimal universal all-to-all encode (§IV-B).
//!
//! For any square matrix `C ∈ F_q^{K×K}`, every processor `P_k` (holding
//! `x_k`) obtains `x̃_k = Σ_r C[r][k]·x_r` in `C1 = ⌈log_{p+1} K⌉` rounds
//! (optimal by Lemma 1) with `C2 ≈ 2√K/p` (within `√2` of Lemma 2).
//!
//! Let `L = ⌈log_{p+1} K⌉`, `T_p = ⌈L/2⌉`, `T_s = L − T_p`,
//! `m = (p+1)^{T_p}`, `n = ⌈K/m⌉`.
//!
//! * **Prepare** (Algorithm 1): `K` parallel `(p+1)`-nomial broadcasts;
//!   after round `t` of distances `ρ(p+1)^{T_p−t}`, every `P_k` holds
//!   `x_r` for `r ∈ R_k^- = {k−ℓ mod K : ℓ < (p+1)^t}`.
//! * **Shoot** (Algorithm 2): every `P_k` forms the partially-coded
//!   packets `w_{k,k+ℓm} = Σ_{r∈R_k^-} C[r][k+ℓm]·x_r` and the `K`
//!   stride-`m` classes run parallel `(p+1)`-ary reductions: writing the
//!   destination offset `δ = ℓ` in base `p+1`, round `t` moves every
//!   packet whose digit `t−1` equals `ρ` over distance `ρ(p+1)^{t−1}m`,
//!   summing into the receiver's matching packet. (Algorithm 2 of the
//!   paper prints the distance as `ρ·m^t`; Lemma 4 and Fig. 7 are only
//!   consistent with `ρ·(p+1)^{t−1}·m`, which is what we implement — see
//!   DESIGN.md §1.)
//! * **Correction** (eq. (4)): when `mn > K` the stride class wraps and
//!   `y_k` double-counts `C[r][k]·x_r` for `r ∈ [k−(nm−K)+1 … k]`; each
//!   processor subtracts those terms locally (it holds all of them).
//!
//! Message contents are never tagged on the wire: the scheduling is known
//! a priori (Remark 1), so receivers recompute the exact (owner / offset)
//! lists the sender used.

use crate::gf::{Field, Mat};
use crate::net::{pkt_add, pkt_add_scaled, pkt_zero, Collective, Msg, Packet, ProcId};
use crate::util::{ceil_log, ipow};
use std::collections::HashMap;
use std::sync::Arc;

/// Static shape parameters of a prepare-and-shoot instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PsParams {
    pub k: usize,
    pub p: usize,
    /// `L = ⌈log_{p+1} K⌉` — total rounds.
    pub l: u32,
    /// Prepare rounds `T_p = ⌈L/2⌉`.
    pub tp: u32,
    /// Shoot rounds `T_s = L − T_p`.
    pub ts: u32,
    /// `m = (p+1)^{T_p}`.
    pub m: u64,
    /// `n = ⌈K/m⌉`.
    pub n: u64,
}

impl PsParams {
    pub fn new(k: usize, p: usize) -> Self {
        assert!(k >= 1 && p >= 1);
        let l = ceil_log(p as u64 + 1, k as u64);
        let tp = l.div_ceil(2);
        let ts = l - tp;
        let m = ipow(p as u64 + 1, tp);
        let n = (k as u64).div_ceil(m);
        PsParams {
            k,
            p,
            l,
            tp,
            ts,
            m,
            n,
        }
    }
}

/// The prepare-and-shoot universal A2A collective.
pub struct PrepareShoot<F: Field> {
    f: F,
    procs: Vec<ProcId>,
    c: Arc<Mat>,
    params: PsParams,
    w: usize,
    /// Completed step calls (== rounds issued so far).
    t: u32,
    /// Per-rank: owner → initial packet (prepare-phase memory).
    mem: Vec<HashMap<usize, Packet>>,
    /// Per-rank: partial packet per destination offset δ (dense, len n;
    /// offsets vacate as packets move toward their destinations).
    wpkts: Vec<Vec<Option<Packet>>>,
    out: Vec<Option<Packet>>,
    done: bool,
}

impl<F: Field> PrepareShoot<F> {
    /// `procs[k]` holds `inputs[k]`; computes the matrix `c` (`K×K`).
    pub fn new(f: F, procs: Vec<ProcId>, p: usize, c: Arc<Mat>, inputs: Vec<Packet>) -> Self {
        let k = procs.len();
        assert_eq!(c.rows, k, "matrix rows must equal K");
        assert_eq!(c.cols, k, "matrix cols must equal K");
        assert_eq!(inputs.len(), k);
        let w = inputs.first().map_or(0, |p| p.len());
        assert!(inputs.iter().all(|p| p.len() == w));
        let params = PsParams::new(k, p);
        let mem = inputs
            .into_iter()
            .enumerate()
            .map(|(r, pkt)| HashMap::from([(r, pkt)]))
            .collect();
        let mut ps = PrepareShoot {
            f,
            procs,
            c,
            params,
            w,
            t: 0,
            mem,
            wpkts: vec![Vec::new(); k],
            out: vec![None; k],
            done: false,
        };
        if k == 1 {
            // Degenerate: x̃_0 = C[0][0]·x_0, no communication.
            let x0 = ps.mem[0][&0].clone();
            ps.out[0] = Some(crate::net::pkt_scale(&ps.f, ps.c[(0, 0)], &x0));
            ps.done = true;
        }
        ps
    }

    /// Convenience: build from a pipeline output map.
    pub fn from_outputs(
        f: F,
        procs: Vec<ProcId>,
        p: usize,
        c: Arc<Mat>,
        inputs: &HashMap<ProcId, Packet>,
    ) -> Self {
        let packets = procs
            .iter()
            .map(|pid| inputs[pid].clone())
            .collect();
        PrepareShoot::new(f, procs, p, c, packets)
    }

    /// Owners held by rank `k` at the start of prepare round `t`
    /// (1-indexed). Distances shrink over rounds (`ρ(p+1)^{T_p−t}`), so
    /// after `t−1` rounds the memory holds
    /// `{k − j·(p+1)^{T_p−t+1} mod K : j < (p+1)^{t−1}}` — contiguous only
    /// once the phase completes (`t = T_p+1`, stride 1, i.e. `R_k^-`).
    /// Ordered by `j`, deduplicated on wrap-around.
    fn prep_owners(&self, k: usize, t: u32) -> Vec<usize> {
        let kk = self.params.k;
        let span = ipow(self.params.p as u64 + 1, t - 1);
        let stride = ipow(self.params.p as u64 + 1, self.params.tp + 1 - t);
        // Fast path: no wrap-around possible ⇒ all owners distinct
        // (span·stride = (p+1)^{T_p} = m, so this covers every round
        // whenever m ≤ K — i.e. all but degenerate instances).
        if span * stride <= kk as u64 {
            return (0..span)
                .map(|j| ((k as u64 + kk as u64 - j * stride) % kk as u64) as usize)
                .collect();
        }
        let mut out = Vec::new();
        let mut seen = vec![false; kk];
        for j in 0..span {
            let back = (j * stride) % kk as u64;
            let owner = ((k as u64 + kk as u64 - back) % kk as u64) as usize;
            if !seen[owner] {
                seen[owner] = true;
                out.push(owner);
            }
            if out.len() == kk {
                break;
            }
        }
        out
    }

    /// Offsets alive at the start of shoot round `t` (1-indexed): all
    /// `δ < n` whose base-(p+1) digits below `t−1` are zero, ascending.
    fn shoot_offsets(&self, t: u32) -> Vec<u64> {
        let stride = ipow(self.params.p as u64 + 1, t - 1);
        (0..self.params.n).filter(|d| d % stride == 0).collect()
    }

    /// Process one prepare-round inbox.
    fn absorb_prepare(&mut self, inbox: Vec<Msg>, t: u32) {
        let rank_of: HashMap<ProcId, usize> =
            self.procs.iter().enumerate().map(|(i, &p)| (p, i)).collect();
        for msg in inbox {
            let dst = rank_of[&msg.dst];
            let src = rank_of[&msg.src];
            let owners = self.prep_owners(src, t);
            assert_eq!(owners.len(), msg.payload.len(), "prepare schedule mismatch");
            for (owner, pkt) in owners.into_iter().zip(msg.payload) {
                self.mem[dst].entry(owner).or_insert(pkt);
            }
        }
    }

    /// Emit prepare round `t` (1-indexed): send the whole memory over
    /// distances `ρ(p+1)^{T_p−t}`, skipping self-targets and duplicates.
    fn emit_prepare(&self, t: u32) -> Vec<Msg> {
        let kk = self.params.k;
        let mut out = Vec::new();
        for k in 0..kk {
            let owners = self.prep_owners(k, t);
            let mut targets = Vec::new();
            for rho in 1..=self.params.p as u64 {
                let d = (rho * ipow(self.params.p as u64 + 1, self.params.tp - t)) % kk as u64;
                if d == 0 {
                    continue;
                }
                let dst = (k + d as usize) % kk;
                if dst != k && !targets.contains(&dst) {
                    targets.push(dst);
                }
            }
            for dst in targets {
                let payload: Vec<Packet> = owners
                    .iter()
                    .map(|&o| self.mem[k][&o].clone())
                    .collect();
                out.push(Msg::new(self.procs[k], self.procs[dst], payload));
            }
        }
        out
    }

    /// After the prepare phase: initialise the shoot-phase partial packets
    /// `w_{k,k+ℓm}` (or compute outputs directly when `n == 1`).
    fn init_shoot(&mut self) {
        let PsParams { k: kk, m, n, .. } = self.params;
        if n == 1 {
            // m ≥ K: everyone holds everything — pure local combine.
            for k in 0..kk {
                let mut acc = pkt_zero(self.w);
                let terms: Vec<(u64, &[u64])> = (0..kk)
                    .map(|r| (self.c[(r, k)], self.mem[k][&r].as_slice()))
                    .collect();
                self.f.lincomb_into(&mut acc, &terms);
                self.out[k] = Some(acc);
            }
            self.done = true;
            return;
        }
        // Row-sweep accumulation. Every matrix entry `C[r][dest]` is
        // touched exactly once during w-initialisation (Σ_k m·n ≈ K²);
        // iterating destination-major per processor reads the K×K matrix
        // (134 MB at K = 4096) in a cache-hostile scatter. Instead sweep
        // rows `r` sequentially: row `r` contributes `x_r` to processor
        // `k ∈ [r, r+m)` and offset `ℓ`, at column `dest = k + ℓm` — so
        // for fixed `ℓ` the columns form a *contiguous* run of `m`, and
        // the live accumulator window is only `m·n·W` words (~32 KB).
        // Products accumulate unreduced (`m ≤ lazy_chunk` always holds
        // for the supported field sizes; enforced below). §Perf: 2.6×.
        let lazy_chunk = self.f.lazy_chunk();
        let per_term_reduce = (m as usize) > lazy_chunk;
        let mut accs: Vec<Vec<Packet>> = (0..kk)
            .map(|_| (0..n).map(|_| pkt_zero(self.w)).collect())
            .collect();
        for r in 0..kk {
            let crow = self.c.row(r);
            // Every processor in [r, r+m) holds an identical copy of x_r
            // after the prepare phase; read one of them.
            let x = self.mem[r][&r].as_slice();
            for l in 0..n as usize {
                for k_off in 0..m as usize {
                    let k = (r + k_off) % kk;
                    let dest = (k + l * m as usize) % kk;
                    let coeff = crow[dest];
                    if coeff == 0 {
                        continue;
                    }
                    let acc = &mut accs[k][l];
                    for (a, &s) in acc.iter_mut().zip(x) {
                        *a = self.f.lazy_mul_acc(*a, coeff, s);
                    }
                    if per_term_reduce {
                        for a in acc.iter_mut() {
                            *a = self.f.lazy_reduce(*a);
                        }
                    }
                }
            }
        }
        for (k, dests) in accs.into_iter().enumerate() {
            let w: Vec<Option<Packet>> = dests
                .into_iter()
                .map(|mut acc| {
                    for a in acc.iter_mut() {
                        *a = self.f.lazy_reduce(*a);
                    }
                    Some(acc)
                })
                .collect();
            self.wpkts[k] = w;
        }
    }

    /// Process one shoot-round inbox (accumulate matching offsets).
    fn absorb_shoot(&mut self, inbox: Vec<Msg>, t: u32) {
        let rank_of: HashMap<ProcId, usize> =
            self.procs.iter().enumerate().map(|(i, &p)| (p, i)).collect();
        let kk = self.params.k as u64;
        let stride = ipow(self.params.p as u64 + 1, t - 1);
        for msg in inbox {
            let dst = rank_of[&msg.dst];
            let src = rank_of[&msg.src];
            // Which ρ values map src→dst over distance ρ·stride·m (mod K)?
            let mut expect: Vec<u64> = Vec::new(); // new offsets, sender order
            for rho in 1..=self.params.p as u64 {
                let d = (rho * stride * self.params.m) % kk;
                if d == 0 {
                    continue;
                }
                if (src as u64 + d) % kk == dst as u64 {
                    for delta in self.shoot_offsets(t) {
                        if (delta / stride) % (self.params.p as u64 + 1) == rho {
                            expect.push(delta - rho * stride);
                        }
                    }
                }
            }
            assert_eq!(expect.len(), msg.payload.len(), "shoot schedule mismatch");
            for (delta_new, pkt) in expect.into_iter().zip(msg.payload) {
                let acc = self.wpkts[dst][delta_new as usize]
                    .as_mut()
                    .expect("receiver missing offset packet");
                pkt_add(&self.f, acc, &pkt);
            }
        }
    }

    /// Emit shoot round `t` (1-indexed).
    fn emit_shoot(&mut self, t: u32) -> Vec<Msg> {
        let PsParams { k: kk, m, p, .. } = self.params;
        let stride = ipow(p as u64 + 1, t - 1);
        let mut out = Vec::new();
        for k in 0..kk {
            // Group offsets by ρ = digit_{t−1}(δ).
            let offsets = self.shoot_offsets(t);
            let mut by_target: Vec<(usize, Vec<u64>)> = Vec::new(); // (dst, old offsets)
            for rho in 1..=p as u64 {
                let deltas: Vec<u64> = offsets
                    .iter()
                    .copied()
                    .filter(|d| (d / stride) % (p as u64 + 1) == rho)
                    .collect();
                if deltas.is_empty() {
                    continue;
                }
                let d = (rho * stride * m) % kk as u64;
                if d == 0 {
                    // Self-target: merge locally, no message.
                    for delta in deltas {
                        let pkt = self.wpkts[k][delta as usize]
                            .take()
                            .expect("missing offset");
                        let tgt = (delta - rho * stride) as usize;
                        let acc = self.wpkts[k][tgt].as_mut().expect("missing target");
                        pkt_add(&self.f, acc, &pkt);
                    }
                    continue;
                }
                let dst = (k + d as usize) % kk;
                if let Some(entry) = by_target.iter_mut().find(|(t, _)| *t == dst) {
                    entry.1.extend(deltas);
                } else {
                    by_target.push((dst, deltas));
                }
            }
            for (dst, deltas) in by_target {
                let payload: Vec<Packet> = deltas
                    .iter()
                    .map(|d| self.wpkts[k][*d as usize].take().expect("missing offset packet"))
                    .collect();
                out.push(Msg::new(self.procs[k], self.procs[dst], payload));
            }
        }
        out
    }

    /// Final local step: `x̃_k = y_k − Σ_{i=K}^{nm−1} C[k−i][k]·x_{k−i}`
    /// (eq. (4)); no-op when `mn == K`.
    fn finalize(&mut self) {
        let PsParams { k: kk, m, n, .. } = self.params;
        for k in 0..kk {
            let mut y = self.wpkts[k][0].take().expect("y_k missing");
            for i in kk as u64..n * m {
                // r = (k − (i − K)) mod K — the owner counted twice; the
                // prepare memory still holds x_r (i − K < m).
                let r = ((k as u64 + kk as u64 - (i - kk as u64)) % kk as u64) as usize;
                let coeff = self.f.neg(self.c[(r, k)]);
                let x = self.mem[k].get(&r).expect("missing dup packet");
                pkt_add_scaled(&self.f, &mut y, coeff, x);
            }
            self.out[k] = Some(y);
        }
        self.done = true;
    }
}

impl<F: Field> Collective for PrepareShoot<F> {
    fn participants(&self) -> Vec<ProcId> {
        self.procs.clone()
    }

    fn is_done(&self) -> bool {
        self.done
    }

    fn step(&mut self, inbox: Vec<Msg>) -> Vec<Msg> {
        let PsParams { tp, ts, .. } = self.params;
        // Deliver the previous round's messages.
        let prev = self.t;
        if prev >= 1 && prev <= tp {
            self.absorb_prepare(inbox, prev);
        } else if prev > tp {
            self.absorb_shoot(inbox, prev - tp);
        } else {
            debug_assert!(inbox.is_empty());
        }
        // Phase transitions.
        if prev == tp {
            self.init_shoot();
            if self.done {
                return Vec::new();
            }
        }
        if prev == tp + ts {
            self.finalize();
            return Vec::new();
        }
        // Emit the next round.
        self.t += 1;
        if self.t <= tp {
            self.emit_prepare(self.t)
        } else {
            self.emit_shoot(self.t - tp)
        }
    }

    fn outputs(&self) -> HashMap<ProcId, Packet> {
        self.procs
            .iter()
            .zip(&self.out)
            .map(|(&p, o)| (p, o.clone().expect("prepare-and-shoot incomplete")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::GfPrime;
    use crate::net::{run, Sim};

    fn check(k: usize, p: usize, w: usize, seed: u64) -> crate::net::SimReport {
        let f = GfPrime::default_field();
        let c = Arc::new(Mat::random(&f, k, k, seed));
        let inputs: Vec<Packet> = (0..k)
            .map(|i| (0..w).map(|j| f.elem((i * w + j) as u64 * 7919 + 13)).collect())
            .collect();
        let mut ps = PrepareShoot::new(f, (0..k).collect(), p, c.clone(), inputs.clone());
        let rep = run(&mut Sim::new(p), &mut ps).unwrap();
        // Oracle: x̃ = x · C, column k per processor, element-wise over W.
        let outs = ps.outputs();
        for kk in 0..k {
            let mut want = pkt_zero(w);
            for r in 0..k {
                pkt_add_scaled(&f, &mut want, c[(r, kk)], &inputs[r]);
            }
            assert_eq!(outs[&kk], want, "K={k} p={p} proc {kk}");
        }
        rep
    }

    #[test]
    fn correct_for_many_shapes() {
        for (k, p) in [
            (1usize, 1usize),
            (2, 1),
            (3, 1),
            (4, 1),
            (5, 1),
            (8, 1),
            (9, 1),
            (16, 1),
            (25, 1),
            (3, 2),
            (9, 2),
            (10, 2),
            (27, 2),
            (65, 2),
            (4, 3),
            (16, 3),
            (31, 3),
            (100, 4),
        ] {
            check(k, p, 1, k as u64 * 31 + p as u64);
        }
    }

    #[test]
    fn correct_for_vector_payloads() {
        check(25, 2, 4, 99);
        check(16, 1, 3, 98);
    }

    #[test]
    fn c1_is_optimal() {
        // Lemma 1: C1 = ⌈log_{p+1} K⌉ exactly.
        for (k, p) in [(4usize, 1usize), (64, 1), (65, 2), (27, 2), (100, 4)] {
            let rep = check(k, p, 1, 7);
            assert_eq!(rep.c1, ceil_log(p as u64 + 1, k as u64) as u64);
        }
    }

    #[test]
    fn c2_matches_theorem3_exact_powers() {
        // Theorem 3 for K = (p+1)^L: C2 = ((p+1)^{T_p} − 1 + (p+1)^{T_s} − 1)/p.
        for (k, p) in [(16usize, 1usize), (64, 1), (81, 2), (256, 3)] {
            let rep = check(k, p, 1, 3);
            let prm = PsParams::new(k, p);
            let expect = (ipow(p as u64 + 1, prm.tp) - 1) / p as u64
                + (ipow(p as u64 + 1, prm.ts) - 1) / p as u64;
            assert_eq!(rep.c2, expect, "K={k} p={p}");
        }
    }

    #[test]
    fn fig2_k4_p1_two_rounds() {
        // Fig. 2: K=4, p=1 — any C computed in exactly 2 rounds.
        let rep = check(4, 1, 1, 42);
        assert_eq!(rep.c1, 2);
        assert_eq!(rep.c2, 2); // one element per round
    }

    #[test]
    fn gf2e_field_also_works() {
        let f = crate::gf::Gf2e::new(8).unwrap();
        let k = 13;
        let c = Arc::new(Mat::random(&f, k, k, 5));
        let inputs: Vec<Packet> = (0..k as u64).map(|i| vec![(i * 17 + 1) % 256]).collect();
        let mut ps = PrepareShoot::new(f.clone(), (0..k).collect(), 2, c.clone(), inputs.clone());
        run(&mut Sim::new(2), &mut ps).unwrap();
        let outs = ps.outputs();
        for kk in 0..k {
            let mut want = pkt_zero(1);
            for r in 0..k {
                pkt_add_scaled(&f, &mut want, c[(r, kk)], &inputs[r]);
            }
            assert_eq!(outs[&kk], want);
        }
    }
}

//! **Prepare-and-shoot** — the optimal universal all-to-all encode (§IV-B).
//!
//! For any square matrix `C ∈ F_q^{K×K}`, every processor `P_k` (holding
//! `x_k`) obtains `x̃_k = Σ_r C[r][k]·x_r` in `C1 = ⌈log_{p+1} K⌉` rounds
//! (optimal by Lemma 1) with `C2 ≈ 2√K/p` (within `√2` of Lemma 2).
//!
//! Let `L = ⌈log_{p+1} K⌉`, `T_p = ⌈L/2⌉`, `T_s = L − T_p`,
//! `m = (p+1)^{T_p}`, `n = ⌈K/m⌉`.
//!
//! * **Prepare** (Algorithm 1): `K` parallel `(p+1)`-nomial broadcasts;
//!   after round `t` of distances `ρ(p+1)^{T_p−t}`, every `P_k` holds
//!   `x_r` for `r ∈ R_k^- = {k−ℓ mod K : ℓ < (p+1)^t}`.
//! * **Shoot** (Algorithm 2): every `P_k` forms the partially-coded
//!   packets `w_{k,k+ℓm} = Σ_{r∈R_k^-} C[r][k+ℓm]·x_r` and the `K`
//!   stride-`m` classes run parallel `(p+1)`-ary reductions: writing the
//!   destination offset `δ = ℓ` in base `p+1`, round `t` moves every
//!   packet whose digit `t−1` equals `ρ` over distance `ρ(p+1)^{t−1}m`,
//!   summing into the receiver's matching packet. (Algorithm 2 of the
//!   paper prints the distance as `ρ·m^t`; Lemma 4 and Fig. 7 are only
//!   consistent with `ρ·(p+1)^{t−1}·m`, which is what we implement — see
//!   DESIGN.md §1.)
//! * **Correction** (eq. (4)): when `mn > K` the stride class wraps and
//!   `y_k` double-counts `C[r][k]·x_r` for `r ∈ [k−(nm−K)+1 … k]`; each
//!   processor subtracts those terms locally (it holds all of them).
//!
//! Message contents are never tagged on the wire: the scheduling is known
//! a priori (Remark 1), so receivers recompute the exact (owner / offset)
//! lists the sender used.
//!
//! Every per-processor working set — the prepare memory and the `n` shoot
//! accumulators — lives in one contiguous [`PacketBuf`], and the per-rank
//! emit/accumulate loops fan out over rayon under the `parallel` feature
//! (bit-identical to sequential stepping: disjoint outputs merged in rank
//! order, exact integer accumulation).

use super::{par_flat_map_msgs, par_for_each_mut, par_map_msgs_mut};
use crate::gf::{Field, Mat};
use crate::net::{
    pkt_add, pkt_add_scaled, pkt_zero, Collective, Msg, Outputs, Packet, PacketBuf, ProcId,
};
use crate::util::{ceil_log, ipow};
use std::collections::HashMap;
use std::sync::Arc;

/// Static shape parameters of a prepare-and-shoot instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PsParams {
    pub k: usize,
    pub p: usize,
    /// `L = ⌈log_{p+1} K⌉` — total rounds.
    pub l: u32,
    /// Prepare rounds `T_p = ⌈L/2⌉`.
    pub tp: u32,
    /// Shoot rounds `T_s = L − T_p`.
    pub ts: u32,
    /// `m = (p+1)^{T_p}`.
    pub m: u64,
    /// `n = ⌈K/m⌉`.
    pub n: u64,
}

impl PsParams {
    pub fn new(k: usize, p: usize) -> Self {
        assert!(k >= 1 && p >= 1);
        let l = ceil_log(p as u64 + 1, k as u64);
        let tp = l.div_ceil(2);
        let ts = l - tp;
        let m = ipow(p as u64 + 1, tp);
        let n = (k as u64).div_ceil(m);
        PsParams {
            k,
            p,
            l,
            tp,
            ts,
            m,
            n,
        }
    }
}

/// A rank's prepare-phase memory: every received owner packet appended to
/// one flat buffer, with an owner → slot index.
struct PrepMem {
    buf: PacketBuf,
    slot: HashMap<usize, usize>,
    /// Substitute for owners that never arrived: under crash-stop fault
    /// injection (`net::run_degraded`) an expected delivery may be
    /// dropped; the rank is then *tainted* — its values are garbage by
    /// definition — but it must keep the schedule, so it sends zeros in
    /// place of the missing packet instead of panicking.
    zero: Packet,
}

impl PrepMem {
    fn new(owner: usize, pkt: Packet) -> Self {
        PrepMem {
            zero: vec![0; pkt.len()],
            slot: HashMap::from([(owner, 0)]),
            buf: PacketBuf::from_packet(pkt),
        }
    }

    /// Store `pkt` for `owner` unless already held (duplicate deliveries
    /// may occur when two ports collapse to the same distance mod K).
    fn insert(&mut self, owner: usize, pkt: &[u64]) {
        if let std::collections::hash_map::Entry::Vacant(e) = self.slot.entry(owner) {
            e.insert(self.buf.count());
            self.buf.push(pkt);
        }
    }

    /// The packet held for `owner`, or zeros if its delivery was dropped
    /// (possible only on a tainted rank of a degraded run — healthy runs
    /// always hold every scheduled owner).
    fn get(&self, owner: usize) -> &[u64] {
        match self.slot.get(&owner) {
            Some(&s) => self.buf.pkt(s),
            None => self.zero.as_slice(),
        }
    }
}

/// A rank's shoot-phase working set: the `n` partial packets `w_{k,k+ℓm}`
/// in one flat allocation (offsets vacate as packets move toward their
/// destinations — tracked by `alive`).
struct ShootSet {
    buf: PacketBuf,
    alive: Vec<bool>,
}

/// The prepare-and-shoot universal A2A collective.
pub struct PrepareShoot<F: Field> {
    f: F,
    procs: Vec<ProcId>,
    rank_of: HashMap<ProcId, usize>,
    c: Arc<Mat>,
    params: PsParams,
    w: usize,
    /// Completed step calls (== rounds issued so far).
    t: u32,
    mem: Vec<PrepMem>,
    wsets: Vec<ShootSet>,
    out: Vec<Option<Packet>>,
    done: bool,
}

impl<F: Field> PrepareShoot<F> {
    /// `procs[k]` holds `inputs[k]`; computes the matrix `c` (`K×K`).
    pub fn new(f: F, procs: Vec<ProcId>, p: usize, c: Arc<Mat>, inputs: Vec<Packet>) -> Self {
        let k = procs.len();
        assert_eq!(c.rows, k, "matrix rows must equal K");
        assert_eq!(c.cols, k, "matrix cols must equal K");
        assert_eq!(inputs.len(), k);
        let w = inputs.first().map_or(0, |p| p.len());
        assert!(inputs.iter().all(|p| p.len() == w));
        let params = PsParams::new(k, p);
        let mem = inputs
            .into_iter()
            .enumerate()
            .map(|(r, pkt)| PrepMem::new(r, pkt))
            .collect();
        let mut ps = PrepareShoot {
            rank_of: procs.iter().enumerate().map(|(i, &p)| (p, i)).collect(),
            f,
            procs,
            c,
            params,
            w,
            t: 0,
            mem,
            wsets: Vec::new(),
            out: vec![None; k],
            done: false,
        };
        if k == 1 {
            // Degenerate: x̃_0 = C[0][0]·x_0, no communication.
            let pkt = crate::net::pkt_scale(&ps.f, ps.c[(0, 0)], ps.mem[0].get(0));
            ps.out[0] = Some(pkt);
            ps.done = true;
        }
        ps
    }

    /// Convenience: build from a pipeline output map.
    pub fn from_outputs(
        f: F,
        procs: Vec<ProcId>,
        p: usize,
        c: Arc<Mat>,
        inputs: &Outputs,
    ) -> Self {
        let packets = procs.iter().map(|pid| inputs[pid].clone()).collect();
        PrepareShoot::new(f, procs, p, c, packets)
    }

    /// Owners held by rank `k` at the start of prepare round `t`
    /// (1-indexed). Distances shrink over rounds (`ρ(p+1)^{T_p−t}`), so
    /// after `t−1` rounds the memory holds
    /// `{k − j·(p+1)^{T_p−t+1} mod K : j < (p+1)^{t−1}}` — contiguous only
    /// once the phase completes (`t = T_p+1`, stride 1, i.e. `R_k^-`).
    /// Ordered by `j`, deduplicated on wrap-around.
    fn prep_owners(&self, k: usize, t: u32) -> Vec<usize> {
        let kk = self.params.k;
        let span = ipow(self.params.p as u64 + 1, t - 1);
        let stride = ipow(self.params.p as u64 + 1, self.params.tp + 1 - t);
        // Fast path: no wrap-around possible ⇒ all owners distinct
        // (span·stride = (p+1)^{T_p} = m, so this covers every round
        // whenever m ≤ K — i.e. all but degenerate instances).
        if span * stride <= kk as u64 {
            return (0..span)
                .map(|j| ((k as u64 + kk as u64 - j * stride) % kk as u64) as usize)
                .collect();
        }
        let mut out = Vec::new();
        let mut seen = vec![false; kk];
        for j in 0..span {
            let back = (j * stride) % kk as u64;
            let owner = ((k as u64 + kk as u64 - back) % kk as u64) as usize;
            if !seen[owner] {
                seen[owner] = true;
                out.push(owner);
            }
            if out.len() == kk {
                break;
            }
        }
        out
    }

    /// Offsets alive at the start of shoot round `t` (1-indexed): all
    /// `δ < n` whose base-(p+1) digits below `t−1` are zero, ascending.
    fn shoot_offsets(&self, t: u32) -> Vec<u64> {
        let stride = ipow(self.params.p as u64 + 1, t - 1);
        (0..self.params.n).filter(|d| d % stride == 0).collect()
    }

    /// Process one prepare-round inbox.
    fn absorb_prepare(&mut self, inbox: Vec<Msg>, t: u32) {
        for msg in inbox {
            let dst = self.rank_of[&msg.dst];
            let src = self.rank_of[&msg.src];
            let owners = self.prep_owners(src, t);
            assert_eq!(
                owners.len(),
                msg.payload.count(),
                "prepare schedule mismatch"
            );
            for (owner, pkt) in owners.into_iter().zip(msg.payload.iter()) {
                self.mem[dst].insert(owner, pkt);
            }
        }
    }

    /// Emit prepare round `t` (1-indexed): send the whole memory over
    /// distances `ρ(p+1)^{T_p−t}`, skipping self-targets and duplicates.
    fn emit_prepare(&self, t: u32) -> Vec<Msg> {
        let kk = self.params.k;
        par_flat_map_msgs(kk, |k| {
            let owners = self.prep_owners(k, t);
            let mut targets = Vec::new();
            for rho in 1..=self.params.p as u64 {
                let d = (rho * ipow(self.params.p as u64 + 1, self.params.tp - t)) % kk as u64;
                if d == 0 {
                    continue;
                }
                let dst = (k + d as usize) % kk;
                if dst != k && !targets.contains(&dst) {
                    targets.push(dst);
                }
            }
            let mut msgs = Vec::with_capacity(targets.len());
            for dst in targets {
                let payload = PacketBuf::from_slices(
                    self.w,
                    owners.iter().map(|&o| self.mem[k].get(o)),
                );
                msgs.push(Msg::new(self.procs[k], self.procs[dst], payload));
            }
            msgs
        })
    }

    /// After the prepare phase: initialise the shoot-phase partial packets
    /// `w_{k,k+ℓm}` (or compute outputs directly when `n == 1`).
    fn init_shoot(&mut self) {
        let PsParams { k: kk, m, n, .. } = self.params;
        let f = &self.f;
        let c = &self.c;
        let mem = &self.mem;
        let w = self.w;
        if n == 1 {
            // m ≥ K: everyone holds everything — pure local combine.
            par_for_each_mut(&mut self.out, |k, slot| {
                let mut acc = pkt_zero(w);
                let terms: Vec<(u64, &[u64])> =
                    (0..kk).map(|r| (c[(r, k)], mem[k].get(r))).collect();
                f.lincomb_into(&mut acc, &terms);
                *slot = Some(acc);
            });
            self.done = true;
            return;
        }
        // k-major sweep: rank k holds x_r for every r ∈ R_k^- = (k−m, k]
        // after the prepare phase (n ≥ 2 ⇒ m < K, no wrap), so each rank's
        // n·W accumulator block and its own flat prepare memory are the
        // only live state — one contiguous working set per processor.
        // Products accumulate unreduced within the lazy bound (`m` terms
        // per accumulator); accumulation is exact integer (or XOR)
        // arithmetic, so the parallel fan-out below is bit-identical to a
        // sequential sweep.
        let lazy_chunk = f.lazy_chunk();
        let per_term_reduce = (m as usize) > lazy_chunk;
        let mut wsets: Vec<ShootSet> = (0..kk)
            .map(|_| ShootSet {
                buf: PacketBuf::zeros(w, n as usize),
                alive: vec![true; n as usize],
            })
            .collect();
        par_for_each_mut(&mut wsets, |k, ws| {
            for back in 0..m {
                let r = ((k as u64 + kk as u64 - back) % kk as u64) as usize;
                let x = mem[k].get(r);
                let crow = c.row(r);
                for l in 0..n as usize {
                    let dest = (k + l * m as usize) % kk;
                    let coeff = crow[dest];
                    if coeff == 0 {
                        continue;
                    }
                    let acc = ws.buf.pkt_mut(l);
                    for (a, &s) in acc.iter_mut().zip(x) {
                        *a = f.lazy_mul_acc(*a, coeff, s);
                    }
                    if per_term_reduce {
                        for a in acc.iter_mut() {
                            *a = f.lazy_reduce(*a);
                        }
                    }
                }
            }
            for a in ws.buf.data_mut() {
                *a = f.lazy_reduce(*a);
            }
        });
        self.wsets = wsets;
    }

    /// Process one shoot-round inbox (accumulate matching offsets).
    fn absorb_shoot(&mut self, inbox: Vec<Msg>, t: u32) {
        let kk = self.params.k as u64;
        let stride = ipow(self.params.p as u64 + 1, t - 1);
        let offsets = self.shoot_offsets(t);
        for msg in inbox {
            let dst = self.rank_of[&msg.dst];
            let src = self.rank_of[&msg.src];
            // Which ρ values map src→dst over distance ρ·stride·m (mod K)?
            let mut expect: Vec<u64> = Vec::new(); // new offsets, sender order
            for rho in 1..=self.params.p as u64 {
                let d = (rho * stride * self.params.m) % kk;
                if d == 0 {
                    continue;
                }
                if (src as u64 + d) % kk == dst as u64 {
                    for &delta in &offsets {
                        if (delta / stride) % (self.params.p as u64 + 1) == rho {
                            expect.push(delta - rho * stride);
                        }
                    }
                }
            }
            assert_eq!(expect.len(), msg.payload.count(), "shoot schedule mismatch");
            let ws = &mut self.wsets[dst];
            for (delta_new, pkt) in expect.into_iter().zip(msg.payload.iter()) {
                assert!(
                    ws.alive[delta_new as usize],
                    "receiver missing offset packet"
                );
                pkt_add(&self.f, ws.buf.pkt_mut(delta_new as usize), pkt);
            }
        }
    }

    /// Emit shoot round `t` (1-indexed).
    fn emit_shoot(&mut self, t: u32) -> Vec<Msg> {
        let PsParams { k: kk, m, p, .. } = self.params;
        let stride = ipow(p as u64 + 1, t - 1);
        let offsets = self.shoot_offsets(t);
        let f = &self.f;
        let procs = &self.procs;
        let w = self.w;
        par_map_msgs_mut(&mut self.wsets, |k, ws| {
            // Group offsets by ρ = digit_{t−1}(δ).
            let mut by_target: Vec<(usize, Vec<u64>)> = Vec::new(); // (dst, old offsets)
            for rho in 1..=p as u64 {
                let deltas: Vec<u64> = offsets
                    .iter()
                    .copied()
                    .filter(|d| (d / stride) % (p as u64 + 1) == rho)
                    .collect();
                if deltas.is_empty() {
                    continue;
                }
                let d = (rho * stride * m) % kk as u64;
                if d == 0 {
                    // Self-target: merge locally, no message.
                    for delta in deltas {
                        let tgt = (delta - rho * stride) as usize;
                        let delta = delta as usize;
                        assert!(ws.alive[delta] && ws.alive[tgt], "missing offset");
                        let (dst_pkt, src_pkt) = ws.buf.pair_mut(tgt, delta);
                        pkt_add(f, dst_pkt, src_pkt);
                        ws.alive[delta] = false;
                    }
                    continue;
                }
                let dst = (k + d as usize) % kk;
                if let Some(entry) = by_target.iter_mut().find(|(t, _)| *t == dst) {
                    entry.1.extend(deltas);
                } else {
                    by_target.push((dst, deltas));
                }
            }
            let mut msgs = Vec::with_capacity(by_target.len());
            for (dst, deltas) in by_target {
                let payload = PacketBuf::from_slices(
                    w,
                    deltas.iter().map(|&d| {
                        assert!(ws.alive[d as usize], "missing offset packet");
                        ws.buf.pkt(d as usize)
                    }),
                );
                for &d in &deltas {
                    ws.alive[d as usize] = false;
                }
                msgs.push(Msg::new(procs[k], procs[dst], payload));
            }
            msgs
        })
    }

    /// Final local step: `x̃_k = y_k − Σ_{i=K}^{nm−1} C[k−i][k]·x_{k−i}`
    /// (eq. (4)); no-op when `mn == K`.
    fn finalize(&mut self) {
        let PsParams { k: kk, m, n, .. } = self.params;
        for k in 0..kk {
            let ws = &self.wsets[k];
            assert!(ws.alive[0], "y_k missing");
            let mut y = ws.buf.pkt(0).to_vec();
            for i in kk as u64..n * m {
                // r = (k − (i − K)) mod K — the owner counted twice; the
                // prepare memory still holds x_r (i − K < m).
                let r = ((k as u64 + kk as u64 - (i - kk as u64)) % kk as u64) as usize;
                let coeff = self.f.neg(self.c[(r, k)]);
                pkt_add_scaled(&self.f, &mut y, coeff, self.mem[k].get(r));
            }
            self.out[k] = Some(y);
        }
        self.done = true;
    }
}

impl<F: Field> Collective for PrepareShoot<F> {
    fn participants(&self) -> Vec<ProcId> {
        self.procs.clone()
    }

    fn is_done(&self) -> bool {
        self.done
    }

    fn step(&mut self, inbox: Vec<Msg>) -> Vec<Msg> {
        let PsParams { tp, ts, .. } = self.params;
        // Deliver the previous round's messages.
        let prev = self.t;
        if prev >= 1 && prev <= tp {
            self.absorb_prepare(inbox, prev);
        } else if prev > tp {
            self.absorb_shoot(inbox, prev - tp);
        } else {
            debug_assert!(inbox.is_empty());
        }
        // Phase transitions.
        if prev == tp {
            self.init_shoot();
            if self.done {
                return Vec::new();
            }
        }
        if prev == tp + ts {
            self.finalize();
            return Vec::new();
        }
        // Emit the next round.
        self.t += 1;
        if self.t <= tp {
            self.emit_prepare(self.t)
        } else {
            self.emit_shoot(self.t - tp)
        }
    }

    fn outputs(&self) -> Outputs {
        self.procs
            .iter()
            .zip(&self.out)
            .map(|(&p, o)| (p, o.clone().expect("prepare-and-shoot incomplete")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::GfPrime;
    use crate::net::{run, Sim};

    fn check(k: usize, p: usize, w: usize, seed: u64) -> crate::net::SimReport {
        let f = GfPrime::default_field();
        let c = Arc::new(Mat::random(&f, k, k, seed));
        let inputs: Vec<Packet> = (0..k)
            .map(|i| (0..w).map(|j| f.elem((i * w + j) as u64 * 7919 + 13)).collect())
            .collect();
        let mut ps = PrepareShoot::new(f, (0..k).collect(), p, c.clone(), inputs.clone());
        let rep = run(&mut Sim::new(p), &mut ps).unwrap();
        // Oracle: x̃ = x · C, column k per processor, element-wise over W.
        let outs = ps.outputs();
        for kk in 0..k {
            let mut want = pkt_zero(w);
            for r in 0..k {
                pkt_add_scaled(&f, &mut want, c[(r, kk)], &inputs[r]);
            }
            assert_eq!(outs[&kk], want, "K={k} p={p} proc {kk}");
        }
        rep
    }

    #[test]
    fn correct_for_many_shapes() {
        for (k, p) in [
            (1usize, 1usize),
            (2, 1),
            (3, 1),
            (4, 1),
            (5, 1),
            (8, 1),
            (9, 1),
            (16, 1),
            (25, 1),
            (3, 2),
            (9, 2),
            (10, 2),
            (27, 2),
            (65, 2),
            (4, 3),
            (16, 3),
            (31, 3),
            (100, 4),
        ] {
            check(k, p, 1, k as u64 * 31 + p as u64);
        }
    }

    #[test]
    fn correct_for_vector_payloads() {
        check(25, 2, 4, 99);
        check(16, 1, 3, 98);
    }

    #[test]
    fn c1_is_optimal() {
        // Lemma 1: C1 = ⌈log_{p+1} K⌉ exactly.
        for (k, p) in [(4usize, 1usize), (64, 1), (65, 2), (27, 2), (100, 4)] {
            let rep = check(k, p, 1, 7);
            assert_eq!(rep.c1, ceil_log(p as u64 + 1, k as u64) as u64);
        }
    }

    #[test]
    fn c2_matches_theorem3_exact_powers() {
        // Theorem 3 for K = (p+1)^L: C2 = ((p+1)^{T_p} − 1 + (p+1)^{T_s} − 1)/p.
        for (k, p) in [(16usize, 1usize), (64, 1), (81, 2), (256, 3)] {
            let rep = check(k, p, 1, 3);
            let prm = PsParams::new(k, p);
            let expect = (ipow(p as u64 + 1, prm.tp) - 1) / p as u64
                + (ipow(p as u64 + 1, prm.ts) - 1) / p as u64;
            assert_eq!(rep.c2, expect, "K={k} p={p}");
        }
    }

    #[test]
    fn fig2_k4_p1_two_rounds() {
        // Fig. 2: K=4, p=1 — any C computed in exactly 2 rounds.
        let rep = check(4, 1, 1, 42);
        assert_eq!(rep.c1, 2);
        assert_eq!(rep.c2, 2); // one element per round
    }

    #[test]
    fn gf2e_field_also_works() {
        let f = crate::gf::Gf2e::new(8).unwrap();
        let k = 13;
        let c = Arc::new(Mat::random(&f, k, k, 5));
        let inputs: Vec<Packet> = (0..k as u64).map(|i| vec![(i * 17 + 1) % 256]).collect();
        let mut ps = PrepareShoot::new(f.clone(), (0..k).collect(), 2, c.clone(), inputs.clone());
        run(&mut Sim::new(2), &mut ps).unwrap();
        let outs = ps.outputs();
        for kk in 0..k {
            let mut want = pkt_zero(1);
            for r in 0..k {
                pkt_add_scaled(&f, &mut want, c[(r, kk)], &inputs[r]);
            }
            assert_eq!(outs[&kk], want);
        }
    }
}

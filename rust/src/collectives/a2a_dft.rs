//! Permuted-DFT all-to-all encode (§V-A) and its inverse (Lemma 5).
//!
//! For `K = P^H` with `K | q−1`, processors compute `D_K·Π` (`Π` = base-P
//! digit reversal): processor `k` obtains `f(β^{k'})`. The algorithm is an
//! in-network FFT: `H` sequential steps; in step `h`, the `K/P` groups of
//! processors whose *reversed* indices agree outside digit `h` each
//! perform a `P×P` all-to-all encode on the Vandermonde `A_k^{(h)}`
//! (eq. (14)) built from the element tree `γ` (eqs. (9)–(10)) — run here
//! with prepare-and-shoot, which degenerates to the optimal single-round
//! exchange when `P ≤ p+1` (Corollary 1).
//!
//! The inverse runs the steps in reverse order with `(A_k^{(h)})^{-1}`
//! (invertible Vandermonde), at identical cost.

use super::{Par, Pipeline, PrepareShoot, StageBuilder};
use crate::gf::{dft, vandermonde, Field, Mat};
use crate::net::{Collective, Msg, Outputs, Packet, ProcId};
use crate::util::ipow;
use std::sync::Arc;

/// The §V-A specific A2A. Computes `D_K·Π` (or its inverse).
pub struct DftA2A {
    pipe: Pipeline,
    k: usize,
}

impl DftA2A {
    /// `procs.len() = K = p_base^h`; `inputs[k]` is held by `procs[k]`.
    /// `invert = false` computes `D_K·Π`, `true` computes `(D_K·Π)^{-1}`.
    pub fn new<F: Field>(
        f: F,
        procs: Vec<ProcId>,
        p: usize,
        p_base: u64,
        h: u32,
        inputs: Vec<Packet>,
        invert: bool,
    ) -> anyhow::Result<Self> {
        let k = procs.len();
        anyhow::ensure!(k as u64 == ipow(p_base, h), "K must equal P^H");
        anyhow::ensure!(p_base >= 2, "P >= 2");
        let beta = f
            .root_of_unity(k as u64)
            .ok_or_else(|| anyhow::anyhow!("K = {k} must divide q−1 = {}", f.order() - 1))?;
        anyhow::ensure!(inputs.len() == k);

        // Steps h = 1..=H forward, or H..=1 reversed for the inverse.
        let steps: Vec<u32> = if invert {
            (1..=h).rev().collect()
        } else {
            (1..=h).collect()
        };
        let builders: Vec<StageBuilder> = steps
            .into_iter()
            .map(|step_h| {
                let f = f.clone();
                let procs = procs.clone();
                Box::new(move |prev: &Outputs| {
                    step_stage(&f, &procs, p, p_base, h, beta, step_h, invert, prev)
                }) as StageBuilder
            })
            .collect();
        let init: Outputs = procs
            .iter()
            .zip(inputs)
            .map(|(&pid, pkt)| (pid, pkt))
            .collect();
        Ok(DftA2A {
            pipe: Pipeline::from_inputs(init, builders),
            k,
        })
    }

    /// The matrix this collective computes (oracle for tests):
    /// `(D_K Π)[i][j] = β^{i·rev(j)}`, or its inverse.
    pub fn matrix<F: Field>(f: &F, p_base: u64, h: u32, invert: bool) -> Option<Mat> {
        let m = dft::permuted_dft_matrix(f, p_base, h)?;
        if invert {
            m.inverse(f)
        } else {
            Some(m)
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }
}

/// Build step `h` as a [`Par`] of `K/P` group-wise `P×P` prepare-and-shoots.
#[allow(clippy::too_many_arguments)]
fn step_stage<F: Field>(
    f: &F,
    procs: &[ProcId],
    p: usize,
    p_base: u64,
    h_total: u32,
    beta: u64,
    h: u32,
    invert: bool,
    prev: &Outputs,
) -> Box<dyn Collective> {
    let k = procs.len() as u64;
    let ph_1 = ipow(p_base, h - 1); // P^{h−1} — the digit weight in k′
    let mut groups: Vec<Box<dyn Collective>> = Vec::new();
    // Enumerate groups: fix all digits of k′ except digit h.
    for base in 0..k / p_base {
        let high = base / ph_1; // digits above position h of k′
        let low = base % ph_1; // digits below position h of k′
        // Group members in digit order c = 0..P−1, and their γ points.
        let mut members = Vec::with_capacity(p_base as usize);
        let mut points = Vec::with_capacity(p_base as usize);
        for c in 0..p_base {
            let kprime = high * ipow(p_base, h) + c * ph_1 + low;
            let kk = dft::digit_reverse(kprime, p_base, h_total) as usize;
            members.push(procs[kk]);
            // γ_{c k'_{h−1}…k'_1} = β^{(c·P^{h−1} + low)·K/P^h}
            points.push(dft::gamma(f, beta, k, p_base, h, c * ph_1 + low));
        }
        // A_k^{(h)}[ρ][c] = γ_c^ρ — a P×P Vandermonde (eq. (14)).
        let mat = if invert {
            vandermonde::inverse(f, &points)
        } else {
            vandermonde::square(f, &points)
        };
        let inputs: Vec<Packet> = members.iter().map(|pid| prev[pid].clone()).collect();
        groups.push(Box::new(PrepareShoot::new(
            f.clone(),
            members,
            p,
            Arc::new(mat),
            inputs,
        )));
    }
    Box::new(Par::new(groups).expect("disjoint by construction"))
}

impl Collective for DftA2A {
    fn participants(&self) -> Vec<ProcId> {
        self.pipe.participants()
    }
    fn is_done(&self) -> bool {
        self.pipe.is_done()
    }
    fn step(&mut self, inbox: Vec<Msg>) -> Vec<Msg> {
        self.pipe.step(inbox)
    }
    fn outputs(&self) -> Outputs {
        self.pipe.outputs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::GfPrime;
    use crate::net::{pkt_add_scaled, pkt_zero, run, Sim};

    fn f() -> GfPrime {
        GfPrime::default_field()
    }

    fn inputs_for(k: usize) -> Vec<Packet> {
        let f = f();
        (0..k as u64).map(|i| vec![f.elem(i * 997 + 3)]).collect()
    }

    fn run_dft(p_base: u64, h: u32, p: usize, invert: bool) -> (crate::net::SimReport, Vec<Packet>) {
        let f = f();
        let k = ipow(p_base, h) as usize;
        let mut dft =
            DftA2A::new(f, (0..k).collect(), p, p_base, h, inputs_for(k), invert).unwrap();
        let rep = run(&mut Sim::new(p), &mut dft).unwrap();
        let outs = dft.outputs();
        let got: Vec<Packet> = (0..k).map(|i| outs[&i].clone()).collect();
        (rep, got)
    }

    fn oracle(f: &GfPrime, m: &Mat, inputs: &[Packet]) -> Vec<Packet> {
        let k = inputs.len();
        (0..k)
            .map(|j| {
                let mut acc = pkt_zero(1);
                for r in 0..k {
                    pkt_add_scaled(f, &mut acc, m[(r, j)], &inputs[r]);
                }
                acc
            })
            .collect()
    }

    #[test]
    fn computes_permuted_dft() {
        let f = f();
        for (p_base, h, p) in [(2u64, 3u32, 1usize), (2, 4, 1), (4, 2, 3), (2, 3, 2), (8, 2, 7)] {
            let k = ipow(p_base, h) as usize;
            let m = DftA2A::matrix(&f, p_base, h, false).unwrap();
            let (_, got) = run_dft(p_base, h, p, false);
            assert_eq!(got, oracle(&f, &m, &inputs_for(k)), "P={p_base} H={h} p={p}");
        }
    }

    #[test]
    fn inverse_composes_to_identity() {
        let f = f();
        let (p_base, h, p) = (2u64, 3u32, 1usize);
        let k = ipow(p_base, h) as usize;
        let inputs = inputs_for(k);
        let mut fwd =
            DftA2A::new(f, (0..k).collect(), p, p_base, h, inputs.clone(), false).unwrap();
        run(&mut Sim::new(p), &mut fwd).unwrap();
        let mid: Vec<Packet> = (0..k).map(|i| fwd.outputs()[&i].clone()).collect();
        let mut inv = DftA2A::new(f, (0..k).collect(), p, p_base, h, mid, true).unwrap();
        run(&mut Sim::new(p), &mut inv).unwrap();
        let back: Vec<Packet> = (0..k).map(|i| inv.outputs()[&i].clone()).collect();
        assert_eq!(back, inputs);
    }

    #[test]
    fn corollary1_cost_when_p_base_is_p_plus_1() {
        // K = (p+1)^H: C1 = H, C2 = H (one element per round) — strictly
        // optimal per Remark 5.
        for (p, h) in [(1usize, 4u32), (3, 3)] {
            let p_base = p as u64 + 1;
            let (rep, _) = run_dft(p_base, h, p, false);
            assert_eq!(rep.c1, h as u64, "p={p} H={h}");
            assert_eq!(rep.c2, h as u64, "p={p} H={h}");
        }
        // p = 2 needs 3^H | q−1; the default prime has a single factor of
        // 3, so run K = 27 over q = 109 (108 = 4·27).
        let f = GfPrime::new(109).unwrap();
        let k = 27usize;
        let inputs: Vec<Packet> = (0..k as u64).map(|i| vec![f.elem(i * 5 + 1)]).collect();
        let mut d = DftA2A::new(f, (0..k).collect(), 2, 3, 3, inputs.clone(), false).unwrap();
        let rep = run(&mut Sim::new(2), &mut d).unwrap();
        assert_eq!((rep.c1, rep.c2), (3, 3));
        let m = DftA2A::matrix(&GfPrime::new(109).unwrap(), 3, 3, false).unwrap();
        let got: Vec<Packet> = (0..k).map(|i| d.outputs()[&i].clone()).collect();
        assert_eq!(got, oracle(&GfPrime::new(109).unwrap(), &m, &inputs));
    }

    #[test]
    fn theorem4_cost_general() {
        // C_{A2A,DFT} = H · C_{A2A,Univ}(P): with P = 8, p = 1:
        // C_univ(8) has C1 = 3 and C2 = (2^2−1)/1 + (2^1−1)/1 = 4.
        let (rep, _) = run_dft(8, 2, 1, false);
        assert_eq!(rep.c1, 2 * 3);
        assert_eq!(rep.c2, 2 * 4);
    }

    #[test]
    fn inverse_costs_match_lemma5() {
        let (fwd, _) = run_dft(2, 4, 1, false);
        let (inv, _) = run_dft(2, 4, 1, true);
        assert_eq!(fwd.c1, inv.c1);
        assert_eq!(fwd.c2, inv.c2);
    }
}

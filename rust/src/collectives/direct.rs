//! Direct-transfer encoding — the no-network-coding strawman.
//!
//! In the spirit of decentralized erasure codes (Dimakis et al. \[22\]):
//! every source sends its (pre-scaled) packet *directly* to every sink
//! that needs it; sinks accumulate. No intermediate combining, so the
//! schedule is a round-robin edge colouring of the complete bipartite
//! graph `K × R`: `C1 = ⌈K/p⌉·⌈R·p/…⌉`-ish — concretely `K·R` messages
//! at ≤ `p` per endpoint per round.
//!
//! This is the baseline that motivates the whole paper: its `C2` scales
//! with `K·W`, versus `O(√K·W)` for prepare-and-shoot.

use crate::gf::{Field, Mat};
use crate::net::{pkt_add_scaled, pkt_scale, pkt_zero, Collective, Msg, Outputs, Packet, ProcId};
use std::collections::HashMap;
use std::sync::Arc;

/// Direct dense encoding of `x·A` (`A: K×R`): sources `procs[..K]`,
/// sinks `procs[K..K+R]`.
pub struct DirectEncode<F: Field> {
    f: F,
    sources: Vec<ProcId>,
    sinks: Vec<ProcId>,
    p: usize,
    a: Arc<Mat>,
    inputs: Vec<Packet>,
    acc: Vec<Packet>,
    /// Pending (source rank, sink rank) transfers, in schedule order.
    pending: Vec<(usize, usize)>,
    cursor: usize,
    done: bool,
}

impl<F: Field> DirectEncode<F> {
    pub fn new(
        f: F,
        sources: Vec<ProcId>,
        sinks: Vec<ProcId>,
        p: usize,
        a: Arc<Mat>,
        inputs: Vec<Packet>,
    ) -> Self {
        let (k, r) = (sources.len(), sinks.len());
        assert_eq!(a.rows, k);
        assert_eq!(a.cols, r);
        assert_eq!(inputs.len(), k);
        let w = inputs.first().map_or(0, |x| x.len());
        // Latin-square-style schedule: in "slot" s, source i targets sink
        // (i + s) mod R — every slot is a partial matching.
        let mut pending = Vec::with_capacity(k * r);
        for s in 0..r {
            for i in 0..k {
                pending.push((i, (i + s) % r));
            }
        }
        DirectEncode {
            f,
            sources,
            sinks,
            p,
            a,
            inputs,
            acc: vec![pkt_zero(w); r],
            pending,
            cursor: 0,
            done: k == 0 || r == 0,
        }
    }
}

impl<F: Field> Collective for DirectEncode<F> {
    fn participants(&self) -> Vec<ProcId> {
        self.sources.iter().chain(&self.sinks).copied().collect()
    }

    fn is_done(&self) -> bool {
        self.done
    }

    fn step(&mut self, inbox: Vec<Msg>) -> Vec<Msg> {
        // Accumulate deliveries (packets arrive pre-scaled by A[i][j]).
        let sink_rank: HashMap<ProcId, usize> =
            self.sinks.iter().enumerate().map(|(i, &p)| (p, i)).collect();
        for m in inbox {
            let j = sink_rank[&m.dst];
            for pkt in m.payload.iter() {
                pkt_add_scaled(&self.f, &mut self.acc[j], 1, pkt);
            }
        }
        if self.cursor >= self.pending.len() {
            self.done = true;
            return Vec::new();
        }
        // Greedily fill a round under the p-port constraint.
        let mut out = Vec::new();
        let mut src_used: HashMap<usize, usize> = HashMap::new();
        let mut dst_used: HashMap<usize, usize> = HashMap::new();
        let mut remaining = Vec::new();
        for &(i, j) in &self.pending[self.cursor..] {
            let su = src_used.entry(i).or_default();
            let du = dst_used.entry(j).or_default();
            if *su < self.p && *du < self.p {
                *su += 1;
                *du += 1;
                let coeff = self.a[(i, j)];
                out.push(Msg::single(
                    self.sources[i],
                    self.sinks[j],
                    pkt_scale(&self.f, coeff, &self.inputs[i]),
                ));
            } else {
                remaining.push((i, j));
            }
        }
        self.pending.truncate(self.cursor);
        self.pending.extend(remaining);
        out
    }

    fn outputs(&self) -> Outputs {
        self.sinks
            .iter()
            .zip(&self.acc)
            .map(|(&p, a)| (p, a.clone()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{run, Sim};

    #[test]
    fn dense_encode_is_correct() {
        let f = crate::gf::GfPrime::default_field();
        for (k, r, p) in [(6usize, 3usize, 1usize), (4, 8, 2), (5, 5, 3)] {
            let a = Arc::new(Mat::random(&f, k, r, 9));
            let inputs: Vec<Packet> = (0..k as u64).map(|i| vec![f.elem(i + 1), i]).collect();
            let sources: Vec<ProcId> = (0..k).collect();
            let sinks: Vec<ProcId> = (k..k + r).collect();
            let mut d = DirectEncode::new(f, sources, sinks, p, a.clone(), inputs.clone());
            let rep = run(&mut Sim::new(p), &mut d).unwrap();
            let outs = d.outputs();
            for j in 0..r {
                let mut want = pkt_zero(2);
                for i in 0..k {
                    pkt_add_scaled(&f, &mut want, a[(i, j)], &inputs[i]);
                }
                assert_eq!(outs[&(k + j)], want, "k={k} r={r} p={p} sink {j}");
            }
            assert_eq!(rep.messages, (k * r) as u64);
        }
    }

    #[test]
    fn c2_scales_linearly_in_k() {
        // The strawman moves Θ(K·W) elements per sink — the paper's
        // motivation for in-network coding.
        let f = crate::gf::GfPrime::default_field();
        let (k, r) = (32usize, 4usize);
        let a = Arc::new(Mat::random(&f, k, r, 1));
        let inputs: Vec<Packet> = (0..k as u64).map(|i| vec![i + 1]).collect();
        let mut d = DirectEncode::new(
            f,
            (0..k).collect(),
            (k..k + r).collect(),
            1,
            a,
            inputs,
        );
        let rep = run(&mut Sim::new(1), &mut d).unwrap();
        assert!(rep.c1 >= k as u64); // each sink receives K packets, 1/round
    }
}

//! Cauchy-like all-to-all encode (§VI) — two consecutive draw-and-looses.
//!
//! Theorem 6/8 factor every square block of the systematic-GRS parity
//! matrix as `A_m = Φ^{-1}·V_α^{-1}·V_β·Ψ` with diagonal `Φ` (eq. (26)),
//! `Ψ` (eq. (27)) and *structured* Vandermonde factors. The collective
//! therefore runs
//!
//! ```text
//! scale φ⁻¹  →  draw-and-loose⁻¹ on V_α  →  draw-and-loose on V_β  →  scale ψ
//! ```
//!
//! with both Vandermonde passes on [`StructuredPoints`] designs, giving
//! Theorem 7/9's cost `C = α·2⌈log_{p+1} K⌉ + β⌈log2 q⌉(C2(V_α)+C2(V_β))`
//! — the scales are free (local computation). Lagrange matrices
//! (Remark 9) are the `u = v = 1` special case.

use super::{DrawLoose, LocalOp, Pipeline, StageBuilder};
use crate::codes::StructuredPoints;
use crate::gf::{vandermonde, Field, Mat};
use crate::net::{pkt_scale, Collective, Msg, Outputs, Packet, ProcId};
use std::collections::HashMap;

/// The §VI Cauchy-like A2A: computes `diag(pre)·V_α^{-1}·V_β·diag(post)`.
pub struct CauchyA2A {
    pipe: Pipeline,
}

impl CauchyA2A {
    /// `sp_alpha` / `sp_beta` — structured designs for the two Vandermonde
    /// factors (all points mutually distinct); `pre[s]`, `post[r]` — the
    /// `φ_{m,s}^{-1}` and `ψ_r` diagonals (pass all-ones for Lagrange).
    pub fn new<F: Field>(
        f: F,
        procs: Vec<ProcId>,
        p: usize,
        sp_alpha: &StructuredPoints,
        sp_beta: &StructuredPoints,
        pre: Vec<u64>,
        post: Vec<u64>,
        inputs: Vec<Packet>,
    ) -> anyhow::Result<Self> {
        let k = procs.len();
        anyhow::ensure!(sp_alpha.len() == k && sp_beta.len() == k, "point designs must be K×K");
        anyhow::ensure!(pre.len() == k && post.len() == k && inputs.len() == k);
        let init: Outputs = procs
            .iter()
            .map(|&pid| pid)
            .zip(inputs)
            .collect();
        let rank_of: HashMap<ProcId, usize> =
            procs.iter().enumerate().map(|(i, &pid)| (pid, i)).collect();

        let pre_stage: StageBuilder = {
            let f = f.clone();
            let rank_of = rank_of.clone();
            Box::new(move |prev: &Outputs| {
                Box::new(LocalOp::map(prev, |pid, pkt| {
                    pkt_scale(&f, pre[rank_of[&pid]], pkt)
                })) as Box<dyn Collective>
            })
        };
        let inv_alpha: StageBuilder = {
            let f = f.clone();
            let procs = procs.clone();
            let sp = sp_alpha.clone();
            Box::new(move |prev: &Outputs| {
                let ins: Vec<Packet> = procs.iter().map(|pid| prev[pid].clone()).collect();
                Box::new(
                    DrawLoose::new(f.clone(), procs.clone(), p, &sp, ins, true)
                        .expect("validated design"),
                ) as Box<dyn Collective>
            })
        };
        let fwd_beta: StageBuilder = {
            let f = f.clone();
            let procs = procs.clone();
            let sp = sp_beta.clone();
            Box::new(move |prev: &Outputs| {
                let ins: Vec<Packet> = procs.iter().map(|pid| prev[pid].clone()).collect();
                Box::new(
                    DrawLoose::new(f.clone(), procs.clone(), p, &sp, ins, false)
                        .expect("validated design"),
                ) as Box<dyn Collective>
            })
        };
        let post_stage: StageBuilder = {
            let f = f.clone();
            Box::new(move |prev: &Outputs| {
                Box::new(LocalOp::map(prev, |pid, pkt| {
                    pkt_scale(&f, post[rank_of[&pid]], pkt)
                })) as Box<dyn Collective>
            })
        };

        Ok(CauchyA2A {
            pipe: Pipeline::from_inputs(init, vec![pre_stage, inv_alpha, fwd_beta, post_stage]),
        })
    }

    /// Oracle: `diag(pre)·V_α^{-1}·V_β·diag(post)`.
    pub fn matrix<F: Field>(
        f: &F,
        sp_alpha: &StructuredPoints,
        sp_beta: &StructuredPoints,
        pre: &[u64],
        post: &[u64],
    ) -> Mat {
        let va_inv = vandermonde::inverse(f, &sp_alpha.points);
        let vb = vandermonde::square(f, &sp_beta.points);
        va_inv.diag_mul(f, pre).mul(f, &vb).mul_diag(f, post)
    }
}

impl Collective for CauchyA2A {
    fn participants(&self) -> Vec<ProcId> {
        self.pipe.participants()
    }
    fn is_done(&self) -> bool {
        self.pipe.is_done()
    }
    fn step(&mut self, inbox: Vec<Msg>) -> Vec<Msg> {
        self.pipe.step(inbox)
    }
    fn outputs(&self) -> Outputs {
        self.pipe.outputs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::structured::disjoint_family;
    use crate::gf::GfPrime;
    use crate::net::{pkt_add_scaled, pkt_zero, run, Sim};

    #[test]
    fn computes_cauchy_like_matrix() {
        let f = GfPrime::default_field();
        for (n, p_base, p) in [(8usize, 2u64, 1usize), (16, 2, 1), (12, 2, 2), (9, 3, 2)] {
            let fam = disjoint_family(&f, n, p_base, 2).unwrap();
            let (spa, spb) = (&fam[0], &fam[1]);
            let pre: Vec<u64> = (0..n as u64).map(|i| f.elem(i * 3 + 1)).collect();
            let post: Vec<u64> = (0..n as u64).map(|i| f.elem(i * 5 + 2)).collect();
            let inputs: Vec<Packet> =
                (0..n as u64).map(|i| vec![f.elem(i * 71 + 11)]).collect();
            let mut ca = CauchyA2A::new(
                f,
                (0..n).collect(),
                p,
                spa,
                spb,
                pre.clone(),
                post.clone(),
                inputs.clone(),
            )
            .unwrap();
            let rep = run(&mut Sim::new(p), &mut ca).unwrap();
            let m = CauchyA2A::matrix(&f, spa, spb, &pre, &post);
            let outs = ca.outputs();
            for j in 0..n {
                let mut want = pkt_zero(1);
                for r in 0..n {
                    pkt_add_scaled(&f, &mut want, m[(r, j)], &inputs[r]);
                }
                assert_eq!(outs[&j], want, "n={n} proc {j}");
            }
            // Theorem 7 round count: two draw-and-loose passes.
            assert!(rep.c1 >= 2, "n={n}");
        }
    }

    #[test]
    fn lagrange_special_case() {
        // u = v = 1 (Remark 9): the matrix is V_α^{-1}·V_β exactly.
        let f = GfPrime::default_field();
        let n = 8;
        let fam = disjoint_family(&f, n, 2, 2).unwrap();
        let ones = vec![1u64; n];
        let m = CauchyA2A::matrix(&f, &fam[0], &fam[1], &ones, &ones);
        let direct = vandermonde::inverse(&f, &fam[0].points)
            .mul(&f, &vandermonde::square(&f, &fam[1].points));
        assert_eq!(m, direct);
    }
}

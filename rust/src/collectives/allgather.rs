//! All-gather (Bruck et al. \[26\]) — every processor ends with every
//! initial packet. `C1 = ⌈log_{p+1} N⌉`, `C2 ≈ (N−1)·W/p`.
//!
//! Not used by the paper's own algorithms (that is the point: prepare-and-
//! shoot moves `O(√K)` elements where an all-gather-based scheme moves
//! `O(K)`), but it is the substrate of the multi-reduce baseline of
//! Jeong et al. \[21\] which §II compares against.

use crate::net::{Collective, Msg, Outputs, Packet, PacketBuf, ProcId};
use crate::util::ipow;
use std::collections::HashMap;

/// Bruck all-gather over `procs`; rank `r` contributes `inputs[r]`.
pub struct AllGather {
    procs: Vec<ProcId>,
    p: usize,
    rounds: u32,
    t: u32,
    /// Packet width `W` (all inputs equal-width).
    w: usize,
    /// `have[r][j]` = packet of owner `j` if received by rank `r`.
    have: Vec<Vec<Option<Packet>>>,
    /// Schedule-preserving substitute for owners whose delivery was
    /// dropped under fault injection (`net::run_degraded`): a tainted
    /// rank forwards zeros instead of panicking.
    zero: Packet,
    done: bool,
}

impl AllGather {
    pub fn new(procs: Vec<ProcId>, p: usize, inputs: Vec<Packet>) -> Self {
        assert_eq!(procs.len(), inputs.len());
        let n = procs.len();
        let w = inputs.first().map_or(0, |x| x.len());
        assert!(inputs.iter().all(|x| x.len() == w), "equal-width inputs");
        let rounds = crate::util::ceil_log(p as u64 + 1, n as u64);
        let mut have = vec![vec![None; n]; n];
        for (r, pkt) in inputs.into_iter().enumerate() {
            have[r][r] = Some(pkt);
        }
        AllGather {
            procs,
            p,
            rounds,
            t: 0,
            w,
            have,
            zero: vec![0; w],
            done: n <= 1,
        }
    }

    /// Owners rank `r` is guaranteed to hold at the start of round `t`
    /// (1-indexed): `{r − j mod n : j ∈ [0, (p+1)^{t−1})}`.
    fn held_owners(&self, r: usize, t: u32) -> Vec<usize> {
        let n = self.procs.len();
        let span = ipow(self.p as u64 + 1, t - 1).min(n as u64) as usize;
        (0..span).map(|j| (r + n - j) % n).collect()
    }
}

impl Collective for AllGather {
    fn participants(&self) -> Vec<ProcId> {
        self.procs.clone()
    }

    fn is_done(&self) -> bool {
        self.done
    }

    fn step(&mut self, inbox: Vec<Msg>) -> Vec<Msg> {
        let n = self.procs.len();
        let rank_of: HashMap<ProcId, usize> =
            self.procs.iter().enumerate().map(|(i, &p)| (p, i)).collect();
        // Receivers reconstruct the (deterministic) owner list the sender
        // used, in the same order.
        for m in inbox {
            let dst = rank_of[&m.dst];
            let src = rank_of[&m.src];
            let dst_had = self.held_owners(dst, self.t);
            let src_had = self.held_owners(src, self.t);
            let expected: Vec<usize> = src_had
                .into_iter()
                .filter(|o| !dst_had.contains(o))
                .collect();
            assert_eq!(expected.len(), m.payload.count(), "schedule mismatch");
            for (owner, pkt) in expected.into_iter().zip(m.payload.iter()) {
                // Two ports may collapse to the same distance mod N, in
                // which case the same owner arrives twice; keep the first.
                if self.have[dst][owner].is_none() {
                    self.have[dst][owner] = Some(pkt.to_vec());
                }
            }
        }
        if self.t == self.rounds {
            self.done = true;
            return Vec::new();
        }
        self.t += 1;
        let mut out = Vec::new();
        for r in 0..n {
            let src_had = self.held_owners(r, self.t);
            let mut targets = Vec::new();
            for rho in 1..=self.p as u64 {
                let d = (rho * ipow(self.p as u64 + 1, self.t - 1)) % n as u64;
                if d == 0 {
                    continue;
                }
                let dst = (r + d as usize) % n;
                if !targets.contains(&dst) {
                    targets.push(dst);
                }
            }
            for dst in targets {
                let dst_had = self.held_owners(dst, self.t);
                let payload = PacketBuf::from_slices(
                    self.w,
                    src_had
                        .iter()
                        .filter(|o| !dst_had.contains(o))
                        .map(|&o| self.have[r][o].as_deref().unwrap_or(self.zero.as_slice())),
                );
                if !payload.is_empty() {
                    out.push(Msg::new(self.procs[r], self.procs[dst], payload));
                }
            }
        }
        out
    }

    /// Every processor's output is the concatenation of all `N` packets in
    /// owner-rank order.
    fn outputs(&self) -> Outputs {
        self.procs
            .iter()
            .enumerate()
            .map(|(r, &pid)| {
                let cat: Packet = (0..self.procs.len())
                    .flat_map(|o| {
                        self.have[r][o]
                            .clone()
                            .unwrap_or_else(|| self.zero.clone())
                    })
                    .collect();
                (pid, cat)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{run, Sim};

    #[test]
    fn everyone_gets_everything() {
        for (n, p) in [(8usize, 1usize), (9, 2), (7, 1), (5, 2), (16, 3)] {
            let procs: Vec<ProcId> = (0..n).collect();
            let inputs: Vec<Packet> = (0..n as u64).map(|i| vec![i, i * i]).collect();
            let mut ag = AllGather::new(procs, p, inputs);
            let rep = run(&mut Sim::new(p), &mut ag).unwrap();
            assert_eq!(
                rep.c1,
                crate::util::ceil_log(p as u64 + 1, n as u64) as u64,
                "n={n} p={p}"
            );
            for (_, cat) in ag.outputs() {
                let want: Packet = (0..n as u64).flat_map(|i| vec![i, i * i]).collect();
                assert_eq!(cat, want);
            }
        }
    }

    #[test]
    fn one_port_pow2_c2_is_n_minus_1() {
        // The classic Bruck bound: C2 = (N−1)·W for p = 1, N a power of 2.
        let n = 16usize;
        let procs: Vec<ProcId> = (0..n).collect();
        let inputs: Vec<Packet> = (0..n as u64).map(|i| vec![i]).collect();
        let mut ag = AllGather::new(procs, 1, inputs);
        let rep = run(&mut Sim::new(1), &mut ag).unwrap();
        assert_eq!(rep.c2, (n - 1) as u64);
        assert_eq!(rep.c1, 4);
    }
}

//! Multi-reduce — the Jeong et al. \[21\] baseline the paper compares
//! against in §II.
//!
//! \[21\] builds decentralized MDS encoding from *broadcast* and
//! *all-gather*: every processor gathers all `K` initial packets
//! (Bruck all-gather, `C2 = (K−1)·W` one-port), then locally combines
//! them with its column of the coding matrix. The paper's claim: this
//! costs `(R − 2√R − 1)·β⌈log2 q⌉·W` *more* than prepare-and-shoot —
//! `(K−1)·W` versus `≈ 2√K·W` — which `benches/baselines.rs` reproduces.
//!
//! Restrictions inherited from \[21\]: designed for the one-port model
//! (`p = 1`) and `R | K`; the implementation below nevertheless runs for
//! any `p` via the generalized all-gather.

use super::{AllGather, LocalOp, Pipeline, StageBuilder};
use crate::gf::{Field, Mat};
use crate::net::{pkt_zero, Collective, Msg, Outputs, Packet, ProcId};
use std::sync::Arc;

/// All-gather-then-combine all-to-all encode (the \[21\] baseline).
pub struct MultiReduce {
    pipe: Pipeline,
}

impl MultiReduce {
    /// Same interface as [`PrepareShoot`](super::PrepareShoot): computes
    /// `x·C` for arbitrary square `C`.
    pub fn new<F: Field>(
        f: F,
        procs: Vec<ProcId>,
        p: usize,
        c: Arc<Mat>,
        inputs: Vec<Packet>,
    ) -> Self {
        let k = procs.len();
        assert_eq!(c.rows, k);
        assert_eq!(c.cols, k);
        let w = inputs.first().map_or(0, |x| x.len());
        let gather = AllGather::new(procs.clone(), p, inputs);
        let combine: StageBuilder = {
            let procs = procs.clone();
            Box::new(move |prev: &Outputs| {
                Box::new(LocalOp::map(prev, |pid, cat| {
                    // `cat` = concatenation of all K packets in rank order.
                    let j = procs.iter().position(|&x| x == pid).unwrap();
                    let mut acc = pkt_zero(w);
                    let terms: Vec<(u64, &[u64])> = (0..k)
                        .map(|r| (c[(r, j)], &cat[r * w..(r + 1) * w]))
                        .collect();
                    f.lincomb_into(&mut acc, &terms);
                    acc
                })) as Box<dyn Collective>
            })
        };
        MultiReduce {
            pipe: Pipeline::new(Box::new(gather), vec![combine]),
        }
    }
}

impl Collective for MultiReduce {
    fn participants(&self) -> Vec<ProcId> {
        self.pipe.participants()
    }
    fn is_done(&self) -> bool {
        self.pipe.is_done()
    }
    fn step(&mut self, inbox: Vec<Msg>) -> Vec<Msg> {
        self.pipe.step(inbox)
    }
    fn outputs(&self) -> Outputs {
        self.pipe.outputs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::PrepareShoot;
    use crate::gf::GfPrime;
    use crate::net::{run, Sim};

    #[test]
    fn correct_but_more_expensive_than_prepare_shoot() {
        let f = GfPrime::default_field();
        let k = 64usize;
        let c = Arc::new(Mat::random(&f, k, k, 17));
        let inputs: Vec<Packet> = (0..k as u64).map(|i| vec![f.elem(i * 13 + 1)]).collect();

        let mut mr = MultiReduce::new(f, (0..k).collect(), 1, c.clone(), inputs.clone());
        let rep_mr = run(&mut Sim::new(1), &mut mr).unwrap();

        let mut ps = PrepareShoot::new(f, (0..k).collect(), 1, c.clone(), inputs.clone());
        let rep_ps = run(&mut Sim::new(1), &mut ps).unwrap();

        // Same outputs...
        assert_eq!(mr.outputs(), ps.outputs());
        // ...same optimal round count (both are log-trees)...
        assert_eq!(rep_mr.c1, rep_ps.c1);
        // ...but C2 = K−1 vs ≈ 2√K (the §II gap).
        assert_eq!(rep_mr.c2, (k - 1) as u64);
        assert_eq!(rep_ps.c2, 14); // 2(√64 − 1)/1 = 14
    }

    #[test]
    fn multiport_variant_works() {
        let f = GfPrime::default_field();
        let k = 27usize;
        let c = Arc::new(Mat::random(&f, k, k, 3));
        let inputs: Vec<Packet> = (0..k as u64).map(|i| vec![i, i + 1]).collect();
        let mut mr = MultiReduce::new(f, (0..k).collect(), 2, c.clone(), inputs.clone());
        run(&mut Sim::new(2), &mut mr).unwrap();
        let mut ps = PrepareShoot::new(f, (0..k).collect(), 2, c, inputs);
        run(&mut Sim::new(2), &mut ps).unwrap();
        assert_eq!(mr.outputs(), ps.outputs());
    }
}

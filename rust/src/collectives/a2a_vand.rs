//! **Draw-and-loose** — the specific A2A for Vandermonde matrices (§V-B),
//! and its inverse (Lemma 6).
//!
//! For `K = M·Z` processors with `Z = P^H | q−1` and structured evaluation
//! points `ω_{i,j} = g^{φ(i)}·g^{j′(q−1)/Z}` ([`StructuredPoints`]),
//! processor `i·Z+j` obtains `f(ω_{i,j})`:
//!
//! * **Draw**: `Z` parallel *column* prepare-and-shoots on the `M×M`
//!   Vandermonde `V_M` (eq. (20), points `α_i^Z`), then a free local scale
//!   by `α_i^j` — giving processor `(i,j)` the sub-polynomial evaluation
//!   `f_j(α_i)` (eq. (21)).
//! * **Loose**: `M` parallel *row* DFT A2As on `D_Z·Π` — combining the
//!   `f_ℓ(α_i)` into `x̃_{i,j} = Σ_ℓ β_{j'}^ℓ f_ℓ(α_i)` (eq. (19)).
//!
//! Cost (Theorem 5): `C = (α + β⌈log2 q⌉)·H·C_univ(P) + C_univ(M)`; for
//! `H = 0` the structure buys nothing (Remark 8) and the collective
//! degenerates to a single prepare-and-shoot on the whole matrix.
//!
//! The inverse (Lemma 6) runs loose⁻¹ (inverse DFT per row), unscales, then
//! draw⁻¹ (prepare-and-shoot on `V_M^{-1}` per column).

use super::{DftA2A, LocalOp, Par, Pipeline, PrepareShoot, StageBuilder};
use crate::codes::StructuredPoints;
use crate::gf::{vandermonde, Field, Mat};
use crate::net::{Collective, Msg, Outputs, Packet, ProcId};
use std::collections::HashMap;
use std::sync::Arc;

/// Draw-and-loose for the Vandermonde matrix on a [`StructuredPoints`]
/// design (`invert = true` computes the inverse Vandermonde).
pub struct DrawLoose {
    pipe: Pipeline,
}

impl DrawLoose {
    pub fn new<F: Field>(
        f: F,
        procs: Vec<ProcId>,
        p: usize,
        sp: &StructuredPoints,
        inputs: Vec<Packet>,
        invert: bool,
    ) -> anyhow::Result<Self> {
        let k = procs.len();
        anyhow::ensure!(sp.len() == k, "point design covers {} != {k} procs", sp.len());
        anyhow::ensure!(inputs.len() == k);
        let z = sp.z as usize;
        let m = sp.m;
        let init: Outputs = procs
            .iter()
            .zip(inputs)
            .map(|(&pid, pkt)| (pid, pkt))
            .collect();

        // H = 0 ⇒ no DFT structure: fall back to one universal A2A
        // (Remark 8). The matrix is the (inverse) Vandermonde on points.
        if sp.h == 0 {
            let vm = vandermonde::square(&f, &sp.points);
            let mat = if invert {
                vm.inverse(&f)
                    .ok_or_else(|| anyhow::anyhow!("singular Vandermonde"))?
            } else {
                vm
            };
            let ps = PrepareShoot::from_outputs(f, procs, p, Arc::new(mat), &init);
            return Ok(DrawLoose {
                pipe: Pipeline::new(Box::new(ps), vec![]),
            });
        }

        // Grid: processor (i, j) = procs[i·Z + j]; column j = {(i,j)}_i,
        // row i = {(i,j)}_j.
        let alpha: Vec<u64> = (0..m).map(|i| sp.alpha(&f, i)).collect();
        let alpha_z: Vec<u64> = alpha.iter().map(|&a| f.pow(a, sp.z)).collect();

        let draw: StageBuilder = {
            let f = f.clone();
            let procs = procs.clone();
            let alpha_z = alpha_z.clone();
            Box::new(move |prev: &Outputs| {
                // V_M[r][c] = (α_c^Z)^r — square Vandermonde on α_i^Z.
                let vm = vandermonde::square(&f, &alpha_z);
                let mat = Arc::new(if invert {
                    vandermonde::inverse(&f, &alpha_z)
                } else {
                    vm
                });
                let cols: Vec<Box<dyn Collective>> = (0..z)
                    .map(|j| {
                        let members: Vec<ProcId> = (0..m).map(|i| procs[i * z + j]).collect();
                        Box::new(PrepareShoot::from_outputs(
                            f.clone(),
                            members,
                            p,
                            mat.clone(),
                            prev,
                        )) as Box<dyn Collective>
                    })
                    .collect();
                Box::new(Par::new(cols).expect("disjoint by construction"))
            })
        };

        let scale: StageBuilder = {
            let f = f.clone();
            let procs = procs.clone();
            let alpha = alpha.clone();
            Box::new(move |prev: &Outputs| {
                let rank_of: HashMap<ProcId, usize> =
                    procs.iter().enumerate().map(|(i, &p)| (p, i)).collect();
                Box::new(LocalOp::map(prev, |pid, pkt| {
                    let rank = rank_of[&pid];
                    let (i, j) = (rank / z, rank % z);
                    let s = f.pow(alpha[i], j as u64);
                    let s = if invert { f.inv(s) } else { s };
                    crate::net::pkt_scale(&f, s, pkt)
                }))
            })
        };

        let loose: StageBuilder = {
            let f = f.clone();
            let procs = procs.clone();
            let (p_base, h) = (sp.p_base, sp.h);
            Box::new(move |prev: &Outputs| {
                let rows: Vec<Box<dyn Collective>> = (0..m)
                    .map(|i| {
                        let members: Vec<ProcId> = (0..z).map(|j| procs[i * z + j]).collect();
                        let ins: Vec<Packet> =
                            members.iter().map(|pid| prev[pid].clone()).collect();
                        Box::new(
                            DftA2A::new(f.clone(), members, p, p_base, h, ins, invert)
                                .expect("validated Z | q−1"),
                        ) as Box<dyn Collective>
                    })
                    .collect();
                Box::new(Par::new(rows).expect("disjoint by construction"))
            })
        };

        // Forward: draw → scale → loose. Inverse: loose⁻¹ → scale⁻¹ → draw⁻¹.
        let stages = if invert {
            vec![loose, scale, draw]
        } else {
            vec![draw, scale, loose]
        };
        Ok(DrawLoose {
            pipe: Pipeline::from_inputs(init, stages),
        })
    }

    /// The matrix computed (oracle): the (inverse) square Vandermonde on
    /// `sp.points` in processor-rank order.
    pub fn matrix<F: Field>(f: &F, sp: &StructuredPoints, invert: bool) -> Option<Mat> {
        let v = vandermonde::square(f, &sp.points);
        if invert {
            v.inverse(f)
        } else {
            Some(v)
        }
    }
}

impl Collective for DrawLoose {
    fn participants(&self) -> Vec<ProcId> {
        self.pipe.participants()
    }
    fn is_done(&self) -> bool {
        self.pipe.is_done()
    }
    fn step(&mut self, inbox: Vec<Msg>) -> Vec<Msg> {
        self.pipe.step(inbox)
    }
    fn outputs(&self) -> Outputs {
        self.pipe.outputs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::GfPrime;
    use crate::net::{pkt_add_scaled, pkt_zero, run, Sim};

    fn f() -> GfPrime {
        GfPrime::default_field()
    }

    fn oracle(f: &GfPrime, m: &Mat, inputs: &[Packet]) -> Vec<Packet> {
        (0..m.cols)
            .map(|j| {
                let mut acc = pkt_zero(inputs[0].len());
                for r in 0..m.rows {
                    pkt_add_scaled(f, &mut acc, m[(r, j)], &inputs[r]);
                }
                acc
            })
            .collect()
    }

    fn check(n: usize, p_base: u64, p: usize, invert: bool) -> crate::net::SimReport {
        let f = f();
        let hmax = StructuredPoints::max_h(&f, n as u64, p_base);
        let m = n / crate::util::ipow(p_base, hmax) as usize;
        let sp = StructuredPoints::new(&f, n, p_base, (0..m as u64).collect()).unwrap();
        let inputs: Vec<Packet> = (0..n as u64).map(|i| vec![f.elem(i * 131 + 7)]).collect();
        let mut dl =
            DrawLoose::new(f, (0..n).collect(), p, &sp, inputs.clone(), invert).unwrap();
        let rep = run(&mut Sim::new(p), &mut dl).unwrap();
        let outs = dl.outputs();
        let got: Vec<Packet> = (0..n).map(|i| outs[&i].clone()).collect();
        let mat = DrawLoose::matrix(&f, &sp, invert).unwrap();
        assert_eq!(got, oracle(&f, &mat, &inputs), "n={n} P={p_base} inv={invert}");
        rep
    }

    #[test]
    fn computes_structured_vandermonde() {
        for (n, p_base, p) in [
            (8usize, 2u64, 1usize),
            (16, 2, 1),
            (24, 2, 1),
            (12, 2, 3),
            (9, 3, 2),
            (48, 4, 3),
        ] {
            check(n, p_base, p, false);
        }
    }

    #[test]
    fn computes_inverse_vandermonde() {
        for (n, p_base, p) in [(8usize, 2u64, 1usize), (24, 2, 1), (12, 2, 3)] {
            check(n, p_base, p, true);
        }
    }

    #[test]
    fn h0_falls_back_to_universal() {
        // n = 5 with P = 2: H = 0 (5 is odd) — still correct (Remark 8).
        check(5, 2, 1, false);
        check(5, 2, 1, true);
    }

    #[test]
    fn inverse_cost_equals_forward_cost() {
        // Lemma 6: same C1/C2 both directions.
        let fwd = check(24, 2, 1, false);
        let inv = check(24, 2, 1, true);
        assert_eq!(fwd.c1, inv.c1);
        assert_eq!(fwd.c2, inv.c2);
    }

    #[test]
    fn theorem5_special_case_cost() {
        // K = Z (M = 1): pure DFT — C1 = C2 = H·C_univ(P); with
        // P = p+1 = 2: C1 = H = log2 K exactly (Theorem 5 with M ≤ P²).
        let rep = check(16, 2, 1, false);
        assert_eq!(rep.c1, 4);
        assert_eq!(rep.c2, 4);
    }

    #[test]
    fn beats_universal_c2_when_structured() {
        // The §V headline: for K = 2^H | q−1, draw-and-loose moves
        // O(log K) elements where prepare-and-shoot moves O(√K).
        let f = f();
        let n = 256usize;
        let sp = StructuredPoints::new(&f, n, 2, vec![0]).unwrap();
        let inputs: Vec<Packet> = (0..n as u64).map(|i| vec![f.elem(i + 1)]).collect();
        let mut dl = DrawLoose::new(f, (0..n).collect(), 1, &sp, inputs.clone(), false).unwrap();
        let dl_rep = run(&mut Sim::new(1), &mut dl).unwrap();

        let f = GfPrime::default_field();
        let mat = Arc::new(DrawLoose::matrix(&f, &sp, false).unwrap());
        let mut ps = PrepareShoot::new(f, (0..n).collect(), 1, mat, inputs);
        let ps_rep = run(&mut Sim::new(1), &mut ps).unwrap();
        assert!(
            dl_rep.c2 < ps_rep.c2 / 2,
            "draw-and-loose C2 {} should beat universal C2 {}",
            dl_rep.c2,
            ps_rep.c2
        );
    }
}

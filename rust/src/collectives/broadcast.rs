//! One-to-all broadcast (Definition 2) — the folklore `(p+1)`-nomial tree.
//!
//! In round `t`, every processor that already holds the packet forwards it
//! to `p` more, so after `t` rounds `(p+1)^t` processors are covered:
//! `C1 = ⌈log_{p+1} N⌉`, `C2 = W·⌈log_{p+1} N⌉` (Appendix A's
//! `C_BR(N, W) = (α + β⌈log2 q⌉W)·⌈log_{p+1} N⌉`).
//!
//! A pipelined chain variant for large `W` is provided as
//! [`PipelinedBroadcast`] (Appendix A discusses this family; the chain is
//! the simplest member, with `C1 = m + N − 2` rounds of `W/m`-element
//! messages).

use crate::net::{Collective, Msg, Outputs, Packet, ProcId};
use crate::util::ipow;
use std::collections::HashMap;

/// `(p+1)`-nomial tree broadcast from `procs[0]` to all of `procs`.
pub struct TreeBroadcast {
    procs: Vec<ProcId>,
    rank_of: HashMap<ProcId, usize>,
    p: usize,
    rounds: u32,
    t: u32,
    have: Vec<Option<Packet>>,
    /// Schedule-preserving substitute for a dropped delivery: a tainted
    /// rank of a degraded run (`net::run_degraded`) forwards zeros
    /// instead of panicking — healthy runs never touch it.
    zero: Packet,
    done: bool,
}

impl TreeBroadcast {
    /// `procs[0]` is the root and must hold `data`.
    pub fn new(procs: Vec<ProcId>, p: usize, data: Packet) -> Self {
        assert!(!procs.is_empty());
        let n = procs.len();
        let rounds = crate::util::ceil_log(p as u64 + 1, n as u64);
        let zero = vec![0; data.len()];
        let mut have = vec![None; n];
        have[0] = Some(data);
        TreeBroadcast {
            rank_of: procs.iter().enumerate().map(|(i, &p)| (p, i)).collect(),
            procs,
            p,
            rounds,
            t: 0,
            have,
            zero,
            done: n <= 1,
        }
    }
}

impl Collective for TreeBroadcast {
    fn participants(&self) -> Vec<ProcId> {
        self.procs.clone()
    }

    fn is_done(&self) -> bool {
        self.done
    }

    fn step(&mut self, inbox: Vec<Msg>) -> Vec<Msg> {
        // Deliver: each receiver stores the packet.
        for m in inbox {
            let r = self.rank_of[&m.dst];
            debug_assert!(self.have[r].is_none(), "duplicate delivery");
            self.have[r] = Some(m.payload.into_single());
        }
        if self.t == self.rounds {
            self.done = true;
            return Vec::new();
        }
        self.t += 1;
        let covered = ipow(self.p as u64 + 1, self.t - 1) as usize;
        let next_cover = (covered * (self.p + 1)).min(self.procs.len());
        let mut out = Vec::new();
        for r in 0..covered.min(self.procs.len()) {
            let pkt = self.have[r].as_ref().unwrap_or(&self.zero);
            for rho in 1..=self.p {
                let dst = r + rho * covered;
                if dst < next_cover {
                    out.push(Msg::single(self.procs[r], self.procs[dst], pkt.clone()));
                }
            }
        }
        out
    }

    fn outputs(&self) -> Outputs {
        self.procs
            .iter()
            .zip(&self.have)
            .map(|(&p, h)| (p, h.clone().unwrap_or_else(|| self.zero.clone())))
            .collect()
    }
}

/// Pipelined chain broadcast: the root splits its `W`-element packet into
/// `segments` chunks and streams them down a line; processor `i` forwards
/// chunk `c` in round `c + i + 1`. One port suffices.
pub struct PipelinedBroadcast {
    procs: Vec<ProcId>,
    segments: usize,
    chunks: Vec<Packet>,
    /// chunks received per rank.
    got: Vec<Vec<Packet>>,
    t: u32,
    done: bool,
}

impl PipelinedBroadcast {
    pub fn new(procs: Vec<ProcId>, data: Packet, segments: usize) -> Self {
        assert!(!procs.is_empty());
        let segments = segments.clamp(1, data.len().max(1));
        let w = data.len();
        let base = w / segments;
        let extra = w % segments;
        let mut chunks = Vec::with_capacity(segments);
        let mut off = 0;
        for i in 0..segments {
            let len = base + usize::from(i < extra);
            chunks.push(data[off..off + len].to_vec());
            off += len;
        }
        let n = procs.len();
        PipelinedBroadcast {
            procs,
            segments,
            got: vec![Vec::new(); n],
            chunks,
            t: 0,
            done: n <= 1,
        }
    }

    /// Total rounds: the last chunk leaves the root at round `segments`
    /// and reaches the tail after `N − 1` hops in total.
    pub fn rounds(&self) -> u32 {
        (self.segments + self.procs.len() - 2) as u32
    }
}

impl Collective for PipelinedBroadcast {
    fn participants(&self) -> Vec<ProcId> {
        self.procs.clone()
    }

    fn is_done(&self) -> bool {
        self.done
    }

    fn step(&mut self, inbox: Vec<Msg>) -> Vec<Msg> {
        let rank_of: HashMap<ProcId, usize> =
            self.procs.iter().enumerate().map(|(i, &p)| (p, i)).collect();
        for m in inbox {
            let r = rank_of[&m.dst];
            self.got[r].push(m.payload.into_single());
        }
        if self.t == self.rounds() {
            self.done = true;
            return Vec::new();
        }
        self.t += 1;
        let t = self.t as usize;
        let mut out = Vec::new();
        // In round t, rank i (0-based) forwards chunk c = t − 1 − i to
        // rank i+1, if that chunk exists and rank i already has it.
        for i in 0..self.procs.len() - 1 {
            if t < i + 1 {
                continue;
            }
            let c = t - 1 - i;
            if c >= self.segments {
                continue;
            }
            let chunk = if i == 0 {
                self.chunks[c].clone()
            } else {
                self.got[i][c].clone()
            };
            out.push(Msg::single(self.procs[i], self.procs[i + 1], chunk));
        }
        out
    }

    fn outputs(&self) -> Outputs {
        self.procs
            .iter()
            .enumerate()
            .map(|(i, &p)| {
                let full: Packet = if i == 0 {
                    self.chunks.concat()
                } else {
                    self.got[i].concat()
                };
                (p, full)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{run, Sim};

    #[test]
    fn tree_broadcast_costs_match_appendix_a() {
        for (n, p) in [(9usize, 1usize), (9, 2), (27, 2), (5, 1), (16, 3), (1, 1)] {
            let procs: Vec<ProcId> = (100..100 + n).collect();
            let mut b = TreeBroadcast::new(procs.clone(), p, vec![7, 8, 9]);
            let rep = run(&mut Sim::new(p), &mut b).unwrap();
            let l = crate::util::ceil_log(p as u64 + 1, n as u64) as u64;
            assert_eq!(rep.c1, l, "n={n} p={p}");
            assert_eq!(rep.c2, 3 * l, "n={n} p={p}");
            let outs = b.outputs();
            assert_eq!(outs.len(), n);
            assert!(outs.values().all(|v| *v == vec![7, 8, 9]));
        }
    }

    #[test]
    fn pipelined_chain_fills_everyone() {
        let data: Packet = (0..12).collect();
        let procs: Vec<ProcId> = (0..5).collect();
        let mut b = PipelinedBroadcast::new(procs.clone(), data.clone(), 4);
        let rep = run(&mut Sim::new(1), &mut b).unwrap();
        assert_eq!(rep.c1, (4 + 5 - 2) as u64);
        assert_eq!(rep.per_round_max[0], 3); // W/m elements per round
        for (_, v) in b.outputs() {
            assert_eq!(v, data);
        }
    }

    #[test]
    fn pipelined_beats_tree_for_large_w_small_alpha() {
        // The Appendix-A motivation: for big W the chain amortises α.
        let w = 1024usize;
        let n = 8usize;
        let data: Packet = (0..w as u64).collect();
        let procs: Vec<ProcId> = (0..n).collect();
        let model = crate::net::CostModel::new(1.0, 1.0, 20);

        let mut tree = TreeBroadcast::new(procs.clone(), 1, data.clone());
        let rt = run(&mut Sim::new(1), &mut tree).unwrap();
        let mut chain = PipelinedBroadcast::new(procs.clone(), data, 64);
        let rc = run(&mut Sim::new(1), &mut chain).unwrap();
        assert!(
            rc.cost(&model) < rt.cost(&model),
            "chain {} vs tree {}",
            rc.cost(&model),
            rt.cost(&model)
        );
    }
}

//! The deployable layer: configuration, job execution, verification,
//! metrics, and a threaded batch-encode service.
//!
//! A [`JobConfig`] describes one decentralized-encoding deployment (field,
//! code, K/R/W, ports, cost model, algorithm request); [`job::EncodeJob`]
//! plans it (via [`framework::plan`](crate::framework::plan)), executes it
//! on the round engine, verifies the coded output against an oracle
//! (native matrix math or the PJRT artifact), and emits a
//! [`job::JobReport`] with the paper's cost metrics.
//!
//! [`service::EncodeService`] is the long-running form: worker threads
//! consume encode requests from a queue and run the bulk-encode hot path
//! through the AOT-compiled kernel (`runtime::GfEncoder`) or — the
//! artifact-free replay engine — through the shape's cached optimized
//! plan, micro-batching queued requests into one columnar
//! `replay_batch` pass per width (`service::BatchPolicy`). The
//! "request path never touches Python" property in action.

pub mod config;
pub mod job;
pub mod metrics;
pub mod plan_cache;
pub mod service;
pub mod verify;

pub use config::JobConfig;
pub use job::{DegradedJobReport, EncodeJob, JobReport, RecoveryStats};
pub use metrics::Metrics;
pub use plan_cache::{PlanCache, PlanKey};
pub use service::{BatchPolicy, EncodeRequest, EncodeResponse, EncodeService};

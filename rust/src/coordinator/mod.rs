//! The deployable layer: configuration, job execution, verification,
//! metrics, and a threaded batch-encode service.
//!
//! A [`JobConfig`] describes one decentralized-encoding deployment (field,
//! code, K/R/W, ports, cost model, algorithm request); [`job::EncodeJob`]
//! plans it (via [`framework::plan`](crate::framework::plan)), executes it
//! on the round engine, verifies the coded output against an oracle
//! (native matrix math or the PJRT artifact), and emits a
//! [`job::JobReport`] with the paper's cost metrics.
//!
//! [`service::EncodeService`] is the long-running form: an
//! event-driven dispatcher (per-width queues, condvar wakeups, no
//! polling) feeds worker threads that run the bulk-encode hot path
//! through the AOT-compiled kernel (`runtime::GfEncoder`) or — the
//! artifact-free replay engine — through the shape's cached optimized
//! plan, micro-batching queued requests into one columnar
//! `replay_batch` pass per width under a deadline/occupancy
//! [`service::BatchPolicy`], with per-tenant admission control
//! ([`service::ServeRejection`]) and drain-and-respond shutdown. The
//! "request path never touches Python" property in action.
//!
//! [`server::WireServer`] puts that dispatcher on a TCP socket: framed
//! requests packed at the field's symbol lane, multi-tenant admission,
//! out-of-order pipelined responses (see `net::payload`'s frame codec).

pub mod config;
pub mod job;
pub mod metrics;
pub mod plan_cache;
pub mod server;
pub mod service;
pub mod verify;

pub use config::{JobConfig, ServeOptions};
pub use job::{
    DegradedInfo, DegradedJobReport, EncodeJob, EncodeOutcome, Engine, ExecOptions, JobReport,
    RecoveryStats,
};
pub use metrics::Metrics;
pub use plan_cache::{PlanCache, PlanKey};
pub use server::{wire_layout, WireClient, WireServer};
pub use service::{
    BatchPolicy, EncodeRequest, EncodeResponse, EncodeService, ServeRejection,
};

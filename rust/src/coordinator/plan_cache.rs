//! The shape-keyed plan cache: compile an encoding schedule once, replay
//! it for every subsequent same-shape request.
//!
//! Each cached [`CompiledPlan`](crate::framework::CompiledPlan) carries
//! **both** forms of the schedule: the raw Plan IR (wire-level replay,
//! tracing, inspection) and its optimizer-pass lowering
//! ([`OptimizedPlan`](crate::net::opt::OptimizedPlan) — the flattened
//! `OutputMatrix` the serving and micro-batching paths execute). One
//! miss pays for compile + optimize; every hit serves either form.
//!
//! A [`PlanKey`] identifies everything the compiled
//! [`CompiledPlan`](crate::framework::CompiledPlan) depends on: the field,
//! the `(K, R)` shape, the port budget, the code family + seed, a
//! [`parity_fingerprint`] of the matrix itself (the config *usually*
//! determines the matrix, but the plan's coefficients depend on the
//! entries — the fingerprint enforces it), and the *resolved* algorithm
//! choice (`Auto` resolves differently per width, so the key carries the
//! outcome, not the request). Deliberately absent: the payload width `W` —
//! plans are width-independent (Remark 2), so one compiled plan serves
//! every `W` of the same shape. That is the cache's big win: a service
//! seeing mixed-width traffic on one code shape compiles exactly once.
//!
//! # Concurrency
//!
//! The map is **sharded**: a key hashes to one of `shards` (a power of
//! two) independently locked sub-maps, so concurrent lookups of
//! different shapes never contend on one global lock. Each shard bounds
//! its population to `⌈capacity / shards⌉` entries with **LRU
//! eviction** (a monotone per-shard tick stamps every touch; eviction
//! removes the smallest stamp and bumps `plan_cache_evictions`).
//!
//! Misses are **single-flight**: the first thread to miss a key
//! registers an in-flight marker and compiles *outside* the shard lock;
//! concurrent requests for the same key wait on that compile
//! (`plan_cache_single_flight_waits`) and then read the inserted entry,
//! instead of burning cores on redundant compiles of an identical plan.
//! A failed compile wakes the waiters and leaves nothing cached — the
//! next caller (possibly a just-woken waiter) retries, preserving the
//! "failed compile is not cached" contract.
//!
//! Hit/miss/eviction/wait/contention counters land on the attached
//! [`Metrics`](super::metrics::Metrics) registry (`plan_cache_hits`,
//! `plan_cache_misses`, `plan_cache_evictions`,
//! `plan_cache_single_flight_waits`, `plan_cache_shard_contention`), so
//! they appear in the service metrics summary.

use super::metrics::{self, Metrics};
use crate::framework::{CompiledPlan, PlanChoice};
use anyhow::Result;
use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, TryLockError};

/// Everything a compiled plan's bits depend on (see module docs).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// Field spec string (e.g. `"prime:786433"`, `"gf2e:8"`).
    pub field: String,
    pub k: usize,
    pub r: usize,
    pub ports: usize,
    /// Code family — with `seed`, determines the parity matrix.
    pub code: super::config::CodeKind,
    /// Seed for code/matrix construction (`CodeKind::Random` derives the
    /// matrix from it; structured codes ignore it but keying on it is
    /// harmlessly conservative).
    pub seed: u64,
    /// [`parity_fingerprint`] of the matrix actually compiled against —
    /// the plan's coefficients are functions of the matrix entries, so
    /// the key must pin them, not just the config that *usually*
    /// determines them.
    pub parity_fp: u64,
    /// The *resolved* algorithm (post-`Auto`).
    pub choice: PlanChoice,
    /// The job's explicit kernel ISA request, if any (`None` = process
    /// default). Keyed on the *request*, not the resolved tier: two
    /// configs asking for different tiers must not share a plan object,
    /// since the tier is baked into the compiled plan's kernel vtable.
    pub isa: Option<crate::gf::IsaRequest>,
}

/// Positional FNV-1a fingerprint of a parity matrix (shape + every
/// entry). Not cryptographic — it guards against accidental key
/// collisions (a job whose matrix diverged from its config), not
/// adversarial ones.
pub fn parity_fingerprint(a: &crate::gf::Mat) -> u64 {
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    h = (h ^ a.rows as u64).wrapping_mul(PRIME);
    h = (h ^ a.cols as u64).wrapping_mul(PRIME);
    for i in 0..a.rows {
        for &v in a.row(i) {
            h = (h ^ v).wrapping_mul(PRIME);
        }
    }
    h
}

/// Default total capacity (compiled plans across all shards).
pub const DEFAULT_CAPACITY: usize = 256;
/// Default shard count (rounded up to a power of two).
pub const DEFAULT_SHARDS: usize = 16;

/// One in-flight compile: waiters block on the condvar until the
/// leader flips `done`.
struct Flight {
    done: Mutex<bool>,
    cv: Condvar,
}

impl Flight {
    fn new() -> Self {
        Flight {
            done: Mutex::new(false),
            cv: Condvar::new(),
        }
    }

    fn finish(&self) {
        *self.done.lock().unwrap() = true;
        self.cv.notify_all();
    }

    fn wait(&self) {
        let mut g = self.done.lock().unwrap();
        while !*g {
            g = self.cv.wait(g).unwrap();
        }
    }
}

struct Entry {
    plan: Arc<CompiledPlan>,
    last_used: u64,
}

#[derive(Default)]
struct Shard {
    map: HashMap<PlanKey, Entry>,
    inflight: HashMap<PlanKey, Arc<Flight>>,
    tick: u64,
}

/// A sharded, capacity-bounded (LRU), single-flight shape →
/// compiled-plan map with hit/miss accounting. See module docs.
pub struct PlanCache {
    shards: Vec<Mutex<Shard>>,
    per_shard_cap: usize,
    metrics: Arc<Metrics>,
}

impl PlanCache {
    pub fn new() -> Self {
        Self::with_metrics(Arc::new(Metrics::new()))
    }

    /// Share a metrics registry (e.g. the service's) so cache counters
    /// land in the same summary. Default capacity and shard count.
    pub fn with_metrics(metrics: Arc<Metrics>) -> Self {
        Self::with_config(DEFAULT_CAPACITY, DEFAULT_SHARDS, metrics)
    }

    /// Full-control constructor: `capacity` total compiled plans
    /// (divided evenly over the shards — each shard holds at most
    /// `⌈capacity / shards⌉`, so a skewed key distribution may evict
    /// before the global total is reached) across `shards` sub-maps
    /// (rounded up to a power of two, at least 1).
    pub fn with_config(capacity: usize, shards: usize, metrics: Arc<Metrics>) -> Self {
        let n = shards.max(1).next_power_of_two();
        let per_shard_cap = capacity.max(1).div_ceil(n).max(1);
        PlanCache {
            shards: (0..n).map(|_| Mutex::new(Shard::default())).collect(),
            per_shard_cap,
            metrics,
        }
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Number of shards (always a power of two).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Total capacity bound (per-shard quota × shards).
    pub fn capacity(&self) -> usize {
        self.per_shard_cap * self.shards.len()
    }

    fn shard_index(&self, key: &PlanKey) -> usize {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        (h.finish() as usize) & (self.shards.len() - 1)
    }

    /// Lock one shard, counting the times the lock was already held
    /// (`plan_cache_shard_contention`) — the signal that the shard
    /// count is too low for the offered concurrency.
    fn lock_shard(&self, idx: usize) -> MutexGuard<'_, Shard> {
        match self.shards[idx].try_lock() {
            Ok(g) => g,
            Err(TryLockError::WouldBlock) => {
                self.metrics.incr(metrics::PLAN_CACHE_CONTENTION, 1);
                self.shards[idx].lock().unwrap()
            }
            Err(TryLockError::Poisoned(e)) => panic!("poisoned plan-cache shard: {e}"),
        }
    }

    /// Fetch the plan for `key`, compiling it with `compile` on a miss.
    /// Concurrent misses on the same key are single-flight: one caller
    /// compiles, the rest wait and share the inserted plan object. A
    /// failed compile is not cached; its waiters retry (the first
    /// becomes the new leader).
    pub fn get_or_compile(
        &self,
        key: &PlanKey,
        compile: impl FnOnce() -> Result<CompiledPlan>,
    ) -> Result<Arc<CompiledPlan>> {
        let idx = self.shard_index(key);
        let mut compile = Some(compile);
        loop {
            let flight = {
                let mut shard = self.lock_shard(idx);
                shard.tick += 1;
                let tick = shard.tick;
                if let Some(entry) = shard.map.get_mut(key) {
                    entry.last_used = tick;
                    let plan = entry.plan.clone();
                    drop(shard);
                    self.metrics.plan_cache_hit();
                    return Ok(plan);
                }
                match shard.inflight.get(key) {
                    Some(f) => {
                        let f = f.clone();
                        drop(shard);
                        self.metrics.incr(metrics::PLAN_CACHE_WAITS, 1);
                        f
                    }
                    None => {
                        // This caller leads the compile for everyone.
                        let f = Arc::new(Flight::new());
                        shard.inflight.insert(key.clone(), f.clone());
                        drop(shard);
                        self.metrics.plan_cache_miss();
                        let outcome = (compile.take().expect("one compile per caller"))();
                        return self.finish_flight(idx, key, f, outcome);
                    }
                }
            };
            flight.wait();
            // Re-lookup: normally a hit on the leader's insert; if the
            // leader's compile failed, this caller becomes the leader.
        }
    }

    /// Leader epilogue: publish the compiled plan (or nothing, on
    /// failure), retire the in-flight marker, wake the waiters.
    fn finish_flight(
        &self,
        idx: usize,
        key: &PlanKey,
        flight: Arc<Flight>,
        outcome: Result<CompiledPlan>,
    ) -> Result<Arc<CompiledPlan>> {
        let published = match outcome {
            Ok(plan) => {
                let fresh = Arc::new(plan);
                let tier = format!(
                    "{}{}",
                    metrics::PLANS_COMPILED_ISA_PREFIX,
                    fresh.kernels.isa().name()
                );
                self.metrics.incr(&tier, 1);
                Ok(fresh)
            }
            Err(e) => Err(e),
        };
        let mut shard = self.lock_shard(idx);
        shard.inflight.remove(key);
        if let Ok(fresh) = &published {
            shard.tick += 1;
            let tick = shard.tick;
            shard.map.insert(
                key.clone(),
                Entry {
                    plan: fresh.clone(),
                    last_used: tick,
                },
            );
            while shard.map.len() > self.per_shard_cap {
                // O(n) min-scan: plan populations are tiny (hundreds at
                // most), so a scan beats maintaining an intrusive list.
                let lru = shard
                    .map
                    .iter()
                    .min_by_key(|(_, e)| e.last_used)
                    .map(|(k, _)| k.clone())
                    .expect("non-empty over-capacity shard");
                shard.map.remove(&lru);
                self.metrics.incr(metrics::PLAN_CACHE_EVICTIONS, 1);
            }
        }
        drop(shard);
        flight.finish();
        published
    }

    /// Whether `key` currently holds a compiled plan (no LRU touch, no
    /// hit/miss accounting).
    pub fn contains(&self, key: &PlanKey) -> bool {
        self.lock_shard(self.shard_index(key)).map.contains_key(key)
    }

    /// Pre-compile the plan for every config **before** traffic
    /// arrives, so the first real request of each shape is a cache hit
    /// instead of paying a compile. Returns the number of plans
    /// compiled fresh (duplicate shapes in `cfgs`, and shapes already
    /// cached, cost nothing).
    pub fn warmup(&self, cfgs: &[super::JobConfig]) -> Result<usize> {
        let mut fresh = 0;
        for cfg in cfgs {
            let job = super::EncodeJob::synthetic(cfg.clone())?;
            if job.warm(self)? {
                fresh += 1;
            }
        }
        Ok(fresh)
    }

    /// Number of distinct compiled shapes held.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// `(hits, misses)` recorded so far.
    pub fn stats(&self) -> (u64, u64) {
        self.metrics.plan_cache()
    }
}

impl Default for PlanCache {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::CodeKind;
    use crate::coordinator::JobConfig;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Barrier;

    fn key(k: usize) -> PlanKey {
        PlanKey {
            field: "prime:786433".into(),
            k,
            r: 4,
            ports: 1,
            code: CodeKind::RsStructured,
            seed: 42,
            parity_fp: 7,
            choice: PlanChoice::Universal,
            isa: None,
        }
    }

    #[test]
    fn parity_fingerprint_pins_matrix_content() {
        let f = crate::gf::GfPrime::default_field();
        let a = crate::gf::Mat::random(&f, 6, 3, 1);
        let b = crate::gf::Mat::random(&f, 6, 3, 2);
        assert_eq!(parity_fingerprint(&a), parity_fingerprint(&a.clone()));
        assert_ne!(parity_fingerprint(&a), parity_fingerprint(&b));
        // Shape is part of the fingerprint, not just entries.
        let t = a.transpose();
        assert_ne!(parity_fingerprint(&a), parity_fingerprint(&t));
    }

    fn dummy_plan(k: usize) -> CompiledPlan {
        let f = crate::gf::GfPrime::default_field();
        let a = std::sync::Arc::new(crate::gf::Mat::random(&f, k, 4, 1));
        crate::framework::compile_plan(
            &f,
            None,
            Some(a),
            1,
            1,
            crate::framework::AlgoRequest::Universal,
            None,
        )
        .unwrap()
    }

    #[test]
    fn caches_by_key_and_counts_hits() {
        let cache = PlanCache::new();
        let mut compiles = 0;
        for _ in 0..3 {
            cache
                .get_or_compile(&key(8), || {
                    compiles += 1;
                    Ok(dummy_plan(8))
                })
                .unwrap();
        }
        cache
            .get_or_compile(&key(12), || {
                compiles += 1;
                Ok(dummy_plan(12))
            })
            .unwrap();
        assert_eq!(compiles, 2);
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats(), (2, 2)); // 2 hits on the k=8 key
        // Both fresh compiles bumped the resolved-tier counter; hits
        // did not.
        let plan = cache.get_or_compile(&key(8), || unreachable!()).unwrap();
        let counter = format!(
            "{}{}",
            crate::coordinator::metrics::PLANS_COMPILED_ISA_PREFIX,
            plan.kernels.isa().name()
        );
        assert_eq!(cache.metrics().counter(&counter), 2);
    }

    #[test]
    fn failed_compile_is_not_cached() {
        let cache = PlanCache::new();
        let err = cache.get_or_compile(&key(8), || anyhow::bail!("boom"));
        assert!(err.is_err());
        assert!(cache.is_empty());
        // A later successful compile goes through.
        cache.get_or_compile(&key(8), || Ok(dummy_plan(8))).unwrap();
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.stats(), (0, 2));
    }

    #[test]
    fn lru_evicts_the_least_recently_used_shape() {
        // One shard, capacity 2 — eviction order is deterministic.
        let cache = PlanCache::with_config(2, 1, Arc::new(Metrics::new()));
        assert_eq!(cache.shard_count(), 1);
        assert_eq!(cache.capacity(), 2);
        cache.get_or_compile(&key(8), || Ok(dummy_plan(8))).unwrap();
        cache.get_or_compile(&key(12), || Ok(dummy_plan(12))).unwrap();
        // Touch k=8 so k=12 becomes the LRU entry.
        cache.get_or_compile(&key(8), || unreachable!()).unwrap();
        cache.get_or_compile(&key(16), || Ok(dummy_plan(16))).unwrap();
        assert_eq!(cache.len(), 2);
        assert_eq!(
            cache.metrics().counter(metrics::PLAN_CACHE_EVICTIONS),
            1
        );
        // k=8 survived (recently used) …
        assert!(cache.contains(&key(8)));
        // … and k=12 was evicted: asking again recompiles.
        let recompiled = AtomicUsize::new(0);
        cache
            .get_or_compile(&key(12), || {
                recompiled.fetch_add(1, Ordering::Relaxed);
                Ok(dummy_plan(12))
            })
            .unwrap();
        assert_eq!(recompiled.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn single_flight_compiles_once_under_concurrency() {
        let cache = PlanCache::new();
        let compiles = AtomicUsize::new(0);
        let n = 8;
        let barrier = Barrier::new(n);
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for _ in 0..n {
                handles.push(s.spawn(|| {
                    barrier.wait();
                    cache
                        .get_or_compile(&key(8), || {
                            compiles.fetch_add(1, Ordering::Relaxed);
                            // Hold the flight open long enough for the
                            // other threads to arrive and park on it.
                            std::thread::sleep(std::time::Duration::from_millis(30));
                            Ok(dummy_plan(8))
                        })
                        .unwrap()
                }));
            }
            let plans: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
            // Everyone shares the single compiled object.
            for p in &plans[1..] {
                assert!(Arc::ptr_eq(&plans[0], p));
            }
        });
        assert_eq!(compiles.load(Ordering::Relaxed), 1, "single-flight");
        let (hits, misses) = cache.stats();
        assert_eq!(misses, 1);
        assert_eq!(hits, (n - 1) as u64, "waiters resolve to hits");
        assert!(cache.metrics().counter(metrics::PLAN_CACHE_WAITS) >= 1);
    }

    #[test]
    fn failed_leader_hands_the_flight_to_a_waiter() {
        let cache = PlanCache::new();
        let barrier = Barrier::new(2);
        std::thread::scope(|s| {
            let leader = s.spawn(|| {
                cache.get_or_compile(&key(8), || {
                    barrier.wait(); // waiter is about to call in
                    std::thread::sleep(std::time::Duration::from_millis(20));
                    anyhow::bail!("leader compile failed")
                })
            });
            let waiter = s.spawn(|| {
                barrier.wait();
                // Lands while the leader's flight is (very likely) still
                // open; either way the retry loop must end with a plan.
                cache.get_or_compile(&key(8), || Ok(dummy_plan(8)))
            });
            assert!(leader.join().unwrap().is_err(), "leader sees its own failure");
            assert!(waiter.join().unwrap().is_ok(), "waiter recovers the flight");
        });
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn warmup_precompiles_each_distinct_shape_once() {
        let cache = PlanCache::new();
        let a = JobConfig {
            k: 8,
            r: 4,
            ..JobConfig::default()
        };
        let b = JobConfig {
            k: 6,
            r: 3,
            ..JobConfig::default()
        };
        // Duplicate shapes cost nothing.
        let fresh = cache.warmup(&[a.clone(), b.clone(), a.clone()]).unwrap();
        assert_eq!(fresh, 2);
        assert_eq!(cache.len(), 2);
        // A warmed cache serves the shape without recompiling.
        let job = crate::coordinator::EncodeJob::synthetic(a).unwrap();
        let (_, misses_before) = cache.stats();
        job.compiled(&cache).unwrap();
        let (_, misses_after) = cache.stats();
        assert_eq!(misses_before, misses_after, "warmed shape is a hit");
        // Warming again is a no-op.
        assert_eq!(cache.warmup(&[b]).unwrap(), 0);
    }
}

//! The shape-keyed plan cache: compile an encoding schedule once, replay
//! it for every subsequent same-shape request.
//!
//! Each cached [`CompiledPlan`](crate::framework::CompiledPlan) carries
//! **both** forms of the schedule: the raw Plan IR (wire-level replay,
//! tracing, inspection) and its optimizer-pass lowering
//! ([`OptimizedPlan`](crate::net::opt::OptimizedPlan) — the flattened
//! `OutputMatrix` the serving and micro-batching paths execute). One
//! miss pays for compile + optimize; every hit serves either form.
//!
//! A [`PlanKey`] identifies everything the compiled
//! [`CompiledPlan`](crate::framework::CompiledPlan) depends on: the field,
//! the `(K, R)` shape, the port budget, the code family + seed, a
//! [`parity_fingerprint`] of the matrix itself (the config *usually*
//! determines the matrix, but the plan's coefficients depend on the
//! entries — the fingerprint enforces it), and the *resolved* algorithm
//! choice (`Auto` resolves differently per width, so the key carries the
//! outcome, not the request). Deliberately absent: the payload width `W` —
//! plans are width-independent (Remark 2), so one compiled plan serves
//! every `W` of the same shape. That is the cache's big win: a service
//! seeing mixed-width traffic on one code shape compiles exactly once.
//!
//! Hit/miss counters are recorded on the attached
//! [`Metrics`](super::metrics::Metrics) registry (`plan_cache_hits` /
//! `plan_cache_misses`), so they appear in the service metrics summary.

use super::metrics::Metrics;
use crate::framework::{CompiledPlan, PlanChoice};
use anyhow::Result;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Everything a compiled plan's bits depend on (see module docs).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// Field spec string (e.g. `"prime:786433"`, `"gf2e:8"`).
    pub field: String,
    pub k: usize,
    pub r: usize,
    pub ports: usize,
    /// Code family — with `seed`, determines the parity matrix.
    pub code: super::config::CodeKind,
    /// Seed for code/matrix construction (`CodeKind::Random` derives the
    /// matrix from it; structured codes ignore it but keying on it is
    /// harmlessly conservative).
    pub seed: u64,
    /// [`parity_fingerprint`] of the matrix actually compiled against —
    /// the plan's coefficients are functions of the matrix entries, so
    /// the key must pin them, not just the config that *usually*
    /// determines them.
    pub parity_fp: u64,
    /// The *resolved* algorithm (post-`Auto`).
    pub choice: PlanChoice,
    /// The job's explicit kernel ISA request, if any (`None` = process
    /// default). Keyed on the *request*, not the resolved tier: two
    /// configs asking for different tiers must not share a plan object,
    /// since the tier is baked into the compiled plan's kernel vtable.
    pub isa: Option<crate::gf::IsaRequest>,
}

/// Positional FNV-1a fingerprint of a parity matrix (shape + every
/// entry). Not cryptographic — it guards against accidental key
/// collisions (a job whose matrix diverged from its config), not
/// adversarial ones.
pub fn parity_fingerprint(a: &crate::gf::Mat) -> u64 {
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    h = (h ^ a.rows as u64).wrapping_mul(PRIME);
    h = (h ^ a.cols as u64).wrapping_mul(PRIME);
    for i in 0..a.rows {
        for &v in a.row(i) {
            h = (h ^ v).wrapping_mul(PRIME);
        }
    }
    h
}

/// A concurrent shape → compiled-plan map with hit/miss accounting.
pub struct PlanCache {
    inner: Mutex<HashMap<PlanKey, Arc<CompiledPlan>>>,
    metrics: Arc<Metrics>,
}

impl PlanCache {
    pub fn new() -> Self {
        Self::with_metrics(Arc::new(Metrics::new()))
    }

    /// Share a metrics registry (e.g. the service's) so cache counters
    /// land in the same summary.
    pub fn with_metrics(metrics: Arc<Metrics>) -> Self {
        PlanCache {
            inner: Mutex::new(HashMap::new()),
            metrics,
        }
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Fetch the plan for `key`, compiling it with `compile` on a miss.
    /// Concurrent misses may compile redundantly; the first insert wins
    /// so every caller replays the same plan object.
    pub fn get_or_compile(
        &self,
        key: &PlanKey,
        compile: impl FnOnce() -> Result<CompiledPlan>,
    ) -> Result<Arc<CompiledPlan>> {
        if let Some(hit) = self.inner.lock().unwrap().get(key).cloned() {
            self.metrics.plan_cache_hit();
            return Ok(hit);
        }
        self.metrics.plan_cache_miss();
        let fresh = Arc::new(compile()?);
        let tier = format!(
            "{}{}",
            super::metrics::PLANS_COMPILED_ISA_PREFIX,
            fresh.kernels.isa().name()
        );
        self.metrics.incr(&tier, 1);
        let mut guard = self.inner.lock().unwrap();
        let entry = guard.entry(key.clone()).or_insert(fresh);
        Ok(entry.clone())
    }

    /// Number of distinct compiled shapes held.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// `(hits, misses)` recorded so far.
    pub fn stats(&self) -> (u64, u64) {
        self.metrics.plan_cache()
    }
}

impl Default for PlanCache {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::CodeKind;

    fn key(k: usize) -> PlanKey {
        PlanKey {
            field: "prime:786433".into(),
            k,
            r: 4,
            ports: 1,
            code: CodeKind::RsStructured,
            seed: 42,
            parity_fp: 7,
            choice: PlanChoice::Universal,
            isa: None,
        }
    }

    #[test]
    fn parity_fingerprint_pins_matrix_content() {
        let f = crate::gf::GfPrime::default_field();
        let a = crate::gf::Mat::random(&f, 6, 3, 1);
        let b = crate::gf::Mat::random(&f, 6, 3, 2);
        assert_eq!(parity_fingerprint(&a), parity_fingerprint(&a.clone()));
        assert_ne!(parity_fingerprint(&a), parity_fingerprint(&b));
        // Shape is part of the fingerprint, not just entries.
        let t = a.transpose();
        assert_ne!(parity_fingerprint(&a), parity_fingerprint(&t));
    }

    fn dummy_plan(k: usize) -> CompiledPlan {
        let f = crate::gf::GfPrime::default_field();
        let a = std::sync::Arc::new(crate::gf::Mat::random(&f, k, 4, 1));
        crate::framework::compile_plan(
            &f,
            None,
            Some(a),
            1,
            1,
            crate::framework::AlgoRequest::Universal,
            None,
        )
        .unwrap()
    }

    #[test]
    fn caches_by_key_and_counts_hits() {
        let cache = PlanCache::new();
        let mut compiles = 0;
        for _ in 0..3 {
            cache
                .get_or_compile(&key(8), || {
                    compiles += 1;
                    Ok(dummy_plan(8))
                })
                .unwrap();
        }
        cache
            .get_or_compile(&key(12), || {
                compiles += 1;
                Ok(dummy_plan(12))
            })
            .unwrap();
        assert_eq!(compiles, 2);
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats(), (2, 2)); // 2 hits on the k=8 key
        // Both fresh compiles bumped the resolved-tier counter; hits
        // did not.
        let plan = cache.get_or_compile(&key(8), || unreachable!()).unwrap();
        let counter = format!(
            "{}{}",
            crate::coordinator::metrics::PLANS_COMPILED_ISA_PREFIX,
            plan.kernels.isa().name()
        );
        assert_eq!(cache.metrics().counter(&counter), 2);
    }

    #[test]
    fn failed_compile_is_not_cached() {
        let cache = PlanCache::new();
        let err = cache.get_or_compile(&key(8), || anyhow::bail!("boom"));
        assert!(err.is_err());
        assert!(cache.is_empty());
        // A later successful compile goes through.
        cache.get_or_compile(&key(8), || Ok(dummy_plan(8))).unwrap();
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.stats(), (0, 2));
    }
}

//! One decentralized-encoding job: plan → simulate → verify → report.

use super::config::{CodeKind, JobConfig, VerifyMode};
use super::verify;
use crate::codes::GrsCode;
use crate::framework::{systematic::Layout, Plan, PlanChoice};
use crate::gf::{AnyField, Field, Mat};
use crate::net::{run, Packet, Sim, SimReport};
use crate::util::Rng;
use std::sync::Arc;
use std::time::Instant;

/// The outcome of one job, with every paper metric.
#[derive(Clone, Debug)]
pub struct JobReport {
    pub choice: PlanChoice,
    pub layout: Layout,
    pub sim: SimReport,
    /// `C = α·C1 + β⌈log2 q⌉·C2`.
    pub cost: f64,
    pub verified: Option<bool>,
    pub wall: std::time::Duration,
}

impl JobReport {
    pub fn to_json(&self) -> String {
        format!(
            concat!(
                "{{\"algorithm\":\"{}\",\"k\":{},\"r\":{},\"c1\":{},\"c2\":{},",
                "\"messages\":{},\"bandwidth\":{},\"cost\":{},\"verified\":{},",
                "\"wall_us\":{}}}"
            ),
            self.choice,
            self.layout.k,
            self.layout.r,
            self.sim.c1,
            self.sim.c2,
            self.sim.messages,
            self.sim.bandwidth,
            self.cost,
            self.verified.map_or("null".into(), |v| v.to_string()),
            self.wall.as_micros(),
        )
    }
}

impl std::fmt::Display for JobReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "algorithm: {:<12} K={} R={}",
            self.choice.to_string(),
            self.layout.k,
            self.layout.r
        )?;
        writeln!(
            f,
            "C1 = {} rounds, C2 = {} elems (messages {}, bandwidth {} elems)",
            self.sim.c1, self.sim.c2, self.sim.messages, self.sim.bandwidth
        )?;
        writeln!(f, "C  = {:.3} (model cost)", self.cost)?;
        match self.verified {
            Some(true) => writeln!(f, "verification: OK")?,
            Some(false) => writeln!(f, "verification: FAILED")?,
            None => writeln!(f, "verification: skipped")?,
        }
        write!(f, "wall: {:?}", self.wall)
    }
}

/// A planned job with its data, ready to execute.
pub struct EncodeJob {
    pub config: JobConfig,
    pub field: AnyField,
    pub code: Option<GrsCode>,
    pub parity: Arc<Mat>,
    pub inputs: Vec<Packet>,
}

impl EncodeJob {
    /// Build a job with synthetic (seeded) payload data.
    pub fn synthetic(config: JobConfig) -> anyhow::Result<Self> {
        let field = config.any_field()?;
        let (k, r) = (config.k, config.r);
        let code = match config.code {
            CodeKind::RsStructured => Some(build_structured(&field, k, r)?),
            CodeKind::RsPlain => Some(GrsCode::plain(
                &field,
                (1..=k as u64).collect(),
                (k as u64 + 1..=(k + r) as u64).collect(),
            )?),
            CodeKind::Lagrange => {
                // Systematic Lagrange = GRS with u/v from the Lagrange
                // normalisation (u = v = 1 — Remark 9).
                Some(GrsCode::plain(
                    &field,
                    (1..=k as u64).collect(),
                    (k as u64 + 1..=(k + r) as u64).collect(),
                )?)
            }
            CodeKind::Random => None,
        };
        let parity: Arc<Mat> = match &code {
            Some(c) => Arc::new(c.parity_matrix(&field)),
            None => Arc::new(Mat::random(&field, k, r, config.seed ^ 0xA5A5)),
        };
        let mut rng = Rng::new(config.seed);
        let inputs: Vec<Packet> = (0..k)
            .map(|_| (0..config.w).map(|_| rng.below(field.order())).collect())
            .collect();
        Ok(EncodeJob {
            config,
            field,
            code,
            parity,
            inputs,
        })
    }

    /// Plan, simulate, verify.
    pub fn run(&self) -> anyhow::Result<JobReport> {
        let t0 = Instant::now();
        let mut pl: Plan = crate::framework::plan_with_model(
            &self.field,
            self.code.as_ref(),
            Some(self.parity.clone()),
            self.inputs.clone(),
            self.config.ports,
            self.config.algorithm,
            Some(self.config.cost_model()?),
        )?;
        let mut sim = Sim::new(self.config.ports);
        let sim_report = run(&mut sim, pl.job.as_mut())?;
        let outs = pl.job.outputs();
        let coded: Vec<Packet> = (0..pl.layout.r)
            .map(|r| outs[&pl.layout.sink(r)].clone())
            .collect();
        let verified = match self.config.verify {
            VerifyMode::Off => None,
            VerifyMode::Native => Some(verify::native(
                &self.field,
                &self.parity,
                &self.inputs,
                &coded,
            )),
            VerifyMode::Freivalds => Some(verify::freivalds(
                &self.field,
                &self.parity,
                &self.inputs,
                &coded,
                self.config.seed ^ 0xF5EE,
                2,
            )),
            VerifyMode::Pjrt => Some(verify::pjrt(
                &self.config.artifacts_dir,
                &self.field,
                &self.parity,
                &self.inputs,
                &coded,
            )?),
        };
        let cost = sim_report.cost(&self.config.cost_model()?);
        Ok(JobReport {
            choice: pl.choice,
            layout: pl.layout,
            sim: sim_report,
            cost,
            verified,
            wall: t0.elapsed(),
        })
    }
}

/// Build a structured GRS code, preferring the largest usable radix.
fn build_structured(f: &AnyField, k: usize, r: usize) -> anyhow::Result<GrsCode> {
    // Radix 2 maximises H for the default prime (q−1 = 2^18·3).
    GrsCode::structured(f, k, r, 2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::framework::AlgoRequest;

    #[test]
    fn synthetic_job_runs_and_verifies() {
        let cfg = JobConfig {
            k: 16,
            r: 4,
            w: 8,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg).unwrap();
        let rep = job.run().unwrap();
        assert_eq!(rep.verified, Some(true));
        // Auto is cost-aware: for this small code the universal path wins
        // (Remark 8); forcing the specific path still verifies.
        assert_eq!(rep.choice, PlanChoice::Universal);
        assert!(rep.sim.c1 > 0);
        let mut cfg2 = job.config.clone();
        cfg2.algorithm = crate::framework::AlgoRequest::RsSpecific;
        let rep2 = EncodeJob::synthetic(cfg2).unwrap().run().unwrap();
        assert_eq!(rep2.verified, Some(true));
        assert_eq!(rep2.choice, PlanChoice::RsSpecific);
    }

    #[test]
    fn freivalds_verify_mode_accepts_simulated_encode() {
        let cfg = JobConfig {
            k: 16,
            r: 4,
            w: 8,
            verify: crate::coordinator::config::VerifyMode::Freivalds,
            ..JobConfig::default()
        };
        let rep = EncodeJob::synthetic(cfg).unwrap().run().unwrap();
        assert_eq!(rep.verified, Some(true));
    }

    #[test]
    fn universal_on_random_matrix() {
        let cfg = JobConfig {
            k: 10,
            r: 14,
            w: 2,
            code: CodeKind::Random,
            algorithm: AlgoRequest::Universal,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg).unwrap();
        let rep = job.run().unwrap();
        assert_eq!(rep.verified, Some(true));
        assert_eq!(rep.choice, PlanChoice::Universal);
    }

    #[test]
    fn json_report_is_parseable_shape() {
        let cfg = JobConfig {
            k: 8,
            r: 4,
            w: 2,
            ..JobConfig::default()
        };
        let rep = EncodeJob::synthetic(cfg).unwrap().run().unwrap();
        let j = rep.to_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"c1\":"));
    }
}

//! One decentralized-encoding job: plan → simulate → verify → report.
//!
//! Two execution paths share the verification and reporting logic:
//!
//! * [`EncodeJob::run`] — live: build the collective, step it on the
//!   round engine, measure `C1`/`C2`.
//! * [`EncodeJob::run_cached`] — replay: fetch (or compile) the shape's
//!   [`CompiledPlan`](crate::framework::CompiledPlan) from a
//!   [`PlanCache`] and replay it — bit-identical outputs and the exact
//!   same report, with zero control-flow rederivation per request.

use super::config::{CodeKind, JobConfig, VerifyMode};
use super::plan_cache::{PlanCache, PlanKey};
use super::verify;
use crate::codes::GrsCode;
use crate::framework::{systematic::Layout, CompiledPlan, PlanChoice, PlannedJob};
use crate::gf::{AnyField, Field, Mat};
use crate::net::{run, Outputs, Packet, Sim, SimReport};
use crate::util::Rng;
use std::sync::{Arc, OnceLock};
use std::time::Instant;

/// The outcome of one job, with every paper metric.
#[derive(Clone, Debug)]
pub struct JobReport {
    pub choice: PlanChoice,
    pub layout: Layout,
    pub sim: SimReport,
    /// `C = α·C1 + β⌈log2 q⌉·C2`.
    pub cost: f64,
    pub verified: Option<bool>,
    pub wall: std::time::Duration,
}

impl JobReport {
    pub fn to_json(&self) -> String {
        format!(
            concat!(
                "{{\"algorithm\":\"{}\",\"k\":{},\"r\":{},\"c1\":{},\"c2\":{},",
                "\"messages\":{},\"bandwidth\":{},\"cost\":{},\"verified\":{},",
                "\"wall_us\":{}}}"
            ),
            self.choice,
            self.layout.k,
            self.layout.r,
            self.sim.c1,
            self.sim.c2,
            self.sim.messages,
            self.sim.bandwidth,
            self.cost,
            self.verified.map_or("null".into(), |v| v.to_string()),
            self.wall.as_micros(),
        )
    }
}

impl std::fmt::Display for JobReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "algorithm: {:<12} K={} R={}",
            self.choice.to_string(),
            self.layout.k,
            self.layout.r
        )?;
        writeln!(
            f,
            "C1 = {} rounds, C2 = {} elems (messages {}, bandwidth {} elems)",
            self.sim.c1, self.sim.c2, self.sim.messages, self.sim.bandwidth
        )?;
        writeln!(f, "C  = {:.3} (model cost)", self.cost)?;
        match self.verified {
            Some(true) => writeln!(f, "verification: OK")?,
            Some(false) => writeln!(f, "verification: FAILED")?,
            None => writeln!(f, "verification: skipped")?,
        }
        write!(f, "wall: {:?}", self.wall)
    }
}

/// A planned job with its data, ready to execute.
pub struct EncodeJob {
    pub config: JobConfig,
    pub field: AnyField,
    pub code: Option<GrsCode>,
    pub parity: Arc<Mat>,
    pub inputs: Vec<Packet>,
    /// Memoised [`plan_key`](EncodeJob::plan_key) — the serving hot path
    /// derives the key once per job, not per request. Mutating `config`
    /// or `parity` after the first cached call is not supported.
    plan_key_memo: OnceLock<PlanKey>,
}

impl EncodeJob {
    /// Build a job with synthetic (seeded) payload data.
    pub fn synthetic(config: JobConfig) -> anyhow::Result<Self> {
        let field = config.any_field()?;
        let (k, r) = (config.k, config.r);
        let code = match config.code {
            CodeKind::RsStructured => Some(build_structured(&field, k, r)?),
            CodeKind::RsPlain => Some(GrsCode::plain(
                &field,
                (1..=k as u64).collect(),
                (k as u64 + 1..=(k + r) as u64).collect(),
            )?),
            CodeKind::Lagrange => {
                // Systematic Lagrange = GRS with u/v from the Lagrange
                // normalisation (u = v = 1 — Remark 9).
                Some(GrsCode::plain(
                    &field,
                    (1..=k as u64).collect(),
                    (k as u64 + 1..=(k + r) as u64).collect(),
                )?)
            }
            CodeKind::Random => None,
        };
        let parity: Arc<Mat> = match &code {
            Some(c) => Arc::new(c.parity_matrix(&field)),
            None => Arc::new(Mat::random(&field, k, r, config.seed ^ 0xA5A5)),
        };
        let mut rng = Rng::new(config.seed);
        let inputs: Vec<Packet> = (0..k)
            .map(|_| (0..config.w).map(|_| rng.below(field.order())).collect())
            .collect();
        Ok(EncodeJob {
            config,
            field,
            code,
            parity,
            inputs,
            plan_key_memo: OnceLock::new(),
        })
    }

    /// Verify coded sink packets per the configured mode.
    fn verify_coded(&self, coded: &[Packet]) -> anyhow::Result<Option<bool>> {
        Ok(match self.config.verify {
            VerifyMode::Off => None,
            VerifyMode::Native => Some(verify::native(
                &self.field,
                &self.parity,
                &self.inputs,
                coded,
            )),
            VerifyMode::Freivalds => Some(verify::freivalds(
                &self.field,
                &self.parity,
                &self.inputs,
                coded,
                self.config.seed ^ 0xF5EE,
                2,
            )),
            VerifyMode::Pjrt => Some(verify::pjrt(
                &self.config.artifacts_dir,
                &self.field,
                &self.parity,
                &self.inputs,
                coded,
            )?),
        })
    }

    /// Plan, simulate (live stepping), verify.
    pub fn run(&self) -> anyhow::Result<JobReport> {
        let t0 = Instant::now();
        let mut pl: PlannedJob = crate::framework::plan_with_model(
            &self.field,
            self.code.as_ref(),
            Some(self.parity.clone()),
            self.inputs.clone(),
            self.config.ports,
            self.config.algorithm,
            Some(self.config.cost_model()?),
        )?;
        let mut sim = Sim::new(self.config.ports);
        let sim_report = run(&mut sim, pl.job.as_mut())?;
        let outs = pl.job.outputs();
        let coded: Vec<Packet> = (0..pl.layout.r)
            .map(|r| outs[&pl.layout.sink(r)].clone())
            .collect();
        let verified = self.verify_coded(&coded)?;
        let cost = sim_report.cost(&self.config.cost_model()?);
        Ok(JobReport {
            choice: pl.choice,
            layout: pl.layout,
            sim: sim_report,
            cost,
            verified,
            wall: t0.elapsed(),
        })
    }

    /// The cache key of this job's compiled plan: the shape, a
    /// fingerprint of the parity matrix actually encoded against, and
    /// the *resolved* algorithm choice (width-independent — see
    /// [`PlanCache`]'s module docs on why `W` is absent). Derived once
    /// per job and memoised — the per-request path pays a clone, not a
    /// re-resolution.
    pub fn plan_key(&self) -> anyhow::Result<PlanKey> {
        if let Some(key) = self.plan_key_memo.get() {
            return Ok(key.clone());
        }
        let choice = crate::framework::resolve_choice(
            &self.field,
            self.code.as_ref(),
            self.config.w,
            self.config.ports,
            self.config.algorithm,
            Some(self.config.cost_model()?),
        )?;
        let key = PlanKey {
            field: self.config.field.clone(),
            k: self.config.k,
            r: self.config.r,
            ports: self.config.ports,
            code: self.config.code,
            seed: self.config.seed,
            parity_fp: super::plan_cache::parity_fingerprint(&self.parity),
            choice,
        };
        let _ = self.plan_key_memo.set(key.clone());
        Ok(key)
    }

    /// Fetch this shape's compiled plan from `cache`, compiling on miss.
    pub fn compiled(&self, cache: &PlanCache) -> anyhow::Result<Arc<CompiledPlan>> {
        let key = self.plan_key()?;
        cache.get_or_compile(&key, || {
            crate::framework::compile_plan(
                &self.field,
                self.code.as_ref(),
                Some(self.parity.clone()),
                self.config.ports,
                self.config.w,
                self.config.algorithm,
                Some(self.config.cost_model()?),
            )
        })
    }

    /// Replay-encode arbitrary payload rows (any width) through the
    /// shape's cached *optimized* plan — the serving-path hot loop: no
    /// planning, no round stepping, no routing; just the flattened
    /// output rows (`net::exec::replay_opt`), bit-identical to raw-plan
    /// replay and to live stepping.
    pub fn encode_cached(&self, cache: &PlanCache, x: &[Packet]) -> anyhow::Result<Vec<Packet>> {
        anyhow::ensure!(x.len() == self.config.k, "need K = {} rows", self.config.k);
        let compiled = self.compiled(cache)?;
        let mut replay = crate::net::exec::replay_opt(&compiled.opt, &self.field, x)?;
        take_sinks(&compiled.layout, &mut replay.outputs)
    }

    /// Batch-encode `B` same-width payload sets in **one columnar pass**
    /// over the shape's cached optimized plan
    /// (`net::exec::replay_batch`) — the micro-batching service path.
    /// Returns the `R` coded rows per job, in job order, bit-identical
    /// to [`encode_cached`](EncodeJob::encode_cached) per job.
    pub fn encode_batch_cached(
        &self,
        cache: &PlanCache,
        jobs: &[&[Packet]],
    ) -> anyhow::Result<Vec<Vec<Packet>>> {
        // A batch of one skips the arena pack/unpack entirely — the
        // common low-load case when the micro-batch window expires with
        // a single request.
        if let [x] = jobs {
            return Ok(vec![self.encode_cached(cache, x)?]);
        }
        let compiled = self.compiled(cache)?;
        let replays = crate::net::exec::replay_batch(&compiled.opt, &self.field, jobs)?;
        replays
            .into_iter()
            .map(|mut rep| take_sinks(&compiled.layout, &mut rep.outputs))
            .collect()
    }

    /// Plan-cache execution path: compile-or-fetch, replay, verify.
    /// Produces bit-identical coded packets and the exact `C1`/`C2`
    /// report of [`run`](EncodeJob::run), without re-deriving any
    /// control flow when the cache hits.
    pub fn run_cached(&self, cache: &PlanCache) -> anyhow::Result<JobReport> {
        let t0 = Instant::now();
        let compiled = self.compiled(cache)?;
        let mut replay = crate::net::exec::replay_opt(&compiled.opt, &self.field, &self.inputs)?;
        let coded = take_sinks(&compiled.layout, &mut replay.outputs)?;
        let verified = self.verify_coded(&coded)?;
        let cost = replay.report.cost(&self.config.cost_model()?);
        Ok(JobReport {
            choice: compiled.choice,
            layout: compiled.layout,
            sim: replay.report,
            cost,
            verified,
            wall: t0.elapsed(),
        })
    }
}

/// Pull the `R` sink packets out of a replay's output map, in sink
/// order — the one sink-extraction path shared by every cached
/// execution route.
fn take_sinks(layout: &Layout, outputs: &mut Outputs) -> anyhow::Result<Vec<Packet>> {
    (0..layout.r)
        .map(|r| {
            let pid = layout.sink(r);
            outputs
                .remove(&pid)
                .ok_or_else(|| anyhow::anyhow!("replay missing sink {pid}"))
        })
        .collect()
}

/// Build a structured GRS code, preferring the largest usable radix.
fn build_structured(f: &AnyField, k: usize, r: usize) -> anyhow::Result<GrsCode> {
    // Radix 2 maximises H for the default prime (q−1 = 2^18·3).
    GrsCode::structured(f, k, r, 2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::framework::AlgoRequest;

    #[test]
    fn synthetic_job_runs_and_verifies() {
        let cfg = JobConfig {
            k: 16,
            r: 4,
            w: 8,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg).unwrap();
        let rep = job.run().unwrap();
        assert_eq!(rep.verified, Some(true));
        // Auto is cost-aware: for this small code the universal path wins
        // (Remark 8); forcing the specific path still verifies.
        assert_eq!(rep.choice, PlanChoice::Universal);
        assert!(rep.sim.c1 > 0);
        let mut cfg2 = job.config.clone();
        cfg2.algorithm = crate::framework::AlgoRequest::RsSpecific;
        let rep2 = EncodeJob::synthetic(cfg2).unwrap().run().unwrap();
        assert_eq!(rep2.verified, Some(true));
        assert_eq!(rep2.choice, PlanChoice::RsSpecific);
    }

    #[test]
    fn freivalds_verify_mode_accepts_simulated_encode() {
        let cfg = JobConfig {
            k: 16,
            r: 4,
            w: 8,
            verify: crate::coordinator::config::VerifyMode::Freivalds,
            ..JobConfig::default()
        };
        let rep = EncodeJob::synthetic(cfg).unwrap().run().unwrap();
        assert_eq!(rep.verified, Some(true));
    }

    #[test]
    fn universal_on_random_matrix() {
        let cfg = JobConfig {
            k: 10,
            r: 14,
            w: 2,
            code: CodeKind::Random,
            algorithm: AlgoRequest::Universal,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg).unwrap();
        let rep = job.run().unwrap();
        assert_eq!(rep.verified, Some(true));
        assert_eq!(rep.choice, PlanChoice::Universal);
    }

    #[test]
    fn run_cached_matches_live_run_for_every_algorithm() {
        let cache = crate::coordinator::PlanCache::new();
        for algo in [
            AlgoRequest::Auto,
            AlgoRequest::Universal,
            AlgoRequest::RsSpecific,
            AlgoRequest::MultiReduce,
            AlgoRequest::Direct,
        ] {
            let cfg = JobConfig {
                k: 16,
                r: 4,
                w: 8,
                algorithm: algo,
                ..JobConfig::default()
            };
            let job = EncodeJob::synthetic(cfg).unwrap();
            let live = job.run().unwrap();
            let cached = job.run_cached(&cache).unwrap();
            assert_eq!(cached.verified, Some(true), "{algo:?}");
            assert_eq!(cached.choice, live.choice, "{algo:?}");
            // Identical (C1, C2) and full report — statics, not re-runs.
            assert_eq!(cached.sim, live.sim, "{algo:?}");
            assert_eq!(cached.cost, live.cost, "{algo:?}");
        }
        // Auto resolved to Universal here (Remark 8), so five requests
        // hit four distinct plans: one hit, four misses.
        assert_eq!(cache.len(), 4);
        assert_eq!(cache.stats(), (1, 4));
    }

    #[test]
    fn one_cached_plan_serves_every_width() {
        let cache = crate::coordinator::PlanCache::new();
        let cfg = JobConfig {
            k: 8,
            r: 4,
            w: 5,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg.clone()).unwrap();
        job.run_cached(&cache).unwrap();
        let f = job.field.clone();
        use crate::gf::Field;
        let mut rng = crate::util::Rng::new(3);
        for w in [1usize, 5, 17] {
            let x: Vec<Packet> = (0..cfg.k)
                .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
                .collect();
            let y = job.encode_cached(&cache, &x).unwrap();
            assert!(crate::coordinator::verify::native(&f, &job.parity, &x, &y), "w={w}");
        }
        // One shape, one compile — widths share the plan.
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.stats().1, 1);
    }

    #[test]
    fn batch_encode_matches_per_job_encode_bit_for_bit() {
        let cache = crate::coordinator::PlanCache::new();
        let cfg = JobConfig {
            k: 8,
            r: 4,
            w: 3,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg.clone()).unwrap();
        let f = job.field.clone();
        use crate::gf::Field;
        let mut rng = crate::util::Rng::new(11);
        let jobs: Vec<Vec<Packet>> = (0..5)
            .map(|_| {
                (0..cfg.k)
                    .map(|_| (0..cfg.w).map(|_| rng.below(f.order())).collect())
                    .collect()
            })
            .collect();
        let refs: Vec<&[Packet]> = jobs.iter().map(|x| x.as_slice()).collect();
        let batched = job.encode_batch_cached(&cache, &refs).unwrap();
        assert_eq!(batched.len(), jobs.len());
        for (x, y) in jobs.iter().zip(&batched) {
            assert_eq!(y, &job.encode_cached(&cache, x).unwrap());
            assert!(verify::native(&f, &job.parity, x, y));
        }
        // One shape: the whole batch plus the singles hit one compile.
        assert_eq!(cache.stats().1, 1);
    }

    #[test]
    fn json_report_is_parseable_shape() {
        let cfg = JobConfig {
            k: 8,
            r: 4,
            w: 2,
            ..JobConfig::default()
        };
        let rep = EncodeJob::synthetic(cfg).unwrap().run().unwrap();
        let j = rep.to_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"c1\":"));
    }
}

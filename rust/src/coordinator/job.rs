//! One decentralized-encoding job: plan → execute → verify → report.
//!
//! Execution is configured, not multiplied: [`EncodeJob::run`] takes an
//! [`ExecOptions`] naming the engine ([`Engine::Live`] round stepping,
//! [`Engine::Replay`] through the plan cache, or [`Engine::Peer`] over
//! a real transport mesh), an optional [`FaultSpec`], an optional
//! [`PlanCache`] and an optional ISA override — every combination runs
//! through the same verification and reporting tail and returns the
//! same [`JobReport`]. The batched serving path is
//! [`EncodeJob::encode`] with the same options. The pre-0.4 entry-point
//! family (`run_cached`, `encode_cached`, `run_degraded`, …) survives
//! one release as `#[deprecated]` shims over these two.

use super::config::{CodeKind, JobConfig, VerifyMode};
use super::plan_cache::{PlanCache, PlanKey};
use super::verify;
use crate::codes::structured::independent_positions;
use crate::codes::{GrsCode, Recovery, StructuredPoints};
use crate::error::{Error, RecoveryShortfall};
use crate::framework::{systematic::Layout, CompiledPlan, PlanChoice, PlannedJob};
use crate::gf::{AnyField, Field, IsaRequest, IsaTier, Mat};
use crate::net::peer::{spawn_local, spawn_local_chaos, RetryPolicy, ShardedPlan};
use crate::net::transport::{ChaosSpec, TransportKind};
use crate::net::{run, DegradedReport, FaultSpec, Outputs, Packet, ProcId, Sim, SimReport};
use crate::util::{ipow, Rng};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

/// Which execution engine carries the job.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Engine {
    /// Build the collective and step it live on the round simulator.
    #[default]
    Live,
    /// Replay the shape's compiled plan (cache-served hot path) —
    /// bit-identical outputs and the exact same report as `Live`.
    Replay,
    /// Peer-to-peer execution: shard the plan, run every rank against a
    /// real [`Transport`](crate::net::transport::Transport) mesh of the
    /// given kind, and report *measured* traffic.
    Peer(TransportKind),
}

impl std::str::FromStr for Engine {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> anyhow::Result<Engine> {
        Ok(match s {
            "live" => Engine::Live,
            "replay" | "cached" => Engine::Replay,
            "peer" | "peer-channel" => Engine::Peer(TransportKind::Channel),
            "peer-shmem" => Engine::Peer(TransportKind::SharedMem),
            "peer-tcp" => Engine::Peer(TransportKind::Tcp),
            other => anyhow::bail!(
                "unknown engine {other:?} (live|replay|peer-channel|peer-shmem|peer-tcp)"
            ),
        })
    }
}

impl std::fmt::Display for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Engine::Live => f.write_str("live"),
            Engine::Replay => f.write_str("replay"),
            Engine::Peer(k) => write!(f, "peer-{k}"),
        }
    }
}

/// How to execute a job: engine, optional plan cache, optional fault
/// injection, optional ISA override. `Default` is a live, healthy,
/// uncached run at the config's ISA.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecOptions<'a> {
    /// Compiled-plan cache for the `Replay`/`Peer` engines (and for
    /// [`EncodeJob::run`]'s compile step). `None` compiles privately.
    pub cache: Option<&'a PlanCache>,
    /// Fault injection: a degraded run with survivor repair. On the
    /// `Peer` engine the same directives drive a seeded
    /// [`ChaosTransport`](crate::net::transport::ChaosTransport) under
    /// every rank and the mesh heals itself before the repair tail.
    pub faults: Option<&'a FaultSpec>,
    /// Per-call ISA override; `None` keeps the config's request.
    pub isa: Option<IsaRequest>,
    /// The execution engine.
    pub engine: Engine,
}

impl<'a> ExecOptions<'a> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Replay through `cache` — the serving-path default.
    pub fn cached(cache: &'a PlanCache) -> Self {
        ExecOptions {
            cache: Some(cache),
            engine: Engine::Replay,
            ..Default::default()
        }
    }

    pub fn engine(mut self, engine: Engine) -> Self {
        self.engine = engine;
        self
    }

    pub fn faults(mut self, faults: &'a FaultSpec) -> Self {
        self.faults = Some(faults);
        self
    }

    pub fn isa(mut self, isa: IsaRequest) -> Self {
        self.isa = Some(isa);
        self
    }
}

/// What a degraded run did beyond encoding: the failure pattern's
/// analysis and the repaired coded rows.
#[derive(Clone, Debug)]
pub struct DegradedInfo {
    /// Fault directives in the spec (crashes + links + erasures).
    pub faults_injected: u64,
    pub crashed: Vec<ProcId>,
    /// Sink indices whose outputs survived untainted.
    pub surviving_sinks: Vec<usize>,
    /// Sink indices reconstructed from survivors.
    pub lost_sinks: Vec<usize>,
    pub outputs_recovered: usize,
    /// Wall time of the recovery pass (operator build + lincombs).
    pub recovery_wall: Duration,
    /// Transient recv/barrier retries absorbed by the mesh (`Peer`
    /// engine only; the simulator engines report zero).
    pub peer_retries: u64,
    /// Rank-rounds that needed at least one retry (`Peer` engine only).
    pub peer_rounds_delayed: u64,
    /// Dead peers the mesh detected on the wire and gossiped (`Peer`
    /// engine only).
    pub peer_crashes_detected: u64,
    /// All `R` coded rows in sink order — surviving sinks verbatim,
    /// lost sinks reconstructed; bit-identical to a healthy run's.
    pub coded: Vec<Packet>,
}

/// The outcome of one job, with every paper metric.
#[derive(Clone, Debug)]
pub struct JobReport {
    pub choice: PlanChoice,
    pub layout: Layout,
    /// For `Peer` runs this is **measured** traffic (barriers crossed,
    /// messages shipped); for `Live`/`Replay` it is the simulator's
    /// exact accounting — conformance tests pin them equal.
    pub sim: SimReport,
    /// `C = α·C1 + β⌈log2 q⌉·C2`.
    pub cost: f64,
    pub verified: Option<bool>,
    pub wall: std::time::Duration,
    /// Present iff the run was fault-injected.
    pub degraded: Option<DegradedInfo>,
}

impl JobReport {
    pub fn to_json(&self) -> String {
        format!(
            concat!(
                "{{\"algorithm\":\"{}\",\"k\":{},\"r\":{},\"c1\":{},\"c2\":{},",
                "\"messages\":{},\"bandwidth\":{},\"cost\":{},\"verified\":{},",
                "\"wall_us\":{}}}"
            ),
            self.choice,
            self.layout.k,
            self.layout.r,
            self.sim.c1,
            self.sim.c2,
            self.sim.messages,
            self.sim.bandwidth,
            self.cost,
            self.verified.map_or("null".into(), |v| v.to_string()),
            self.wall.as_micros(),
        )
    }
}

impl std::fmt::Display for JobReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "algorithm: {:<12} K={} R={}",
            self.choice.to_string(),
            self.layout.k,
            self.layout.r
        )?;
        writeln!(
            f,
            "C1 = {} rounds, C2 = {} elems (messages {}, bandwidth {} elems)",
            self.sim.c1, self.sim.c2, self.sim.messages, self.sim.bandwidth
        )?;
        writeln!(f, "C  = {:.3} (model cost)", self.cost)?;
        if let Some(d) = &self.degraded {
            writeln!(
                f,
                "degraded: {} crashed, {} sinks repaired in {:?}",
                d.crashed.len(),
                d.lost_sinks.len(),
                d.recovery_wall
            )?;
        }
        match self.verified {
            Some(true) => writeln!(f, "verification: OK")?,
            Some(false) => writeln!(f, "verification: FAILED")?,
            None => writeln!(f, "verification: skipped")?,
        }
        write!(f, "wall: {:?}", self.wall)
    }
}

/// What [`EncodeJob::encode`] returns: the `R` coded rows per job, plus
/// recovery accounting when the batch ran degraded.
#[derive(Clone, Debug)]
pub struct EncodeOutcome {
    /// Per job in batch order, the `R` coded rows in sink order.
    pub coded: Vec<Vec<Packet>>,
    /// Present iff the batch ran under fault injection.
    pub recovery: Option<RecoveryStats>,
}

/// A planned job with its data, ready to execute.
pub struct EncodeJob {
    pub config: JobConfig,
    pub field: AnyField,
    pub code: Option<GrsCode>,
    pub parity: Arc<Mat>,
    pub inputs: Vec<Packet>,
    /// Memoised [`plan_key`](EncodeJob::plan_key) — the serving hot path
    /// derives the key once per job, not per request. Mutating `config`
    /// or `parity` after the first cached call is not supported.
    plan_key_memo: OnceLock<PlanKey>,
    /// Memoised per-processor shards of the compiled plan (the `Peer`
    /// engine's analogue of the plan cache — shard once, run many).
    shard_memo: OnceLock<Arc<ShardedPlan>>,
}

/// Recv/barrier bound for in-process peer meshes: generous enough for
/// CI loadspikes, finite so a lost rank is an error, not a hang.
pub const PEER_TIMEOUT: Duration = Duration::from_secs(30);

impl EncodeJob {
    /// Build a job with synthetic (seeded) payload data.
    pub fn synthetic(config: JobConfig) -> anyhow::Result<Self> {
        let field = config.any_field()?;
        let (k, r) = (config.k, config.r);
        let code = match config.code {
            CodeKind::RsStructured => Some(build_structured(&field, k, r)?),
            CodeKind::RsPlain => Some(GrsCode::plain(
                &field,
                (1..=k as u64).collect(),
                (k as u64 + 1..=(k + r) as u64).collect(),
            )?),
            CodeKind::Lagrange => {
                // Systematic Lagrange = GRS with u/v from the Lagrange
                // normalisation (u = v = 1 — Remark 9).
                Some(GrsCode::plain(
                    &field,
                    (1..=k as u64).collect(),
                    (k as u64 + 1..=(k + r) as u64).collect(),
                )?)
            }
            CodeKind::RsNtt => {
                // NTT-friendly geometry (roots + generator coset) with
                // seeded non-unit multipliers — the general GRS flavor
                // of the transform backend. A field without the two-adic
                // root tower is a proper construction error.
                let mut mrng = Rng::new(config.seed ^ 0x17A7);
                let u: Vec<u64> = (0..k).map(|_| mrng.below(field.order() - 1) + 1).collect();
                let v: Vec<u64> = (0..r).map(|_| mrng.below(field.order() - 1) + 1).collect();
                Some(GrsCode::ntt_friendly(&field, k, r, u, v)?)
            }
            CodeKind::Random => None,
        };
        let parity: Arc<Mat> = match &code {
            Some(c) => Arc::new(c.parity_matrix(&field)),
            None => Arc::new(Mat::random(&field, k, r, config.seed ^ 0xA5A5)),
        };
        let mut rng = Rng::new(config.seed);
        let inputs: Vec<Packet> = (0..k)
            .map(|_| (0..config.w).map(|_| rng.below(field.order())).collect())
            .collect();
        Ok(EncodeJob {
            config,
            field,
            code,
            parity,
            inputs,
            plan_key_memo: OnceLock::new(),
            shard_memo: OnceLock::new(),
        })
    }

    /// Verify coded sink packets per the configured mode.
    fn verify_coded(&self, coded: &[Packet]) -> anyhow::Result<Option<bool>> {
        Ok(match self.config.verify {
            VerifyMode::Off => None,
            VerifyMode::Native => Some(verify::native(
                &self.field,
                &self.parity,
                &self.inputs,
                coded,
            )),
            VerifyMode::Freivalds => Some(verify::freivalds(
                &self.field,
                &self.parity,
                &self.inputs,
                coded,
                self.config.seed ^ 0xF5EE,
                2,
            )),
            VerifyMode::Pjrt => Some(verify::pjrt(
                &self.config.artifacts_dir,
                &self.field,
                &self.parity,
                &self.inputs,
                coded,
            )?),
        })
    }

    /// **The** execution entry point: run this job per `opts` — engine
    /// × optional faults × optional cache × optional ISA override — and
    /// report. Every path produces bit-identical coded packets and (for
    /// `Live`/`Replay`) the identical `C1`/`C2` report; the `Peer`
    /// engine reports what its ranks *measured*, which conformance
    /// tests pin equal to the plan statics.
    pub fn run(&self, opts: &ExecOptions) -> Result<JobReport, Error> {
        self.run_impl(opts).map_err(Error::classify)
    }

    fn run_impl(&self, opts: &ExecOptions) -> anyhow::Result<JobReport> {
        match (opts.engine, opts.faults) {
            (Engine::Live, None) => self.run_live(),
            (Engine::Live, Some(faults)) => self.run_live_degraded(faults),
            (Engine::Replay, None) => {
                self.with_cache(opts, |job, cache| job.run_replay(cache, opts.isa))
            }
            (Engine::Replay, Some(faults)) => self.with_cache(opts, |job, cache| {
                job.run_replay_degraded(cache, faults, opts.isa)
            }),
            (Engine::Peer(kind), None) => {
                self.with_cache(opts, |job, cache| job.run_peer(cache, kind, opts.isa))
            }
            (Engine::Peer(kind), Some(faults)) => self.with_cache(opts, |job, cache| {
                job.run_peer_degraded(cache, kind, faults, opts.isa)
            }),
        }
    }

    /// Batched execution entry point: encode `B` same-width payload
    /// sets per `opts`. `Live` is served through the replay engine —
    /// the data path is bit-identical by construction, and stepping the
    /// round simulator per request would only re-measure what the plan
    /// statics already pin.
    pub fn encode(
        &self,
        cache: &PlanCache,
        batch: &[&[Packet]],
        opts: &ExecOptions,
    ) -> Result<EncodeOutcome, Error> {
        self.encode_impl(cache, batch, opts).map_err(Error::classify)
    }

    fn encode_impl(
        &self,
        cache: &PlanCache,
        batch: &[&[Packet]],
        opts: &ExecOptions,
    ) -> anyhow::Result<EncodeOutcome> {
        match (opts.engine, opts.faults) {
            (Engine::Peer(kind), Some(faults)) => {
                self.encode_peer_degraded(cache, batch, kind, faults, opts.isa)
            }
            (_, Some(faults)) => {
                let (coded, stats) =
                    self.encode_degraded_impl(cache, batch, faults, opts.isa)?;
                Ok(EncodeOutcome {
                    coded,
                    recovery: Some(stats),
                })
            }
            (Engine::Peer(kind), None) => self.encode_peer(cache, batch, kind, opts.isa),
            (Engine::Live | Engine::Replay, None) => Ok(EncodeOutcome {
                coded: self.encode_batch_impl(cache, batch, opts.isa)?,
                recovery: None,
            }),
        }
    }

    /// Run `f` with the caller's cache, or a private one-shot cache
    /// when `opts` brought none (single compile, then dropped).
    fn with_cache<T>(
        &self,
        opts: &ExecOptions,
        f: impl FnOnce(&Self, &PlanCache) -> anyhow::Result<T>,
    ) -> anyhow::Result<T> {
        match opts.cache {
            Some(cache) => f(self, cache),
            None => f(self, &PlanCache::new()),
        }
    }

    /// Live engine, healthy: build the collective, step it, measure.
    fn run_live(&self) -> anyhow::Result<JobReport> {
        let t0 = Instant::now();
        let mut pl: PlannedJob = crate::framework::plan_with_model(
            &self.field,
            self.code.as_ref(),
            Some(self.parity.clone()),
            self.inputs.clone(),
            self.config.ports,
            self.config.algorithm,
            Some(self.config.cost_model()?),
        )?;
        let mut sim = Sim::new(self.config.ports);
        let sim_report = run(&mut sim, pl.job.as_mut())?;
        let outs = pl.job.outputs();
        let coded: Vec<Packet> = (0..pl.layout.r)
            .map(|r| outs[&pl.layout.sink(r)].clone())
            .collect();
        let verified = self.verify_coded(&coded)?;
        let cost = sim_report.cost(&self.config.cost_model()?);
        Ok(JobReport {
            choice: pl.choice,
            layout: pl.layout,
            sim: sim_report,
            cost,
            verified,
            wall: t0.elapsed(),
            degraded: None,
        })
    }

    /// Live engine under fault injection.
    fn run_live_degraded(&self, faults: &FaultSpec) -> anyhow::Result<JobReport> {
        let t0 = Instant::now();
        let mut pl: PlannedJob = crate::framework::plan_with_model(
            &self.field,
            self.code.as_ref(),
            Some(self.parity.clone()),
            self.inputs.clone(),
            self.config.ports,
            self.config.algorithm,
            Some(self.config.cost_model()?),
        )?;
        let mut sim = Sim::new(self.config.ports);
        let deg = crate::net::run_degraded(&mut sim, pl.job.as_mut(), faults)?;
        self.finish_degraded(pl.choice, pl.layout, deg.fault, &deg.outputs, faults, t0)
    }

    /// Replay engine, healthy: compile-or-fetch, replay, verify.
    fn run_replay(&self, cache: &PlanCache, isa: Option<IsaRequest>) -> anyhow::Result<JobReport> {
        let t0 = Instant::now();
        let compiled = self.compiled_with(cache, isa)?;
        let mut replay = crate::net::exec::replay_opt(&compiled.opt, &self.field, &self.inputs)?;
        let coded = take_sinks(&compiled.layout, &mut replay.outputs)?;
        let verified = self.verify_coded(&coded)?;
        let cost = replay.report.cost(&self.config.cost_model()?);
        Ok(JobReport {
            choice: compiled.choice,
            layout: compiled.layout,
            sim: replay.report,
            cost,
            verified,
            wall: t0.elapsed(),
            degraded: None,
        })
    }

    /// Replay engine under fault injection: taint-analyze the plan,
    /// evaluate surviving rows, repair the rest.
    fn run_replay_degraded(
        &self,
        cache: &PlanCache,
        faults: &FaultSpec,
        isa: Option<IsaRequest>,
    ) -> anyhow::Result<JobReport> {
        let t0 = Instant::now();
        let compiled = self.compiled_with(cache, isa)?;
        let jobs = [self.inputs.as_slice()];
        let (fault, mut outs) = compiled.replay_degraded_batch(&jobs, faults)?;
        let outputs = outs.pop().expect("one job in, one out");
        self.finish_degraded(compiled.choice, compiled.layout, fault, &outputs, faults, t0)
    }

    /// Peer engine: shard the compiled plan, run every rank as a thread
    /// over a fresh transport mesh, report **measured** traffic.
    fn run_peer(
        &self,
        cache: &PlanCache,
        kind: TransportKind,
        isa: Option<IsaRequest>,
    ) -> anyhow::Result<JobReport> {
        let t0 = Instant::now();
        let compiled = self.compiled_with(cache, isa)?;
        let sharded = self.sharded(&compiled)?;
        let run = spawn_local(&sharded, &self.field, &self.inputs, kind, PEER_TIMEOUT)?;
        let mut outputs = run.outputs;
        let coded = take_sinks(&compiled.layout, &mut outputs)?;
        let verified = self.verify_coded(&coded)?;
        let cost = run.measured.cost(&self.config.cost_model()?);
        Ok(JobReport {
            choice: compiled.choice,
            layout: compiled.layout,
            sim: run.measured,
            cost,
            verified,
            wall: t0.elapsed(),
            degraded: None,
        })
    }

    /// Peer engine, batched: each job runs the full peer collective.
    fn encode_peer(
        &self,
        cache: &PlanCache,
        batch: &[&[Packet]],
        kind: TransportKind,
        isa: Option<IsaRequest>,
    ) -> anyhow::Result<EncodeOutcome> {
        let compiled = self.compiled_with(cache, isa)?;
        let sharded = self.sharded(&compiled)?;
        let coded = batch
            .iter()
            .map(|x| {
                self.check_canonical(x)?;
                let run = spawn_local(&sharded, &self.field, x, kind, PEER_TIMEOUT)?;
                let mut outputs = run.outputs;
                take_sinks(&compiled.layout, &mut outputs)
            })
            .collect::<anyhow::Result<_>>()?;
        Ok(EncodeOutcome {
            coded,
            recovery: None,
        })
    }

    /// Peer engine under fault injection: wrap every rank's transport
    /// in a [`ChaosTransport`](crate::net::transport::ChaosTransport)
    /// driving the same `FaultSpec` directives, let the mesh heal
    /// itself (crash gossip + zero substitution), then repair the lost
    /// sink outputs from survivors exactly like the simulator engines.
    fn run_peer_degraded(
        &self,
        cache: &PlanCache,
        kind: TransportKind,
        faults: &FaultSpec,
        isa: Option<IsaRequest>,
    ) -> anyhow::Result<JobReport> {
        let t0 = Instant::now();
        let compiled = self.compiled_with(cache, isa)?;
        let sharded = self.sharded(&compiled)?;
        let chaos = ChaosSpec::from_fault_spec(faults);
        let run = spawn_local_chaos(
            &sharded,
            &self.field,
            &self.inputs,
            kind,
            PEER_TIMEOUT,
            &chaos,
            &RetryPolicy::default(),
        )?;
        let mut report = self.finish_degraded(
            compiled.choice,
            compiled.layout,
            run.report,
            &run.outputs,
            faults,
            t0,
        )?;
        let d = report.degraded.as_mut().expect("degraded path set info");
        d.peer_retries = run.retries;
        d.peer_rounds_delayed = run.rounds_delayed;
        d.peer_crashes_detected = run.crashes_detected.len() as u64;
        Ok(report)
    }

    /// Peer engine, batched, under fault injection: every job runs the
    /// full chaos-wrapped collective; the repair strategy is planned
    /// once (the failure pattern is shape-level, pinned deterministic
    /// by the seeded injector) and applied per job.
    fn encode_peer_degraded(
        &self,
        cache: &PlanCache,
        batch: &[&[Packet]],
        kind: TransportKind,
        faults: &FaultSpec,
        isa: Option<IsaRequest>,
    ) -> anyhow::Result<EncodeOutcome> {
        let compiled = self.compiled_with(cache, isa)?;
        let sharded = self.sharded(&compiled)?;
        let chaos = ChaosSpec::from_fault_spec(faults);
        let policy = RetryPolicy::default();
        let mut repair: Option<Repair> = None;
        let mut recovery_wall = Duration::ZERO;
        let mut healing = (0u64, 0u64, 0u64);
        let mut coded = Vec::with_capacity(batch.len());
        for x in batch {
            self.check_canonical(x)?;
            let run = spawn_local_chaos(
                &sharded,
                &self.field,
                x,
                kind,
                PEER_TIMEOUT,
                &chaos,
                &policy,
            )?;
            healing.0 += run.retries;
            healing.1 += run.rounds_delayed;
            healing.2 += run.crashes_detected.len() as u64;
            let rt0 = Instant::now();
            if repair.is_none() {
                repair = Some(self.plan_repair(&compiled.layout, &run.report)?);
            }
            let rep = repair.as_ref().expect("repair planned on first job");
            coded.push(self.apply_repair(rep, &compiled.layout, x, &run.outputs)?);
            recovery_wall += rt0.elapsed();
        }
        let outputs_lost = repair.as_ref().map_or(0, |r| r.lost_sinks.len());
        Ok(EncodeOutcome {
            coded,
            recovery: Some(RecoveryStats {
                faults_injected: faults.injected(),
                outputs_lost,
                outputs_recovered: (outputs_lost * batch.len()) as u64,
                recovery_wall,
                peer_retries: healing.0,
                peer_rounds_delayed: healing.1,
                peer_crashes_detected: healing.2,
            }),
        })
    }

    /// The memoised per-processor shards of this job's compiled plan.
    fn sharded(&self, compiled: &CompiledPlan) -> anyhow::Result<Arc<ShardedPlan>> {
        if let Some(s) = self.shard_memo.get() {
            return Ok(s.clone());
        }
        let owners: Vec<ProcId> = (0..compiled.plan.n_inputs).collect();
        let sharded = Arc::new(ShardedPlan::new(&compiled.plan, &self.field, &owners)?);
        let _ = self.shard_memo.set(sharded.clone());
        Ok(sharded)
    }

    /// The cache key of this job's compiled plan: the shape, a
    /// fingerprint of the parity matrix actually encoded against, and
    /// the *resolved* algorithm choice (width-independent — see
    /// [`PlanCache`]'s module docs on why `W` is absent). Derived once
    /// per job and memoised — the per-request path pays a clone, not a
    /// re-resolution.
    pub fn plan_key(&self) -> anyhow::Result<PlanKey> {
        if let Some(key) = self.plan_key_memo.get() {
            return Ok(key.clone());
        }
        let choice = crate::framework::resolve_choice(
            &self.field,
            self.code.as_ref(),
            self.config.w,
            self.config.ports,
            self.config.algorithm,
            Some(self.config.cost_model()?),
        )?;
        let key = PlanKey {
            field: self.config.field.clone(),
            k: self.config.k,
            r: self.config.r,
            ports: self.config.ports,
            code: self.config.code,
            seed: self.config.seed,
            parity_fp: super::plan_cache::parity_fingerprint(&self.parity),
            choice,
            isa: self.config.isa,
        };
        let _ = self.plan_key_memo.set(key.clone());
        Ok(key)
    }

    /// Fetch this shape's compiled plan from `cache`, compiling on miss.
    pub fn compiled(&self, cache: &PlanCache) -> anyhow::Result<Arc<CompiledPlan>> {
        let key = self.plan_key()?;
        cache.get_or_compile(&key, || {
            let compiled = crate::framework::compile_plan(
                &self.field,
                self.code.as_ref(),
                Some(self.parity.clone()),
                self.config.ports,
                self.config.w,
                self.config.algorithm,
                Some(self.config.cost_model()?),
            )?;
            // Apply the job's explicit ISA request (clamped to what this
            // host can execute); `None` keeps the process-default tier
            // `compile_plan` already resolved.
            Ok(match self.config.isa {
                Some(req) => compiled.with_isa(crate::gf::IsaTier::resolve(req)),
                None => compiled,
            })
        })
    }

    /// [`compiled`](EncodeJob::compiled) plus a per-call ISA override:
    /// a request differing from the config's re-targets a clone of the
    /// cached plan instead of poisoning the cache (whose key embeds the
    /// config's ISA).
    fn compiled_with(
        &self,
        cache: &PlanCache,
        isa: Option<IsaRequest>,
    ) -> anyhow::Result<Arc<CompiledPlan>> {
        let compiled = self.compiled(cache)?;
        match isa {
            Some(req) if self.config.isa != Some(req) => {
                Ok(Arc::new((*compiled).clone().with_isa(IsaTier::resolve(req))))
            }
            _ => Ok(compiled),
        }
    }

    /// Warm `cache` with this shape's compiled plan. Returns `true`
    /// when the plan was compiled fresh, `false` when the shape was
    /// already cached — the [`PlanCache::warmup`] building block.
    pub fn warm(&self, cache: &PlanCache) -> anyhow::Result<bool> {
        let key = self.plan_key()?;
        if cache.contains(&key) {
            return Ok(false);
        }
        self.compiled(cache)?;
        Ok(true)
    }

    /// Non-canonical elements must be a proper Err on every encode path
    /// (the batched engines validate before packing; the scalar
    /// GF(2^w) kernels would panic on a table lookup instead — killing
    /// a service worker).
    fn check_canonical(&self, x: &[Packet]) -> anyhow::Result<()> {
        anyhow::ensure!(x.len() == self.config.k, "need K = {} rows", self.config.k);
        let q = self.field.order();
        for row in x {
            if let Some(&v) = row.iter().find(|&&v| v >= q) {
                anyhow::bail!("payload element {v} is not canonical (field order {q})");
            }
        }
        Ok(())
    }

    /// Single-job replay through the cached *optimized* plan.
    fn encode_one_impl(
        &self,
        cache: &PlanCache,
        x: &[Packet],
        isa: Option<IsaRequest>,
    ) -> anyhow::Result<Vec<Packet>> {
        self.check_canonical(x)?;
        let compiled = self.compiled_with(cache, isa)?;
        let mut replay = crate::net::exec::replay_opt(&compiled.opt, &self.field, x)?;
        take_sinks(&compiled.layout, &mut replay.outputs)
    }

    /// Batched columnar replay (the micro-batching service path); a
    /// batch of one skips the arena pack/unpack entirely.
    fn encode_batch_impl(
        &self,
        cache: &PlanCache,
        jobs: &[&[Packet]],
        isa: Option<IsaRequest>,
    ) -> anyhow::Result<Vec<Vec<Packet>>> {
        if let [x] = jobs {
            return Ok(vec![self.encode_one_impl(cache, x, isa)?]);
        }
        let compiled = self.compiled_with(cache, isa)?;
        let replays = compiled.replay_batch(jobs)?;
        replays
            .into_iter()
            .map(|mut rep| take_sinks(&compiled.layout, &mut rep.outputs))
            .collect()
    }

    /// Degraded batch: one taint analysis, one columnar pass over the
    /// surviving rows, one recovery operator applied per job.
    fn encode_degraded_impl(
        &self,
        cache: &PlanCache,
        jobs: &[&[Packet]],
        faults: &FaultSpec,
        isa: Option<IsaRequest>,
    ) -> anyhow::Result<(Vec<Vec<Packet>>, RecoveryStats)> {
        let compiled = self.compiled_with(cache, isa)?;
        let (fault, outs) = compiled.replay_degraded_batch(jobs, faults)?;
        let rt0 = Instant::now();
        let repair = self.plan_repair(&compiled.layout, &fault)?;
        let coded: Vec<Vec<Packet>> = outs
            .iter()
            .zip(jobs)
            .map(|(o, x)| self.apply_repair(&repair, &compiled.layout, x, o))
            .collect::<anyhow::Result<_>>()?;
        let stats = RecoveryStats {
            faults_injected: faults.injected(),
            outputs_lost: repair.lost_sinks.len(),
            outputs_recovered: (repair.lost_sinks.len() * jobs.len()) as u64,
            recovery_wall: rt0.elapsed(),
            peer_retries: 0,
            peer_rounds_delayed: 0,
            peer_crashes_detected: 0,
        };
        Ok((coded, stats))
    }

    /// Shared tail of the degraded paths: plan the repair, assemble the
    /// full coded rows, verify, report.
    fn finish_degraded(
        &self,
        choice: PlanChoice,
        layout: Layout,
        fault: DegradedReport,
        outputs: &Outputs,
        faults: &FaultSpec,
        t0: Instant,
    ) -> anyhow::Result<JobReport> {
        let rt0 = Instant::now();
        let repair = self.plan_repair(&layout, &fault)?;
        let coded = self.apply_repair(&repair, &layout, &self.inputs, outputs)?;
        let recovery_wall = rt0.elapsed();
        let verified = self.verify_coded(&coded)?;
        let cost = fault.delivered.cost(&self.config.cost_model()?);
        Ok(JobReport {
            choice,
            layout,
            sim: fault.delivered,
            cost,
            verified,
            wall: t0.elapsed(),
            degraded: Some(DegradedInfo {
                faults_injected: faults.injected(),
                crashed: fault.crashed.iter().copied().collect(),
                outputs_recovered: repair.lost_sinks.len(),
                surviving_sinks: repair.surviving_sinks,
                lost_sinks: repair.lost_sinks,
                recovery_wall,
                peer_retries: 0,
                peer_rounds_delayed: 0,
                peer_crashes_detected: 0,
                coded,
            }),
        })
    }

    /// Build the repair strategy for one failure pattern: lost vs
    /// surviving sinks, the first `K` survivor coordinates (alive
    /// sources keep their input data even when their computed state is
    /// tainted; surviving sinks contribute their coded outputs), and the
    /// [`Recovery`] operator when anything was lost.
    fn plan_repair(&self, layout: &Layout, fault: &DegradedReport) -> anyhow::Result<Repair> {
        let (k, r) = (layout.k, layout.r);
        let (surviving_sinks, lost_sinks): (Vec<usize>, Vec<usize>) =
            (0..r).partition(|&s| fault.survives(layout.sink(s)));
        if lost_sinks.is_empty() {
            return Ok(Repair {
                surviving_sinks,
                lost_sinks,
                positions: Vec::new(),
                op: None,
            });
        }
        let mut candidates: Vec<usize> = (0..k)
            .filter(|&kk| fault.holds_data(layout.source(kk)))
            .collect();
        candidates.extend(surviving_sinks.iter().map(|&s| k + s));
        // Rank-revealing selection: for MDS codes this keeps the first
        // K candidates verbatim; for arbitrary parity it skips
        // dependent coordinates so a full-rank survivor set is never
        // spuriously rejected.
        let positions = independent_positions(&self.field, &self.parity, &candidates);
        if positions.len() != k {
            return Err(anyhow::Error::new(RecoveryShortfall {
                independent: positions.len(),
                survivors: candidates.len(),
                k,
                crashed: fault.crashed.len(),
                tainted: fault.tainted.len(),
            }));
        }
        let op = Recovery::plan(
            &self.field,
            self.code.as_ref(),
            &self.parity,
            &positions,
            &lost_sinks,
        )?;
        Ok(Repair {
            surviving_sinks,
            lost_sinks,
            positions,
            op: Some(op),
        })
    }

    /// Assemble one job's full `R` coded rows: surviving sink packets
    /// verbatim from `outputs`, lost sinks reconstructed from the
    /// survivor coordinates (`x` rows for sources, `outputs` for sinks).
    fn apply_repair(
        &self,
        repair: &Repair,
        layout: &Layout,
        x: &[Packet],
        outputs: &Outputs,
    ) -> anyhow::Result<Vec<Packet>> {
        let k = layout.k;
        let sink_pkt = |s: usize| {
            outputs
                .get(&layout.sink(s))
                .ok_or_else(|| anyhow::anyhow!("surviving sink {s} missing from outputs"))
        };
        let mut coded: Vec<Option<Packet>> = vec![None; layout.r];
        for &s in &repair.surviving_sinks {
            coded[s] = Some(sink_pkt(s)?.clone());
        }
        if let Some(op) = &repair.op {
            let coords: Vec<&[u64]> = repair
                .positions
                .iter()
                .map(|&pos| {
                    if pos < k {
                        Ok(x[pos].as_slice())
                    } else {
                        sink_pkt(pos - k).map(|p| p.as_slice())
                    }
                })
                .collect::<anyhow::Result<_>>()?;
            let repaired = op.lost_outputs(&self.field, &coords);
            for (&s, pkt) in repair.lost_sinks.iter().zip(repaired.into_packets()) {
                coded[s] = Some(pkt);
            }
        }
        Ok(coded
            .into_iter()
            .map(|p| p.expect("every sink surviving or repaired"))
            .collect())
    }

    // ------------------------------------------------------------------
    // Pre-0.4 entry points — thin shims over `run`/`encode`, kept one
    // release. Nothing in-tree calls them (pinned by clippy's
    // `deprecated` lint passing with them in place).
    // ------------------------------------------------------------------

    /// Deprecated alias for `run(&ExecOptions::cached(cache))`.
    #[deprecated(since = "0.4.0", note = "use `run(&ExecOptions::cached(cache))`")]
    pub fn run_cached(&self, cache: &PlanCache) -> anyhow::Result<JobReport> {
        self.run(&ExecOptions::cached(cache))
            .map_err(Error::into_inner)
    }

    /// Deprecated alias for `encode` with a one-job batch.
    #[deprecated(since = "0.4.0", note = "use `encode(cache, &[x], &ExecOptions::cached(cache))`")]
    pub fn encode_cached(&self, cache: &PlanCache, x: &[Packet]) -> anyhow::Result<Vec<Packet>> {
        let mut out = self
            .encode(cache, &[x], &ExecOptions::cached(cache))
            .map_err(Error::into_inner)?;
        Ok(out.coded.pop().expect("one job in, one out"))
    }

    /// Deprecated alias for `encode`.
    #[deprecated(since = "0.4.0", note = "use `encode(cache, jobs, &ExecOptions::cached(cache))`")]
    pub fn encode_batch_cached(
        &self,
        cache: &PlanCache,
        jobs: &[&[Packet]],
    ) -> anyhow::Result<Vec<Vec<Packet>>> {
        Ok(self
            .encode(cache, jobs, &ExecOptions::cached(cache))
            .map_err(Error::into_inner)?
            .coded)
    }

    /// Deprecated alias for `run` with live engine + faults.
    #[deprecated(since = "0.4.0", note = "use `run(&ExecOptions::new().faults(spec))`")]
    pub fn run_degraded(&self, faults: &FaultSpec) -> anyhow::Result<DegradedJobReport> {
        let rep = self
            .run(&ExecOptions::new().faults(faults))
            .map_err(Error::into_inner)?;
        DegradedJobReport::from_report(rep)
    }

    /// Deprecated alias for `run` with replay engine + faults.
    #[deprecated(
        since = "0.4.0",
        note = "use `run(&ExecOptions::cached(cache).faults(spec))`"
    )]
    pub fn run_degraded_cached(
        &self,
        cache: &PlanCache,
        faults: &FaultSpec,
    ) -> anyhow::Result<DegradedJobReport> {
        let rep = self
            .run(&ExecOptions::cached(cache).faults(faults))
            .map_err(Error::into_inner)?;
        DegradedJobReport::from_report(rep)
    }

    /// Deprecated alias for `encode` with faults.
    #[deprecated(
        since = "0.4.0",
        note = "use `encode(cache, jobs, &ExecOptions::cached(cache).faults(spec))`"
    )]
    pub fn encode_degraded_batch_cached(
        &self,
        cache: &PlanCache,
        jobs: &[&[Packet]],
        faults: &FaultSpec,
    ) -> anyhow::Result<(Vec<Vec<Packet>>, RecoveryStats)> {
        let out = self
            .encode(cache, jobs, &ExecOptions::cached(cache).faults(faults))
            .map_err(Error::into_inner)?;
        let stats = out.recovery.expect("degraded batch carries recovery stats");
        Ok((out.coded, stats))
    }
}

/// The outcome of one degraded job in the pre-0.4 shape — returned by
/// the deprecated `run_degraded*` shims; new code reads
/// [`JobReport::degraded`] instead.
#[derive(Clone, Debug)]
pub struct DegradedJobReport {
    pub choice: PlanChoice,
    pub layout: Layout,
    /// Delivered traffic (`C1` counts every scheduled round; the rest
    /// counts surviving messages only).
    pub sim: SimReport,
    /// Fault directives in the spec (crashes + links + erasures).
    pub faults_injected: u64,
    pub crashed: Vec<ProcId>,
    /// Sink indices whose outputs survived untainted.
    pub surviving_sinks: Vec<usize>,
    /// Sink indices reconstructed from survivors.
    pub lost_sinks: Vec<usize>,
    pub outputs_recovered: usize,
    /// Wall time of the recovery pass (operator build + lincombs).
    pub recovery_wall: Duration,
    pub verified: Option<bool>,
    pub wall: Duration,
    /// All `R` coded rows in sink order.
    pub coded: Vec<Packet>,
}

impl DegradedJobReport {
    fn from_report(rep: JobReport) -> anyhow::Result<DegradedJobReport> {
        let d = rep
            .degraded
            .ok_or_else(|| anyhow::anyhow!("run was not degraded"))?;
        Ok(DegradedJobReport {
            choice: rep.choice,
            layout: rep.layout,
            sim: rep.sim,
            faults_injected: d.faults_injected,
            crashed: d.crashed,
            surviving_sinks: d.surviving_sinks,
            lost_sinks: d.lost_sinks,
            outputs_recovered: d.outputs_recovered,
            recovery_wall: d.recovery_wall,
            verified: rep.verified,
            wall: rep.wall,
            coded: d.coded,
        })
    }
}

/// Aggregate stats of one degraded batch serve (the service metrics
/// source).
#[derive(Clone, Copy, Debug)]
pub struct RecoveryStats {
    /// Fault directives honored, per job in the batch.
    pub faults_injected: u64,
    /// Sink outputs lost per job (the failure pattern is shape-level).
    pub outputs_lost: usize,
    /// Sink outputs reconstructed across the whole batch.
    pub outputs_recovered: u64,
    /// Wall time of the recovery pass (operator build + lincombs, whole
    /// batch).
    pub recovery_wall: Duration,
    /// Transient recv/barrier retries absorbed by the mesh (`Peer`
    /// engine only; the simulator engines report zero).
    pub peer_retries: u64,
    /// Rank-rounds that needed at least one retry (`Peer` engine only).
    pub peer_rounds_delayed: u64,
    /// Dead peers detected on the wire, summed over the batch (`Peer`
    /// engine only).
    pub peer_crashes_detected: u64,
}

/// One failure pattern's repair strategy: which sinks are lost, which
/// `K` survivor coordinates feed the [`Recovery`] operator. Built once
/// per (shape, fault) pair, applied per job.
struct Repair {
    surviving_sinks: Vec<usize>,
    lost_sinks: Vec<usize>,
    /// `K` independent survivor coordinate positions (first-fit over
    /// sources ascending, then surviving sinks ascending), when
    /// anything needs recovering.
    positions: Vec<usize>,
    op: Option<Recovery>,
}

/// Pull the `R` sink packets out of a replay's output map, in sink
/// order — the one sink-extraction path shared by every cached
/// execution route.
fn take_sinks(layout: &Layout, outputs: &mut Outputs) -> anyhow::Result<Vec<Packet>> {
    (0..layout.r)
        .map(|r| {
            let pid = layout.sink(r);
            outputs
                .remove(&pid)
                .ok_or_else(|| anyhow::anyhow!("replay missing sink {pid}"))
        })
        .collect()
}

/// Build a structured GRS code. Radix 2 stays the default whenever it
/// buys *any* DFT structure for the Theorem-6/8 block size (`Z = 2^H >
/// 1`) — existing prime-field shapes keep their exact historical
/// designs. Only when radix 2 is structureless (e.g. `GF(2^8)`, where
/// `q−1 = 255` is odd, or odd block sizes) do we fall through to the
/// radix with the largest `Z`.
fn build_structured(f: &AnyField, k: usize, r: usize) -> anyhow::Result<GrsCode> {
    let block = if k >= r { r } else { k } as u64;
    if StructuredPoints::max_h(f, block, 2) >= 1 {
        return GrsCode::structured(f, k, r, 2);
    }
    let mut best = (2u64, 1u64);
    for p_base in [3u64, 5, 7] {
        let z = ipow(p_base, StructuredPoints::max_h(f, block, p_base));
        if z > best.1 {
            best = (p_base, z);
        }
    }
    GrsCode::structured(f, k, r, best.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::framework::AlgoRequest;

    #[test]
    fn synthetic_job_runs_and_verifies() {
        let cfg = JobConfig {
            k: 16,
            r: 4,
            w: 8,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg).unwrap();
        let rep = job.run(&ExecOptions::new()).unwrap();
        assert_eq!(rep.verified, Some(true));
        // Auto is cost-aware: for this small code the universal path wins
        // (Remark 8); forcing the specific path still verifies.
        assert_eq!(rep.choice, PlanChoice::Universal);
        assert!(rep.sim.c1 > 0);
        let mut cfg2 = job.config.clone();
        cfg2.algorithm = crate::framework::AlgoRequest::RsSpecific;
        let rep2 = EncodeJob::synthetic(cfg2)
            .unwrap()
            .run(&ExecOptions::new())
            .unwrap();
        assert_eq!(rep2.verified, Some(true));
        assert_eq!(rep2.choice, PlanChoice::RsSpecific);
    }

    #[test]
    fn engine_parses_and_displays() {
        for (s, e) in [
            ("live", Engine::Live),
            ("replay", Engine::Replay),
            ("peer-channel", Engine::Peer(TransportKind::Channel)),
            ("peer-shmem", Engine::Peer(TransportKind::SharedMem)),
            ("peer-tcp", Engine::Peer(TransportKind::Tcp)),
        ] {
            assert_eq!(s.parse::<Engine>().unwrap(), e);
            assert_eq!(e.to_string().parse::<Engine>().unwrap(), e);
        }
        assert!("carrier-pigeon".parse::<Engine>().is_err());
    }

    #[test]
    fn freivalds_verify_mode_accepts_simulated_encode() {
        let cfg = JobConfig {
            k: 16,
            r: 4,
            w: 8,
            verify: crate::coordinator::config::VerifyMode::Freivalds,
            ..JobConfig::default()
        };
        let rep = EncodeJob::synthetic(cfg)
            .unwrap()
            .run(&ExecOptions::new())
            .unwrap();
        assert_eq!(rep.verified, Some(true));
    }

    #[test]
    fn universal_on_random_matrix() {
        let cfg = JobConfig {
            k: 10,
            r: 14,
            w: 2,
            code: CodeKind::Random,
            algorithm: AlgoRequest::Universal,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg).unwrap();
        let rep = job.run(&ExecOptions::new()).unwrap();
        assert_eq!(rep.verified, Some(true));
        assert_eq!(rep.choice, PlanChoice::Universal);
    }

    #[test]
    fn replay_engine_matches_live_run_for_every_algorithm() {
        let cache = crate::coordinator::PlanCache::new();
        for algo in [
            AlgoRequest::Auto,
            AlgoRequest::Universal,
            AlgoRequest::RsSpecific,
            AlgoRequest::MultiReduce,
            AlgoRequest::Direct,
        ] {
            let cfg = JobConfig {
                k: 16,
                r: 4,
                w: 8,
                algorithm: algo,
                ..JobConfig::default()
            };
            let job = EncodeJob::synthetic(cfg).unwrap();
            let live = job.run(&ExecOptions::new()).unwrap();
            let cached = job.run(&ExecOptions::cached(&cache)).unwrap();
            assert_eq!(cached.verified, Some(true), "{algo:?}");
            assert_eq!(cached.choice, live.choice, "{algo:?}");
            // Identical (C1, C2) and full report — statics, not re-runs.
            assert_eq!(cached.sim, live.sim, "{algo:?}");
            assert_eq!(cached.cost, live.cost, "{algo:?}");
        }
        // Auto resolved to Universal here (Remark 8), so five requests
        // hit four distinct plans: one hit, four misses.
        assert_eq!(cache.len(), 4);
        assert_eq!(cache.stats(), (1, 4));
    }

    #[test]
    fn peer_engine_matches_replay_bit_for_bit() {
        let cache = crate::coordinator::PlanCache::new();
        let cfg = JobConfig {
            k: 8,
            r: 4,
            w: 3,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg).unwrap();
        let replayed = job.run(&ExecOptions::cached(&cache)).unwrap();
        let peer = job
            .run(&ExecOptions::cached(&cache).engine(Engine::Peer(TransportKind::Channel)))
            .unwrap();
        assert_eq!(peer.verified, Some(true));
        // Measured traffic equals the simulator's static accounting.
        assert_eq!(peer.sim, replayed.sim);
        assert_eq!(peer.choice, replayed.choice);
    }

    #[test]
    fn peer_engine_heals_fault_injection() {
        let cache = crate::coordinator::PlanCache::new();
        let cfg = JobConfig {
            k: 16,
            r: 4,
            w: 6,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg).unwrap();
        let opts = ExecOptions::cached(&cache);
        let healthy = job
            .encode(&cache, &[job.inputs.as_slice()], &opts)
            .unwrap()
            .coded
            .remove(0);
        // Lose two sinks and one source after the run completed.
        let faults = crate::net::FaultSpec::new()
            .crash_after(16)
            .crash_after(18)
            .crash_after(3);
        let replayed = job.run(&opts.faults(&faults)).unwrap();
        let peer = job
            .run(
                &opts
                    .faults(&faults)
                    .engine(Engine::Peer(TransportKind::Channel)),
            )
            .unwrap();
        let d = peer.degraded.as_ref().expect("degraded info");
        assert_eq!(d.coded, healthy, "peer repair ≡ healthy");
        assert_eq!(peer.verified, Some(true));
        assert_eq!(d.lost_sinks, vec![0, 2]);
        assert_eq!(d.outputs_recovered, 2);
        // The peer mesh's receive-side observations reproduce the plan
        // analysis: the delivered report matches the replay engine's.
        assert_eq!(peer.sim, replayed.sim);
        let rd = replayed.degraded.as_ref().unwrap();
        assert_eq!(d.crashed, rd.crashed);
        assert_eq!(d.peer_retries, 0, "post-run crashes never stall a round");
        assert_eq!(d.peer_crashes_detected, 0, "post-run deaths leave no wire trace");
    }

    #[test]
    fn peer_degraded_encode_matches_healthy_batch() {
        let cache = crate::coordinator::PlanCache::new();
        let cfg = JobConfig {
            k: 8,
            r: 4,
            w: 3,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg.clone()).unwrap();
        let f = job.field.clone();
        use crate::gf::Field;
        let mut rng = crate::util::Rng::new(29);
        let jobs: Vec<Vec<Packet>> = (0..3)
            .map(|_| {
                (0..cfg.k)
                    .map(|_| (0..cfg.w).map(|_| rng.below(f.order())).collect())
                    .collect()
            })
            .collect();
        let refs: Vec<&[Packet]> = jobs.iter().map(|x| x.as_slice()).collect();
        let opts = ExecOptions::cached(&cache);
        let healthy = job.encode(&cache, &refs, &opts).unwrap().coded;
        // One sink dies after encoding: its output is rebuilt per job.
        let faults = crate::net::FaultSpec::new().crash_after(8);
        let out = job
            .encode(
                &cache,
                &refs,
                &opts
                    .faults(&faults)
                    .engine(Engine::Peer(TransportKind::Channel)),
            )
            .unwrap();
        assert_eq!(out.coded, healthy, "peer degraded batch ≡ healthy batch");
        let stats = out.recovery.expect("recovery stats");
        assert_eq!(stats.outputs_lost, 1);
        assert_eq!(stats.outputs_recovered, jobs.len() as u64);
    }

    #[test]
    fn one_cached_plan_serves_every_width() {
        let cache = crate::coordinator::PlanCache::new();
        let cfg = JobConfig {
            k: 8,
            r: 4,
            w: 5,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg.clone()).unwrap();
        job.run(&ExecOptions::cached(&cache)).unwrap();
        let f = job.field.clone();
        use crate::gf::Field;
        let mut rng = crate::util::Rng::new(3);
        let opts = ExecOptions::cached(&cache);
        for w in [1usize, 5, 17] {
            let x: Vec<Packet> = (0..cfg.k)
                .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
                .collect();
            let y = job.encode(&cache, &[&x], &opts).unwrap().coded.remove(0);
            assert!(crate::coordinator::verify::native(&f, &job.parity, &x, &y), "w={w}");
        }
        // One shape, one compile — widths share the plan.
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.stats().1, 1);
    }

    #[test]
    fn batch_encode_matches_per_job_encode_bit_for_bit() {
        let cache = crate::coordinator::PlanCache::new();
        let cfg = JobConfig {
            k: 8,
            r: 4,
            w: 3,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg.clone()).unwrap();
        let f = job.field.clone();
        use crate::gf::Field;
        let mut rng = crate::util::Rng::new(11);
        let jobs: Vec<Vec<Packet>> = (0..5)
            .map(|_| {
                (0..cfg.k)
                    .map(|_| (0..cfg.w).map(|_| rng.below(f.order())).collect())
                    .collect()
            })
            .collect();
        let refs: Vec<&[Packet]> = jobs.iter().map(|x| x.as_slice()).collect();
        let opts = ExecOptions::cached(&cache);
        let batched = job.encode(&cache, &refs, &opts).unwrap().coded;
        assert_eq!(batched.len(), jobs.len());
        for (x, y) in jobs.iter().zip(&batched) {
            let single = job
                .encode(&cache, &[x.as_slice()], &opts)
                .unwrap()
                .coded
                .remove(0);
            assert_eq!(y, &single);
            assert!(verify::native(&f, &job.parity, x, y));
        }
        // One shape: the whole batch plus the singles hit one compile.
        assert_eq!(cache.stats().1, 1);
    }

    #[test]
    fn degraded_run_repairs_lost_sinks_bit_identically() {
        let cache = crate::coordinator::PlanCache::new();
        let cfg = JobConfig {
            k: 16,
            r: 4,
            w: 6,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg).unwrap();
        let opts = ExecOptions::cached(&cache);
        let healthy = job
            .encode(&cache, &[job.inputs.as_slice()], &opts)
            .unwrap()
            .coded
            .remove(0);
        // Lose two sinks and one source after the run completed.
        let faults = crate::net::FaultSpec::new()
            .crash_after(16)
            .crash_after(18)
            .crash_after(3);
        let live = job.run(&ExecOptions::new().faults(&faults)).unwrap();
        let live_d = live.degraded.as_ref().expect("degraded info");
        assert_eq!(live_d.coded, healthy, "live repair ≡ healthy");
        assert_eq!(live.verified, Some(true));
        assert_eq!(live_d.lost_sinks, vec![0, 2]);
        assert_eq!(live_d.surviving_sinks, vec![1, 3]);
        assert_eq!(live_d.outputs_recovered, 2);
        assert_eq!(live_d.faults_injected, 3);
        let cached = job
            .run(&ExecOptions::cached(&cache).faults(&faults))
            .unwrap();
        let cached_d = cached.degraded.as_ref().expect("degraded info");
        assert_eq!(cached_d.coded, healthy, "cached repair ≡ healthy");
        assert_eq!(cached.sim, live.sim, "delivered stats agree live vs replay");
        assert_eq!(cached_d.lost_sinks, live_d.lost_sinks);
    }

    #[test]
    fn degraded_batch_matches_healthy_batch() {
        use crate::net::POST_RUN;
        let cache = crate::coordinator::PlanCache::new();
        let cfg = JobConfig {
            k: 8,
            r: 4,
            w: 3,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg.clone()).unwrap();
        let f = job.field.clone();
        use crate::gf::Field;
        let mut rng = crate::util::Rng::new(13);
        let jobs: Vec<Vec<Packet>> = (0..4)
            .map(|_| {
                (0..cfg.k)
                    .map(|_| (0..cfg.w).map(|_| rng.below(f.order())).collect())
                    .collect()
            })
            .collect();
        let refs: Vec<&[Packet]> = jobs.iter().map(|x| x.as_slice()).collect();
        let opts = ExecOptions::cached(&cache);
        let healthy = job.encode(&cache, &refs, &opts).unwrap().coded;
        let procs: Vec<usize> = (0..cfg.k + cfg.r).collect();
        let faults = crate::net::FaultSpec::random_crashes(7, &procs, cfg.r, POST_RUN);
        let out = job
            .encode(&cache, &refs, &opts.faults(&faults))
            .unwrap();
        assert_eq!(out.coded, healthy, "degraded batch ≡ healthy batch");
        let stats = out.recovery.expect("recovery stats");
        assert_eq!(stats.faults_injected, cfg.r as u64);
        assert_eq!(
            stats.outputs_recovered,
            (stats.outputs_lost * jobs.len()) as u64
        );
    }

    #[test]
    fn unrecoverable_pattern_is_a_typed_error() {
        // Crash 3 of N=6 post-run: K=4 > 3 surviving coordinates.
        let cfg = JobConfig {
            k: 4,
            r: 2,
            w: 2,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg).unwrap();
        let faults = crate::net::FaultSpec::new()
            .crash_after(0)
            .crash_after(1)
            .crash_after(4);
        let err = job.run(&ExecOptions::new().faults(&faults)).unwrap_err();
        assert!(matches!(err, Error::Unrecoverable(_)), "{err}");
        assert!(err.to_string().contains("unrecoverable"), "{err}");
        // The typed marker is reachable through the chain.
        assert!(err
            .inner()
            .chain()
            .any(|c| c.downcast_ref::<RecoveryShortfall>().is_some()));
    }

    #[test]
    fn structured_codes_pick_a_usable_radix_per_field() {
        // GF(2^8): q−1 = 255 is odd — radix 3 must be chosen and the
        // specific path must still verify.
        let cfg = JobConfig {
            field: "gf2e:8".into(),
            k: 6,
            r: 3,
            w: 4,
            algorithm: crate::framework::AlgoRequest::RsSpecific,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg).unwrap();
        let code = job.code.as_ref().unwrap();
        assert!(code.alpha_designs.iter().all(|d| d.p_base == 3 && d.h >= 1));
        let rep = job.run(&ExecOptions::new()).unwrap();
        assert_eq!(rep.verified, Some(true));
        assert_eq!(rep.choice, PlanChoice::RsSpecific);
    }

    #[test]
    fn json_report_is_parseable_shape() {
        let cfg = JobConfig {
            k: 8,
            r: 4,
            w: 2,
            ..JobConfig::default()
        };
        let rep = EncodeJob::synthetic(cfg)
            .unwrap()
            .run(&ExecOptions::new())
            .unwrap();
        let j = rep.to_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"c1\":"));
    }
}

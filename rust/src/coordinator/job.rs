//! One decentralized-encoding job: plan → simulate → verify → report.
//!
//! Two execution paths share the verification and reporting logic:
//!
//! * [`EncodeJob::run`] — live: build the collective, step it on the
//!   round engine, measure `C1`/`C2`.
//! * [`EncodeJob::run_cached`] — replay: fetch (or compile) the shape's
//!   [`CompiledPlan`](crate::framework::CompiledPlan) from a
//!   [`PlanCache`] and replay it — bit-identical outputs and the exact
//!   same report, with zero control-flow rederivation per request.

use super::config::{CodeKind, JobConfig, VerifyMode};
use super::plan_cache::{PlanCache, PlanKey};
use super::verify;
use crate::codes::structured::independent_positions;
use crate::codes::{GrsCode, Recovery, StructuredPoints};
use crate::framework::{systematic::Layout, CompiledPlan, PlanChoice, PlannedJob};
use crate::gf::{AnyField, Field, Mat};
use crate::net::{run, DegradedReport, FaultSpec, Outputs, Packet, ProcId, Sim, SimReport};
use crate::util::{ipow, Rng};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

/// The outcome of one job, with every paper metric.
#[derive(Clone, Debug)]
pub struct JobReport {
    pub choice: PlanChoice,
    pub layout: Layout,
    pub sim: SimReport,
    /// `C = α·C1 + β⌈log2 q⌉·C2`.
    pub cost: f64,
    pub verified: Option<bool>,
    pub wall: std::time::Duration,
}

impl JobReport {
    pub fn to_json(&self) -> String {
        format!(
            concat!(
                "{{\"algorithm\":\"{}\",\"k\":{},\"r\":{},\"c1\":{},\"c2\":{},",
                "\"messages\":{},\"bandwidth\":{},\"cost\":{},\"verified\":{},",
                "\"wall_us\":{}}}"
            ),
            self.choice,
            self.layout.k,
            self.layout.r,
            self.sim.c1,
            self.sim.c2,
            self.sim.messages,
            self.sim.bandwidth,
            self.cost,
            self.verified.map_or("null".into(), |v| v.to_string()),
            self.wall.as_micros(),
        )
    }
}

impl std::fmt::Display for JobReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "algorithm: {:<12} K={} R={}",
            self.choice.to_string(),
            self.layout.k,
            self.layout.r
        )?;
        writeln!(
            f,
            "C1 = {} rounds, C2 = {} elems (messages {}, bandwidth {} elems)",
            self.sim.c1, self.sim.c2, self.sim.messages, self.sim.bandwidth
        )?;
        writeln!(f, "C  = {:.3} (model cost)", self.cost)?;
        match self.verified {
            Some(true) => writeln!(f, "verification: OK")?,
            Some(false) => writeln!(f, "verification: FAILED")?,
            None => writeln!(f, "verification: skipped")?,
        }
        write!(f, "wall: {:?}", self.wall)
    }
}

/// A planned job with its data, ready to execute.
pub struct EncodeJob {
    pub config: JobConfig,
    pub field: AnyField,
    pub code: Option<GrsCode>,
    pub parity: Arc<Mat>,
    pub inputs: Vec<Packet>,
    /// Memoised [`plan_key`](EncodeJob::plan_key) — the serving hot path
    /// derives the key once per job, not per request. Mutating `config`
    /// or `parity` after the first cached call is not supported.
    plan_key_memo: OnceLock<PlanKey>,
}

impl EncodeJob {
    /// Build a job with synthetic (seeded) payload data.
    pub fn synthetic(config: JobConfig) -> anyhow::Result<Self> {
        let field = config.any_field()?;
        let (k, r) = (config.k, config.r);
        let code = match config.code {
            CodeKind::RsStructured => Some(build_structured(&field, k, r)?),
            CodeKind::RsPlain => Some(GrsCode::plain(
                &field,
                (1..=k as u64).collect(),
                (k as u64 + 1..=(k + r) as u64).collect(),
            )?),
            CodeKind::Lagrange => {
                // Systematic Lagrange = GRS with u/v from the Lagrange
                // normalisation (u = v = 1 — Remark 9).
                Some(GrsCode::plain(
                    &field,
                    (1..=k as u64).collect(),
                    (k as u64 + 1..=(k + r) as u64).collect(),
                )?)
            }
            CodeKind::RsNtt => {
                // NTT-friendly geometry (roots + generator coset) with
                // seeded non-unit multipliers — the general GRS flavor
                // of the transform backend. A field without the two-adic
                // root tower is a proper construction error.
                let mut mrng = Rng::new(config.seed ^ 0x17A7);
                let u: Vec<u64> = (0..k).map(|_| mrng.below(field.order() - 1) + 1).collect();
                let v: Vec<u64> = (0..r).map(|_| mrng.below(field.order() - 1) + 1).collect();
                Some(GrsCode::ntt_friendly(&field, k, r, u, v)?)
            }
            CodeKind::Random => None,
        };
        let parity: Arc<Mat> = match &code {
            Some(c) => Arc::new(c.parity_matrix(&field)),
            None => Arc::new(Mat::random(&field, k, r, config.seed ^ 0xA5A5)),
        };
        let mut rng = Rng::new(config.seed);
        let inputs: Vec<Packet> = (0..k)
            .map(|_| (0..config.w).map(|_| rng.below(field.order())).collect())
            .collect();
        Ok(EncodeJob {
            config,
            field,
            code,
            parity,
            inputs,
            plan_key_memo: OnceLock::new(),
        })
    }

    /// Verify coded sink packets per the configured mode.
    fn verify_coded(&self, coded: &[Packet]) -> anyhow::Result<Option<bool>> {
        Ok(match self.config.verify {
            VerifyMode::Off => None,
            VerifyMode::Native => Some(verify::native(
                &self.field,
                &self.parity,
                &self.inputs,
                coded,
            )),
            VerifyMode::Freivalds => Some(verify::freivalds(
                &self.field,
                &self.parity,
                &self.inputs,
                coded,
                self.config.seed ^ 0xF5EE,
                2,
            )),
            VerifyMode::Pjrt => Some(verify::pjrt(
                &self.config.artifacts_dir,
                &self.field,
                &self.parity,
                &self.inputs,
                coded,
            )?),
        })
    }

    /// Plan, simulate (live stepping), verify.
    pub fn run(&self) -> anyhow::Result<JobReport> {
        let t0 = Instant::now();
        let mut pl: PlannedJob = crate::framework::plan_with_model(
            &self.field,
            self.code.as_ref(),
            Some(self.parity.clone()),
            self.inputs.clone(),
            self.config.ports,
            self.config.algorithm,
            Some(self.config.cost_model()?),
        )?;
        let mut sim = Sim::new(self.config.ports);
        let sim_report = run(&mut sim, pl.job.as_mut())?;
        let outs = pl.job.outputs();
        let coded: Vec<Packet> = (0..pl.layout.r)
            .map(|r| outs[&pl.layout.sink(r)].clone())
            .collect();
        let verified = self.verify_coded(&coded)?;
        let cost = sim_report.cost(&self.config.cost_model()?);
        Ok(JobReport {
            choice: pl.choice,
            layout: pl.layout,
            sim: sim_report,
            cost,
            verified,
            wall: t0.elapsed(),
        })
    }

    /// The cache key of this job's compiled plan: the shape, a
    /// fingerprint of the parity matrix actually encoded against, and
    /// the *resolved* algorithm choice (width-independent — see
    /// [`PlanCache`]'s module docs on why `W` is absent). Derived once
    /// per job and memoised — the per-request path pays a clone, not a
    /// re-resolution.
    pub fn plan_key(&self) -> anyhow::Result<PlanKey> {
        if let Some(key) = self.plan_key_memo.get() {
            return Ok(key.clone());
        }
        let choice = crate::framework::resolve_choice(
            &self.field,
            self.code.as_ref(),
            self.config.w,
            self.config.ports,
            self.config.algorithm,
            Some(self.config.cost_model()?),
        )?;
        let key = PlanKey {
            field: self.config.field.clone(),
            k: self.config.k,
            r: self.config.r,
            ports: self.config.ports,
            code: self.config.code,
            seed: self.config.seed,
            parity_fp: super::plan_cache::parity_fingerprint(&self.parity),
            choice,
            isa: self.config.isa,
        };
        let _ = self.plan_key_memo.set(key.clone());
        Ok(key)
    }

    /// Fetch this shape's compiled plan from `cache`, compiling on miss.
    pub fn compiled(&self, cache: &PlanCache) -> anyhow::Result<Arc<CompiledPlan>> {
        let key = self.plan_key()?;
        cache.get_or_compile(&key, || {
            let compiled = crate::framework::compile_plan(
                &self.field,
                self.code.as_ref(),
                Some(self.parity.clone()),
                self.config.ports,
                self.config.w,
                self.config.algorithm,
                Some(self.config.cost_model()?),
            )?;
            // Apply the job's explicit ISA request (clamped to what this
            // host can execute); `None` keeps the process-default tier
            // `compile_plan` already resolved.
            Ok(match self.config.isa {
                Some(req) => compiled.with_isa(crate::gf::IsaTier::resolve(req)),
                None => compiled,
            })
        })
    }

    /// Warm `cache` with this shape's compiled plan. Returns `true`
    /// when the plan was compiled fresh, `false` when the shape was
    /// already cached — the [`PlanCache::warmup`] building block.
    pub fn warm(&self, cache: &PlanCache) -> anyhow::Result<bool> {
        let key = self.plan_key()?;
        if cache.contains(&key) {
            return Ok(false);
        }
        self.compiled(cache)?;
        Ok(true)
    }

    /// Replay-encode arbitrary payload rows (any width) through the
    /// shape's cached *optimized* plan — the serving-path hot loop: no
    /// planning, no round stepping, no routing; just the flattened
    /// output rows (`net::exec::replay_opt`), bit-identical to raw-plan
    /// replay and to live stepping.
    pub fn encode_cached(&self, cache: &PlanCache, x: &[Packet]) -> anyhow::Result<Vec<Packet>> {
        anyhow::ensure!(x.len() == self.config.k, "need K = {} rows", self.config.k);
        // Non-canonical elements must be a proper Err on the single-job
        // path too (the batched engines validate before packing; the
        // scalar GF(2^w) kernels would panic on a table lookup instead
        // — killing a service worker).
        let q = self.field.order();
        for row in x {
            if let Some(&v) = row.iter().find(|&&v| v >= q) {
                anyhow::bail!("payload element {v} is not canonical (field order {q})");
            }
        }
        let compiled = self.compiled(cache)?;
        let mut replay = crate::net::exec::replay_opt(&compiled.opt, &self.field, x)?;
        take_sinks(&compiled.layout, &mut replay.outputs)
    }

    /// Batch-encode `B` same-width payload sets in **one columnar pass**
    /// over the shape's cached optimized plan — the micro-batching
    /// service path. The pass runs over packed narrow-lane storage: the
    /// symbol layout was selected from the field's `⌈log2 q⌉` when the
    /// plan compiled (`CompiledPlan::kernels`), so per job shape the
    /// batch streams `u8`/`u16`/`u32` lanes with zero per-element field
    /// dispatch (`net::exec::replay_batch_kernels`). Returns the `R`
    /// coded rows per job, in job order, bit-identical to
    /// [`encode_cached`](EncodeJob::encode_cached) per job.
    pub fn encode_batch_cached(
        &self,
        cache: &PlanCache,
        jobs: &[&[Packet]],
    ) -> anyhow::Result<Vec<Vec<Packet>>> {
        // A batch of one skips the arena pack/unpack entirely — the
        // common low-load case when the micro-batch window expires with
        // a single request.
        if let [x] = jobs {
            return Ok(vec![self.encode_cached(cache, x)?]);
        }
        let compiled = self.compiled(cache)?;
        let replays = compiled.replay_batch(jobs)?;
        replays
            .into_iter()
            .map(|mut rep| take_sinks(&compiled.layout, &mut rep.outputs))
            .collect()
    }

    /// Plan-cache execution path: compile-or-fetch, replay, verify.
    /// Produces bit-identical coded packets and the exact `C1`/`C2`
    /// report of [`run`](EncodeJob::run), without re-deriving any
    /// control flow when the cache hits.
    pub fn run_cached(&self, cache: &PlanCache) -> anyhow::Result<JobReport> {
        let t0 = Instant::now();
        let compiled = self.compiled(cache)?;
        let mut replay = crate::net::exec::replay_opt(&compiled.opt, &self.field, &self.inputs)?;
        let coded = take_sinks(&compiled.layout, &mut replay.outputs)?;
        let verified = self.verify_coded(&coded)?;
        let cost = replay.report.cost(&self.config.cost_model()?);
        Ok(JobReport {
            choice: compiled.choice,
            layout: compiled.layout,
            sim: replay.report,
            cost,
            verified,
            wall: t0.elapsed(),
        })
    }

    /// Live fault-injected execution: step the planned collective under
    /// `faults` (`net::run_degraded`), then **repair** — reconstruct
    /// every lost sink output from any `K` surviving coordinates
    /// (`codes::recovery`) instead of re-encoding. The returned `coded`
    /// rows are bit-identical to a healthy run whenever at most `R`
    /// coordinates are lost; an unrecoverable pattern (fewer than `K`
    /// survivors) is a proper error naming the shortfall.
    pub fn run_degraded(&self, faults: &FaultSpec) -> anyhow::Result<DegradedJobReport> {
        let t0 = Instant::now();
        let mut pl: PlannedJob = crate::framework::plan_with_model(
            &self.field,
            self.code.as_ref(),
            Some(self.parity.clone()),
            self.inputs.clone(),
            self.config.ports,
            self.config.algorithm,
            Some(self.config.cost_model()?),
        )?;
        let mut sim = Sim::new(self.config.ports);
        let deg = crate::net::run_degraded(&mut sim, pl.job.as_mut(), faults)?;
        self.finish_degraded(pl.choice, pl.layout, deg.fault, &deg.outputs, faults, t0)
    }

    /// The replay-path twin of [`run_degraded`](EncodeJob::run_degraded):
    /// fetch the shape's compiled plan, analyze the failure pattern on
    /// the plan's schedule, evaluate only the surviving output rows
    /// through the batched columnar engine, and repair the rest.
    /// Bit-identical coded rows and failure analysis to the live path.
    pub fn run_degraded_cached(
        &self,
        cache: &PlanCache,
        faults: &FaultSpec,
    ) -> anyhow::Result<DegradedJobReport> {
        let t0 = Instant::now();
        let compiled = self.compiled(cache)?;
        let jobs = [self.inputs.as_slice()];
        let (fault, mut outs) = compiled.replay_degraded_batch(&jobs, faults)?;
        let outputs = outs.pop().expect("one job in, one out");
        self.finish_degraded(compiled.choice, compiled.layout, fault, &outputs, faults, t0)
    }

    /// Batch-serve `B` same-width jobs under one failure pattern: one
    /// taint analysis, one columnar pass over the surviving rows, one
    /// recovery operator applied per job — the degraded serving path of
    /// [`EncodeService::start_degraded`](super::EncodeService::start_degraded).
    /// Every job's `R` rows come back complete and bit-identical to
    /// healthy [`encode_batch_cached`](EncodeJob::encode_batch_cached).
    pub fn encode_degraded_batch_cached(
        &self,
        cache: &PlanCache,
        jobs: &[&[Packet]],
        faults: &FaultSpec,
    ) -> anyhow::Result<(Vec<Vec<Packet>>, RecoveryStats)> {
        let compiled = self.compiled(cache)?;
        let (fault, outs) = compiled.replay_degraded_batch(jobs, faults)?;
        let rt0 = Instant::now();
        let repair = self.plan_repair(&compiled.layout, &fault)?;
        let coded: Vec<Vec<Packet>> = outs
            .iter()
            .zip(jobs)
            .map(|(o, x)| self.apply_repair(&repair, &compiled.layout, x, o))
            .collect::<anyhow::Result<_>>()?;
        let stats = RecoveryStats {
            faults_injected: faults.injected(),
            outputs_lost: repair.lost_sinks.len(),
            outputs_recovered: (repair.lost_sinks.len() * jobs.len()) as u64,
            recovery_wall: rt0.elapsed(),
        };
        Ok((coded, stats))
    }

    /// Shared tail of the degraded paths: plan the repair, assemble the
    /// full coded rows, verify, report.
    fn finish_degraded(
        &self,
        choice: PlanChoice,
        layout: Layout,
        fault: DegradedReport,
        outputs: &Outputs,
        faults: &FaultSpec,
        t0: Instant,
    ) -> anyhow::Result<DegradedJobReport> {
        let rt0 = Instant::now();
        let repair = self.plan_repair(&layout, &fault)?;
        let coded = self.apply_repair(&repair, &layout, &self.inputs, outputs)?;
        let recovery_wall = rt0.elapsed();
        let verified = self.verify_coded(&coded)?;
        Ok(DegradedJobReport {
            choice,
            layout,
            sim: fault.delivered,
            faults_injected: faults.injected(),
            crashed: fault.crashed.iter().copied().collect(),
            outputs_recovered: repair.lost_sinks.len(),
            surviving_sinks: repair.surviving_sinks,
            lost_sinks: repair.lost_sinks,
            recovery_wall,
            verified,
            wall: t0.elapsed(),
            coded,
        })
    }

    /// Build the repair strategy for one failure pattern: lost vs
    /// surviving sinks, the first `K` survivor coordinates (alive
    /// sources keep their input data even when their computed state is
    /// tainted; surviving sinks contribute their coded outputs), and the
    /// [`Recovery`] operator when anything was lost.
    fn plan_repair(&self, layout: &Layout, fault: &DegradedReport) -> anyhow::Result<Repair> {
        let (k, r) = (layout.k, layout.r);
        let (surviving_sinks, lost_sinks): (Vec<usize>, Vec<usize>) =
            (0..r).partition(|&s| fault.survives(layout.sink(s)));
        if lost_sinks.is_empty() {
            return Ok(Repair {
                surviving_sinks,
                lost_sinks,
                positions: Vec::new(),
                op: None,
            });
        }
        let mut candidates: Vec<usize> = (0..k)
            .filter(|&kk| fault.holds_data(layout.source(kk)))
            .collect();
        candidates.extend(surviving_sinks.iter().map(|&s| k + s));
        // Rank-revealing selection: for MDS codes this keeps the first
        // K candidates verbatim; for arbitrary parity it skips
        // dependent coordinates so a full-rank survivor set is never
        // spuriously rejected.
        let positions = independent_positions(&self.field, &self.parity, &candidates);
        anyhow::ensure!(
            positions.len() == k,
            "unrecoverable failure pattern: only {} independent coordinates among the \
             {} survivors, K = {k} needed ({} crashed, {} tainted)",
            positions.len(),
            candidates.len(),
            fault.crashed.len(),
            fault.tainted.len()
        );
        let op = Recovery::plan(
            &self.field,
            self.code.as_ref(),
            &self.parity,
            &positions,
            &lost_sinks,
        )?;
        Ok(Repair {
            surviving_sinks,
            lost_sinks,
            positions,
            op: Some(op),
        })
    }

    /// Assemble one job's full `R` coded rows: surviving sink packets
    /// verbatim from `outputs`, lost sinks reconstructed from the
    /// survivor coordinates (`x` rows for sources, `outputs` for sinks).
    fn apply_repair(
        &self,
        repair: &Repair,
        layout: &Layout,
        x: &[Packet],
        outputs: &Outputs,
    ) -> anyhow::Result<Vec<Packet>> {
        let k = layout.k;
        let sink_pkt = |s: usize| {
            outputs
                .get(&layout.sink(s))
                .ok_or_else(|| anyhow::anyhow!("surviving sink {s} missing from outputs"))
        };
        let mut coded: Vec<Option<Packet>> = vec![None; layout.r];
        for &s in &repair.surviving_sinks {
            coded[s] = Some(sink_pkt(s)?.clone());
        }
        if let Some(op) = &repair.op {
            let coords: Vec<&[u64]> = repair
                .positions
                .iter()
                .map(|&pos| {
                    if pos < k {
                        Ok(x[pos].as_slice())
                    } else {
                        sink_pkt(pos - k).map(|p| p.as_slice())
                    }
                })
                .collect::<anyhow::Result<_>>()?;
            let repaired = op.lost_outputs(&self.field, &coords);
            for (&s, pkt) in repair.lost_sinks.iter().zip(repaired.into_packets()) {
                coded[s] = Some(pkt);
            }
        }
        Ok(coded
            .into_iter()
            .map(|p| p.expect("every sink surviving or repaired"))
            .collect())
    }
}

/// The outcome of one degraded job: delivered-traffic metrics, the
/// failure analysis, and the **full** `R` coded rows — surviving sinks
/// verbatim, lost sinks reconstructed from survivors — bit-identical to
/// a healthy run's.
#[derive(Clone, Debug)]
pub struct DegradedJobReport {
    pub choice: PlanChoice,
    pub layout: Layout,
    /// Delivered traffic (`C1` counts every scheduled round; the rest
    /// counts surviving messages only).
    pub sim: SimReport,
    /// Fault directives in the spec (crashes + links + erasures).
    pub faults_injected: u64,
    pub crashed: Vec<ProcId>,
    /// Sink indices whose outputs survived untainted.
    pub surviving_sinks: Vec<usize>,
    /// Sink indices reconstructed from survivors.
    pub lost_sinks: Vec<usize>,
    pub outputs_recovered: usize,
    /// Wall time of the recovery pass (operator build + lincombs).
    pub recovery_wall: Duration,
    pub verified: Option<bool>,
    pub wall: Duration,
    /// All `R` coded rows in sink order.
    pub coded: Vec<Packet>,
}

/// Aggregate stats of one degraded batch serve (the service metrics
/// source).
#[derive(Clone, Copy, Debug)]
pub struct RecoveryStats {
    /// Fault directives honored, per job in the batch.
    pub faults_injected: u64,
    /// Sink outputs lost per job (the failure pattern is shape-level).
    pub outputs_lost: usize,
    /// Sink outputs reconstructed across the whole batch.
    pub outputs_recovered: u64,
    /// Wall time of the recovery pass (operator build + lincombs, whole
    /// batch).
    pub recovery_wall: Duration,
}

/// One failure pattern's repair strategy: which sinks are lost, which
/// `K` survivor coordinates feed the [`Recovery`] operator. Built once
/// per (shape, fault) pair, applied per job.
struct Repair {
    surviving_sinks: Vec<usize>,
    lost_sinks: Vec<usize>,
    /// `K` independent survivor coordinate positions (first-fit over
    /// sources ascending, then surviving sinks ascending), when
    /// anything needs recovering.
    positions: Vec<usize>,
    op: Option<Recovery>,
}

/// Pull the `R` sink packets out of a replay's output map, in sink
/// order — the one sink-extraction path shared by every cached
/// execution route.
fn take_sinks(layout: &Layout, outputs: &mut Outputs) -> anyhow::Result<Vec<Packet>> {
    (0..layout.r)
        .map(|r| {
            let pid = layout.sink(r);
            outputs
                .remove(&pid)
                .ok_or_else(|| anyhow::anyhow!("replay missing sink {pid}"))
        })
        .collect()
}

/// Build a structured GRS code. Radix 2 stays the default whenever it
/// buys *any* DFT structure for the Theorem-6/8 block size (`Z = 2^H >
/// 1`) — existing prime-field shapes keep their exact historical
/// designs. Only when radix 2 is structureless (e.g. `GF(2^8)`, where
/// `q−1 = 255` is odd, or odd block sizes) do we fall through to the
/// radix with the largest `Z`.
fn build_structured(f: &AnyField, k: usize, r: usize) -> anyhow::Result<GrsCode> {
    let block = if k >= r { r } else { k } as u64;
    if StructuredPoints::max_h(f, block, 2) >= 1 {
        return GrsCode::structured(f, k, r, 2);
    }
    let mut best = (2u64, 1u64);
    for p_base in [3u64, 5, 7] {
        let z = ipow(p_base, StructuredPoints::max_h(f, block, p_base));
        if z > best.1 {
            best = (p_base, z);
        }
    }
    GrsCode::structured(f, k, r, best.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::framework::AlgoRequest;

    #[test]
    fn synthetic_job_runs_and_verifies() {
        let cfg = JobConfig {
            k: 16,
            r: 4,
            w: 8,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg).unwrap();
        let rep = job.run().unwrap();
        assert_eq!(rep.verified, Some(true));
        // Auto is cost-aware: for this small code the universal path wins
        // (Remark 8); forcing the specific path still verifies.
        assert_eq!(rep.choice, PlanChoice::Universal);
        assert!(rep.sim.c1 > 0);
        let mut cfg2 = job.config.clone();
        cfg2.algorithm = crate::framework::AlgoRequest::RsSpecific;
        let rep2 = EncodeJob::synthetic(cfg2).unwrap().run().unwrap();
        assert_eq!(rep2.verified, Some(true));
        assert_eq!(rep2.choice, PlanChoice::RsSpecific);
    }

    #[test]
    fn freivalds_verify_mode_accepts_simulated_encode() {
        let cfg = JobConfig {
            k: 16,
            r: 4,
            w: 8,
            verify: crate::coordinator::config::VerifyMode::Freivalds,
            ..JobConfig::default()
        };
        let rep = EncodeJob::synthetic(cfg).unwrap().run().unwrap();
        assert_eq!(rep.verified, Some(true));
    }

    #[test]
    fn universal_on_random_matrix() {
        let cfg = JobConfig {
            k: 10,
            r: 14,
            w: 2,
            code: CodeKind::Random,
            algorithm: AlgoRequest::Universal,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg).unwrap();
        let rep = job.run().unwrap();
        assert_eq!(rep.verified, Some(true));
        assert_eq!(rep.choice, PlanChoice::Universal);
    }

    #[test]
    fn run_cached_matches_live_run_for_every_algorithm() {
        let cache = crate::coordinator::PlanCache::new();
        for algo in [
            AlgoRequest::Auto,
            AlgoRequest::Universal,
            AlgoRequest::RsSpecific,
            AlgoRequest::MultiReduce,
            AlgoRequest::Direct,
        ] {
            let cfg = JobConfig {
                k: 16,
                r: 4,
                w: 8,
                algorithm: algo,
                ..JobConfig::default()
            };
            let job = EncodeJob::synthetic(cfg).unwrap();
            let live = job.run().unwrap();
            let cached = job.run_cached(&cache).unwrap();
            assert_eq!(cached.verified, Some(true), "{algo:?}");
            assert_eq!(cached.choice, live.choice, "{algo:?}");
            // Identical (C1, C2) and full report — statics, not re-runs.
            assert_eq!(cached.sim, live.sim, "{algo:?}");
            assert_eq!(cached.cost, live.cost, "{algo:?}");
        }
        // Auto resolved to Universal here (Remark 8), so five requests
        // hit four distinct plans: one hit, four misses.
        assert_eq!(cache.len(), 4);
        assert_eq!(cache.stats(), (1, 4));
    }

    #[test]
    fn one_cached_plan_serves_every_width() {
        let cache = crate::coordinator::PlanCache::new();
        let cfg = JobConfig {
            k: 8,
            r: 4,
            w: 5,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg.clone()).unwrap();
        job.run_cached(&cache).unwrap();
        let f = job.field.clone();
        use crate::gf::Field;
        let mut rng = crate::util::Rng::new(3);
        for w in [1usize, 5, 17] {
            let x: Vec<Packet> = (0..cfg.k)
                .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
                .collect();
            let y = job.encode_cached(&cache, &x).unwrap();
            assert!(crate::coordinator::verify::native(&f, &job.parity, &x, &y), "w={w}");
        }
        // One shape, one compile — widths share the plan.
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.stats().1, 1);
    }

    #[test]
    fn batch_encode_matches_per_job_encode_bit_for_bit() {
        let cache = crate::coordinator::PlanCache::new();
        let cfg = JobConfig {
            k: 8,
            r: 4,
            w: 3,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg.clone()).unwrap();
        let f = job.field.clone();
        use crate::gf::Field;
        let mut rng = crate::util::Rng::new(11);
        let jobs: Vec<Vec<Packet>> = (0..5)
            .map(|_| {
                (0..cfg.k)
                    .map(|_| (0..cfg.w).map(|_| rng.below(f.order())).collect())
                    .collect()
            })
            .collect();
        let refs: Vec<&[Packet]> = jobs.iter().map(|x| x.as_slice()).collect();
        let batched = job.encode_batch_cached(&cache, &refs).unwrap();
        assert_eq!(batched.len(), jobs.len());
        for (x, y) in jobs.iter().zip(&batched) {
            assert_eq!(y, &job.encode_cached(&cache, x).unwrap());
            assert!(verify::native(&f, &job.parity, x, y));
        }
        // One shape: the whole batch plus the singles hit one compile.
        assert_eq!(cache.stats().1, 1);
    }

    #[test]
    fn degraded_run_repairs_lost_sinks_bit_identically() {
        let cache = crate::coordinator::PlanCache::new();
        let cfg = JobConfig {
            k: 16,
            r: 4,
            w: 6,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg).unwrap();
        let healthy = job.encode_cached(&cache, &job.inputs).unwrap();
        // Lose two sinks and one source after the run completed.
        let faults = crate::net::FaultSpec::new()
            .crash_after(16)
            .crash_after(18)
            .crash_after(3);
        let live = job.run_degraded(&faults).unwrap();
        assert_eq!(live.coded, healthy, "live repair ≡ healthy");
        assert_eq!(live.verified, Some(true));
        assert_eq!(live.lost_sinks, vec![0, 2]);
        assert_eq!(live.surviving_sinks, vec![1, 3]);
        assert_eq!(live.outputs_recovered, 2);
        assert_eq!(live.faults_injected, 3);
        let cached = job.run_degraded_cached(&cache, &faults).unwrap();
        assert_eq!(cached.coded, healthy, "cached repair ≡ healthy");
        assert_eq!(cached.sim, live.sim, "delivered stats agree live vs replay");
        assert_eq!(cached.lost_sinks, live.lost_sinks);
    }

    #[test]
    fn degraded_batch_matches_healthy_batch() {
        use crate::net::POST_RUN;
        let cache = crate::coordinator::PlanCache::new();
        let cfg = JobConfig {
            k: 8,
            r: 4,
            w: 3,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg.clone()).unwrap();
        let f = job.field.clone();
        use crate::gf::Field;
        let mut rng = crate::util::Rng::new(13);
        let jobs: Vec<Vec<Packet>> = (0..4)
            .map(|_| {
                (0..cfg.k)
                    .map(|_| (0..cfg.w).map(|_| rng.below(f.order())).collect())
                    .collect()
            })
            .collect();
        let refs: Vec<&[Packet]> = jobs.iter().map(|x| x.as_slice()).collect();
        let healthy = job.encode_batch_cached(&cache, &refs).unwrap();
        let procs: Vec<usize> = (0..cfg.k + cfg.r).collect();
        let faults = crate::net::FaultSpec::random_crashes(7, &procs, cfg.r, POST_RUN);
        let (coded, stats) = job
            .encode_degraded_batch_cached(&cache, &refs, &faults)
            .unwrap();
        assert_eq!(coded, healthy, "degraded batch ≡ healthy batch");
        assert_eq!(stats.faults_injected, cfg.r as u64);
        assert_eq!(
            stats.outputs_recovered,
            (stats.outputs_lost * jobs.len()) as u64
        );
    }

    #[test]
    fn unrecoverable_pattern_is_a_proper_error() {
        // Crash R+1 = 5 processors post-run: fewer than K coordinates
        // survive only if sinks+sources lost exceed R... here K=4, R=2,
        // N=6; crashing 3 leaves 3 < K=4 coordinates.
        let cfg = JobConfig {
            k: 4,
            r: 2,
            w: 2,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg).unwrap();
        let faults = crate::net::FaultSpec::new()
            .crash_after(0)
            .crash_after(1)
            .crash_after(4);
        let err = job.run_degraded(&faults).unwrap_err();
        assert!(err.to_string().contains("unrecoverable"), "{err}");
    }

    #[test]
    fn structured_codes_pick_a_usable_radix_per_field() {
        // GF(2^8): q−1 = 255 is odd — radix 3 must be chosen and the
        // specific path must still verify.
        let cfg = JobConfig {
            field: "gf2e:8".into(),
            k: 6,
            r: 3,
            w: 4,
            algorithm: crate::framework::AlgoRequest::RsSpecific,
            ..JobConfig::default()
        };
        let job = EncodeJob::synthetic(cfg).unwrap();
        let code = job.code.as_ref().unwrap();
        assert!(code.alpha_designs.iter().all(|d| d.p_base == 3 && d.h >= 1));
        let rep = job.run().unwrap();
        assert_eq!(rep.verified, Some(true));
        assert_eq!(rep.choice, PlanChoice::RsSpecific);
    }

    #[test]
    fn json_report_is_parseable_shape() {
        let cfg = JobConfig {
            k: 8,
            r: 4,
            w: 2,
            ..JobConfig::default()
        };
        let rep = EncodeJob::synthetic(cfg).unwrap().run().unwrap();
        let j = rep.to_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"c1\":"));
    }
}

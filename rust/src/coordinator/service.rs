//! The batch-encode service — the serving-path face of the system.
//!
//! Requests enter an **event-driven dispatcher**: per-width FIFO queues
//! under one mutex, with condvar wakeups — no polling, no sleep quanta.
//! An idle service answers a submit in microseconds. Worker threads
//! take **single-width batches** from the dispatcher and serve each in
//! one columnar pass:
//!
//! * [`EncodeService::start`] — the PJRT path: chunk rows to the AOT
//!   artifact's width `W` and run the compiled GF(p) kernel
//!   (`runtime::GfEncoder`), request-at-a-time.
//! * [`EncodeService::start_replay`] — the plan-replay path: compile the
//!   shape's decentralized schedule **once** into a
//!   [`CompiledPlan`](crate::framework::CompiledPlan) (first request =
//!   one cache miss on the sharded [`PlanCache`]) and replay its
//!   optimized form for every request.
//!
//! **Adaptive batching** ([`BatchPolicy`]): every admitted request
//! carries a deadline (`admitted + max_delay`). A width group fires as
//! a batch when it reaches `max_batch` requests (occupancy) *or* when
//! its oldest request's slack is spent (deadline) — so a loaded service
//! serves full columnar batches while a lightly-loaded one never holds
//! a request longer than `max_delay`. Because queues are per width,
//! co-batching across widths is structurally impossible.
//!
//! **Admission control**: every request belongs to a `tenant` (plain
//! [`EncodeService::submit`] uses tenant 0). The dispatcher bounds the
//! global queue (`queue_depth`) and each tenant's in-flight requests
//! (`tenant_quota`). The blocking [`submit`](EncodeService::submit)
//! path waits for room (backpressure); the non-blocking
//! [`try_submit_tenant`](EncodeService::try_submit_tenant) /
//! [`submit_with`](EncodeService::submit_with) paths — what the wire
//! front end uses — refuse with a typed
//! [`ServeRejection::Overloaded`] instead (load shedding), counted in
//! `admission_rejects`.
//!
//! **Shutdown drains**: [`EncodeService::shutdown`] marks the
//! dispatcher stopping and wakes everyone; workers serve every queued
//! request (deadlines ignored) before exiting, so each gets a real
//! response. Requests submitted after stop — and requests stranded by
//! the death of the last worker — get a typed
//! [`ServeRejection::ServiceStopped`] reply instead of being silently
//! dropped.
//!
//! Malformed payloads (wrong row count, ragged or empty widths) are
//! rejected with a proper `Err` — at [`EncodeService::submit`] before
//! they ever enqueue, and again per request inside the batch worker, so
//! one bad request can neither poison a batch nor kill a worker.
//!
//! (The offline build has no tokio; std threads + condvars provide the
//! same architecture — see DESIGN.md §10.)

use super::job::EncodeJob;
use super::metrics::{self, Metrics};
use super::plan_cache::PlanCache;
use crate::gf::{Field, Mat};
use crate::runtime::Runtime;
use anyhow::{Context, Result};
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A batch of payloads to encode: `x[k]` is source `k`'s row (all rows
/// the same width, any width — the service groups by width internally).
pub struct EncodeRequest {
    /// Admission-control principal (plain `submit` uses tenant 0).
    pub tenant: u64,
    /// Caller-chosen correlation id, echoed on the response — lets many
    /// requests share one reply channel (the wire front end does).
    pub req_id: u64,
    pub x: Vec<Vec<u64>>,
    /// Reply channel.
    pub reply: mpsc::Sender<EncodeResponse>,
    /// When the dispatcher admitted the request (set on admission).
    pub(crate) admitted: Instant,
    /// `admitted + max_delay` — the batch must fire by here.
    pub(crate) deadline: Instant,
}

impl EncodeRequest {
    /// Build a request; the dispatcher stamps `admitted`/`deadline` on
    /// admission.
    pub fn new(tenant: u64, req_id: u64, x: Vec<Vec<u64>>, reply: mpsc::Sender<EncodeResponse>) -> Self {
        let now = Instant::now();
        EncodeRequest {
            tenant,
            req_id,
            x,
            reply,
            admitted: now,
            deadline: now,
        }
    }
}

/// Parity rows `y[r]`, one per sink, same width as the request.
#[derive(Debug)]
pub struct EncodeResponse {
    /// Echo of [`EncodeRequest::req_id`].
    pub req_id: u64,
    pub y: Result<Vec<Vec<u64>>>,
    pub wall: std::time::Duration,
}

/// Adaptive micro-batching policy: a width group is served as one
/// columnar batch when it holds `max_batch` requests (occupancy-driven,
/// fires early under load) or when its oldest request has been queued
/// for `max_delay` (deadline-driven — every request carries an
/// admission deadline and its batch fires when the oldest one's slack
/// is spent). `max_batch = 1` or `max_delay = 0` degenerate to
/// request-at-a-time serving.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Largest number of requests served in one `replay_batch` call.
    pub max_batch: usize,
    /// Longest an admitted request waits for co-batched company.
    pub max_delay: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 32,
            max_delay: Duration::from_micros(500),
        }
    }
}

/// Typed admission-control refusal. Carried as the error of
/// [`EncodeService::try_submit_tenant`] / [`EncodeService::submit_with`]
/// (downcast with `err.downcast_ref::<ServeRejection>()`) and as the
/// reply to requests stranded by shutdown.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeRejection {
    /// The global queue or the tenant's in-flight quota is full —
    /// back off and retry.
    Overloaded {
        tenant: u64,
        /// Requests currently counted against the breached limit.
        in_flight: usize,
        /// The breached limit (queue depth or tenant quota).
        limit: usize,
        /// `true` when the *global* queue bound rejected, `false` when
        /// the per-tenant quota did.
        global: bool,
    },
    /// The service is shutting down (or every worker died); the
    /// request was not served.
    ServiceStopped,
}

impl std::fmt::Display for ServeRejection {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeRejection::Overloaded {
                tenant,
                in_flight,
                limit,
                global: true,
            } => write!(
                f,
                "overloaded: queue full ({in_flight}/{limit}) rejecting tenant {tenant}"
            ),
            ServeRejection::Overloaded {
                tenant,
                in_flight,
                limit,
                global: false,
            } => write!(
                f,
                "overloaded: tenant {tenant} quota exhausted ({in_flight}/{limit} in flight)"
            ),
            ServeRejection::ServiceStopped => write!(f, "service stopped"),
        }
    }
}

impl std::error::Error for ServeRejection {}

/// Mutable dispatcher state, guarded by [`Dispatcher::state`].
struct QueueState {
    /// Per-width FIFO queues — batches never mix widths.
    groups: BTreeMap<usize, VecDeque<EncodeRequest>>,
    /// Total requests across all groups.
    queued: usize,
    /// Per-tenant in-flight counts (queued + currently serving).
    in_flight: HashMap<u64, usize>,
    /// Shutdown begun: serve the backlog, admit nothing new.
    stopping: bool,
    /// Worker threads still able to serve. When the last one exits
    /// with requests still queued, the tail is reject-drained.
    workers_alive: usize,
}

/// The event-driven heart of the service: per-width queues, condvar
/// wakeups, deadline/occupancy batch firing, tenant admission control.
struct Dispatcher {
    state: Mutex<QueueState>,
    /// Wakes workers (new request, shutdown).
    ready: Condvar,
    /// Wakes blocking submitters (queue space / quota freed, shutdown).
    space: Condvar,
    policy: BatchPolicy,
    queue_depth: usize,
    tenant_quota: usize,
    k: usize,
    metrics: Arc<Metrics>,
}

impl Dispatcher {
    fn new(
        policy: BatchPolicy,
        queue_depth: usize,
        tenant_quota: usize,
        k: usize,
        n_workers: usize,
        metrics: Arc<Metrics>,
    ) -> Self {
        Dispatcher {
            state: Mutex::new(QueueState {
                groups: BTreeMap::new(),
                queued: 0,
                in_flight: HashMap::new(),
                stopping: false,
                workers_alive: n_workers,
            }),
            ready: Condvar::new(),
            space: Condvar::new(),
            policy,
            queue_depth,
            tenant_quota,
            k,
            metrics,
        }
    }

    /// Admit one request into its width queue. `block = true` waits for
    /// queue space / tenant quota (backpressure); `block = false`
    /// refuses with [`ServeRejection::Overloaded`] (load shedding).
    /// Either way a stopping service refuses with `ServiceStopped`.
    fn admit(
        &self,
        mut req: EncodeRequest,
        block: bool,
    ) -> std::result::Result<(), ServeRejection> {
        let mut s = self.state.lock().unwrap();
        loop {
            if s.stopping || s.workers_alive == 0 {
                self.metrics.incr(metrics::STOPPED_REJECTS, 1);
                return Err(ServeRejection::ServiceStopped);
            }
            if s.queued >= self.queue_depth {
                if !block {
                    self.metrics.incr(metrics::ADMISSION_REJECTS, 1);
                    return Err(ServeRejection::Overloaded {
                        tenant: req.tenant,
                        in_flight: s.queued,
                        limit: self.queue_depth,
                        global: true,
                    });
                }
            } else {
                let used = s.in_flight.get(&req.tenant).copied().unwrap_or(0);
                if used < self.tenant_quota {
                    break;
                }
                if !block {
                    self.metrics.incr(metrics::ADMISSION_REJECTS, 1);
                    return Err(ServeRejection::Overloaded {
                        tenant: req.tenant,
                        in_flight: used,
                        limit: self.tenant_quota,
                        global: false,
                    });
                }
            }
            self.metrics.incr(metrics::ADMISSION_WAITS, 1);
            s = self.space.wait(s).unwrap();
        }
        *s.in_flight.entry(req.tenant).or_insert(0) += 1;
        req.admitted = Instant::now();
        req.deadline = req.admitted + self.policy.max_delay;
        let width = req.x.first().map_or(0, |r| r.len());
        s.groups.entry(width).or_default().push_back(req);
        s.queued += 1;
        self.metrics.incr_to_max(metrics::QUEUE_DEPTH_MAX, s.queued as u64);
        drop(s);
        self.ready.notify_one();
        Ok(())
    }

    /// Block until a batch is ready and take it, or return `None` when
    /// the service is stopping and the backlog is fully drained. A
    /// group is ready when it holds `max_batch` requests, when its
    /// oldest request's deadline has passed, or — while stopping —
    /// unconditionally (the drain ignores deadlines). Among ready
    /// groups the one with the oldest head deadline fires first.
    fn next_batch(&self) -> Option<Vec<EncodeRequest>> {
        let mut s = self.state.lock().unwrap();
        loop {
            let now = Instant::now();
            let mut pick: Option<usize> = None;
            let mut pick_deadline = None;
            let mut earliest: Option<Instant> = None;
            for (&w, q) in &s.groups {
                let head = match q.front() {
                    Some(h) => h,
                    None => continue,
                };
                if s.stopping || q.len() >= self.policy.max_batch || head.deadline <= now {
                    if pick_deadline.map_or(true, |d| head.deadline < d) {
                        pick = Some(w);
                        pick_deadline = Some(head.deadline);
                    }
                } else {
                    earliest = Some(match earliest {
                        Some(e) if e < head.deadline => e,
                        _ => head.deadline,
                    });
                }
            }
            if let Some(w) = pick {
                let q = s.groups.get_mut(&w).expect("picked group exists");
                let n = q.len().min(self.policy.max_batch);
                let batch: Vec<EncodeRequest> = q.drain(..n).collect();
                if q.is_empty() {
                    s.groups.remove(&w);
                }
                s.queued -= n;
                let more_ready = s.stopping && s.queued > 0
                    || s.groups.values().any(|q| {
                        q.len() >= self.policy.max_batch
                            || q.front().is_some_and(|h| h.deadline <= now)
                    });
                drop(s);
                // Queue space freed — wake blocked submitters; and if
                // another group is already ready, wake a second worker.
                self.space.notify_all();
                if more_ready {
                    self.ready.notify_one();
                }
                return Some(batch);
            }
            if s.stopping {
                // Backlog drained (every group either empty or gone).
                debug_assert_eq!(s.queued, 0);
                return None;
            }
            s = match earliest {
                Some(dl) => {
                    let wait = dl.saturating_duration_since(now);
                    if wait.is_zero() {
                        continue; // became due while scanning
                    }
                    self.ready.wait_timeout(s, wait).unwrap().0
                }
                None => self.ready.wait(s).unwrap(),
            };
        }
    }

    /// Retire served requests from their tenants' in-flight counts
    /// (called after the replies went out) and wake blocked submitters.
    fn release(&self, counts: &[(u64, usize)]) {
        let mut s = self.state.lock().unwrap();
        for &(tenant, n) in counts {
            if let Some(c) = s.in_flight.get_mut(&tenant) {
                *c = c.saturating_sub(n);
                if *c == 0 {
                    s.in_flight.remove(&tenant);
                }
            }
        }
        drop(s);
        self.space.notify_all();
    }

    /// Begin shutdown: stop admitting, wake everyone so workers drain
    /// the backlog and blocked submitters see `ServiceStopped`.
    fn begin_stop(&self) {
        self.state.lock().unwrap().stopping = true;
        self.ready.notify_all();
        self.space.notify_all();
    }
}

/// Decrements `workers_alive` when a worker exits — however it exits.
/// The *last* worker to go reject-drains any still-queued requests with
/// a typed `ServiceStopped` reply (nothing can serve them anymore), so
/// no request is ever silently dropped, even if workers die abnormally.
struct WorkerExit {
    dispatcher: Arc<Dispatcher>,
}

impl Drop for WorkerExit {
    fn drop(&mut self) {
        let d = &self.dispatcher;
        let mut s = d.state.lock().unwrap();
        s.workers_alive = s.workers_alive.saturating_sub(1);
        if s.workers_alive > 0 {
            return;
        }
        s.stopping = true; // future submits → ServiceStopped
        let groups = std::mem::take(&mut s.groups);
        s.queued = 0;
        s.in_flight.clear();
        drop(s);
        d.ready.notify_all();
        d.space.notify_all();
        for (_w, q) in groups {
            for req in q {
                d.metrics.incr(metrics::STOPPED_REJECTS, 1);
                d.metrics.incr("requests", 1);
                d.metrics.incr("failures", 1);
                let _ = req.reply.send(EncodeResponse {
                    req_id: req.req_id,
                    y: Err(ServeRejection::ServiceStopped.into()),
                    wall: Duration::ZERO,
                });
            }
        }
    }
}

/// A running encode service over a fixed code (parity matrix).
pub struct EncodeService {
    dispatcher: Arc<Dispatcher>,
    workers: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    k: usize,
    next_id: AtomicU64,
}

impl EncodeService {
    /// Start `n_workers` threads, each with its own compiled encoder for
    /// `(K, R, W=chunk)` from the artifact directory.
    pub fn start<F: Field>(
        f: &F,
        parity: &Mat,
        artifacts_dir: &Path,
        chunk_w: usize,
        n_workers: usize,
        queue_depth: usize,
    ) -> Result<Self> {
        anyhow::ensure!(n_workers >= 1, "need at least one worker");
        let (k, r) = (parity.rows, parity.cols);
        let a_flat: Arc<Vec<u64>> =
            Arc::new((0..k).flat_map(|i| parity.row(i).to_vec()).collect());
        let metrics = Arc::new(Metrics::new());
        // The PJRT engine chunks each request independently — serve
        // request-at-a-time (max_batch 1, no added delay).
        let policy = BatchPolicy {
            max_batch: 1,
            max_delay: Duration::ZERO,
        };
        let dispatcher = Arc::new(Dispatcher::new(
            policy,
            queue_depth,
            queue_depth.max(1),
            k,
            n_workers,
            metrics.clone(),
        ));
        let q = f.order();
        let mut workers = Vec::new();
        for wid in 0..n_workers {
            let dispatcher = dispatcher.clone();
            let metrics = metrics.clone();
            let a_flat = a_flat.clone();
            let dir = artifacts_dir.to_path_buf();
            let handle = std::thread::Builder::new()
                .name(format!("encode-worker-{wid}"))
                .spawn(move || {
                    let _guard = WorkerExit {
                        dispatcher: dispatcher.clone(),
                    };
                    // Per-worker PJRT session + compiled executable: the
                    // request path never leaves rust.
                    let rt = match Runtime::cpu() {
                        Ok(rt) => rt,
                        Err(e) => {
                            metrics.incr("worker_init_failures", 1);
                            eprintln!("worker {wid}: PJRT init failed: {e:#}");
                            return;
                        }
                    };
                    let enc = match rt.load_encoder(&dir, k, r, chunk_w, q) {
                        Ok(enc) => enc,
                        Err(e) => {
                            metrics.incr("worker_init_failures", 1);
                            eprintln!("worker {wid}: encoder load failed: {e:#}");
                            return;
                        }
                    };
                    batch_worker(&dispatcher, &metrics, |jobs| {
                        jobs.iter()
                            .map(|x| encode_chunked(&enc, &a_flat, x, k, r, chunk_w))
                            .collect()
                    });
                })
                .context("spawning worker")?;
            workers.push(handle);
        }
        Ok(EncodeService {
            dispatcher,
            workers,
            metrics,
            k,
            next_id: AtomicU64::new(1),
        })
    }

    /// Start a plan-replay service for the shape described by `cfg` with
    /// the batching policy from `cfg.serve`: no PJRT artifacts required.
    /// Workers share one sharded [`PlanCache`] wired to the service
    /// metrics; the first batch compiles the plan (one
    /// `plan_cache_misses`), every later batch replays it. Requests may
    /// have any payload width — the compiled plan is width-independent
    /// (each batch is one width group, served in one columnar pass).
    pub fn start_replay(
        cfg: &super::JobConfig,
        n_workers: usize,
        queue_depth: usize,
    ) -> Result<Self> {
        Self::start_replay_with(cfg, n_workers, queue_depth, cfg.serve.policy())
    }

    /// Start a **degraded** replay service: every request is served
    /// through the fault-injected replay path (`faults` applied to the
    /// shape's compiled schedule), and lost sink outputs are
    /// **repaired** — reconstructed from the surviving coordinates via
    /// the code's redundancy (`codes::recovery`) — instead of
    /// re-encoded. Responses carry all `R` parity rows, bit-identical
    /// to the healthy service's, as long as the failure pattern leaves
    /// `K` coordinates alive; the `faults_injected` /
    /// `outputs_recovered` counters and the `recovery_latency`
    /// histogram land in the service metrics next to the batch and
    /// plan-cache counters.
    pub fn start_degraded(
        cfg: &super::JobConfig,
        n_workers: usize,
        queue_depth: usize,
        faults: crate::net::FaultSpec,
    ) -> Result<Self> {
        Self::start_replay_inner(
            cfg,
            n_workers,
            queue_depth,
            cfg.serve.policy(),
            Some(faults),
            super::Engine::Replay,
        )
    }

    /// Start a degraded **peer** service: every request runs the full
    /// chaos-wrapped peer collective (the `FaultSpec` directives drive
    /// a seeded fault-injecting transport under every rank), the mesh
    /// heals transient faults and gossips crashes, and lost sink
    /// outputs are repaired from survivors — responses stay
    /// bit-identical to the healthy service's. Healing telemetry lands
    /// in `peer_retries` / `peer_rounds_delayed` /
    /// `peer_crashes_detected` next to the recovery counters.
    pub fn start_peer_degraded(
        cfg: &super::JobConfig,
        n_workers: usize,
        queue_depth: usize,
        kind: crate::net::transport::TransportKind,
        faults: crate::net::FaultSpec,
    ) -> Result<Self> {
        Self::start_replay_inner(
            cfg,
            n_workers,
            queue_depth,
            cfg.serve.policy(),
            Some(faults),
            super::Engine::Peer(kind),
        )
    }

    /// [`start_replay`](EncodeService::start_replay) with an explicit
    /// micro-batching policy (overrides `cfg.serve`).
    pub fn start_replay_with(
        cfg: &super::JobConfig,
        n_workers: usize,
        queue_depth: usize,
        policy: BatchPolicy,
    ) -> Result<Self> {
        Self::start_replay_inner(cfg, n_workers, queue_depth, policy, None, super::Engine::Replay)
    }

    /// The shared replay-service spawner: healthy micro-batching when
    /// `faults` is `None`, the degraded repair path otherwise.
    fn start_replay_inner(
        cfg: &super::JobConfig,
        n_workers: usize,
        queue_depth: usize,
        policy: BatchPolicy,
        faults: Option<crate::net::FaultSpec>,
        engine: super::Engine,
    ) -> Result<Self> {
        anyhow::ensure!(policy.max_batch >= 1, "batch policy needs max_batch >= 1");
        anyhow::ensure!(n_workers >= 1, "need at least one worker");
        // Build the (field, code, parity) triple once; the synthetic
        // inputs are ignored — requests carry their own payloads.
        let job = Arc::new(EncodeJob::synthetic(cfg.clone())?);
        let faults = Arc::new(faults);
        let k = cfg.k;
        let metrics = Arc::new(Metrics::new());
        let cache = Arc::new(PlanCache::with_config(
            cfg.serve.plan_cache_capacity,
            cfg.serve.plan_cache_shards,
            metrics.clone(),
        ));
        let dispatcher = Arc::new(Dispatcher::new(
            policy,
            queue_depth,
            cfg.serve.tenant_quota,
            k,
            n_workers,
            metrics.clone(),
        ));
        let mut workers = Vec::new();
        for wid in 0..n_workers {
            let dispatcher = dispatcher.clone();
            let metrics = metrics.clone();
            let job = job.clone();
            let cache = cache.clone();
            let faults = faults.clone();
            let handle = std::thread::Builder::new()
                .name(format!("replay-worker-{wid}"))
                .spawn(move || {
                    let _guard = WorkerExit {
                        dispatcher: dispatcher.clone(),
                    };
                    let metrics_for_recovery = metrics.clone();
                    batch_worker(&dispatcher, &metrics, move |jobs| {
                        let base = super::job::ExecOptions::cached(&cache).engine(engine);
                        let opts = match &*faults {
                            None => base,
                            Some(spec) => base.faults(spec),
                        };
                        let out = job
                            .encode(&cache, jobs, &opts)
                            .map_err(crate::error::Error::into_inner)?;
                        if let Some(stats) = out.recovery {
                            let m = &metrics_for_recovery;
                            let injected = stats.faults_injected * jobs.len() as u64;
                            m.incr(metrics::FAULTS_INJECTED, injected);
                            m.incr(metrics::OUTPUTS_RECOVERED, stats.outputs_recovered);
                            m.observe(metrics::RECOVERY_LATENCY, stats.recovery_wall);
                            // Peer-engine healing telemetry; the replay
                            // path reports zeros, which stay silent.
                            if stats.peer_retries > 0 {
                                m.incr(metrics::PEER_RETRIES, stats.peer_retries);
                            }
                            if stats.peer_rounds_delayed > 0 {
                                m.incr(metrics::PEER_ROUNDS_DELAYED, stats.peer_rounds_delayed);
                            }
                            if stats.peer_crashes_detected > 0 {
                                m.incr(
                                    metrics::PEER_CRASHES_DETECTED,
                                    stats.peer_crashes_detected,
                                );
                            }
                        }
                        Ok(out.coded)
                    });
                })
                .context("spawning replay worker")?;
            workers.push(handle);
        }
        Ok(EncodeService {
            dispatcher,
            workers,
            metrics,
            k,
            next_id: AtomicU64::new(1),
        })
    }

    /// Submit a batch as tenant 0 (blocks for queue space when full —
    /// backpressure). Malformed payloads — wrong row count, ragged or
    /// empty widths — are rejected here with an `Err` before they
    /// enqueue.
    pub fn submit(&self, x: Vec<Vec<u64>>) -> Result<mpsc::Receiver<EncodeResponse>> {
        self.submit_tenant(0, x)
    }

    /// [`submit`](EncodeService::submit) under an explicit tenant id
    /// (blocks while the tenant's quota or the global queue is full).
    pub fn submit_tenant(
        &self,
        tenant: u64,
        x: Vec<Vec<u64>>,
    ) -> Result<mpsc::Receiver<EncodeResponse>> {
        validate_payload(self.k, &x)?;
        self.enqueue(tenant, x, true)
    }

    /// Non-blocking submit: refuses with a typed
    /// [`ServeRejection::Overloaded`] (downcastable from the returned
    /// error) instead of waiting — the load-shedding path.
    pub fn try_submit_tenant(
        &self,
        tenant: u64,
        x: Vec<Vec<u64>>,
    ) -> Result<mpsc::Receiver<EncodeResponse>> {
        validate_payload(self.k, &x)?;
        self.enqueue(tenant, x, false)
    }

    /// Non-blocking submit onto a **shared** reply channel: the
    /// response echoes `req_id`, so one channel can serve a whole
    /// connection's pipeline (the wire front end's path). Admission
    /// refusals come back as typed [`ServeRejection`] errors.
    pub fn submit_with(
        &self,
        tenant: u64,
        req_id: u64,
        x: Vec<Vec<u64>>,
        reply: mpsc::Sender<EncodeResponse>,
    ) -> Result<()> {
        validate_payload(self.k, &x)?;
        self.dispatcher
            .admit(EncodeRequest::new(tenant, req_id, x, reply), false)
            .map_err(anyhow::Error::from)
    }

    /// A cheap, cloneable, `'static` submit handle for front ends: it
    /// shares the dispatcher (not the service), and validates + admits
    /// exactly like [`submit_with`](EncodeService::submit_with). The
    /// wire server's connection threads hold one of these while the
    /// service itself stays owned by the server for shutdown.
    pub fn submit_handle(
        &self,
    ) -> impl Fn(u64, u64, Vec<Vec<u64>>, mpsc::Sender<EncodeResponse>) -> Result<()>
           + Clone
           + Send
           + Sync
           + 'static {
        let dispatcher = self.dispatcher.clone();
        let k = self.k;
        move |tenant, req_id, x, reply| {
            validate_payload(k, &x)?;
            dispatcher
                .admit(EncodeRequest::new(tenant, req_id, x, reply), false)
                .map_err(anyhow::Error::from)
        }
    }

    /// The shared enqueue path: build the reply channel and admit.
    fn enqueue(
        &self,
        tenant: u64,
        x: Vec<Vec<u64>>,
        block: bool,
    ) -> Result<mpsc::Receiver<EncodeResponse>> {
        let (reply, rx) = mpsc::channel();
        let req_id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.dispatcher
            .admit(EncodeRequest::new(tenant, req_id, x, reply), block)?;
        Ok(rx)
    }

    /// Test-only: enqueue a payload *without* submit-side validation, to
    /// exercise the worker's own shape checks.
    #[cfg(test)]
    fn submit_unchecked(&self, x: Vec<Vec<u64>>) -> Result<mpsc::Receiver<EncodeResponse>> {
        let (reply, rx) = mpsc::channel();
        let req_id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.dispatcher
            .admit(EncodeRequest::new(0, req_id, x, reply), true)?;
        Ok(rx)
    }

    /// Graceful shutdown: stop admitting, serve every queued request
    /// (drain-and-respond), join the workers. No queued request is
    /// dropped — each gets its response before the workers exit.
    pub fn shutdown(mut self) {
        self.dispatcher.begin_stop();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// The worker loop shared by both engines: take ready single-width
/// batches from the dispatcher until shutdown drains the queue, serve
/// each, then retire the batch's tenants' in-flight counts.
fn batch_worker(
    dispatcher: &Arc<Dispatcher>,
    metrics: &Metrics,
    encode_batch: impl Fn(&[&[Vec<u64>]]) -> Result<Vec<Vec<Vec<u64>>>>,
) {
    while let Some(batch) = dispatcher.next_batch() {
        let mut tenants: Vec<(u64, usize)> = Vec::new();
        for req in &batch {
            match tenants.iter_mut().find(|(t, _)| *t == req.tenant) {
                Some((_, n)) => *n += 1,
                None => tenants.push((req.tenant, 1)),
            }
        }
        serve_batch(batch, metrics, dispatcher.k, &encode_batch);
        dispatcher.release(&tenants);
    }
}

/// Shape-check one submitted payload: exactly `k` rows, uniform nonzero
/// width. Shared by [`EncodeService::submit`] and the batch worker.
fn validate_payload(k: usize, x: &[Vec<u64>]) -> Result<()> {
    anyhow::ensure!(
        x.len() == k,
        "need K = {k} payload rows, got {}",
        x.len()
    );
    let w = x.first().map_or(0, |r| r.len());
    anyhow::ensure!(w > 0, "empty payload rows (width 0)");
    anyhow::ensure!(x.iter().all(|r| r.len() == w), "ragged payload rows");
    Ok(())
}

/// Serve one collected micro-batch: shape-validate each request (bad
/// ones get their own `Err` reply and never poison the batch), group
/// the valid ones by payload width (the dispatcher already delivers
/// single-width batches; the grouping also guards direct callers), run
/// one columnar `encode_batch` pass per width, and reply per request
/// **as its width group finishes** — a request's `wall` /
/// `encode_latency` is the serve time of its own group; `queue_wait`
/// records admission → serve start; `batch_latency` covers the full
/// serve. Records the batch-size/occupancy/throughput counters.
fn serve_batch(
    batch: Vec<EncodeRequest>,
    metrics: &Metrics,
    k: usize,
    encode_batch: &impl Fn(&[&[Vec<u64>]]) -> Result<Vec<Vec<Vec<u64>>>>,
) {
    let batch_t0 = Instant::now();
    let mut valid: Vec<Option<EncodeRequest>> = Vec::with_capacity(batch.len());
    for req in batch {
        metrics.observe(
            metrics::QUEUE_WAIT,
            batch_t0.saturating_duration_since(req.admitted),
        );
        if let Err(e) = validate_payload(k, &req.x) {
            metrics.incr("requests", 1);
            metrics.incr("failures", 1);
            let _ = req.reply.send(EncodeResponse {
                req_id: req.req_id,
                y: Err(e),
                wall: batch_t0.elapsed(),
            });
        } else {
            valid.push(Some(req));
        }
    }
    if valid.is_empty() {
        return;
    }
    metrics.record_batch(valid.len() as u64);

    // One columnar pass per payload width (the dispatcher's per-width
    // queues make this a single group on the service path).
    let mut by_width: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for (i, req) in valid.iter().enumerate() {
        let req = req.as_ref().expect("request present before serving");
        by_width.entry(req.x[0].len()).or_default().push(i);
    }
    let mut elems = 0u64;
    for idxs in by_width.values() {
        let jobs: Vec<&[Vec<u64>]> = idxs
            .iter()
            .map(|&i| valid[i].as_ref().expect("unserved request").x.as_slice())
            .collect();
        let t0 = Instant::now();
        let result = encode_batch(&jobs);
        drop(jobs);
        let wall = t0.elapsed();
        match result {
            Ok(ys) => {
                for (&slot, y) in idxs.iter().zip(ys) {
                    let req = valid[slot].take().expect("reply slot served once");
                    metrics.incr("requests", 1);
                    elems += y.iter().map(|r| r.len() as u64).sum::<u64>();
                    metrics.observe("encode_latency", wall);
                    let _ = req.reply.send(EncodeResponse {
                        req_id: req.req_id,
                        y: Ok(y),
                        wall,
                    });
                }
            }
            Err(e) => {
                // Group-level failure: every request in the width group
                // carries the error (anyhow errors don't clone — each
                // reply gets the formatted chain). A kernel layout or
                // arena-shape mismatch — a plan paired with buffers
                // packed for a different field, or mis-sized arenas —
                // used to panic the batcher thread; it is now a typed
                // rejection ([`KernelError`]) with its own counter.
                //
                // [`KernelError`]: crate::gf::kernels::KernelError
                if e.chain().any(|c| {
                    c.downcast_ref::<crate::gf::kernels::LayoutMismatch>().is_some()
                        || c.downcast_ref::<crate::gf::kernels::KernelError>().is_some()
                }) {
                    metrics.incr(metrics::KERNEL_LAYOUT_REJECTS, idxs.len() as u64);
                }
                let msg = format!("{e:#}");
                for &slot in idxs {
                    let req = valid[slot].take().expect("reply slot served once");
                    metrics.incr("requests", 1);
                    metrics.incr("failures", 1);
                    metrics.observe("encode_latency", wall);
                    let _ = req.reply.send(EncodeResponse {
                        req_id: req.req_id,
                        y: Err(anyhow::anyhow!(msg.clone())),
                        wall,
                    });
                }
            }
        }
    }
    metrics.incr(metrics::ENCODED_ELEMS, elems);
    metrics.observe("batch_latency", batch_t0.elapsed());
}

/// Encode arbitrary-width payloads by chunking to the artifact width.
fn encode_chunked(
    enc: &crate::runtime::GfEncoder,
    a_flat: &[u64],
    x: &[Vec<u64>],
    k: usize,
    r: usize,
    chunk_w: usize,
) -> Result<Vec<Vec<u64>>> {
    let width = x.first().map_or(0, |row| row.len());
    anyhow::ensure!(
        x.iter().all(|row| row.len() == width),
        "ragged payload rows"
    );
    let mut out = vec![Vec::with_capacity(width); r];
    let mut off = 0;
    while off < width {
        let take = chunk_w.min(width - off);
        // Zero-pad the tail chunk to the artifact width.
        let mut x_flat = vec![0u64; k * chunk_w];
        for (i, row) in x.iter().enumerate() {
            x_flat[i * chunk_w..i * chunk_w + take].copy_from_slice(&row[off..off + take]);
        }
        let y = enc.encode_u64(a_flat, &x_flat)?;
        for (j, row) in out.iter_mut().enumerate() {
            row.extend_from_slice(&y[j * chunk_w..j * chunk_w + take]);
        }
        off += take;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{verify, JobConfig};

    #[test]
    fn replay_service_serves_mixed_widths_from_one_compiled_plan() {
        let cfg = JobConfig {
            k: 8,
            r: 4,
            w: 4,
            ..JobConfig::default()
        };
        // Same config ⇒ same deterministic code/parity as the service.
        let oracle_job = EncodeJob::synthetic(cfg.clone()).unwrap();
        let f = cfg.any_field().unwrap();
        let svc = EncodeService::start_replay(&cfg, 1, 8).unwrap();
        let mut rng = crate::util::Rng::new(9);
        // Sequential submit/await so every request lands in its own
        // micro-batch — the cache accounting below stays deterministic.
        for w in [4usize, 9, 1, 4] {
            let x: Vec<Vec<u64>> = (0..cfg.k)
                .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
                .collect();
            let rx = svc.submit(x.clone()).unwrap();
            let resp = rx.recv().unwrap();
            let y = resp.y.expect("replay encode ok");
            assert_eq!(y.len(), cfg.r);
            assert!(verify::native(&f, &oracle_job.parity, &x, &y));
        }
        // One worker: the first batch compiled (miss), the rest replayed.
        assert_eq!(svc.metrics.plan_cache(), (3, 1));
        let j = svc.metrics.to_json();
        assert!(j.contains("\"plan_cache_hits\":3"), "{j}");
        assert!(j.contains("\"plan_cache_misses\":1"), "{j}");
        assert_eq!(svc.metrics.counter("requests"), 4);
        // Four single-request micro-batches.
        assert_eq!(svc.metrics.batch_stats(), (4, 4, 1));
        // The dispatcher records queueing delay for every request.
        let (n, _, _, _) = svc.metrics.latency_summary(metrics::QUEUE_WAIT).unwrap();
        assert_eq!(n, 4);
        svc.shutdown();
    }

    #[test]
    fn submit_rejects_malformed_payloads_and_workers_survive() {
        let cfg = JobConfig {
            k: 4,
            r: 2,
            w: 4,
            ..JobConfig::default()
        };
        let f = cfg.any_field().unwrap();
        let oracle_job = EncodeJob::synthetic(cfg.clone()).unwrap();
        let svc = EncodeService::start_replay(&cfg, 1, 8).unwrap();
        // Submit-side rejection: wrong K, ragged rows, empty width.
        assert!(svc.submit(vec![vec![1, 2]; 3]).is_err(), "wrong K");
        assert!(
            svc.submit(vec![vec![1, 2], vec![1, 2], vec![1], vec![1, 2]])
                .is_err(),
            "ragged rows"
        );
        assert!(svc.submit(vec![Vec::new(); 4]).is_err(), "empty width");
        // Worker-side rejection: bypass submit's checks — the worker
        // must reply with a proper Err, not die on a downstream panic.
        let rx = svc.submit_unchecked(vec![vec![7, 7], vec![7]]).unwrap();
        let resp = rx.recv().expect("worker replied instead of dying");
        assert!(resp.y.is_err());
        let rx = svc.submit_unchecked(vec![Vec::new(); 4]).unwrap();
        assert!(rx.recv().unwrap().y.is_err(), "empty width at the worker");
        // Non-canonical field elements: a proper Err reply (the encode
        // paths validate the canonical range), not a dead worker.
        let rx = svc
            .submit_unchecked(vec![vec![1 << 40, 2], vec![1, 2], vec![1, 2], vec![1, 2]])
            .unwrap();
        assert!(rx.recv().expect("worker survived").y.is_err(), "non-canonical");
        // The same worker still serves well-formed requests afterwards.
        let x: Vec<Vec<u64>> = (0..cfg.k).map(|i| vec![i as u64 + 1, 3]).collect();
        let y = svc.submit(x.clone()).unwrap().recv().unwrap().y.unwrap();
        assert!(verify::native(&f, &oracle_job.parity, &x, &y));
        assert_eq!(svc.metrics.counter("failures"), 3);
        svc.shutdown();
    }

    #[test]
    fn kernel_layout_mismatch_is_a_counted_rejection_not_a_dead_worker() {
        use crate::gf::kernels::Kernels;
        // Drive the batch-serving tail with an encode path that trips
        // the typed layout mismatch (prime kernels against GF(2^8)
        // buffers — what used to be a batcher-killing panic): the
        // request must get a proper Err reply and the dedicated counter
        // must move alongside the generic failure count.
        let m = Metrics::new();
        let (tx, reply_rx) = mpsc::channel();
        let req = EncodeRequest::new(0, 9, vec![vec![1u64]; 4], tx);
        let encode = |_jobs: &[&[Vec<u64>]]| -> Result<Vec<Vec<Vec<u64>>>> {
            let prime = Kernels::for_field(&crate::gf::GfPrime::default_field());
            let wrong = Kernels::for_field(&crate::gf::Gf2e::new(8).unwrap());
            let b = wrong.zeros(4);
            let mut out = wrong.zeros(4);
            let row: &[u64] = &[1, 2, 3, 4];
            prime.gemm_rows(&[row], &b, 4, &mut out, false)?;
            unreachable!("mismatched layouts must error");
        };
        serve_batch(vec![req], &m, 4, &encode);
        let resp = reply_rx.recv().expect("a reply, not a panic");
        assert_eq!(resp.req_id, 9, "response echoes the request id");
        let err = resp.y.unwrap_err();
        assert!(err.to_string().contains("does not match"), "{err}");
        assert_eq!(m.counter("failures"), 1);
        assert_eq!(m.counter(metrics::KERNEL_LAYOUT_REJECTS), 1);
    }

    #[test]
    fn mixed_width_requests_never_co_batch_and_shutdown_drains_them() {
        let cfg = JobConfig {
            k: 5,
            r: 3,
            w: 4,
            ..JobConfig::default()
        };
        let f = cfg.any_field().unwrap();
        let oracle_job = EncodeJob::synthetic(cfg.clone()).unwrap();
        // Widths deliberately interleaved; the batch window is wide
        // open (5s deadline, occupancy 6 never reached per width), so
        // nothing fires until shutdown drains — which must serve every
        // queued request, one single-width batch per group.
        let widths = [3usize, 7, 3, 1, 7, 3];
        let svc = EncodeService::start_replay_with(
            &cfg,
            1,
            16,
            BatchPolicy {
                max_batch: widths.len(),
                max_delay: Duration::from_secs(5),
            },
        )
        .unwrap();
        let metrics = svc.metrics.clone();
        let mut rng = crate::util::Rng::new(47);
        let mut pending = Vec::new();
        for &w in &widths {
            let x: Vec<Vec<u64>> = (0..cfg.k)
                .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
                .collect();
            pending.push((x.clone(), svc.submit(x).unwrap()));
        }
        let t0 = Instant::now();
        svc.shutdown();
        assert!(
            t0.elapsed() < Duration::from_secs(4),
            "drain ignores the 5s batch deadline"
        );
        for (x, rx) in pending {
            let y = rx.recv().unwrap().y.expect("drained request served, not dropped");
            assert_eq!(y.len(), cfg.r);
            // Random payloads per request: a crossed reply (another
            // request's rows, or another width group's) fails the
            // parity verification against this request's own x.
            assert!(verify::native(&f, &oracle_job.parity, &x, &y));
        }
        // Three width groups → three single-width batches (widths are
        // never co-batched), the largest holding the three w=3 requests.
        assert_eq!(metrics.batch_stats(), (3, widths.len() as u64, 3));
        assert_eq!(metrics.plan_cache(), (2, 1));
        assert_eq!(metrics.counter("requests"), widths.len() as u64);
        assert_eq!(metrics.counter("failures"), 0);
    }

    #[test]
    fn degraded_service_repairs_failed_sinks_transparently() {
        let cfg = JobConfig {
            k: 8,
            r: 4,
            w: 4,
            ..JobConfig::default()
        };
        let f = cfg.any_field().unwrap();
        let oracle_job = EncodeJob::synthetic(cfg.clone()).unwrap();
        // Two sinks lost after encoding (storage-loss scenario) plus one
        // source: the service must keep answering with all R rows.
        let faults = crate::net::FaultSpec::new()
            .crash_after(8)
            .crash_after(10)
            .crash_after(2);
        let n_faults = faults.injected();
        let svc = EncodeService::start_degraded(&cfg, 1, 8, faults).unwrap();
        let mut rng = crate::util::Rng::new(77);
        let n_req = 3usize;
        for _ in 0..n_req {
            let x: Vec<Vec<u64>> = (0..cfg.k)
                .map(|_| (0..cfg.w).map(|_| rng.below(f.order())).collect())
                .collect();
            let y = svc.submit(x.clone()).unwrap().recv().unwrap().y.unwrap();
            assert_eq!(y.len(), cfg.r, "all R rows, repaired ones included");
            // A repaired row that diverged from x·A fails verification.
            assert!(verify::native(&f, &oracle_job.parity, &x, &y));
        }
        assert_eq!(
            svc.metrics.counter(metrics::FAULTS_INJECTED),
            n_faults * n_req as u64
        );
        assert_eq!(
            svc.metrics.counter(metrics::OUTPUTS_RECOVERED),
            2 * n_req as u64,
            "two sinks repaired per request"
        );
        assert!(svc.metrics.latency_summary(metrics::RECOVERY_LATENCY).is_some());
        svc.shutdown();
    }

    #[test]
    fn peer_degraded_service_heals_and_reports_telemetry() {
        let cfg = JobConfig {
            k: 8,
            r: 4,
            w: 4,
            ..JobConfig::default()
        };
        let f = cfg.any_field().unwrap();
        let oracle_job = EncodeJob::synthetic(cfg.clone()).unwrap();
        // Sink 0 (proc 8) crash-stops from round 1: the chaos injector
        // under every rank swallows its traffic, the mesh detects and
        // gossips the death, and the repair tail rebuilds its row.
        let faults = crate::net::FaultSpec::new().crash(8);
        let svc = EncodeService::start_peer_degraded(
            &cfg,
            1,
            8,
            crate::net::transport::TransportKind::Channel,
            faults,
        )
        .unwrap();
        let mut rng = crate::util::Rng::new(99);
        let n_req = 2usize;
        for _ in 0..n_req {
            let x: Vec<Vec<u64>> = (0..cfg.k)
                .map(|_| (0..cfg.w).map(|_| rng.below(f.order())).collect())
                .collect();
            let y = svc.submit(x.clone()).unwrap().recv().unwrap().y.unwrap();
            assert_eq!(y.len(), cfg.r, "all R rows, the repaired ones included");
            // A repaired row that diverged from x·A fails verification.
            assert!(verify::native(&f, &oracle_job.parity, &x, &y));
        }
        assert_eq!(svc.metrics.counter(metrics::FAULTS_INJECTED), n_req as u64);
        assert!(
            svc.metrics.counter(metrics::OUTPUTS_RECOVERED) >= n_req as u64,
            "the dead sink's row is rebuilt for every request"
        );
        assert!(
            svc.metrics.counter(metrics::PEER_CRASHES_DETECTED) >= n_req as u64,
            "every request's mesh detects the dead sink"
        );
        svc.shutdown();
    }

    #[test]
    fn micro_batching_coalesces_requests_into_one_columnar_pass() {
        let cfg = JobConfig {
            k: 6,
            r: 3,
            w: 5,
            ..JobConfig::default()
        };
        let f = cfg.any_field().unwrap();
        let oracle_job = EncodeJob::synthetic(cfg.clone()).unwrap();
        let n_req = 8usize;
        // One worker, deadline far away: the batch fires on occupancy,
        // exactly when the n_req-th request lands.
        let svc = EncodeService::start_replay_with(
            &cfg,
            1,
            16,
            BatchPolicy {
                max_batch: n_req,
                max_delay: Duration::from_secs(5),
            },
        )
        .unwrap();
        let mut rng = crate::util::Rng::new(31);
        let mut pending = Vec::new();
        for _ in 0..n_req {
            let x: Vec<Vec<u64>> = (0..cfg.k)
                .map(|_| (0..cfg.w).map(|_| rng.below(f.order())).collect())
                .collect();
            pending.push((x.clone(), svc.submit(x).unwrap()));
        }
        for (x, rx) in pending {
            let y = rx.recv().unwrap().y.expect("batched encode ok");
            assert!(verify::native(&f, &oracle_job.parity, &x, &y));
        }
        let (batches, batched, occ_max) = svc.metrics.batch_stats();
        assert_eq!(batched, n_req as u64);
        assert_eq!(occ_max, n_req as u64, "all requests in one batch");
        assert_eq!(batches, 1);
        // One compile for the whole batch; throughput counter adds up.
        assert_eq!(svc.metrics.plan_cache(), (0, 1));
        assert_eq!(
            svc.metrics.counter(metrics::ENCODED_ELEMS),
            (n_req * cfg.r * cfg.w) as u64
        );
        svc.shutdown();
    }

    #[test]
    fn shutdown_drains_and_responds_to_every_queued_request() {
        let cfg = JobConfig {
            k: 4,
            r: 2,
            w: 4,
            ..JobConfig::default()
        };
        let f = cfg.any_field().unwrap();
        // A 10s batch window: nothing would fire for seconds — except
        // that shutdown must drain immediately. The old stop-flag race
        // could drop the queued tail on the floor; every one of the N
        // requests must now get a real response.
        let n = 32usize;
        let svc = EncodeService::start_replay_with(
            &cfg,
            2,
            n,
            BatchPolicy {
                max_batch: 4,
                max_delay: Duration::from_secs(10),
            },
        )
        .unwrap();
        let mut rng = crate::util::Rng::new(5);
        let mut pending = Vec::new();
        for _ in 0..n {
            let x: Vec<Vec<u64>> = (0..cfg.k)
                .map(|_| (0..3).map(|_| rng.below(f.order())).collect())
                .collect();
            pending.push(svc.submit(x).unwrap());
        }
        let metrics = svc.metrics.clone();
        let t0 = Instant::now();
        svc.shutdown();
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "drain must not wait out the 10s window"
        );
        let mut served = 0;
        for rx in pending {
            let resp = rx.recv().expect("every queued request gets a reply");
            assert!(resp.y.is_ok(), "drained request served: {:?}", resp.y.err());
            served += 1;
        }
        assert_eq!(served, n);
        assert_eq!(metrics.counter("requests"), n as u64);
        assert_eq!(metrics.counter(metrics::STOPPED_REJECTS), 0);
    }

    #[test]
    fn idle_submit_to_response_has_no_poll_floor() {
        // Regression test for the 50ms poll loops: with max_delay = 0
        // the dispatcher wakes a worker per submit, so a round trip on
        // an idle service is microseconds. 20 sequential round trips at
        // the old 50ms floor would need ≥ 1s; the bound below is
        // generous for CI noise while still pinning the event-driven
        // wakeup.
        let cfg = JobConfig {
            k: 4,
            r: 2,
            w: 4,
            ..JobConfig::default()
        };
        let f = cfg.any_field().unwrap();
        let svc = EncodeService::start_replay_with(
            &cfg,
            1,
            8,
            BatchPolicy {
                max_batch: 1,
                max_delay: Duration::ZERO,
            },
        )
        .unwrap();
        // Warm the plan cache so timed round trips replay, not compile.
        let warm: Vec<Vec<u64>> = (0..cfg.k).map(|_| vec![1, 2]).collect();
        svc.submit(warm).unwrap().recv().unwrap().y.unwrap();
        let mut rng = crate::util::Rng::new(13);
        let t0 = Instant::now();
        let n = 20;
        for _ in 0..n {
            let x: Vec<Vec<u64>> = (0..cfg.k)
                .map(|_| (0..2).map(|_| rng.below(f.order())).collect())
                .collect();
            svc.submit(x).unwrap().recv().unwrap().y.unwrap();
        }
        let elapsed = t0.elapsed();
        assert!(
            elapsed < Duration::from_millis(50 * n as u64 / 2),
            "{n} idle round trips took {elapsed:?} — poll-floor regression"
        );
        let t1 = Instant::now();
        svc.shutdown();
        assert!(t1.elapsed() < Duration::from_secs(2), "prompt shutdown");
    }

    #[test]
    fn tenant_quota_rejects_typed_and_releases_after_serving() {
        let mut cfg = JobConfig {
            k: 4,
            r: 2,
            w: 4,
            ..JobConfig::default()
        };
        cfg.serve.tenant_quota = 2;
        // Deadline far away: submitted requests stay queued, holding
        // their tenant's quota, so the third submit rejects
        // deterministically.
        let svc = EncodeService::start_replay_with(
            &cfg,
            1,
            16,
            BatchPolicy {
                max_batch: 16,
                max_delay: Duration::from_secs(10),
            },
        )
        .unwrap();
        let x: Vec<Vec<u64>> = (0..cfg.k).map(|_| vec![1, 2, 3]).collect();
        let a = svc.try_submit_tenant(7, x.clone()).unwrap();
        let b = svc.try_submit_tenant(7, x.clone()).unwrap();
        let err = svc.try_submit_tenant(7, x.clone()).unwrap_err();
        match err.downcast_ref::<ServeRejection>() {
            Some(ServeRejection::Overloaded {
                tenant: 7,
                in_flight: 2,
                limit: 2,
                global: false,
            }) => {}
            other => panic!("expected a typed tenant-quota rejection, got {other:?}"),
        }
        // A different tenant is unaffected.
        let c = svc.try_submit_tenant(8, x.clone()).unwrap();
        assert_eq!(svc.metrics.counter(metrics::ADMISSION_REJECTS), 1);
        let metrics = svc.metrics.clone();
        svc.shutdown(); // drains the three admitted requests
        for rx in [a, b, c] {
            assert!(rx.recv().unwrap().y.is_ok());
        }
        assert_eq!(metrics.counter("requests"), 3);
    }

    #[test]
    fn global_queue_bound_rejects_typed() {
        let cfg = JobConfig {
            k: 4,
            r: 2,
            w: 4,
            ..JobConfig::default()
        };
        let svc = EncodeService::start_replay_with(
            &cfg,
            1,
            2, // queue_depth
            BatchPolicy {
                max_batch: 16,
                max_delay: Duration::from_secs(10),
            },
        )
        .unwrap();
        let x: Vec<Vec<u64>> = (0..cfg.k).map(|_| vec![1, 2]).collect();
        let a = svc.try_submit_tenant(1, x.clone()).unwrap();
        let b = svc.try_submit_tenant(2, x.clone()).unwrap();
        let err = svc.try_submit_tenant(3, x.clone()).unwrap_err();
        match err.downcast_ref::<ServeRejection>() {
            Some(ServeRejection::Overloaded { global: true, limit: 2, .. }) => {}
            other => panic!("expected a typed queue-full rejection, got {other:?}"),
        }
        let metrics = svc.metrics.clone();
        svc.shutdown();
        for rx in [a, b] {
            assert!(rx.recv().unwrap().y.is_ok());
        }
        assert_eq!(metrics.counter(metrics::ADMISSION_REJECTS), 1);
        assert_eq!(metrics.counter(metrics::QUEUE_DEPTH_MAX), 2);
    }

    #[test]
    fn stopping_dispatcher_refuses_with_service_stopped() {
        let m = Arc::new(Metrics::new());
        let d = Dispatcher::new(BatchPolicy::default(), 8, 8, 4, 1, m.clone());
        d.begin_stop();
        let (tx, _rx) = mpsc::channel();
        let err = d
            .admit(EncodeRequest::new(0, 1, vec![vec![1]; 4], tx), true)
            .unwrap_err();
        assert_eq!(err, ServeRejection::ServiceStopped);
        assert_eq!(m.counter(metrics::STOPPED_REJECTS), 1);
        assert_eq!(err.to_string(), "service stopped");
    }
}

//! The batch-encode service — the serving-path face of the system.
//!
//! Worker threads consume [`EncodeRequest`]s (K payload rows of arbitrary
//! width) from a bounded queue and reply on a per-request channel.
//! Bounded-queue submission gives natural backpressure; metrics record
//! throughput and latency percentiles. Two engines:
//!
//! * [`EncodeService::start`] — the PJRT path: chunk rows to the AOT
//!   artifact's width `W` and run the compiled GF(p) kernel
//!   (`runtime::GfEncoder`).
//! * [`EncodeService::start_replay`] — the plan-replay path: compile the
//!   shape's decentralized schedule **once** into a
//!   [`CompiledPlan`](crate::framework::CompiledPlan) (first request =
//!   one cache miss) and replay it for every request — no per-request
//!   planning or round stepping, any payload width, no artifacts needed.
//!   Cache hit/miss counters land in the service metrics summary.
//!
//! (The offline build has no tokio; std threads + mpsc channels provide
//! the same architecture — see DESIGN.md §1.)

use super::job::EncodeJob;
use super::metrics::Metrics;
use super::plan_cache::PlanCache;
use crate::gf::{Field, Mat};
use crate::runtime::Runtime;
use anyhow::{Context, Result};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// A batch of payloads to encode: `x[k]` is source `k`'s row (all rows
/// the same width, any width — the service chunks internally).
pub struct EncodeRequest {
    pub x: Vec<Vec<u64>>,
    /// Reply channel.
    pub reply: mpsc::Sender<EncodeResponse>,
}

/// Parity rows `y[r]`, one per sink, same width as the request.
#[derive(Debug)]
pub struct EncodeResponse {
    pub y: Result<Vec<Vec<u64>>>,
    pub wall: std::time::Duration,
}

/// A running encode service over a fixed code (parity matrix).
pub struct EncodeService {
    tx: Option<mpsc::SyncSender<EncodeRequest>>,
    workers: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    k: usize,
}

impl EncodeService {
    /// Start `n_workers` threads, each with its own compiled encoder for
    /// `(K, R, W=chunk)` from the artifact directory.
    pub fn start<F: Field>(
        f: &F,
        parity: &Mat,
        artifacts_dir: &Path,
        chunk_w: usize,
        n_workers: usize,
        queue_depth: usize,
    ) -> Result<Self> {
        let (k, r) = (parity.rows, parity.cols);
        let a_flat: Arc<Vec<u64>> =
            Arc::new((0..k).flat_map(|i| parity.row(i).to_vec()).collect());
        let (tx, rx) = mpsc::sync_channel::<EncodeRequest>(queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::new());
        let stop = Arc::new(AtomicBool::new(false));
        let q = f.order();
        let mut workers = Vec::new();
        for wid in 0..n_workers {
            let rx = rx.clone();
            let metrics = metrics.clone();
            let stop = stop.clone();
            let a_flat = a_flat.clone();
            let dir = artifacts_dir.to_path_buf();
            let handle = std::thread::Builder::new()
                .name(format!("encode-worker-{wid}"))
                .spawn(move || {
                    // Per-worker PJRT session + compiled executable: the
                    // request path never leaves rust.
                    let rt = match Runtime::cpu() {
                        Ok(rt) => rt,
                        Err(e) => {
                            metrics.incr("worker_init_failures", 1);
                            eprintln!("worker {wid}: PJRT init failed: {e:#}");
                            return;
                        }
                    };
                    let enc = match rt.load_encoder(&dir, k, r, chunk_w, q) {
                        Ok(enc) => enc,
                        Err(e) => {
                            metrics.incr("worker_init_failures", 1);
                            eprintln!("worker {wid}: encoder load failed: {e:#}");
                            return;
                        }
                    };
                    worker_loop(&rx, &metrics, &stop, |x| {
                        encode_chunked(&enc, &a_flat, x, k, r, chunk_w)
                    });
                })
                .context("spawning worker")?;
            workers.push(handle);
        }
        Ok(EncodeService {
            tx: Some(tx),
            workers,
            metrics,
            stop,
            k,
        })
    }

    /// Start a plan-replay service for the shape described by `cfg`: no
    /// PJRT artifacts required. Workers share one [`PlanCache`] wired to
    /// the service metrics; the first request compiles the plan (one
    /// `plan_cache_misses`), every later request replays it (one
    /// `plan_cache_hits` each). Requests may have any payload width —
    /// the compiled plan is width-independent.
    pub fn start_replay(
        cfg: &super::JobConfig,
        n_workers: usize,
        queue_depth: usize,
    ) -> Result<Self> {
        // Build the (field, code, parity) triple once; the synthetic
        // inputs are ignored — requests carry their own payloads.
        let job = Arc::new(EncodeJob::synthetic(cfg.clone())?);
        let k = cfg.k;
        let (tx, rx) = mpsc::sync_channel::<EncodeRequest>(queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::new());
        let cache = Arc::new(PlanCache::with_metrics(metrics.clone()));
        let stop = Arc::new(AtomicBool::new(false));
        let mut workers = Vec::new();
        for wid in 0..n_workers {
            let rx = rx.clone();
            let metrics = metrics.clone();
            let stop = stop.clone();
            let job = job.clone();
            let cache = cache.clone();
            let handle = std::thread::Builder::new()
                .name(format!("replay-worker-{wid}"))
                .spawn(move || {
                    worker_loop(&rx, &metrics, &stop, |x| job.encode_cached(&cache, x))
                })
                .context("spawning replay worker")?;
            workers.push(handle);
        }
        Ok(EncodeService {
            tx: Some(tx),
            workers,
            metrics,
            stop,
            k,
        })
    }

    /// Submit a batch (blocks when the queue is full — backpressure).
    pub fn submit(&self, x: Vec<Vec<u64>>) -> Result<mpsc::Receiver<EncodeResponse>> {
        anyhow::ensure!(x.len() == self.k, "need K = {} payload rows", self.k);
        let (reply, rx) = mpsc::channel();
        self.tx
            .as_ref()
            .context("service stopped")?
            .send(EncodeRequest { x, reply })
            .ok()
            .context("service stopped")?;
        Ok(rx)
    }

    /// Drain and stop all workers.
    pub fn shutdown(mut self) {
        self.tx.take(); // close the queue
        self.stop.store(true, Ordering::Relaxed);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// The worker protocol shared by both engines: poll the stop flag, drain
/// the bounded queue (50ms poll so shutdown is prompt), time each
/// request, record the `requests`/`failures`/`encode_latency` metrics,
/// reply on the per-request channel. `encode` is the only per-engine
/// part.
fn worker_loop(
    rx: &Mutex<mpsc::Receiver<EncodeRequest>>,
    metrics: &Metrics,
    stop: &AtomicBool,
    encode: impl Fn(&[Vec<u64>]) -> Result<Vec<Vec<u64>>>,
) {
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let req = {
            let guard = rx.lock().unwrap();
            match guard.recv_timeout(std::time::Duration::from_millis(50)) {
                Ok(req) => req,
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        };
        let t0 = Instant::now();
        let y = encode(&req.x);
        let wall = t0.elapsed();
        metrics.incr("requests", 1);
        if y.is_err() {
            metrics.incr("failures", 1);
        }
        metrics.observe("encode_latency", wall);
        let _ = req.reply.send(EncodeResponse { y, wall });
    }
}

/// Encode arbitrary-width payloads by chunking to the artifact width.
fn encode_chunked(
    enc: &crate::runtime::GfEncoder,
    a_flat: &[u64],
    x: &[Vec<u64>],
    k: usize,
    r: usize,
    chunk_w: usize,
) -> Result<Vec<Vec<u64>>> {
    let width = x.first().map_or(0, |row| row.len());
    anyhow::ensure!(
        x.iter().all(|row| row.len() == width),
        "ragged payload rows"
    );
    let mut out = vec![Vec::with_capacity(width); r];
    let mut off = 0;
    while off < width {
        let take = chunk_w.min(width - off);
        // Zero-pad the tail chunk to the artifact width.
        let mut x_flat = vec![0u64; k * chunk_w];
        for (i, row) in x.iter().enumerate() {
            x_flat[i * chunk_w..i * chunk_w + take].copy_from_slice(&row[off..off + take]);
        }
        let y = enc.encode_u64(a_flat, &x_flat)?;
        for (j, row) in out.iter_mut().enumerate() {
            row.extend_from_slice(&y[j * chunk_w..j * chunk_w + take]);
        }
        off += take;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{verify, JobConfig};

    #[test]
    fn replay_service_serves_mixed_widths_from_one_compiled_plan() {
        let cfg = JobConfig {
            k: 8,
            r: 4,
            w: 4,
            ..JobConfig::default()
        };
        // Same config ⇒ same deterministic code/parity as the service.
        let oracle_job = EncodeJob::synthetic(cfg.clone()).unwrap();
        let f = cfg.any_field().unwrap();
        let svc = EncodeService::start_replay(&cfg, 1, 8).unwrap();
        let mut rng = crate::util::Rng::new(9);
        let mut pending = Vec::new();
        for w in [4usize, 9, 1, 4] {
            let x: Vec<Vec<u64>> = (0..cfg.k)
                .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
                .collect();
            pending.push((x.clone(), svc.submit(x).unwrap()));
        }
        for (x, rx) in pending {
            let resp = rx.recv().unwrap();
            let y = resp.y.expect("replay encode ok");
            assert_eq!(y.len(), cfg.r);
            assert!(verify::native(&f, &oracle_job.parity, &x, &y));
        }
        // One worker: first request compiled (miss), the rest replayed.
        assert_eq!(svc.metrics.plan_cache(), (3, 1));
        let j = svc.metrics.to_json();
        assert!(j.contains("\"plan_cache_hits\":3"), "{j}");
        assert!(j.contains("\"plan_cache_misses\":1"), "{j}");
        assert_eq!(svc.metrics.counter("requests"), 4);
        svc.shutdown();
    }
}

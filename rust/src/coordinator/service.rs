//! The batch-encode service — the serving-path face of the system.
//!
//! Worker threads consume [`EncodeRequest`]s (K payload rows of arbitrary
//! width) from a bounded queue and reply on a per-request channel.
//! Bounded-queue submission gives natural backpressure; metrics record
//! throughput and latency percentiles. Two engines:
//!
//! * [`EncodeService::start`] — the PJRT path: chunk rows to the AOT
//!   artifact's width `W` and run the compiled GF(p) kernel
//!   (`runtime::GfEncoder`).
//! * [`EncodeService::start_replay`] — the plan-replay path: compile the
//!   shape's decentralized schedule **once** into a
//!   [`CompiledPlan`](crate::framework::CompiledPlan) (first request =
//!   one cache miss) and replay its optimized form for every request —
//!   no per-request planning or round stepping, any payload width, no
//!   artifacts needed. Workers **micro-batch**: having taken one
//!   request, a worker keeps draining the queue until it holds
//!   [`BatchPolicy::max_batch`] requests or [`BatchPolicy::max_delay`]
//!   has elapsed, then serves the whole batch in one columnar
//!   [`replay_batch`](crate::net::exec::replay_batch) pass per payload
//!   width. Cache hit/miss, batch-size/occupancy and throughput
//!   counters all land in the service metrics summary.
//!
//! Malformed payloads (wrong row count, ragged or empty widths) are
//! rejected with a proper `Err` — at [`EncodeService::submit`] before
//! they ever enqueue, and again per request inside the batch worker, so
//! one bad request can neither poison a batch nor kill a worker.
//!
//! (The offline build has no tokio; std threads + mpsc channels provide
//! the same architecture — see DESIGN.md §1.)

use super::job::EncodeJob;
use super::metrics::Metrics;
use super::plan_cache::PlanCache;
use crate::gf::{Field, Mat};
use crate::runtime::Runtime;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A batch of payloads to encode: `x[k]` is source `k`'s row (all rows
/// the same width, any width — the service chunks internally).
pub struct EncodeRequest {
    pub x: Vec<Vec<u64>>,
    /// Reply channel.
    pub reply: mpsc::Sender<EncodeResponse>,
}

/// Parity rows `y[r]`, one per sink, same width as the request.
#[derive(Debug)]
pub struct EncodeResponse {
    pub y: Result<Vec<Vec<u64>>>,
    pub wall: std::time::Duration,
}

/// Micro-batching policy for the replay service: a worker that has
/// taken one request keeps draining the queue until it holds
/// `max_batch` requests or `max_delay` has passed since the first take,
/// then serves everything it collected in one columnar pass per payload
/// width. `max_batch = 1` degenerates to request-at-a-time serving.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Largest number of requests served in one `replay_batch` call.
    pub max_batch: usize,
    /// Longest a taken request waits for co-batched company.
    pub max_delay: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 32,
            max_delay: Duration::from_micros(500),
        }
    }
}

/// A running encode service over a fixed code (parity matrix).
pub struct EncodeService {
    tx: Option<mpsc::SyncSender<EncodeRequest>>,
    workers: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    k: usize,
}

impl EncodeService {
    /// Start `n_workers` threads, each with its own compiled encoder for
    /// `(K, R, W=chunk)` from the artifact directory.
    pub fn start<F: Field>(
        f: &F,
        parity: &Mat,
        artifacts_dir: &Path,
        chunk_w: usize,
        n_workers: usize,
        queue_depth: usize,
    ) -> Result<Self> {
        let (k, r) = (parity.rows, parity.cols);
        let a_flat: Arc<Vec<u64>> =
            Arc::new((0..k).flat_map(|i| parity.row(i).to_vec()).collect());
        let (tx, rx) = mpsc::sync_channel::<EncodeRequest>(queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::new());
        let stop = Arc::new(AtomicBool::new(false));
        let q = f.order();
        let mut workers = Vec::new();
        for wid in 0..n_workers {
            let rx = rx.clone();
            let metrics = metrics.clone();
            let stop = stop.clone();
            let a_flat = a_flat.clone();
            let dir = artifacts_dir.to_path_buf();
            let handle = std::thread::Builder::new()
                .name(format!("encode-worker-{wid}"))
                .spawn(move || {
                    // Per-worker PJRT session + compiled executable: the
                    // request path never leaves rust.
                    let rt = match Runtime::cpu() {
                        Ok(rt) => rt,
                        Err(e) => {
                            metrics.incr("worker_init_failures", 1);
                            eprintln!("worker {wid}: PJRT init failed: {e:#}");
                            return;
                        }
                    };
                    let enc = match rt.load_encoder(&dir, k, r, chunk_w, q) {
                        Ok(enc) => enc,
                        Err(e) => {
                            metrics.incr("worker_init_failures", 1);
                            eprintln!("worker {wid}: encoder load failed: {e:#}");
                            return;
                        }
                    };
                    worker_loop(&rx, &metrics, &stop, |x| {
                        encode_chunked(&enc, &a_flat, x, k, r, chunk_w)
                    });
                })
                .context("spawning worker")?;
            workers.push(handle);
        }
        Ok(EncodeService {
            tx: Some(tx),
            workers,
            metrics,
            stop,
            k,
        })
    }

    /// Start a plan-replay service for the shape described by `cfg` with
    /// the default [`BatchPolicy`]: no PJRT artifacts required. Workers
    /// share one [`PlanCache`] wired to the service metrics; the first
    /// batch compiles the plan (one `plan_cache_misses`), every later
    /// batch replays it. Requests may have any payload width — the
    /// compiled plan is width-independent (each micro-batch is served
    /// with one columnar pass per width it contains).
    pub fn start_replay(
        cfg: &super::JobConfig,
        n_workers: usize,
        queue_depth: usize,
    ) -> Result<Self> {
        Self::start_replay_with(cfg, n_workers, queue_depth, BatchPolicy::default())
    }

    /// Start a **degraded** replay service: every request is served
    /// through the fault-injected replay path (`faults` applied to the
    /// shape's compiled schedule), and lost sink outputs are
    /// **repaired** — reconstructed from the surviving coordinates via
    /// the code's redundancy (`codes::recovery`) — instead of
    /// re-encoded. Responses carry all `R` parity rows, bit-identical
    /// to the healthy service's, as long as the failure pattern leaves
    /// `K` coordinates alive; the `faults_injected` /
    /// `outputs_recovered` counters and the `recovery_latency`
    /// histogram land in the service metrics next to the batch and
    /// plan-cache counters.
    pub fn start_degraded(
        cfg: &super::JobConfig,
        n_workers: usize,
        queue_depth: usize,
        faults: crate::net::FaultSpec,
    ) -> Result<Self> {
        Self::start_replay_inner(cfg, n_workers, queue_depth, BatchPolicy::default(), Some(faults))
    }

    /// [`start_replay`](EncodeService::start_replay) with an explicit
    /// micro-batching policy.
    pub fn start_replay_with(
        cfg: &super::JobConfig,
        n_workers: usize,
        queue_depth: usize,
        policy: BatchPolicy,
    ) -> Result<Self> {
        Self::start_replay_inner(cfg, n_workers, queue_depth, policy, None)
    }

    /// The shared replay-service spawner: healthy micro-batching when
    /// `faults` is `None`, the degraded repair path otherwise.
    fn start_replay_inner(
        cfg: &super::JobConfig,
        n_workers: usize,
        queue_depth: usize,
        policy: BatchPolicy,
        faults: Option<crate::net::FaultSpec>,
    ) -> Result<Self> {
        anyhow::ensure!(policy.max_batch >= 1, "batch policy needs max_batch >= 1");
        // Build the (field, code, parity) triple once; the synthetic
        // inputs are ignored — requests carry their own payloads.
        let job = Arc::new(EncodeJob::synthetic(cfg.clone())?);
        let faults = Arc::new(faults);
        let k = cfg.k;
        let (tx, rx) = mpsc::sync_channel::<EncodeRequest>(queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::new());
        let cache = Arc::new(PlanCache::with_metrics(metrics.clone()));
        let stop = Arc::new(AtomicBool::new(false));
        let mut workers = Vec::new();
        for wid in 0..n_workers {
            let rx = rx.clone();
            let metrics = metrics.clone();
            let stop = stop.clone();
            let job = job.clone();
            let cache = cache.clone();
            let faults = faults.clone();
            let handle = std::thread::Builder::new()
                .name(format!("replay-worker-{wid}"))
                .spawn(move || {
                    let metrics_for_recovery = metrics.clone();
                    batch_worker_loop(&rx, &metrics, &stop, k, policy, move |jobs| {
                        match &*faults {
                            None => job.encode_batch_cached(&cache, jobs),
                            Some(spec) => {
                                let (ys, stats) =
                                    job.encode_degraded_batch_cached(&cache, jobs, spec)?;
                                let m = &metrics_for_recovery;
                                let injected = stats.faults_injected * jobs.len() as u64;
                                m.incr(super::metrics::FAULTS_INJECTED, injected);
                                m.incr(super::metrics::OUTPUTS_RECOVERED, stats.outputs_recovered);
                                m.observe(super::metrics::RECOVERY_LATENCY, stats.recovery_wall);
                                Ok(ys)
                            }
                        }
                    })
                })
                .context("spawning replay worker")?;
            workers.push(handle);
        }
        Ok(EncodeService {
            tx: Some(tx),
            workers,
            metrics,
            stop,
            k,
        })
    }

    /// Submit a batch (blocks when the queue is full — backpressure).
    /// Malformed payloads — wrong row count, ragged or empty widths —
    /// are rejected here with an `Err` before they enqueue.
    pub fn submit(&self, x: Vec<Vec<u64>>) -> Result<mpsc::Receiver<EncodeResponse>> {
        validate_payload(self.k, &x)?;
        self.enqueue(x)
    }

    /// The shared enqueue path: build the reply channel and send the
    /// request into the bounded queue.
    fn enqueue(&self, x: Vec<Vec<u64>>) -> Result<mpsc::Receiver<EncodeResponse>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .as_ref()
            .context("service stopped")?
            .send(EncodeRequest { x, reply })
            .ok()
            .context("service stopped")?;
        Ok(rx)
    }

    /// Test-only: enqueue a payload *without* submit-side validation, to
    /// exercise the worker's own shape checks.
    #[cfg(test)]
    fn submit_unchecked(&self, x: Vec<Vec<u64>>) -> Result<mpsc::Receiver<EncodeResponse>> {
        self.enqueue(x)
    }

    /// Drain and stop all workers.
    pub fn shutdown(mut self) {
        self.tx.take(); // close the queue
        self.stop.store(true, Ordering::Relaxed);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// The worker protocol shared by both engines: poll the stop flag, drain
/// the bounded queue (50ms poll so shutdown is prompt), time each
/// request, record the `requests`/`failures`/`encode_latency` metrics,
/// reply on the per-request channel. `encode` is the only per-engine
/// part.
fn worker_loop(
    rx: &Mutex<mpsc::Receiver<EncodeRequest>>,
    metrics: &Metrics,
    stop: &AtomicBool,
    encode: impl Fn(&[Vec<u64>]) -> Result<Vec<Vec<u64>>>,
) {
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let req = {
            let guard = rx.lock().unwrap();
            match guard.recv_timeout(std::time::Duration::from_millis(50)) {
                Ok(req) => req,
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        };
        let t0 = Instant::now();
        let y = encode(&req.x);
        let wall = t0.elapsed();
        metrics.incr("requests", 1);
        if y.is_err() {
            metrics.incr("failures", 1);
        }
        metrics.observe("encode_latency", wall);
        let _ = req.reply.send(EncodeResponse { y, wall });
    }
}

/// Shape-check one submitted payload: exactly `k` rows, uniform nonzero
/// width. Shared by [`EncodeService::submit`] and the batch worker.
fn validate_payload(k: usize, x: &[Vec<u64>]) -> Result<()> {
    anyhow::ensure!(
        x.len() == k,
        "need K = {k} payload rows, got {}",
        x.len()
    );
    let w = x.first().map_or(0, |r| r.len());
    anyhow::ensure!(w > 0, "empty payload rows (width 0)");
    anyhow::ensure!(x.iter().all(|r| r.len() == w), "ragged payload rows");
    Ok(())
}

/// The micro-batching worker protocol of the replay engine: take one
/// request (50ms poll so shutdown stays prompt), then keep draining the
/// queue until the batch holds `policy.max_batch` requests or
/// `policy.max_delay` has elapsed, and serve the whole batch. The queue
/// lock is held only while collecting — the encode itself runs
/// lock-free so other workers can collect their own batches meanwhile.
fn batch_worker_loop(
    rx: &Mutex<mpsc::Receiver<EncodeRequest>>,
    metrics: &Metrics,
    stop: &AtomicBool,
    k: usize,
    policy: BatchPolicy,
    encode_batch: impl Fn(&[&[Vec<u64>]]) -> Result<Vec<Vec<Vec<u64>>>>,
) {
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let mut batch: Vec<EncodeRequest> = Vec::with_capacity(policy.max_batch);
        let disconnected = {
            let guard = rx.lock().unwrap();
            match guard.recv_timeout(Duration::from_millis(50)) {
                Ok(req) => batch.push(req),
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
            let deadline = Instant::now() + policy.max_delay;
            let mut disconnected = false;
            while batch.len() < policy.max_batch {
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    break;
                }
                match guard.recv_timeout(left) {
                    Ok(req) => batch.push(req),
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        disconnected = true;
                        break;
                    }
                }
            }
            disconnected
        };
        serve_batch(batch, metrics, k, &encode_batch);
        if disconnected {
            // The queue closed while collecting: the batch just served
            // was the drain's tail — nothing more will arrive.
            break;
        }
    }
}

/// Serve one collected micro-batch: shape-validate each request (bad
/// ones get their own `Err` reply and never poison the batch), group
/// the valid ones by payload width, run one columnar `encode_batch`
/// pass per width, and reply per request **as its width group
/// finishes** — a request's `wall` / `encode_latency` is the serve time
/// of its own group, not of the whole batch (queueing delay inside the
/// collection window is not included; `batch_latency` covers the full
/// serve). Records the batch-size/occupancy/throughput counters.
fn serve_batch(
    batch: Vec<EncodeRequest>,
    metrics: &Metrics,
    k: usize,
    encode_batch: &impl Fn(&[&[Vec<u64>]]) -> Result<Vec<Vec<Vec<u64>>>>,
) {
    let batch_t0 = Instant::now();
    let mut valid: Vec<Option<EncodeRequest>> = Vec::with_capacity(batch.len());
    for req in batch {
        if let Err(e) = validate_payload(k, &req.x) {
            metrics.incr("requests", 1);
            metrics.incr("failures", 1);
            let _ = req.reply.send(EncodeResponse {
                y: Err(e),
                wall: batch_t0.elapsed(),
            });
        } else {
            valid.push(Some(req));
        }
    }
    if valid.is_empty() {
        return;
    }
    metrics.record_batch(valid.len() as u64);

    // One columnar pass per payload width (mixed-width batches split
    // into width groups; single-width traffic gets exactly one pass).
    let mut by_width: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for (i, req) in valid.iter().enumerate() {
        let req = req.as_ref().expect("request present before serving");
        by_width.entry(req.x[0].len()).or_default().push(i);
    }
    let mut elems = 0u64;
    for idxs in by_width.values() {
        let jobs: Vec<&[Vec<u64>]> = idxs
            .iter()
            .map(|&i| valid[i].as_ref().expect("unserved request").x.as_slice())
            .collect();
        let t0 = Instant::now();
        let result = encode_batch(&jobs);
        drop(jobs);
        let wall = t0.elapsed();
        match result {
            Ok(ys) => {
                for (&slot, y) in idxs.iter().zip(ys) {
                    let req = valid[slot].take().expect("reply slot served once");
                    metrics.incr("requests", 1);
                    elems += y.iter().map(|r| r.len() as u64).sum::<u64>();
                    metrics.observe("encode_latency", wall);
                    let _ = req.reply.send(EncodeResponse { y: Ok(y), wall });
                }
            }
            Err(e) => {
                // Group-level failure: every request in the width group
                // carries the error (anyhow errors don't clone — each
                // reply gets the formatted chain). A kernel layout or
                // arena-shape mismatch — a plan paired with buffers
                // packed for a different field, or mis-sized arenas —
                // used to panic the batcher thread; it is now a typed
                // rejection ([`KernelError`]) with its own counter.
                //
                // [`KernelError`]: crate::gf::kernels::KernelError
                if e.chain().any(|c| {
                    c.downcast_ref::<crate::gf::kernels::LayoutMismatch>().is_some()
                        || c.downcast_ref::<crate::gf::kernels::KernelError>().is_some()
                }) {
                    metrics.incr(super::metrics::KERNEL_LAYOUT_REJECTS, idxs.len() as u64);
                }
                let msg = format!("{e:#}");
                for &slot in idxs {
                    let req = valid[slot].take().expect("reply slot served once");
                    metrics.incr("requests", 1);
                    metrics.incr("failures", 1);
                    metrics.observe("encode_latency", wall);
                    let _ = req.reply.send(EncodeResponse {
                        y: Err(anyhow::anyhow!(msg.clone())),
                        wall,
                    });
                }
            }
        }
    }
    metrics.incr(super::metrics::ENCODED_ELEMS, elems);
    metrics.observe("batch_latency", batch_t0.elapsed());
}

/// Encode arbitrary-width payloads by chunking to the artifact width.
fn encode_chunked(
    enc: &crate::runtime::GfEncoder,
    a_flat: &[u64],
    x: &[Vec<u64>],
    k: usize,
    r: usize,
    chunk_w: usize,
) -> Result<Vec<Vec<u64>>> {
    let width = x.first().map_or(0, |row| row.len());
    anyhow::ensure!(
        x.iter().all(|row| row.len() == width),
        "ragged payload rows"
    );
    let mut out = vec![Vec::with_capacity(width); r];
    let mut off = 0;
    while off < width {
        let take = chunk_w.min(width - off);
        // Zero-pad the tail chunk to the artifact width.
        let mut x_flat = vec![0u64; k * chunk_w];
        for (i, row) in x.iter().enumerate() {
            x_flat[i * chunk_w..i * chunk_w + take].copy_from_slice(&row[off..off + take]);
        }
        let y = enc.encode_u64(a_flat, &x_flat)?;
        for (j, row) in out.iter_mut().enumerate() {
            row.extend_from_slice(&y[j * chunk_w..j * chunk_w + take]);
        }
        off += take;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{verify, JobConfig};

    #[test]
    fn replay_service_serves_mixed_widths_from_one_compiled_plan() {
        let cfg = JobConfig {
            k: 8,
            r: 4,
            w: 4,
            ..JobConfig::default()
        };
        // Same config ⇒ same deterministic code/parity as the service.
        let oracle_job = EncodeJob::synthetic(cfg.clone()).unwrap();
        let f = cfg.any_field().unwrap();
        let svc = EncodeService::start_replay(&cfg, 1, 8).unwrap();
        let mut rng = crate::util::Rng::new(9);
        // Sequential submit/await so every request lands in its own
        // micro-batch — the cache accounting below stays deterministic.
        for w in [4usize, 9, 1, 4] {
            let x: Vec<Vec<u64>> = (0..cfg.k)
                .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
                .collect();
            let rx = svc.submit(x.clone()).unwrap();
            let resp = rx.recv().unwrap();
            let y = resp.y.expect("replay encode ok");
            assert_eq!(y.len(), cfg.r);
            assert!(verify::native(&f, &oracle_job.parity, &x, &y));
        }
        // One worker: the first batch compiled (miss), the rest replayed.
        assert_eq!(svc.metrics.plan_cache(), (3, 1));
        let j = svc.metrics.to_json();
        assert!(j.contains("\"plan_cache_hits\":3"), "{j}");
        assert!(j.contains("\"plan_cache_misses\":1"), "{j}");
        assert_eq!(svc.metrics.counter("requests"), 4);
        // Four single-request micro-batches.
        assert_eq!(svc.metrics.batch_stats(), (4, 4, 1));
        svc.shutdown();
    }

    #[test]
    fn submit_rejects_malformed_payloads_and_workers_survive() {
        let cfg = JobConfig {
            k: 4,
            r: 2,
            w: 4,
            ..JobConfig::default()
        };
        let f = cfg.any_field().unwrap();
        let oracle_job = EncodeJob::synthetic(cfg.clone()).unwrap();
        let svc = EncodeService::start_replay(&cfg, 1, 8).unwrap();
        // Submit-side rejection: wrong K, ragged rows, empty width.
        assert!(svc.submit(vec![vec![1, 2]; 3]).is_err(), "wrong K");
        assert!(
            svc.submit(vec![vec![1, 2], vec![1, 2], vec![1], vec![1, 2]])
                .is_err(),
            "ragged rows"
        );
        assert!(svc.submit(vec![Vec::new(); 4]).is_err(), "empty width");
        // Worker-side rejection: bypass submit's checks — the worker
        // must reply with a proper Err, not die on a downstream panic.
        let rx = svc.submit_unchecked(vec![vec![7, 7], vec![7]]).unwrap();
        let resp = rx.recv().expect("worker replied instead of dying");
        assert!(resp.y.is_err());
        let rx = svc.submit_unchecked(vec![Vec::new(); 4]).unwrap();
        assert!(rx.recv().unwrap().y.is_err(), "empty width at the worker");
        // Non-canonical field elements: a proper Err reply (the encode
        // paths validate the canonical range), not a dead worker.
        let rx = svc
            .submit_unchecked(vec![vec![1 << 40, 2], vec![1, 2], vec![1, 2], vec![1, 2]])
            .unwrap();
        assert!(rx.recv().expect("worker survived").y.is_err(), "non-canonical");
        // The same worker still serves well-formed requests afterwards.
        let x: Vec<Vec<u64>> = (0..cfg.k).map(|i| vec![i as u64 + 1, 3]).collect();
        let y = svc.submit(x.clone()).unwrap().recv().unwrap().y.unwrap();
        assert!(verify::native(&f, &oracle_job.parity, &x, &y));
        assert_eq!(svc.metrics.counter("failures"), 3);
        svc.shutdown();
    }

    #[test]
    fn kernel_layout_mismatch_is_a_counted_rejection_not_a_dead_worker() {
        use crate::gf::kernels::Kernels;
        // Drive the batch-serving tail with an encode path that trips
        // the typed layout mismatch (prime kernels against GF(2^8)
        // buffers — what used to be a batcher-killing panic): the
        // request must get a proper Err reply and the dedicated counter
        // must move alongside the generic failure count.
        let metrics = Metrics::new();
        let (tx, reply_rx) = mpsc::channel();
        let req = EncodeRequest {
            x: vec![vec![1u64]; 4],
            reply: tx,
        };
        let encode = |_jobs: &[&[Vec<u64>]]| -> Result<Vec<Vec<Vec<u64>>>> {
            let prime = Kernels::for_field(&crate::gf::GfPrime::default_field());
            let wrong = Kernels::for_field(&crate::gf::Gf2e::new(8).unwrap());
            let b = wrong.zeros(4);
            let mut out = wrong.zeros(4);
            let row: &[u64] = &[1, 2, 3, 4];
            prime.gemm_rows(&[row], &b, 4, &mut out, false)?;
            unreachable!("mismatched layouts must error");
        };
        serve_batch(vec![req], &metrics, 4, &encode);
        let resp = reply_rx.recv().expect("a reply, not a panic");
        let err = resp.y.unwrap_err();
        assert!(err.to_string().contains("does not match"), "{err}");
        assert_eq!(metrics.counter("failures"), 1);
        assert_eq!(
            metrics.counter(crate::coordinator::metrics::KERNEL_LAYOUT_REJECTS),
            1
        );
    }

    #[test]
    fn one_mixed_width_batch_splits_into_width_groups_without_crossing_replies() {
        let cfg = JobConfig {
            k: 5,
            r: 3,
            w: 4,
            ..JobConfig::default()
        };
        let f = cfg.any_field().unwrap();
        let oracle_job = EncodeJob::synthetic(cfg.clone()).unwrap();
        // Widths deliberately interleaved: the reply-index remapping
        // across the three width groups must route every group's rows
        // back to the right request.
        let widths = [3usize, 7, 3, 1, 7, 3];
        let svc = EncodeService::start_replay_with(
            &cfg,
            1,
            16,
            BatchPolicy {
                max_batch: widths.len(),
                max_delay: std::time::Duration::from_secs(5),
            },
        )
        .unwrap();
        let mut rng = crate::util::Rng::new(47);
        let mut pending = Vec::new();
        for &w in &widths {
            let x: Vec<Vec<u64>> = (0..cfg.k)
                .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
                .collect();
            pending.push((x.clone(), svc.submit(x).unwrap()));
        }
        for (x, rx) in pending {
            let y = rx.recv().unwrap().y.expect("mixed-width batch ok");
            assert_eq!(y.len(), cfg.r);
            // Random payloads per request: a crossed reply (another
            // request's rows, or another width group's) fails the
            // parity verification against this request's own x.
            assert!(verify::native(&f, &oracle_job.parity, &x, &y));
        }
        // One batch of six requests, served as three width groups:
        // one plan compile, then a cache hit per further group.
        assert_eq!(svc.metrics.batch_stats(), (1, widths.len() as u64, widths.len() as u64));
        assert_eq!(svc.metrics.plan_cache(), (2, 1));
        assert_eq!(svc.metrics.counter("requests"), widths.len() as u64);
        assert_eq!(svc.metrics.counter("failures"), 0);
        svc.shutdown();
    }

    #[test]
    fn degraded_service_repairs_failed_sinks_transparently() {
        let cfg = JobConfig {
            k: 8,
            r: 4,
            w: 4,
            ..JobConfig::default()
        };
        let f = cfg.any_field().unwrap();
        let oracle_job = EncodeJob::synthetic(cfg.clone()).unwrap();
        // Two sinks lost after encoding (storage-loss scenario) plus one
        // source: the service must keep answering with all R rows.
        let faults = crate::net::FaultSpec::new()
            .crash_after(8)
            .crash_after(10)
            .crash_after(2);
        let n_faults = faults.injected();
        let svc = EncodeService::start_degraded(&cfg, 1, 8, faults).unwrap();
        let mut rng = crate::util::Rng::new(77);
        let n_req = 3usize;
        for _ in 0..n_req {
            let x: Vec<Vec<u64>> = (0..cfg.k)
                .map(|_| (0..cfg.w).map(|_| rng.below(f.order())).collect())
                .collect();
            let y = svc.submit(x.clone()).unwrap().recv().unwrap().y.unwrap();
            assert_eq!(y.len(), cfg.r, "all R rows, repaired ones included");
            // A repaired row that diverged from x·A fails verification.
            assert!(verify::native(&f, &oracle_job.parity, &x, &y));
        }
        assert_eq!(
            svc.metrics.counter(super::super::metrics::FAULTS_INJECTED),
            n_faults * n_req as u64
        );
        assert_eq!(
            svc.metrics.counter(super::super::metrics::OUTPUTS_RECOVERED),
            2 * n_req as u64,
            "two sinks repaired per request"
        );
        assert!(svc
            .metrics
            .latency_summary(super::super::metrics::RECOVERY_LATENCY)
            .is_some());
        svc.shutdown();
    }

    #[test]
    fn micro_batching_coalesces_requests_into_one_columnar_pass() {
        let cfg = JobConfig {
            k: 6,
            r: 3,
            w: 5,
            ..JobConfig::default()
        };
        let f = cfg.any_field().unwrap();
        let oracle_job = EncodeJob::synthetic(cfg.clone()).unwrap();
        let n_req = 8usize;
        // One worker, a batch window big enough that all requests (sent
        // back-to-back below) coalesce into exactly one micro-batch.
        let svc = EncodeService::start_replay_with(
            &cfg,
            1,
            16,
            BatchPolicy {
                max_batch: n_req,
                max_delay: std::time::Duration::from_secs(5),
            },
        )
        .unwrap();
        let mut rng = crate::util::Rng::new(31);
        let mut pending = Vec::new();
        for _ in 0..n_req {
            let x: Vec<Vec<u64>> = (0..cfg.k)
                .map(|_| (0..cfg.w).map(|_| rng.below(f.order())).collect())
                .collect();
            pending.push((x.clone(), svc.submit(x).unwrap()));
        }
        for (x, rx) in pending {
            let y = rx.recv().unwrap().y.expect("batched encode ok");
            assert!(verify::native(&f, &oracle_job.parity, &x, &y));
        }
        let (batches, batched, occ_max) = svc.metrics.batch_stats();
        assert_eq!(batched, n_req as u64);
        assert_eq!(occ_max, n_req as u64, "all requests in one batch");
        assert_eq!(batches, 1);
        // One compile for the whole batch; throughput counter adds up.
        assert_eq!(svc.metrics.plan_cache(), (0, 1));
        assert_eq!(
            svc.metrics.counter(super::super::metrics::ENCODED_ELEMS),
            (n_req * cfg.r * cfg.w) as u64
        );
        svc.shutdown();
    }
}

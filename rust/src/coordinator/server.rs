//! The multi-tenant wire front end: framed encode requests over TCP,
//! served by the event-driven dispatcher ([`EncodeService`]).
//!
//! The wire format is the sans-IO frame codec in
//! [`net::payload`](crate::net::payload): a 40-byte header plus a
//! payload packed at the **field's symbol lane** — a GF(2^8) request
//! ships one byte per element, a `prime:786433` request four — so the
//! wire sees the same narrow-lane zero-copy-friendly representation the
//! kernels stream. Requests carry `(tenant, req_id)`; responses echo
//! `req_id` and may arrive **out of request order** (batches complete
//! per width group), which is what lets one connection pipeline freely.
//!
//! Per-request failures — malformed payloads, admission rejections
//! ([`ServeRejection`](super::service::ServeRejection)) — come back as
//! `Error` frames on the same connection, which stays up. Only an
//! unparseable frame (bad magic, impossible header) drops the
//! connection, since there is no way to resync the byte stream.
//!
//! The default front end runs on std threads: one acceptor, one reader
//! plus one writer per connection, all interruptible via a stop flag
//! and socket read timeouts. A `tokio` build of the same front end —
//! sharing this codec and dispatcher — is gated behind the bare
//! `tokio` cargo feature exactly like the `pjrt` stub pair: the
//! offline container has no tokio crate, so the feature only compiles
//! where the dependency is added (see `Cargo.toml` and the CI matrix).

use super::config::JobConfig;
use super::metrics::{self, Metrics};
use super::service::{EncodeResponse, EncodeService};
use crate::gf::kernels::{Kernels, SymbolLayout};
use crate::net::payload::{
    decode_rows_frame, encode_error_frame, encode_rows_frame, frame_error_message, FrameHeader,
    FrameKind, FRAME_HEADER_LEN,
};
use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// How often blocked socket reads wake up to check the stop flag.
const POLL_TIMEOUT: Duration = Duration::from_millis(100);

/// The symbol lane this config's field uses on the wire — the same
/// layout-selection rule as the compiled kernels.
pub fn wire_layout(cfg: &JobConfig) -> Result<SymbolLayout> {
    Ok(Kernels::for_field(&cfg.any_field()?).layout())
}

fn read_exact_interruptible(
    stream: &mut TcpStream,
    buf: &mut [u8],
    stop: &AtomicBool,
) -> Result<ReadOutcome> {
    let mut off = 0;
    while off < buf.len() {
        match stream.read(&mut buf[off..]) {
            Ok(0) => {
                // Clean EOF only between frames; inside one it's a cut.
                return Ok(if off == 0 {
                    ReadOutcome::Eof
                } else {
                    ReadOutcome::Cut
                });
            }
            Ok(n) => off += n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if stop.load(Ordering::Relaxed) {
                    return Ok(ReadOutcome::Stopped);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(ReadOutcome::Full)
}

enum ReadOutcome {
    Full,
    Eof,
    Cut,
    Stopped,
}

/// A running TCP front end over one [`EncodeService`].
pub struct WireServer {
    svc: Option<EncodeService>,
    metrics: Arc<Metrics>,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    writers: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl WireServer {
    /// Bind `addr` (use `127.0.0.1:0` for an ephemeral port) and serve
    /// the shape described by `cfg` with `n_workers` encode workers and
    /// the batching/admission knobs from `cfg.serve`.
    pub fn start(cfg: &JobConfig, addr: &str, n_workers: usize) -> Result<WireServer> {
        let layout = wire_layout(cfg)?;
        let svc = EncodeService::start_replay(cfg, n_workers, cfg.serve.queue_depth)?;
        let metrics = svc.metrics.clone();
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let writers: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let server = WireServer {
            svc: Some(svc),
            metrics: metrics.clone(),
            addr: local,
            stop: stop.clone(),
            acceptor: None,
            conns: conns.clone(),
            writers: writers.clone(),
        };
        // The acceptor owns the listener; shutdown unblocks it with a
        // wake-up connection after raising the stop flag. Connections
        // submit through a cloneable handle that shares the dispatcher,
        // so the service stays owned here for the graceful shutdown.
        let submit: Arc<SubmitFn> =
            Arc::new(server.svc.as_ref().expect("service just built").submit_handle());
        let acceptor = std::thread::Builder::new()
            .name("wire-acceptor".into())
            .spawn(move || {
                for incoming in listener.incoming() {
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    let stream = match incoming {
                        Ok(s) => s,
                        Err(_) => continue,
                    };
                    metrics.incr(metrics::WIRE_CONNECTIONS, 1);
                    let stop = stop.clone();
                    let metrics = metrics.clone();
                    let svc = submit.clone();
                    let writers = writers.clone();
                    let conn = std::thread::Builder::new()
                        .name("wire-conn".into())
                        .spawn(move || {
                            serve_connection(stream, layout, &svc, &metrics, &stop, &writers);
                        });
                    if let Ok(h) = conn {
                        conns.lock().unwrap().push(h);
                    }
                }
            })
            .context("spawning acceptor")?;
        let mut server = server;
        server.acceptor = Some(acceptor);
        Ok(server)
    }

    /// The bound address (resolves the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The service metrics (wire counters included).
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Stop accepting, drain every queued request to its connection,
    /// and join all threads. Graceful: in-flight requests get real
    /// responses before their writers exit.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Unblock the acceptor's `incoming()`.
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        // Readers notice the stop flag on their next poll tick and drop
        // their reply senders.
        for h in self.conns.lock().unwrap().drain(..) {
            let _ = h.join();
        }
        // Drain the dispatcher: every queued request is served and its
        // reply lands in some connection's channel...
        if let Some(svc) = self.svc.take() {
            svc.shutdown();
        }
        // ...whose writer flushes it before seeing the disconnect.
        for h in self.writers.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

/// The type-erased submit path connection threads hold — produced by
/// [`EncodeService::submit_handle`], shares the dispatcher only.
type SubmitFn =
    dyn Fn(u64, u64, Vec<Vec<u64>>, mpsc::Sender<EncodeResponse>) -> Result<()> + Send + Sync;

/// One connection: this thread reads Request frames and submits them;
/// a paired writer thread streams completion-order responses back.
fn serve_connection(
    stream: TcpStream,
    layout: SymbolLayout,
    svc: &SubmitFn,
    metrics: &Arc<Metrics>,
    stop: &Arc<AtomicBool>,
    writers: &Mutex<Vec<JoinHandle<()>>>,
) {
    let _ = stream.set_read_timeout(Some(POLL_TIMEOUT));
    let _ = stream.set_nodelay(true);
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let (reply_tx, reply_rx) = mpsc::channel::<EncodeResponse>();
    let writer = {
        let metrics = metrics.clone();
        std::thread::Builder::new()
            .name("wire-writer".into())
            .spawn(move || write_responses(write_half, layout, reply_rx, &metrics))
    };
    match writer {
        Ok(h) => writers.lock().unwrap().push(h),
        Err(_) => return,
    }
    let mut stream = stream;
    let mut head = [0u8; FRAME_HEADER_LEN];
    loop {
        match read_exact_interruptible(&mut stream, &mut head, stop) {
            Ok(ReadOutcome::Full) => {}
            Ok(_) => break, // EOF / cut / stopping — reader done.
            Err(_) => break,
        }
        let header = match FrameHeader::parse(&head) {
            Ok(h) if h.kind == FrameKind::Request => h,
            // Unparseable or non-request frame: the stream cannot be
            // resynced — drop the connection.
            _ => {
                metrics.incr(metrics::WIRE_ERRORS, 1);
                break;
            }
        };
        let mut payload = vec![0u8; header.payload_len as usize];
        match read_exact_interruptible(&mut stream, &mut payload, stop) {
            Ok(ReadOutcome::Full) => {}
            _ => break,
        }
        metrics.incr(metrics::WIRE_REQUESTS, 1);
        let rows = match decode_rows_frame(&header, &payload) {
            Ok(rows) => rows,
            Err(e) => {
                // Shape-consistent header but undecodable payload: the
                // framing is intact, so the connection survives.
                metrics.incr(metrics::WIRE_ERRORS, 1);
                let _ = reply_tx.send(EncodeResponse {
                    req_id: header.req_id,
                    y: Err(e),
                    wall: Duration::ZERO,
                });
                continue;
            }
        };
        if let Err(e) = svc(header.tenant, header.req_id, rows, reply_tx.clone()) {
            // Validation or admission refusal (Overloaded /
            // ServiceStopped): a per-request Error frame, not a
            // connection drop.
            metrics.incr(metrics::WIRE_ERRORS, 1);
            let _ = reply_tx.send(EncodeResponse {
                req_id: header.req_id,
                y: Err(e),
                wall: Duration::ZERO,
            });
        }
    }
    // Dropping reply_tx lets the writer exit once every in-flight
    // request of this connection has been answered.
}

/// The per-connection writer: responses (any order) → frames.
fn write_responses(
    mut stream: TcpStream,
    layout: SymbolLayout,
    replies: mpsc::Receiver<EncodeResponse>,
    metrics: &Metrics,
) {
    let mut wire = Vec::new();
    // Blocks until every sender (reader + queued requests) is gone —
    // which is exactly "all of this connection's requests answered".
    while let Ok(resp) = replies.recv() {
        wire.clear();
        match resp.y {
            Ok(rows) => {
                if encode_rows_frame(&mut wire, FrameKind::Response, layout, 0, resp.req_id, &rows)
                    .is_err()
                {
                    wire.clear();
                    encode_error_frame(&mut wire, 0, resp.req_id, "response framing failed");
                    metrics.incr(metrics::WIRE_ERRORS, 1);
                }
            }
            Err(e) => {
                encode_error_frame(&mut wire, 0, resp.req_id, &format!("{e:#}"));
                metrics.incr(metrics::WIRE_ERRORS, 1);
            }
        }
        if stream.write_all(&wire).is_err() {
            break; // peer gone; drain remaining replies to /dev/null
        }
    }
    let _ = stream.flush();
    let _ = stream.shutdown(std::net::Shutdown::Write);
}

/// A minimal blocking client for the wire protocol — the counterpart
/// the load generator and the integration tests drive.
pub struct WireClient {
    stream: TcpStream,
    layout: SymbolLayout,
}

impl WireClient {
    /// Connect to a [`WireServer`]; `layout` must be the server field's
    /// wire lane ([`wire_layout`]).
    pub fn connect(addr: SocketAddr, layout: SymbolLayout) -> Result<WireClient> {
        let stream = TcpStream::connect(addr).context("connecting to wire server")?;
        let _ = stream.set_nodelay(true);
        Ok(WireClient { stream, layout })
    }

    /// Send one encode request (does not wait for the response — the
    /// connection pipelines; match responses by `req_id`).
    pub fn send(&mut self, tenant: u64, req_id: u64, rows: &[Vec<u64>]) -> Result<()> {
        let mut wire = Vec::new();
        encode_rows_frame(&mut wire, FrameKind::Request, self.layout, tenant, req_id, rows)?;
        self.stream.write_all(&wire)?;
        Ok(())
    }

    /// Receive the next response frame: `(req_id, parity rows or the
    /// server's error message)`. Blocks; `Err` means the connection
    /// itself died.
    pub fn recv(&mut self) -> Result<(u64, Result<Vec<Vec<u64>>>)> {
        let mut head = [0u8; FRAME_HEADER_LEN];
        self.stream.read_exact(&mut head).context("reading frame header")?;
        let header = FrameHeader::parse(&head)?;
        let mut payload = vec![0u8; header.payload_len as usize];
        self.stream.read_exact(&mut payload).context("reading frame payload")?;
        match header.kind {
            FrameKind::Response => Ok((header.req_id, Ok(decode_rows_frame(&header, &payload)?))),
            FrameKind::Error => Ok((
                header.req_id,
                Err(anyhow::anyhow!("{}", frame_error_message(&header, &payload))),
            )),
            FrameKind::Request => anyhow::bail!("unexpected request frame from server"),
        }
    }

    /// Half-close: tell the server no more requests are coming, while
    /// keeping the read side open for pending responses.
    pub fn close_send(&mut self) -> Result<()> {
        self.stream.shutdown(std::net::Shutdown::Write)?;
        Ok(())
    }
}

/// The async (tokio) build of the same front end, gated exactly like
/// the `pjrt` feature: the bare `tokio` cargo feature names no
/// dependency the offline container would need, and turning it on
/// requires adding the `tokio` crate to `Cargo.toml` (the CI `tokio`
/// job does this). It shares the sans-IO frame codec and the
/// [`EncodeService`] dispatcher — tasks replace threads, nothing else
/// changes.
#[cfg(feature = "tokio")]
pub mod nonblocking {
    use super::*;
    use tokio::io::{AsyncReadExt, AsyncWriteExt};

    /// Serve `listener` until `shutdown` resolves. One task per
    /// connection reads frames and submits into the shared dispatcher;
    /// a writer task per connection streams completion-order replies.
    pub async fn serve(
        listener: tokio::net::TcpListener,
        svc: std::sync::Arc<EncodeService>,
        layout: SymbolLayout,
        mut shutdown: tokio::sync::watch::Receiver<bool>,
    ) -> Result<()> {
        let metrics = svc.metrics.clone();
        loop {
            let (stream, _peer) = tokio::select! {
                accepted = listener.accept() => accepted?,
                _ = shutdown.changed() => return Ok(()),
            };
            metrics.incr(metrics::WIRE_CONNECTIONS, 1);
            let svc = svc.clone();
            let metrics = metrics.clone();
            tokio::spawn(async move {
                let _ = serve_conn_async(stream, svc, layout, metrics).await;
            });
        }
    }

    async fn serve_conn_async(
        stream: tokio::net::TcpStream,
        svc: std::sync::Arc<EncodeService>,
        layout: SymbolLayout,
        metrics: std::sync::Arc<Metrics>,
    ) -> Result<()> {
        let _ = stream.set_nodelay(true);
        let (mut rd, mut wr) = stream.into_split();
        // Bridge the dispatcher's std-mpsc replies onto an async
        // channel via a blocking forwarder task.
        let (reply_tx, reply_rx) = mpsc::channel::<EncodeResponse>();
        let (async_tx, mut async_rx) = tokio::sync::mpsc::unbounded_channel();
        let forwarder = tokio::task::spawn_blocking(move || {
            while let Ok(resp) = reply_rx.recv() {
                if async_tx.send(resp).is_err() {
                    break;
                }
            }
        });
        let writer_metrics = metrics.clone();
        let writer = tokio::spawn(async move {
            let mut wire = Vec::new();
            while let Some(resp) = async_rx.recv().await {
                wire.clear();
                match resp.y {
                    Ok(rows) => {
                        if encode_rows_frame(
                            &mut wire,
                            FrameKind::Response,
                            layout,
                            0,
                            resp.req_id,
                            &rows,
                        )
                        .is_err()
                        {
                            wire.clear();
                            encode_error_frame(&mut wire, 0, resp.req_id, "response framing failed");
                            writer_metrics.incr(metrics::WIRE_ERRORS, 1);
                        }
                    }
                    Err(e) => {
                        encode_error_frame(&mut wire, 0, resp.req_id, &format!("{e:#}"));
                        writer_metrics.incr(metrics::WIRE_ERRORS, 1);
                    }
                }
                if wr.write_all(&wire).await.is_err() {
                    break;
                }
            }
            let _ = wr.shutdown().await;
        });
        let mut head = [0u8; FRAME_HEADER_LEN];
        loop {
            match rd.read_exact(&mut head).await {
                Ok(_) => {}
                Err(_) => break,
            }
            let header = match FrameHeader::parse(&head) {
                Ok(h) if h.kind == FrameKind::Request => h,
                _ => {
                    metrics.incr(metrics::WIRE_ERRORS, 1);
                    break;
                }
            };
            let mut payload = vec![0u8; header.payload_len as usize];
            if rd.read_exact(&mut payload).await.is_err() {
                break;
            }
            metrics.incr(metrics::WIRE_REQUESTS, 1);
            let submitted = decode_rows_frame(&header, &payload)
                .and_then(|rows| svc.submit_with(header.tenant, header.req_id, rows, reply_tx.clone()));
            if let Err(e) = submitted {
                metrics.incr(metrics::WIRE_ERRORS, 1);
                let _ = reply_tx.send(EncodeResponse {
                    req_id: header.req_id,
                    y: Err(e),
                    wall: Duration::ZERO,
                });
            }
        }
        drop(reply_tx);
        let _ = forwarder.await;
        let _ = writer.await;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::verify;
    use crate::coordinator::EncodeJob;

    fn test_cfg() -> JobConfig {
        JobConfig {
            k: 6,
            r: 3,
            w: 4,
            ..JobConfig::default()
        }
    }

    #[test]
    fn wire_round_trip_matches_the_direct_encode_path() {
        let cfg = test_cfg();
        let f = cfg.any_field().unwrap();
        let oracle = EncodeJob::synthetic(cfg.clone()).unwrap();
        let server = WireServer::start(&cfg, "127.0.0.1:0", 2).unwrap();
        let layout = wire_layout(&cfg).unwrap();
        let mut client = WireClient::connect(server.local_addr(), layout).unwrap();
        let mut rng = crate::util::Rng::new(21);
        // Pipeline several mixed-width requests, then collect by id.
        let mut sent: std::collections::HashMap<u64, Vec<Vec<u64>>> = Default::default();
        for (i, w) in [3usize, 8, 3, 5].into_iter().enumerate() {
            let x: Vec<Vec<u64>> = (0..cfg.k)
                .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
                .collect();
            client.send(7, i as u64, &x).unwrap();
            sent.insert(i as u64, x);
        }
        for _ in 0..sent.len() {
            let (id, y) = client.recv().unwrap();
            let y = y.expect("server answered with parity rows");
            let x = sent.remove(&id).expect("response id matches a request");
            assert_eq!(y.len(), cfg.r);
            assert!(verify::native(&f, &oracle.parity, &x, &y));
        }
        assert_eq!(server.metrics().counter(metrics::WIRE_REQUESTS), 4);
        assert_eq!(server.metrics().counter(metrics::WIRE_CONNECTIONS), 1);
        server.shutdown();
    }

    #[test]
    fn bad_requests_get_error_frames_and_garbage_drops_the_connection() {
        let cfg = test_cfg();
        let server = WireServer::start(&cfg, "127.0.0.1:0", 1).unwrap();
        let layout = wire_layout(&cfg).unwrap();
        // Wrong row count: a per-request Error frame, connection lives.
        let mut client = WireClient::connect(server.local_addr(), layout).unwrap();
        client.send(0, 5, &[vec![1, 2], vec![3, 4]]).unwrap();
        let (id, y) = client.recv().unwrap();
        assert_eq!(id, 5);
        let msg = y.unwrap_err().to_string();
        assert!(msg.contains("K ="), "names the shape problem: {msg}");
        // The same connection still serves a good request.
        let x: Vec<Vec<u64>> = (0..cfg.k).map(|i| vec![i as u64 + 1, 2]).collect();
        client.send(0, 6, &x).unwrap();
        let (id, y) = client.recv().unwrap();
        assert_eq!(id, 6);
        assert_eq!(y.unwrap().len(), cfg.r);
        // Garbage bytes: the stream cannot be resynced — the server
        // closes the connection (read returns EOF / reset).
        let mut raw = TcpStream::connect(server.local_addr()).unwrap();
        raw.write_all(b"this is not a DCE1 frame header....!!....").unwrap();
        let _ = raw.flush();
        let mut buf = [0u8; 16];
        let closed = matches!(raw.read(&mut buf), Ok(0) | Err(_));
        assert!(closed, "server must drop an unparseable connection");
        assert!(server.metrics().counter(metrics::WIRE_ERRORS) >= 2);
        server.shutdown();
    }

    #[test]
    fn shutdown_flushes_pipelined_responses_before_closing() {
        let mut cfg = test_cfg();
        // A wide-open batch window: requests sit queued until the
        // server's graceful shutdown drains them.
        cfg.serve.max_batch = 64;
        cfg.serve.max_delay_us = 5_000_000;
        let f = cfg.any_field().unwrap();
        let server = WireServer::start(&cfg, "127.0.0.1:0", 1).unwrap();
        let layout = wire_layout(&cfg).unwrap();
        let mut client = WireClient::connect(server.local_addr(), layout).unwrap();
        let n = 10u64;
        let mut rng = crate::util::Rng::new(3);
        for i in 0..n {
            let x: Vec<Vec<u64>> = (0..cfg.k)
                .map(|_| (0..4).map(|_| rng.below(f.order())).collect())
                .collect();
            client.send(1, i, &x).unwrap();
        }
        // Wait until the dispatcher has admitted all of them, then shut
        // down: every one must still produce a Response frame.
        while server.metrics().counter(metrics::WIRE_REQUESTS) < n {
            std::thread::sleep(Duration::from_millis(1));
        }
        let handle = std::thread::spawn(move || {
            let mut got = std::collections::HashSet::new();
            for _ in 0..n {
                let (id, y) = client.recv().expect("response before close");
                assert!(y.is_ok());
                got.insert(id);
            }
            assert_eq!(got.len(), n as usize, "each request answered once");
        });
        server.shutdown();
        handle.join().unwrap();
    }
}

//! Output verification: the simulated collective's coded packets must
//! equal `x·A` computed by an independent oracle — either native rust
//! matrix math or the AOT-compiled PJRT artifact (proving the three-layer
//! stack agrees end-to-end).

use crate::gf::{Field, Mat};
use crate::net::{pkt_zero, Packet};
use std::path::Path;

/// Native oracle: direct `x·A` over packets (delayed-reduction lincomb).
pub fn native<F: Field>(f: &F, a: &Mat, inputs: &[Packet], coded: &[Packet]) -> bool {
    let w = inputs.first().map_or(0, |p| p.len());
    if coded.len() != a.cols {
        return false;
    }
    for j in 0..a.cols {
        let mut want = pkt_zero(w);
        let terms: Vec<(u64, &[u64])> = (0..a.rows)
            .map(|i| (a[(i, j)], inputs[i].as_slice()))
            .collect();
        f.lincomb_into(&mut want, &terms);
        if coded[j] != want {
            return false;
        }
    }
    true
}

/// PJRT oracle: run the AOT-compiled `encode` artifact and compare.
/// Requires a matching artifact shape (K, R, W, p) in `dir`.
pub fn pjrt<F: Field>(
    dir: &Path,
    f: &F,
    a: &Mat,
    inputs: &[Packet],
    coded: &[Packet],
) -> anyhow::Result<bool> {
    let (k, r) = (a.rows, a.cols);
    let w = inputs.first().map_or(0, |p| p.len());
    let rt = crate::runtime::Runtime::cpu()?;
    let enc = rt.load_encoder(dir, k, r, w, f.order())?;
    let a_flat: Vec<u64> = (0..k).flat_map(|i| a.row(i).to_vec()).collect();
    let x_flat: Vec<u64> = inputs.iter().flatten().copied().collect();
    let y = enc.encode_u64(&a_flat, &x_flat)?;
    // y is row-major R×W; coded[j] should equal row j.
    Ok((0..r).all(|j| coded[j][..] == y[j * w..(j + 1) * w]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::GfPrime;

    #[test]
    fn native_accepts_correct_and_rejects_wrong() {
        let f = GfPrime::default_field();
        let a = Mat::random(&f, 4, 2, 3);
        let inputs: Vec<Packet> = (0..4u64).map(|i| vec![i + 1, i + 2]).collect();
        let mut coded: Vec<Packet> = (0..2)
            .map(|j| {
                let mut acc = pkt_zero(2);
                for i in 0..4 {
                    crate::net::pkt_add_scaled(&f, &mut acc, a[(i, j)], &inputs[i]);
                }
                acc
            })
            .collect();
        assert!(native(&f, &a, &inputs, &coded));
        coded[1][0] ^= 1;
        assert!(!native(&f, &a, &inputs, &coded));
    }
}

//! Output verification: the simulated collective's coded packets must
//! equal `x·A` computed by an independent oracle — native rust matrix
//! math (full re-encode), a Freivalds-style random projection (sublinear
//! in the matrix volume), or the AOT-compiled PJRT artifact (proving the
//! three-layer stack agrees end-to-end).

use crate::gf::{Field, Mat};
use crate::net::{pkt_zero, Packet};
use crate::util::Rng;
use std::path::Path;

/// Native oracle: direct `x·A` over packets (delayed-reduction lincomb).
pub fn native<F: Field>(f: &F, a: &Mat, inputs: &[Packet], coded: &[Packet]) -> bool {
    let w = inputs.first().map_or(0, |p| p.len());
    if coded.len() != a.cols {
        return false;
    }
    for j in 0..a.cols {
        let mut want = pkt_zero(w);
        let terms: Vec<(u64, &[u64])> = (0..a.rows)
            .map(|i| (a[(i, j)], inputs[i].as_slice()))
            .collect();
        f.lincomb_into(&mut want, &terms);
        if coded[j] != want {
            return false;
        }
    }
    true
}

/// Freivalds-style randomized verification of `x·A = y`.
///
/// Instead of the `O(K·R·W)` full re-encode of [`native`], draw a random
/// projection `u ∈ F^R` and compare
///
/// ```text
/// Σ_j u_j·y_j   ==   Σ_i (Σ_j A[i][j]·u_j) · x_i
/// ```
///
/// — `O(R·W + K·R + K·W)` work per round. A wrong codeword survives one
/// round with probability ≤ 1/q, so `rounds` trials push the error below
/// `q^{-rounds}` (≈ 2^{-40} for the default field at `rounds = 2`).
/// Deterministic for a fixed `seed` — regression tests can pin a
/// known-bad codeword and the projection that rejects it.
pub fn freivalds<F: Field>(
    f: &F,
    a: &Mat,
    inputs: &[Packet],
    coded: &[Packet],
    seed: u64,
    rounds: u32,
) -> bool {
    let w = inputs.first().map_or(0, |p| p.len());
    if coded.len() != a.cols
        || inputs.len() != a.rows
        || inputs.iter().any(|p| p.len() != w)
        || coded.iter().any(|p| p.len() != w)
    {
        return false;
    }
    let mut rng = Rng::new(seed);
    for _ in 0..rounds.max(1) {
        let u: Vec<u64> = (0..a.cols).map(|_| rng.below(f.order())).collect();
        // lhs = Σ_j u_j·y_j  — O(R·W).
        let mut lhs = pkt_zero(w);
        let terms: Vec<(u64, &[u64])> = u
            .iter()
            .zip(coded)
            .map(|(&c, p)| (c, p.as_slice()))
            .collect();
        f.lincomb_into(&mut lhs, &terms);
        // v_i = Σ_j A[i][j]·u_j — O(K·R); rhs = Σ_i v_i·x_i — O(K·W).
        let v: Vec<u64> = (0..a.rows)
            .map(|i| {
                let mut acc = 0u64;
                for (&aij, &uj) in a.row(i).iter().zip(&u) {
                    acc = f.mul_add(acc, aij, uj);
                }
                acc
            })
            .collect();
        let mut rhs = pkt_zero(w);
        let terms: Vec<(u64, &[u64])> = v
            .iter()
            .zip(inputs)
            .map(|(&c, p)| (c, p.as_slice()))
            .collect();
        f.lincomb_into(&mut rhs, &terms);
        if lhs != rhs {
            return false;
        }
    }
    true
}

/// Freivalds-check a plan **replay**: pull the sink packets out of a
/// [`Replay`](crate::net::exec::Replay)'s output map in sink order and
/// random-project them against `x·A` — the sublinear integrity check for
/// the cached serving path (a replayed plan is only as trustworthy as
/// the compilation run; this catches a stale or corrupted cache entry
/// with error probability ≤ `q^{-rounds}`).
pub fn freivalds_replay<F: Field>(
    f: &F,
    a: &Mat,
    inputs: &[Packet],
    replay: &crate::net::exec::Replay,
    layout: &crate::framework::Layout,
    seed: u64,
    rounds: u32,
) -> bool {
    let coded: Vec<Packet> = (0..layout.r)
        .filter_map(|r| replay.outputs.get(&layout.sink(r)).cloned())
        .collect();
    // A sink missing from the replay surfaces as a length mismatch,
    // which `freivalds` rejects.
    freivalds(f, a, inputs, &coded, seed, rounds)
}

/// PJRT oracle: run the AOT-compiled `encode` artifact and compare.
/// Requires a matching artifact shape (K, R, W, p) in `dir`.
pub fn pjrt<F: Field>(
    dir: &Path,
    f: &F,
    a: &Mat,
    inputs: &[Packet],
    coded: &[Packet],
) -> anyhow::Result<bool> {
    let (k, r) = (a.rows, a.cols);
    let w = inputs.first().map_or(0, |p| p.len());
    let rt = crate::runtime::Runtime::cpu()?;
    let enc = rt.load_encoder(dir, k, r, w, f.order())?;
    let a_flat: Vec<u64> = (0..k).flat_map(|i| a.row(i).to_vec()).collect();
    let x_flat: Vec<u64> = inputs.iter().flatten().copied().collect();
    let y = enc.encode_u64(&a_flat, &x_flat)?;
    // y is row-major R×W; coded[j] should equal row j.
    Ok((0..r).all(|j| coded[j][..] == y[j * w..(j + 1) * w]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::GfPrime;

    #[test]
    fn native_accepts_correct_and_rejects_wrong() {
        let f = GfPrime::default_field();
        let a = Mat::random(&f, 4, 2, 3);
        let inputs: Vec<Packet> = (0..4u64).map(|i| vec![i + 1, i + 2]).collect();
        let mut coded: Vec<Packet> = (0..2)
            .map(|j| {
                let mut acc = pkt_zero(2);
                for i in 0..4 {
                    crate::net::pkt_add_scaled(&f, &mut acc, a[(i, j)], &inputs[i]);
                }
                acc
            })
            .collect();
        assert!(native(&f, &a, &inputs, &coded));
        coded[1][0] ^= 1;
        assert!(!native(&f, &a, &inputs, &coded));
    }

    #[test]
    fn freivalds_accepts_correct_codewords() {
        let f = GfPrime::default_field();
        let mut rng = crate::util::Rng::new(17);
        for (k, r, w) in [(8usize, 4usize, 3usize), (16, 16, 1), (4, 20, 2)] {
            let a = Mat::random(&f, k, r, rng.next_u64());
            let inputs: Vec<Packet> = (0..k)
                .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
                .collect();
            let coded: Vec<Packet> = (0..r)
                .map(|j| {
                    let mut acc = pkt_zero(w);
                    for i in 0..k {
                        crate::net::pkt_add_scaled(&f, &mut acc, a[(i, j)], &inputs[i]);
                    }
                    acc
                })
                .collect();
            for seed in 0..20 {
                assert!(freivalds(&f, &a, &inputs, &coded, seed, 2), "K={k} R={r}");
            }
        }
    }

    #[test]
    fn freivalds_rejects_pinned_bad_codeword() {
        // Regression pin: this exact corrupted codeword, with this exact
        // projection seed, must be rejected (and stay rejected — the
        // projection is deterministic in the seed).
        let f = GfPrime::default_field();
        let a = Mat::random(&f, 6, 3, 99);
        let inputs: Vec<Packet> = (0..6u64).map(|i| vec![i * 41 + 7, i + 1]).collect();
        let mut coded: Vec<Packet> = (0..3)
            .map(|j| {
                let mut acc = pkt_zero(2);
                for i in 0..6 {
                    crate::net::pkt_add_scaled(&f, &mut acc, a[(i, j)], &inputs[i]);
                }
                acc
            })
            .collect();
        assert!(freivalds(&f, &a, &inputs, &coded, 42, 2));
        // Corrupt one symbol of one coded packet.
        coded[2][1] = f.add(coded[2][1], 1);
        assert!(!freivalds(&f, &a, &inputs, &coded, 42, 2));
        // Shape mismatches are rejected outright.
        assert!(!freivalds(&f, &a, &inputs, &coded[..2].to_vec(), 42, 2));
    }

    #[test]
    fn freivalds_accepts_replay_and_rejects_corrupted_one() {
        let f = GfPrime::default_field();
        let (k, r, w) = (12usize, 4usize, 3usize);
        let a = std::sync::Arc::new(Mat::random(&f, k, r, 31));
        let compiled = crate::framework::compile_plan(
            &f,
            None,
            Some(a.clone()),
            1,
            w,
            crate::framework::AlgoRequest::Universal,
            None,
        )
        .unwrap();
        let inputs: Vec<Packet> = (0..k)
            .map(|i| (0..w).map(|j| f.elem((i * w + j) as u64 * 7 + 1)).collect())
            .collect();
        let mut replay = crate::net::exec::replay(&compiled.plan, &f, &inputs).unwrap();
        assert!(freivalds_replay(
            &f,
            &a,
            &inputs,
            &replay,
            &compiled.layout,
            77,
            2
        ));
        // Corrupt one sink packet: the projection must reject.
        let sink = compiled.layout.sink(1);
        replay.outputs.get_mut(&sink).unwrap()[0] ^= 1;
        assert!(!freivalds_replay(
            &f,
            &a,
            &inputs,
            &replay,
            &compiled.layout,
            77,
            2
        ));
    }

    #[test]
    fn freivalds_random_corruptions_rejected() {
        // Sweep: random single-symbol corruptions must essentially always
        // be caught at rounds = 2 (error probability q^{-2} ≈ 2^{-40}).
        let f = GfPrime::default_field();
        let mut rng = crate::util::Rng::new(0xF5EE);
        let (k, r, w) = (12usize, 8usize, 4usize);
        let a = Mat::random(&f, k, r, 5);
        let inputs: Vec<Packet> = (0..k)
            .map(|_| (0..w).map(|_| rng.below(f.order())).collect())
            .collect();
        let coded: Vec<Packet> = (0..r)
            .map(|j| {
                let mut acc = pkt_zero(w);
                for i in 0..k {
                    crate::net::pkt_add_scaled(&f, &mut acc, a[(i, j)], &inputs[i]);
                }
                acc
            })
            .collect();
        for trial in 0..50 {
            let mut bad = coded.clone();
            let j = rng.below(r as u64) as usize;
            let c = rng.below(w as u64) as usize;
            let delta = rng.range(1, f.order());
            bad[j][c] = f.add(bad[j][c], delta);
            assert!(
                !freivalds(&f, &a, &inputs, &bad, trial, 2),
                "trial {trial}: corruption at ({j},{c}) slipped through"
            );
        }
    }
}

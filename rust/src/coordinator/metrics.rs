//! Lightweight metrics registry (no external deps): monotonic counters
//! and duration histograms, JSON-dumpable, shared across service threads.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

/// Counter name: plan-cache lookups served from a compiled plan.
pub const PLAN_CACHE_HITS: &str = "plan_cache_hits";
/// Counter name: plan-cache lookups that had to compile.
pub const PLAN_CACHE_MISSES: &str = "plan_cache_misses";
/// Counter name: micro-batches served by the replay service.
pub const BATCHES: &str = "batches";
/// Counter name: requests served *inside* micro-batches
/// (`batched_requests / batches` = mean batch occupancy).
pub const BATCHED_REQUESTS: &str = "batched_requests";
/// Counter name: high-water mark of requests in one micro-batch.
pub const BATCH_OCCUPANCY_MAX: &str = "batch_occupancy_max";
/// Counter name: total output field elements produced by the service —
/// the throughput numerator (divide by wall time for elems/s).
pub const ENCODED_ELEMS: &str = "encoded_elems";
/// Counter name: fault directives honored while serving (one per
/// crash/link/erasure directive per served request).
pub const FAULTS_INJECTED: &str = "faults_injected";
/// Counter name: sink outputs reconstructed from survivors instead of
/// re-encoded.
pub const OUTPUTS_RECOVERED: &str = "outputs_recovered";
/// Latency-series name: wall time of the erasure-recovery pass
/// (decode-matrix build + survivor lincombs), per served batch.
pub const RECOVERY_LATENCY: &str = "recovery_latency";
/// Counter name: jobs rejected because their packed-buffer layout did
/// not match the plan's kernels (a typed
/// [`LayoutMismatch`](crate::gf::kernels::LayoutMismatch), not a
/// worker-killing panic).
pub const KERNEL_LAYOUT_REJECTS: &str = "kernel_layout_rejects";
/// Counter-name prefix: plans compiled per resolved kernel ISA tier.
/// The full counter is `plans_compiled_isa_<tier>` with `<tier>` an
/// [`IsaTier::name`](crate::gf::IsaTier::name) label (`scalar`, `avx2`,
/// `neon`) — one bump per fresh compile, so the metrics summary shows
/// which SIMD backend the serving path actually resolved to.
pub const PLANS_COMPILED_ISA_PREFIX: &str = "plans_compiled_isa_";

/// A set of named counters and latency recorders.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    latencies: BTreeMap<String, Vec<u64>>, // µs
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn incr(&self, name: &str, by: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_default() += by;
    }

    pub fn observe(&self, name: &str, d: Duration) {
        let mut g = self.inner.lock().unwrap();
        g.latencies
            .entry(name.to_string())
            .or_default()
            .push(d.as_micros() as u64);
    }

    /// Raise `name` to `max(current, v)` — for high-water marks.
    pub fn incr_to_max(&self, name: &str, v: u64) {
        let mut g = self.inner.lock().unwrap();
        let e = g.counters.entry(name.to_string()).or_default();
        *e = (*e).max(v);
    }

    /// Record one served micro-batch of `size` requests: bumps
    /// `batches` / `batched_requests` and the occupancy high-water mark.
    pub fn record_batch(&self, size: u64) {
        self.incr(BATCHES, 1);
        self.incr(BATCHED_REQUESTS, size);
        self.incr_to_max(BATCH_OCCUPANCY_MAX, size);
    }

    /// `(batches, batched_requests, occupancy_max)` recorded so far.
    pub fn batch_stats(&self) -> (u64, u64, u64) {
        (
            self.counter(BATCHES),
            self.counter(BATCHED_REQUESTS),
            self.counter(BATCH_OCCUPANCY_MAX),
        )
    }

    /// Record a plan-cache hit (replayed a compiled plan).
    pub fn plan_cache_hit(&self) {
        self.incr(PLAN_CACHE_HITS, 1);
    }

    /// Record a plan-cache miss (had to compile).
    pub fn plan_cache_miss(&self) {
        self.incr(PLAN_CACHE_MISSES, 1);
    }

    /// `(hits, misses)` of the plan cache. Both appear in [`to_json`]
    /// alongside the other counters, so the service metrics summary
    /// exposes them without extra plumbing.
    ///
    /// [`to_json`]: Metrics::to_json
    pub fn plan_cache(&self) -> (u64, u64) {
        (self.counter(PLAN_CACHE_HITS), self.counter(PLAN_CACHE_MISSES))
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .counters
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    /// (count, p50, p99, max) in µs for a latency series.
    pub fn latency_summary(&self, name: &str) -> Option<(usize, u64, u64, u64)> {
        let g = self.inner.lock().unwrap();
        let v = g.latencies.get(name)?;
        if v.is_empty() {
            return None;
        }
        let mut s = v.clone();
        s.sort_unstable();
        let pct = |q: f64| s[((s.len() - 1) as f64 * q) as usize];
        Some((s.len(), pct(0.5), pct(0.99), *s.last().unwrap()))
    }

    /// JSON dump of all counters and latency summaries.
    pub fn to_json(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mut parts = Vec::new();
        for (k, v) in &g.counters {
            parts.push(format!("\"{k}\":{v}"));
        }
        for (k, v) in &g.latencies {
            if v.is_empty() {
                continue;
            }
            let mut s = v.clone();
            s.sort_unstable();
            let pct = |q: f64| s[((s.len() - 1) as f64 * q) as usize];
            parts.push(format!(
                "\"{k}\":{{\"count\":{},\"p50_us\":{},\"p99_us\":{},\"max_us\":{}}}",
                s.len(),
                pct(0.5),
                pct(0.99),
                s.last().unwrap()
            ));
        }
        format!("{{{}}}", parts.join(","))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_latencies() {
        let m = Metrics::new();
        m.incr("requests", 2);
        m.incr("requests", 3);
        assert_eq!(m.counter("requests"), 5);
        m.observe("encode", Duration::from_micros(100));
        m.observe("encode", Duration::from_micros(300));
        let (n, p50, _, max) = m.latency_summary("encode").unwrap();
        assert_eq!(n, 2);
        assert!(p50 >= 100 && max == 300);
        let j = m.to_json();
        assert!(j.contains("\"requests\":5"));
        assert!(j.contains("\"encode\""));
    }

    #[test]
    fn batch_counters_track_occupancy() {
        let m = Metrics::new();
        m.record_batch(1);
        m.record_batch(7);
        m.record_batch(3);
        assert_eq!(m.batch_stats(), (3, 11, 7));
        m.incr_to_max(BATCH_OCCUPANCY_MAX, 2); // never lowers the mark
        assert_eq!(m.counter(BATCH_OCCUPANCY_MAX), 7);
        let j = m.to_json();
        assert!(j.contains("\"batches\":3"), "{j}");
        assert!(j.contains("\"batched_requests\":11"), "{j}");
        assert!(j.contains("\"batch_occupancy_max\":7"), "{j}");
    }

    #[test]
    fn plan_cache_counters_surface_in_json() {
        let m = Metrics::new();
        m.plan_cache_miss();
        m.plan_cache_hit();
        m.plan_cache_hit();
        assert_eq!(m.plan_cache(), (2, 1));
        let j = m.to_json();
        assert!(j.contains("\"plan_cache_hits\":2"), "{j}");
        assert!(j.contains("\"plan_cache_misses\":1"), "{j}");
    }
}

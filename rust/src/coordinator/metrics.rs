//! Lightweight metrics registry (no external deps): monotonic counters
//! and fixed-bucket latency histograms, JSON-dumpable, shared across
//! service threads.
//!
//! Latencies are recorded into [`LatencyHistogram`] — a power-of-two
//! bucketed histogram (bucket `i ≥ 1` covers `[2^(i-1), 2^i - 1]` µs)
//! with O(1) record and O(buckets) quantile estimation. Unlike the old
//! unbounded `Vec<u64>` store, memory per series is constant no matter
//! how many requests the service has served, and p50/p99/p999 stay
//! available at any point of a long run. A quantile estimate is the
//! upper bound of its bucket (≤ 2× the true value), clamped to the
//! exact maximum ever observed — so a series with one sample reports
//! that sample exactly.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

/// Counter name: plan-cache lookups served from a compiled plan.
pub const PLAN_CACHE_HITS: &str = "plan_cache_hits";
/// Counter name: plan-cache lookups that had to compile.
pub const PLAN_CACHE_MISSES: &str = "plan_cache_misses";
/// Counter name: compiled plans evicted by the cache's LRU capacity
/// bound.
pub const PLAN_CACHE_EVICTIONS: &str = "plan_cache_evictions";
/// Counter name: lookups that waited on another thread's in-flight
/// compile of the same key (single-flight) instead of compiling
/// redundantly.
pub const PLAN_CACHE_WAITS: &str = "plan_cache_single_flight_waits";
/// Counter name: shard-lock acquisitions that found the lock held
/// (`try_lock` failed and the caller had to block) — the cache's
/// contention signal. With enough shards this stays near zero.
pub const PLAN_CACHE_CONTENTION: &str = "plan_cache_shard_contention";
/// Counter name: requests refused admission (global queue full or
/// per-tenant in-flight quota exhausted) with a typed `Overloaded`
/// rejection.
pub const ADMISSION_REJECTS: &str = "admission_rejects";
/// Counter name: blocking submits that had to wait for queue space or
/// tenant quota (the backpressure path, as opposed to the rejecting
/// `try_submit` path).
pub const ADMISSION_WAITS: &str = "admission_waits";
/// Counter name: high-water mark of requests queued in the dispatcher.
pub const QUEUE_DEPTH_MAX: &str = "queue_depth_max";
/// Counter name: requests answered with a typed `ServiceStopped`
/// rejection (submitted after shutdown began, or stranded when every
/// worker died).
pub const STOPPED_REJECTS: &str = "stopped_rejects";
/// Latency-series name: time a request spent queued in the dispatcher
/// before its batch started serving (admission → batch start).
pub const QUEUE_WAIT: &str = "queue_wait";
/// Counter name: micro-batches served by the replay service.
pub const BATCHES: &str = "batches";
/// Counter name: requests served *inside* micro-batches
/// (`batched_requests / batches` = mean batch occupancy).
pub const BATCHED_REQUESTS: &str = "batched_requests";
/// Counter name: high-water mark of requests in one micro-batch.
pub const BATCH_OCCUPANCY_MAX: &str = "batch_occupancy_max";
/// Counter name: total output field elements produced by the service —
/// the throughput numerator (divide by wall time for elems/s).
pub const ENCODED_ELEMS: &str = "encoded_elems";
/// Counter name: fault directives honored while serving (one per
/// crash/link/erasure directive per served request).
pub const FAULTS_INJECTED: &str = "faults_injected";
/// Counter name: sink outputs reconstructed from survivors instead of
/// re-encoded.
pub const OUTPUTS_RECOVERED: &str = "outputs_recovered";
/// Latency-series name: wall time of the erasure-recovery pass
/// (decode-matrix build + survivor lincombs), per served batch.
pub const RECOVERY_LATENCY: &str = "recovery_latency";
/// Counter name: transient recv/barrier retries absorbed by peer-engine
/// meshes (delay/duplicate/reorder faults healed by bounded backoff).
pub const PEER_RETRIES: &str = "peer_retries";
/// Counter name: peer rank-rounds that needed at least one retry — the
/// straggler-round signal behind `peer_retries`.
pub const PEER_ROUNDS_DELAYED: &str = "peer_rounds_delayed";
/// Counter name: dead peers detected on the wire (and gossiped) by
/// peer-engine meshes while serving degraded.
pub const PEER_CRASHES_DETECTED: &str = "peer_crashes_detected";
/// Counter name: jobs rejected because their packed-buffer layout did
/// not match the plan's kernels (a typed
/// [`LayoutMismatch`](crate::gf::kernels::LayoutMismatch), not a
/// worker-killing panic).
pub const KERNEL_LAYOUT_REJECTS: &str = "kernel_layout_rejects";
/// Counter-name prefix: plans compiled per resolved kernel ISA tier.
/// The full counter is `plans_compiled_isa_<tier>` with `<tier>` an
/// [`IsaTier::name`](crate::gf::IsaTier::name) label (`scalar`, `avx2`,
/// `neon`) — one bump per fresh compile, so the metrics summary shows
/// which SIMD backend the serving path actually resolved to.
pub const PLANS_COMPILED_ISA_PREFIX: &str = "plans_compiled_isa_";
/// Counter name: wire connections accepted by the framed front end.
pub const WIRE_CONNECTIONS: &str = "wire_connections";
/// Counter name: request frames decoded by the framed front end.
pub const WIRE_REQUESTS: &str = "wire_requests";
/// Counter name: error frames written by the framed front end
/// (admission rejections and per-request failures).
pub const WIRE_ERRORS: &str = "wire_errors";

/// Power-of-two bucket count: bucket 0 holds zeros, bucket `i ≥ 1`
/// holds `[2^(i-1), 2^i - 1]`, bucket 64 holds values with bit 63 set.
pub const HISTOGRAM_BUCKETS: usize = 65;

/// A fixed-size power-of-two latency histogram (µs granularity).
///
/// `record` is O(1); `quantile` walks the 65 buckets. Estimates are
/// bucket upper bounds (≤ 2× true), clamped to the exact observed
/// maximum.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    buckets: [u64; HISTOGRAM_BUCKETS],
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: [0; HISTOGRAM_BUCKETS],
            count: 0,
            sum_us: 0,
            max_us: 0,
        }
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// The bucket index holding `v`: 0 for 0, else `floor(log2 v) + 1`.
    pub fn bucket_index(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            64 - v.leading_zeros() as usize
        }
    }

    /// Inclusive upper bound of bucket `i` (`u64::MAX` for the last).
    pub fn bucket_upper_bound(i: usize) -> u64 {
        if i >= 64 {
            u64::MAX
        } else {
            (1u64 << i) - 1
        }
    }

    pub fn record_us(&mut self, us: u64) {
        self.buckets[Self::bucket_index(us)] += 1;
        self.count += 1;
        self.sum_us = self.sum_us.saturating_add(us);
        self.max_us = self.max_us.max(us);
    }

    pub fn record(&mut self, d: Duration) {
        self.record_us(d.as_micros() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    pub fn mean_us(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.sum_us / self.count
        }
    }

    /// Estimated `q`-quantile in µs (`q` in `[0, 1]`): the upper bound
    /// of the bucket holding the rank-`⌈q·count⌉` sample, clamped to
    /// the exact maximum. 0 for an empty histogram.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                return Self::bucket_upper_bound(i).min(self.max_us);
            }
        }
        self.max_us
    }
}

/// A set of named counters and latency histograms.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    latencies: BTreeMap<String, LatencyHistogram>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn incr(&self, name: &str, by: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_default() += by;
    }

    pub fn observe(&self, name: &str, d: Duration) {
        self.observe_us(name, d.as_micros() as u64);
    }

    /// Record a latency sample already expressed in µs.
    pub fn observe_us(&self, name: &str, us: u64) {
        let mut g = self.inner.lock().unwrap();
        g.latencies
            .entry(name.to_string())
            .or_default()
            .record_us(us);
    }

    /// Raise `name` to `max(current, v)` — for high-water marks.
    pub fn incr_to_max(&self, name: &str, v: u64) {
        let mut g = self.inner.lock().unwrap();
        let e = g.counters.entry(name.to_string()).or_default();
        *e = (*e).max(v);
    }

    /// Record one served micro-batch of `size` requests: bumps
    /// `batches` / `batched_requests` and the occupancy high-water mark.
    pub fn record_batch(&self, size: u64) {
        self.incr(BATCHES, 1);
        self.incr(BATCHED_REQUESTS, size);
        self.incr_to_max(BATCH_OCCUPANCY_MAX, size);
    }

    /// `(batches, batched_requests, occupancy_max)` recorded so far.
    pub fn batch_stats(&self) -> (u64, u64, u64) {
        (
            self.counter(BATCHES),
            self.counter(BATCHED_REQUESTS),
            self.counter(BATCH_OCCUPANCY_MAX),
        )
    }

    /// Record a plan-cache hit (replayed a compiled plan).
    pub fn plan_cache_hit(&self) {
        self.incr(PLAN_CACHE_HITS, 1);
    }

    /// Record a plan-cache miss (had to compile).
    pub fn plan_cache_miss(&self) {
        self.incr(PLAN_CACHE_MISSES, 1);
    }

    /// `(hits, misses)` of the plan cache. Both appear in [`to_json`]
    /// alongside the other counters, so the service metrics summary
    /// exposes them without extra plumbing.
    ///
    /// [`to_json`]: Metrics::to_json
    pub fn plan_cache(&self) -> (u64, u64) {
        (self.counter(PLAN_CACHE_HITS), self.counter(PLAN_CACHE_MISSES))
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .counters
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    /// (count, p50, p99, max) in µs for a latency series. Percentiles
    /// are histogram-bucket estimates (≤ 2× true, clamped to max).
    pub fn latency_summary(&self, name: &str) -> Option<(usize, u64, u64, u64)> {
        let g = self.inner.lock().unwrap();
        let h = g.latencies.get(name)?;
        if h.count() == 0 {
            return None;
        }
        Some((
            h.count() as usize,
            h.quantile(0.5),
            h.quantile(0.99),
            h.max_us(),
        ))
    }

    /// A snapshot of one latency histogram (for quantiles beyond the
    /// summary tuple, e.g. p999).
    pub fn latency_histogram(&self, name: &str) -> Option<LatencyHistogram> {
        self.inner.lock().unwrap().latencies.get(name).cloned()
    }

    /// JSON dump of all counters and latency summaries.
    pub fn to_json(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mut parts = Vec::new();
        for (k, v) in &g.counters {
            parts.push(format!("\"{k}\":{v}"));
        }
        for (k, h) in &g.latencies {
            if h.count() == 0 {
                continue;
            }
            parts.push(format!(
                "\"{k}\":{{\"count\":{},\"p50_us\":{},\"p99_us\":{},\"p999_us\":{},\"max_us\":{}}}",
                h.count(),
                h.quantile(0.5),
                h.quantile(0.99),
                h.quantile(0.999),
                h.max_us()
            ));
        }
        format!("{{{}}}", parts.join(","))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_latencies() {
        let m = Metrics::new();
        m.incr("requests", 2);
        m.incr("requests", 3);
        assert_eq!(m.counter("requests"), 5);
        m.observe("encode", Duration::from_micros(100));
        m.observe("encode", Duration::from_micros(300));
        let (n, p50, _, max) = m.latency_summary("encode").unwrap();
        assert_eq!(n, 2);
        assert!(p50 >= 100 && max == 300);
        let j = m.to_json();
        assert!(j.contains("\"requests\":5"));
        assert!(j.contains("\"encode\""));
    }

    #[test]
    fn batch_counters_track_occupancy() {
        let m = Metrics::new();
        m.record_batch(1);
        m.record_batch(7);
        m.record_batch(3);
        assert_eq!(m.batch_stats(), (3, 11, 7));
        m.incr_to_max(BATCH_OCCUPANCY_MAX, 2); // never lowers the mark
        assert_eq!(m.counter(BATCH_OCCUPANCY_MAX), 7);
        let j = m.to_json();
        assert!(j.contains("\"batches\":3"), "{j}");
        assert!(j.contains("\"batched_requests\":11"), "{j}");
        assert!(j.contains("\"batch_occupancy_max\":7"), "{j}");
    }

    #[test]
    fn plan_cache_counters_surface_in_json() {
        let m = Metrics::new();
        m.plan_cache_miss();
        m.plan_cache_hit();
        m.plan_cache_hit();
        assert_eq!(m.plan_cache(), (2, 1));
        let j = m.to_json();
        assert!(j.contains("\"plan_cache_hits\":2"), "{j}");
        assert!(j.contains("\"plan_cache_misses\":1"), "{j}");
    }

    #[test]
    fn histogram_bucket_boundaries_are_powers_of_two() {
        // Bucket 0 is exactly {0}; bucket i ≥ 1 is [2^(i-1), 2^i - 1].
        assert_eq!(LatencyHistogram::bucket_index(0), 0);
        for i in 0..63usize {
            let lo = 1u64 << i;
            assert_eq!(LatencyHistogram::bucket_index(lo), i + 1, "lower edge 2^{i}");
            assert_eq!(
                LatencyHistogram::bucket_index(lo + (lo - 1)),
                i + 1,
                "upper edge 2^{}-1",
                i + 1
            );
            if i >= 1 {
                assert_eq!(LatencyHistogram::bucket_index(lo - 1), i, "below 2^{i}");
            }
        }
        assert_eq!(LatencyHistogram::bucket_index(u64::MAX), 64);
        assert_eq!(LatencyHistogram::bucket_upper_bound(0), 0);
        assert_eq!(LatencyHistogram::bucket_upper_bound(3), 7);
        assert_eq!(LatencyHistogram::bucket_upper_bound(64), u64::MAX);
    }

    #[test]
    fn histogram_quantiles_estimate_within_bucket_bounds() {
        let mut h = LatencyHistogram::new();
        assert_eq!(h.quantile(0.5), 0, "empty histogram");
        h.record_us(5);
        // Single sample: the bucket bound (7) clamps to the exact max.
        assert_eq!(h.quantile(0.5), 5);
        assert_eq!(h.quantile(0.99), 5);
        for _ in 0..99 {
            h.record_us(100); // bucket [64, 127]
        }
        h.record_us(10_000); // bucket [8192, 16383]
        let p50 = h.quantile(0.5);
        assert!((100..=127).contains(&p50), "p50={p50}");
        // 101 samples: rank(p99) = ceil(0.99*101) = 100 → still the
        // 100µs bucket; rank(p999) = 101 → the outlier, clamped exact.
        assert!((100..=127).contains(&h.quantile(0.99)), "{}", h.quantile(0.99));
        assert_eq!(h.quantile(0.999), 10_000);
        assert_eq!(h.max_us(), 10_000);
        assert_eq!(h.count(), 101);
        // The estimate never undershoots its bucket's true members:
        // upper-bound semantics mean p50 ≥ the true median here.
        assert!(p50 >= 100);
    }

    #[test]
    fn histogram_memory_is_constant_and_mean_tracks_sum() {
        let mut h = LatencyHistogram::new();
        for i in 0..10_000u64 {
            h.record_us(i % 1000);
        }
        assert_eq!(h.count(), 10_000);
        assert!(h.mean_us() < 1000);
        assert_eq!(std::mem::size_of_val(&h), std::mem::size_of::<LatencyHistogram>());
    }
}

//! Job configuration — a minimal `key = value` format (the offline build
//! has no serde/toml; the grammar is a strict TOML subset so configs stay
//! valid TOML).
//!
//! ```text
//! # sensor-network.conf
//! field = "prime:786433"
//! k = 48
//! r = 16
//! w = 256
//! ports = 2
//! alpha = 10.0
//! beta = 0.1
//! code = "rs-structured"
//! algorithm = "auto"
//! verify = "native"
//! seed = 42
//! artifacts_dir = "artifacts"
//! ```

use crate::framework::AlgoRequest;
use crate::gf::AnyField;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;

/// Which code family the job encodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum CodeKind {
    /// Structured GRS (draw-and-loose–compatible points) — the §VI target.
    #[default]
    RsStructured,
    /// GRS on plain sequential points (universal algorithms only).
    RsPlain,
    /// Systematic Lagrange code (Remark 9).
    Lagrange,
    /// GRS on NTT-friendly geometry (`α` = K-th roots of unity, `β` on
    /// a generator coset) — eligible for the `O(K log K)` encode backend
    /// at large K ([`NttBackend`](crate::net::NttBackend)).
    RsNtt,
    /// A random dense parity matrix (universal algorithms only).
    Random,
}

impl std::str::FromStr for CodeKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "rs-structured" | "rs" => CodeKind::RsStructured,
            "rs-plain" => CodeKind::RsPlain,
            "lagrange" => CodeKind::Lagrange,
            "rs-ntt" => CodeKind::RsNtt,
            "random" => CodeKind::Random,
            other => anyhow::bail!("unknown code kind {other:?}"),
        })
    }
}

/// How to verify coded outputs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum VerifyMode {
    /// Native rust matrix oracle (full re-encode).
    #[default]
    Native,
    /// Freivalds random-projection check — sublinear in the matrix
    /// volume, error probability ≤ q^{-2}.
    Freivalds,
    /// The AOT-compiled PJRT artifact (requires `make artifacts`).
    Pjrt,
    /// Skip verification.
    Off,
}

impl std::str::FromStr for VerifyMode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "native" => VerifyMode::Native,
            "freivalds" => VerifyMode::Freivalds,
            "pjrt" => VerifyMode::Pjrt,
            "off" => VerifyMode::Off,
            other => anyhow::bail!("unknown verify mode {other:?}"),
        })
    }
}

/// Serving-tier knobs: micro-batching, admission control and plan-cache
/// sizing for [`EncodeService`](super::service::EncodeService) and the
/// wire front end. All keys are optional in the config text
/// (`max_batch`, `max_delay_us`, `tenant_quota`, `queue_depth`,
/// `plan_cache_capacity`, `plan_cache_shards`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServeOptions {
    /// Largest number of requests served in one columnar pass.
    pub max_batch: usize,
    /// Longest a queued request waits for co-batched company (µs) —
    /// the admission deadline added to every request.
    pub max_delay_us: u64,
    /// Per-tenant in-flight request bound (admission control).
    pub tenant_quota: usize,
    /// Global dispatcher queue bound (admission control).
    pub queue_depth: usize,
    /// Total compiled plans the cache holds before LRU eviction.
    pub plan_cache_capacity: usize,
    /// Plan-cache shard count (rounded up to a power of two).
    pub plan_cache_shards: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            max_batch: 32,
            max_delay_us: 500,
            tenant_quota: 256,
            queue_depth: 1024,
            plan_cache_capacity: 256,
            plan_cache_shards: 16,
        }
    }
}

impl ServeOptions {
    /// The micro-batching policy these options describe.
    pub fn policy(&self) -> super::service::BatchPolicy {
        super::service::BatchPolicy {
            max_batch: self.max_batch,
            max_delay: std::time::Duration::from_micros(self.max_delay_us),
        }
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.max_batch >= 1, "need max_batch ≥ 1");
        anyhow::ensure!(self.tenant_quota >= 1, "need tenant_quota ≥ 1");
        anyhow::ensure!(self.queue_depth >= 1, "need queue_depth ≥ 1");
        anyhow::ensure!(self.plan_cache_capacity >= 1, "need plan_cache_capacity ≥ 1");
        anyhow::ensure!(self.plan_cache_shards >= 1, "need plan_cache_shards ≥ 1");
        Ok(())
    }
}

/// Full description of one decentralized-encoding job.
#[derive(Clone, Debug)]
pub struct JobConfig {
    pub field: String,
    pub k: usize,
    pub r: usize,
    pub w: usize,
    pub ports: usize,
    /// Cost-model parameters (the paper's α and β).
    pub alpha: f64,
    pub beta: f64,
    pub code: CodeKind,
    pub algorithm: AlgoRequest,
    pub verify: VerifyMode,
    pub seed: u64,
    pub artifacts_dir: PathBuf,
    /// Optional kernel ISA-tier override
    /// (`isa = "scalar" | "avx2" | "neon" | "native"`). `None` serves at
    /// the process default ([`IsaTier::detect`](crate::gf::IsaTier):
    /// `DCE_FORCE_ISA` when set, else the widest tier the host
    /// supports); an unsupported explicit request degrades to scalar.
    pub isa: Option<crate::gf::IsaRequest>,
    /// Default execution engine
    /// (`engine = "live" | "replay" | "peer-channel" | "peer-shmem" |
    /// "peer-tcp"`) — what [`ExecOptions`](super::ExecOptions) callers
    /// start from when the config drives execution (CLI, loadgen).
    pub engine: super::job::Engine,
    /// Serving-tier knobs (batching, admission, plan-cache sizing).
    pub serve: ServeOptions,
}

impl Default for JobConfig {
    fn default() -> Self {
        JobConfig {
            field: "prime:786433".into(),
            k: 16,
            r: 4,
            w: 64,
            ports: 1,
            alpha: 10.0,
            beta: 0.1,
            code: CodeKind::RsStructured,
            algorithm: AlgoRequest::Auto,
            verify: VerifyMode::Native,
            seed: 42,
            artifacts_dir: "artifacts".into(),
            isa: None,
            engine: super::job::Engine::default(),
            serve: ServeOptions::default(),
        }
    }
}

impl JobConfig {
    /// Parse the `key = value` config text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut map: HashMap<&str, String> = HashMap::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected `key = value`", ln + 1))?;
            let val = val.trim().trim_matches('"').to_string();
            map.insert(key.trim_end(), val);
        }
        let mut cfg = JobConfig::default();
        let set = |cfg: &mut JobConfig, k: &str, v: &str| -> Result<()> {
            match k {
                "field" => cfg.field = v.into(),
                "k" => cfg.k = v.parse()?,
                "r" => cfg.r = v.parse()?,
                "w" => cfg.w = v.parse()?,
                "ports" | "p" => cfg.ports = v.parse()?,
                "alpha" => cfg.alpha = v.parse()?,
                "beta" => cfg.beta = v.parse()?,
                "code" => cfg.code = v.parse()?,
                "algorithm" => cfg.algorithm = v.parse()?,
                "verify" => cfg.verify = v.parse()?,
                "seed" => cfg.seed = v.parse()?,
                "artifacts_dir" => cfg.artifacts_dir = v.into(),
                "isa" => cfg.isa = Some(v.parse()?),
                "engine" => cfg.engine = v.parse()?,
                "max_batch" => cfg.serve.max_batch = v.parse()?,
                "max_delay_us" => cfg.serve.max_delay_us = v.parse()?,
                "tenant_quota" => cfg.serve.tenant_quota = v.parse()?,
                "queue_depth" => cfg.serve.queue_depth = v.parse()?,
                "plan_cache_capacity" => cfg.serve.plan_cache_capacity = v.parse()?,
                "plan_cache_shards" => cfg.serve.plan_cache_shards = v.parse()?,
                other => anyhow::bail!("unknown config key {other:?}"),
            }
            Ok(())
        };
        let entries: Vec<(String, String)> =
            map.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
        for (k, v) in entries {
            set(&mut cfg, &k, &v).with_context(|| format!("config key {k}"))?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.k >= 1 && self.r >= 1, "need K ≥ 1 and R ≥ 1");
        anyhow::ensure!(self.w >= 1, "need W ≥ 1");
        anyhow::ensure!(self.ports >= 1, "need at least one port");
        let f = self.any_field()?;
        use crate::gf::Field;
        anyhow::ensure!(
            (self.k + self.r) as u64 <= f.order(),
            "N = K+R must be at most q for GRS codes"
        );
        self.serve.validate()?;
        Ok(())
    }

    pub fn any_field(&self) -> Result<AnyField> {
        AnyField::parse(&self.field)
    }

    /// The cost model for this deployment.
    pub fn cost_model(&self) -> Result<crate::net::CostModel> {
        use crate::gf::Field;
        let f = self.any_field()?;
        Ok(crate::net::CostModel::new(self.alpha, self.beta, f.bits()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_config() {
        let cfg = JobConfig::parse(
            r#"
            # a comment
            field = "prime:65537"
            k = 12
            r = 4
            w = 8       # trailing comment
            ports = 2
            alpha = 100.0
            beta = 0.5
            code = "rs-plain"
            algorithm = "universal"
            verify = "off"
            seed = 7
            isa = "scalar"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.k, 12);
        assert_eq!(cfg.ports, 2);
        assert_eq!(cfg.code, CodeKind::RsPlain);
        assert_eq!(cfg.algorithm, AlgoRequest::Universal);
        assert_eq!(cfg.verify, VerifyMode::Off);
        assert_eq!(cfg.isa, Some(crate::gf::IsaRequest::Scalar));
        assert_eq!(cfg.cost_model().unwrap().q_bits, 17);
    }

    #[test]
    fn isa_key_defaults_to_none_and_rejects_junk_tiers() {
        assert_eq!(JobConfig::default().isa, None);
        assert_eq!(JobConfig::parse("k = 4").unwrap().isa, None);
        for (v, want) in [
            ("native", crate::gf::IsaRequest::Native),
            ("avx2", crate::gf::IsaRequest::Avx2),
            ("neon", crate::gf::IsaRequest::Neon),
        ] {
            let cfg = JobConfig::parse(&format!("isa = \"{v}\"")).unwrap();
            assert_eq!(cfg.isa, Some(want));
        }
        assert!(JobConfig::parse("isa = \"sse9\"").is_err());
    }

    #[test]
    fn defaults_are_valid() {
        JobConfig::default().validate().unwrap();
    }

    #[test]
    fn engine_key_parses_every_variant() {
        use super::super::job::Engine;
        use crate::net::transport::TransportKind;
        assert_eq!(JobConfig::parse("k = 4").unwrap().engine, Engine::Live);
        for (v, want) in [
            ("live", Engine::Live),
            ("replay", Engine::Replay),
            ("peer-channel", Engine::Peer(TransportKind::Channel)),
            ("peer-shmem", Engine::Peer(TransportKind::SharedMem)),
            ("peer-tcp", Engine::Peer(TransportKind::Tcp)),
        ] {
            let cfg = JobConfig::parse(&format!("engine = \"{v}\"")).unwrap();
            assert_eq!(cfg.engine, want);
        }
        assert!(JobConfig::parse("engine = \"smoke-signal\"").is_err());
    }

    #[test]
    fn serve_options_parse_and_validate() {
        let cfg = JobConfig::parse(
            "max_batch = 8\nmax_delay_us = 0\ntenant_quota = 4\n\
             queue_depth = 64\nplan_cache_capacity = 32\nplan_cache_shards = 4",
        )
        .unwrap();
        assert_eq!(cfg.serve.max_batch, 8);
        assert_eq!(cfg.serve.max_delay_us, 0);
        assert_eq!(cfg.serve.tenant_quota, 4);
        assert_eq!(cfg.serve.queue_depth, 64);
        assert_eq!(cfg.serve.plan_cache_capacity, 32);
        assert_eq!(cfg.serve.plan_cache_shards, 4);
        assert_eq!(
            cfg.serve.policy().max_delay,
            std::time::Duration::ZERO,
            "max_delay_us = 0 → fire immediately"
        );
        assert_eq!(JobConfig::parse("k = 4").unwrap().serve, ServeOptions::default());
        assert!(JobConfig::parse("max_batch = 0").is_err());
        assert!(JobConfig::parse("queue_depth = 0").is_err());
    }

    #[test]
    fn rejects_unknown_keys_and_oversized_codes() {
        assert!(JobConfig::parse("bogus = 1").is_err());
        assert!(JobConfig::parse("field = \"prime:13\"\nk = 10\nr = 10").is_err());
    }
}

//! The crate's unified error surface.
//!
//! Fallible public entry points ([`EncodeJob::run`],
//! [`EncodeJob::encode`](crate::coordinator::EncodeJob::encode)) return
//! [`Error`] — one enum over the failure domains the engine actually
//! has, replacing the mixed `anyhow::Error` / `KernelError` /
//! `ServeRejection` vocabulary the coordinator grew historically:
//!
//! * [`Error::Compile`] — planning, code construction, plan compilation
//!   or optimisation failed; also the catch-all for malformed requests.
//! * [`Error::Kernel`] — the execution kernels rejected the payload
//!   (layout/shape mismatch, non-canonical elements).
//! * [`Error::Transport`] — a peer-execution substrate failure
//!   ([`TransportError`](crate::net::transport::TransportError) in the
//!   chain).
//! * [`Error::Rejected`] — admission control turned the request away
//!   ([`ServeRejection`](crate::coordinator::ServeRejection)); retryable.
//! * [`Error::Unrecoverable`] — a degraded run whose failure pattern
//!   left fewer than `K` independent survivor coordinates
//!   ([`RecoveryShortfall`] in the chain); the data is gone.
//!
//! Every variant keeps its full underlying cause chain via
//! [`std::error::Error::source`], so `anyhow`-style chain walks (and
//! the serving tier's metric classification, pinned by test) see
//! through the wrapper unchanged.
//!
//! [`EncodeJob::run`]: crate::coordinator::EncodeJob::run

use std::fmt;

/// The unified top-level error of the crate. See the module docs for
/// the variant taxonomy.
#[derive(Debug)]
pub enum Error {
    /// Planning / code construction / plan compilation failed.
    Compile(anyhow::Error),
    /// The execution kernels rejected the payload.
    Kernel(anyhow::Error),
    /// A peer transport failed (timeout, closed peer, bad frame…).
    Transport(anyhow::Error),
    /// Admission control rejected the request (overload, shutdown) —
    /// back off and retry.
    Rejected(anyhow::Error),
    /// The failure pattern is beyond the code's erasure tolerance.
    Unrecoverable(anyhow::Error),
}

impl Error {
    /// Classify an `anyhow` error by walking its cause chain for the
    /// typed markers each domain emits; anything unrecognized lands in
    /// [`Error::Compile`] (construction is the only untyped domain).
    pub fn classify(e: anyhow::Error) -> Error {
        let chain_has = |pred: &dyn Fn(&(dyn std::error::Error + 'static)) -> bool| {
            e.chain().any(pred)
        };
        if chain_has(&|c| c.downcast_ref::<RecoveryShortfall>().is_some()) {
            Error::Unrecoverable(e)
        } else if chain_has(&|c| {
            c.downcast_ref::<crate::net::transport::TransportError>()
                .is_some()
        }) {
            Error::Transport(e)
        } else if chain_has(&|c| {
            c.downcast_ref::<crate::coordinator::ServeRejection>()
                .is_some()
        }) {
            Error::Rejected(e)
        } else if chain_has(&|c| {
            c.downcast_ref::<crate::gf::kernels::KernelError>().is_some()
                || c.downcast_ref::<crate::gf::kernels::LayoutMismatch>()
                    .is_some()
                || c.downcast_ref::<crate::gf::kernels::ShapeMismatch>()
                    .is_some()
        }) {
            Error::Kernel(e)
        } else {
            Error::Compile(e)
        }
    }

    /// The wrapped cause, whatever the variant.
    pub fn inner(&self) -> &anyhow::Error {
        match self {
            Error::Compile(e)
            | Error::Kernel(e)
            | Error::Transport(e)
            | Error::Rejected(e)
            | Error::Unrecoverable(e) => e,
        }
    }

    /// Consume the wrapper, yielding the full cause chain as `anyhow`.
    pub fn into_inner(self) -> anyhow::Error {
        match self {
            Error::Compile(e)
            | Error::Kernel(e)
            | Error::Transport(e)
            | Error::Rejected(e)
            | Error::Unrecoverable(e) => e,
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Short domain labels; the detail lives in the source chain.
        // `Unrecoverable`'s label deliberately contains "unrecoverable"
        // — callers match on it (tests pin this).
        match self {
            Error::Compile(_) => f.write_str("plan construction or compilation failed"),
            Error::Kernel(_) => f.write_str("kernel rejected the payload"),
            Error::Transport(_) => f.write_str("peer transport failed"),
            Error::Rejected(_) => f.write_str("request rejected by admission control"),
            Error::Unrecoverable(_) => f.write_str("unrecoverable failure pattern"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        let inner: &(dyn std::error::Error + 'static) = self.inner().as_ref();
        Some(inner)
    }
}

impl From<crate::coordinator::ServeRejection> for Error {
    fn from(r: crate::coordinator::ServeRejection) -> Error {
        Error::Rejected(anyhow::Error::new(r))
    }
}

/// A degraded run's survivor set spans fewer than `K` dimensions: the
/// lost outputs cannot be reconstructed. The typed marker
/// [`Error::classify`] maps to [`Error::Unrecoverable`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RecoveryShortfall {
    /// Independent coordinates found among the survivors.
    pub independent: usize,
    /// Total surviving candidate coordinates.
    pub survivors: usize,
    /// Coordinates needed (`K`).
    pub k: usize,
    /// Crashed processors in the failure pattern.
    pub crashed: usize,
    /// Tainted (indirectly lost) processors.
    pub tainted: usize,
}

impl fmt::Display for RecoveryShortfall {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "unrecoverable failure pattern: only {} independent coordinates among the \
             {} survivors, K = {} needed ({} crashed, {} tainted)",
            self.independent, self.survivors, self.k, self.crashed, self.tainted
        )
    }
}

impl std::error::Error for RecoveryShortfall {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_labels_are_stable() {
        let e = Error::Unrecoverable(anyhow::anyhow!("detail"));
        assert!(e.to_string().contains("unrecoverable"));
        let e = Error::Rejected(anyhow::anyhow!("detail"));
        assert!(e.to_string().contains("rejected"));
    }

    #[test]
    fn source_chain_reaches_the_typed_marker() {
        let shortfall = RecoveryShortfall {
            independent: 2,
            survivors: 3,
            k: 4,
            crashed: 3,
            tainted: 0,
        };
        let e = Error::classify(anyhow::Error::new(shortfall).context("repair failed"));
        assert!(matches!(e, Error::Unrecoverable(_)));
        // An anyhow rewrap (what the serving tier does) must still see
        // the marker through the chain.
        let rewrapped = anyhow::Error::new(e);
        assert!(rewrapped
            .chain()
            .any(|c| c.downcast_ref::<RecoveryShortfall>().is_some()));
        assert!(rewrapped.to_string().contains("unrecoverable"));
    }

    #[test]
    fn transport_errors_classify_as_transport() {
        let te = crate::net::transport::TransportError::PeerClosed { round: 3, peer: 1 };
        let e = Error::classify(anyhow::Error::new(te).context("peer run failed"));
        assert!(matches!(e, Error::Transport(_)));
    }

    #[test]
    fn unknown_errors_classify_as_compile() {
        let e = Error::classify(anyhow::anyhow!("some planner failure"));
        assert!(matches!(e, Error::Compile(_)));
    }
}

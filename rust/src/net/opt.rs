//! The Plan-IR optimizer: a pass pipeline that turns a recorded
//! [`Plan`] into the densest possible serving artifact.
//!
//! A compiled plan is a *coefficient program*: every slot is a fixed
//! linear combination of the `K` inputs (Remark 2 — width-independent),
//! and the only slots a serving replay ever needs are the ones
//! `output_slots` names. The pipeline exploits exactly that:
//!
//! 1. **Liveness / dead-slot elimination** — walk backwards from
//!    `output_slots` through the defining lincombs. The IR stores every
//!    lincomb over the *input* slots, so the backward closure terminates
//!    in one step: live = output slots ∪ inputs. Everything else — the
//!    wire-only intermediates of the prepare/butterfly/draw phases — is
//!    dead for replay and dropped.
//! 2. **CSE / re-interning** — surviving lincombs are re-interned by
//!    coefficient row, merging duplicates and renumbering densely.
//!    (Compile-time interning already dedups globally, so on
//!    compiler-produced plans this pass merges nothing; it is the
//!    normalisation guarantee for any future IR transform, and it counts
//!    what it merged.)
//! 3. **Flattening** — every live output lincomb is lowered to a dense
//!    row over the `K` inputs, yielding the [`OutputMatrix`]: serving a
//!    job is now literally `M · x`, a gemm
//!    ([`gemm_row_into`](crate::gf::matrix::gemm_row_into), driven by
//!    [`replay_opt`](crate::net::exec::replay_opt) /
//!    [`replay_batch`](crate::net::exec::replay_batch)).
//!
//! For a systematic encode the `OutputMatrix` rows at the sink
//! processors *are* the parity columns of the code's generator matrix —
//! `framework::compile_plan` cross-checks them against the `codes::`
//! algebra on every compile, so a miscompiled or corrupted plan fails
//! loudly before it is ever cached.

use super::plan::Plan;
use super::sim::{ProcId, SimReport};
use std::collections::{BTreeMap, HashMap};

/// What the pass pipeline did to one plan. Reported next to `C1`/`C2`
/// by [`plan_profile`](crate::framework::costs::plan_profile).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OptStats {
    /// Arena slots in the raw plan (`K` inputs + interned lincombs).
    pub slots_before: usize,
    /// Live slots after DCE + CSE (`K` inputs + surviving lincombs).
    pub slots_after: usize,
    /// Interned lincombs dropped by liveness (wire-only intermediates).
    pub dead_lincombs: usize,
    /// Live lincombs merged by re-interning (duplicate coefficient rows).
    pub cse_merged: usize,
}

impl OptStats {
    /// Total interned lincombs the pipeline eliminated.
    pub fn lincombs_eliminated(&self) -> usize {
        self.dead_lincombs + self.cse_merged
    }
}

/// The flattened form of a plan's outputs: one dense coefficient row
/// over the `K` inputs per distinct live output lincomb, plus the
/// `ProcId → row` assignment. Evaluating a job is `M · x`; several
/// processors may share one row (e.g. a broadcast is a single row
/// referenced by every participant).
#[derive(Clone, Debug)]
pub struct OutputMatrix {
    k: usize,
    n_rows: usize,
    /// Row-major `n_rows × k` coefficient rows.
    rows: Vec<u64>,
    /// Final-packet row index per processor.
    assignment: BTreeMap<ProcId, usize>,
}

impl OutputMatrix {
    /// `K` — the number of columns (input slots).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Number of distinct coefficient rows.
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Dense coefficient row `i`.
    pub fn row(&self, i: usize) -> &[u64] {
        &self.rows[i * self.k..(i + 1) * self.k]
    }

    /// The dense row computing `pid`'s final packet, if `pid` has one.
    pub fn row_for(&self, pid: ProcId) -> Option<&[u64]> {
        self.assignment.get(&pid).map(|&i| self.row(i))
    }

    /// `ProcId → row index` of every final packet.
    pub fn assignment(&self) -> &BTreeMap<ProcId, usize> {
        &self.assignment
    }

    /// The whole matrix as a flat row-major buffer.
    pub fn rows_flat(&self) -> &[u64] {
        &self.rows
    }

    /// Distinct row indices computing the outputs of the processors
    /// `keep` accepts, ascending — the degraded replay path evaluates
    /// exactly the rows of the surviving processors and skips the rest.
    pub fn rows_where(&self, mut keep: impl FnMut(ProcId) -> bool) -> Vec<usize> {
        let mut rows: Vec<usize> = self
            .assignment
            .iter()
            .filter(|&(&pid, _)| keep(pid))
            .map(|(_, &r)| r)
            .collect();
        rows.sort_unstable();
        rows.dedup();
        rows
    }
}

/// A plan lowered through the full pass pipeline: the [`OutputMatrix`],
/// the pipeline's [`OptStats`], and enough statics to reproduce the
/// exact [`SimReport`] of a live run at any width. This is what the
/// serving path executes; the raw [`Plan`] is kept alongside it (in
/// `framework::CompiledPlan`) for wire-level replay and inspection.
#[derive(Clone, Debug)]
pub struct OptimizedPlan {
    /// `K` — number of input slots.
    pub n_inputs: usize,
    pub matrix: OutputMatrix,
    pub stats: OptStats,
    /// The raw plan's report at unit width; [`report`](Self::report)
    /// scales it (every term of `C2`/bandwidth is linear in `W`).
    unit_report: SimReport,
}

impl OptimizedPlan {
    /// The exact [`SimReport`] a live run at payload width `w` produces
    /// — identical to [`Plan::report`] on the raw plan.
    pub fn report(&self, w: usize) -> SimReport {
        let w = w as u64;
        let per_round_max: Vec<u64> =
            self.unit_report.per_round_max.iter().map(|m| m * w).collect();
        SimReport {
            c1: self.unit_report.c1,
            c2: per_round_max.iter().sum(),
            per_round_max,
            messages: self.unit_report.messages,
            bandwidth: self.unit_report.bandwidth * w,
        }
    }

    /// Live slots after the pipeline (`stats.slots_after`).
    pub fn live_slots(&self) -> usize {
        self.stats.slots_after
    }
}

/// Run the pass pipeline (liveness → CSE/re-intern → flatten) over a
/// compiled plan. Pure function of the plan; the result replays
/// bit-identically to the raw plan (asserted in `tests/plan_opt.rs`).
pub fn optimize(plan: &Plan) -> OptimizedPlan {
    let k = plan.n_inputs;

    // Pass 1 — liveness: the replay path needs exactly the output slots
    // (their lincombs are stored over the inputs, so the backward
    // closure adds nothing further). Dedup'd in slot order so the later
    // passes are deterministic.
    let mut live: Vec<usize> = plan.output_slots().values().copied().collect();
    live.sort_unstable();
    live.dedup();
    let live_compute_count = live.iter().filter(|&&s| s >= k).count();
    let dead_lincombs = (plan.n_slots() - k) - live_compute_count;

    // Pass 2 + 3 — re-intern by dense coefficient row and flatten. An
    // input slot flattens to its unit vector; a compute slot scatters
    // its (coeff, src) terms into a dense row.
    let mut seen: HashMap<Vec<u64>, usize> = HashMap::with_capacity(live.len());
    let mut rows: Vec<u64> = Vec::with_capacity(live.len() * k);
    let mut slot_row: HashMap<usize, usize> = HashMap::with_capacity(live.len());
    let mut cse_merged = 0usize;
    let mut live_after_cse = 0usize;
    for &slot in &live {
        let mut row = vec![0u64; k];
        if slot < k {
            row[slot] = 1;
        } else {
            for &(c, src) in plan.lincomb(slot) {
                row[src] = c;
            }
        }
        let idx = if let Some(&i) = seen.get(&row) {
            if slot >= k {
                cse_merged += 1;
            }
            i
        } else {
            let i = seen.len();
            rows.extend_from_slice(&row);
            seen.insert(row, i);
            if slot >= k {
                live_after_cse += 1;
            }
            i
        };
        slot_row.insert(slot, idx);
    }
    let assignment: BTreeMap<ProcId, usize> = plan
        .output_slots()
        .iter()
        .map(|(&pid, &slot)| (pid, slot_row[&slot]))
        .collect();

    let n_rows = seen.len();
    OptimizedPlan {
        n_inputs: k,
        matrix: OutputMatrix {
            k,
            n_rows,
            rows,
            assignment,
        },
        stats: OptStats {
            slots_before: plan.n_slots(),
            slots_after: k + live_after_cse,
            dead_lincombs,
            cse_merged,
        },
        unit_report: plan.report(1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{PrepareShoot, TreeBroadcast, TreeReduce};
    use crate::gf::{GfPrime, Mat};
    use crate::net::plan::compile;
    use std::sync::Arc;

    #[test]
    fn prepare_shoot_drops_wire_only_slots() {
        let f = GfPrime::default_field();
        let k = 16usize;
        let c = Arc::new(Mat::random(&f, k, k, 5));
        let plan = compile(1, k, |basis| {
            Ok(Box::new(PrepareShoot::new(
                f,
                (0..k).collect(),
                1,
                c.clone(),
                basis,
            )))
        })
        .unwrap();
        let opt = optimize(&plan);
        assert_eq!(opt.stats.slots_before, plan.n_slots());
        assert!(
            opt.stats.slots_after < opt.stats.slots_before,
            "prepare-phase partials must be dead: {:?}",
            opt.stats
        );
        assert!(opt.stats.dead_lincombs > 0);
        assert_eq!(opt.stats.cse_merged, 0, "compile interning already dedups");
        assert_eq!(
            opt.stats.slots_before - opt.stats.slots_after,
            opt.stats.lincombs_eliminated()
        );
        // Flattened rows at each processor are the columns of C: output
        // of proc j is Σ_k C[k][j]·x_k.
        for j in 0..k {
            let row = opt.matrix.row_for(j).unwrap();
            for i in 0..k {
                assert_eq!(row[i], c[(i, j)], "proc {j} input {i}");
            }
        }
        // The report statics survive the lowering, at every width.
        for w in [1usize, 7] {
            assert_eq!(opt.report(w), plan.report(w), "w={w}");
        }
    }

    #[test]
    fn broadcast_flattens_to_one_shared_unit_row() {
        let plan = compile(1, 1, |basis| {
            Ok(Box::new(TreeBroadcast::new(
                (0..8).collect(),
                1,
                basis.into_iter().next().unwrap(),
            )))
        })
        .unwrap();
        let opt = optimize(&plan);
        assert_eq!(opt.matrix.n_rows(), 1, "one row shared by all 8 procs");
        assert_eq!(opt.matrix.row(0), &[1]);
        assert_eq!(opt.matrix.assignment().len(), 8);
        assert!(opt.matrix.assignment().values().all(|&i| i == 0));
        assert_eq!(opt.stats.slots_after, 1);
    }

    #[test]
    fn reduce_flattens_root_to_all_ones_row() {
        let f = GfPrime::default_field();
        let n = 5usize;
        let plan = compile(1, n, |basis| {
            Ok(Box::new(TreeReduce::new(f, (0..n).collect(), 1, basis)))
        })
        .unwrap();
        let opt = optimize(&plan);
        let root = opt.matrix.row_for(0).unwrap();
        assert_eq!(root, vec![1u64; n]);
    }
}

//! The Plan-IR optimizer: a pass pipeline that turns a recorded
//! [`Plan`] into the densest possible serving artifact.
//!
//! A compiled plan is a *coefficient program*: every slot is a fixed
//! linear combination of the `K` inputs (Remark 2 — width-independent),
//! and the only slots a serving replay ever needs are the ones
//! `output_slots` names. The pipeline exploits exactly that:
//!
//! 1. **Liveness / dead-slot elimination** — walk backwards from
//!    `output_slots` through the defining lincombs. The IR stores every
//!    lincomb over the *input* slots, so the backward closure terminates
//!    in one step: live = output slots ∪ inputs. Everything else — the
//!    wire-only intermediates of the prepare/butterfly/draw phases — is
//!    dead for replay and dropped.
//! 2. **CSE / re-interning** — surviving lincombs are re-interned by
//!    coefficient row, merging duplicates and renumbering densely.
//!    (Compile-time interning already dedups globally, so on
//!    compiler-produced plans this pass merges nothing; it is the
//!    normalisation guarantee for any future IR transform, and it counts
//!    what it merged.)
//! 3. **Flattening** — every live output lincomb is lowered to a dense
//!    row over the `K` inputs, yielding the [`OutputMatrix`]: serving a
//!    job is now literally `M · x`, a gemm
//!    ([`gemm_row_into`](crate::gf::matrix::gemm_row_into), driven by
//!    [`replay_opt`](crate::net::exec::replay_opt) /
//!    [`replay_batch`](crate::net::exec::replay_batch)).
//!
//! For a systematic encode the `OutputMatrix` rows at the sink
//! processors *are* the parity columns of the code's generator matrix —
//! `framework::compile_plan` cross-checks them against the `codes::`
//! algebra on every compile, so a miscompiled or corrupted plan fails
//! loudly before it is ever cached.

use super::plan::Plan;
use super::sim::{ProcId, SimReport};
use crate::gf::{ntt, AnyField, Field, GfPrime};
use std::collections::{BTreeMap, HashMap};

/// What the pass pipeline did to one plan. Reported next to `C1`/`C2`
/// by [`plan_profile`](crate::framework::costs::plan_profile).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OptStats {
    /// Arena slots in the raw plan (`K` inputs + interned lincombs).
    pub slots_before: usize,
    /// Live slots after DCE + CSE (`K` inputs + surviving lincombs).
    pub slots_after: usize,
    /// Interned lincombs dropped by liveness (wire-only intermediates).
    pub dead_lincombs: usize,
    /// Live lincombs merged by re-interning (duplicate coefficient rows).
    pub cse_merged: usize,
}

impl OptStats {
    /// Total interned lincombs the pipeline eliminated.
    pub fn lincombs_eliminated(&self) -> usize {
        self.dead_lincombs + self.cse_merged
    }
}

/// The flattened form of a plan's outputs: one dense coefficient row
/// over the `K` inputs per distinct live output lincomb, plus the
/// `ProcId → row` assignment. Evaluating a job is `M · x`; several
/// processors may share one row (e.g. a broadcast is a single row
/// referenced by every participant).
#[derive(Clone, Debug)]
pub struct OutputMatrix {
    k: usize,
    n_rows: usize,
    /// Row-major `n_rows × k` coefficient rows.
    rows: Vec<u64>,
    /// Final-packet row index per processor.
    assignment: BTreeMap<ProcId, usize>,
}

impl OutputMatrix {
    /// `K` — the number of columns (input slots).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Number of distinct coefficient rows.
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Dense coefficient row `i`.
    pub fn row(&self, i: usize) -> &[u64] {
        &self.rows[i * self.k..(i + 1) * self.k]
    }

    /// The dense row computing `pid`'s final packet, if `pid` has one.
    pub fn row_for(&self, pid: ProcId) -> Option<&[u64]> {
        self.assignment.get(&pid).map(|&i| self.row(i))
    }

    /// `ProcId → row index` of every final packet.
    pub fn assignment(&self) -> &BTreeMap<ProcId, usize> {
        &self.assignment
    }

    /// The whole matrix as a flat row-major buffer.
    pub fn rows_flat(&self) -> &[u64] {
        &self.rows
    }

    /// Distinct row indices computing the outputs of the processors
    /// `keep` accepts, ascending — the degraded replay path evaluates
    /// exactly the rows of the surviving processors and skips the rest.
    pub fn rows_where(&self, mut keep: impl FnMut(ProcId) -> bool) -> Vec<usize> {
        let mut rows: Vec<usize> = self
            .assignment
            .iter()
            .filter(|&(&pid, _)| keep(pid))
            .map(|(_, &r)| r)
            .collect();
        rows.sort_unstable();
        rows.dedup();
        rows
    }
}

/// A plan lowered through the full pass pipeline: the [`OutputMatrix`],
/// the pipeline's [`OptStats`], and enough statics to reproduce the
/// exact [`SimReport`] of a live run at any width. This is what the
/// serving path executes; the raw [`Plan`] is kept alongside it (in
/// `framework::CompiledPlan`) for wire-level replay and inspection.
#[derive(Clone, Debug)]
pub struct OptimizedPlan {
    /// `K` — number of input slots.
    pub n_inputs: usize,
    pub matrix: OutputMatrix,
    pub stats: OptStats,
    /// The raw plan's report at unit width; [`report`](Self::report)
    /// scales it (every term of `C2`/bandwidth is linear in `W`).
    unit_report: SimReport,
}

impl OptimizedPlan {
    /// The exact [`SimReport`] a live run at payload width `w` produces
    /// — identical to [`Plan::report`] on the raw plan.
    pub fn report(&self, w: usize) -> SimReport {
        let w = w as u64;
        let per_round_max: Vec<u64> =
            self.unit_report.per_round_max.iter().map(|m| m * w).collect();
        SimReport {
            c1: self.unit_report.c1,
            c2: per_round_max.iter().sum(),
            per_round_max,
            messages: self.unit_report.messages,
            bandwidth: self.unit_report.bandwidth * w,
        }
    }

    /// Live slots after the pipeline (`stats.slots_after`).
    pub fn live_slots(&self) -> usize {
        self.stats.slots_after
    }
}

/// Run the pass pipeline (liveness → CSE/re-intern → flatten) over a
/// compiled plan. Pure function of the plan; the result replays
/// bit-identically to the raw plan (asserted in `tests/plan_opt.rs`).
pub fn optimize(plan: &Plan) -> OptimizedPlan {
    let k = plan.n_inputs;

    // Pass 1 — liveness: the replay path needs exactly the output slots
    // (their lincombs are stored over the inputs, so the backward
    // closure adds nothing further). Dedup'd in slot order so the later
    // passes are deterministic.
    let mut live: Vec<usize> = plan.output_slots().values().copied().collect();
    live.sort_unstable();
    live.dedup();
    let live_compute_count = live.iter().filter(|&&s| s >= k).count();
    let dead_lincombs = (plan.n_slots() - k) - live_compute_count;

    // Pass 2 + 3 — re-intern by dense coefficient row and flatten. An
    // input slot flattens to its unit vector; a compute slot scatters
    // its (coeff, src) terms into a dense row.
    let mut seen: HashMap<Vec<u64>, usize> = HashMap::with_capacity(live.len());
    let mut rows: Vec<u64> = Vec::with_capacity(live.len() * k);
    let mut slot_row: HashMap<usize, usize> = HashMap::with_capacity(live.len());
    let mut cse_merged = 0usize;
    let mut live_after_cse = 0usize;
    for &slot in &live {
        let mut row = vec![0u64; k];
        if slot < k {
            row[slot] = 1;
        } else {
            for &(c, src) in plan.lincomb(slot) {
                row[src] = c;
            }
        }
        let idx = if let Some(&i) = seen.get(&row) {
            if slot >= k {
                cse_merged += 1;
            }
            i
        } else {
            let i = seen.len();
            rows.extend_from_slice(&row);
            seen.insert(row, i);
            if slot >= k {
                live_after_cse += 1;
            }
            i
        };
        slot_row.insert(slot, idx);
    }
    let assignment: BTreeMap<ProcId, usize> = plan
        .output_slots()
        .iter()
        .map(|(&pid, &slot)| (pid, slot_row[&slot]))
        .collect();

    let n_rows = seen.len();
    OptimizedPlan {
        n_inputs: k,
        matrix: OutputMatrix {
            k,
            n_rows,
            rows,
            assignment,
        },
        stats: OptStats {
            slots_before: plan.n_slots(),
            slots_after: k + live_after_cse,
            dead_lincombs,
            cse_merged,
        },
        unit_report: plan.report(1),
    }
}

/// The GRS/Lagrange evaluation geometry of a compiled plan's code —
/// borrowed views of the pieces backend selection needs, so `net` does
/// not depend on the `codes` layer (which already depends on `net`).
#[derive(Clone, Copy, Debug)]
pub struct CodeShape<'a> {
    /// Systematic evaluation points `α_0..α_{K−1}`.
    pub alphas: &'a [u64],
    /// Parity evaluation points `β_0..β_{R−1}`.
    pub betas: &'a [u64],
    /// Column multipliers `u` (systematic) and `v` (parity).
    pub u: &'a [u64],
    pub v: &'a [u64],
}

/// What one [`OutputMatrix`] row computes, as the NTT backend sees it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RowKind {
    /// The unit row `e_j`: the output *is* input `j` (systematic half).
    Unit(usize),
    /// Parity coordinate `r` of the code (`c_{K+r} = v_r·g(β_r)`).
    Parity(usize),
}

/// Dense-op : NTT-op threshold for preferring the transform. The NTT's
/// per-op constant (full-width `u64` modmul butterflies) is worse than
/// the packed gemm's narrow-lane delayed-reduction multiply-add, so the
/// transform must win the *op count* by a comfortable factor before it
/// wins wall time; `benches/ntt_backend.rs` measures the real crossover.
pub const NTT_DENSE_OP_RATIO: usize = 4;

/// The `O(K log K)` encode engine for GRS/Lagrange codes on NTT-friendly
/// geometry: `α` sweeping all `K`-th roots of unity and `β` on a coset
/// `c·⟨ω₂⟩` of the `n2`-th roots (`n2 = R.next_power_of_two()`). One
/// batch encode over the columnar `K × (W·B)` arena is then
///
/// ```text
/// t = x ⊙ u⁻¹   →   y = INTT_K(t)   →   ŷ_i = c^i·y_i   →
/// z_m = Σ_{i ≡ m (n2)} ŷ_i   →   NTT_{n2}(z)   →   parity_r = v_r·z_r
/// ```
///
/// — interpolation of `g` (degree < K, `x_k = u_k·g(α_k)`), a single
/// diagonal *twist* moving the evaluation grid onto the coset, a fold
/// exploiting `ω₂^{n2} = 1`, and one small forward transform. Detection
/// ([`detect`](Self::detect)) is structural and separate from the
/// cost-gate policy ([`select_backend`]), so tests can force the
/// transform at any `K`; every detected backend is cross-checked against
/// the flattened [`OutputMatrix`] on a `K × K` identity arena before it
/// is trusted (divergence is a loud error, exactly like the generator
/// cross-check in `framework::compile_plan`).
#[derive(Clone, Debug)]
pub struct NttBackend {
    field: GfPrime,
    k: usize,
    r: usize,
    /// Transform size of the parity-side NTT: `max(1, R)` rounded up to
    /// a power of two.
    n2: usize,
    /// `u_k^{-1}` — undoes the systematic multipliers before interpolation.
    u_inv: Vec<u64>,
    /// `c^i` for `i < K`, `c = f.generator()` — the coset twist diagonal.
    twist: Vec<u64>,
    /// Parity multipliers `v_r`, applied after evaluation.
    v: Vec<u64>,
    /// What each [`OutputMatrix`] row computes, by row index.
    row_kinds: Vec<RowKind>,
}

/// Resolve `f` to the crate's concrete prime field, including through
/// [`AnyField`] (the coordinator's erased field) — same discipline as
/// `Kernels::for_field`. Extension fields have no two-adic root tower
/// here, so they never get an NTT backend.
fn prime_of<F: Field>(f: &F) -> Option<GfPrime> {
    let any: &dyn std::any::Any = f;
    if let Some(af) = any.downcast_ref::<AnyField>() {
        return match af {
            AnyField::Prime(p) => Some(*p),
            _ => None,
        };
    }
    any.downcast_ref::<GfPrime>().copied()
}

impl NttBackend {
    /// Structural detection: does this plan's flattened output matrix
    /// compute exactly an NTT-friendly GRS encode? `sink_rows[r]` is the
    /// matrix row computing parity coordinate `r` (from the compiled
    /// layout's sink assignment). Returns `Ok(None)` when the shape does
    /// not fit (non-prime field, `K` not a power of two, points off the
    /// root/coset grid, a non-unit non-sink row); returns `Err` only
    /// when the shape *claims* to fit but the identity cross-check
    /// against the matrix algebra diverges — a miscompile, never a
    /// fallback.
    pub fn detect<F: Field>(
        f: &F,
        matrix: &OutputMatrix,
        shape: &CodeShape<'_>,
        sink_rows: &[usize],
    ) -> anyhow::Result<Option<Self>> {
        let Some(p) = prime_of(f) else {
            return Ok(None);
        };
        let k = matrix.k();
        let r = shape.betas.len();
        if k == 0 || r == 0 || !k.is_power_of_two() {
            return Ok(None);
        }
        if shape.alphas.len() != k || shape.u.len() != k || shape.v.len() != r {
            return Ok(None);
        }
        if sink_rows.len() != r || shape.u.iter().chain(shape.v).any(|&m| m == 0) {
            return Ok(None);
        }
        let n2 = r.next_power_of_two();
        let (Some(w1), Some(w2)) =
            (p.root_of_unity(k as u64), p.root_of_unity(n2 as u64))
        else {
            return Ok(None);
        };
        let c = p.generator();
        // The evaluation grid must be exactly roots + coset, in order.
        for (i, &a) in shape.alphas.iter().enumerate() {
            if a != p.pow(w1, i as u64) {
                return Ok(None);
            }
        }
        for (j, &b) in shape.betas.iter().enumerate() {
            if b != p.mul(c, p.pow(w2, j as u64)) {
                return Ok(None);
            }
        }
        // Classify every matrix row: parity rows come from the sink
        // assignment, everything else must be a coefficient-1 unit row.
        let n_rows = matrix.n_rows();
        let mut row_kinds = vec![None; n_rows];
        for (pr, &ri) in sink_rows.iter().enumerate() {
            if ri >= n_rows || row_kinds[ri].is_some() {
                return Ok(None);
            }
            row_kinds[ri] = Some(RowKind::Parity(pr));
        }
        for (ri, kind) in row_kinds.iter_mut().enumerate() {
            if kind.is_some() {
                continue;
            }
            let row = matrix.row(ri);
            let mut unit = None;
            for (j, &cv) in row.iter().enumerate() {
                if cv != 0 {
                    if cv != p.one() || unit.is_some() {
                        return Ok(None);
                    }
                    unit = Some(j);
                }
            }
            match unit {
                Some(j) => *kind = Some(RowKind::Unit(j)),
                None => return Ok(None),
            }
        }
        let backend = NttBackend {
            field: p,
            k,
            r,
            n2,
            u_inv: shape.u.iter().map(|&m| p.inv(m)).collect(),
            twist: (0..k as u64).map(|i| p.pow(c, i)).collect(),
            v: shape.v.to_vec(),
            row_kinds: row_kinds.into_iter().map(Option::unwrap).collect(),
        };
        // Compile-time cross-check against the flattened algebra: on the
        // K × K identity arena, parity staging row `r` must reproduce
        // the matrix's parity row bit for bit.
        let mut ident = vec![0u64; k * k];
        for i in 0..k {
            ident[i * k + i] = p.one();
        }
        let staging = backend.parity_rows(&ident, k)?;
        for (pr, &ri) in sink_rows.iter().enumerate() {
            if &staging[pr * k..(pr + 1) * k] != matrix.row(ri) {
                anyhow::bail!(
                    "NTT backend diverges from the flattened output matrix at \
                     parity row {pr}: the compiled plan does not encode the \
                     claimed GRS code"
                );
            }
        }
        Ok(Some(backend))
    }

    /// Evaluate all parity coordinates across a columnar `K × width`
    /// arena (`width = W·B` on the serving path): the interpolate →
    /// twist → fold → evaluate pipeline from the type docs. Returns the
    /// `R × width` parity staging buffer, canonical `u64`.
    pub fn parity_rows(&self, arena: &[u64], width: usize) -> anyhow::Result<Vec<u64>> {
        let f = self.field;
        anyhow::ensure!(arena.len() == self.k * width, "arena must be K × width");
        // t = x ⊙ u⁻¹: undo the systematic multipliers.
        let mut t = arena.to_vec();
        for (ki, &ui) in self.u_inv.iter().enumerate() {
            for x in &mut t[ki * width..(ki + 1) * width] {
                *x = f.mul(*x, ui);
            }
        }
        // y = INTT_K(t): coefficients of g (α_i = ω₁^i, natural order).
        ntt::intt_rows(&f, &mut t, self.k, width)?;
        // Twist by c^i, then fold mod n2: since ω₂^{n2} = 1, evaluating
        // Σ c^i·y_i·ω₂^{ij} only needs the folded sums z_m.
        let mut z = vec![0u64; self.n2 * width];
        for (i, &ci) in self.twist.iter().enumerate() {
            let zi = (i % self.n2) * width;
            for x in 0..width {
                z[zi + x] = f.add(z[zi + x], f.mul(t[i * width + x], ci));
            }
        }
        // NTT_{n2}(z): g(c·ω₂^j) for every parity point at once.
        ntt::ntt_rows(&f, &mut z, self.n2, width)?;
        // parity_r = v_r·g(β_r).
        let mut out = vec![0u64; self.r * width];
        for (r, &vr) in self.v.iter().enumerate() {
            for x in 0..width {
                out[r * width + x] = f.mul(vr, z[r * width + x]);
            }
        }
        Ok(out)
    }

    /// What matrix row `ri` computes.
    pub fn row_kind(&self, ri: usize) -> RowKind {
        self.row_kinds[ri]
    }

    /// Number of matrix rows this backend was detected against.
    pub fn n_rows(&self) -> usize {
        self.row_kinds.len()
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn r(&self) -> usize {
        self.r
    }

    /// The field order `q` (for canonical-input validation).
    pub fn order(&self) -> u64 {
        self.field.order()
    }

    /// Per-column multiply count of the dense engine's non-trivial rows
    /// (`R` parity rows × `K` coefficients; unit rows are copies either
    /// way).
    pub fn dense_ops(&self) -> usize {
        self.r * self.k
    }

    /// Per-column multiply count of the transform pipeline: two
    /// transforms plus the scale/twist/fold diagonals.
    pub fn ntt_ops(&self) -> usize {
        let lg = |n: usize| n.trailing_zeros() as usize;
        self.k * lg(self.k) + self.n2 * lg(self.n2) + 2 * self.k + 2 * self.n2
    }

    /// The cost-gate policy: prefer the transform only when it wins the
    /// op count by [`NTT_DENSE_OP_RATIO`].
    pub fn ntt_wins(&self) -> bool {
        self.dense_ops() >= NTT_DENSE_OP_RATIO * self.ntt_ops()
    }
}

/// Which engine serves a compiled plan's batched replays.
#[derive(Clone, Debug)]
pub enum EncodeBackend {
    /// The packed dense gemm over the full [`OutputMatrix`].
    Dense,
    /// The `O(K log K)` transform pipeline (plus unit-row copies).
    Ntt(NttBackend),
}

/// The tag of an [`EncodeBackend`] — what `plan_profile` records.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    Dense,
    Ntt,
}

impl BackendKind {
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Dense => "dense",
            BackendKind::Ntt => "ntt",
        }
    }
}

impl EncodeBackend {
    pub fn kind(&self) -> BackendKind {
        match self {
            EncodeBackend::Dense => BackendKind::Dense,
            EncodeBackend::Ntt(_) => BackendKind::Ntt,
        }
    }
}

/// The backend-selection pass: structural detection
/// ([`NttBackend::detect`]) gated by the op-count crossover
/// ([`NttBackend::ntt_wins`]). `shape = None` (no code attached to the
/// plan — random matrices, ad-hoc collectives) always serves dense.
/// `Err` means the detected shape failed its identity cross-check — a
/// miscompile that must not be served at all.
pub fn select_backend<F: Field>(
    f: &F,
    opt: &OptimizedPlan,
    shape: Option<CodeShape<'_>>,
    sink_rows: &[usize],
) -> anyhow::Result<EncodeBackend> {
    let Some(shape) = shape else {
        return Ok(EncodeBackend::Dense);
    };
    match NttBackend::detect(f, &opt.matrix, &shape, sink_rows)? {
        Some(b) if b.ntt_wins() => Ok(EncodeBackend::Ntt(b)),
        _ => Ok(EncodeBackend::Dense),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{PrepareShoot, TreeBroadcast, TreeReduce};
    use crate::gf::{GfPrime, Mat};
    use crate::net::plan::compile;
    use std::sync::Arc;

    #[test]
    fn prepare_shoot_drops_wire_only_slots() {
        let f = GfPrime::default_field();
        let k = 16usize;
        let c = Arc::new(Mat::random(&f, k, k, 5));
        let plan = compile(1, k, |basis| {
            Ok(Box::new(PrepareShoot::new(
                f,
                (0..k).collect(),
                1,
                c.clone(),
                basis,
            )))
        })
        .unwrap();
        let opt = optimize(&plan);
        assert_eq!(opt.stats.slots_before, plan.n_slots());
        assert!(
            opt.stats.slots_after < opt.stats.slots_before,
            "prepare-phase partials must be dead: {:?}",
            opt.stats
        );
        assert!(opt.stats.dead_lincombs > 0);
        assert_eq!(opt.stats.cse_merged, 0, "compile interning already dedups");
        assert_eq!(
            opt.stats.slots_before - opt.stats.slots_after,
            opt.stats.lincombs_eliminated()
        );
        // Flattened rows at each processor are the columns of C: output
        // of proc j is Σ_k C[k][j]·x_k.
        for j in 0..k {
            let row = opt.matrix.row_for(j).unwrap();
            for i in 0..k {
                assert_eq!(row[i], c[(i, j)], "proc {j} input {i}");
            }
        }
        // The report statics survive the lowering, at every width.
        for w in [1usize, 7] {
            assert_eq!(opt.report(w), plan.report(w), "w={w}");
        }
    }

    #[test]
    fn broadcast_flattens_to_one_shared_unit_row() {
        let plan = compile(1, 1, |basis| {
            Ok(Box::new(TreeBroadcast::new(
                (0..8).collect(),
                1,
                basis.into_iter().next().unwrap(),
            )))
        })
        .unwrap();
        let opt = optimize(&plan);
        assert_eq!(opt.matrix.n_rows(), 1, "one row shared by all 8 procs");
        assert_eq!(opt.matrix.row(0), &[1]);
        assert_eq!(opt.matrix.assignment().len(), 8);
        assert!(opt.matrix.assignment().values().all(|&i| i == 0));
        assert_eq!(opt.stats.slots_after, 1);
    }

    #[test]
    fn reduce_flattens_root_to_all_ones_row() {
        let f = GfPrime::default_field();
        let n = 5usize;
        let plan = compile(1, n, |basis| {
            Ok(Box::new(TreeReduce::new(f, (0..n).collect(), 1, basis)))
        })
        .unwrap();
        let opt = optimize(&plan);
        let root = opt.matrix.row_for(0).unwrap();
        assert_eq!(root, vec![1u64; n]);
    }
}

//! Noisy links (§VII Discussion): *"our methods can be easily integrated
//! into noisy environments — the processors apply some error-correcting
//! code over their sent packets prior to sending them, and the received
//! packets undergo the respective decoding process."*
//!
//! This module implements exactly that integration:
//!
//! * [`ErasureChannel`] — a symbol-erasure channel: each field element of
//!   each message is erased independently with probability `rate`
//!   (erasures are flagged, as in storage/packet networks);
//! * [`InnerFec`] — a systematic RS inner code over the *transport* field:
//!   every packet gets `t` parity symbols appended before transmission and
//!   is repaired at the receiver if it suffered at most `t` erasures;
//! * [`NoisyCollective`] — a decorator that FEC-wraps an inner collective:
//!   outgoing packets are encoded, the channel corrupts them, incoming
//!   packets are decoded — transparently to the wrapped algorithm.
//!
//! The cost impact is visible in the reports: `C2` grows by the factor
//! `(W+t)/W` — the paper's claim that noise integration is orthogonal to
//! the scheduling.

use super::payload::{Packet, PacketBuf};
use super::sim::{Collective, Msg, Outputs, ProcId};
use crate::codes::GrsCode;
use crate::gf::Field;
use crate::util::Rng;

/// Marker for an erased symbol on the wire. Channel-level only; the value
/// is outside every supported field (fields here have order ≤ 2^31).
const ERASED: u64 = u64::MAX;

/// Independent symbol-erasure channel.
#[derive(Debug)]
pub struct ErasureChannel {
    pub rate: f64,
    rng: Rng,
}

impl ErasureChannel {
    pub fn new(rate: f64, seed: u64) -> Self {
        assert!((0.0..1.0).contains(&rate));
        ErasureChannel {
            rate,
            rng: Rng::new(seed),
        }
    }

    /// Corrupt wire symbols in place.
    fn hit(&mut self, symbols: &mut [u64]) {
        for s in symbols.iter_mut() {
            if (self.rng.next_u64() as f64 / u64::MAX as f64) < self.rate {
                *s = ERASED;
            }
        }
    }
}

/// Systematic RS inner code: `W` data symbols + `t` parity symbols.
#[derive(Clone, Debug)]
pub struct InnerFec<F: Field> {
    f: F,
    code: GrsCode,
    w: usize,
    t: usize,
}

impl<F: Field> InnerFec<F> {
    /// Protect `w`-symbol packets against up to `t` erasures each.
    pub fn new(f: F, w: usize, t: usize) -> anyhow::Result<Self> {
        anyhow::ensure!(w >= 1 && t >= 1);
        anyhow::ensure!(
            (w + t) as u64 <= f.order(),
            "inner code needs W + t ≤ q"
        );
        let code = GrsCode::plain(
            &f,
            (0..w as u64).collect(),
            (w as u64..(w + t) as u64).collect(),
        )?;
        Ok(InnerFec { f, code, w, t })
    }

    /// Encode: append `t` parity symbols.
    pub fn protect(&self, pkt: &[u64]) -> Packet {
        debug_assert_eq!(pkt.len(), self.w);
        self.code.encode(&self.f, pkt)
    }

    /// Decode: repair ≤ `t` erasures; `None` when unrecoverable.
    pub fn recover(&self, wire: &[u64]) -> Option<Packet> {
        debug_assert_eq!(wire.len(), self.w + self.t);
        let coords: Vec<(usize, u64)> = wire
            .iter()
            .enumerate()
            .filter(|(_, &v)| v != ERASED)
            .map(|(i, &v)| (i, v))
            .collect();
        if coords.len() < self.w {
            return None; // more than t erasures
        }
        self.code.decode(&self.f, &coords).ok()
    }
}

/// FEC-wrapping decorator: transparently protects every message of the
/// wrapped collective against the given channel.
pub struct NoisyCollective<F: Field> {
    inner: Box<dyn Collective>,
    fec: InnerFec<F>,
    channel: ErasureChannel,
    /// Unrecoverable packets observed (a real deployment would ARQ; the
    /// round-synchronous model has no retransmission slot, so we count).
    pub losses: u64,
}

impl<F: Field> NoisyCollective<F> {
    pub fn new(inner: Box<dyn Collective>, fec: InnerFec<F>, channel: ErasureChannel) -> Self {
        NoisyCollective {
            inner,
            fec,
            channel,
            losses: 0,
        }
    }
}

impl<F: Field> Collective for NoisyCollective<F> {
    fn participants(&self) -> Vec<ProcId> {
        self.inner.participants()
    }

    fn is_done(&self) -> bool {
        self.inner.is_done()
    }

    fn step(&mut self, inbox: Vec<Msg>) -> Vec<Msg> {
        // Decode incoming wire packets back to logical packets.
        let decoded: Vec<Msg> = inbox
            .into_iter()
            .map(|mut m| {
                let mut logical = PacketBuf::with_capacity(self.fec.w, m.payload.count());
                for wire in m.payload.iter() {
                    match self.fec.recover(wire) {
                        Some(p) => logical.push(&p),
                        None => {
                            self.losses += 1;
                            logical.push(&vec![0; self.fec.w]); // erase to zero; counted
                        }
                    }
                }
                m.payload = logical;
                m
            })
            .collect();
        // Encode outgoing packets and pass them through the channel.
        let out = self.inner.step(decoded);
        out.into_iter()
            .map(|mut m| {
                let mut wire = PacketBuf::with_capacity(self.fec.w + self.fec.t, m.payload.count());
                for p in m.payload.iter() {
                    wire.push(&self.fec.protect(p));
                }
                self.channel.hit(wire.data_mut());
                m.payload = wire;
                m
            })
            .collect()
    }

    fn outputs(&self) -> Outputs {
        self.inner.outputs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::PrepareShoot;
    use crate::gf::{GfPrime, Mat};
    use crate::net::{pkt_add_scaled, pkt_zero, run, Sim};
    use std::sync::Arc;

    #[test]
    fn inner_fec_roundtrip_and_limits() {
        let f = GfPrime::default_field();
        let fec = InnerFec::new(f, 8, 3).unwrap();
        let pkt: Packet = (10..18).collect();
        let wire = fec.protect(&pkt);
        assert_eq!(wire.len(), 11);
        assert_eq!(&wire[..8], &pkt[..]); // systematic
        // Up to t erasures anywhere repair.
        let mut hit = wire.clone();
        hit[0] = ERASED;
        hit[5] = ERASED;
        hit[9] = ERASED;
        assert_eq!(fec.recover(&hit).unwrap(), pkt);
        // t+1 erasures are detected as unrecoverable.
        hit[10] = ERASED;
        assert!(fec.recover(&hit).is_none());
    }

    #[test]
    fn a2a_survives_noisy_links() {
        // Prepare-and-shoot over a 2% symbol-erasure channel with a
        // t = 4 inner code on W = 8 packets: still exact.
        let f = GfPrime::default_field();
        let (k, w) = (16usize, 8usize);
        let c = Arc::new(Mat::random(&f, k, k, 5));
        let inputs: Vec<Packet> = (0..k)
            .map(|i| (0..w as u64).map(|j| f.elem(i as u64 * 31 + j)).collect())
            .collect();
        let ps = PrepareShoot::new(f, (0..k).collect(), 1, c.clone(), inputs.clone());
        let fec = InnerFec::new(f, w, 4).unwrap();
        let mut noisy =
            NoisyCollective::new(Box::new(ps), fec, ErasureChannel::new(0.02, 42));
        let rep = run(&mut Sim::new(1), &mut noisy).unwrap();
        assert_eq!(noisy.losses, 0, "2% noise must be absorbed by t=4 FEC");
        let outs = noisy.outputs();
        for kk in 0..k {
            let mut want = pkt_zero(w);
            for r in 0..k {
                pkt_add_scaled(&f, &mut want, c[(r, kk)], &inputs[r]);
            }
            assert_eq!(outs[&kk], want, "proc {kk}");
        }
        // And the cost impact is the predicted (W+t)/W factor on C2.
        let ps2 = PrepareShoot::new(f, (0..k).collect(), 1, c, inputs);
        let mut clean = Sim::new(1);
        let mut ps2 = ps2;
        let rep_clean = run(&mut clean, &mut ps2).unwrap();
        assert_eq!(rep.c1, rep_clean.c1);
        assert_eq!(rep.c2 * w as u64, rep_clean.c2 * (w + 4) as u64);
    }

    #[test]
    fn heavy_noise_without_enough_fec_loses_packets() {
        let f = GfPrime::default_field();
        let (k, w) = (16usize, 8usize);
        let c = Arc::new(Mat::random(&f, k, k, 5));
        let inputs: Vec<Packet> = (0..k).map(|_| vec![1; w]).collect();
        let ps = PrepareShoot::new(f, (0..k).collect(), 1, c, inputs);
        let fec = InnerFec::new(f, w, 1).unwrap();
        let mut noisy =
            NoisyCollective::new(Box::new(ps), fec, ErasureChannel::new(0.30, 9));
        let _ = run(&mut Sim::new(1), &mut noisy).unwrap();
        assert!(noisy.losses > 0, "30% noise must overwhelm t=1 FEC");
    }
}

//! Plan compilation: turn one live run of a [`Collective`] into a
//! reusable, data-independent **Plan IR**.
//!
//! Every algorithm in the paper is *linear* and *shape-determined*: for a
//! fixed `(code, K, R, p)` the round-by-round communication pattern
//! (the *scheduling*) and the coefficients of every transmitted linear
//! combination (the *coding scheme*) are identical across runs — only the
//! payload data changes (Remark 1: message contents are never tagged on
//! the wire because the schedule is known a priori). A [`Plan`] captures
//! both halves once so the serving path can replay them without
//! re-deriving any control flow (see [`crate::net::exec`]).
//!
//! **How compilation works.** [`compile`] builds the collective with the
//! `K` *basis* payloads `e_0 … e_{K−1}` (unit vectors of width `K`, valid
//! in any field) and runs it once through a [`PlanRecorder`] under the
//! ordinary engine. Because every local operation is an element-wise
//! linear combination with scalar coefficients, the value of any packet
//! in that run *is* its coefficient row: packet `= Σ_k c_k·e_k` carries
//! exactly `(c_0, …, c_{K−1})`. The recorder therefore reads off, per
//! round, the exact `SendOp` schedule and the lincomb each transmitted
//! packet applies to the inputs — symbolic payload tracking at the cost
//! of one `W = K` run.
//!
//! **The IR.** A slot-addressed buffer arena: slots `0..K` are the
//! inputs; every further slot is defined by one [`ComputeOp`] — a linear
//! combination over input slots — and is first materialised in the round
//! that first transmits it (deduplicated: a packet broadcast down a tree
//! is one slot referenced by many [`SendOp`]s). Outputs are a
//! `ProcId → slot` map. The IR is validated at compile time (p-port
//! constraint, no self-messages, slot well-formedness) and its `C1`/`C2`
//! statics are cross-checked against the recording run's [`SimReport`],
//! so [`Plan::report`] returns the exact engine metrics for any payload
//! width `W` without executing anything.
//!
//! Collectives that are *not* packet-linear (e.g. the FEC-wrapping
//! [`NoisyCollective`](crate::net::NoisyCollective) or the sub-packet
//! chunking [`PipelinedBroadcast`](crate::collectives::PipelinedBroadcast))
//! change packet widths on the wire and are rejected with an error.

use super::payload::Packet;
use super::sim::{run, Collective, Msg, Outputs, ProcId, Sim, SimReport};
use super::trace::TraceEvent;
use anyhow::{ensure, Result};
use std::collections::{BTreeMap, HashMap};

/// Index into the plan's slot arena. Slots `0..n_inputs` are the input
/// packets; higher slots are defined by [`ComputeOp`]s.
pub type SlotId = usize;

/// One local linear combination over the *input* slots:
/// `slot = Σ (coeff · inputs[src])` — zero coefficients omitted.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ComputeOp {
    pub slot: SlotId,
    pub terms: Vec<(u64, SlotId)>,
}

/// One scheduled message: the packets in `slots` travel `src → dst`
/// through send-port `port` (ports numbered per source per round).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SendOp {
    pub src: ProcId,
    pub dst: ProcId,
    pub port: u32,
    /// Payload: one slot per packet, in wire order.
    pub slots: Vec<SlotId>,
}

/// One synchronous round of the compiled schedule.
#[derive(Clone, Debug)]
pub struct RoundPlan {
    /// Slots first materialised (and first transmitted) this round:
    /// the half-open range `[new_slots.0, new_slots.1)`.
    pub new_slots: (SlotId, SlotId),
    pub sends: Vec<SendOp>,
    /// `m_t / W` — the largest packet count of any message this round.
    pub max_packets: u64,
}

/// A compiled, reusable schedule + coding scheme (see module docs).
///
/// Width-independent: a plan compiled once replays for any payload width
/// `W` (Remark 2 — the coding matrix stays over `F_q` while payloads live
/// in `F_q^W`), with `C2` scaling exactly by `W`.
#[derive(Clone, Debug)]
pub struct Plan {
    /// `K` — number of input slots (and of basis payloads at compile).
    pub n_inputs: usize,
    /// `p` — the port budget the schedule was compiled (and validated)
    /// against.
    pub ports: usize,
    rounds: Vec<RoundPlan>,
    /// `computes[i]` defines slot `n_inputs + i`.
    computes: Vec<ComputeOp>,
    /// Final packet per processor, as a slot reference.
    outputs: BTreeMap<ProcId, SlotId>,
    /// Fresh slots allocated for outputs that never hit the wire
    /// (final local combines): `[output_slots.0, output_slots.1)`.
    output_slots: (SlotId, SlotId),
    messages: u64,
    /// Total packets over all messages (`bandwidth / W`).
    packets: u64,
}

impl Plan {
    /// Total number of slots in the arena.
    pub fn n_slots(&self) -> usize {
        self.n_inputs + self.computes.len()
    }

    /// The compiled rounds.
    pub fn rounds(&self) -> &[RoundPlan] {
        &self.rounds
    }

    /// `ProcId → slot` of the final packets.
    pub fn output_slots(&self) -> &BTreeMap<ProcId, SlotId> {
        &self.outputs
    }

    /// The lincomb defining a non-input slot (terms over input slots).
    pub fn lincomb(&self, slot: SlotId) -> &[(u64, SlotId)] {
        assert!(slot >= self.n_inputs, "input slots have no lincomb");
        &self.computes[slot - self.n_inputs].terms
    }

    /// `C1` — round count, width-independent.
    pub fn c1(&self) -> u64 {
        self.rounds.len() as u64
    }

    /// `C2 = Σ_t m_t` for payload width `w`.
    pub fn c2(&self, w: u64) -> u64 {
        self.rounds.iter().map(|r| r.max_packets * w).sum()
    }

    /// The exact [`SimReport`] a live run at payload width `w` produces —
    /// from statics alone, nothing is executed.
    pub fn report(&self, w: usize) -> SimReport {
        let w = w as u64;
        let per_round_max: Vec<u64> = self.rounds.iter().map(|r| r.max_packets * w).collect();
        SimReport {
            c1: self.rounds.len() as u64,
            c2: per_round_max.iter().sum(),
            per_round_max,
            messages: self.messages,
            bandwidth: self.packets * w,
        }
    }

    /// The exact message trace a live run at payload width `w` produces
    /// (round/src/dst/size), in round-major send order.
    pub fn trace_events(&self, w: usize) -> Vec<TraceEvent> {
        let mut out = Vec::with_capacity(self.messages as usize);
        for (t, round) in self.rounds.iter().enumerate() {
            for s in &round.sends {
                out.push(TraceEvent {
                    round: t as u64 + 1,
                    src: s.src,
                    dst: s.dst,
                    elems: (s.slots.len() * w) as u64,
                });
            }
        }
        out
    }

    /// Structural validation: the p-port constraint per round, no
    /// self-messages, no empty payloads, every referenced slot defined
    /// before use, every compute term over input slots in canonical
    /// (strictly ascending, duplicate-free) order, every output slot
    /// live and well-formed, and the stored `C1`/`C2` statics consistent
    /// with the schedule.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.ports >= 1, "plan needs at least one port");
        for (i, c) in self.computes.iter().enumerate() {
            ensure!(c.slot == self.n_inputs + i, "compute op out of order");
            for &(coeff, src) in &c.terms {
                ensure!(src < self.n_inputs, "compute term over non-input slot");
                ensure!(coeff != 0, "zero coefficient stored in lincomb");
            }
            // Canonical term order: the interner emits sources strictly
            // ascending; flattening and replay rely on it for
            // deterministic, bit-identical evaluation.
            ensure!(
                c.terms.windows(2).all(|t| t[0].1 < t[1].1),
                "lincomb terms of slot {} not in strictly ascending source order",
                c.slot
            );
        }
        let mut messages = 0u64;
        let mut packets = 0u64;
        let mut defined = self.n_inputs;
        for (t, round) in self.rounds.iter().enumerate() {
            let (lo, hi) = round.new_slots;
            ensure!(lo == defined && hi >= lo, "round {t}: bad slot range");
            defined = hi;
            ensure!(!round.sends.is_empty(), "round {t}: no sends");
            let mut send_used: HashMap<ProcId, usize> = HashMap::new();
            let mut recv_used: HashMap<ProcId, usize> = HashMap::new();
            let mut m_t = 0u64;
            for s in &round.sends {
                ensure!(s.src != s.dst, "round {t}: self-message at {}", s.src);
                ensure!(!s.slots.is_empty(), "round {t}: empty payload");
                ensure!(
                    s.slots.iter().all(|&sl| sl < defined),
                    "round {t}: slot used before defined"
                );
                ensure!((s.port as usize) < self.ports, "round {t}: port out of range");
                let su = send_used.entry(s.src).or_default();
                *su += 1;
                ensure!(*su <= self.ports, "round {t}: {} exceeds send ports", s.src);
                let ru = recv_used.entry(s.dst).or_default();
                *ru += 1;
                ensure!(*ru <= self.ports, "round {t}: {} exceeds recv ports", s.dst);
                m_t = m_t.max(s.slots.len() as u64);
                messages += 1;
                packets += s.slots.len() as u64;
            }
            ensure!(m_t == round.max_packets, "round {t}: m_t mismatch");
        }
        let (lo, hi) = self.output_slots;
        ensure!(lo == defined && hi == self.n_slots(), "bad output slot range");
        ensure!(messages == self.messages, "message count mismatch");
        ensure!(packets == self.packets, "packet count mismatch");
        ensure!(!self.outputs.is_empty(), "plan has no outputs");
        for (&pid, &slot) in &self.outputs {
            ensure!(slot < self.n_slots(), "output of {pid} references undefined slot");
        }
        // Liveness of the trailing output-only range: those slots exist
        // *only* because an output first materialised them, so each must
        // be referenced by some output — anything else is dead weight a
        // recorder bug smuggled in.
        let referenced: std::collections::HashSet<SlotId> =
            self.outputs.values().copied().collect();
        for s in lo..hi {
            ensure!(
                referenced.contains(&s),
                "output-only slot {s} is not referenced by any output"
            );
        }
        Ok(())
    }
}

/// The instrumenting recorder: a transparent [`Collective`] decorator
/// that clones every non-empty round emission (the engine counts `C1`
/// exactly over non-empty emissions, so recorded rounds align with it).
pub struct PlanRecorder {
    inner: Box<dyn Collective>,
    rounds: Vec<Vec<Msg>>,
}

impl PlanRecorder {
    pub fn new(inner: Box<dyn Collective>) -> Self {
        PlanRecorder {
            inner,
            rounds: Vec::new(),
        }
    }

    /// The recorded per-round emissions.
    pub fn rounds(&self) -> &[Vec<Msg>] {
        &self.rounds
    }
}

impl Collective for PlanRecorder {
    fn participants(&self) -> Vec<ProcId> {
        self.inner.participants()
    }
    fn is_done(&self) -> bool {
        self.inner.is_done()
    }
    fn step(&mut self, inbox: Vec<Msg>) -> Vec<Msg> {
        let out = self.inner.step(inbox);
        if !out.is_empty() {
            self.rounds.push(out.clone());
        }
        out
    }
    fn outputs(&self) -> Outputs {
        self.inner.outputs()
    }
}

/// The `K` basis payloads `e_0 … e_{K−1}` (unit vectors of width `K`) —
/// valid in every field, since entries are 0/1.
pub fn basis_inputs(k: usize) -> Vec<Packet> {
    (0..k)
        .map(|i| {
            let mut e = vec![0u64; k];
            e[i] = 1;
            e
        })
        .collect()
}

/// Interning state: coefficient row → slot, with input slots pre-seeded
/// to the unit vectors.
struct Interner {
    n_inputs: usize,
    seen: HashMap<Vec<u64>, SlotId>,
    computes: Vec<ComputeOp>,
}

impl Interner {
    fn new(n_inputs: usize) -> Self {
        let mut seen = HashMap::with_capacity(n_inputs * 2);
        for (i, e) in basis_inputs(n_inputs).into_iter().enumerate() {
            seen.insert(e, i);
        }
        Interner {
            n_inputs,
            seen,
            computes: Vec::new(),
        }
    }

    fn intern(&mut self, row: &[u64]) -> SlotId {
        if let Some(&slot) = self.seen.get(row) {
            return slot;
        }
        let slot = self.n_inputs + self.computes.len();
        let terms: Vec<(u64, SlotId)> = row
            .iter()
            .enumerate()
            .filter(|(_, &c)| c != 0)
            .map(|(i, &c)| (c, i))
            .collect();
        self.computes.push(ComputeOp { slot, terms });
        self.seen.insert(row.to_vec(), slot);
        slot
    }
}

/// Compile a collective into a [`Plan`]: `build` receives the `n_inputs`
/// basis payloads and returns the collective to record (its `inputs[i]`
/// must be the `i`-th basis packet). One live run under `Sim::new(ports)`
/// is executed; the resulting plan is validated and its statics
/// cross-checked against that run's report.
pub fn compile<B>(ports: usize, n_inputs: usize, build: B) -> Result<Plan>
where
    B: FnOnce(Vec<Packet>) -> Result<Box<dyn Collective>>,
{
    ensure!(n_inputs >= 1, "plan needs at least one input");
    let inner = build(basis_inputs(n_inputs))?;
    let mut recorder = PlanRecorder::new(inner);
    let mut sim = Sim::new(ports);
    let live = run(&mut sim, &mut recorder)?;

    let mut interner = Interner::new(n_inputs);
    let mut rounds = Vec::with_capacity(recorder.rounds.len());
    let mut messages = 0u64;
    let mut packets = 0u64;
    for emitted in &recorder.rounds {
        let lo = n_inputs + interner.computes.len();
        let mut sends = Vec::with_capacity(emitted.len());
        let mut port_of: HashMap<ProcId, u32> = HashMap::new();
        let mut max_packets = 0u64;
        for msg in emitted {
            ensure!(
                msg.payload.width() == n_inputs,
                "collective is not packet-linear: wire packet width {} != K = {n_inputs} \
                 (width-changing collectives cannot be plan-compiled)",
                msg.payload.width()
            );
            let slots: Vec<SlotId> = msg.payload.iter().map(|row| interner.intern(row)).collect();
            let port = port_of.entry(msg.src).or_insert(0);
            let send = SendOp {
                src: msg.src,
                dst: msg.dst,
                port: *port,
                slots,
            };
            *port += 1;
            max_packets = max_packets.max(send.slots.len() as u64);
            messages += 1;
            packets += send.slots.len() as u64;
            sends.push(send);
        }
        let hi = n_inputs + interner.computes.len();
        rounds.push(RoundPlan {
            new_slots: (lo, hi),
            sends,
            max_packets,
        });
    }

    // Outputs: final local combines may create slots that never hit the
    // wire; they land in a trailing range of the arena.
    let out_lo = n_inputs + interner.computes.len();
    let outputs: BTreeMap<ProcId, SlotId> = recorder
        .outputs()
        .iter()
        .map(|(&pid, row)| {
            ensure!(
                row.len() == n_inputs,
                "collective is not packet-linear: output width {} != K = {n_inputs}",
                row.len()
            );
            Ok((pid, interner.intern(row)))
        })
        .collect::<Result<_>>()?;
    let out_hi = n_inputs + interner.computes.len();

    let plan = Plan {
        n_inputs,
        ports,
        rounds,
        computes: interner.computes,
        outputs,
        output_slots: (out_lo, out_hi),
        messages,
        packets,
    };
    plan.validate()?;
    // Statics cross-check: the plan must predict the recording run
    // exactly (the basis run has payload width W = K).
    let predicted = plan.report(n_inputs);
    ensure!(
        predicted == live,
        "compiled statics diverge from the live recording run:\n \
         plan: {predicted:?}\n live: {live:?}"
    );
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{PrepareShoot, TreeBroadcast, TreeReduce};
    use crate::gf::{GfPrime, Mat};
    use std::sync::Arc;

    #[test]
    fn basis_inputs_are_unit_vectors() {
        let b = basis_inputs(3);
        assert_eq!(b, vec![vec![1, 0, 0], vec![0, 1, 0], vec![0, 0, 1]]);
    }

    #[test]
    fn compiled_prepare_shoot_matches_live_statics() {
        let f = GfPrime::default_field();
        let k = 16usize;
        let c = Arc::new(Mat::random(&f, k, k, 7));
        let plan = compile(1, k, |basis| {
            Ok(Box::new(PrepareShoot::new(
                f,
                (0..k).collect(),
                1,
                c.clone(),
                basis,
            )))
        })
        .unwrap();
        // Theorem 3 at K = 16, p = 1: C1 = 4, C2 = 6 (per unit width).
        assert_eq!(plan.c1(), 4);
        assert_eq!(plan.c2(1), 6);
        assert_eq!(plan.c2(5), 30);
        assert_eq!(plan.output_slots().len(), k);
        plan.validate().unwrap();
    }

    #[test]
    fn broadcast_dedups_to_one_slot() {
        // A tree broadcast forwards one identical packet everywhere: the
        // plan must intern a single slot (the input itself).
        let plan = compile(1, 1, |basis| {
            Ok(Box::new(TreeBroadcast::new(
                (0..8).collect(),
                1,
                basis.into_iter().next().unwrap(),
            )))
        })
        .unwrap();
        assert_eq!(plan.n_slots(), 1, "no compute ops for a pure forward");
        assert_eq!(plan.c1(), 3);
        assert!(plan.output_slots().values().all(|&s| s == 0));
    }

    #[test]
    fn reduce_compiles_to_sum_lincomb() {
        let f = GfPrime::default_field();
        let n = 5usize;
        let plan = compile(1, n, |basis| {
            Ok(Box::new(TreeReduce::new(f, (0..n).collect(), 1, basis)))
        })
        .unwrap();
        // Root output = Σ_i e_i: one slot whose lincomb has n unit terms.
        let &root_slot = plan.output_slots().get(&0).unwrap();
        assert!(root_slot >= plan.n_inputs);
        let mut terms = plan.lincomb(root_slot).to_vec();
        terms.sort_by_key(|&(_, s)| s);
        assert_eq!(terms, (0..n).map(|i| (1u64, i)).collect::<Vec<_>>());
    }

    #[test]
    fn degenerate_single_processor_plan() {
        let f = GfPrime::default_field();
        let c = Arc::new(Mat::from_fn(1, 1, |_, _| 42));
        let plan = compile(1, 1, |basis| {
            Ok(Box::new(PrepareShoot::new(
                f,
                vec![0],
                1,
                c.clone(),
                basis,
            )))
        })
        .unwrap();
        assert_eq!(plan.c1(), 0);
        assert_eq!(plan.c2(9), 0);
        let &slot = plan.output_slots().get(&0).unwrap();
        assert_eq!(plan.lincomb(slot), &[(42, 0)]);
    }
}

//! Peer-to-peer Plan execution: N ranks, each holding only its own
//! [`PlanShard`], exchanging packets over a [`Transport`] with no
//! global state — the paper's decentralized model made literal.
//!
//! Each rank runs the same loop: materialise this round's emissions
//! from its local knowledge arena, ship them, collect the arrivals the
//! schedule promises, cross the round barrier. Nothing outside the
//! shard is consulted — no slot table, no other rank's schedule, no
//! shared memory beyond the transport itself.
//!
//! The loop is **chaos-hardened**. Transient faults — stragglers,
//! duplicated frames, reorder-within-round, whether injected by
//! [`ChaosTransport`] or produced by a real network — are absorbed by
//! bounded retry with exponential backoff ([`RetryPolicy`]): outputs
//! stay bit-identical to a healthy run, with only `retries` /
//! `rounds_delayed` counters as evidence. Permanent faults — crash-stop
//! ranks, partitions — are handled by the degraded executor
//! ([`execute_shard_degraded`]): ranks detect dead peers through typed
//! `PeerClosed`/`Timeout` errors, zero-substitute the missing inputs
//! exactly like the round simulator, gossip the crash set after the
//! last scheduled round, and the harness folds every rank's
//! receive-side observations through the same taint closure as
//! [`fault::analyze_plan`](crate::net::fault::analyze_plan) — which is
//! why `tests/chaos.rs` can assert the two reports equal.
//!
//! Conformance contract (enforced by `tests/peer.rs`): outputs are
//! bit-identical to [`exec::replay`](crate::net::exec::replay), and the
//! **measured** traffic — rounds crossed, messages shipped, per-round
//! maxima — reproduces [`Plan::report`] exactly, which is what makes
//! the simulator an honest oracle for the real thing.

use crate::gf::Field;
use crate::net::fault::DegradedReport;
use crate::net::payload::Packet;
use crate::net::plan::Plan;
use crate::net::shard::PlanShard;
use crate::net::sim::{Outputs, ProcId, SimReport};
use crate::net::transport::{
    self, ChaosSpec, ChaosTransport, Transport, TransportError, TransportKind,
};
use anyhow::{ensure, Context, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::time::Duration;

/// A Plan cut into per-processor shards, ready for peer execution.
#[derive(Clone, Debug)]
pub struct ShardedPlan {
    /// Participants, ascending; `shards[i]` belongs to `procs[i]`.
    pub procs: Vec<ProcId>,
    pub shards: Vec<PlanShard>,
    /// `K` — inputs the collective encodes.
    pub n_inputs: usize,
    /// Plan rounds (= every rank's barrier count = `C1`).
    pub n_rounds: usize,
    /// The schedule's port budget `p` (transport sizing).
    pub ports: usize,
    /// Largest packet count of any single message (ring sizing).
    pub max_msg_packets: usize,
}

impl ShardedPlan {
    /// Shard `plan` for every participant. `owners[k]` is the rank
    /// holding input `k` at start — the systematic layout's
    /// `source(k) = k` in every collective this repo compiles.
    pub fn new<F: Field>(plan: &Plan, f: &F, owners: &[ProcId]) -> Result<ShardedPlan> {
        let procs = plan.participants(owners);
        let shards = plan.shard_all(f, owners)?;
        let max_msg_packets = shards.iter().map(|s| s.max_msg_packets()).max().unwrap_or(0);
        Ok(ShardedPlan {
            procs,
            shards,
            n_inputs: plan.n_inputs,
            n_rounds: plan.rounds().len(),
            ports: plan.ports,
            max_msg_packets,
        })
    }
}

/// Bounded retry with exponential backoff for *transient* transport
/// faults. The budget covers the worst honest stacking a single
/// receive can suffer under injected chaos (a straggler's charged
/// timeouts, plus one stale duplicate, plus one reorder) with slack;
/// anything that outlives it is treated as permanent.
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    /// Total attempts per operation (first try included).
    pub max_attempts: u32,
    /// Backoff before retry `i` is `base_backoff * 2^i`, capped.
    pub base_backoff: Duration,
    pub max_backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 6,
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(20),
        }
    }
}

impl RetryPolicy {
    fn backoff(&self, attempt: u32) -> Duration {
        let factor = 2u32.saturating_pow(attempt.min(16));
        (self.base_backoff * factor).min(self.max_backoff)
    }
}

/// Can a retry of the same operation heal this error?
///
/// * `Timeout` — a straggler (or an injected delay): the frame may
///   still arrive.
/// * `OutOfOrder` carrying an *older* round — a stale duplicate that
///   the substrate consumed (or the chaos layer synthesized); the
///   genuine frame is still next in FIFO order. A *newer* round means
///   this rank fell behind the mesh — not healable by retrying.
/// * `PortMismatch` — within-round reordering; same reasoning.
fn is_transient(e: &TransportError) -> bool {
    match e {
        TransportError::Timeout { .. } => true,
        TransportError::OutOfOrder {
            expected_round,
            got_round,
            ..
        } => got_round < expected_round,
        TransportError::PortMismatch { .. } => true,
        _ => false,
    }
}

/// Receive with bounded retry: transient faults are retried (counted
/// into `retries`), everything else — and a transient fault that
/// outlives the budget — surfaces as the final error.
fn recv_hardened(
    transport: &mut dyn Transport,
    round: u32,
    port: u32,
    src: ProcId,
    policy: &RetryPolicy,
    retries: &mut u64,
) -> Result<Vec<Packet>, TransportError> {
    let mut attempt = 0u32;
    loop {
        match transport.recv(round, port, src) {
            Ok(rows) => return Ok(rows),
            Err(e) if is_transient(&e) && attempt + 1 < policy.max_attempts.max(1) => {
                *retries += 1;
                std::thread::sleep(policy.backoff(attempt));
                attempt += 1;
            }
            Err(e) => return Err(e),
        }
    }
}

/// Barrier with bounded retry — safe because every substrate's barrier
/// is retry-idempotent (identified arrivals on `LocalBarrier`, resumed
/// send/collect state on TCP).
fn barrier_hardened(
    transport: &mut dyn Transport,
    round: u32,
    policy: &RetryPolicy,
    retries: &mut u64,
) -> Result<(), TransportError> {
    let mut attempt = 0u32;
    loop {
        match transport.barrier(round) {
            Ok(()) => return Ok(()),
            Err(e) if is_transient(&e) && attempt + 1 < policy.max_attempts.max(1) => {
                *retries += 1;
                std::thread::sleep(policy.backoff(attempt));
                attempt += 1;
            }
            Err(e) => return Err(e),
        }
    }
}

/// What one rank measured while executing its shard — honest counts
/// from the execution loop itself, not from plan statics.
#[derive(Clone, Debug, Default)]
pub struct PeerStats {
    /// Barriers crossed (= rounds executed).
    pub rounds: u64,
    /// Per round, the largest message (in field elements) **this rank
    /// sent** — zero for rounds it sent nothing.
    pub per_round_sent_max: Vec<u64>,
    /// Messages this rank sent.
    pub messages: u64,
    /// Field elements this rank sent (its bandwidth share).
    pub elems: u64,
    /// Transient transport faults absorbed by retry.
    pub retries: u64,
    /// Rounds in which at least one retry happened (straggler rounds).
    pub rounds_delayed: u64,
}

/// The merged result of a peer run.
#[derive(Clone, Debug)]
pub struct PeerRun {
    /// Final packet per processor — bit-identical to
    /// [`exec::replay`](crate::net::exec::replay).
    pub outputs: Outputs,
    /// The merged measured traffic: `c1` = rounds every rank crossed,
    /// `per_round_max[t]` = largest message any rank sent in round `t`,
    /// `c2` = their sum, plus total messages and bandwidth.
    pub measured: SimReport,
    /// Transient faults absorbed across all ranks (zero on a healthy
    /// mesh; the *only* trace a transient chaos scenario leaves).
    pub retries: u64,
    /// Rank-rounds that needed at least one retry.
    pub rounds_delayed: u64,
}

fn eval_comb<F: Field>(
    f: &F,
    w: usize,
    arena: &[Option<Packet>],
    comb: &[(u64, usize)],
) -> Result<Packet> {
    let terms: Vec<(u64, &[u64])> = comb
        .iter()
        .map(|&(c, j)| {
            arena[j]
                .as_deref()
                .map(|p| (c, p))
                .with_context(|| format!("arena slot {j} not materialised"))
        })
        .collect::<Result<_>>()?;
    let mut out = vec![0u64; w];
    f.lincomb_into(&mut out, &terms);
    Ok(out)
}

/// Execute one shard against a live transport. `my_inputs` are the
/// values of `shard.owned`, in order. Returns this rank's final packet
/// (if the Plan assigns one) and its measured traffic.
///
/// Transient faults are absorbed through the default [`RetryPolicy`];
/// permanent faults surface as errors (use [`execute_shard_degraded`]
/// to survive those).
pub fn execute_shard<F: Field>(
    shard: &PlanShard,
    f: &F,
    w: usize,
    my_inputs: &[Packet],
    transport: &mut dyn Transport,
) -> Result<(Option<Packet>, PeerStats)> {
    let policy = RetryPolicy::default();
    ensure!(
        my_inputs.len() == shard.owned.len(),
        "rank {} holds {} inputs, shard expects {}",
        shard.proc,
        my_inputs.len(),
        shard.owned.len()
    );
    for pkt in my_inputs {
        ensure!(
            pkt.len() == w,
            "rank {}: input packet width {} != {w}",
            shard.proc,
            pkt.len()
        );
    }
    // The local knowledge arena: owned inputs, then (per round) each
    // emission materialised and each arrival, in shard index order.
    let mut arena: Vec<Option<Packet>> = vec![None; shard.n_local];
    for (i, pkt) in my_inputs.iter().enumerate() {
        arena[i] = Some(pkt.clone());
    }
    let mut next = my_inputs.len();
    let mut stats = PeerStats::default();
    for (t, round) in shard.rounds.iter().enumerate() {
        let t32 = t as u32;
        let retries_before = stats.retries;
        for comp in &round.computes {
            let pkt = eval_comb(f, w, &arena, &comp.comb)
                .with_context(|| format!("rank {}: compute for slot {}", shard.proc, comp.slot))?;
            arena[next] = Some(pkt);
            next += 1;
        }
        let mut sent_max = 0u64;
        for send in &round.sends {
            let rows: Vec<Packet> = send
                .locals
                .iter()
                .map(|&j| {
                    arena[j]
                        .clone()
                        .with_context(|| format!("arena slot {j} not materialised"))
                })
                .collect::<Result<_>>()?;
            transport
                .send(t32, send.port, send.dst, &rows)
                .with_context(|| {
                    format!(
                        "rank {}: send to {} port {} in round {t}",
                        shard.proc, send.dst, send.port
                    )
                })?;
            let elems = (rows.len() * w) as u64;
            sent_max = sent_max.max(elems);
            stats.messages += 1;
            stats.elems += elems;
        }
        stats.per_round_sent_max.push(sent_max);
        for recv in &round.recvs {
            let rows = recv_hardened(
                transport,
                t32,
                recv.port,
                recv.src,
                &policy,
                &mut stats.retries,
            )
            .with_context(|| {
                format!(
                    "rank {}: recv from {} port {} in round {t}",
                    shard.proc, recv.src, recv.port
                )
            })?;
            ensure!(
                rows.len() == recv.n_slots,
                "rank {}: round {t} message from {} carries {} packets, schedule says {}",
                shard.proc,
                recv.src,
                rows.len(),
                recv.n_slots
            );
            ensure!(
                recv.first_local == next,
                "shard arena misalignment at rank {} round {t}",
                shard.proc
            );
            for row in rows {
                ensure!(
                    row.len() == w,
                    "rank {}: packet width {} != {w} from {}",
                    shard.proc,
                    row.len(),
                    recv.src
                );
                arena[next] = Some(row);
                next += 1;
            }
        }
        barrier_hardened(transport, t32, &policy, &mut stats.retries)
            .with_context(|| format!("rank {}: barrier for round {t}", shard.proc))?;
        stats.rounds += 1;
        if stats.retries > retries_before {
            stats.rounds_delayed += 1;
        }
    }
    let output = match &shard.output {
        None => None,
        Some(comb) => Some(
            eval_comb(f, w, &arena, comb)
                .with_context(|| format!("rank {}: final output", shard.proc))?,
        ),
    };
    Ok((output, stats))
}

/// Merge per-rank measurements into the global [`SimReport`] the
/// simulator would produce: `C1` from barriers, `m_t` as the max over
/// ranks, `C2` as their sum.
pub fn merge_stats(n_rounds: usize, stats: &[PeerStats]) -> SimReport {
    let mut per_round_max = vec![0u64; n_rounds];
    for s in stats {
        for (t, &m) in s.per_round_sent_max.iter().enumerate() {
            per_round_max[t] = per_round_max[t].max(m);
        }
    }
    SimReport {
        c1: n_rounds as u64,
        c2: per_round_max.iter().sum(),
        per_round_max,
        messages: stats.iter().map(|s| s.messages).sum(),
        bandwidth: stats.iter().map(|s| s.elems).sum(),
    }
}

/// One rank's receive-side trace of a degraded run — everything the
/// harness needs to reconstruct the global taint closure without any
/// rank ever holding global state.
#[derive(Clone, Debug, Default)]
struct RankObservation {
    /// 1-based round at which this rank found *itself* dead (its first
    /// wire operation of that round failed self-addressed).
    self_crashed_from: Option<u64>,
    /// Peer → earliest 1-based round this rank saw it dead.
    crash_seen: BTreeMap<ProcId, u64>,
    /// Every receive the schedule promised this rank, in round order:
    /// `(round, src, elems, delivered)`. Ghost rounds log their
    /// scheduled arrivals as undelivered — that is what makes the union
    /// over ranks exactly the schedule's message multiset.
    in_edges: Vec<(u64, ProcId, u64, bool)>,
}

/// What one rank's degraded execution produced.
struct ShardOutcome {
    proc: ProcId,
    output: Option<Packet>,
    stats: PeerStats,
    obs: RankObservation,
}

/// The merged result of a chaos run with permanent faults: surviving
/// outputs, the wire-observed [`DegradedReport`], and the healing
/// telemetry the coordinator exports as metrics.
#[derive(Clone, Debug)]
pub struct DegradedPeerRun {
    /// Outputs of every rank that finished — crashed ranks' outputs are
    /// dropped (a dead node holds nothing), tainted ranks' garbage is
    /// kept, mirroring the live engine's degraded semantics.
    pub outputs: Outputs,
    /// Built from receive-side observations only; `tests/chaos.rs`
    /// asserts it equals [`analyze_plan`](crate::net::fault::analyze_plan)
    /// on the same spec.
    pub report: DegradedReport,
    /// Transient faults absorbed across ranks.
    pub retries: u64,
    /// Rank-rounds that needed at least one retry.
    pub rounds_delayed: u64,
    /// Dead peers detected on the wire (union over ranks, incl. the
    /// self-detections gossiped after the last round).
    pub crashes_detected: BTreeSet<ProcId>,
}

/// Execute one shard expecting *permanent* faults: a dead peer's
/// missing inputs are zero-substituted (exactly like the round
/// simulator's degraded walk), this rank's own crash turns it into a
/// **ghost** that keeps crossing barriers so the mesh stays
/// round-synchronous, and after the last scheduled round the alive
/// ranks gossip their crash observations so every survivor knows the
/// full crash set.
fn execute_shard_degraded<F: Field>(
    shard: &PlanShard,
    f: &F,
    w: usize,
    my_inputs: &[Packet],
    transport: &mut dyn Transport,
    policy: &RetryPolicy,
) -> Result<ShardOutcome> {
    ensure!(
        my_inputs.len() == shard.owned.len(),
        "rank {} holds {} inputs, shard expects {}",
        shard.proc,
        my_inputs.len(),
        shard.owned.len()
    );
    let me = shard.proc;
    let procs: Vec<ProcId> = transport.peers().to_vec();
    let mut arena: Vec<Option<Packet>> = vec![None; shard.n_local];
    for (i, pkt) in my_inputs.iter().enumerate() {
        arena[i] = Some(pkt.clone());
    }
    let mut next = my_inputs.len();
    let mut stats = PeerStats::default();
    let mut obs = RankObservation::default();
    for (t, round) in shard.rounds.iter().enumerate() {
        let t32 = t as u32;
        let t1 = t as u64 + 1;
        let retries_before = stats.retries;
        let mut ghost = obs.self_crashed_from.is_some();
        if !ghost {
            for comp in &round.computes {
                let pkt = eval_comb(f, w, &arena, &comp.comb).with_context(|| {
                    format!("rank {me}: compute for slot {}", comp.slot)
                })?;
                arena[next] = Some(pkt);
                next += 1;
            }
            let mut sent_max = 0u64;
            for send in &round.sends {
                let rows: Vec<Packet> = send
                    .locals
                    .iter()
                    .map(|&j| {
                        arena[j]
                            .clone()
                            .with_context(|| format!("arena slot {j} not materialised"))
                    })
                    .collect::<Result<_>>()?;
                match transport.send(t32, send.port, send.dst, &rows) {
                    Ok(()) => {
                        let elems = (rows.len() * w) as u64;
                        sent_max = sent_max.max(elems);
                        stats.messages += 1;
                        stats.elems += elems;
                    }
                    Err(TransportError::PeerClosed { peer, .. }) if peer == me => {
                        // Our own crash round: every wire op from here
                        // on is dead — become a ghost.
                        obs.self_crashed_from = Some(t1);
                        ghost = true;
                        break;
                    }
                    Err(TransportError::PeerClosed { peer, .. }) => {
                        let e = obs.crash_seen.entry(peer).or_insert(t1);
                        *e = (*e).min(t1);
                    }
                    Err(TransportError::Timeout { .. }) => {
                        // The frame may be lost; the receiver's side of
                        // the trace decides what that means.
                    }
                    Err(e) => {
                        return Err(e).with_context(|| {
                            format!(
                                "rank {me}: send to {} port {} in round {t}",
                                send.dst, send.port
                            )
                        })
                    }
                }
            }
            stats.per_round_sent_max.push(sent_max);
        }
        for recv in &round.recvs {
            let elems = (recv.n_slots * w) as u64;
            if ghost {
                obs.in_edges.push((t1, recv.src, elems, false));
                continue;
            }
            ensure!(
                recv.first_local == next,
                "shard arena misalignment at rank {me} round {t}"
            );
            // Known-dead source: don't burn a timeout on silence we can
            // predict — synthesize the drop directly.
            let known_dead = obs.crash_seen.get(&recv.src).is_some_and(|&r| r <= t1);
            let got = if known_dead {
                Err(TransportError::PeerClosed {
                    round: t32,
                    peer: recv.src,
                })
            } else {
                recv_hardened(transport, t32, recv.port, recv.src, policy, &mut stats.retries)
            };
            match got {
                Ok(rows) => {
                    ensure!(
                        rows.len() == recv.n_slots,
                        "rank {me}: round {t} message from {} carries {} packets, schedule says {}",
                        recv.src,
                        rows.len(),
                        recv.n_slots
                    );
                    for row in rows {
                        ensure!(
                            row.len() == w,
                            "rank {me}: packet width {} != {w} from {}",
                            row.len(),
                            recv.src
                        );
                        arena[next] = Some(row);
                        next += 1;
                    }
                    obs.in_edges.push((t1, recv.src, elems, true));
                }
                Err(TransportError::PeerClosed { peer, .. }) if peer == me => {
                    obs.self_crashed_from = Some(t1);
                    ghost = true;
                    obs.in_edges.push((t1, recv.src, elems, false));
                }
                Err(TransportError::PeerClosed { .. }) => {
                    // The source is *crashed* (closed its side): it
                    // stays dead — remember the round so later rounds
                    // take the fast path instead of burning timeouts.
                    let e = obs.crash_seen.entry(recv.src).or_insert(t1);
                    *e = (*e).min(t1);
                    // Zero-substitute the missing packets — the exact
                    // degraded semantics of `sim::run_degraded`: the
                    // schedule marches on, the values are zeros.
                    for _ in 0..recv.n_slots {
                        arena[next] = Some(vec![0u64; w]);
                        next += 1;
                    }
                    obs.in_edges.push((t1, recv.src, elems, false));
                }
                Err(TransportError::Timeout { .. }) => {
                    // Silence (partition or single-round erasure): the
                    // message is lost but the source may be alive —
                    // and an erased link heals next round, so this
                    // must NOT mark the source dead.
                    for _ in 0..recv.n_slots {
                        arena[next] = Some(vec![0u64; w]);
                        next += 1;
                    }
                    obs.in_edges.push((t1, recv.src, elems, false));
                }
                Err(e) => {
                    return Err(e).with_context(|| {
                        format!(
                            "rank {me}: recv from {} port {} in round {t}",
                            recv.src, recv.port
                        )
                    })
                }
            }
        }
        cross_degraded_barrier(transport, t32, t1, &obs, policy, &mut stats.retries)
            .with_context(|| format!("rank {me}: barrier for round {t}"))?;
        stats.rounds += 1;
        if stats.retries > retries_before {
            stats.rounds_delayed += 1;
        }
    }
    gossip_crash_set(
        transport,
        shard.rounds.len() as u32,
        &procs,
        &mut obs,
        policy,
        &mut stats.retries,
    )?;
    let output = match (&shard.output, obs.self_crashed_from) {
        (_, Some(_)) | (None, _) => None,
        (Some(comb), None) => Some(
            eval_comb(f, w, &arena, comb).with_context(|| format!("rank {me}: final output"))?,
        ),
    };
    Ok(ShardOutcome {
        proc: me,
        output,
        stats,
        obs,
    })
}

/// Cross a round barrier in a degraded run: retry transients, and
/// treat an error blamed on a peer we already know is dead as crossed
/// (on a real mesh the dead process cannot arrive; every survivor
/// makes the same call, so the mesh stays synchronized).
fn cross_degraded_barrier(
    transport: &mut dyn Transport,
    round: u32,
    t1: u64,
    obs: &RankObservation,
    policy: &RetryPolicy,
    retries: &mut u64,
) -> Result<(), TransportError> {
    let mut attempt = 0u32;
    loop {
        match transport.barrier(round) {
            Ok(()) => return Ok(()),
            Err(
                TransportError::Timeout { peer, .. } | TransportError::PeerClosed { peer, .. },
            ) if obs.crash_seen.get(&peer).is_some_and(|&r| r <= t1) => {
                return Ok(());
            }
            Err(e) if is_transient(&e) && attempt + 1 < policy.max_attempts.max(1) => {
                *retries += 1;
                std::thread::sleep(policy.backoff(attempt));
                attempt += 1;
            }
            Err(e) => return Err(e),
        }
    }
}

/// One extra all-to-all after the last scheduled round: each alive
/// rank ships its `crash_seen` map (packed as one `u64` per
/// participant — 0 for "alive as far as I know") and min-merges what
/// it hears back. Ghosts skip it (their sends are dead); partitioned
/// links lose it (crash knowledge travels only where messages can).
fn gossip_crash_set(
    transport: &mut dyn Transport,
    round: u32,
    procs: &[ProcId],
    obs: &mut RankObservation,
    policy: &RetryPolicy,
    retries: &mut u64,
) -> Result<()> {
    let me = transport.rank();
    let t1 = round as u64 + 1;
    let ghost = obs.self_crashed_from.is_some();
    if !ghost {
        let mut packet = vec![0u64; procs.len()];
        for (i, &p) in procs.iter().enumerate() {
            if let Some(&r) = obs.crash_seen.get(&p) {
                packet[i] = r;
            }
        }
        let rows = [packet];
        for &dst in procs {
            if dst == me {
                continue;
            }
            match transport.send(round, 0, dst, &rows) {
                Ok(()) | Err(TransportError::Timeout { .. }) => {}
                Err(TransportError::PeerClosed { peer, .. }) if peer == me => {
                    obs.self_crashed_from = Some(t1);
                    break;
                }
                Err(TransportError::PeerClosed { peer, .. }) => {
                    let e = obs.crash_seen.entry(peer).or_insert(t1);
                    *e = (*e).min(t1);
                }
                Err(e) => {
                    return Err(e).with_context(|| format!("rank {me}: crash gossip to {dst}"))
                }
            }
        }
    }
    if obs.self_crashed_from.is_none() {
        for &src in procs {
            if src == me {
                continue;
            }
            if obs.crash_seen.get(&src).is_some_and(|&r| r <= t1) {
                continue; // the dead don't gossip
            }
            match recv_hardened(transport, round, 0, src, policy, retries) {
                Ok(rows) => {
                    if let Some(row) = rows.first() {
                        for (i, &p) in procs.iter().enumerate() {
                            match row.get(i) {
                                Some(&r) if r > 0 && p != me => {
                                    let e = obs.crash_seen.entry(p).or_insert(r);
                                    *e = (*e).min(r);
                                }
                                _ => {}
                            }
                        }
                    }
                }
                Err(TransportError::PeerClosed { peer, .. }) if peer == me => {
                    obs.self_crashed_from = Some(t1);
                    break;
                }
                Err(TransportError::PeerClosed { peer, .. }) if peer == src => {
                    let e = obs.crash_seen.entry(src).or_insert(t1);
                    *e = (*e).min(t1);
                }
                Err(TransportError::Timeout { .. }) => {
                    // Partitioned away: no gossip across a cut link.
                }
                Err(e) => {
                    return Err(e).with_context(|| format!("rank {me}: crash gossip from {src}"))
                }
            }
        }
    }
    cross_degraded_barrier(transport, round, t1, obs, policy, retries)
        .with_context(|| format!("rank {me}: gossip barrier"))?;
    Ok(())
}

/// Run all ranks of a sharded plan as threads over a fresh in-process
/// mesh of the given kind — the test/bench harness for peer execution
/// (`examples/peer_encode.rs` does the same dance with real processes
/// over TCP).
///
/// When `DCE_CHAOS` names a *transient-only* scenario, every endpoint
/// is wrapped in a [`ChaosTransport`] — the run must still produce
/// bit-identical outputs, just with nonzero `retries`.
pub fn spawn_local<F: Field + Sync>(
    sharded: &ShardedPlan,
    f: &F,
    inputs: &[Packet],
    kind: TransportKind,
    timeout: Duration,
) -> Result<PeerRun> {
    ensure!(
        inputs.len() == sharded.n_inputs,
        "{} inputs for a {}-input plan",
        inputs.len(),
        sharded.n_inputs
    );
    let w = inputs.first().map_or(0, |p| p.len());
    for pkt in inputs {
        ensure!(pkt.len() == w, "ragged input widths");
    }
    let max_msg_bytes = sharded.max_msg_packets * w * 8;
    let mut mesh = transport::mesh(kind, &sharded.procs, sharded.ports, max_msg_bytes, timeout)?;
    if let Some(spec) = ChaosSpec::from_env() {
        if spec.is_transient_only() {
            mesh = mesh
                .into_iter()
                .map(|t| Box::new(ChaosTransport::wrap(t, spec.clone())) as Box<dyn Transport>)
                .collect();
        } else {
            eprintln!(
                "dce: DCE_CHAOS carries permanent faults; those need the chaos harness \
                 (spawn_local_chaos), ignoring for this healthy run"
            );
        }
    }
    let ran: Vec<Result<(ProcId, Option<Packet>, PeerStats)>> = std::thread::scope(|s| {
        let handles: Vec<_> = sharded
            .shards
            .iter()
            .zip(mesh)
            .map(|(shard, mut transport)| {
                let my_inputs: Vec<Packet> =
                    shard.owned.iter().map(|&k| inputs[k].clone()).collect();
                s.spawn(move || {
                    let (out, stats) =
                        execute_shard(shard, f, w, &my_inputs, transport.as_mut())?;
                    Ok((shard.proc, out, stats))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("peer rank panicked"))
            .collect()
    });
    let mut outputs = Outputs::new();
    let mut stats = Vec::with_capacity(ran.len());
    for r in ran {
        let (proc, out, st) = r?;
        if let Some(pkt) = out {
            outputs.insert(proc, pkt);
        }
        stats.push(st);
    }
    Ok(PeerRun {
        measured: merge_stats(sharded.n_rounds, &stats),
        retries: stats.iter().map(|s| s.retries).sum(),
        rounds_delayed: stats.iter().map(|s| s.rounds_delayed).sum(),
        outputs,
    })
}

/// Run a sharded plan under a [`ChaosSpec`] that may include permanent
/// faults: every endpoint is chaos-wrapped, every rank runs the
/// degraded executor, and the harness folds the receive-side traces
/// through the taint closure — producing a [`DegradedReport`] that
/// must equal [`analyze_plan`](crate::net::fault::analyze_plan) on
/// `chaos.to_fault_spec()`.
pub fn spawn_local_chaos<F: Field + Sync>(
    sharded: &ShardedPlan,
    f: &F,
    inputs: &[Packet],
    kind: TransportKind,
    timeout: Duration,
    chaos: &ChaosSpec,
    policy: &RetryPolicy,
) -> Result<DegradedPeerRun> {
    ensure!(
        inputs.len() == sharded.n_inputs,
        "{} inputs for a {}-input plan",
        inputs.len(),
        sharded.n_inputs
    );
    let w = inputs.first().map_or(0, |p| p.len());
    for pkt in inputs {
        ensure!(pkt.len() == w, "ragged input widths");
    }
    let n_procs = sharded.procs.len();
    // The gossip packet (one u64 per participant) must also fit.
    let max_msg_bytes = (sharded.max_msg_packets * w * 8).max((n_procs + 1) * 8);
    let mesh = transport::mesh(
        kind,
        &sharded.procs,
        sharded.ports.max(1),
        max_msg_bytes,
        timeout,
    )?;
    let ran: Vec<Result<ShardOutcome>> = std::thread::scope(|s| {
        let handles: Vec<_> = sharded
            .shards
            .iter()
            .zip(mesh)
            .map(|(shard, transport)| {
                let my_inputs: Vec<Packet> =
                    shard.owned.iter().map(|&k| inputs[k].clone()).collect();
                let mut chaotic = ChaosTransport::wrap(transport, chaos.clone());
                s.spawn(move || {
                    execute_shard_degraded(shard, f, w, &my_inputs, &mut chaotic, policy)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("peer rank panicked"))
            .collect()
    });
    let outcomes: Vec<ShardOutcome> = ran.into_iter().collect::<Result<_>>()?;
    // The authoritative crash set is the spec's directives (a rank
    // whose crash round lies beyond its schedule — POST_RUN, or a
    // degenerate shard with no wire traffic — has no wire op to fail,
    // so no self-report; the directive still loses its output).
    let crash_round: BTreeMap<ProcId, u64> = chaos.crash_entries().collect();
    let mut crashes_detected: BTreeSet<ProcId> = BTreeSet::new();
    for o in &outcomes {
        crashes_detected.extend(o.obs.crash_seen.keys().copied());
        if o.obs.self_crashed_from.is_some() {
            crashes_detected.insert(o.proc);
        }
    }
    // Fold every rank's receive-side trace through the same taint
    // closure as `fault::analyze_plan`: each scheduled message appears
    // exactly once (its receiver logged it — ghosts included), rounds
    // ascend, and taint propagates only across strictly later rounds,
    // so within-round order is immaterial.
    let mut edges: Vec<(u64, ProcId, ProcId, u64, bool)> = Vec::new();
    for o in &outcomes {
        for &(t, src, elems, delivered) in &o.obs.in_edges {
            edges.push((t, src, o.proc, elems, delivered));
        }
    }
    edges.sort_unstable_by_key(|&(t, src, dst, ..)| (t, src, dst));
    let alive_at = |pid: ProcId, t: u64| !crash_round.get(&pid).is_some_and(|&r| t >= r);
    let mut taint: BTreeMap<ProcId, u64> = BTreeMap::new();
    let mut delivered_report = SimReport {
        c1: sharded.n_rounds as u64,
        per_round_max: vec![0u64; sharded.n_rounds],
        ..SimReport::default()
    };
    let mut dropped_messages = 0u64;
    let mut dropped_elems = 0u64;
    for &(t, src, dst, elems, delivered) in &edges {
        if !delivered {
            dropped_messages += 1;
            dropped_elems += elems;
            if alive_at(dst, t) {
                taint.entry(dst).or_insert(t);
            }
        } else {
            if taint.get(&src).is_some_and(|&t0| t0 < t) {
                taint.entry(dst).or_insert(t);
            }
            let slot = &mut delivered_report.per_round_max[(t - 1) as usize];
            *slot = (*slot).max(elems);
            delivered_report.messages += 1;
            delivered_report.bandwidth += elems;
        }
    }
    delivered_report.c2 = delivered_report.per_round_max.iter().sum();
    let report = DegradedReport {
        delivered: delivered_report,
        dropped_messages,
        dropped_elems,
        crashed: crash_round.keys().copied().collect(),
        tainted: taint.keys().copied().collect(),
    };
    let mut outputs = Outputs::new();
    for o in &outcomes {
        if let Some(pkt) = &o.output {
            if !crash_round.contains_key(&o.proc) {
                outputs.insert(o.proc, pkt.clone());
            }
        }
    }
    Ok(DegradedPeerRun {
        outputs,
        report,
        retries: outcomes.iter().map(|o| o.stats.retries).sum(),
        rounds_delayed: outcomes.iter().map(|o| o.stats.rounds_delayed).sum(),
        crashes_detected,
    })
}

/// Convenience: shard + run in one call (plan-cache paths hold a
/// [`ShardedPlan`] and call [`spawn_local`] directly).
pub fn run_peer<F: Field + Sync>(
    plan: &Plan,
    f: &F,
    inputs: &[Packet],
    kind: TransportKind,
    timeout: Duration,
) -> Result<PeerRun> {
    let owners: Vec<ProcId> = (0..plan.n_inputs).collect();
    let sharded = ShardedPlan::new(plan, f, &owners)?;
    spawn_local(&sharded, f, inputs, kind, timeout)
}

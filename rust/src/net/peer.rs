//! Peer-to-peer Plan execution: N ranks, each holding only its own
//! [`PlanShard`], exchanging packets over a [`Transport`] with no
//! global state — the paper's decentralized model made literal.
//!
//! Each rank runs the same loop: materialise this round's emissions
//! from its local knowledge arena, ship them, collect the arrivals the
//! schedule promises, cross the round barrier. Nothing outside the
//! shard is consulted — no slot table, no other rank's schedule, no
//! shared memory beyond the transport itself.
//!
//! Conformance contract (enforced by `tests/peer.rs`): outputs are
//! bit-identical to [`exec::replay`](crate::net::exec::replay), and the
//! **measured** traffic — rounds crossed, messages shipped, per-round
//! maxima — reproduces [`Plan::report`] exactly, which is what makes
//! the simulator an honest oracle for the real thing.

use crate::gf::Field;
use crate::net::payload::Packet;
use crate::net::plan::Plan;
use crate::net::shard::PlanShard;
use crate::net::sim::{Outputs, ProcId, SimReport};
use crate::net::transport::{self, Transport, TransportKind};
use anyhow::{ensure, Context, Result};
use std::time::Duration;

/// A Plan cut into per-processor shards, ready for peer execution.
#[derive(Clone, Debug)]
pub struct ShardedPlan {
    /// Participants, ascending; `shards[i]` belongs to `procs[i]`.
    pub procs: Vec<ProcId>,
    pub shards: Vec<PlanShard>,
    /// `K` — inputs the collective encodes.
    pub n_inputs: usize,
    /// Plan rounds (= every rank's barrier count = `C1`).
    pub n_rounds: usize,
    /// The schedule's port budget `p` (transport sizing).
    pub ports: usize,
    /// Largest packet count of any single message (ring sizing).
    pub max_msg_packets: usize,
}

impl ShardedPlan {
    /// Shard `plan` for every participant. `owners[k]` is the rank
    /// holding input `k` at start — the systematic layout's
    /// `source(k) = k` in every collective this repo compiles.
    pub fn new<F: Field>(plan: &Plan, f: &F, owners: &[ProcId]) -> Result<ShardedPlan> {
        let procs = plan.participants(owners);
        let shards = plan.shard_all(f, owners)?;
        let max_msg_packets = shards.iter().map(|s| s.max_msg_packets()).max().unwrap_or(0);
        Ok(ShardedPlan {
            procs,
            shards,
            n_inputs: plan.n_inputs,
            n_rounds: plan.rounds().len(),
            ports: plan.ports,
            max_msg_packets,
        })
    }
}

/// What one rank measured while executing its shard — honest counts
/// from the execution loop itself, not from plan statics.
#[derive(Clone, Debug, Default)]
pub struct PeerStats {
    /// Barriers crossed (= rounds executed).
    pub rounds: u64,
    /// Per round, the largest message (in field elements) **this rank
    /// sent** — zero for rounds it sent nothing.
    pub per_round_sent_max: Vec<u64>,
    /// Messages this rank sent.
    pub messages: u64,
    /// Field elements this rank sent (its bandwidth share).
    pub elems: u64,
}

/// The merged result of a peer run.
#[derive(Clone, Debug)]
pub struct PeerRun {
    /// Final packet per processor — bit-identical to
    /// [`exec::replay`](crate::net::exec::replay).
    pub outputs: Outputs,
    /// The merged measured traffic: `c1` = rounds every rank crossed,
    /// `per_round_max[t]` = largest message any rank sent in round `t`,
    /// `c2` = their sum, plus total messages and bandwidth.
    pub measured: SimReport,
}

/// Execute one shard against a live transport. `my_inputs` are the
/// values of `shard.owned`, in order. Returns this rank's final packet
/// (if the Plan assigns one) and its measured traffic.
pub fn execute_shard<F: Field>(
    shard: &PlanShard,
    f: &F,
    w: usize,
    my_inputs: &[Packet],
    transport: &mut dyn Transport,
) -> Result<(Option<Packet>, PeerStats)> {
    ensure!(
        my_inputs.len() == shard.owned.len(),
        "rank {} holds {} inputs, shard expects {}",
        shard.proc,
        my_inputs.len(),
        shard.owned.len()
    );
    for pkt in my_inputs {
        ensure!(
            pkt.len() == w,
            "rank {}: input packet width {} != {w}",
            shard.proc,
            pkt.len()
        );
    }
    // The local knowledge arena: owned inputs, then (per round) each
    // emission materialised and each arrival, in shard index order.
    let mut arena: Vec<Option<Packet>> = vec![None; shard.n_local];
    for (i, pkt) in my_inputs.iter().enumerate() {
        arena[i] = Some(pkt.clone());
    }
    let mut next = my_inputs.len();
    let eval = |arena: &[Option<Packet>], comb: &[(u64, usize)]| -> Result<Packet> {
        let terms: Vec<(u64, &[u64])> = comb
            .iter()
            .map(|&(c, j)| {
                arena[j]
                    .as_deref()
                    .map(|p| (c, p))
                    .with_context(|| format!("arena slot {j} not materialised"))
            })
            .collect::<Result<_>>()?;
        let mut out = vec![0u64; w];
        f.lincomb_into(&mut out, &terms);
        Ok(out)
    };
    let mut stats = PeerStats::default();
    for (t, round) in shard.rounds.iter().enumerate() {
        let t32 = t as u32;
        for comp in &round.computes {
            let pkt = eval(&arena, &comp.comb)
                .with_context(|| format!("rank {}: compute for slot {}", shard.proc, comp.slot))?;
            arena[next] = Some(pkt);
            next += 1;
        }
        let mut sent_max = 0u64;
        for send in &round.sends {
            let rows: Vec<Packet> = send
                .locals
                .iter()
                .map(|&j| {
                    arena[j]
                        .clone()
                        .with_context(|| format!("arena slot {j} not materialised"))
                })
                .collect::<Result<_>>()?;
            transport
                .send(t32, send.port, send.dst, &rows)
                .with_context(|| {
                    format!(
                        "rank {}: send to {} port {} in round {t}",
                        shard.proc, send.dst, send.port
                    )
                })?;
            let elems = (rows.len() * w) as u64;
            sent_max = sent_max.max(elems);
            stats.messages += 1;
            stats.elems += elems;
        }
        stats.per_round_sent_max.push(sent_max);
        for recv in &round.recvs {
            let rows = transport
                .recv(t32, recv.port, recv.src)
                .with_context(|| {
                    format!(
                        "rank {}: recv from {} port {} in round {t}",
                        shard.proc, recv.src, recv.port
                    )
                })?;
            ensure!(
                rows.len() == recv.n_slots,
                "rank {}: round {t} message from {} carries {} packets, schedule says {}",
                shard.proc,
                recv.src,
                rows.len(),
                recv.n_slots
            );
            ensure!(
                recv.first_local == next,
                "shard arena misalignment at rank {} round {t}",
                shard.proc
            );
            for row in rows {
                ensure!(
                    row.len() == w,
                    "rank {}: packet width {} != {w} from {}",
                    shard.proc,
                    row.len(),
                    recv.src
                );
                arena[next] = Some(row);
                next += 1;
            }
        }
        transport
            .barrier(t32)
            .with_context(|| format!("rank {}: barrier for round {t}", shard.proc))?;
        stats.rounds += 1;
    }
    let output = match &shard.output {
        None => None,
        Some(comb) => Some(
            eval(&arena, comb).with_context(|| format!("rank {}: final output", shard.proc))?,
        ),
    };
    Ok((output, stats))
}

/// Merge per-rank measurements into the global [`SimReport`] the
/// simulator would produce: `C1` from barriers, `m_t` as the max over
/// ranks, `C2` as their sum.
pub fn merge_stats(n_rounds: usize, stats: &[PeerStats]) -> SimReport {
    let mut per_round_max = vec![0u64; n_rounds];
    for s in stats {
        for (t, &m) in s.per_round_sent_max.iter().enumerate() {
            per_round_max[t] = per_round_max[t].max(m);
        }
    }
    SimReport {
        c1: n_rounds as u64,
        c2: per_round_max.iter().sum(),
        per_round_max,
        messages: stats.iter().map(|s| s.messages).sum(),
        bandwidth: stats.iter().map(|s| s.elems).sum(),
    }
}

/// Run all ranks of a sharded plan as threads over a fresh in-process
/// mesh of the given kind — the test/bench harness for peer execution
/// (`examples/peer_encode.rs` does the same dance with real processes
/// over TCP).
pub fn spawn_local<F: Field + Sync>(
    sharded: &ShardedPlan,
    f: &F,
    inputs: &[Packet],
    kind: TransportKind,
    timeout: Duration,
) -> Result<PeerRun> {
    ensure!(
        inputs.len() == sharded.n_inputs,
        "{} inputs for a {}-input plan",
        inputs.len(),
        sharded.n_inputs
    );
    let w = inputs.first().map_or(0, |p| p.len());
    for pkt in inputs {
        ensure!(pkt.len() == w, "ragged input widths");
    }
    let max_msg_bytes = sharded.max_msg_packets * w * 8;
    let mesh = transport::mesh(kind, &sharded.procs, sharded.ports, max_msg_bytes, timeout)?;
    let ran: Vec<Result<(ProcId, Option<Packet>, PeerStats)>> = std::thread::scope(|s| {
        let handles: Vec<_> = sharded
            .shards
            .iter()
            .zip(mesh)
            .map(|(shard, mut transport)| {
                let my_inputs: Vec<Packet> =
                    shard.owned.iter().map(|&k| inputs[k].clone()).collect();
                s.spawn(move || {
                    let (out, stats) =
                        execute_shard(shard, f, w, &my_inputs, transport.as_mut())?;
                    Ok((shard.proc, out, stats))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("peer rank panicked"))
            .collect()
    });
    let mut outputs = Outputs::new();
    let mut stats = Vec::with_capacity(ran.len());
    for r in ran {
        let (proc, out, st) = r?;
        if let Some(pkt) = out {
            outputs.insert(proc, pkt);
        }
        stats.push(st);
    }
    Ok(PeerRun {
        outputs,
        measured: merge_stats(sharded.n_rounds, &stats),
    })
}

/// Convenience: shard + run in one call (plan-cache paths hold a
/// [`ShardedPlan`] and call [`spawn_local`] directly).
pub fn run_peer<F: Field + Sync>(
    plan: &Plan,
    f: &F,
    inputs: &[Packet],
    kind: TransportKind,
    timeout: Duration,
) -> Result<PeerRun> {
    let owners: Vec<ProcId> = (0..plan.n_inputs).collect();
    let sharded = ShardedPlan::new(plan, f, &owners)?;
    spawn_local(&sharded, f, inputs, kind, timeout)
}
